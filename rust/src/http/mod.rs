//! Network-facing HTTP/JSON serving front end.
//!
//! The paper's chip is an edge-AI *service*: sessions arrive over a
//! network, queues are bounded, and overload must degrade gracefully
//! instead of hanging. This module puts the in-process serving stack
//! ([`crate::serve::ServeRuntime`]) behind a hand-rolled, dependency-free
//! HTTP/1.1 server (`std::net` only — the offline environment has no
//! crate registry, per the mik-sdk pure-Rust-JSON rationale):
//!
//! - [`framing`] — bounded-memory request parsing (hard caps on request
//!   line, header bytes/count and `Content-Length`, each mapping to its
//!   own 4xx) and `Content-Length`-framed responses with keep-alive.
//! - [`gateway`] — the routing/bridge layer: JSON workload-spec
//!   submissions become [`crate::serve::SessionSpec`]s via the same
//!   `workload_from_spec` grammar as the CLI, backpressure surfaces as
//!   **429 + `Retry-After`** straight from [`crate::Error::QueueFull`],
//!   and `/metrics` exposes queue depth, verdict tallies, the
//!   [`crate::serve::HealthReport`] ledger and per-class energy totals.
//! - [`server`] — the TCP accept loop, per-connection threads with
//!   socket timeouts, and the clean-drain shutdown path built on
//!   [`crate::serve::ServeRuntime::shutdown`].
//! - [`client`] — a minimal blocking keep-alive client for the load
//!   generator (`examples/http_load.rs`), the `BENCH_http.json` bench
//!   and the end-to-end tests.
//!
//! Endpoints: `POST /v1/sessions`, `GET /v1/sessions/<id>`,
//! `GET /metrics`, `GET /healthz`, `POST /admin/shutdown`
//! (flag-gated bearer token). See README §serve-http for the wire
//! contract and curl examples.
//!
//! Determinism: the serving physics is untouched — an outcome fetched
//! over HTTP carries `f64::to_bits` hex pins of its energy totals and is
//! bit-identical to the same spec served in-process (pinned in
//! `tests/http_api.rs`).

pub mod client;
pub mod framing;
pub mod gateway;
pub mod server;

pub use client::{Client, ClientResponse};
pub use framing::{Request, Response};
pub use gateway::{Gateway, GatewayConfig};
pub use server::{HttpConfig, HttpServer, HttpStats};
