//! Deterministic fault injection for the NoC fabric.
//!
//! A [`FaultPlan`] is a seeded schedule of degradation events — router
//! kills, link kills, level-wide link throttles and transient congestion
//! windows — each with a cycle- or timestep-keyed activation. The
//! simulator arms a plan by resolving it against its topology (seeded
//! `kill-frac` events expand to a concrete router set here, so the same
//! plan + seed always kills the same routers) into a [`FaultState`] it
//! consults on its hot path.
//!
//! **Determinism contract** (pinned by `tests/chaos_faults.rs` and the
//! equivalence suite):
//! * An empty plan arms to nothing — the simulator stores `None` and its
//!   behavior is bit-identical to one that never saw a plan, including
//!   `switch_visits()`.
//! * Every degraded run is a pure function of (topology, traffic, plan):
//!   event expansion is seeded, activation order is `(when, plan order)`,
//!   and rerouting reuses the topology's deterministic lowest-id policy
//!   over the alive subgraph ([`Topology::out_port_table_masked`]).
//! * Flits are conserved: `injected == delivered + dropped + in-flight`
//!   at every cycle. Kills drop eagerly (the dead switch and the links
//!   feeding it drain into the `FlitDropped` ledger class); link kills
//!   strand flits already committed to the severed link, which the drain
//!   loop classifies as `FabricDegraded` instead of spinning.

use super::topology::{NodeId, Topology};
use crate::util::prng::Rng;
use crate::{Error, Result};

/// When a fault event activates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum When {
    /// At the start of simulation cycle `c` (the first stepped cycle
    /// is 1; `Cycle(0)` fires on the first step).
    Cycle(u64),
    /// When [`crate::noc::NocSim::set_timestep`] first reaches `t`.
    Timestep(u32),
}

/// Which link level a throttle applies to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkLevel {
    /// Intra-domain links (core↔L1 wires).
    L1,
    /// Scale-up links (either endpoint is a level-2 router).
    L2,
}

/// What breaks.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// Permanently kill a router node: its buffers (and flits already
    /// committed onto its links) drop, routing recomputes around it, and
    /// it never re-enters the active worklist.
    RouterKill {
        /// The router's node id.
        node: NodeId,
    },
    /// Permanently sever the link between adjacent nodes `a` and `b`:
    /// routing recomputes around it; flits already committed to the
    /// link's output FIFO strand (→ `FabricDegraded`).
    LinkKill {
        /// One endpoint.
        a: NodeId,
        /// The other endpoint.
        b: NodeId,
    },
    /// Throttle every link of one fabric level to one traversal per
    /// `factor` cycles (`factor == 1` is a no-op).
    LinkThrottle {
        /// Which links slow down.
        level: LinkLevel,
        /// Period in cycles between permitted traversals.
        factor: u64,
    },
    /// Transient congestion: the node's arbiter stalls for `duration`
    /// cycles (upstream traffic backpressures), then recovers.
    Congest {
        /// The congested node.
        node: NodeId,
        /// Window length in cycles.
        duration: u64,
    },
    /// Seeded random kill of `round(frac × router count)` routers,
    /// resolved deterministically when the plan is armed.
    KillFrac {
        /// Fraction of routers to kill, in `[0, 1]`.
        frac: f64,
        /// PRNG seed for the router choice.
        seed: u64,
    },
    /// Permanently kill one off-chip level-3 router of a cluster ring
    /// (the extended scale-out node attached to chip `chip`). Only
    /// meaningful on a multi-chip [`crate::cluster::Cluster`]; a plain
    /// on-chip fabric rejects it at validation.
    RouterKillL3 {
        /// Chip index whose L3 ring node dies.
        chip: usize,
    },
    /// Throttle every off-chip (chip↔chip) ring link to one traversal
    /// per `factor` L3 cycles (`factor == 1` is a no-op). Only
    /// meaningful on a multi-chip cluster.
    LinkThrottleL3 {
        /// Period in L3 cycles between permitted traversals.
        factor: u64,
    },
}

/// One scheduled fault.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    /// Activation point.
    pub when: When,
    /// What happens.
    pub kind: FaultKind,
}

/// A deterministic, seeded schedule of fabric faults. The empty plan is
/// the no-fault contract: arming it changes nothing, bit-for-bit.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    /// Scheduled events (plan order breaks activation-cycle ties).
    pub events: Vec<FaultEvent>,
}

/// CLI grammar for `--fault-plan` (also `FaultPlan::parse`).
pub const FAULT_SPEC_USAGE: &str = "fault plan spec: ';'-separated events \
     — kill-router:<node>@<when>; kill-link:<a>-<b>@<when>; \
     throttle-l1:<factor>@<when>; throttle-l2:<factor>@<when>; \
     congest:<node>+<cycles>@<when>; kill-frac:<frac>#<seed>@<when>; \
     kill-l3:<chip>@<when>; throttle-l3:<factor>@<when> (L3 events need \
     --chips > 1) — with <when> a cycle number or t<timestep> (e.g. \
     \"kill-router:3@200;kill-frac:0.2#7@t4\")";

impl FaultPlan {
    /// The empty plan: no faults, provably free when armed.
    pub fn none() -> Self {
        Self::default()
    }

    /// True when the plan schedules nothing.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Schedule a router kill.
    pub fn kill_router(mut self, node: NodeId, when: When) -> Self {
        self.events.push(FaultEvent { when, kind: FaultKind::RouterKill { node } });
        self
    }

    /// Schedule a link kill.
    pub fn kill_link(mut self, a: NodeId, b: NodeId, when: When) -> Self {
        self.events.push(FaultEvent { when, kind: FaultKind::LinkKill { a, b } });
        self
    }

    /// Schedule a level-wide link throttle.
    pub fn throttle(mut self, level: LinkLevel, factor: u64, when: When) -> Self {
        self.events.push(FaultEvent { when, kind: FaultKind::LinkThrottle { level, factor } });
        self
    }

    /// Schedule a transient congestion window.
    pub fn congest(mut self, node: NodeId, duration: u64, when: When) -> Self {
        self.events.push(FaultEvent { when, kind: FaultKind::Congest { node, duration } });
        self
    }

    /// Schedule a seeded fractional router kill.
    pub fn kill_frac(mut self, frac: f64, seed: u64, when: When) -> Self {
        self.events.push(FaultEvent { when, kind: FaultKind::KillFrac { frac, seed } });
        self
    }

    /// Schedule an off-chip level-3 router kill (cluster rings only).
    pub fn kill_l3(mut self, chip: usize, when: When) -> Self {
        self.events.push(FaultEvent { when, kind: FaultKind::RouterKillL3 { chip } });
        self
    }

    /// Schedule an off-chip ring-link throttle (cluster rings only).
    pub fn throttle_l3(mut self, factor: u64, when: When) -> Self {
        self.events.push(FaultEvent { when, kind: FaultKind::LinkThrottleL3 { factor } });
        self
    }

    /// The plan as seen by a retry attempt that starts `offset` cycles
    /// into the original schedule: cycle-keyed events that would already
    /// have fired are dropped (a kill that fired is healed by the
    /// power-cycle; a congest window that opened has closed), and later
    /// ones shift earlier by `offset` so the storm's *remaining* tail
    /// still hits the retried session at the same absolute point.
    /// Timestep-keyed events re-fire unchanged — they key off workload
    /// progress, which the retry replays from the start. `offset == 0`
    /// is an exact clone, so retry-disabled paths stay bit-identical.
    pub fn shifted(&self, offset: u64) -> FaultPlan {
        if offset == 0 {
            return self.clone();
        }
        let mut plan = FaultPlan::none();
        for ev in &self.events {
            match ev.when {
                When::Cycle(c) if c > offset => {
                    plan.events.push(FaultEvent {
                        when: When::Cycle(c - offset),
                        kind: ev.kind.clone(),
                    });
                }
                When::Cycle(_) => {}
                When::Timestep(_) => plan.events.push(ev.clone()),
            }
        }
        plan
    }

    /// True when the plan schedules off-chip (L3) events.
    pub fn has_l3_events(&self) -> bool {
        self.events.iter().any(|ev| {
            matches!(
                ev.kind,
                FaultKind::RouterKillL3 { .. } | FaultKind::LinkThrottleL3 { .. }
            )
        })
    }

    /// Split into the on-chip plan (armed identically on every shard
    /// fabric of a cluster) and the L3-only plan (armed on the off-chip
    /// ring). Event order within each half is preserved.
    pub fn split_l3(&self) -> (FaultPlan, FaultPlan) {
        let mut chip = FaultPlan::none();
        let mut l3 = FaultPlan::none();
        for ev in &self.events {
            match ev.kind {
                FaultKind::RouterKillL3 { .. } | FaultKind::LinkThrottleL3 { .. } => {
                    l3.events.push(ev.clone());
                }
                _ => chip.events.push(ev.clone()),
            }
        }
        (chip, l3)
    }

    /// Validate the L3 half of the plan against a cluster of `chips`
    /// chips: killed ring nodes must exist, and any L3 event at all
    /// requires more than one chip (a single chip has no off-chip ring).
    pub fn validate_l3(&self, chips: usize) -> Result<()> {
        for ev in &self.events {
            match ev.kind {
                FaultKind::RouterKillL3 { chip } => {
                    if chips < 2 {
                        return Err(Error::Config(
                            "fault plan: kill-l3 requires a multi-chip cluster (--chips > 1)"
                                .into(),
                        ));
                    }
                    if chip >= chips {
                        return Err(Error::Config(format!(
                            "fault plan: kill-l3 chip {chip} out of range (cluster has \
                             {chips} chips)"
                        )));
                    }
                }
                FaultKind::LinkThrottleL3 { .. } if chips < 2 => {
                    return Err(Error::Config(
                        "fault plan: throttle-l3 requires a multi-chip cluster (--chips > 1)"
                            .into(),
                    ));
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Parse the CLI spec grammar ([`FAULT_SPEC_USAGE`]). The empty
    /// string parses to [`FaultPlan::none`].
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        // Every diagnostic names the event ordinal, the offending token
        // and its char position inside the event, so a long ';'-joined
        // spec is debuggable without bisecting it by hand.
        fn bad(ord: usize, ev: &str, what: &str) -> Error {
            Error::Config(format!(
                "fault plan event #{} ({ev:?}): {what} — {FAULT_SPEC_USAGE}",
                ord + 1
            ))
        }
        fn num<T: std::str::FromStr>(ord: usize, ev: &str, field: &str, tok: &str) -> Result<T> {
            let tok = tok.trim();
            tok.parse().map_err(|_| {
                let pos = ev.find(tok).unwrap_or(0);
                bad(ord, ev, &format!("bad {field} {tok:?} at char {pos}"))
            })
        }
        let mut plan = FaultPlan::none();
        for (ord, ev) in spec.split(';').map(str::trim).filter(|s| !s.is_empty()).enumerate() {
            let (head, when_s) = ev
                .rsplit_once('@')
                .ok_or_else(|| bad(ord, ev, "missing '@<when>' activation suffix"))?;
            let when = parse_when(when_s.trim()).ok_or_else(|| {
                bad(
                    ord,
                    ev,
                    &format!(
                        "bad activation time {:?} at char {} (want <cycle> or t<timestep>)",
                        when_s.trim(),
                        head.len() + 1
                    ),
                )
            })?;
            let kind = if let Some(rest) = head.strip_prefix("kill-router:") {
                FaultKind::RouterKill { node: num(ord, ev, "router node", rest)? }
            } else if let Some(rest) = head.strip_prefix("kill-link:") {
                let (a, b) = rest.split_once('-').ok_or_else(|| {
                    bad(ord, ev, "missing '-' between link endpoints (want kill-link:<a>-<b>)")
                })?;
                FaultKind::LinkKill {
                    a: num(ord, ev, "link endpoint a", a)?,
                    b: num(ord, ev, "link endpoint b", b)?,
                }
            } else if let Some(rest) = head.strip_prefix("throttle-l1:") {
                FaultKind::LinkThrottle {
                    level: LinkLevel::L1,
                    factor: num(ord, ev, "throttle factor", rest)?,
                }
            } else if let Some(rest) = head.strip_prefix("throttle-l2:") {
                FaultKind::LinkThrottle {
                    level: LinkLevel::L2,
                    factor: num(ord, ev, "throttle factor", rest)?,
                }
            } else if let Some(rest) = head.strip_prefix("congest:") {
                let (node, dur) = rest.split_once('+').ok_or_else(|| {
                    bad(ord, ev, "missing '+' between node and duration (want congest:<node>+<cycles>)")
                })?;
                FaultKind::Congest {
                    node: num(ord, ev, "congested node", node)?,
                    duration: num(ord, ev, "congestion cycles", dur)?,
                }
            } else if let Some(rest) = head.strip_prefix("kill-frac:") {
                let (frac, seed) = rest.split_once('#').ok_or_else(|| {
                    bad(ord, ev, "missing '#' between fraction and seed (want kill-frac:<frac>#<seed>)")
                })?;
                FaultKind::KillFrac {
                    frac: num(ord, ev, "kill fraction", frac)?,
                    seed: num(ord, ev, "kill seed", seed)?,
                }
            } else if let Some(rest) = head.strip_prefix("kill-l3:") {
                FaultKind::RouterKillL3 { chip: num(ord, ev, "l3 chip", rest)? }
            } else if let Some(rest) = head.strip_prefix("throttle-l3:") {
                FaultKind::LinkThrottleL3 { factor: num(ord, ev, "throttle factor", rest)? }
            } else {
                let kind_tok = head.split(':').next().unwrap_or(head);
                return Err(bad(ord, ev, &format!("unknown event kind {kind_tok:?} at char 0")));
            };
            plan.events.push(FaultEvent { when, kind });
        }
        plan.validate_values()?;
        Ok(plan)
    }

    /// Topology-free value checks (ranges a builder can verify before the
    /// fabric exists).
    pub fn validate_values(&self) -> Result<()> {
        for ev in &self.events {
            match &ev.kind {
                FaultKind::LinkThrottle { factor, .. } if *factor == 0 => {
                    return Err(Error::Config("fault plan: throttle factor must be ≥ 1".into()));
                }
                FaultKind::Congest { duration, .. } if *duration == 0 => {
                    return Err(Error::Config(
                        "fault plan: congestion duration must be ≥ 1 cycle".into(),
                    ));
                }
                FaultKind::KillFrac { frac, .. } if !(0.0..=1.0).contains(frac) => {
                    return Err(Error::Config(format!(
                        "fault plan: kill fraction {frac} outside [0, 1]"
                    )));
                }
                FaultKind::LinkThrottleL3 { factor } if *factor == 0 => {
                    return Err(Error::Config(
                        "fault plan: throttle-l3 factor must be ≥ 1".into(),
                    ));
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Full validation against the fabric the plan will run on: killed
    /// nodes must be routers (cores are compute endpoints, not fabric),
    /// severed links must exist.
    pub fn validate(&self, topo: &Topology) -> Result<()> {
        self.validate_values()?;
        for ev in &self.events {
            match &ev.kind {
                FaultKind::RouterKill { node } | FaultKind::Congest { node, .. } => {
                    if *node >= topo.len() || !topo.kind(*node).is_router() {
                        return Err(Error::Config(format!(
                            "fault plan: node {node} is not a router of {}",
                            topo.name
                        )));
                    }
                }
                FaultKind::LinkKill { a, b } => {
                    if *a >= topo.len() || *b >= topo.len() || !topo.neighbors(*a).contains(b) {
                        return Err(Error::Config(format!(
                            "fault plan: no link {a}-{b} in {}",
                            topo.name
                        )));
                    }
                }
                FaultKind::RouterKillL3 { .. } | FaultKind::LinkThrottleL3 { .. } => {
                    return Err(Error::Config(format!(
                        "fault plan: L3 events target the off-chip cluster ring, not the \
                         on-chip fabric {} — they require a multi-chip cluster (--chips > 1)",
                        topo.name
                    )));
                }
                _ => {}
            }
        }
        Ok(())
    }
}

fn parse_when(s: &str) -> Option<When> {
    if let Some(t) = s.strip_prefix('t') {
        t.parse().ok().map(When::Timestep)
    } else {
        s.parse().ok().map(When::Cycle)
    }
}

/// Degradation counters surfaced by `NocSim::fabric_health` — all zero
/// (and `armed == false`) when no fault plan is armed. Counters follow
/// the accounting window (`reset_accounting` zeroes them and re-arms the
/// plan, healing the fabric — warm chips stay bit-identical to fresh).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FabricHealth {
    /// A fault plan with at least one event is armed.
    pub armed: bool,
    /// Flits discarded (dead-router drain or severed route).
    pub dropped: u64,
    /// Flit-hops taken over links that differ from the pristine route
    /// (the redundancy actually exercised).
    pub rerouted_hops: u64,
    /// Routers killed so far.
    pub dead_routers: u64,
    /// Links severed so far (a router kill does not count its links).
    pub dead_links: u64,
}

/// One concrete, topology-resolved action (`KillFrac` already expanded).
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum Action {
    Kill(NodeId),
    CutLink(NodeId, NodeId),
    Throttle(LinkLevel, u64),
    Congest(NodeId, u64),
}

/// An armed plan: the resolved schedule plus the degradation state the
/// simulator mutates as events fire. Created by [`FaultState::arm`].
#[derive(Debug, Clone)]
pub(crate) struct FaultState {
    /// The source plan, retained so `reset_accounting` can re-arm.
    pub plan: FaultPlan,
    /// Cycle-keyed actions sorted by activation cycle (stable sort: plan
    /// order breaks ties); `cursor` marks the first unapplied entry.
    by_cycle: Vec<(u64, Action)>,
    cursor: usize,
    /// Timestep-keyed actions; each fires once, whenever the timestep
    /// first reaches it.
    by_timestep: Vec<(u32, Action, bool)>,
    /// Kills applied so far.
    pub node_dead: Vec<bool>,
    /// Severed links applied so far (normalized `a < b`, sorted).
    pub dead_links: Vec<(NodeId, NodeId)>,
    /// Degraded routing table (pristine until the first kill/cut).
    pub out_port: Vec<Vec<u16>>,
    /// Open congestion windows: `(node, re-enable cycle)`.
    pub congested: Vec<(NodeId, u64)>,
    /// Active throttle period per level (1 = unthrottled).
    pub throttle_l1: u64,
    /// Active throttle period for scale-up links.
    pub throttle_l2: u64,
    /// Any kill or cut applied: routes differ from pristine, unroutable
    /// heads must drop, and fixed points classify as `FabricDegraded`.
    pub degraded: bool,
    /// Flits discarded this accounting window.
    pub dropped: u64,
    /// Detour flit-hops this accounting window.
    pub rerouted_hops: u64,
}

impl FaultState {
    /// Resolve `plan` against `topo`: validate, expand seeded `KillFrac`
    /// events into concrete router kills, sort the cycle schedule. The
    /// caller passes the pristine out-port table (cloned) as the initial
    /// degraded table.
    pub(crate) fn arm(
        plan: &FaultPlan,
        topo: &Topology,
        pristine: Vec<Vec<u16>>,
    ) -> Result<Box<FaultState>> {
        plan.validate(topo)?;
        let mut by_cycle = Vec::new();
        let mut by_timestep = Vec::new();
        for ev in &plan.events {
            let actions: Vec<Action> = match &ev.kind {
                FaultKind::RouterKill { node } => vec![Action::Kill(*node)],
                FaultKind::LinkKill { a, b } => {
                    vec![Action::CutLink((*a).min(*b), (*a).max(*b))]
                }
                FaultKind::LinkThrottle { level, factor } => {
                    vec![Action::Throttle(*level, *factor)]
                }
                FaultKind::Congest { node, duration } => {
                    vec![Action::Congest(*node, *duration)]
                }
                FaultKind::KillFrac { frac, seed } => {
                    let routers = topo.routers();
                    let k = ((frac * routers.len() as f64).round() as usize).min(routers.len());
                    let mut rng = Rng::new(*seed);
                    let mut picks = rng.choose_k(routers.len(), k);
                    picks.sort_unstable();
                    picks.into_iter().map(|i| Action::Kill(routers[i])).collect()
                }
                // Rejected by `validate` above: L3 events never reach an
                // on-chip fabric (the cluster arms them on its ring).
                FaultKind::RouterKillL3 { .. } | FaultKind::LinkThrottleL3 { .. } => Vec::new(),
            };
            for a in actions {
                match ev.when {
                    When::Cycle(c) => by_cycle.push((c, a)),
                    When::Timestep(t) => by_timestep.push((t, a, false)),
                }
            }
        }
        by_cycle.sort_by_key(|&(c, _)| c);
        Ok(Box::new(FaultState {
            plan: plan.clone(),
            by_cycle,
            cursor: 0,
            by_timestep,
            node_dead: vec![false; topo.len()],
            dead_links: Vec::new(),
            out_port: pristine,
            congested: Vec::new(),
            throttle_l1: 1,
            throttle_l2: 1,
            degraded: false,
            dropped: 0,
            rerouted_hops: 0,
        }))
    }

    /// Cycle-keyed actions due at/before `cycle`; advances the cursor.
    /// Returns an empty (allocation-free) vec when nothing is due.
    pub(crate) fn take_due_cycle(&mut self, cycle: u64) -> Vec<Action> {
        if self.cursor >= self.by_cycle.len() || self.by_cycle[self.cursor].0 > cycle {
            return Vec::new();
        }
        let mut due = Vec::new();
        while self.cursor < self.by_cycle.len() && self.by_cycle[self.cursor].0 <= cycle {
            due.push(self.by_cycle[self.cursor].1.clone());
            self.cursor += 1;
        }
        due
    }

    /// Timestep-keyed actions due at `ts`, each fired at most once.
    pub(crate) fn take_due_timestep(&mut self, ts: u32) -> Vec<Action> {
        if self.by_timestep.iter().all(|&(t, _, fired)| fired || t > ts) {
            return Vec::new();
        }
        let mut due = Vec::new();
        for (t, a, fired) in &mut self.by_timestep {
            if !*fired && *t <= ts {
                *fired = true;
                due.push(a.clone());
            }
        }
        due
    }

    /// Congestion windows expired by `cycle` (removed; the simulator
    /// re-enables the switches).
    pub(crate) fn take_expired_congestion(&mut self, cycle: u64) -> Vec<NodeId> {
        if self.congested.iter().all(|&(_, until)| until > cycle) {
            return Vec::new();
        }
        let mut expired = Vec::new();
        self.congested.retain(|&(n, until)| {
            if until <= cycle {
                expired.push(n);
                false
            } else {
                true
            }
        });
        expired
    }

    /// True when the link `a`–`b` must not move a flit: either endpoint
    /// is dead or the link itself is severed.
    pub(crate) fn link_blocked(&self, a: NodeId, b: NodeId) -> bool {
        if self.node_dead[a] || self.node_dead[b] {
            return true;
        }
        if self.dead_links.is_empty() {
            return false;
        }
        let key = if a < b { (a, b) } else { (b, a) };
        self.dead_links.binary_search(&key).is_ok()
    }

    /// True when a link of the given level sits out cycle `cycle` under
    /// an active throttle.
    pub(crate) fn throttled(&self, l2: bool, cycle: u64) -> bool {
        let f = if l2 { self.throttle_l2 } else { self.throttle_l1 };
        f > 1 && cycle % f != 0
    }

    /// How many consecutive zero-progress cycles the drain loop should
    /// tolerate at `cycle`: pending cycle-keyed activations, open
    /// congestion windows and throttle periods can all unblock the
    /// fabric without external input. 0 = a zero-progress cycle is a
    /// true fixed point.
    pub(crate) fn zero_progress_tolerance(&self, cycle: u64) -> u64 {
        let mut tol = 0u64;
        if self.cursor < self.by_cycle.len() {
            tol = tol.max(self.by_cycle[self.cursor].0.saturating_sub(cycle) + 1);
        }
        for &(_, until) in &self.congested {
            tol = tol.max(until.saturating_sub(cycle) + 1);
        }
        if self.throttle_l1 > 1 {
            tol = tol.max(self.throttle_l1);
        }
        if self.throttle_l2 > 1 {
            tol = tol.max(self.throttle_l2);
        }
        tol
    }

    /// Current degradation counters.
    pub(crate) fn health(&self) -> FabricHealth {
        FabricHealth {
            armed: true,
            dropped: self.dropped,
            rerouted_hops: self.rerouted_hops,
            dead_routers: self.node_dead.iter().filter(|&&d| d).count() as u64,
            dead_links: self.dead_links.len() as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_every_event_kind() {
        let plan = FaultPlan::parse(
            "kill-router:3@200; kill-link:0-12@t2; throttle-l1:4@0; \
             throttle-l2:8@t1; congest:5+30@100; kill-frac:0.25#42@t3",
        )
        .unwrap();
        assert_eq!(plan.events.len(), 6);
        assert_eq!(
            plan.events[0],
            FaultEvent { when: When::Cycle(200), kind: FaultKind::RouterKill { node: 3 } }
        );
        assert_eq!(
            plan.events[1],
            FaultEvent { when: When::Timestep(2), kind: FaultKind::LinkKill { a: 0, b: 12 } }
        );
        assert_eq!(
            plan.events[2],
            FaultEvent {
                when: When::Cycle(0),
                kind: FaultKind::LinkThrottle { level: LinkLevel::L1, factor: 4 }
            }
        );
        assert_eq!(
            plan.events[4],
            FaultEvent { when: When::Cycle(100), kind: FaultKind::Congest { node: 5, duration: 30 } }
        );
        assert_eq!(
            plan.events[5],
            FaultEvent { when: When::Timestep(3), kind: FaultKind::KillFrac { frac: 0.25, seed: 42 } }
        );
    }

    #[test]
    fn l3_grammar_parses_splits_and_validates() {
        let plan = FaultPlan::parse("kill-l3:1@t2; throttle-l3:4@100; kill-router:3@5").unwrap();
        assert_eq!(plan.events.len(), 3);
        assert!(plan.has_l3_events());
        assert_eq!(
            plan.events[0],
            FaultEvent { when: When::Timestep(2), kind: FaultKind::RouterKillL3 { chip: 1 } }
        );
        assert_eq!(
            plan.events[1],
            FaultEvent { when: When::Cycle(100), kind: FaultKind::LinkThrottleL3 { factor: 4 } }
        );
        // The split keeps on-chip and L3 halves in plan order.
        let (chip, l3) = plan.split_l3();
        assert_eq!(chip.events.len(), 1);
        assert_eq!(l3.events.len(), 2);
        assert!(!chip.has_l3_events() && l3.has_l3_events());
        // The on-chip fabric refuses L3 events outright.
        let err = plan.validate(&Topology::fullerene()).unwrap_err().to_string();
        assert!(err.contains("multi-chip"), "{err}");
        // Cluster-side checks: chip index range and the chips > 1 rule.
        l3.validate_l3(4).unwrap();
        assert!(l3.validate_l3(1).is_err(), "L3 events need chips > 1");
        let oob = FaultPlan::none().kill_l3(4, When::Cycle(1));
        assert!(oob.validate_l3(4).is_err(), "chip 4 of a 4-chip ring");
        assert!(FaultPlan::parse("throttle-l3:0@5").is_err(), "factor 0");
    }

    #[test]
    fn shifted_drops_fired_cycles_and_keeps_timesteps() {
        let plan = FaultPlan::none()
            .congest(0, 300, When::Cycle(100))
            .kill_router(3, When::Cycle(500))
            .throttle(LinkLevel::L2, 4, When::Timestep(2));
        // Zero offset is an exact clone (the retry-off contract).
        assert_eq!(plan.shifted(0), plan);
        // Offset past the congest window: it has fired and healed; the
        // later kill shifts earlier; the timestep event re-fires as-is.
        let s = plan.shifted(200);
        assert_eq!(s.events.len(), 2);
        assert_eq!(
            s.events[0],
            FaultEvent { when: When::Cycle(300), kind: FaultKind::RouterKill { node: 3 } }
        );
        assert_eq!(
            s.events[1],
            FaultEvent {
                when: When::Timestep(2),
                kind: FaultKind::LinkThrottle { level: LinkLevel::L2, factor: 4 }
            }
        );
        // Offset past everything cycle-keyed: only timesteps remain.
        let s = plan.shifted(10_000);
        assert_eq!(s.events.len(), 1);
        assert!(matches!(s.events[0].when, When::Timestep(2)));
        // An event exactly at the offset boundary counts as fired.
        let edge = FaultPlan::none().kill_router(1, When::Cycle(200));
        assert!(edge.shifted(200).is_empty());
    }

    #[test]
    fn empty_spec_parses_to_none() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ;  ; ").unwrap().is_empty());
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::none());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "kill-router:3",        // no @when
            "kill-router:x@5",      // bad node
            "kill-link:3@5",        // missing endpoint
            "warp-core:3@5",        // unknown kind
            "congest:5@100",        // missing +duration
            "kill-frac:0.5@3",      // missing #seed
            "throttle-l1:0@5",      // factor 0
            "kill-frac:1.5#2@3",    // frac out of range
            "congest:5+0@9",        // zero-length window
        ] {
            let err = FaultPlan::parse(bad).unwrap_err().to_string();
            assert!(err.contains("fault"), "{bad}: {err}");
        }
    }

    #[test]
    fn validate_rejects_cores_and_missing_links() {
        let t = Topology::fullerene(); // nodes 0..12 routers, 12..32 cores
        let core_kill = FaultPlan::none().kill_router(15, When::Cycle(1));
        assert!(core_kill.validate(&t).is_err(), "killed a core");
        let no_such_link = FaultPlan::none().kill_link(0, 1, When::Cycle(1));
        assert!(no_such_link.validate(&t).is_err(), "routers 0-1 are not adjacent");
        let ok = FaultPlan::none()
            .kill_router(3, When::Cycle(1))
            .kill_link(12, 0, When::Cycle(2));
        ok.validate(&t).unwrap();
    }

    #[test]
    fn kill_frac_resolution_is_seed_deterministic() {
        let t = Topology::fullerene();
        let plan = FaultPlan::none().kill_frac(0.25, 7, When::Cycle(5));
        let a = FaultState::arm(&plan, &t, t.out_port_table()).unwrap();
        let b = FaultState::arm(&plan, &t, t.out_port_table()).unwrap();
        let kills = |s: &FaultState| {
            s.clone_by_cycle()
        };
        let (ka, kb) = (kills(&a), kills(&b));
        assert_eq!(ka, kb, "same seed must kill the same routers");
        // 25 % of 12 routers rounds to 3 kills.
        assert_eq!(ka.len(), 3);
        for (c, act) in &ka {
            assert_eq!(*c, 5);
            match act {
                Action::Kill(n) => assert!(t.kind(*n).is_router()),
                other => panic!("unexpected action {other:?}"),
            }
        }
        let other_seed = FaultPlan::none().kill_frac(0.25, 8, When::Cycle(5));
        let c = FaultState::arm(&other_seed, &t, t.out_port_table()).unwrap();
        assert_ne!(ka, c.clone_by_cycle(), "different seed, different routers (w.h.p.)");
    }

    #[test]
    fn schedule_cursor_and_timestep_firing() {
        let t = Topology::fullerene();
        let plan = FaultPlan::none()
            .kill_router(2, When::Cycle(10))
            .kill_router(4, When::Cycle(3))
            .kill_router(6, When::Timestep(2));
        let mut s = FaultState::arm(&plan, &t, t.out_port_table()).unwrap();
        assert!(s.take_due_cycle(2).is_empty());
        assert_eq!(s.take_due_cycle(3), vec![Action::Kill(4)]);
        assert!(s.take_due_cycle(9).is_empty());
        assert_eq!(s.take_due_cycle(50), vec![Action::Kill(2)]);
        assert!(s.take_due_timestep(1).is_empty());
        assert_eq!(s.take_due_timestep(2), vec![Action::Kill(6)]);
        assert!(s.take_due_timestep(2).is_empty(), "timestep events fire once");
    }

    #[test]
    fn zero_progress_tolerance_tracks_self_unblocking_faults() {
        let t = Topology::fullerene();
        let plan = FaultPlan::none().kill_router(2, When::Cycle(100));
        let mut s = FaultState::arm(&plan, &t, t.out_port_table()).unwrap();
        assert!(s.zero_progress_tolerance(10) >= 90, "pending event must keep the loop alive");
        s.take_due_cycle(100);
        assert_eq!(s.zero_progress_tolerance(101), 0, "spent schedule tolerates nothing");
        s.throttle_l1 = 4;
        assert_eq!(s.zero_progress_tolerance(101), 4);
        s.congested.push((3, 150));
        assert!(s.zero_progress_tolerance(101) >= 49);
    }

    impl FaultState {
        /// Test helper: the resolved cycle schedule.
        fn clone_by_cycle(&self) -> Vec<(u64, Action)> {
            self.by_cycle.clone()
        }
    }
}
