//! Streaming serving demo: many independent edge sessions — different
//! users, different traffic — submitted to the persistent `ServeRuntime`
//! and served by pull-based workers on **warm, reused chips**.
//!
//! Results stream back in completion order (short sessions surface while
//! the saturation session is still running — no head-of-line blocking),
//! and the final merged report is **bit-identical** to serving the same
//! sessions sequentially on fresh chips (asserted below down to
//! `f64::to_bits`), so neither multi-threading nor warm chip reuse ever
//! changes the physics.
//!
//! ```bash
//! cargo run --release --example serve_sessions
//! ```

use fullerene_soc::benches_support::{saturation_workload, structural_net};
use fullerene_soc::datasets::Workload;
use fullerene_soc::energy::ChipReport;
use fullerene_soc::metrics::Table;
use fullerene_soc::nn::network::NetworkDesc;
use fullerene_soc::serve::{SessionSpec, SocBuilder, SyntheticStream, TrafficWorkload};

/// Structural network at the NMNIST geometry (untrained — this demo is
/// about the serving machinery, not accuracy).
fn net() -> NetworkDesc {
    let w = Workload::Nmnist;
    structural_net("serve-demo", w.inputs(), 48, w.classes(), w.timesteps())
}

/// The session mix: one session at the shared saturation recipe — the
/// same scenario the NoC benches and the CI perf-smoke job measure —
/// submitted FIRST, then two synthetic NMNIST streams (different seeds)
/// and two seeded traffic generators at the same geometry.
fn specs() -> Vec<SessionSpec> {
    let w = Workload::Nmnist;
    vec![
        SessionSpec::new(
            "user4-saturation",
            Box::new(saturation_workload(
                w.inputs(),
                w.classes(),
                w.timesteps(),
                2,
                23,
            )),
        ),
        SessionSpec::new(
            "user0-nmnist",
            Box::new(SyntheticStream::new(w, 4, 7)),
        ),
        SessionSpec::new(
            "user1-nmnist",
            Box::new(SyntheticStream::new(w, 4, 8)),
        ),
        SessionSpec::new(
            "user2-traffic",
            Box::new(TrafficWorkload::new(
                w.inputs(),
                w.classes(),
                w.timesteps(),
                0.01,
                4,
                21,
            )),
        ),
        SessionSpec::new(
            "user3-traffic",
            Box::new(TrafficWorkload::new(
                w.inputs(),
                w.classes(),
                w.timesteps(),
                0.02,
                4,
                22,
            )),
        ),
    ]
}

fn main() -> fullerene_soc::Result<()> {
    let net = net();
    let builder = SocBuilder::new().workers(4).queue_depth(8).keep_warm(true);

    // The persistent runtime: submit sessions as they "arrive" (here, all
    // at once), stream outcomes back as they finish.
    let mut rt = builder.build_serve_runtime(&net)?;
    println!("serving {} sessions across {} workers …", specs().len(), rt.workers());
    let tickets: Vec<_> = specs()
        .into_iter()
        .map(|s| rt.submit(s))
        .collect::<fullerene_soc::Result<_>>()?;
    for r in rt.outcomes() {
        match &r.outcome {
            Ok(o) => println!(
                "  finished {:16} (#{}) — {} samples, queue wait {:.3} ms",
                r.name,
                r.index,
                o.stats.samples,
                o.queue_wait_s * 1e3
            ),
            Err(e) => println!("  FAILED {:16} (#{}) — {e}", r.name, r.index),
        }
    }
    // Tickets are an equivalent per-session view (waits return instantly
    // now that everything is done).
    assert!(tickets.iter().all(|t| t.wait().is_ok()));
    let par = rt.finish()?;

    let mut t = Table::new(&["session", "samples", "p50 ms", "p99 ms", "SOPs", "pJ/SOP"]);
    for s in &par.sessions {
        t.push_row(vec![
            s.name.clone(),
            s.stats.samples.to_string(),
            format!("{:.3}", s.stats.p50_latency_ms),
            format!("{:.3}", s.stats.p99_latency_ms),
            s.stats.sops.to_string(),
            format!("{:.3}", s.report.pj_per_sop),
        ]);
    }
    println!("{}", t.render());

    // Determinism: warm concurrent serving is bit-identical to a
    // sequential pass on fresh chips (the reference path).
    let seq = builder.build_pool(&net)?.serve_sequential(specs())?;
    assert_eq!(
        par.merged.pj_per_sop.to_bits(),
        seq.merged.pj_per_sop.to_bits()
    );
    assert_eq!(par.merged.power_mw.to_bits(), seq.merged.power_mw.to_bits());
    assert_eq!(par.merged.cycles, seq.merged.cycles);
    println!("runtime (warm, 4 workers) == sequential (cold) — bit-identical merge ✓\n");

    println!(
        "merged report:\n{}",
        ChipReport::table(std::slice::from_ref(&par.merged)).render()
    );
    Ok(())
}
