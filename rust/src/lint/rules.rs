//! Layer-1 **source lints**: token-level rules that enforce the repo's
//! determinism and robustness contracts (see DESIGN.md §Determinism
//! contract). Each rule is named; findings are suppressed only by an
//! inline `// lint:allow(<rule>) <justification>` on the offending line or
//! the line above it.

use super::tokens::{Tok, TokKind};
use super::{Finding, SourceFile};
use std::collections::BTreeSet;

/// All layer-1 rule names, in report order.
pub const SOURCE_RULES: &[&str] = &[
    "no-hash-collections",
    "host-clock-quarantine",
    "no-unscoped-threads",
    "no-float-eq",
    "no-silent-panic-in-serving",
    "no-unsafe",
];

/// Host-timing sites where wall-clock reads are expected wholesale; other
/// crate files need an inline `lint:allow(host-clock-quarantine)`.
const HOST_CLOCK_FILE_ALLOWLIST: &[&str] = &["rust/src/util/bench.rs", "rust/src/benches_support.rs"];

/// Is this file part of the simulator crate proper (as opposed to benches,
/// tests or examples, which run on the host by definition)?
fn in_crate_src(path: &str) -> bool {
    path.starts_with("rust/src/")
}

/// The serving surface hardened by PR 8: panics here escape to operators.
fn in_serving(path: &str) -> bool {
    path.starts_with("rust/src/serve/") || path.starts_with("rust/src/cluster/")
}

/// Run every source rule over one tokenized file. `test_lines` are the
/// `#[cfg(test)]` regions; most rules skip them (test code may use host
/// clocks, unwrap freely, etc.).
pub fn run_source_rules(
    file: &SourceFile,
    toks: &[Tok],
    test_lines: &BTreeSet<usize>,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let in_test = |line: usize| test_lines.contains(&line);
    let path = file.path.as_str();

    for (i, t) in toks.iter().enumerate() {
        let prev = i.checked_sub(1).and_then(|p| toks.get(p));
        let next = toks.get(i + 1);
        let next2 = toks.get(i + 2);

        // no-hash-collections: HashMap/HashSet iteration order is
        // nondeterministic and would silently break every bit-identity
        // oracle. Sim code must use BTreeMap/BTreeSet/Vec.
        if in_crate_src(path)
            && !in_test(t.line)
            && (t.is_ident("HashMap") || t.is_ident("HashSet"))
        {
            out.push(Finding::new(
                "no-hash-collections",
                path,
                t.line,
                format!("{} in sim code: iteration order breaks replay; use BTree* or Vec", t.text),
            ));
        }

        // host-clock-quarantine: Instant::now / SystemTime only in the
        // allowlisted host-timing sites; everywhere else simulated cycles
        // are the clock.
        if in_crate_src(path)
            && !HOST_CLOCK_FILE_ALLOWLIST.contains(&path)
            && !in_test(t.line)
        {
            let instant_now = t.is_ident("Instant")
                && next.map(|x| x.is_op("::")).unwrap_or(false)
                && next2.map(|x| x.is_ident("now")).unwrap_or(false);
            if instant_now || t.is_ident("SystemTime") {
                out.push(Finding::new(
                    "host-clock-quarantine",
                    path,
                    t.line,
                    "host clock read outside the quarantined timing sites; simulated \
                     cycles are the only clock sim code may observe"
                        .into(),
                ));
            }
        }

        // no-unscoped-threads: thread::spawn outside thread::scope means
        // join order (and thus report merge order) is up to the caller.
        if in_crate_src(path)
            && !in_test(t.line)
            && t.is_ident("thread")
            && next.map(|x| x.is_op("::")).unwrap_or(false)
            && next2.map(|x| x.is_ident("spawn")).unwrap_or(false)
        {
            out.push(Finding::new(
                "no-unscoped-threads",
                path,
                t.line,
                "thread::spawn outside thread::scope: results must be merged in \
                 deterministic submission order and joins proven"
                    .into(),
            ));
        }

        // no-float-eq: == / != touching a float literal. Bit-level
        // comparisons must go through f64::to_bits; exact-value tests
        // need a lint:allow with the IEEE argument spelled out.
        if in_crate_src(path)
            && !in_test(t.line)
            && (t.is_op("==") || t.is_op("!="))
        {
            let is_float = |x: Option<&Tok>| {
                matches!(x, Some(Tok { kind: TokKind::Num { float: true }, .. }))
            };
            if is_float(prev) || is_float(next) {
                out.push(Finding::new(
                    "no-float-eq",
                    path,
                    t.line,
                    format!(
                        "`{}` against a float literal: compare via f64::to_bits or \
                         justify the exact-value test inline",
                        t.text
                    ),
                ));
            }
        }

        // no-silent-panic-in-serving: the serving surface promises
        // per-session failure isolation (PR 8); panics there must become
        // Error variants. unwrap/expect/panic-family in serve/ and
        // cluster/; slice-indexing in serve/ (cluster planners index
        // heavily under catch_unwind attribution — see DESIGN.md).
        if in_serving(path) && !in_test(t.line) {
            let dotted_call = |name: &str| {
                prev.map(|x| x.is_op(".")).unwrap_or(false)
                    && t.is_ident(name)
                    && next.map(|x| x.is_op("(")).unwrap_or(false)
            };
            if dotted_call("unwrap") || dotted_call("expect") {
                out.push(Finding::new(
                    "no-silent-panic-in-serving",
                    path,
                    t.line,
                    format!(".{}() on the serving surface: return a proper Error variant", t.text),
                ));
            }
            let panic_macro = ["panic", "unreachable", "todo", "unimplemented"]
                .iter()
                .any(|m| t.is_ident(m))
                && next.map(|x| x.is_op("!")).unwrap_or(false);
            if panic_macro {
                out.push(Finding::new(
                    "no-silent-panic-in-serving",
                    path,
                    t.line,
                    format!("{}! on the serving surface: return a proper Error variant", t.text),
                ));
            }
            // `expr[`: indexing can panic out-of-bounds. Previous token
            // Ident / `)` / `]` distinguishes indexing from array types,
            // attributes and slice literals.
            if path.starts_with("rust/src/serve/") && t.is_op("[") {
                let indexes = prev
                    .map(|x| x.kind == TokKind::Ident || x.is_op(")") || x.is_op("]"))
                    .unwrap_or(false);
                if indexes {
                    out.push(Finding::new(
                        "no-silent-panic-in-serving",
                        path,
                        t.line,
                        "slice index on the serving surface can panic: use get()/min() \
                         or justify the bound inline"
                            .into(),
                    ));
                }
            }
        }

        // no-unsafe: crate-wide (the compiler backs this with
        // #![forbid(unsafe_code)]; the lint also covers benches, examples
        // and integration tests, which are outside the crate root).
        if t.is_ident("unsafe") {
            out.push(Finding::new(
                "no-unsafe",
                path,
                t.line,
                "unsafe is forbidden everywhere in this repo".into(),
            ));
        }
    }
    out
}
