//! Experiment coordination: ties datasets, the SoC simulator and the XLA
//! golden model together into reproducible experiment runs (the layer the
//! CLI and benches drive). The sharded batch runner
//! ([`ExperimentRunner::run_parallel`]) spreads a sample set across all
//! host cores, one simulated chip per worker, with a deterministic merge.

pub mod runner;

pub use runner::{ExperimentConfig, ExperimentOutcome, ExperimentRunner, GoldenCheck};
