//! Layer-2 **model lints**: whole-model semantic checks that no single
//! unit test covers. These cross-reference several files at once:
//!
//! - `ledger-completeness` — every [`crate::energy::EventClass`] variant
//!   must have (a) a priced arm in `energy_pj` backed by a field that
//!   exists in `energy/constants.rs`, (b) at least one charge site in
//!   non-test sim code, and (c) a report key (membership in
//!   `EventClass::ALL`, which drives the breakdown/snapshot keys). This is
//!   the invariant behind every pJ/SOP number the repo reports.
//! - `error-variants-constructed` — every `Error` variant is actually
//!   constructed somewhere (a variant nobody can produce is dead API).
//! - `cli-flag-coverage` — every flag accepted by a `reject_unknown`
//!   allowlist in `main.rs` is read somewhere in `main.rs` (the builder
//!   choke-point path) and mentioned as `--flag` in the README.
//!
//! Findings anchor to the declaring line (variant / flag), so the same
//! `lint:allow` mechanism works on them.

use super::tokens::{Tok, TokKind};
use super::{FileSet, Finding};

/// All layer-2 rule names, in report order.
pub const MODEL_RULES: &[&str] =
    &["ledger-completeness", "error-variants-constructed", "cli-flag-coverage"];

/// An enum variant with the line it is declared on.
#[derive(Debug)]
struct Variant {
    name: String,
    line: usize,
}

/// Extract the variants of `pub enum <name>` from a token stream:
/// uppercase idents at brace depth 1, skipping payload parens/braces.
fn enum_variants(toks: &[Tok], enum_name: &str) -> Vec<Variant> {
    let Some(start) = find_seq(toks, 0, &["enum", enum_name, "{"]) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    let mut depth = 1usize;
    let mut i = start + 3;
    let mut expect_variant = true;
    while i < toks.len() && depth > 0 {
        let t = &toks[i];
        if t.is_op("{") || t.is_op("(") || t.is_op("[") || t.is_op("<") {
            depth += 1;
        } else if t.is_op("}") || t.is_op(")") || t.is_op("]") || t.is_op(">") {
            depth -= 1;
        } else if depth == 1 {
            if t.is_op(",") {
                expect_variant = true;
            } else if expect_variant
                && t.kind == TokKind::Ident
                && t.text.chars().next().map(char::is_uppercase).unwrap_or(false)
            {
                out.push(Variant { name: t.text.clone(), line: t.line });
                expect_variant = false;
            } else if t.is_op("#") {
                // attribute on a variant — skip `[...]` via depth tracking
            }
        }
        i += 1;
    }
    out
}

/// Find the first index where `toks[i..]` matches the given ident/op
/// texts in sequence (each element matches either kind by text).
fn find_seq(toks: &[Tok], from: usize, pat: &[&str]) -> Option<usize> {
    let n = pat.len();
    (from..toks.len().saturating_sub(n - 1))
        .find(|&i| (0..n).all(|k| toks[i + k].text == pat[k]))
}

/// The token index range of the brace-matched block starting at the first
/// `{` at or after `from`. Returns (open_index, close_index_exclusive).
fn brace_block(toks: &[Tok], from: usize) -> Option<(usize, usize)> {
    let open = (from..toks.len()).find(|&i| toks[i].is_op("{"))?;
    let mut depth = 1usize;
    let mut i = open + 1;
    while i < toks.len() {
        if toks[i].is_op("{") {
            depth += 1;
        } else if toks[i].is_op("}") {
            depth -= 1;
            if depth == 0 {
                return Some((open, i + 1));
            }
        }
        i += 1;
    }
    Some((open, toks.len()))
}

/// Run all model lints over a loaded [`FileSet`].
pub fn run_model_lints(fs: &FileSet) -> Vec<Finding> {
    let mut out = Vec::new();
    out.extend(ledger_completeness(fs));
    out.extend(error_variants_constructed(fs));
    out.extend(cli_flag_coverage(fs));
    out
}

/// `ledger-completeness` (see module docs).
fn ledger_completeness(fs: &FileSet) -> Vec<Finding> {
    const RULE: &str = "ledger-completeness";
    const MODEL: &str = "rust/src/energy/model.rs";
    const CONSTANTS: &str = "rust/src/energy/constants.rs";
    let mut out = Vec::new();
    let Some(model_toks) = fs.tokens(MODEL) else {
        return vec![Finding::new(RULE, MODEL, 1, "energy/model.rs not found".into())];
    };
    let variants = enum_variants(model_toks, "EventClass");
    if variants.is_empty() {
        return vec![Finding::new(RULE, MODEL, 1, "no EventClass variants found".into())];
    }
    // The priced arms: inside fn energy_pj's match, `Variant => p.<field>`.
    let pj_region = find_seq(model_toks, 0, &["fn", "energy_pj"])
        .and_then(|i| brace_block(model_toks, i))
        .map(|(a, b)| &model_toks[a..b])
        .unwrap_or(&[]);
    // ALL membership drives breakdown()/snapshot report keys. Skip past
    // the `=` so the type annotation's `[EventClass; N]` brackets don't
    // shadow the value array.
    let all_region = find_seq(model_toks, 0, &["ALL", ":"])
        .and_then(|i| (i..model_toks.len()).find(|&k| model_toks[k].is_op("=")))
        .and_then(|i| brace_block_like(model_toks, i, "[", "]"))
        .map(|(a, b)| &model_toks[a..b])
        .unwrap_or(&[]);
    let constants_idents: std::collections::BTreeSet<&str> = fs
        .tokens(CONSTANTS)
        .map(|toks| {
            toks.iter()
                .filter(|t| t.kind == TokKind::Ident)
                .map(|t| t.text.as_str())
                .collect()
        })
        .unwrap_or_default();

    for v in &variants {
        // (a) priced arm + constant field.
        let arm = find_seq(pj_region, 0, &[&v.name, "=>", "p", "."]);
        match arm {
            None => out.push(Finding::new(
                RULE,
                MODEL,
                v.line,
                format!("EventClass::{} has no `{} => p.e_*` arm in energy_pj", v.name, v.name),
            )),
            Some(i) => {
                let field = &pj_region[i + 4];
                if !constants_idents.contains(field.text.as_str()) {
                    out.push(Finding::new(
                        RULE,
                        MODEL,
                        v.line,
                        format!(
                            "EventClass::{} is priced from `p.{}` but that field does not \
                             exist in energy/constants.rs",
                            v.name, field.text
                        ),
                    ));
                }
            }
        }
        // (b) ≥1 charge site: `EventClass::Variant` in non-test sim code
        // outside the declaring file.
        let charged = fs.files.iter().any(|f| {
            f.path != MODEL
                && f.path.starts_with("rust/src/")
                && charge_site(fs, &f.path, &v.name)
        });
        if !charged {
            out.push(Finding::new(
                RULE,
                MODEL,
                v.line,
                format!(
                    "EventClass::{} is never charged: no `EventClass::{}` site in \
                     non-test sim code — a priced class nobody charges silently \
                     under-reports pJ/SOP",
                    v.name, v.name
                ),
            ));
        }
        // (c) report key: membership in ALL.
        if find_seq(all_region, 0, &["EventClass", "::", &v.name]).is_none() {
            out.push(Finding::new(
                RULE,
                MODEL,
                v.line,
                format!(
                    "EventClass::{} missing from EventClass::ALL: it gets no \
                     breakdown/snapshot report key",
                    v.name
                ),
            ));
        }
    }
    out
}

/// Does `path` contain `EventClass::<variant>` outside `#[cfg(test)]`?
fn charge_site(fs: &FileSet, path: &str, variant: &str) -> bool {
    let Some(toks) = fs.tokens(path) else { return false };
    let test_lines = fs.test_lines(path);
    let mut from = 0usize;
    while let Some(i) = find_seq(toks, from, &["EventClass", "::", variant]) {
        if !test_lines.contains(&toks[i].line) {
            return true;
        }
        from = i + 1;
    }
    false
}

/// Like [`brace_block`] but for an arbitrary bracket pair.
fn brace_block_like(toks: &[Tok], from: usize, open: &str, close: &str) -> Option<(usize, usize)> {
    let start = (from..toks.len()).find(|&i| toks[i].is_op(open))?;
    let mut depth = 1usize;
    let mut i = start + 1;
    while i < toks.len() {
        if toks[i].is_op(open) {
            depth += 1;
        } else if toks[i].is_op(close) {
            depth -= 1;
            if depth == 0 {
                return Some((start, i + 1));
            }
        }
        i += 1;
    }
    Some((start, toks.len()))
}

/// `error-variants-constructed` (see module docs).
///
/// Construction sites are `Error::<Variant>` token sequences anywhere in
/// the tree **except** inside `error.rs`'s own enum declaration and trait
/// impls (whose match arms mention every variant without anyone being
/// able to produce it): within `error.rs` only `impl From<…> for Error`
/// blocks and the inherent `impl Error` block (shorthand constructors)
/// count.
fn error_variants_constructed(fs: &FileSet) -> Vec<Finding> {
    const RULE: &str = "error-variants-constructed";
    const ERRS: &str = "rust/src/error.rs";
    let Some(err_toks) = fs.tokens(ERRS) else {
        return vec![Finding::new(RULE, ERRS, 1, "error.rs not found".into())];
    };
    let variants = enum_variants(err_toks, "Error");
    if variants.is_empty() {
        return vec![Finding::new(RULE, ERRS, 1, "no Error variants found".into())];
    }
    // Lines of error.rs where construction counts: From impls + inherent.
    let mut countable = std::collections::BTreeSet::new();
    let mut i = 0usize;
    while i < err_toks.len() {
        if err_toks[i].is_ident("impl") {
            // Header tokens up to `{` decide the block's class.
            let Some((open, close)) = brace_block(err_toks, i) else { break };
            let header: Vec<&str> =
                err_toks[i..open].iter().map(|t| t.text.as_str()).collect();
            let is_from = header.contains(&"From") && header.contains(&"for");
            let is_inherent = !header.contains(&"for");
            if is_from || is_inherent {
                for t in &err_toks[open..close] {
                    countable.insert(t.line);
                }
            }
            i = close;
        } else {
            i += 1;
        }
    }
    let mut out = Vec::new();
    for v in &variants {
        let constructed = fs.files.iter().any(|f| {
            let Some(toks) = fs.tokens(&f.path) else { return false };
            let mut from = 0usize;
            while let Some(k) = find_seq(toks, from, &["Error", "::", &v.name]) {
                if f.path != ERRS || countable.contains(&toks[k].line) {
                    return true;
                }
                from = k + 1;
            }
            false
        });
        if !constructed {
            out.push(Finding::new(
                RULE,
                ERRS,
                v.line,
                format!("Error::{} is never constructed anywhere in the tree", v.name),
            ));
        }
    }
    out
}

/// `cli-flag-coverage` (see module docs).
fn cli_flag_coverage(fs: &FileSet) -> Vec<Finding> {
    const RULE: &str = "cli-flag-coverage";
    const MAIN: &str = "rust/src/main.rs";
    let Some(toks) = fs.tokens(MAIN) else {
        return Vec::new(); // fixture sets without a main.rs skip this lint
    };
    // Collect the flag string literals inside reject_unknown(&[ … ]) and
    // remember which token indices belong to those arrays.
    let mut flags: Vec<(String, usize)> = Vec::new();
    let mut array_tokens = std::collections::BTreeSet::new();
    let mut from = 0usize;
    while let Some(i) = find_seq(toks, from, &["reject_unknown", "(", "&", "["]) {
        if let Some((open, close)) = brace_block_like(toks, i + 3, "[", "]") {
            for (k, t) in toks[open..close].iter().enumerate() {
                if t.kind == TokKind::Str {
                    flags.push((t.text.clone(), t.line));
                    array_tokens.insert(open + k);
                }
            }
            from = close;
        } else {
            from = i + 1;
        }
    }
    let mut out = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    for (flag, line) in flags {
        if !seen.insert(flag.clone()) {
            continue; // shared between run/serve allowlists — check once
        }
        // (a) read somewhere in main.rs outside the allowlist arrays.
        let read = toks.iter().enumerate().any(|(k, t)| {
            t.kind == TokKind::Str && t.text == flag && !array_tokens.contains(&k)
        });
        if !read {
            out.push(Finding::new(
                RULE,
                MAIN,
                line,
                format!(
                    "flag --{flag} is accepted by reject_unknown but never read in \
                     main.rs: it has no path to the builder choke point"
                ),
            ));
        }
        // (b) README mention.
        let mentioned = fs
            .readme
            .as_deref()
            .map(|r| r.contains(&format!("--{flag}")))
            .unwrap_or(true); // fixture sets without a README skip this half
        if !mentioned {
            out.push(Finding::new(
                RULE,
                MAIN,
                line,
                format!("flag --{flag} is not documented in README.md"),
            ));
        }
    }
    out
}
