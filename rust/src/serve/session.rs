//! Streaming inference session over one serving engine — a single
//! simulated chip or a whole multi-chip cluster.
//!
//! A [`Session`] owns an [`Engine`] for its lifetime and replaces the
//! batch-only `run_sample … finish_report` dance with a typestate-safe
//! stream: [`Session::push`] runs one sample, [`Session::snapshot`]
//! assembles an incremental [`ChipReport`] at any point without
//! disturbing accounting, and [`Session::close`] **consumes** the
//! session to produce the final report — forgetting `finish_report` is a
//! compile error, not a silent accounting bug. Per-sample latency is
//! ledgered so sessions expose p50/p99 serving percentiles.

use crate::cluster::Engine;
use crate::datasets::Sample;
use crate::energy::ChipReport;
use crate::soc::{SampleResult, Soc};
use crate::Result;

/// Fabric-degradation view of one session's accounting window: the
/// chip's [`crate::noc::FabricHealth`] counters joined with the window's
/// delivery totals, so serving callers can judge *how gracefully* a
/// session degraded without reaching into the NoC. All-zero (with
/// `armed == false`) for sessions on a healthy fabric.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DegradationStats {
    /// A fault plan with at least one event was armed on the chip.
    pub armed: bool,
    /// Spike flits delivered this window.
    pub delivered: u64,
    /// Spike flits discarded (dead-router drain or severed route).
    pub dropped: u64,
    /// Flit-hops taken over links the pristine route would not have used
    /// (the fabric redundancy the session actually consumed).
    pub rerouted_hops: u64,
    /// Routers killed during the window.
    pub dead_routers: u64,
    /// Links severed during the window.
    pub dead_links: u64,
}

impl DegradationStats {
    /// Fraction of routed flits that survived to delivery (1.0 for an
    /// idle or healthy window).
    pub fn delivered_frac(&self) -> f64 {
        let total = self.delivered + self.dropped;
        if total == 0 {
            1.0
        } else {
            self.delivered as f64 / total as f64
        }
    }
}

/// Per-session serving statistics (simulated time).
#[derive(Debug, Clone)]
pub struct SessionStats {
    /// Samples pushed through the session.
    pub samples: u64,
    /// Core-clock cycles consumed by the session's samples.
    pub cycles: u64,
    /// Synapse operations performed.
    pub sops: u64,
    /// Neuromorphic-processor clock the session ran at (Hz).
    pub f_core_hz: f64,
    /// Median per-sample latency (ms, simulated).
    pub p50_latency_ms: f64,
    /// 99th-percentile per-sample latency (ms, simulated).
    pub p99_latency_ms: f64,
}

impl SessionStats {
    /// Total simulated session latency (ms).
    pub fn session_ms(&self) -> f64 {
        self.cycles as f64 / self.f_core_hz * 1e3
    }
}

/// The final artifact of a closed session.
#[derive(Debug, Clone)]
pub struct SessionReport {
    /// Chip-level energy/performance report for the session window.
    pub report: ChipReport,
    /// Serving statistics (latency percentiles, throughput counters).
    pub stats: SessionStats,
}

/// A live streaming session. Create one via
/// [`crate::serve::SocBuilder::open_session`] (or [`Session::open`] /
/// [`Session::open_engine`] with a hand-assembled engine), push samples,
/// close for the report.
pub struct Session {
    engine: Engine,
    name: String,
    latencies: Vec<u64>,
    cycles: u64,
    sops: u64,
}

impl Session {
    /// Open a session named `name` over an assembled single chip. The
    /// chip's accounting window becomes the session's energy/latency
    /// ledger. (Convenience wrapper over [`Session::open_engine`].)
    pub fn open(soc: Soc, name: &str) -> Session {
        Session::open_engine(Engine::Chip(Box::new(soc)), name)
    }

    /// Open a session named `name` over any serving engine — one chip or
    /// a cluster. The engine's accounting window becomes the session's
    /// energy/latency ledger.
    pub fn open_engine(engine: Engine, name: &str) -> Session {
        Session {
            engine,
            name: name.to_string(),
            latencies: Vec::new(),
            cycles: 0,
            sops: 0,
        }
    }

    /// Session name (the report's workload label).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The underlying engine (read-only; mapping/network introspection).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// The underlying chip when the session runs on exactly one (`None`
    /// for multi-chip sessions — use [`Session::engine`] there).
    pub fn soc(&self) -> Option<&Soc> {
        self.engine.as_soc()
    }

    /// NoC fabric statistics for this session's accounting window
    /// (delivered flits, latency/hop aggregates, stall totals). O(1):
    /// folded incrementally by the event-driven simulator, so polling it
    /// per push costs nothing — and the session chip keeps no per-flit
    /// trace, so long-lived sessions hold only this ledger.
    pub fn noc_stats(&self) -> crate::noc::SimStats {
        self.engine.noc_stats()
    }

    /// Fabric-degradation statistics for this session's window (all zero
    /// with `armed == false` on a chip without a fault plan). On a
    /// cluster, counters fold the per-shard NoCs *and* the L3 ring.
    pub fn degradation(&self) -> DegradationStats {
        let h = self.engine.fabric_health();
        DegradationStats {
            armed: h.armed,
            delivered: self.engine.noc_stats().delivered,
            dropped: h.dropped,
            rerouted_hops: h.rerouted_hops,
            dead_routers: h.dead_routers,
            dead_links: h.dead_links,
        }
    }

    /// Run one labelled sample through the chip and ledger its latency.
    pub fn push(&mut self, sample: &Sample) -> Result<SampleResult> {
        self.push_inner(sample, true)
    }

    /// Run one sample whose label is unknown/ignored (pure serving: the
    /// result's `correct` flag is always false and accuracy is not
    /// accumulated).
    pub fn push_unlabelled(&mut self, sample: &Sample) -> Result<SampleResult> {
        self.push_inner(sample, false)
    }

    fn push_inner(&mut self, sample: &Sample, label_known: bool) -> Result<SampleResult> {
        let r = self.engine.run_sample(sample, label_known)?;
        self.latencies.push(r.cycles);
        self.cycles += r.cycles;
        self.sops += r.sops;
        Ok(r)
    }

    /// Core-clock cycles consumed so far — O(1), polled per push by the
    /// serving deadline enforcement, so it must not touch the latency
    /// ledger (which [`Session::stats`] sorts).
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Abandon the session and hand the engine back without producing a
    /// report — the recovery path: a deadline-killed or degraded attempt
    /// returns its engine so the retry loop can power-cycle it via
    /// [`Engine::reset_for_session`] instead of paying a fresh build.
    /// The engine's accounting window is left dirty; the caller must
    /// reset it before reuse.
    pub(crate) fn into_engine(self) -> Engine {
        self.engine
    }

    /// Incremental chip report over the work so far. Non-destructive:
    /// pushing more samples and snapshotting again extends the same
    /// accounting window, and [`Session::close`] right after a snapshot
    /// returns bit-identical numbers.
    pub fn snapshot(&self) -> ChipReport {
        self.engine.snapshot_report(&self.name)
    }

    /// Serving statistics so far.
    pub fn stats(&self) -> SessionStats {
        let mut sorted = self.latencies.clone();
        sorted.sort_unstable();
        let f = self.engine.config().f_core_hz;
        let to_ms = |cycles: u64| cycles as f64 / f * 1e3;
        SessionStats {
            samples: self.latencies.len() as u64,
            cycles: self.cycles,
            sops: self.sops,
            f_core_hz: f,
            p50_latency_ms: to_ms(percentile(&sorted, 0.50)),
            p99_latency_ms: to_ms(percentile(&sorted, 0.99)),
        }
    }

    /// Close the session: consume it and produce the final chip report +
    /// serving statistics. The compiler guarantees no sample can be
    /// pushed after the close, and the report cannot be forgotten
    /// half-assembled.
    pub fn close(self) -> SessionReport {
        self.close_reuse().0
    }

    /// Close the session but hand the engine back instead of dropping it
    /// — the warm-serving path: [`crate::serve::ServeRuntime`] re-arms
    /// the returned [`Engine`] via [`Engine::reset_for_session`] for the
    /// next session rather than paying a fresh build. The report is
    /// exactly what [`Session::close`] would have produced (`close` is
    /// this plus a drop).
    pub fn close_reuse(self) -> (SessionReport, Engine) {
        let stats = self.stats();
        let mut engine = self.engine;
        let report = engine.finish_report(&self.name);
        (SessionReport { report, stats }, engine)
    }
}

/// Nearest-rank percentile over an ascending-sorted slice (the type's
/// default — zero — for empty input). The single implementation behind
/// both [`SessionStats`] percentiles and the serving bench, so the two
/// can never drift apart.
pub(crate) fn percentile<T: Copy + Default>(sorted: &[T], p: f64) -> T {
    if sorted.is_empty() {
        return T::default();
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted.get(idx).or_else(|| sorted.last()).copied().unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        assert_eq!(percentile::<u64>(&[], 0.5), 0);
        assert_eq!(percentile(&[7u64], 0.99), 7);
        assert_eq!(percentile(&[1u64, 2, 3, 4], 0.0), 1);
        assert_eq!(percentile(&[1u64, 2, 3, 4], 1.0), 4);
        assert_eq!(percentile(&[1u64, 2, 3, 4, 5], 0.5), 3);
        assert_eq!(percentile(&[1.5f64, 2.5], 0.0), 1.5);
        assert_eq!(percentile::<f64>(&[], 0.5), 0.0);
    }

    #[test]
    fn percentile_edge_cases_pin_nearest_rank_semantics() {
        // Empty input: the type's default, at every p.
        assert_eq!(percentile::<u64>(&[], 0.0), 0);
        assert_eq!(percentile::<u64>(&[], 1.0), 0);
        assert_eq!(percentile::<f64>(&[], 0.99), 0.0);
        // Single element: every percentile is that element.
        assert_eq!(percentile(&[42u64], 0.0), 42);
        assert_eq!(percentile(&[42u64], 0.5), 42);
        assert_eq!(percentile(&[42u64], 1.0), 42);
        // Two elements: p50 rounds ((2-1)·0.5) = 0.5 away from zero →
        // index 1, the UPPER of the pair. This is the ledger's pinned
        // nearest-rank convention (not an interpolated midpoint).
        assert_eq!(percentile(&[10u64, 20], 0.5), 20);
        assert_eq!(percentile(&[10u64, 20], 0.0), 10);
        assert_eq!(percentile(&[10u64, 20], 1.0), 20);
        // p99 of 100 ascending elements: index round(99·0.99) = 98.
        let v: Vec<u64> = (0..100).collect();
        assert_eq!(percentile(&v, 0.99), 98);
        // Out-of-range p never reads past the end.
        assert_eq!(percentile(&[1u64, 2, 3], 2.0), 3);
    }
}
