//! Event-stream containers + the JSON interchange with the Python side.

use crate::util::json::Json;
use crate::{Error, Result};
use std::path::Path;

/// One labelled sample: a sparse spike raster.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Sample {
    /// Ground-truth class.
    pub label: usize,
    /// Events as (timestep, axon) pairs, sorted by timestep.
    pub events: Vec<(u16, u32)>,
}

impl Sample {
    /// Expand to a dense raster (`timesteps × inputs` booleans).
    pub fn to_raster(&self, timesteps: usize, inputs: usize) -> Vec<Vec<bool>> {
        let mut r = vec![vec![false; inputs]; timesteps];
        for &(t, a) in &self.events {
            if (t as usize) < timesteps && (a as usize) < inputs {
                r[t as usize][a as usize] = true;
            }
        }
        r
    }

    /// Spikes at one timestep (axon ids, ascending).
    pub fn spikes_at(&self, t: u16) -> Vec<u32> {
        let mut v: Vec<u32> = self
            .events
            .iter()
            .filter(|&&(et, _)| et == t)
            .map(|&(_, a)| a)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Mean spikes per timestep.
    pub fn rate(&self, timesteps: usize) -> f64 {
        self.events.len() as f64 / timesteps as f64
    }
}

/// A labelled dataset of event streams.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Dataset name.
    pub name: String,
    /// Input (axon) count.
    pub inputs: usize,
    /// Timesteps per sample.
    pub timesteps: usize,
    /// Class count.
    pub classes: usize,
    /// Samples.
    pub samples: Vec<Sample>,
}

impl Dataset {
    /// Average spike sparsity: fraction of (timestep × axon) slots that
    /// are **zero** (the x-axis of Fig. 3).
    pub fn sparsity(&self) -> f64 {
        let slots = (self.samples.len() * self.timesteps * self.inputs) as f64;
        let spikes: usize = self.samples.iter().map(|s| s.events.len()).sum();
        1.0 - spikes as f64 / slots
    }

    /// Load the Python-exported interchange file.
    pub fn load_json(path: &Path) -> Result<Dataset> {
        let j = Json::read_file(path)?;
        let samples = j
            .get("samples")?
            .as_arr()?
            .iter()
            .map(|s| -> Result<Sample> {
                let events = s
                    .get("events")?
                    .as_arr()?
                    .iter()
                    .map(|e| -> Result<(u16, u32)> {
                        let pair = e.as_arr()?;
                        if pair.len() != 2 {
                            return Err(Error::Artifact("event must be [t, axon]".into()));
                        }
                        Ok((pair[0].as_i64()? as u16, pair[1].as_i64()? as u32))
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(Sample {
                    label: s.get("label")?.as_usize()?,
                    events,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let d = Dataset {
            name: j.get("name")?.as_str()?.to_string(),
            inputs: j.get("inputs")?.as_usize()?,
            timesteps: j.get("timesteps")?.as_usize()?,
            classes: j.get("classes")?.as_usize()?,
            samples,
        };
        d.validate()?;
        Ok(d)
    }

    /// Serialize to the interchange format.
    pub fn to_json(&self) -> Json {
        let samples: Vec<Json> = self
            .samples
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("label", Json::Num(s.label as f64)),
                    (
                        "events",
                        Json::Arr(
                            s.events
                                .iter()
                                .map(|&(t, a)| {
                                    Json::Arr(vec![Json::Num(t as f64), Json::Num(a as f64)])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("inputs", Json::Num(self.inputs as f64)),
            ("timesteps", Json::Num(self.timesteps as f64)),
            ("classes", Json::Num(self.classes as f64)),
            ("samples", Json::Arr(samples)),
        ])
    }

    /// Validate labels/events are in range.
    pub fn validate(&self) -> Result<()> {
        for (i, s) in self.samples.iter().enumerate() {
            if s.label >= self.classes {
                return Err(Error::Artifact(format!(
                    "sample {i}: label {} out of {} classes",
                    s.label, self.classes
                )));
            }
            for &(t, a) in &s.events {
                if t as usize >= self.timesteps || a as usize >= self.inputs {
                    return Err(Error::Artifact(format!(
                        "sample {i}: event ({t},{a}) out of range"
                    )));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            name: "t".into(),
            inputs: 4,
            timesteps: 3,
            classes: 2,
            samples: vec![
                Sample { label: 0, events: vec![(0, 1), (2, 3)] },
                Sample { label: 1, events: vec![(1, 0)] },
            ],
        }
    }

    #[test]
    fn raster_expansion() {
        let d = tiny();
        let r = d.samples[0].to_raster(3, 4);
        assert!(r[0][1] && r[2][3]);
        assert!(!r[0][0] && !r[1][1]);
        assert_eq!(d.samples[0].spikes_at(0), vec![1]);
    }

    #[test]
    fn sparsity_counts_zero_slots() {
        let d = tiny();
        // 2 samples × 3 t × 4 inputs = 24 slots, 3 spikes.
        assert!((d.sparsity() - (1.0 - 3.0 / 24.0)).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip() {
        let d = tiny();
        let text = d.to_json().to_string();
        let tmp = std::env::temp_dir().join("fsoc_ds_test.json");
        std::fs::write(&tmp, &text).unwrap();
        let back = Dataset::load_json(&tmp).unwrap();
        assert_eq!(back.samples, d.samples);
        assert_eq!(back.inputs, 4);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn validate_rejects_out_of_range() {
        let mut d = tiny();
        d.samples[0].label = 9;
        assert!(d.validate().is_err());
        let mut d = tiny();
        d.samples[0].events.push((9, 0));
        assert!(d.validate().is_err());
    }
}
