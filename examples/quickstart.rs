//! Quickstart: build a small spiking network by hand, map it onto the
//! simulated chip, run a handful of event-stream samples and print the
//! chip report.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fullerene_soc::core::neuron::{LeakMode, NeuronParams, ResetMode};
use fullerene_soc::datasets::Workload;
use fullerene_soc::energy::ChipReport;
use fullerene_soc::nn::network::{LayerDesc, NetworkDesc};
use fullerene_soc::nn::quant::kmeans_quantize;
use fullerene_soc::soc::{Soc, SocConfig};
use fullerene_soc::util::prng::Rng;

fn main() -> fullerene_soc::Result<()> {
    // 1. A 2-layer SNN for the NMNIST-like geometry. Weights here are
    //    random floats quantized through the same non-uniform codebook
    //    pipeline the trained artifacts use (run `make artifacts` +
    //    examples/edge_inference for the trained version).
    let w = Workload::Nmnist;
    let (inputs, hidden, classes) = (w.inputs(), 64, w.classes());
    let mut rng = Rng::new(7);

    let mut make_layer = |name: &str, a: usize, n: usize| -> fullerene_soc::Result<LayerDesc> {
        let floats: Vec<f64> = (0..a * n).map(|_| rng.normal() * 0.3).collect();
        let q = kmeans_quantize(&floats, 16, 8, 12)?;
        Ok(LayerDesc {
            name: name.into(),
            inputs: a,
            neurons: n,
            codebook: q.codebook,
            widx: q.widx,
            neuron_params: NeuronParams {
                threshold: 120,
                leak: LeakMode::Linear(2),
                reset: ResetMode::Subtract,
                mp_bits: 16,
            },
        })
    };
    let net = NetworkDesc {
        name: "quickstart".into(),
        layers: vec![
            make_layer("hidden", inputs, hidden)?,
            make_layer("out", hidden, classes)?,
        ],
        timesteps: w.timesteps(),
        classes,
    };
    println!(
        "network: {} inputs → {hidden} hidden → {classes} classes, {} synapses",
        inputs,
        net.total_synapses()
    );

    // 2. Assemble the chip (20 cores, fullerene NoC, RISC-V control CPU).
    let mut soc = Soc::new(net, SocConfig::default())?;
    println!(
        "mapped onto {} cores: {}",
        soc.mapping().cores_used(),
        soc.mapping()
            .placements
            .iter()
            .map(|p| format!(
                "core{}←layer{}[{}..{}]",
                p.core_id,
                p.layer,
                p.neuron_offset,
                p.neuron_offset + p.neurons
            ))
            .collect::<Vec<_>>()
            .join(" ")
    );

    // 3. Run a few synthetic saccade samples.
    let ds = w.generate(5, 42);
    for (i, s) in ds.samples.iter().enumerate() {
        let r = soc.run_sample(s, true)?;
        println!(
            "sample {i}: label {} → predicted {} | {} SOPs, {} cycles",
            s.label, r.predicted, r.sops, r.cycles
        );
    }

    // 4. The Table-I-style chip report.
    let report = soc.finish_report("quickstart");
    println!("\n{}", ChipReport::table(std::slice::from_ref(&report)).render());
    Ok(())
}
