//! Network description: a feed-forward SNN of fully-connected LIF layers
//! with per-layer non-uniform quantized weights (codebook + index matrix),
//! matching what the Python compile path exports.

use crate::core::neuron::NeuronParams;
use crate::core::Codebook;
use crate::{Error, Result};

/// One fully-connected spiking layer, already quantized.
#[derive(Debug, Clone)]
pub struct LayerDesc {
    /// Layer name (for reports).
    pub name: String,
    /// Input (axon) count.
    pub inputs: usize,
    /// Output (neuron) count.
    pub neurons: usize,
    /// Shared weight codebook (N × W bits).
    pub codebook: Codebook,
    /// Weight indexes, row-major `[input][neuron]`, length = inputs ×
    /// neurons. Index `255` means "no synapse" (pruned).
    pub widx: Vec<u8>,
    /// Neuron dynamics.
    pub neuron_params: NeuronParams,
}

/// Sentinel weight index meaning "no synapse".
pub const NO_SYNAPSE: u8 = 255;

impl LayerDesc {
    /// Validate geometry and index ranges.
    pub fn validate(&self) -> Result<()> {
        if self.widx.len() != self.inputs * self.neurons {
            return Err(Error::Network(format!(
                "layer {}: widx length {} != {}×{}",
                self.name,
                self.widx.len(),
                self.inputs,
                self.neurons
            )));
        }
        let n = self.codebook.n() as u8;
        if let Some(bad) = self
            .widx
            .iter()
            .find(|&&w| w != NO_SYNAPSE && w >= n)
        {
            return Err(Error::Network(format!(
                "layer {}: weight index {bad} out of codebook range {n}",
                self.name
            )));
        }
        Ok(())
    }

    /// Weight index of synapse `input → neuron`.
    #[inline]
    pub fn index_of(&self, input: usize, neuron: usize) -> u8 {
        self.widx[input * self.neurons + neuron]
    }

    /// Count of real (non-pruned) synapses.
    pub fn synapse_count(&self) -> usize {
        self.widx.iter().filter(|&&w| w != NO_SYNAPSE).count()
    }

    /// Integer weight of synapse `input → neuron` (0 when pruned).
    pub fn weight_of(&self, input: usize, neuron: usize) -> i32 {
        match self.index_of(input, neuron) {
            NO_SYNAPSE => 0,
            w => self.codebook.weight(w),
        }
    }
}

/// A feed-forward network of quantized spiking layers.
#[derive(Debug, Clone)]
pub struct NetworkDesc {
    /// Network name (e.g. "nmnist-mlp").
    pub name: String,
    /// Layers in order.
    pub layers: Vec<LayerDesc>,
    /// Number of simulation timesteps per sample.
    pub timesteps: usize,
    /// Class count (output layer neurons are class scores).
    pub classes: usize,
}

impl NetworkDesc {
    /// Validate the whole network (layer chaining + per-layer checks).
    pub fn validate(&self) -> Result<()> {
        if self.layers.is_empty() {
            return Err(Error::Network("no layers".into()));
        }
        for l in &self.layers {
            l.validate()?;
        }
        for pair in self.layers.windows(2) {
            if pair[0].neurons != pair[1].inputs {
                return Err(Error::Network(format!(
                    "layer {} outputs {} but layer {} expects {} inputs",
                    pair[0].name, pair[0].neurons, pair[1].name, pair[1].inputs
                )));
            }
        }
        let last = self.layers.last().unwrap();
        if last.neurons != self.classes {
            return Err(Error::Network(format!(
                "output layer has {} neurons but {} classes",
                last.neurons, self.classes
            )));
        }
        Ok(())
    }

    /// Input width of the network.
    pub fn input_size(&self) -> usize {
        self.layers[0].inputs
    }

    /// Total neurons across layers.
    pub fn total_neurons(&self) -> usize {
        self.layers.iter().map(|l| l.neurons).sum()
    }

    /// Total real synapses.
    pub fn total_synapses(&self) -> usize {
        self.layers.iter().map(|l| l.synapse_count()).sum()
    }

    /// Bit-exact functional reference: run the network on a spike raster
    /// (timesteps × input booleans), returning per-class output spike
    /// counts. This mirrors the chip semantics (partial MP update: only
    /// touched neurons update) and is used to cross-check the cycle
    /// simulator and the XLA golden model.
    pub fn reference_run(&self, raster: &[Vec<bool>]) -> Vec<u32> {
        let mut mps: Vec<Vec<i32>> = self.layers.iter().map(|l| vec![0; l.neurons]).collect();
        let mut counts = vec![0u32; self.classes];
        // Spikes flowing between layers this timestep.
        for step in raster {
            let mut spikes: Vec<bool> = step.clone();
            for (li, layer) in self.layers.iter().enumerate() {
                let mut acc = vec![0i64; layer.neurons];
                let mut touched = vec![false; layer.neurons];
                for (i, &s) in spikes.iter().enumerate() {
                    if !s {
                        continue;
                    }
                    for n in 0..layer.neurons {
                        match layer.index_of(i, n) {
                            NO_SYNAPSE => {}
                            w => {
                                acc[n] += layer.codebook.weight(w) as i64;
                                touched[n] = true;
                            }
                        }
                    }
                }
                let mut out = vec![false; layer.neurons];
                let p = &layer.neuron_params;
                let (lo, hi) = p.mp_range();
                for n in 0..layer.neurons {
                    if !touched[n] {
                        continue; // partial MP update semantics
                    }
                    let mut m =
                        (mps[li][n] as i64 + acc[n]).clamp(lo as i64, hi as i64) as i32;
                    m = match p.leak {
                        crate::core::neuron::LeakMode::None => m,
                        crate::core::neuron::LeakMode::Linear(l) => {
                            if m > 0 {
                                (m - l).max(0)
                            } else if m < 0 {
                                (m + l).min(0)
                            } else {
                                0
                            }
                        }
                        crate::core::neuron::LeakMode::Shift(k) => m - (m >> k),
                    };
                    let spike = m >= p.threshold;
                    if spike {
                        m = match p.reset {
                            crate::core::neuron::ResetMode::Zero => 0,
                            crate::core::neuron::ResetMode::Subtract => m - p.threshold,
                        };
                        out[n] = true;
                        if li == self.layers.len() - 1 {
                            counts[n] += 1;
                        }
                    }
                    mps[li][n] = m;
                }
                spikes = out;
            }
        }
        counts
    }

    /// Classify: argmax of output spike counts (ties → lowest class).
    pub fn classify(&self, raster: &[Vec<bool>]) -> usize {
        let counts = self.reference_run(raster);
        counts
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::neuron::{LeakMode, ResetMode};

    fn tiny_net() -> NetworkDesc {
        let cb = Codebook::new(vec![-4, 0, 2, 6], 4).unwrap();
        let params = NeuronParams {
            threshold: 6,
            leak: LeakMode::None,
            reset: ResetMode::Subtract,
            mp_bits: 16,
        };
        // 2 inputs → 2 hidden → 2 outputs.
        let l0 = LayerDesc {
            name: "h".into(),
            inputs: 2,
            neurons: 2,
            codebook: cb.clone(),
            // input0→n0: 6, input0→n1: 2, input1→n0: 0, input1→n1: 6
            widx: vec![3, 2, 1, 3],
            neuron_params: params.clone(),
        };
        let l1 = LayerDesc {
            name: "out".into(),
            inputs: 2,
            neurons: 2,
            codebook: cb,
            widx: vec![3, NO_SYNAPSE, NO_SYNAPSE, 3],
            neuron_params: params,
        };
        NetworkDesc {
            name: "tiny".into(),
            layers: vec![l0, l1],
            timesteps: 4,
            classes: 2,
        }
    }

    #[test]
    fn validate_accepts_and_rejects() {
        let mut n = tiny_net();
        n.validate().unwrap();
        n.layers[1].inputs = 3;
        assert!(n.validate().is_err());
        let mut n = tiny_net();
        n.layers[0].widx[0] = 7; // codebook has 4 entries
        assert!(n.validate().is_err());
        let mut n = tiny_net();
        n.classes = 5;
        assert!(n.validate().is_err());
    }

    #[test]
    fn reference_run_propagates_spikes() {
        let n = tiny_net();
        // input 0 fires every step: hidden n0 gets +6 → fires each step;
        // hidden n1 gets +2, fires every 3rd step.
        let raster = vec![vec![true, false]; 4];
        let counts = n.reference_run(&raster);
        // Spikes propagate within the same timestep in this reference
        // (pipelined chip: layer l's output at t feeds layer l+1 at t).
        // hidden n0 fires t0..t3 → out n0 fires 4×; hidden n1 reaches the
        // threshold at t2 (2+2+2) → out n1 fires once.
        assert_eq!(counts, vec![4, 1]);
    }

    #[test]
    fn pruned_synapses_contribute_nothing() {
        let n = tiny_net();
        assert_eq!(n.layers[1].weight_of(0, 1), 0);
        assert_eq!(n.layers[1].synapse_count(), 2);
    }

    #[test]
    fn classify_argmax_deterministic_on_tie() {
        let n = tiny_net();
        let raster = vec![vec![false, false]; 4];
        assert_eq!(n.classify(&raster), 0); // all-zero counts → class 0
    }

    #[test]
    fn partial_update_keeps_untouched_mp() {
        let n = tiny_net();
        // Only input1 fires: hidden n0 gets codebook[1]=0 (touched, but
        // acc 0), n1 gets 6 and fires.
        let raster = vec![vec![false, true]];
        let counts = n.reference_run(&raster);
        assert_eq!(counts, vec![0, 1]);
    }
}
