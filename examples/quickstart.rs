//! Quickstart: build a small spiking network by hand, map it onto the
//! simulated chip through `SocBuilder`, stream a handful of event
//! samples through a `Session` and print the chip report.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use fullerene_soc::core::neuron::{LeakMode, NeuronParams, ResetMode};
use fullerene_soc::datasets::Workload;
use fullerene_soc::energy::ChipReport;
use fullerene_soc::nn::network::{LayerDesc, NetworkDesc};
use fullerene_soc::nn::quant::kmeans_quantize;
use fullerene_soc::serve::SocBuilder;
use fullerene_soc::util::prng::Rng;

fn main() -> fullerene_soc::Result<()> {
    // 1. A 2-layer SNN for the NMNIST-like geometry. Weights here are
    //    random floats quantized through the same non-uniform codebook
    //    pipeline the trained artifacts use (run `make artifacts` +
    //    examples/edge_inference for the trained version).
    let w = Workload::Nmnist;
    let (inputs, hidden, classes) = (w.inputs(), 64, w.classes());
    let mut rng = Rng::new(7);

    let mut make_layer = |name: &str, a: usize, n: usize| -> fullerene_soc::Result<LayerDesc> {
        let floats: Vec<f64> = (0..a * n).map(|_| rng.normal() * 0.3).collect();
        let q = kmeans_quantize(&floats, 16, 8, 12)?;
        Ok(LayerDesc {
            name: name.into(),
            inputs: a,
            neurons: n,
            codebook: q.codebook,
            widx: q.widx,
            neuron_params: NeuronParams {
                threshold: 120,
                leak: LeakMode::Linear(2),
                reset: ResetMode::Subtract,
                mp_bits: 16,
            },
        })
    };
    let net = NetworkDesc {
        name: "quickstart".into(),
        layers: vec![
            make_layer("hidden", inputs, hidden)?,
            make_layer("out", hidden, classes)?,
        ],
        timesteps: w.timesteps(),
        classes,
    };
    println!(
        "network: {} inputs → {hidden} hidden → {classes} classes, {} synapses",
        inputs,
        net.total_synapses()
    );

    // 2. Assemble the chip (20 cores, fullerene NoC, RISC-V control CPU)
    //    and open a streaming session on it. The builder validates the
    //    whole configuration; the session owns the accounting window.
    let mut session = SocBuilder::new().open_session(&net, "quickstart")?;
    println!(
        "mapped onto {} cores: {}",
        session.soc().mapping().cores_used(),
        session
            .soc()
            .mapping()
            .placements
            .iter()
            .map(|p| format!(
                "core{}←layer{}[{}..{}]",
                p.core_id,
                p.layer,
                p.neuron_offset,
                p.neuron_offset + p.neurons
            ))
            .collect::<Vec<_>>()
            .join(" ")
    );

    // 3. Stream a few synthetic saccade samples through the session.
    let ds = w.generate(5, 42);
    for (i, s) in ds.samples.iter().enumerate() {
        let r = session.push(s)?;
        println!(
            "sample {i}: label {} → predicted {} | {} SOPs, {} cycles",
            s.label, r.predicted, r.sops, r.cycles
        );
        if i == 1 {
            // Incremental report mid-stream — snapshots don't disturb
            // the session's accounting.
            let snap = session.snapshot();
            println!(
                "  (snapshot after {} samples: {:.3} pJ/SOP, {:.2} mW)",
                snap.samples, snap.pj_per_sop, snap.power_mw
            );
        }
    }

    // 4. Close the session: the final Table-I-style chip report plus the
    //    serving latency ledger. Forgetting this is a compile error —
    //    `close` consumes the session.
    let closed = session.close();
    println!(
        "\nsession latency: p50 {:.3} ms, p99 {:.3} ms per sample",
        closed.stats.p50_latency_ms, closed.stats.p99_latency_ms
    );
    println!("{}", ChipReport::table(std::slice::from_ref(&closed.report)).render());
    Ok(())
}
