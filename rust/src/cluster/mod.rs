//! Multi-chip scale-out: one logical network served by a [`Cluster`] of
//! simulated chips joined through an extended off-chip L3 router ring.
//!
//! The paper's fullerene NoC "can be scaled up through extended
//! off-chip high-level router nodes"; this subsystem exercises that
//! claim end to end:
//!
//! * [`ClusterMapper`] — min-cut-flavored contiguous-layer partitioning
//!   of one network across chips (boundary neurons are the objective,
//!   because every cut neuron rides a link an order of magnitude
//!   costlier than any on-chip wire — Moradi & Manohar, arxiv
//!   1809.06016).
//! * [`L3Fabric`] — the off-chip router ring, with its own energy
//!   classes (`HopL3`/`LinkL3`), latency constants
//!   ([`L3_HOP_CYCLES`]/[`L3_LINK_CYCLES`]), static power per ring
//!   router, and the `kill-l3`/`throttle-l3` half of the fault grammar.
//! * [`Cluster`] — the cycle-interleaved lockstep driver: cross-chip
//!   spikes climb core→L1→L2→L3, cross the ring, and descend, with
//!   flit conservation holding cluster-wide
//!   ([`Cluster::conservation`]).
//! * [`Engine`] — the serving dispatch: `chips == 1` runs the plain
//!   [`Soc`] (bit-identical to the pre-cluster paths), `chips > 1`
//!   builds a [`Cluster`]. [`crate::serve::Session`] and the serving
//!   runtime run over an `Engine`, so one session can span chips.

mod cluster;
mod l3;
mod mapper;

pub use cluster::{Cluster, ClusterConservation};
pub use l3::{L3Fabric, L3Stats, L3_HOP_CYCLES, L3_LINK_CYCLES};
pub use mapper::{ClusterMapper, Partition};

use crate::datasets::Sample;
use crate::energy::ChipReport;
use crate::nn::NetworkDesc;
use crate::noc::{FabricHealth, FaultPlan, SimStats};
use crate::soc::{SampleResult, Soc, SocConfig};
use crate::Result;

/// The serving engine behind a session: a single chip or a cluster,
/// chosen by `config.chips`. Every delegated method is the same call on
/// either arm, so the `chips == 1` serving path executes exactly the
/// pre-cluster [`Soc`] code — the bit-identity oracle that anchors the
/// cluster layer to the existing equivalence chains.
pub enum Engine {
    /// One simulated chip (`chips == 1`).
    Chip(Box<Soc>),
    /// N chips over the off-chip L3 ring (`chips > 1`).
    Cluster(Box<Cluster>),
}

impl Engine {
    /// Build the engine `config` asks for.
    pub fn new(net: NetworkDesc, config: SocConfig) -> Result<Engine> {
        if config.chips <= 1 {
            Ok(Engine::Chip(Box::new(Soc::new(net, config)?)))
        } else {
            Ok(Engine::Cluster(Box::new(Cluster::new(net, config)?)))
        }
    }

    /// The single chip, when this engine is one (`None` for clusters).
    pub fn as_soc(&self) -> Option<&Soc> {
        match self {
            Engine::Chip(s) => Some(s),
            Engine::Cluster(_) => None,
        }
    }

    /// The cluster, when this engine is one (`None` for single chips).
    pub fn as_cluster(&self) -> Option<&Cluster> {
        match self {
            Engine::Chip(_) => None,
            Engine::Cluster(c) => Some(c),
        }
    }

    /// Chips behind this engine (1 for the plain chip).
    pub fn chips(&self) -> usize {
        match self {
            Engine::Chip(_) => 1,
            Engine::Cluster(c) => c.chips(),
        }
    }

    /// The engine's configuration.
    pub fn config(&self) -> &SocConfig {
        match self {
            Engine::Chip(s) => &s.config,
            Engine::Cluster(c) => c.config(),
        }
    }

    /// Run one sample (see [`Soc::run_sample`] / [`Cluster::run_sample`]).
    pub fn run_sample(&mut self, sample: &Sample, label_known: bool) -> Result<SampleResult> {
        match self {
            Engine::Chip(s) => s.run_sample(sample, label_known),
            Engine::Cluster(c) => c.run_sample(sample, label_known),
        }
    }

    /// Incremental report over the window so far.
    pub fn snapshot_report(&self, workload: &str) -> ChipReport {
        match self {
            Engine::Chip(s) => s.snapshot_report(workload),
            Engine::Cluster(c) => c.snapshot_report(workload),
        }
    }

    /// Final report + accounting reset.
    pub fn finish_report(&mut self, workload: &str) -> ChipReport {
        match self {
            Engine::Chip(s) => s.finish_report(workload),
            Engine::Cluster(c) => c.finish_report(workload),
        }
    }

    /// Re-arm for a fresh session (warm == fresh, bit for bit).
    pub fn reset_for_session(&mut self) {
        match self {
            Engine::Chip(s) => s.reset_for_session(),
            Engine::Cluster(c) => c.reset_for_session(),
        }
    }

    /// Replace the engine's armed fault plan (drained fabric only — i.e.
    /// between sessions). The retry path power-cycles an engine with
    /// [`Engine::reset_for_session`], which re-arms the *original*
    /// schedule; retry then installs the plan's unfired tail
    /// ([`crate::noc::FaultPlan::shifted`]) so transient events that
    /// already fired don't replay against the retried attempt.
    pub fn rearm_fault_plan(&mut self, plan: FaultPlan) -> Result<()> {
        match self {
            Engine::Chip(s) => s.rearm_fault_plan(plan),
            Engine::Cluster(c) => c.rearm_fault_plan(plan),
        }
    }

    /// Fabric statistics for the window (summed across shards on a
    /// cluster; the ring reports separately via [`Cluster::l3_stats`]).
    pub fn noc_stats(&self) -> SimStats {
        match self {
            Engine::Chip(s) => s.noc_stats(),
            Engine::Cluster(c) => c.noc_stats(),
        }
    }

    /// Degradation counters for the window (cluster: shard NoCs + ring).
    pub fn fabric_health(&self) -> FabricHealth {
        match self {
            Engine::Chip(s) => s.fabric_health(),
            Engine::Cluster(c) => c.fabric_health(),
        }
    }
}
