//! Event ledger: subsystems record architectural events; the ledger turns
//! them into energy (pJ), average power (mW) and efficiency (pJ/SOP).

use super::constants::EnergyParams;

use std::collections::BTreeMap;

/// Classes of architectural events the simulators record.
///
/// Each class maps to exactly one per-event constant in [`EnergyParams`];
/// static power is handled separately via [`EnergyLedger::add_active_cycles`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum EventClass {
    // core
    Sop,
    ZspeWord,
    ZspeForward,
    ZeroSkip,
    MpUpdate,
    MpLeakOnly,
    SpikeFire,
    CacheRead,
    CacheWrite,
    // noc
    HopP2p,
    HopBroadcast,
    HopMerge,
    LinkTraversal,
    /// Flit switched through a level-2 (inter-domain) router.
    HopL2,
    /// Traversal of a link with a level-2 router endpoint (the longer,
    /// repeater-heavy scale-up wires).
    LinkL2,
    /// Flit switched through a level-3 (off-chip, inter-chip) router —
    /// the extended scale-out nodes of the cluster layer. (pJ constants
    /// an order of magnitude above L2, after Moradi & Manohar's on- vs
    /// off-chip cost gap.)
    HopL3,
    /// One traversal of an off-chip chip↔chip serial link (SerDes +
    /// board trace), the dominant inter-chip energy term.
    LinkL3,
    /// Flit discarded on a degraded fabric (dead router or severed route
    /// under an armed [`crate::noc::FaultPlan`]); never charged on a
    /// healthy fabric.
    FlitDropped,
    // cpu
    CpuAlu,
    CpuMem,
    CpuMulDiv,
    CpuBranch,
    EnuIssue,
    // soc
    BusBeat,
    DmaWord,
    ExtMemWord,
    OutBufWrite,
}

impl EventClass {
    /// Per-event energy (pJ) for this class under `p`.
    pub fn energy_pj(self, p: &EnergyParams) -> f64 {
        use EventClass::*;
        match self {
            Sop => p.e_sop,
            ZspeWord => p.e_zspe_word,
            ZspeForward => p.e_zspe_fwd,
            ZeroSkip => p.e_skip,
            MpUpdate => p.e_mp_update,
            MpLeakOnly => p.e_mp_leak_only,
            SpikeFire => p.e_spike_fire,
            CacheRead => p.e_cache_rd,
            CacheWrite => p.e_cache_wr,
            HopP2p => p.e_hop_p2p,
            HopBroadcast => p.e_hop_bcast,
            HopMerge => p.e_hop_merge,
            LinkTraversal => p.e_link,
            HopL2 => p.e_hop_l2,
            LinkL2 => p.e_link_l2,
            HopL3 => p.e_hop_l3,
            LinkL3 => p.e_link_l3,
            FlitDropped => p.e_flit_drop,
            CpuAlu => p.e_cpu_alu,
            CpuMem => p.e_cpu_mem,
            CpuMulDiv => p.e_cpu_muldiv,
            CpuBranch => p.e_cpu_branch,
            EnuIssue => p.e_enu_issue,
            BusBeat => p.e_bus_beat,
            DmaWord => p.e_dma_word,
            ExtMemWord => p.e_extmem_word,
            OutBufWrite => p.e_outbuf_wr,
        }
    }

    /// All classes, for iteration in reports.
    pub const ALL: [EventClass; 27] = [
        EventClass::Sop,
        EventClass::ZspeWord,
        EventClass::ZspeForward,
        EventClass::ZeroSkip,
        EventClass::MpUpdate,
        EventClass::MpLeakOnly,
        EventClass::SpikeFire,
        EventClass::CacheRead,
        EventClass::CacheWrite,
        EventClass::HopP2p,
        EventClass::HopBroadcast,
        EventClass::HopMerge,
        EventClass::LinkTraversal,
        EventClass::HopL2,
        EventClass::LinkL2,
        EventClass::HopL3,
        EventClass::LinkL3,
        EventClass::FlitDropped,
        EventClass::CpuAlu,
        EventClass::CpuMem,
        EventClass::CpuMulDiv,
        EventClass::CpuBranch,
        EventClass::EnuIssue,
        EventClass::BusBeat,
        EventClass::DmaWord,
        EventClass::ExtMemWord,
        EventClass::OutBufWrite,
    ];
}

/// A static-power contributor: a block that was clocked for some cycles at
/// some power, and gated (leaking) the rest of the time.
#[derive(Debug, Clone, Default)]
struct StaticEntry {
    active_cycles: u64,
    gated_cycles: u64,
    p_active_mw: f64,
    p_gated_mw: f64,
}

/// Accumulates event counts + static-power cycle accounting and converts
/// them to energy/power under a given [`EnergyParams`].
///
/// Ledgers are cheap to create, mergeable (`merge`), and serializable so
/// benches can dump raw counts alongside derived numbers.
#[derive(Debug, Clone, Default)]
pub struct EnergyLedger {
    counts: BTreeMap<EventClass, u64>,
    statics: BTreeMap<String, StaticEntry>,
}

impl EnergyLedger {
    /// Empty ledger.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `n` events of class `c`.
    #[inline]
    pub fn add(&mut self, c: EventClass, n: u64) {
        *self.counts.entry(c).or_insert(0) += n;
    }

    /// Record one event of class `c`.
    #[inline]
    pub fn add1(&mut self, c: EventClass) {
        self.add(c, 1);
    }

    /// Count recorded for class `c`.
    pub fn count(&self, c: EventClass) -> u64 {
        self.counts.get(&c).copied().unwrap_or(0)
    }

    /// Record static-power accounting for named block `label`:
    /// `active` cycles at `p_active_mw`, `gated` cycles at `p_gated_mw`.
    pub fn add_static(
        &mut self,
        label: &str,
        active: u64,
        gated: u64,
        p_active_mw: f64,
        p_gated_mw: f64,
    ) {
        let e = self.statics.entry(label.to_string()).or_default();
        e.active_cycles += active;
        e.gated_cycles += gated;
        e.p_active_mw = p_active_mw;
        e.p_gated_mw = p_gated_mw;
    }

    /// Merge another ledger's counts into this one.
    pub fn merge(&mut self, other: &EnergyLedger) {
        for (c, n) in &other.counts {
            *self.counts.entry(*c).or_insert(0) += n;
        }
        for (k, v) in &other.statics {
            let e = self.statics.entry(k.clone()).or_default();
            e.active_cycles += v.active_cycles;
            e.gated_cycles += v.gated_cycles;
            e.p_active_mw = v.p_active_mw;
            e.p_gated_mw = v.p_gated_mw;
        }
    }

    /// Total dynamic energy (pJ) under `p`.
    pub fn dynamic_pj(&self, p: &EnergyParams) -> f64 {
        self.counts
            .iter()
            .map(|(c, n)| c.energy_pj(p) * *n as f64)
            .sum()
    }

    /// Total static energy (pJ) for all blocks at frequency `f_hz`.
    pub fn static_pj(&self, f_hz: f64) -> f64 {
        self.statics
            .values()
            .map(|e| {
                EnergyParams::static_pj(e.p_active_mw, e.active_cycles, f_hz)
                    + EnergyParams::static_pj(e.p_gated_mw, e.gated_cycles, f_hz)
            })
            .sum()
    }

    /// Total energy (pJ).
    pub fn total_pj(&self, p: &EnergyParams, f_hz: f64) -> f64 {
        self.dynamic_pj(p) + self.static_pj(f_hz)
    }

    /// Average power (mW) over `cycles` at `f_hz`.
    pub fn avg_power_mw(&self, p: &EnergyParams, cycles: u64, f_hz: f64) -> f64 {
        if cycles == 0 {
            return 0.0;
        }
        let t_s = cycles as f64 / f_hz;
        self.total_pj(p, f_hz) / 1.0e9 / t_s
    }

    /// Energy per synapse operation (pJ/SOP); `None` when no SOPs ran.
    pub fn pj_per_sop(&self, p: &EnergyParams, f_hz: f64) -> Option<f64> {
        let sops = self.count(EventClass::Sop);
        (sops > 0).then(|| self.total_pj(p, f_hz) / sops as f64)
    }

    /// Core-complex energy (pJ): neuromorphic-core dynamic events plus the
    /// static entries labelled `core*`. This is the paper's Table-I
    /// accounting ("the neuromorphic core achieves … pJ/SOP in
    /// applications") — CPU, NoC, DMA and chip plumbing excluded.
    pub fn core_pj(&self, p: &EnergyParams, f_hz: f64) -> f64 {
        use EventClass::*;
        let dynamic: f64 = [
            Sop, ZspeWord, ZspeForward, ZeroSkip, MpUpdate, MpLeakOnly, SpikeFire, CacheRead,
            CacheWrite,
        ]
        .iter()
        .map(|&c| c.energy_pj(p) * self.count(c) as f64)
        .sum();
        let stat: f64 = self
            .statics
            .iter()
            .filter(|(k, _)| k.starts_with("core"))
            .map(|(_, e)| {
                EnergyParams::static_pj(e.p_active_mw, e.active_cycles, f_hz)
                    + EnergyParams::static_pj(e.p_gated_mw, e.gated_cycles, f_hz)
            })
            .sum();
        dynamic + stat
    }

    /// Core-complex energy per SOP (the paper's Table-I metric).
    pub fn core_pj_per_sop(&self, p: &EnergyParams, f_hz: f64) -> Option<f64> {
        let sops = self.count(EventClass::Sop);
        (sops > 0).then(|| self.core_pj(p, f_hz) / sops as f64)
    }

    /// Detailed breakdown for reports.
    pub fn breakdown(&self, p: &EnergyParams, f_hz: f64) -> EnergyBreakdown {
        let mut by_class = BTreeMap::new();
        for c in EventClass::ALL {
            let n = self.count(c);
            if n > 0 {
                by_class.insert(format!("{c:?}"), c.energy_pj(p) * n as f64);
            }
        }
        let mut by_static = BTreeMap::new();
        for (k, e) in &self.statics {
            by_static.insert(
                k.clone(),
                EnergyParams::static_pj(e.p_active_mw, e.active_cycles, f_hz)
                    + EnergyParams::static_pj(e.p_gated_mw, e.gated_cycles, f_hz),
            );
        }
        EnergyBreakdown {
            dynamic_pj: self.dynamic_pj(p),
            static_pj: self.static_pj(f_hz),
            by_class,
            by_static,
        }
    }
}

/// Itemized energy report (all pJ).
#[derive(Debug, Clone)]
pub struct EnergyBreakdown {
    pub dynamic_pj: f64,
    pub static_pj: f64,
    pub by_class: BTreeMap<String, f64>,
    pub by_static: BTreeMap<String, f64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates_and_prices_events() {
        let p = EnergyParams::nominal();
        let mut l = EnergyLedger::new();
        l.add(EventClass::Sop, 1000);
        l.add1(EventClass::SpikeFire);
        assert_eq!(l.count(EventClass::Sop), 1000);
        let dyn_pj = l.dynamic_pj(&p);
        assert!((dyn_pj - (1000.0 * p.e_sop + p.e_spike_fire)).abs() < 1e-9);
    }

    #[test]
    fn merge_adds_counts_and_statics() {
        let mut a = EnergyLedger::new();
        a.add(EventClass::HopP2p, 5);
        a.add_static("core0", 100, 50, 0.1, 0.01);
        let mut b = EnergyLedger::new();
        b.add(EventClass::HopP2p, 7);
        b.add_static("core0", 10, 5, 0.1, 0.01);
        a.merge(&b);
        assert_eq!(a.count(EventClass::HopP2p), 12);
        let pj = a.static_pj(200.0e6);
        let expect = EnergyParams::static_pj(0.1, 110, 200.0e6)
            + EnergyParams::static_pj(0.01, 55, 200.0e6);
        assert!((pj - expect).abs() < 1e-6);
    }

    #[test]
    fn pj_per_sop_none_without_sops() {
        let l = EnergyLedger::new();
        assert!(l.pj_per_sop(&EnergyParams::nominal(), 1e8).is_none());
    }

    #[test]
    fn avg_power_basic() {
        let p = EnergyParams::nominal();
        let mut l = EnergyLedger::new();
        // 1e9 pJ over 1 second = 1 mW.
        let n = (1.0e9 / p.e_sop) as u64;
        l.add(EventClass::Sop, n);
        let mw = l.avg_power_mw(&p, 100_000_000, 100.0e6);
        assert!((mw - 1.0).abs() < 0.01, "got {mw}");
    }
}
