//! Output buffers (Fig. 7: "Four independent 0.2 KB output buffers are
//! used to store the computing results of different networks").
//!
//! Each buffer accumulates per-class spike counts for one running network
//! and exposes the head word to the CPU's MMIO result ports.

use crate::energy::{EnergyLedger, EventClass};
use crate::{Error, Result};

/// Capacity of one buffer in 16-bit entries (0.2 KB = 100 entries).
pub const ENTRIES_PER_BUF: usize = 100;

/// The four output buffers.
#[derive(Debug, Clone)]
pub struct OutputBuffers {
    bufs: [Vec<u16>; 4],
}

impl Default for OutputBuffers {
    fn default() -> Self {
        Self::new()
    }
}

impl OutputBuffers {
    /// Four empty buffers.
    pub fn new() -> Self {
        OutputBuffers {
            bufs: [
                vec![0; ENTRIES_PER_BUF],
                vec![0; ENTRIES_PER_BUF],
                vec![0; ENTRIES_PER_BUF],
                vec![0; ENTRIES_PER_BUF],
            ],
        }
    }

    /// Clear buffer `b`.
    pub fn clear(&mut self, b: usize) {
        self.bufs[b].iter_mut().for_each(|v| *v = 0);
    }

    /// Record one output spike of class `class` into buffer `b`.
    pub fn record_spike(
        &mut self,
        b: usize,
        class: usize,
        ledger: &mut EnergyLedger,
    ) -> Result<()> {
        if class >= ENTRIES_PER_BUF {
            return Err(Error::Soc(format!(
                "class {class} exceeds output buffer capacity"
            )));
        }
        self.bufs[b][class] = self.bufs[b][class].saturating_add(1);
        ledger.add1(EventClass::OutBufWrite);
        Ok(())
    }

    /// Per-class counts of buffer `b`.
    pub fn counts(&self, b: usize, classes: usize) -> Vec<u32> {
        self.bufs[b][..classes.min(ENTRIES_PER_BUF)]
            .iter()
            .map(|&v| v as u32)
            .collect()
    }

    /// Argmax class of buffer `b` (ties → lowest class).
    pub fn winner(&self, b: usize, classes: usize) -> usize {
        let counts = self.counts(b, classes);
        counts
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.cmp(y.1).then(y.0.cmp(&x.0)))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// The word exposed on the CPU's MMIO result port for buffer `b`:
    /// `winner << 16 | total_spikes` (a compact status the firmware reads).
    pub fn mmio_word(&self, b: usize, classes: usize) -> u32 {
        let counts = self.counts(b, classes);
        let total: u32 = counts.iter().sum::<u32>().min(0xFFFF);
        ((self.winner(b, classes) as u32) << 16) | total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_finds_winner() {
        let mut ob = OutputBuffers::new();
        let mut l = EnergyLedger::new();
        for _ in 0..3 {
            ob.record_spike(0, 2, &mut l).unwrap();
        }
        ob.record_spike(0, 5, &mut l).unwrap();
        assert_eq!(ob.winner(0, 10), 2);
        assert_eq!(ob.counts(0, 10)[2], 3);
        assert_eq!(ob.mmio_word(0, 10), (2 << 16) | 4);
        assert_eq!(l.count(crate::energy::EventClass::OutBufWrite), 4);
    }

    #[test]
    fn buffers_independent() {
        let mut ob = OutputBuffers::new();
        let mut l = EnergyLedger::new();
        ob.record_spike(1, 0, &mut l).unwrap();
        assert_eq!(ob.counts(0, 4), vec![0; 4]);
        assert_eq!(ob.counts(1, 4)[0], 1);
        ob.clear(1);
        assert_eq!(ob.counts(1, 4)[0], 0);
    }

    #[test]
    fn class_capacity_enforced() {
        let mut ob = OutputBuffers::new();
        let mut l = EnergyLedger::new();
        assert!(ob.record_spike(0, 100, &mut l).is_err());
    }
}
