//! Minimal blocking HTTP/1.1 client over one keep-alive connection —
//! just enough protocol for the load generator, the HTTP bench and the
//! end-to-end tests to drive the front end without external crates.

use crate::util::json::Json;
use crate::{Error, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

/// One parsed response.
#[derive(Debug)]
pub struct ClientResponse {
    /// Status code.
    pub status: u16,
    /// Headers, names lowercased.
    pub headers: Vec<(String, String)>,
    /// Body as UTF-8 (every fullerene-soc endpoint emits text or JSON).
    pub body: String,
}

impl ClientResponse {
    /// First header value by lowercase name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Parse the body as JSON.
    pub fn json(&self) -> Result<Json> {
        Json::parse(&self.body)
    }
}

/// A persistent connection to one server.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    host: String,
}

impl Client {
    /// Connect with a 10 s I/O timeout.
    pub fn connect(addr: &str) -> Result<Client> {
        Client::connect_timeout_ms(addr, 10_000)
    }

    /// Connect with an explicit per-operation I/O timeout.
    pub fn connect_timeout_ms(addr: &str, timeout_ms: u64) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::Runtime(format!("cannot connect to {addr}: {e}")))?;
        let timeout = Some(Duration::from_millis(timeout_ms.max(1)));
        stream.set_read_timeout(timeout)?;
        stream.set_write_timeout(timeout)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client {
            reader,
            writer: stream,
            host: addr.to_string(),
        })
    }

    /// Issue one request on the persistent connection. `body` is sent
    /// `Content-Length`-framed; `extra_headers` ride along verbatim.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
        extra_headers: &[(&str, &str)],
    ) -> Result<ClientResponse> {
        let mut req = format!("{method} {path} HTTP/1.1\r\nHost: {}\r\n", self.host);
        for (k, v) in extra_headers {
            req.push_str(&format!("{k}: {v}\r\n"));
        }
        let body = body.unwrap_or("");
        if !body.is_empty() || method == "POST" {
            req.push_str(&format!("Content-Length: {}\r\n", body.len()));
        }
        req.push_str("\r\n");
        self.writer.write_all(req.as_bytes())?;
        self.writer.write_all(body.as_bytes())?;
        self.writer.flush()?;
        self.read_response()
    }

    /// POST a JSON value.
    pub fn post_json(&mut self, path: &str, body: &Json) -> Result<ClientResponse> {
        self.request(
            "POST",
            path,
            Some(&body.to_string()),
            &[("Content-Type", "application/json")],
        )
    }

    /// GET a path.
    pub fn get(&mut self, path: &str) -> Result<ClientResponse> {
        self.request("GET", path, None, &[])
    }

    fn read_line(&mut self) -> Result<String> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            return Err(Error::Runtime(
                "server closed the connection mid-response".into(),
            ));
        }
        while line.ends_with('\n') || line.ends_with('\r') {
            line.pop();
        }
        Ok(line)
    }

    fn read_response(&mut self) -> Result<ClientResponse> {
        let status_line = self.read_line()?;
        let status = status_line
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse::<u16>().ok())
            .ok_or_else(|| {
                Error::Runtime(format!("bad status line '{status_line}'"))
            })?;
        let mut headers = Vec::new();
        let mut content_length = 0usize;
        loop {
            let line = self.read_line()?;
            if line.is_empty() {
                break;
            }
            if let Some((k, v)) = line.split_once(':') {
                let k = k.trim().to_ascii_lowercase();
                let v = v.trim().to_string();
                if k == "content-length" {
                    content_length = v.parse().map_err(|_| {
                        Error::Runtime(format!("bad Content-Length '{v}'"))
                    })?;
                }
                headers.push((k, v));
            }
        }
        let mut body = vec![0u8; content_length];
        std::io::Read::read_exact(&mut self.reader, &mut body)?;
        let body = String::from_utf8(body)
            .map_err(|_| Error::Runtime("non-UTF-8 response body".into()))?;
        Ok(ClientResponse {
            status,
            headers,
            body,
        })
    }
}
