//! Cross-module property tests (in-tree `propcheck` loop; seeds reported
//! on failure): coordinator/routing/state invariants the paper's system
//! depends on.

use fullerene_soc::core::neuron::{LeakMode, NeuronParams, ResetMode};
use fullerene_soc::core::{pack_spikes, unpack_spikes, Codebook, NeuroCore, SynapsesBuilder};
use fullerene_soc::energy::EnergyParams;
use fullerene_soc::nn::network::{LayerDesc, NetworkDesc};
use fullerene_soc::nn::Mapping;
use fullerene_soc::noc::{Dest, NocSim, Topology};
use fullerene_soc::util::propcheck::check;

#[test]
fn prop_noc_p2p_delivers_exactly_once() {
    check("noc-exactly-once", 25, 0xA11CE, |r| {
        let mut sim = NocSim::new(Topology::fullerene(), 4, EnergyParams::nominal());
        let n_flits = 1 + r.below_usize(60);
        let mut expected = std::collections::BTreeMap::new();
        for _ in 0..n_flits {
            let src = r.below_usize(20);
            let mut dst = r.below_usize(19);
            if dst >= src {
                dst += 1;
            }
            let axon = r.next_u32() % 512;
            let ids = sim.inject(src, &Dest::Core(dst), axon);
            expected.insert(ids.start, (dst, axon));
        }
        sim.run_until_drained(100_000).unwrap();
        let delivered = sim.delivered();
        assert_eq!(delivered.len(), n_flits);
        let mut seen = std::collections::BTreeSet::new();
        for d in delivered {
            assert!(seen.insert(d.flit.id), "flit {} delivered twice", d.flit.id);
            let (dst, axon) = expected[&d.flit.id];
            assert_eq!(d.flit.dst_core, dst);
            assert_eq!(d.flit.axon, axon);
        }
    });
}

#[test]
fn prop_noc_broadcast_reaches_every_target_once() {
    check("noc-broadcast-cover", 20, 0xB0A5, |r| {
        let mut sim = NocSim::new(Topology::fullerene(), 4, EnergyParams::nominal());
        let src = r.below_usize(20);
        let k = 1 + r.below_usize(8);
        let mut dsts: Vec<usize> = r
            .choose_k(19, k)
            .into_iter()
            .map(|d| if d >= src { d + 1 } else { d })
            .collect();
        dsts.sort_unstable();
        sim.inject(src, &Dest::Cores(dsts.clone()), 3);
        sim.run_until_drained(100_000).unwrap();
        let mut got: Vec<usize> = sim.delivered().iter().map(|d| d.flit.dst_core).collect();
        got.sort_unstable();
        assert_eq!(got, dsts);
    });
}

#[test]
fn prop_flit_conservation_on_every_topology() {
    // Conservation law of the NoC: at every cycle,
    //   injected == delivered + in_flight,
    // no flit is duplicated (unique ids), none is dropped, and every
    // destination receives exactly the multiset of flits addressed to it —
    // across the fullerene domain, the mesh/torus/ring baselines AND the
    // hierarchical multi-domain fabric under random P2P+broadcast traffic.
    check("noc-flit-conservation", 12, 0xF117, |r| {
        for topo in [
            Topology::fullerene(),
            Topology::mesh2d(4, 5),
            Topology::torus(4, 5),
            Topology::ring(20),
            Topology::multi_domain(3),
        ] {
            let name = topo.name.clone();
            let n = topo.cores().len();
            let mut sim = NocSim::new(topo, 4, EnergyParams::nominal());
            let mut injected = 0u64;
            let mut expected: std::collections::BTreeMap<usize, u64> = Default::default();
            let rounds = 1 + r.below_usize(4);
            for _ in 0..rounds {
                let burst = 1 + r.below_usize(25);
                for _ in 0..burst {
                    let src = r.below_usize(n);
                    if r.bool(0.3) {
                        // broadcast to 2–4 distinct destinations
                        let k = 2 + r.below_usize(3);
                        let dsts: Vec<usize> = r
                            .choose_k(n - 1, k)
                            .into_iter()
                            .map(|d| if d >= src { d + 1 } else { d })
                            .collect();
                        let ids = sim.inject(src, &Dest::Cores(dsts.clone()), src as u32);
                        injected += ids.end - ids.start;
                        for d in dsts {
                            *expected.entry(d).or_insert(0) += 1;
                        }
                    } else {
                        let mut dst = r.below_usize(n - 1);
                        if dst >= src {
                            dst += 1;
                        }
                        let ids = sim.inject(src, &Dest::Core(dst), src as u32);
                        injected += ids.end - ids.start;
                        *expected.entry(dst).or_insert(0) += 1;
                    }
                }
                // Let the fabric move with traffic still in flight; the
                // conservation law must hold at every intermediate cycle.
                for _ in 0..r.below_usize(30) {
                    sim.step();
                    assert_eq!(
                        injected,
                        sim.delivered().len() as u64 + sim.in_flight(),
                        "{name}: conservation violated mid-flight"
                    );
                }
            }
            sim.run_until_drained(200_000).unwrap();
            assert_eq!(sim.in_flight(), 0, "{name}: undrained flits");
            let mut got: std::collections::BTreeMap<usize, u64> = Default::default();
            let mut seen = std::collections::BTreeSet::new();
            for d in sim.delivered() {
                assert!(seen.insert(d.flit.id), "{name}: flit {} duplicated", d.flit.id);
                assert_eq!(
                    d.flit.axon, d.flit.src_core as u32,
                    "{name}: payload corrupted in flight"
                );
                *got.entry(d.flit.dst_core).or_insert(0) += 1;
            }
            assert_eq!(got, expected, "{name}: delivery multiset mismatch");
        }
    });
}

#[test]
fn prop_flit_conservation_survives_fault_plans() {
    // The degraded-fabric conservation law: with an armed fault plan,
    //   injected == delivered + dropped + in_flight
    // at EVERY cycle — kills drop eagerly, severed links strand (still
    // in flight), and nothing is ever double-counted. The `FlitDropped`
    // ledger class must agree exactly with the health counter.
    use fullerene_soc::energy::EventClass;
    use fullerene_soc::noc::{FaultPlan, LinkLevel, When};
    check("noc-fault-conservation", 12, 0xFA17, |r| {
        for topo in [
            Topology::fullerene(),
            Topology::mesh2d(4, 5),
            Topology::multi_domain(2),
        ] {
            let name = topo.name.clone();
            let n = topo.cores().len();
            let routers = topo.routers();
            // Random schedule: router kills, fractional kills, congestion
            // windows, link throttles, and sometimes a severed
            // router-router link (the one fault class that strands).
            let mut plan = FaultPlan::none();
            for _ in 0..1 + r.below_usize(3) {
                let router = routers[r.below_usize(routers.len())];
                plan = match r.below(4) {
                    0 => plan.kill_router(router, When::Cycle(r.below(60))),
                    1 => plan.congest(router, 1 + r.below(15), When::Cycle(r.below(40))),
                    2 => plan.throttle(
                        if r.bool(0.5) { LinkLevel::L1 } else { LinkLevel::L2 },
                        1 + r.below(4),
                        When::Cycle(r.below(30)),
                    ),
                    _ => plan.kill_frac(
                        r.below(30) as f64 / 100.0,
                        r.next_u32() as u64,
                        When::Cycle(r.below(50)),
                    ),
                };
            }
            if r.bool(0.4) {
                let a = routers[r.below_usize(routers.len())];
                let nbs: Vec<usize> = topo
                    .neighbors(a)
                    .iter()
                    .copied()
                    .filter(|&b| topo.kind(b).is_router())
                    .collect();
                if !nbs.is_empty() {
                    let b = nbs[r.below_usize(nbs.len())];
                    plan = plan.kill_link(a, b, When::Cycle(r.below(40)));
                }
            }

            let mut sim = NocSim::new(topo, 4, EnergyParams::nominal());
            sim.set_fault_plan(plan).unwrap();
            let mut injected = 0u64;
            let conserved = |sim: &NocSim, injected: u64, at: &str| {
                let dropped = sim.fabric_health().dropped;
                assert_eq!(
                    injected,
                    sim.delivered().len() as u64 + dropped + sim.in_flight(),
                    "{name}: conservation violated {at} \
                     (delivered {} dropped {dropped} in-flight {})",
                    sim.delivered().len(),
                    sim.in_flight()
                );
                assert_eq!(
                    sim.snapshot_ledger().count(EventClass::FlitDropped),
                    dropped,
                    "{name}: FlitDropped ledger diverged from the health counter {at}"
                );
            };
            for _ in 0..2 + r.below_usize(3) {
                for _ in 0..1 + r.below_usize(25) {
                    let src = r.below_usize(n);
                    let mut dst = r.below_usize(n - 1);
                    if dst >= src {
                        dst += 1;
                    }
                    let ids = sim.inject(src, &Dest::Core(dst), src as u32);
                    injected += ids.end - ids.start;
                }
                for _ in 0..r.below_usize(40) {
                    sim.step();
                    conserved(&sim, injected, "mid-flight");
                }
            }
            // Kill-only degradation drains; severed links may legitimately
            // strand flits, surfacing the FabricDegraded fixed point. The
            // law holds either way.
            match sim.run_until_drained(200_000) {
                Ok(()) => assert_eq!(sim.in_flight(), 0, "{name}: drained but in flight"),
                Err(e) => {
                    assert!(sim.in_flight() > 0, "{name}: drain failed with nothing in flight");
                    assert!(
                        e.to_string().contains("not drained"),
                        "{name}: unexpected drain error {e}"
                    );
                }
            }
            conserved(&sim, injected, "after the drain");
            // No flit is ever double-counted: delivered ids are unique.
            let mut seen = std::collections::BTreeSet::new();
            for d in sim.delivered() {
                assert!(seen.insert(d.flit.id), "{name}: flit {} duplicated", d.flit.id);
            }
        }
    });
}

#[test]
fn prop_zspe_never_creates_or_drops_spikes() {
    check("pack-unpack-exact", 100, 0x5B1, |r| {
        let n = 1 + r.below_usize(200);
        let spikes: Vec<bool> = (0..n).map(|_| r.bool(0.3)).collect();
        let words = pack_spikes(&spikes);
        assert_eq!(unpack_spikes(&words, n), spikes);
        let ones: u32 = words.iter().map(|w| w.count_ones()).sum();
        assert_eq!(ones as usize, spikes.iter().filter(|&&s| s).count());
    });
}

#[test]
fn prop_core_sop_count_is_sum_of_fanouts() {
    check("core-sop-count", 20, 0xC0DE, |r| {
        let axons = 16 + r.below_usize(64);
        let neurons = 1 + r.below_usize(64);
        let cb = Codebook::default_log16();
        let mut b = SynapsesBuilder::new(axons, neurons, cb.n());
        let mut fanout = vec![0u64; axons];
        for a in 0..axons {
            for n in 0..neurons {
                if r.bool(0.4) {
                    b.connect(a, n, r.below(16) as u8).unwrap();
                    fanout[a] += 1;
                }
            }
        }
        let mut core = NeuroCore::new(
            1,
            axons,
            neurons,
            NeuronParams::default(),
            cb,
            b.build(),
            EnergyParams::nominal(),
        )
        .unwrap();
        let spikes: Vec<u32> = (0..axons)
            .filter(|_| r.bool(0.5))
            .map(|a| a as u32)
            .collect();
        let expect: u64 = spikes.iter().map(|&a| fanout[a as usize]).sum();
        core.stage_input_spikes(&spikes);
        let out = core.tick_timestep();
        assert_eq!(out.stats.pipeline.sops, expect);
        assert_eq!(out.stats.pipeline.spikes_forwarded, spikes.len() as u64);
    });
}

#[test]
fn prop_mapper_places_every_neuron_exactly_once() {
    check("mapper-coverage", 30, 0x3A9, |r| {
        let cb = Codebook::default_log16();
        let params = NeuronParams::default();
        let hidden = 1 + r.below_usize(300);
        let classes = 1 + r.below_usize(20);
        let inputs = 1 + r.below_usize(64);
        let net = NetworkDesc {
            name: "prop".into(),
            layers: vec![
                LayerDesc {
                    name: "h".into(),
                    inputs,
                    neurons: hidden,
                    codebook: cb.clone(),
                    widx: vec![0; inputs * hidden],
                    neuron_params: params.clone(),
                },
                LayerDesc {
                    name: "o".into(),
                    inputs: hidden,
                    neurons: classes,
                    codebook: cb.clone(),
                    widx: vec![0; hidden * classes],
                    neuron_params: params.clone(),
                },
            ],
            timesteps: 2,
            classes,
        };
        let cap = 1 + r.below_usize(64);
        match Mapping::plan(&net, 20, cap) {
            Ok(m) => {
                for (li, layer) in net.layers.iter().enumerate() {
                    let mut covered = vec![false; layer.neurons];
                    for p in m.placements.iter().filter(|p| p.layer == li) {
                        assert!(p.neurons <= cap);
                        for n in p.neuron_offset..p.neuron_offset + p.neurons {
                            assert!(!covered[n], "neuron {n} placed twice");
                            covered[n] = true;
                        }
                    }
                    assert!(covered.iter().all(|&c| c), "layer {li} gap");
                }
                // No two placements share a physical core.
                let mut ids: Vec<usize> = m.placements.iter().map(|p| p.core_id).collect();
                ids.sort_unstable();
                ids.dedup();
                assert_eq!(ids.len(), m.placements.len());
            }
            Err(_) => {
                // Must only fail when the network genuinely doesn't fit.
                let need: usize = net
                    .layers
                    .iter()
                    .map(|l| l.neurons.div_ceil(cap))
                    .sum();
                assert!(need > 20, "mapper refused a fitting network (need {need})");
            }
        }
    });
}

#[test]
fn prop_neuron_mp_always_within_register_range() {
    check("mp-range", 50, 0x90D, |r| {
        use fullerene_soc::core::NeuronArray;
        let bits = 8 + r.below(9) as u32; // 8..16
        let params = NeuronParams {
            threshold: 1 + r.below(1 << (bits - 1)) as i32,
            leak: match r.below(3) {
                0 => LeakMode::None,
                1 => LeakMode::Linear(r.below(16) as i32),
                _ => LeakMode::Shift(1 + r.below(4) as u8),
            },
            reset: if r.bool(0.5) { ResetMode::Zero } else { ResetMode::Subtract },
            mp_bits: bits,
        };
        let (lo, hi) = params.mp_range();
        let mut arr = NeuronArray::new(4, params);
        for _ in 0..200 {
            let i = r.below_usize(4);
            let acc = r.range_i64(-40000, 40000) as i32;
            arr.update_one(i, acc);
            let m = arr.mp(i);
            assert!(m >= lo && m <= hi, "mp {m} outside [{lo}, {hi}]");
        }
    });
}

#[test]
fn prop_reference_run_spike_conservation() {
    // Output spike counts can never exceed neurons × timesteps, and an
    // all-zero raster yields zero spikes.
    check("reference-bounds", 20, 0xFEED, |r| {
        let cb = Codebook::default_log16();
        let classes = 1 + r.below_usize(8);
        let inputs = 1 + r.below_usize(32);
        let t = 1 + r.below_usize(8);
        let net = NetworkDesc {
            name: "c".into(),
            layers: vec![LayerDesc {
                name: "o".into(),
                inputs,
                neurons: classes,
                codebook: cb,
                widx: (0..inputs * classes).map(|_| r.below(16) as u8).collect(),
                neuron_params: NeuronParams::default(),
            }],
            timesteps: t,
            classes,
        };
        let zero = vec![vec![false; inputs]; t];
        assert!(net.reference_run(&zero).iter().all(|&c| c == 0));
        let full = vec![vec![true; inputs]; t];
        let counts = net.reference_run(&full);
        assert!(counts.iter().all(|&c| c as usize <= t));
    });
}

#[test]
fn prop_quantizer_respects_codebook_geometry() {
    check("quant-geometry", 20, 0x0B0E, |r| {
        use fullerene_soc::nn::quant::kmeans_quantize;
        let len = 30 + r.below_usize(200);
        let w: Vec<f64> = (0..len).map(|_| r.normal() * 0.5).collect();
        let n = [4usize, 8, 16][r.below_usize(3)];
        let bits = [4usize, 8, 16][r.below_usize(3)];
        let q = kmeans_quantize(&w, n, bits, 8).unwrap();
        assert_eq!(q.codebook.n(), n);
        assert_eq!(q.codebook.w_bits(), bits);
        assert_eq!(q.widx.len(), len);
    });
}
