//! Experiment coordination: ties datasets, the SoC simulator and the XLA
//! golden model together into reproducible experiment runs (the layer the
//! CLI and benches drive).

pub mod runner;

pub use runner::{ExperimentConfig, ExperimentRunner, GoldenCheck};
