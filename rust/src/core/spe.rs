//! Dual synapse process engines (SPE).
//!
//! The two 4-bit engines jointly retire [`super::SPE_LANES`] (= 4) synapse
//! operations per cycle: weight-index fetch → codebook read → saturating
//! accumulate into the partial-membrane-potential register of the target
//! neuron. The SPE consumes axon jobs queued by the ZSPE; a full queue
//! back-pressures the ZSPE (a pipeline stall).

use super::codebook::Codebook;
use super::synapses::Synapses;
use std::collections::VecDeque;

/// One queued unit of SPE work: an axon whose synapse list must be walked.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Job {
    /// Axon id.
    pub axon: u32,
    /// Next synapse position within the axon's list.
    pub pos: u32,
}

/// SPE state: the job queue and the in-flight job.
#[derive(Debug, Clone)]
pub struct Spe {
    queue: VecDeque<Job>,
    current: Option<Job>,
    capacity: usize,
}

/// Scratch accumulation target shared with the neuron updater.
pub struct AccumCtx<'a> {
    /// Partial-MP accumulators, one per neuron.
    pub acc: &'a mut [i32],
    /// Touched flags (first-touch detection for the partial-update list).
    pub touched: &'a mut [bool],
    /// Ordered list of touched neurons.
    pub touched_list: &'a mut Vec<u32>,
}

impl Spe {
    /// New SPE with a job queue of `capacity` entries (hardware buffer).
    pub fn new(capacity: usize) -> Self {
        Spe {
            queue: VecDeque::with_capacity(capacity),
            current: None,
            capacity,
        }
    }

    /// Free slots in the job queue.
    pub fn free_slots(&self) -> usize {
        self.capacity - self.queue.len()
    }

    /// True when no queued nor in-flight work remains.
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.current.is_none()
    }

    /// Enqueue an axon job (caller must have checked `free_slots`).
    pub fn push(&mut self, axon: u32) {
        debug_assert!(self.queue.len() < self.capacity, "SPE queue overflow");
        self.queue.push_back(Job { axon, pos: 0 });
    }

    /// Bulk-drain every queued and in-flight job (hot-path fast lane used
    /// once the ZSPE has nothing more to forward). Cycle-exact with
    /// repeated [`Self::step`]: the stepper packs 4 lanes across job
    /// boundaries, so draining `S` remaining synapse ops takes
    /// `ceil(S / 4)` cycles either way. Returns `(sops, cycles)`.
    pub fn drain_bulk(&mut self, syn: &Synapses, cb: &Codebook, ctx: &mut AccumCtx) -> (u64, u64) {
        let mut sops = 0u64;
        loop {
            let job = match self.current.take() {
                Some(j) => j,
                None => match self.queue.pop_front() {
                    Some(j) => j,
                    None => break,
                },
            };
            let (targets, widx) = syn.slices_of(job.axon as usize);
            let a = job.pos as usize;
            for (&t, &w) in targets[a..].iter().zip(&widx[a..]) {
                let ti = t as usize;
                ctx.acc[ti] = ctx.acc[ti].saturating_add(cb.weight(w));
                if !ctx.touched[ti] {
                    ctx.touched[ti] = true;
                    ctx.touched_list.push(t);
                }
            }
            sops += (targets.len() - a) as u64;
        }
        (sops, sops.div_ceil(super::SPE_LANES as u64))
    }

    /// Fast-forward through one whole job (used by the pipeline when the
    /// front stages are provably blocked on a full queue — the only
    /// possible progress is the SPE retiring its in-flight job). Returns
    /// `(sops, cycles)`; a no-op when idle.
    pub fn fast_forward_one_job(
        &mut self,
        syn: &Synapses,
        cb: &Codebook,
        ctx: &mut AccumCtx,
    ) -> (u64, u64) {
        let job = match self.current.take() {
            Some(j) => j,
            None => match self.queue.pop_front() {
                Some(j) => j,
                None => return (0, 0),
            },
        };
        let (targets, widx) = syn.slices_of(job.axon as usize);
        let a = job.pos as usize;
        for (&t, &w) in targets[a..].iter().zip(&widx[a..]) {
            let ti = t as usize;
            ctx.acc[ti] = ctx.acc[ti].saturating_add(cb.weight(w));
            if !ctx.touched[ti] {
                ctx.touched[ti] = true;
                ctx.touched_list.push(t);
            }
        }
        let sops = (targets.len() - a) as u64;
        (sops, sops.div_ceil(super::SPE_LANES as u64))
    }

    /// Advance one cycle: retire up to [`super::SPE_LANES`] synapse ops.
    /// Returns the number of SOPs performed this cycle.
    pub fn step(&mut self, syn: &Synapses, cb: &Codebook, ctx: &mut AccumCtx) -> u32 {
        let mut lanes = super::SPE_LANES as u32;
        let mut sops = 0;
        while lanes > 0 {
            let job = match self.current {
                Some(j) => j,
                None => match self.queue.pop_front() {
                    Some(j) => {
                        self.current = Some(j);
                        j
                    }
                    None => break,
                },
            };
            let (targets, widx) = syn.slices_of(job.axon as usize);
            let remaining = targets.len() as u32 - job.pos;
            if remaining == 0 {
                self.current = None;
                continue;
            }
            let take = remaining.min(lanes);
            let a = job.pos as usize;
            let b = (job.pos + take) as usize;
            for (&t, &w) in targets[a..b].iter().zip(&widx[a..b]) {
                let ti = t as usize;
                ctx.acc[ti] = ctx.acc[ti].saturating_add(cb.weight(w));
                if !ctx.touched[ti] {
                    ctx.touched[ti] = true;
                    ctx.touched_list.push(t);
                }
            }
            sops += take;
            lanes -= take;
            if job.pos + take == targets.len() as u32 {
                self.current = None;
            } else {
                self.current = Some(Job {
                    axon: job.axon,
                    pos: job.pos + take,
                });
            }
        }
        sops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::synapses::SynapsesBuilder;

    fn fixture() -> (Synapses, Codebook) {
        let mut b = SynapsesBuilder::new(2, 8, 16);
        // axon 0 → 6 synapses, axon 1 → 2 synapses.
        for n in 0..6 {
            b.connect(0, n, 10).unwrap(); // weight(10) = 4 in default_log16
        }
        b.connect(1, 6, 9).unwrap(); // weight(9) = 1
        b.connect(1, 7, 9).unwrap();
        (b.build(), Codebook::default_log16())
    }

    fn ctx<'a>(
        acc: &'a mut [i32],
        touched: &'a mut [bool],
        list: &'a mut Vec<u32>,
    ) -> AccumCtx<'a> {
        AccumCtx {
            acc,
            touched,
            touched_list: list,
        }
    }

    #[test]
    fn retires_four_lanes_per_cycle_across_jobs() {
        let (syn, cb) = fixture();
        let mut spe = Spe::new(8);
        spe.push(0);
        spe.push(1);
        let mut acc = vec![0i32; 8];
        let mut touched = vec![false; 8];
        let mut list = Vec::new();
        // cycle 1: 4 sops from axon 0.
        assert_eq!(spe.step(&syn, &cb, &mut ctx(&mut acc, &mut touched, &mut list)), 4);
        // cycle 2: 2 remaining from axon 0 + 2 from axon 1.
        assert_eq!(spe.step(&syn, &cb, &mut ctx(&mut acc, &mut touched, &mut list)), 4);
        assert!(spe.idle());
        assert_eq!(acc[0], 4);
        assert_eq!(acc[6], 1);
        assert_eq!(list.len(), 8);
    }

    #[test]
    fn zero_fanout_job_consumes_no_lanes() {
        let mut b = SynapsesBuilder::new(2, 2, 16);
        b.connect(1, 0, 9).unwrap();
        let syn = b.build();
        let cb = Codebook::default_log16();
        let mut spe = Spe::new(4);
        spe.push(0); // fanout 0
        spe.push(1);
        let mut acc = vec![0i32; 2];
        let mut touched = vec![false; 2];
        let mut list = Vec::new();
        let sops = spe.step(&syn, &cb, &mut ctx(&mut acc, &mut touched, &mut list));
        assert_eq!(sops, 1);
        assert!(spe.idle());
    }

    #[test]
    fn touched_list_records_first_touch_once() {
        let (syn, cb) = fixture();
        let mut spe = Spe::new(8);
        spe.push(0);
        let mut acc = vec![0i32; 8];
        let mut touched = vec![false; 8];
        let mut list = Vec::new();
        spe.step(&syn, &cb, &mut ctx(&mut acc, &mut touched, &mut list));
        spe.step(&syn, &cb, &mut ctx(&mut acc, &mut touched, &mut list));
        assert_eq!(list, vec![0, 1, 2, 3, 4, 5]);
    }
}
