//! Fig. 5 reproduction: (a) average NoC latency across topologies,
//! (b) node-degree statistics, (c) CMRouter throughput and transmission
//! energy per mode, plus the level-2 multi-domain scaling scenario
//! (cycle-simulated hierarchical fabric vs the analytic oracle).
//!
//! Paper anchors: fullerene average latency 3.16 hops (up to 39.9 % lower
//! than the baselines), average degree 3.75 (+32 % vs 2D-mesh), degree
//! variance 0.94 (others ≤ 2.6); router 0.026 pJ/hop P2P, 0.009 pJ/hop
//! 1-to-3 broadcast, 0.2–0.4 spike/cycle throughput.

use fullerene_soc::benches_support;
use fullerene_soc::energy::EnergyParams;
use fullerene_soc::noc::traffic::{Pattern, TrafficGen};
use fullerene_soc::noc::{NocSim, TopoStats, Topology};
use fullerene_soc::util::bench::Bench;

fn main() {
    // --- Fig. 5a/5b: static topology comparison ---------------------------
    println!("## Fig. 5a/5b: topology comparison");
    let stats = vec![
        TopoStats::compute(&Topology::fullerene()),
        TopoStats::compute(&Topology::mesh2d(4, 5)),
        TopoStats::compute(&Topology::torus(4, 5)),
        TopoStats::compute(&Topology::ring(20)),
        TopoStats::compute(&Topology::tree(4, 20)),
    ];
    println!("{}", TopoStats::table(&stats).render());
    let f = &stats[0];
    let worst = stats[1..]
        .iter()
        .map(|s| s.avg_core_hops)
        .fold(0.0f64, f64::max);
    println!(
        "fullerene: degree {:.2} (paper 3.75), variance {:.2} (paper 0.94), \
         avg distance {:.2} links = {:.2} router hops; vs worst baseline \
         {:.1}% lower (paper: up to 39.9%)",
        f.avg_degree,
        f.degree_variance,
        f.avg_core_hops,
        f.avg_core_hops / 2.0,
        (1.0 - f.avg_core_hops / worst) * 100.0
    );

    // --- Fig. 5c: router load sweep ----------------------------------------
    println!("\n## Fig. 5c: CMRouter throughput & energy");
    println!("{}", benches_support::fig5c_table(42).render());
    println!(
        "paper anchors: 0.026 pJ/hop (P2P), 0.009 pJ/hop (1-to-3 broadcast), \
         0.2–0.4 spike/cycle at saturation"
    );

    // --- multi-domain scaling (level-2 fabric, cycle-simulated) ------------
    println!("\n## multi-domain scaling: simulated L2 fabric vs analytic oracle");
    println!(
        "{}",
        benches_support::multidomain_table(&[1, 2, 4, 8], 400, 0.8, 42).render()
    );
    println!(
        "80% of traffic stays intra-domain (the mapper's layer-locality \
         regime); inter-domain flits climb core→L1→L2, ride the L2 ring \
         and descend, every hop energy-ledgered"
    );

    // --- serving path: throughput + session latency -------------------------
    // (machine-readable BENCH_sessions.json for the perf trajectory)
    println!("\n## serving path: ServeRuntime sessions bench");
    let sb = benches_support::sessions_bench(6, 8, 4, 42).expect("sessions bench");
    println!(
        "{} sessions x {} samples on {} workers: {:.1} samples/s host, \
         session latency p50 {:.3} ms / p99 {:.3} ms (simulated), \
         merged {:.3} pJ/SOP",
        sb.sessions,
        sb.samples_per_session,
        sb.workers,
        sb.throughput_samples_per_s,
        sb.p50_session_latency_ms,
        sb.p99_session_latency_ms,
        sb.merged_pj_per_sop
    );
    let bench_json = std::path::Path::new("BENCH_sessions.json");
    benches_support::sessions_bench_json(&sb)
        .write_file(bench_json)
        .expect("write BENCH_sessions.json");
    println!("wrote {}", bench_json.display());

    // --- simulator wall-clock (perf tracking) -------------------------------
    let mut b = Bench::new("fig5_noc");
    b.bench("noc-300cy/light", || {
        let mut sim = NocSim::new(Topology::fullerene(), 4, EnergyParams::nominal());
        let mut tg = TrafficGen::new(Pattern::Uniform, 0.05, 20, 3);
        tg.run(&mut sim, 300).unwrap();
        sim.stats().delivered
    });
    // Saturation: the one shared recipe (same scenario as the CI
    // perf-smoke job `noc_throughput` and the serve_sessions example).
    b.bench("noc-sat/shared-recipe", || {
        let mut sim = NocSim::new(Topology::fullerene(), 4, EnergyParams::nominal());
        let mut tg = benches_support::saturation_gen(20, 3);
        tg.run(&mut sim, benches_support::SAT_OFFER_CYCLES).unwrap();
        sim.stats().delivered
    });
    b.bench("multidomain-4x/400-flits", || {
        let m = fullerene_soc::noc::MultiDomain::new(4);
        m.measure(400, 0.8, 7, EnergyParams::nominal())
            .unwrap()
            .delivered
    });
    b.finish();
}
