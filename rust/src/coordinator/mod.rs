//! Batch experiment coordination: ties datasets, the SoC simulator and
//! the XLA golden model together into reproducible experiment runs (the
//! layer the CLI and benches drive for dataset-shaped work). Built on
//! the streaming serving primitives in [`crate::serve`]: a batch run is
//! one [`crate::serve::Session`], a sharded run
//! ([`ExperimentRunner::run_parallel`]) is a [`crate::serve::SocPool`]
//! serving one replay session per shard with a deterministic merge.

pub mod runner;

pub use runner::{ExperimentConfig, ExperimentOutcome, ExperimentRunner, GoldenCheck};
