"""Surrogate-gradient training of the float SNN + conversion to the
deployed integer network.

The pipeline (per dataset): train float → per-layer k-means codebook
quantization → integer threshold/leak scaling → :class:`model.IntLayer`
stack whose accuracy is measured with the chip's exact integer semantics.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import model, quantize
from .kernels import ref


@dataclasses.dataclass
class TrainResult:
    spec: model.NetSpec
    params: list            # float weights
    int_layers: list        # model.IntLayer
    scales: list            # per-layer quantization scales
    float_acc: float
    int_acc: float


def _adam_update(params, grads, mom, vel, step, lr=1e-3, b1=0.9, b2=0.999,
                 eps=1e-8):
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(params, grads, mom, vel):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** step)
        vhat = v / (1 - b2 ** step)
        new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
        new_m.append(m)
        new_v.append(v)
    return new_p, new_m, new_v


def train_float(spec: model.NetSpec, rasters, labels, *, epochs=25,
                batch=64, lr=2e-3, seed=0, log=print):
    """Train the float surrogate network; returns (params, train_acc)."""
    key = jax.random.PRNGKey(seed)
    params = model.init_params(spec, key)
    x = jnp.asarray(rasters, jnp.float32)
    y = jnp.asarray(labels, jnp.int32)

    def loss_fn(params, xb, yb):
        counts = model.batched_float_forward(params, xb, spec)
        logits = counts  # spike counts as class scores
        logp = jax.nn.log_softmax(logits)
        return -logp[jnp.arange(yb.shape[0]), yb].mean()

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    mom = [jnp.zeros_like(p) for p in params]
    vel = [jnp.zeros_like(p) for p in params]
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    step = 0
    for epoch in range(epochs):
        order = rng.permutation(n)
        losses = []
        for i in range(0, n, batch):
            idx = order[i:i + batch]
            step += 1
            loss, grads = grad_fn(params, x[idx], y[idx])
            params, mom, vel = _adam_update(params, grads, mom, vel, step,
                                            lr=lr)
            losses.append(float(loss))
        if epoch % 5 == 0 or epoch == epochs - 1:
            log(f"  epoch {epoch:3d}: loss {np.mean(losses):.4f}")
    # train accuracy (cheap proxy printed by the caller on the test split)
    counts = model.batched_float_forward(params, x, spec)
    acc = float((jnp.argmax(counts, axis=1) == y).mean())
    return params, acc


def to_int_layers(spec: model.NetSpec, params) -> tuple:
    """Quantize float weights into deployed integer layers.

    Per layer: k-means codebook over the float weights, then the float
    threshold/leak are rescaled into the integer domain with the same
    scale (``w_f ≈ level × s`` ⇒ ``th_i = round(th_f / s)``).
    """
    int_layers, scales = [], []
    for w in params:
        q = quantize.kmeans_quantize(np.asarray(w), spec.n_levels,
                                     spec.w_bits)
        th_i = max(1, int(round(spec.threshold / q.scale)))
        leak_i = max(0, int(round(spec.leak / q.scale)))
        mp_bits = 16
        hi = (1 << (mp_bits - 1)) - 1
        if th_i > hi // 2:
            # Saturation headroom: widen MP register to 24 bits (the chip
            # supports configurable widths; extreme scales need headroom).
            mp_bits = 24
        int_layers.append(model.IntLayer(
            widx=jnp.asarray(q.widx, jnp.int32),
            codebook=jnp.asarray(q.codebook, jnp.int32),
            params=ref.LayerParams(
                threshold=th_i,
                leak_mode=ref.LEAK_LINEAR if leak_i > 0 else ref.LEAK_NONE,
                leak_value=leak_i,
                reset_mode=ref.RESET_SUBTRACT,
                mp_bits=mp_bits,
            ),
        ))
        scales.append(q.scale)
    return int_layers, scales


def train_and_quantize(spec: model.NetSpec, train_rasters, train_labels,
                       test_rasters, test_labels, *, epochs=25, batch=64,
                       lr=2e-3, seed=0, log=print) -> TrainResult:
    """Full pipeline; integer accuracy is measured on the test split with
    the chip's exact semantics."""
    log(f"training '{spec.name}' float surrogate "
        f"({spec.inputs}→{'→'.join(map(str, spec.hidden))}→{spec.classes}, "
        f"T={spec.timesteps})")
    params, _ = train_float(spec, train_rasters, train_labels, epochs=epochs,
                            batch=batch, lr=lr, seed=seed, log=log)
    counts = model.batched_float_forward(
        params, jnp.asarray(test_rasters, jnp.float32), spec)
    float_acc = float((jnp.argmax(counts, axis=1)
                       == jnp.asarray(test_labels)).mean())
    int_layers, scales = to_int_layers(spec, params)
    int_acc = model.int_accuracy(int_layers, test_rasters, test_labels)
    log(f"  float test acc {float_acc:.3f} → integer (chip) acc {int_acc:.3f}")
    return TrainResult(spec=spec, params=params, int_layers=int_layers,
                       scales=scales, float_acc=float_acc, int_acc=int_acc)
