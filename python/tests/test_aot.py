"""AOT pipeline tests: HLO text emission (constants included!), weights
JSON schema, dataset export, and the run_one fast path end to end on a
tiny spec."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, train
from compile.kernels import ref


def tiny_result():
    spec = model.NetSpec(name="tiny", inputs=12, hidden=(8,), classes=3,
                         timesteps=4)
    rng = np.random.default_rng(0)
    rasters = rng.random((30, 4, 12)) < 0.3
    labels = rng.integers(0, 3, 30)
    return spec, train.train_and_quantize(
        spec, rasters, labels, rasters[:10], labels[:10], epochs=2,
        log=lambda *_: None)


def test_hlo_text_contains_full_constants(tmp_path):
    spec, result = tiny_result()
    aot.export_hlo(result, str(tmp_path), "tiny", log=lambda *_: None)
    text = (tmp_path / "tiny.hlo.txt").read_text()
    assert "HloModule" in text
    assert "{...}" not in text, "large constants were elided"
    meta = json.loads((tmp_path / "tiny.meta.json").read_text())
    assert meta == {"inputs": 12, "timesteps": 4, "classes": 3}


def test_weights_json_schema(tmp_path):
    spec, result = tiny_result()
    path = tmp_path / "tiny.weights.json"
    aot.export_weights_json(result, str(path))
    doc = json.loads(path.read_text())
    assert doc["classes"] == 3
    l0 = doc["layers"][0]
    assert l0["inputs"] == 12 and l0["neurons"] == 8
    assert len(l0["codebook"]) == spec.n_levels
    assert len(l0["widx_hex"]) == 2 * 12 * 8
    assert l0["reset"] in ("zero", "subtract")
    assert l0["leak"]["mode"] in ("none", "linear", "shift")
    # hex decodes to valid indexes
    raw = bytes.fromhex(l0["widx_hex"])
    assert all(b < spec.n_levels or b == 255 for b in raw)


def test_hlo_executes_and_matches_int_forward(tmp_path):
    """The lowered computation (via jax, pre-export) equals int_forward."""
    spec, result = tiny_result()

    def run_fn(raster):
        return (model.int_forward(result.int_layers, raster,
                                  use_pallas=True),)

    raster = jnp.asarray(
        np.random.default_rng(1).random((4, 12)) < 0.4, jnp.int32)
    direct = model.int_forward(result.int_layers, raster, use_pallas=False)
    lowered = jax.jit(run_fn).lower(
        jax.ShapeDtypeStruct((4, 12), jnp.int32))
    compiled = lowered.compile()
    out = compiled(raster)[0]
    np.testing.assert_array_equal(np.asarray(out), np.asarray(direct))


def test_dataset_export_caps_samples(tmp_path):
    from compile import data
    ds = data.make_nmnist(6, seed=3)
    p = tmp_path / "d.json"
    ds.export_json(str(p), limit=4)
    doc = json.loads(p.read_text())
    assert len(doc["samples"]) == 4


def test_specs_match_workload_geometry():
    assert aot.SPECS["nmnist"].inputs == 2312
    assert aot.SPECS["dvsgesture"].inputs == 2048
    assert aot.SPECS["cifar10"].inputs == 3072
    assert aot.SPECS["dvsgesture"].classes == 11
