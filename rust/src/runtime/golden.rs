//! The golden functional model: an AOT-compiled JAX network step executed
//! through PJRT, used to validate the cycle simulator bit-for-bit.

use super::client::XlaExec;
use crate::datasets::Sample;
use crate::{Error, Result};
use std::path::{Path, PathBuf};

/// A loaded golden model for one network.
pub struct GoldenModel {
    exe: XlaExec,
    /// Input width the model expects.
    pub inputs: usize,
    /// Timesteps the model expects.
    pub timesteps: usize,
    /// Classes it returns counts for.
    pub classes: usize,
}

impl GoldenModel {
    /// Load `artifacts/<name>.hlo.txt` plus its shape sidecar
    /// `artifacts/<name>.meta.json`.
    pub fn load(artifacts_dir: &Path, name: &str) -> Result<GoldenModel> {
        let hlo = artifacts_dir.join(format!("{name}.hlo.txt"));
        let meta_path = artifacts_dir.join(format!("{name}.meta.json"));
        let meta = crate::util::json::Json::read_file(&meta_path)?;
        Ok(GoldenModel {
            exe: XlaExec::load_hlo_text(&hlo)?,
            inputs: meta.get("inputs")?.as_usize()?,
            timesteps: meta.get("timesteps")?.as_usize()?,
            classes: meta.get("classes")?.as_usize()?,
        })
    }

    /// Default artifacts directory (`$FSOC_ARTIFACTS` or `./artifacts`).
    pub fn artifacts_dir() -> PathBuf {
        std::env::var("FSOC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Run one sample: returns per-class output spike counts.
    pub fn run_sample(&self, sample: &Sample) -> Result<Vec<u32>> {
        let raster = sample.to_raster(self.timesteps, self.inputs);
        self.run_raster(&raster)
    }

    /// Run a dense raster (`timesteps × inputs`).
    pub fn run_raster(&self, raster: &[Vec<bool>]) -> Result<Vec<u32>> {
        if raster.len() != self.timesteps {
            return Err(Error::Runtime(format!(
                "raster has {} timesteps, model expects {}",
                raster.len(),
                self.timesteps
            )));
        }
        let mut flat = Vec::with_capacity(self.timesteps * self.inputs);
        for row in raster {
            if row.len() != self.inputs {
                return Err(Error::Runtime(format!(
                    "raster row has {} inputs, model expects {}",
                    row.len(),
                    self.inputs
                )));
            }
            flat.extend(row.iter().map(|&b| b as i32));
        }
        let out = self
            .exe
            .run_i32(&[(&flat, &[self.timesteps, self.inputs])])?;
        if out.len() != self.classes {
            return Err(Error::Runtime(format!(
                "model returned {} outputs, expected {}",
                out.len(),
                self.classes
            )));
        }
        Ok(out.into_iter().map(|v| v.max(0) as u32).collect())
    }

    /// Classify: argmax (ties → lowest class), matching the chip rule.
    pub fn classify(&self, sample: &Sample) -> Result<usize> {
        let counts = self.run_sample(sample)?;
        Ok(counts
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
            .map(|(i, _)| i)
            .unwrap_or(0))
    }
}
