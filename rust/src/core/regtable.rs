//! Core register table: configuration + clock-gate enable (paper §II.A:
//! "A clock gating enables the core clock according to an enable signal in
//! the register table. In addition, the register table stores other
//! parameters, such as neuron configuration parameters and read-only core
//! ID.")

use super::codebook::Codebook;
use super::neuron::NeuronParams;
use crate::{Error, Result};


/// Weight configuration of a core: the codebook geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WeightConfig {
    /// Number of codebook entries (N ∈ {4, 8, 16}).
    pub n: usize,
    /// Weight bit width (W ∈ {4, 8, 16}).
    pub w_bits: usize,
}

/// The per-core register table.
#[derive(Debug, Clone)]
pub struct RegTable {
    /// Read-only core identifier (5 bits on chip: up to 32 nodes/domain).
    core_id: u8,
    /// Clock-gate enable: when false the core burns only gated leakage.
    pub enabled: bool,
    /// Number of input axons this core listens to.
    pub axons: usize,
    /// Number of neurons implemented in this core.
    pub neurons: usize,
    /// Neuron dynamics configuration.
    pub neuron_params: NeuronParams,
    /// Weight/codebook geometry.
    pub weight_config: WeightConfig,
}

impl RegTable {
    /// Build and validate a register table.
    pub fn new(
        core_id: u8,
        axons: usize,
        neurons: usize,
        neuron_params: NeuronParams,
        codebook: &Codebook,
    ) -> Result<Self> {
        if core_id >= 32 {
            return Err(Error::Core(format!(
                "core_id {core_id} exceeds the 5-bit id space"
            )));
        }
        if neurons == 0 || neurons > super::MAX_NEURONS_PER_CORE {
            return Err(Error::Core(format!(
                "neurons {} out of range 1..={}",
                neurons,
                super::MAX_NEURONS_PER_CORE
            )));
        }
        if axons == 0 {
            return Err(Error::Core("axons must be > 0".into()));
        }
        Ok(RegTable {
            core_id,
            enabled: true,
            axons,
            neurons,
            neuron_params,
            weight_config: WeightConfig {
                n: codebook.n(),
                w_bits: codebook.w_bits(),
            },
        })
    }

    /// Read-only core id.
    pub fn core_id(&self) -> u8 {
        self.core_id
    }

    /// Number of 16-bit spike words per timestep.
    pub fn spike_words(&self) -> usize {
        self.axons.div_ceil(super::SPIKE_WORD_BITS)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::neuron::NeuronParams;

    #[test]
    fn validates_id_and_sizes() {
        let cb = Codebook::default_log16();
        let np = NeuronParams::default();
        assert!(RegTable::new(31, 16, 10, np.clone(), &cb).is_ok());
        assert!(RegTable::new(32, 16, 10, np.clone(), &cb).is_err());
        assert!(RegTable::new(0, 16, 0, np.clone(), &cb).is_err());
        assert!(RegTable::new(0, 16, 9000, np.clone(), &cb).is_err());
        assert!(RegTable::new(0, 0, 10, np, &cb).is_err());
    }

    #[test]
    fn spike_words_rounds_up() {
        let cb = Codebook::default_log16();
        let rt = RegTable::new(1, 17, 8, NeuronParams::default(), &cb).unwrap();
        assert_eq!(rt.spike_words(), 2);
    }
}
