//! PJRT/XLA runtime: loads the AOT-compiled JAX golden model
//! (`artifacts/*.hlo.txt`, HLO **text** — see `python/compile/aot.py` and
//! `/opt/xla-example/README.md` for why text, not serialized protos) and
//! executes it from Rust.
//!
//! The golden model is the *functional* definition of the chip's
//! arithmetic: the same quantized integer network the mapper loads into
//! the cycle simulator, lowered through JAX (whose hot spot is the Pallas
//! sparse-codebook kernel). Integration tests assert the cycle simulator
//! and the XLA execution produce identical output spike counts.

pub mod client;
pub mod golden;

pub use client::XlaExec;
pub use golden::GoldenModel;
