//! The off-chip level-3 router ring joining the chips of a cluster.
//!
//! The paper's NoC "can be scaled up through extended off-chip
//! high-level router nodes": each chip exposes one L3 router, and the
//! L3 routers form a bidirectional ring over board-level serial links.
//! The cost model follows the Moradi & Manohar on- vs off-chip gap
//! (arxiv 1809.06016): an L3 hop/link is an order of magnitude more
//! expensive than its on-chip L2 counterpart in both latency
//! ([`L3_HOP_CYCLES`]/[`L3_LINK_CYCLES`]) and energy
//! ([`crate::energy::model::EventClass::HopL3`]/`LinkL3`), so the
//! partitioner's min-cut objective has real teeth.
//!
//! The fabric is **synchronous at timestep granularity**: a transfer
//! either completes within the timestep (its latency is charged to the
//! cluster's cycle count) or its flits drop on a severed ring — nothing
//! stays in flight across a boundary, which keeps cluster-wide flit
//! conservation a per-timestep equality: `injected == delivered +
//! dropped` at every boundary.
//!
//! A cross-chip spike climbs core→L1→L2 on its source chip, crosses the
//! ring, and descends L2→L1→core on the destination chip. Shard chips
//! never route their terminal-layer spikes on their own NoC (those
//! spikes leave the chip), so the climb and descent are charged here,
//! per flit, in the L3 fabric's own ledger — once each of
//! `HopBroadcast`/`LinkTraversal`/`HopL2`/`LinkL2` per side — plus one
//! `HopL3` per ring router visited and one `LinkL3` per ring link
//! traversed. No double counting against the shard NoCs, no missing
//! ascent energy.

use crate::energy::model::{EnergyLedger, EventClass};
use crate::noc::{FabricHealth, FaultKind, FaultPlan, When};
use crate::{Error, Result};

/// Cycles one L3 router spends switching a flit batch (vs 1 for an
/// on-chip hop): SerDes framing plus the wider off-chip arbitration.
pub const L3_HOP_CYCLES: u64 = 8;

/// Cycles one chip↔chip ring link traversal costs at the core clock —
/// the board-trace + SerDes round, an order of magnitude over any
/// on-chip wire (Moradi & Manohar's off-chip latency gap).
pub const L3_LINK_CYCLES: u64 = 24;

/// Counters of the off-chip ring for one accounting window.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct L3Stats {
    /// Ring size (one L3 router per physical chip).
    pub chips: usize,
    /// Flits handed to the ring.
    pub injected: u64,
    /// Flits that reached their destination chip.
    pub delivered: u64,
    /// Flits discarded on a severed ring (dead router / no alive path).
    pub dropped: u64,
    /// Ring links actually traversed by delivered flits.
    pub link_traversals: u64,
    /// Extra flit-hops taken beyond the pristine shortest ring path
    /// (the redundancy the detour consumed).
    pub rerouted_hops: u64,
    /// Busy cycles the ring accumulated (transfer latencies summed).
    pub cycles: u64,
}

/// One scheduled L3 action, resolved from the plan's L3 half.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum L3Action {
    Kill(usize),
    Throttle(u64),
}

/// The simulated off-chip router ring. Built by
/// [`crate::cluster::Cluster`] from the L3 half of the config's
/// [`FaultPlan`] (see [`FaultPlan::split_l3`]); a single-chip config has
/// no ring at all.
#[derive(Debug, Clone)]
pub struct L3Fabric {
    chips: usize,
    /// The L3-only plan, retained so `reset_accounting` re-arms it
    /// (healing the ring — warm clusters stay identical to fresh).
    plan: FaultPlan,
    ledger: EnergyLedger,
    /// Cycle-keyed actions sorted by activation cycle; `cursor` marks
    /// the first unapplied entry. Cycle keys are compared against the
    /// ring's own accumulated busy cycles at transfer boundaries.
    by_cycle: Vec<(u64, L3Action)>,
    cursor: usize,
    /// Timestep-keyed actions; each fires once.
    by_timestep: Vec<(u32, L3Action, bool)>,
    node_dead: Vec<bool>,
    /// Ring-link throttle period (1 = unthrottled): each link traversal
    /// costs `throttle × L3_LINK_CYCLES`.
    throttle: u64,
    stats: L3Stats,
}

impl L3Fabric {
    /// A ring of `chips` L3 routers armed with the (possibly empty)
    /// L3-only fault plan. Rejects plans that reference routers outside
    /// the ring or any L3 event on a ring of fewer than two chips.
    pub fn new(chips: usize, plan: &FaultPlan) -> Result<L3Fabric> {
        if chips < 2 {
            return Err(Error::Config(
                "an off-chip L3 ring needs at least two chips".into(),
            ));
        }
        plan.validate_l3(chips)?;
        let mut f = L3Fabric {
            chips,
            plan: plan.clone(),
            ledger: EnergyLedger::new(),
            by_cycle: Vec::new(),
            cursor: 0,
            by_timestep: Vec::new(),
            node_dead: vec![false; chips],
            throttle: 1,
            stats: L3Stats {
                chips,
                ..L3Stats::default()
            },
        };
        f.arm();
        Ok(f)
    }

    /// Resolve the retained plan into the live schedule (fresh health).
    fn arm(&mut self) {
        self.by_cycle.clear();
        self.by_timestep.clear();
        self.cursor = 0;
        self.node_dead = vec![false; self.chips];
        self.throttle = 1;
        for ev in &self.plan.events {
            let action = match ev.kind {
                FaultKind::RouterKillL3 { chip } => L3Action::Kill(chip),
                FaultKind::LinkThrottleL3 { factor } => L3Action::Throttle(factor),
                // On-chip kinds never reach the ring: the cluster arms
                // only the plan's L3 half here.
                _ => continue,
            };
            match ev.when {
                When::Cycle(c) => self.by_cycle.push((c, action)),
                When::Timestep(t) => self.by_timestep.push((t, action, false)),
            }
        }
        self.by_cycle.sort_by_key(|&(c, _)| c);
    }

    fn apply(&mut self, a: L3Action) {
        match a {
            L3Action::Kill(chip) => self.node_dead[chip] = true,
            L3Action::Throttle(f) => self.throttle = f,
        }
    }

    /// Fire timestep-keyed events; the cluster calls this at the start
    /// of every simulated timestep.
    pub fn set_timestep(&mut self, t: u32) {
        for i in 0..self.by_timestep.len() {
            let (at, action, fired) = self.by_timestep[i];
            if !fired && at <= t {
                self.by_timestep[i].2 = true;
                self.apply(action);
            }
        }
    }

    /// Fire cycle-keyed events due at/before the ring's busy-cycle count.
    fn fire_due_cycle(&mut self) {
        while self.cursor < self.by_cycle.len() && self.by_cycle[self.cursor].0 <= self.stats.cycles
        {
            let (_, action) = self.by_cycle[self.cursor];
            self.cursor += 1;
            self.apply(action);
        }
    }

    /// Ring nodes on the directed path `src → dst` (inclusive), walking
    /// `step = +1` (clockwise) or `-1` (counter-clockwise).
    fn path(&self, src: usize, dst: usize, clockwise: bool) -> Vec<usize> {
        let mut nodes = vec![src];
        let mut cur = src;
        while cur != dst {
            cur = if clockwise {
                (cur + 1) % self.chips
            } else {
                (cur + self.chips - 1) % self.chips
            };
            nodes.push(cur);
        }
        nodes
    }

    fn alive(&self, nodes: &[usize]) -> bool {
        nodes.iter().all(|&n| !self.node_dead[n])
    }

    /// Move `flits` spike flits from chip `src` to chip `dst` within the
    /// current timestep. Returns `true` when they were delivered (the
    /// path is all-or-nothing within a timestep: the ring either has an
    /// alive route or the batch drops into the `FlitDropped` ledger
    /// class). Charges the full cross-chip energy path per flit and
    /// accumulates the transfer latency into [`L3Stats::cycles`].
    pub fn transfer(&mut self, src: usize, dst: usize, flits: u64) -> Result<bool> {
        if src >= self.chips || dst >= self.chips {
            return Err(Error::Soc(format!(
                "L3 transfer {src}→{dst} outside the {}-chip ring",
                self.chips
            )));
        }
        self.fire_due_cycle();
        if flits == 0 || src == dst {
            return Ok(true);
        }
        self.stats.injected += flits;
        // Shortest alive direction; a detour over the longer arc counts
        // its extra hops as rerouted (redundancy actually consumed).
        let cw = self.path(src, dst, true);
        let ccw = self.path(src, dst, false);
        let (short, long) = if cw.len() <= ccw.len() {
            (cw, ccw)
        } else {
            (ccw, cw)
        };
        let pristine_links = (short.len() - 1) as u64;
        let route = if self.alive(&short) {
            Some(short)
        } else if self.alive(&long) {
            Some(long)
        } else {
            None
        };
        let Some(route) = route else {
            self.stats.dropped += flits;
            self.ledger.add(EventClass::FlitDropped, flits);
            // Severed-route detection still occupies the source router.
            self.stats.cycles += L3_HOP_CYCLES;
            return Ok(false);
        };
        let hops = route.len() as u64; // L3 routers visited
        let links = (route.len() - 1) as u64; // ring links traversed
        self.stats.delivered += flits;
        self.stats.link_traversals += links * flits;
        self.stats.rerouted_hops += (links - pristine_links) * flits;
        // Per-flit energy: climb on the source chip, the ring crossing,
        // and the symmetric descent on the destination chip.
        for side in [EventClass::HopBroadcast, EventClass::LinkTraversal] {
            self.ledger.add(side, 2 * flits);
        }
        for side in [EventClass::HopL2, EventClass::LinkL2] {
            self.ledger.add(side, 2 * flits);
        }
        self.ledger.add(EventClass::HopL3, hops * flits);
        self.ledger.add(EventClass::LinkL3, links * flits);
        // Latency: router switching + (possibly throttled) link rounds,
        // plus one issue cycle per extra flit of the pipelined batch.
        self.stats.cycles +=
            hops * L3_HOP_CYCLES + links * L3_LINK_CYCLES * self.throttle + (flits - 1);
        Ok(true)
    }

    /// Window counters (injected/delivered/dropped always balance at
    /// timestep boundaries — nothing stays in flight).
    pub fn stats(&self) -> L3Stats {
        self.stats
    }

    /// Whether ring node `chip` is currently dead (out-of-ring indices
    /// read as alive). Failover consults this at sample boundaries to
    /// decide whether any shard has become unreachable.
    pub fn node_dead(&self, chip: usize) -> bool {
        self.node_dead.get(chip).copied().unwrap_or(false)
    }

    /// Degradation view in the same shape as an on-chip fabric's:
    /// `dead_routers` are dead ring nodes; the ring model severs no
    /// individual links, so `dead_links` stays 0.
    pub fn fabric_health(&self) -> FabricHealth {
        FabricHealth {
            armed: !self.plan.is_empty(),
            dropped: self.stats.dropped,
            rerouted_hops: self.stats.rerouted_hops,
            dead_routers: self.node_dead.iter().filter(|&&d| d).count() as u64,
            dead_links: 0,
        }
    }

    /// The ring's energy ledger for the window: dynamic events plus one
    /// static entry per L3 router (`router-l3-<i>`), active for the
    /// ring's busy cycles and gated the rest of the cluster wall `wall`,
    /// at the operating point `p` (the cluster's voltage-scaled params).
    pub fn snapshot_ledger(&self, wall: u64, p: &crate::energy::EnergyParams) -> EnergyLedger {
        let mut ledger = self.ledger.clone();
        let active = self.stats.cycles.min(wall);
        for i in 0..self.chips {
            ledger.add_static(
                &format!("router-l3-{i}"),
                active,
                wall - active,
                p.p_router_l3_active,
                p.p_router_l3_gated,
            );
        }
        ledger
    }

    /// Zero the window (ledger + counters) and re-arm the retained plan,
    /// healing the ring — the L3 half of the warm == fresh contract.
    pub fn reset_accounting(&mut self) {
        self.ledger = EnergyLedger::new();
        self.stats = L3Stats {
            chips: self.chips,
            ..L3Stats::default()
        };
        self.arm();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_ring_conserves_and_charges_the_l3_path() {
        let mut l3 = L3Fabric::new(4, &FaultPlan::none()).unwrap();
        assert!(l3.transfer(0, 1, 10).unwrap());
        let s = l3.stats();
        assert_eq!((s.injected, s.delivered, s.dropped), (10, 10, 0));
        assert_eq!(s.link_traversals, 10, "one ring link for neighbors");
        assert_eq!(s.rerouted_hops, 0);
        // 2 routers × 10 flits hops, 1 link × 10 flits.
        assert_eq!(l3.ledger.count(EventClass::HopL3), 20);
        assert_eq!(l3.ledger.count(EventClass::LinkL3), 10);
        // Climb + descend: 2 per flit on each on-chip class.
        for c in [
            EventClass::HopBroadcast,
            EventClass::LinkTraversal,
            EventClass::HopL2,
            EventClass::LinkL2,
        ] {
            assert_eq!(l3.ledger.count(c), 20, "{c:?}");
        }
        assert_eq!(
            s.cycles,
            2 * L3_HOP_CYCLES + L3_LINK_CYCLES + 9,
            "2 hops + 1 link + 9 pipelined issue cycles"
        );
        // Zero-flit and same-chip transfers are free no-ops.
        assert!(l3.transfer(2, 2, 5).unwrap());
        assert!(l3.transfer(1, 2, 0).unwrap());
        assert_eq!(l3.stats().injected, 10);
        assert!(l3.transfer(0, 9, 1).is_err(), "outside the ring");
    }

    #[test]
    fn shortest_direction_wins_and_detours_count_reroutes() {
        let mut l3 = L3Fabric::new(4, &FaultPlan::none()).unwrap();
        // 0 → 3 is one counter-clockwise link on a 4-ring.
        assert!(l3.transfer(0, 3, 1).unwrap());
        assert_eq!(l3.stats().link_traversals, 1);
        // Kill router 3's shortest-path neighbor? 0→2 goes via 1 or 3
        // (both length 2). Kill 1: the tie-break (clockwise) route dies,
        // the detour via 3 is the same length — no extra hops.
        let plan = FaultPlan::none().kill_l3(1, When::Timestep(0));
        let mut l3 = L3Fabric::new(4, &plan).unwrap();
        l3.set_timestep(0);
        assert!(l3.transfer(0, 2, 1).unwrap());
        assert_eq!(l3.stats().rerouted_hops, 0, "equal-length detour");
        // Neighbor transfer forced the long way: 0→1 with nothing dead
        // takes 1 link; with the *ring interior* alive it cannot detour
        // around a dead destination — kill 1 and 0→1 must drop.
        assert!(!l3.transfer(0, 1, 3).unwrap(), "dead destination drops");
        let s = l3.stats();
        assert_eq!(s.dropped, 3);
        assert_eq!(l3.ledger.count(EventClass::FlitDropped), 3);
        // Detour that IS longer: 5-ring, 0→1 dead-neighbor… use 0→1 via
        // the long arc by killing nothing on it. Kill node on short path
        // between 0 and 2 of a 5-ring (path 0-1-2); long arc 0-4-3-2.
        let plan = FaultPlan::none().kill_l3(1, When::Timestep(0));
        let mut l3 = L3Fabric::new(5, &plan).unwrap();
        l3.set_timestep(0);
        assert!(l3.transfer(0, 2, 2).unwrap());
        let s = l3.stats();
        assert_eq!(s.link_traversals, 3 * 2, "long arc has 3 links");
        assert_eq!(s.rerouted_hops, (3 - 2) * 2, "one extra link per flit");
        assert_eq!(l3.fabric_health().dead_routers, 1);
        assert!(l3.fabric_health().armed);
    }

    #[test]
    fn throttle_scales_link_latency_and_cycle_events_fire() {
        // Throttle at ring-cycle 0 (immediately), kill later by cycle.
        let plan = FaultPlan::none()
            .throttle_l3(4, When::Cycle(0))
            .kill_l3(2, When::Cycle(1_000));
        let mut l3 = L3Fabric::new(4, &plan).unwrap();
        assert!(l3.transfer(0, 1, 1).unwrap());
        assert_eq!(
            l3.stats().cycles,
            2 * L3_HOP_CYCLES + 4 * L3_LINK_CYCLES,
            "throttle multiplies the link rounds"
        );
        // Push the busy-cycle counter past the kill activation.
        for _ in 0..25 {
            let _ = l3.transfer(0, 1, 1).unwrap();
        }
        assert!(l3.stats().cycles > 1_000);
        assert!(!l3.transfer(1, 2, 1).unwrap(), "cycle-keyed kill fired");
        // reset_accounting heals the ring and re-arms the plan.
        l3.reset_accounting();
        assert_eq!(l3.stats(), L3Stats { chips: 4, ..L3Stats::default() });
        assert_eq!(l3.fabric_health().dead_routers, 0, "healed");
        assert!(l3.fabric_health().armed, "plan re-armed");
        assert!(l3.transfer(1, 2, 1).unwrap(), "kill not yet re-fired");
        assert_eq!(
            l3.stats().cycles,
            2 * L3_HOP_CYCLES + 4 * L3_LINK_CYCLES,
            "throttle re-armed at cycle 0"
        );
    }

    #[test]
    fn construction_rejects_bad_rings_and_plans() {
        assert!(L3Fabric::new(1, &FaultPlan::none()).is_err(), "no 1-ring");
        let oob = FaultPlan::none().kill_l3(4, When::Cycle(1));
        assert!(L3Fabric::new(4, &oob).is_err(), "chip 4 of a 4-ring");
        // Static snapshot charges one entry per ring router.
        let l3 = L3Fabric::new(3, &FaultPlan::none()).unwrap();
        let p = crate::energy::EnergyParams::nominal();
        let led = l3.snapshot_ledger(100, &p);
        assert!(led.static_pj(1e8) > 0.0, "gated routers still leak");
        let expect = 3.0 * p.p_router_l3_gated * 100.0 / 1e8 * 1e9;
        assert!((led.static_pj(1e8) - expect).abs() < 1e-9);
    }
}
