//! DVS-Gesture-like synthetic event streams: 32×32×2, 11 classes of
//! motion (the real dataset's arm gestures become parameterized cluster
//! trajectories: rotation direction/speed, translation axis, oscillation).

use super::encode::{rate_encode, Intensity};
use super::events::{Dataset, Sample};
use crate::util::prng::Rng;

/// Image side (downsampled 128→32, as SNN deployments of DVS Gesture do).
pub const SIDE: usize = 32;
/// Polarity channels.
pub const CHANNELS: usize = 2;
/// Timesteps per sample.
pub const TIMESTEPS: usize = 25;
/// Classes (matching DVS Gesture's 11).
pub const CLASSES: usize = 11;

/// Class-specific motion: returns the cluster center at time `t ∈ [0,1)`.
fn trajectory(class: usize, t: f64) -> (f64, f64) {
    let c = SIDE as f64 / 2.0;
    let r = 8.0;
    match class {
        // circular motions, two speeds × two directions
        0 => (c + r * (t * std::f64::consts::TAU).cos(), c + r * (t * std::f64::consts::TAU).sin()),
        1 => (c + r * (t * std::f64::consts::TAU).cos(), c - r * (t * std::f64::consts::TAU).sin()),
        2 => (c + r * (2.0 * t * std::f64::consts::TAU).cos(), c + r * (2.0 * t * std::f64::consts::TAU).sin()),
        3 => (c + r * (2.0 * t * std::f64::consts::TAU).cos(), c - r * (2.0 * t * std::f64::consts::TAU).sin()),
        // linear oscillations along 4 axes
        4 => (c + r * (2.0 * t - 1.0), c),
        5 => (c, c + r * (2.0 * t - 1.0)),
        6 => (c + r * (2.0 * t - 1.0), c + r * (2.0 * t - 1.0)),
        7 => (c + r * (2.0 * t - 1.0), c - r * (2.0 * t - 1.0)),
        // figure-eight / double-oscillation
        8 => (c + r * (t * std::f64::consts::TAU).sin(), c + r * (2.0 * t * std::f64::consts::TAU).sin() / 2.0),
        9 => (c + r * (2.0 * t * std::f64::consts::TAU).sin() / 2.0, c + r * (t * std::f64::consts::TAU).sin()),
        // stationary flicker
        _ => (c, c),
    }
}

fn sample(class: usize, rng: &mut Rng) -> Sample {
    let mut frames = Vec::with_capacity(TIMESTEPS);
    let mut prev_pos = trajectory(class, 0.0);
    for t in 0..TIMESTEPS {
        let ft = t as f64 / TIMESTEPS as f64;
        let (cx, cy) = trajectory(class, ft);
        let (cx, cy) = (cx + rng.normal() * 0.4, cy + rng.normal() * 0.4);
        let mut f = Intensity::zeros(SIDE, SIDE, CHANNELS);
        // ON events lead the motion, OFF events trail it (DVS physics).
        let (dx, dy) = (cx - prev_pos.0, cy - prev_pos.1);
        let speed = (dx * dx + dy * dy).sqrt().max(0.2);
        f.add_blob(0, cx + dx * 0.7, cy + dy * 0.7, 2.0, (0.5 * speed).min(0.9));
        f.add_blob(1, cx - dx * 0.7, cy - dy * 0.7, 2.0, (0.4 * speed).min(0.8));
        // class 10: flicker — both polarities pulse in place.
        if class == 10 {
            let amp = if t % 2 == 0 { 0.8 } else { 0.1 };
            f.add_blob(0, cx, cy, 2.5, amp);
            f.add_blob(1, cx, cy, 2.5, 0.9 - amp);
        }
        prev_pos = (cx, cy);
        frames.push(f);
    }
    rate_encode(&frames, 0.35, class, rng)
}

/// Generate `n` samples (labels round-robin).
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xD5_0001);
    let samples: Vec<Sample> = (0..n).map(|i| sample(i % CLASSES, &mut rng)).collect();
    Dataset {
        name: "dvsgesture-syn".into(),
        inputs: SIDE * SIDE * CHANNELS,
        timesteps: TIMESTEPS,
        classes: CLASSES,
        samples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_and_sparse() {
        let d = generate(22, 4);
        d.validate().unwrap();
        assert_eq!(d.inputs, 2048);
        let s = d.sparsity();
        assert!(s > 0.85, "sparsity {s}");
    }

    #[test]
    fn motion_classes_touch_different_pixels_over_time() {
        let d = generate(22, 5);
        // Horizontal (4) vs vertical (5) oscillation must differ in the
        // set of active columns/rows.
        let active_cols = |label: usize| -> Vec<bool> {
            let mut cols = vec![false; SIDE];
            for s in d.samples.iter().filter(|s| s.label == label) {
                for &(_, a) in &s.events {
                    let pixel = a as usize % (SIDE * SIDE);
                    cols[pixel % SIDE] = true;
                }
            }
            cols
        };
        let h = active_cols(4).iter().filter(|&&b| b).count();
        let v = active_cols(5).iter().filter(|&&b| b).count();
        assert!(h > v, "horizontal motion must span more columns ({h} vs {v})");
    }

    #[test]
    fn deterministic() {
        assert_eq!(generate(6, 1).samples, generate(6, 1).samples);
    }
}
