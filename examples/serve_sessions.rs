//! Streaming serving demo: many independent edge sessions — different
//! users, different traffic — served concurrently by a `SocPool`, one
//! simulated chip per session, with deterministic merged reporting.
//!
//! The pool result is **bit-identical** to serving the same sessions
//! sequentially (asserted below down to `f64::to_bits`), so heavy
//! multi-threaded serving never changes the physics.
//!
//! ```bash
//! cargo run --release --example serve_sessions
//! ```

use fullerene_soc::benches_support::{saturation_workload, structural_net};
use fullerene_soc::datasets::Workload;
use fullerene_soc::energy::ChipReport;
use fullerene_soc::metrics::Table;
use fullerene_soc::nn::network::NetworkDesc;
use fullerene_soc::serve::{SessionSpec, SocBuilder, SyntheticStream, TrafficWorkload};

/// Structural network at the NMNIST geometry (untrained — this demo is
/// about the serving machinery, not accuracy).
fn net() -> NetworkDesc {
    let w = Workload::Nmnist;
    structural_net("serve-demo", w.inputs(), 48, w.classes(), w.timesteps())
}

/// The session mix: two synthetic NMNIST streams (different seeds), two
/// seeded traffic generators at the same geometry, and one session at
/// the shared saturation recipe — the same scenario the NoC benches and
/// the CI perf-smoke job measure.
fn specs() -> Vec<SessionSpec> {
    let w = Workload::Nmnist;
    vec![
        SessionSpec::new(
            "user4-saturation",
            Box::new(saturation_workload(
                w.inputs(),
                w.classes(),
                w.timesteps(),
                2,
                23,
            )),
        ),
        SessionSpec::new(
            "user0-nmnist",
            Box::new(SyntheticStream::new(w, 4, 7)),
        ),
        SessionSpec::new(
            "user1-nmnist",
            Box::new(SyntheticStream::new(w, 4, 8)),
        ),
        SessionSpec::new(
            "user2-traffic",
            Box::new(TrafficWorkload::new(
                w.inputs(),
                w.classes(),
                w.timesteps(),
                0.01,
                4,
                21,
            )),
        ),
        SessionSpec::new(
            "user3-traffic",
            Box::new(TrafficWorkload::new(
                w.inputs(),
                w.classes(),
                w.timesteps(),
                0.02,
                4,
                22,
            )),
        ),
    ]
}

fn main() -> fullerene_soc::Result<()> {
    let net = net();
    let pool = SocBuilder::new().workers(4).build_pool(&net)?;

    println!(
        "serving {} sessions across {} workers …",
        specs().len(),
        pool.workers()
    );
    let par = pool.serve(specs())?;
    let seq = pool.serve_sequential(specs())?;

    let mut t = Table::new(&["session", "samples", "p50 ms", "p99 ms", "SOPs", "pJ/SOP"]);
    for s in &par.sessions {
        t.push_row(vec![
            s.name.clone(),
            s.stats.samples.to_string(),
            format!("{:.3}", s.stats.p50_latency_ms),
            format!("{:.3}", s.stats.p99_latency_ms),
            s.stats.sops.to_string(),
            format!("{:.3}", s.report.pj_per_sop),
        ]);
    }
    println!("{}", t.render());

    // Determinism: concurrent serving is bit-identical to sequential.
    assert_eq!(
        par.merged.pj_per_sop.to_bits(),
        seq.merged.pj_per_sop.to_bits()
    );
    assert_eq!(par.merged.power_mw.to_bits(), seq.merged.power_mw.to_bits());
    assert_eq!(par.merged.cycles, seq.merged.cycles);
    println!("parallel == sequential (bit-identical merged report) ✓\n");

    println!(
        "merged report:\n{}",
        ChipReport::table(std::slice::from_ref(&par.merged)).render()
    );
    Ok(())
}
