//! The fullerene-like network-on-chip (paper §II.B).
//!
//! Twenty neuromorphic cores and twelve level-1 CMRouters form one
//! fullerene-like routing domain: the routers sit at the 12 vertices of an
//! icosahedron, the cores at its 20 (triangular) faces; each router links
//! to the 5 cores on its incident faces (`Nc = 5`, matching the paper's
//! 5×5×5-bit connection-matrix budget) and each core links to the 3
//! routers at its face's corners. The resulting 32-node graph has average
//! degree 3.75 and degree variance 0.94 — the numbers the paper reports —
//! which pins this construction (see `DESIGN.md` §Fullerene-topology).
//!
//! Modules:
//! - [`topology`] — graph builders: fullerene + baseline 2D-mesh, torus,
//!   ring, tree; [`metrics`] computes degree/latency statistics (Fig. 5a/5b).
//! - [`router`] — the multi-mode connection-matrix router (CMRouter):
//!   input/output buffers, register table, link controller (hang-up),
//!   channel arbiter, reconfigurable connection matrix, clock gating.
//! - [`packet`] — spike flits and the hybrid transmission modes
//!   (P2P / broadcast / merge).
//! - [`sim`] — the cycle-driven NoC simulator (Fig. 5c: throughput,
//!   pJ/hop).
//! - [`traffic`] — synthetic traffic generators for the router benches.
//! - [`multilevel`] — level-2 scale-up: multiple domains joined through
//!   central level-2 routers into one cycle-simulatable fabric, with the
//!   closed-form hop model retained as a cross-check oracle.

pub mod metrics;
pub mod multilevel;
pub mod packet;
pub mod router;
pub mod sim;
pub mod topology;
pub mod traffic;

pub use metrics::TopoStats;
pub use multilevel::{AnalyticModel, MultiDomain, MultiDomainMeasurement};
pub use packet::{Dest, Flit, TxMode};
pub use router::CmRouter;
pub use sim::{NocSim, SimStats};
pub use topology::{NodeId, NodeKind, Topology};
