//! Crate-wide error type (hand-rolled `Display`/`Error` impls — the
//! offline build has no `thiserror`).

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors produced by the simulator, configuration, and runtime layers.
#[derive(Debug)]
pub enum Error {
    /// Configuration failed validation (bad field, inconsistent sizes, …).
    Config(String),

    /// A network description is malformed or cannot be mapped to the chip.
    Network(String),

    /// The neuron→core mapper could not place the network.
    Mapping(String),

    /// NoC simulation error (unroutable packet, buffer misuse, …).
    Noc(String),

    /// Neuromorphic-core simulation error.
    Core(String),

    /// RISC-V ISS error (illegal instruction, bus fault, …).
    Riscv(String),

    /// SoC-level error (bus, DMA, clock manager).
    Soc(String),

    /// PJRT/XLA runtime error.
    Runtime(String),

    /// Artifact (HLO text / weights JSON) missing or malformed.
    Artifact(String),

    /// JSON parse/serialize error (in-tree parser, `util::json`).
    Json(String),

    /// A bounded serving submission queue is full (backpressure signal
    /// from [`crate::serve::ServeRuntime::try_submit`]); carries the
    /// queue depth that was exceeded.
    QueueFull(usize),

    /// A serving session overran its simulated-cycle or host-wall
    /// deadline (see [`crate::serve::RecoveryPolicy`]). Distinct from
    /// `Noc`'s `FabricDegraded` stall classification: the fabric made
    /// progress, just not fast enough.
    Deadline(String),

    /// I/O error.
    Io(std::io::Error),
}

/// Errors are cloneable so one serving outcome can be observed from
/// several places (a [`crate::serve::SessionTicket`], the streaming
/// outcome iterator and the final merged report) without draining it.
/// `Io` carries `std::io::Error` (not `Clone`); its clone preserves the
/// kind and message.
impl Clone for Error {
    fn clone(&self) -> Self {
        match self {
            Error::Config(m) => Error::Config(m.clone()),
            Error::Network(m) => Error::Network(m.clone()),
            Error::Mapping(m) => Error::Mapping(m.clone()),
            Error::Noc(m) => Error::Noc(m.clone()),
            Error::Core(m) => Error::Core(m.clone()),
            Error::Riscv(m) => Error::Riscv(m.clone()),
            Error::Soc(m) => Error::Soc(m.clone()),
            Error::Runtime(m) => Error::Runtime(m.clone()),
            Error::Artifact(m) => Error::Artifact(m.clone()),
            Error::Json(m) => Error::Json(m.clone()),
            Error::QueueFull(d) => Error::QueueFull(*d),
            Error::Deadline(m) => Error::Deadline(m.clone()),
            Error::Io(e) => Error::Io(std::io::Error::new(e.kind(), e.to_string())),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Network(m) => write!(f, "network error: {m}"),
            Error::Mapping(m) => write!(f, "mapping error: {m}"),
            Error::Noc(m) => write!(f, "noc error: {m}"),
            Error::Core(m) => write!(f, "core error: {m}"),
            Error::Riscv(m) => write!(f, "riscv error: {m}"),
            Error::Soc(m) => write!(f, "soc error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::QueueFull(d) => {
                write!(f, "serve queue full (depth {d}); retry or use submit()")
            }
            Error::Deadline(m) => write!(f, "deadline exceeded: {m}"),
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand constructor for configuration errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_layer_prefix() {
        assert_eq!(Error::Noc("x".into()).to_string(), "noc error: x");
        assert_eq!(Error::Config("y".into()).to_string(), "config error: y");
    }

    #[test]
    fn clone_preserves_variant_and_message() {
        let e = Error::QueueFull(4);
        assert!(matches!(e.clone(), Error::QueueFull(4)));
        assert!(e.to_string().contains("depth 4"));
        let io = Error::Io(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let c = io.clone();
        assert_eq!(io.to_string(), c.to_string());
        assert!(matches!(c, Error::Io(_)));
    }

    #[test]
    fn io_errors_convert() {
        fn fails() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
            Ok(())
        }
        assert!(matches!(fails(), Err(Error::Io(_))));
    }
}
