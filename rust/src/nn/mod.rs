//! Network descriptions, non-uniform weight quantization and the
//! neuron→core mapper.
//!
//! The flow: the Python compile path trains a float SNN, quantizes each
//! layer to a non-uniform codebook (`N` levels × `W`-bit integers — the
//! chip's shared-codebook scheme) and exports `artifacts/weights.json`;
//! [`loader`] reads it into a [`network::NetworkDesc`]; [`mapper`] splits
//! each layer across neuromorphic cores (respecting the 8 K-neuron and
//! codebook-per-core limits) and emits the multicast routing plan the
//! coordinator drives through the NoC. [`quant`] reimplements the same
//! k-means quantizer in Rust (used by examples that build networks without
//! the Python path, and property-tested against its invariants).

pub mod loader;
pub mod mapper;
pub mod network;
pub mod quant;

pub use loader::load_weights_json;
pub use mapper::{CorePlacement, Mapping};
pub use network::{LayerDesc, NetworkDesc};
pub use quant::QuantizedLayer;
