//! Non-uniform quantized weight codebook shared by all synapses of a core.
//!
//! The paper: "All synapses share N × W-bit quantized weights in a core,
//! in which N is the weight number, and W is the weight bit width
//! (N, W ∈ {4, 8, 16})." A synapse stores only an index (log2 N bits) into
//! the codebook, which is what makes 64 M synapses/core addressable with
//! tiny on-core weight memory.

use crate::{Error, Result};


/// Allowed codebook sizes / bit widths.
pub const ALLOWED_N: [usize; 3] = [4, 8, 16];
/// Allowed weight bit widths.
pub const ALLOWED_W: [usize; 3] = [4, 8, 16];

/// A core's shared weight codebook: `n` signed `w_bits`-wide values.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Codebook {
    values: Vec<i32>,
    w_bits: usize,
}

impl Codebook {
    /// Build a codebook, validating `N`, `W` and value ranges.
    pub fn new(values: Vec<i32>, w_bits: usize) -> Result<Self> {
        if !ALLOWED_N.contains(&values.len()) {
            return Err(Error::Core(format!(
                "codebook size N={} not in {:?}",
                values.len(),
                ALLOWED_N
            )));
        }
        if !ALLOWED_W.contains(&w_bits) {
            return Err(Error::Core(format!(
                "weight width W={w_bits} not in {ALLOWED_W:?}"
            )));
        }
        let (lo, hi) = Self::range(w_bits);
        for (i, &v) in values.iter().enumerate() {
            if v < lo || v > hi {
                return Err(Error::Core(format!(
                    "codebook[{i}]={v} outside {w_bits}-bit signed range [{lo}, {hi}]"
                )));
            }
        }
        Ok(Codebook { values, w_bits })
    }

    /// Signed range of a `w_bits` weight.
    pub fn range(w_bits: usize) -> (i32, i32) {
        let half = 1i64 << (w_bits - 1);
        ((-half) as i32, (half - 1) as i32)
    }

    /// Number of codebook entries (N).
    pub fn n(&self) -> usize {
        self.values.len()
    }

    /// Weight bit width (W).
    pub fn w_bits(&self) -> usize {
        self.w_bits
    }

    /// Bits needed per synapse index (log2 N).
    pub fn index_bits(&self) -> usize {
        self.values.len().trailing_zeros() as usize
    }

    /// Total codebook storage in bits (`N × W`).
    pub fn storage_bits(&self) -> usize {
        self.n() * self.w_bits
    }

    /// Look up a weight by synapse index.
    #[inline]
    pub fn weight(&self, idx: u8) -> i32 {
        self.values[idx as usize]
    }

    /// All values.
    pub fn values(&self) -> &[i32] {
        &self.values
    }

    /// Default 16-entry, 8-bit codebook with a symmetric non-uniform
    /// (approximately logarithmic) level spacing — a sensible default for
    /// tests and examples.
    pub fn default_log16() -> Self {
        let v = vec![
            -96, -64, -40, -24, -14, -8, -4, -1, 0, 1, 4, 8, 14, 24, 40, 64,
        ];
        Codebook::new(v, 8).expect("static codebook is valid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_sizes_only() {
        assert!(Codebook::new(vec![0; 4], 4).is_ok());
        assert!(Codebook::new(vec![0; 8], 8).is_ok());
        assert!(Codebook::new(vec![0; 16], 16).is_ok());
        assert!(Codebook::new(vec![0; 5], 8).is_err());
        assert!(Codebook::new(vec![0; 16], 6).is_err());
    }

    #[test]
    fn range_enforced() {
        // 4-bit signed: [-8, 7].
        assert!(Codebook::new(vec![-8, 7, 0, 1], 4).is_ok());
        assert!(Codebook::new(vec![-9, 0, 0, 0], 4).is_err());
        assert!(Codebook::new(vec![8, 0, 0, 0], 4).is_err());
    }

    #[test]
    fn index_and_storage_bits() {
        let cb = Codebook::default_log16();
        assert_eq!(cb.n(), 16);
        assert_eq!(cb.index_bits(), 4);
        assert_eq!(cb.storage_bits(), 128);
        assert_eq!(cb.weight(8), 0);
    }
}
