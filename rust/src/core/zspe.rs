//! Zero-skip sparse process engine (ZSPE).
//!
//! Scans one 16-bit spike word per cycle; valid (set) bits become
//! weight-index requests forwarded to the SPE stage, zero bits are
//! *skipped* at near-zero energy. This is the paper's headline sparse
//! optimization: synapse work and energy scale with valid spikes, not
//! with axon count.

/// Result of scanning one spike word.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WordScan {
    /// Absolute axon ids of valid spikes in this word (LSB-first order —
    /// the hardware priority encoder drains from bit 0 upward).
    pub valid_axons: Vec<u32>,
    /// Number of zero (skipped) lanes in this word that map to real axons.
    pub skipped: u32,
}

/// Scan a 16-bit spike word.
///
/// `word_idx` is the word's position in the spike cache, `axons` the core's
/// total axon count (so the final partial word doesn't report padding
/// lanes as skips).
pub fn scan_word(word: u16, word_idx: usize, axons: usize) -> WordScan {
    let base = word_idx * super::SPIKE_WORD_BITS;
    let lanes = super::SPIKE_WORD_BITS.min(axons.saturating_sub(base));
    let mut valid_axons = Vec::new();
    let mut w = word;
    // Drain set bits LSB-first via count-trailing-zeros — mirrors the
    // hardware priority encoder and is branch-light on the host.
    while w != 0 {
        let bit = w.trailing_zeros() as usize;
        if bit >= lanes {
            break; // padding bits beyond the last axon
        }
        valid_axons.push((base + bit) as u32);
        w &= w - 1;
    }
    WordScan {
        skipped: lanes as u32 - valid_axons.len() as u32,
        valid_axons,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scans_lsb_first() {
        let s = scan_word(0b1000_0000_0000_0101, 0, 16);
        assert_eq!(s.valid_axons, vec![0, 2, 15]);
        assert_eq!(s.skipped, 13);
    }

    #[test]
    fn word_offset_applied() {
        let s = scan_word(0b1, 2, 64);
        assert_eq!(s.valid_axons, vec![32]);
    }

    #[test]
    fn partial_final_word_ignores_padding() {
        // 20 axons: word 1 has only 4 real lanes (16..19).
        let s = scan_word(0xFFFF, 1, 20);
        assert_eq!(s.valid_axons, vec![16, 17, 18, 19]);
        assert_eq!(s.skipped, 0);
    }

    #[test]
    fn all_zero_word_skips_all_lanes() {
        let s = scan_word(0, 0, 16);
        assert!(s.valid_axons.is_empty());
        assert_eq!(s.skipped, 16);
    }
}
