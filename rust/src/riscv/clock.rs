//! Three clock domains of the RISC-V subsystem (paper §II.C: "There are
//! three different clock domains in the RISC-V core, in which the
//! high-frequency clock (HFCLK) in the main domain can be halted by clock
//! gating through a sleep instruction in software for low power.").
//!
//! Domains: **HF** (main pipeline, gatable), **LF** (always-on wake
//! controller + timers), **BUS** (neuromorphic-bus interface, active only
//! during transfers). Cycle accounting per domain feeds the Fig. 6 power
//! model.

/// Clock-domain cycle accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClockDomains {
    /// HFCLK cycles with the clock running (core executing).
    pub hf_active: u64,
    /// HFCLK cycles gated (core sleeping).
    pub hf_gated: u64,
    /// LF domain cycles (always-on; == total wall cycles in LF units).
    pub lf_cycles: u64,
    /// Bus-domain active cycles (transfers in flight).
    pub bus_active: u64,
    /// Whether HFCLK gating is implemented (baseline ablation: false).
    pub gating_enabled: bool,
}

impl ClockDomains {
    /// New accounting block; `gating_enabled=false` models the paper's
    /// no-clock-gating baseline (sleep still halts architecturally but the
    /// clock tree keeps toggling — full active power while "sleeping").
    pub fn new(gating_enabled: bool) -> Self {
        ClockDomains {
            gating_enabled,
            ..Default::default()
        }
    }

    /// Account one wall cycle in `running` (true = executing) state.
    #[inline]
    pub fn tick(&mut self, running: bool) {
        if running || !self.gating_enabled {
            self.hf_active += 1;
        } else {
            self.hf_gated += 1;
        }
        self.lf_cycles += 1;
    }

    /// Account a bus transfer burst.
    pub fn bus_burst(&mut self, cycles: u64) {
        self.bus_active += cycles;
    }

    /// Total wall cycles.
    pub fn wall(&self) -> u64 {
        self.hf_active + self.hf_gated
    }

    /// Fraction of wall time the HF domain was gated.
    pub fn gated_fraction(&self) -> f64 {
        if self.wall() == 0 {
            0.0
        } else {
            self.hf_gated as f64 / self.wall() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gating_splits_active_and_gated() {
        let mut c = ClockDomains::new(true);
        for i in 0..100 {
            c.tick(i < 25); // run 25, sleep 75
        }
        assert_eq!(c.hf_active, 25);
        assert_eq!(c.hf_gated, 75);
        assert_eq!(c.lf_cycles, 100);
        assert!((c.gated_fraction() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn baseline_without_gating_burns_hf_always() {
        let mut c = ClockDomains::new(false);
        for i in 0..100 {
            c.tick(i < 25);
        }
        assert_eq!(c.hf_active, 100);
        assert_eq!(c.hf_gated, 0);
    }
}
