//! Property-testing loop (replaces `proptest`, unavailable offline).
//!
//! [`check`] runs a property over `n` seeded random cases; on failure it
//! panics with the offending seed so the case can be replayed with
//! `check_one`. No shrinking — seeds are small enough to debug directly,
//! and generators should keep cases small.

use super::prng::Rng;

/// Run `prop` over `n` cases seeded `base_seed + i`. Panics (failing the
/// test) with the seed on the first violation.
pub fn check(name: &str, n: u64, base_seed: u64, mut prop: impl FnMut(&mut Rng)) {
    for i in 0..n {
        let seed = base_seed.wrapping_add(i);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at seed {seed} (case {i}/{n}): {msg}");
        }
    }
}

/// Replay a single seed (debugging helper).
pub fn check_one(seed: u64, mut prop: impl FnMut(&mut Rng)) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_good_property() {
        check("add-commutes", 50, 1, |r| {
            let a = r.range_i64(-1000, 1000);
            let b = r.range_i64(-1000, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "seed")]
    fn reports_seed_on_failure() {
        check("always-fails-eventually", 50, 1, |r| {
            assert!(r.below(10) != 3, "hit the 3");
        });
    }
}
