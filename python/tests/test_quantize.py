"""Quantizer invariants (mirror of the Rust-side property tests)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.sampled_from([4, 8, 16]),
       bits=st.sampled_from([4, 8, 16]),
       size=st.integers(20, 400))
def test_invariants(seed, n, bits, size):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.4, size)
    q = quantize.kmeans_quantize(w, n, bits)
    assert q.codebook.shape == (n,)
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    assert (q.codebook >= lo).all() and (q.codebook <= hi).all()
    assert (np.diff(q.codebook) >= 0).all(), "levels must be sorted"
    assert q.widx.max() < n
    # Nearest-level assignment in the deployed (integer×scale) domain.
    approx = q.codebook[q.widx.astype(int)] * q.scale
    for lvl in q.codebook:
        alt = lvl * q.scale
        assert (np.abs(w - approx) <= np.abs(w - alt) + 1e-9).all()


def test_discrete_weights_recovered_exactly():
    rng = np.random.default_rng(1)
    vals = np.array([-0.5, -0.1, 0.2, 0.7])
    w = vals[rng.integers(0, 4, 500)]
    q = quantize.kmeans_quantize(w, 4, 8)
    assert quantize.quant_mse(w, q) < 1e-4


def test_more_levels_reduce_error():
    rng = np.random.default_rng(2)
    w = rng.normal(0, 0.3, 1000)
    e4 = quantize.quant_mse(w, quantize.kmeans_quantize(w, 4, 8))
    e16 = quantize.quant_mse(w, quantize.kmeans_quantize(w, 16, 8))
    assert e16 < e4


def test_all_zero_weights():
    q = quantize.kmeans_quantize(np.zeros(64), 4, 8)
    assert (q.codebook == 0).all()


def test_shape_preserved():
    rng = np.random.default_rng(3)
    w = rng.normal(size=(7, 11))
    q = quantize.kmeans_quantize(w, 8, 8)
    assert q.widx.shape == (7, 11)


def test_invalid_geometry_rejected():
    with pytest.raises(AssertionError):
        quantize.kmeans_quantize(np.ones(10), 5, 8)
    with pytest.raises(AssertionError):
        quantize.kmeans_quantize(np.ones(10), 8, 7)
