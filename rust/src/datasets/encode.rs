//! Spike encoders shared by the synthetic generators: Bernoulli rate
//! coding from intensity maps, plus gaussian-blob intensity synthesis.

use super::events::Sample;
use crate::util::prng::Rng;

/// A 2D (or stacked-channel) intensity map in `[0, 1]`.
#[derive(Debug, Clone)]
pub struct Intensity {
    /// Width.
    pub w: usize,
    /// Height.
    pub h: usize,
    /// Channels.
    pub c: usize,
    /// Row-major `[c][y][x]` intensities.
    pub data: Vec<f64>,
}

impl Intensity {
    /// All-zero map.
    pub fn zeros(w: usize, h: usize, c: usize) -> Self {
        Intensity {
            w,
            h,
            c,
            data: vec![0.0; w * h * c],
        }
    }

    /// Flat input index of `(channel, y, x)`.
    #[inline]
    pub fn idx(&self, ch: usize, y: usize, x: usize) -> usize {
        (ch * self.h + y) * self.w + x
    }

    /// Add a gaussian blob at `(cx, cy)` with std `sigma` and peak `amp`
    /// on channel `ch`, clamping to `[0, 1]`.
    pub fn add_blob(&mut self, ch: usize, cx: f64, cy: f64, sigma: f64, amp: f64) {
        for y in 0..self.h {
            for x in 0..self.w {
                let d2 = (x as f64 - cx).powi(2) + (y as f64 - cy).powi(2);
                let v = amp * (-d2 / (2.0 * sigma * sigma)).exp();
                let i = self.idx(ch, y, x);
                self.data[i] = (self.data[i] + v).min(1.0);
            }
        }
    }

    /// Shift the map by integer `(dx, dy)` (zero-fill), returning a copy —
    /// used for saccade/motion simulation.
    pub fn shifted(&self, dx: i64, dy: i64) -> Intensity {
        let mut out = Intensity::zeros(self.w, self.h, self.c);
        for ch in 0..self.c {
            for y in 0..self.h {
                for x in 0..self.w {
                    let sx = x as i64 - dx;
                    let sy = y as i64 - dy;
                    if sx >= 0 && sx < self.w as i64 && sy >= 0 && sy < self.h as i64 {
                        let v = self.data[self.idx(ch, sy as usize, sx as usize)];
                        let i = out.idx(ch, y, x);
                        out.data[i] = v;
                    }
                }
            }
        }
        out
    }

    /// Total inputs (`w × h × c`).
    pub fn inputs(&self) -> usize {
        self.data.len()
    }
}

/// Bernoulli rate coding: each timestep, input `i` spikes with probability
/// `intensity[i] × gain` (clamped to 1).
pub fn rate_encode(
    frames: &[Intensity],
    gain: f64,
    label: usize,
    rng: &mut Rng,
) -> Sample {
    let mut events = Vec::new();
    for (t, f) in frames.iter().enumerate() {
        for (i, &v) in f.data.iter().enumerate() {
            let p = (v * gain).min(1.0);
            if p > 0.0 && rng.bool(p) {
                events.push((t as u16, i as u32));
            }
        }
    }
    Sample { label, events }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blob_peaks_at_center() {
        let mut m = Intensity::zeros(9, 9, 1);
        m.add_blob(0, 4.0, 4.0, 1.5, 0.9);
        let center = m.data[m.idx(0, 4, 4)];
        let corner = m.data[m.idx(0, 0, 0)];
        assert!(center > 0.85);
        assert!(corner < 0.01);
    }

    #[test]
    fn shift_moves_mass() {
        let mut m = Intensity::zeros(9, 9, 1);
        m.add_blob(0, 2.0, 2.0, 1.0, 1.0);
        let s = m.shifted(3, 0);
        let i_orig = m.idx(0, 2, 2);
        let i_new = m.idx(0, 2, 5);
        assert!(s.data[i_new] > 0.9);
        assert!(s.data[i_orig] < s.data[i_new]);
    }

    #[test]
    fn rate_encode_tracks_intensity() {
        let mut hi = Intensity::zeros(10, 10, 1);
        for v in hi.data.iter_mut() {
            *v = 0.8;
        }
        let lo = Intensity::zeros(10, 10, 1);
        let mut rng = Rng::new(1);
        let s_hi = rate_encode(&vec![hi; 10], 0.5, 0, &mut rng);
        let s_lo = rate_encode(&vec![lo; 10], 0.5, 0, &mut rng);
        assert!(s_hi.events.len() > 300); // E = 10t × 100px × 0.4
        assert_eq!(s_lo.events.len(), 0);
    }
}
