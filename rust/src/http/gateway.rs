//! The HTTP ↔ serving bridge: routes parsed requests onto a shared
//! [`ServeRuntime`] and renders outcomes/metrics as JSON and text.
//!
//! The gateway owns the runtime behind a mutex (submission and
//! health/metrics snapshots are short critical sections; serving itself
//! happens on the runtime's own worker threads) plus the ticket table
//! that turns submission indexes into pollable session ids. Every
//! construction knob still funnels through `SocBuilder` — the gateway
//! receives an already-validated runtime and adds no second
//! configuration path.

use super::framing::{Request, Response};
use crate::serve::{
    workload_from_spec, HealthReport, ServeRuntime, SessionOutcome, SessionSpec,
    SessionTicket,
};
use crate::util::json::Json;
use crate::Error;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// `Retry-After` seconds advertised with every 429 (small: the queue
/// turns over in session-serving time, not minutes).
pub const RETRY_AFTER_S: u32 = 1;

/// Gateway policy knobs (all validated upstream by the CLI layer).
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// When set, `POST /admin/shutdown` requires this bearer token;
    /// when `None` the admin surface is open (loopback deployments).
    pub admin_token: Option<String>,
    /// Workload spec used when a submission omits `"workload"` (also
    /// the geometry the runtime's network was built for).
    pub default_workload: String,
    /// Cap on per-session `"samples"` from untrusted submissions.
    pub max_samples: usize,
}

/// Counters the server updates and /metrics exposes.
#[derive(Debug, Default)]
struct HttpCounters {
    requests: u64,
    responses_by_code: BTreeMap<u16, u64>,
}

/// The shared server state: one serving runtime + the ticket table.
pub struct Gateway {
    cfg: GatewayConfig,
    rt: Mutex<ServeRuntime>,
    tickets: Mutex<BTreeMap<u64, SessionTicket>>,
    counters: Mutex<HttpCounters>,
    connections_opened: AtomicU64,
    connections_closed: AtomicU64,
    draining: AtomicBool,
}

impl Gateway {
    /// Wrap an already-built (and therefore already-validated) runtime.
    pub fn new(rt: ServeRuntime, cfg: GatewayConfig) -> Gateway {
        Gateway {
            cfg,
            rt: Mutex::new(rt),
            tickets: Mutex::new(BTreeMap::new()),
            counters: Mutex::new(HttpCounters::default()),
            connections_opened: AtomicU64::new(0),
            connections_closed: AtomicU64::new(0),
            draining: AtomicBool::new(false),
        }
    }

    /// Whether a shutdown has been requested (admin endpoint or
    /// programmatic).
    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Flip the drain flag (also used by the programmatic shutdown).
    pub fn request_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    pub(super) fn connection_opened(&self) {
        self.connections_opened.fetch_add(1, Ordering::SeqCst);
    }

    pub(super) fn connection_closed(&self) {
        self.connections_closed.fetch_add(1, Ordering::SeqCst);
    }

    /// (opened, closed) connection totals.
    pub fn connection_counts(&self) -> (u64, u64) {
        (
            self.connections_opened.load(Ordering::SeqCst),
            self.connections_closed.load(Ordering::SeqCst),
        )
    }

    /// Record one response for /metrics (called by the server after
    /// every write, including framing-error responses).
    pub(super) fn record_response(&self, status: u16) {
        let mut c = lock(&self.counters);
        c.requests += 1;
        *c.responses_by_code.entry(status).or_insert(0) += 1;
    }

    /// Responses emitted with `status`, for tests and stats.
    pub fn responses_with_status(&self, status: u16) -> u64 {
        lock(&self.counters)
            .responses_by_code
            .get(&status)
            .copied()
            .unwrap_or(0)
    }

    /// Total responses by status code (snapshot).
    pub fn responses_by_code(&self) -> BTreeMap<u16, u64> {
        lock(&self.counters).responses_by_code.clone()
    }

    /// Drain the runtime: close the queue, serve everything already
    /// admitted, join the workers. Idempotent; returns the final health
    /// ledger.
    pub fn shutdown_runtime(&self) -> crate::Result<HealthReport> {
        let mut rt = lock(&self.rt);
        rt.shutdown()?;
        Ok(rt.health_report())
    }

    /// Route one request. The bool asks the server to begin its drain
    /// (set only by an authorized `POST /admin/shutdown`).
    pub fn handle(&self, req: &Request) -> (Response, bool) {
        let path = req.path.split('?').next().unwrap_or("");
        match (req.method.as_str(), path) {
            ("GET", "/healthz") => (self.healthz(), false),
            ("GET", "/metrics") => (Response::text(200, self.metrics_text()), false),
            ("POST", "/v1/sessions") => (self.submit(req), false),
            ("GET", p) if p.starts_with("/v1/sessions/") => {
                (self.poll(&p["/v1/sessions/".len()..]), false)
            }
            ("POST", "/admin/shutdown") => self.admin_shutdown(req),
            ("GET" | "POST", _) => (
                Response::json_error(404, &format!("no route for {} {path}", req.method)),
                false,
            ),
            _ => (
                Response::json_error(
                    405,
                    &format!("method {} not allowed", req.method),
                ),
                false,
            ),
        }
    }

    fn healthz(&self) -> Response {
        let (submitted, in_flight, workers) = {
            let rt = lock(&self.rt);
            (rt.submitted(), rt.in_flight(), rt.workers())
        };
        Response::json(
            200,
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("draining", Json::Bool(self.draining())),
                ("workers", Json::Num(workers as f64)),
                ("submitted", Json::Num(submitted as f64)),
                ("in_flight", Json::Num(in_flight as f64)),
            ]),
        )
    }

    /// `POST /v1/sessions`: JSON spec in, ticket id out. `QueueFull`
    /// maps to 429 + `Retry-After`; a drain in progress to 503.
    fn submit(&self, req: &Request) -> Response {
        if self.draining() {
            let mut r = Response::json_error(503, "server is draining; resubmit elsewhere");
            r.retry_after_s = Some(RETRY_AFTER_S);
            return r;
        }
        let body = match req.body_utf8() {
            Ok(b) => b,
            Err(e) => return e.to_response(),
        };
        let parsed = match Json::parse(body) {
            Ok(v) => v,
            Err(e) => return Response::json_error(400, &format!("bad JSON body: {e}")),
        };
        let spec_str = match parsed.get_opt("workload") {
            None => self.cfg.default_workload.clone(),
            Some(v) => match v.as_str() {
                Ok(s) => s.to_string(),
                Err(e) => return Response::json_error(400, &format!("bad 'workload': {e}")),
            },
        };
        let samples = match parsed.get_opt("samples") {
            None => 1,
            Some(v) => match v.as_usize() {
                Ok(n) => n,
                Err(e) => return Response::json_error(400, &format!("bad 'samples': {e}")),
            },
        };
        if samples == 0 || samples > self.cfg.max_samples {
            return Response::json_error(
                400,
                &format!(
                    "'samples' must be in 1..={} (got {samples})",
                    self.cfg.max_samples
                ),
            );
        }
        let seed = match parsed.get_opt("seed") {
            None => 0u64,
            Some(v) => match v.as_i64() {
                Ok(n) if n >= 0 => n as u64,
                _ => return Response::json_error(400, "bad 'seed': expected u64"),
            },
        };
        let workload = match workload_from_spec(&spec_str, samples, seed) {
            Ok(w) => w,
            Err(e) => return Response::json_error(400, &format!("bad workload spec: {e}")),
        };

        let mut rt = lock(&self.rt);
        let name = match parsed.get_opt("name").map(|v| v.as_str()) {
            None => format!("http-{}", rt.submitted()),
            Some(Ok(s)) => s.to_string(),
            Some(Err(e)) => return Response::json_error(400, &format!("bad 'name': {e}")),
        };
        match rt.try_submit(SessionSpec::new(&name, workload)) {
            Ok(ticket) => {
                let id = ticket.index();
                drop(rt);
                lock(&self.tickets).insert(id, ticket);
                Response::json(
                    202,
                    Json::obj(vec![
                        ("id", Json::Num(id as f64)),
                        ("name", Json::Str(name)),
                    ]),
                )
            }
            Err(Error::QueueFull(depth)) => {
                drop(rt);
                let mut r = Response::json(
                    429,
                    Json::obj(vec![
                        (
                            "error",
                            Json::Str(format!("queue full (depth {depth}); retry")),
                        ),
                        ("queue_depth", Json::Num(depth as f64)),
                        ("retry_after_s", Json::Num(RETRY_AFTER_S as f64)),
                    ]),
                );
                r.retry_after_s = Some(RETRY_AFTER_S);
                r
            }
            Err(e @ (Error::Config(_) | Error::Json(_) | Error::Network(_))) => {
                Response::json_error(400, &e.to_string())
            }
            Err(e) => Response::json_error(500, &e.to_string()),
        }
    }

    /// `GET /v1/sessions/<id>`: poll a ticket without blocking.
    fn poll(&self, id_str: &str) -> Response {
        let Ok(id) = id_str.parse::<u64>() else {
            return Response::json_error(400, &format!("bad session id '{id_str}'"));
        };
        let tickets = lock(&self.tickets);
        let Some(ticket) = tickets.get(&id) else {
            return Response::json_error(404, &format!("unknown session id {id}"));
        };
        let state = ticket.try_result();
        let name = ticket.name().to_string();
        drop(tickets);
        match state {
            None => Response::json(
                200,
                Json::obj(vec![
                    ("id", Json::Num(id as f64)),
                    ("name", Json::Str(name)),
                    ("state", Json::Str("pending".into())),
                ]),
            ),
            Some(Ok(o)) => Response::json(
                200,
                Json::obj(vec![
                    ("id", Json::Num(id as f64)),
                    ("name", Json::Str(name)),
                    ("state", Json::Str("completed".into())),
                    ("outcome", outcome_json(&o)),
                ]),
            ),
            Some(Err(e)) => Response::json(
                200,
                Json::obj(vec![
                    ("id", Json::Num(id as f64)),
                    ("name", Json::Str(name)),
                    ("state", Json::Str("failed".into())),
                    ("error", Json::Str(e.to_string())),
                ]),
            ),
        }
    }

    /// `POST /admin/shutdown`: flag-gated bearer-token auth, then ask
    /// the server to drain.
    fn admin_shutdown(&self, req: &Request) -> (Response, bool) {
        if let Some(expect) = &self.cfg.admin_token {
            let presented = req
                .header("authorization")
                .and_then(|v| v.strip_prefix("Bearer "))
                .or_else(|| req.header("x-admin-token"));
            if presented != Some(expect.as_str()) {
                return (
                    Response::json_error(401, "missing or wrong admin token"),
                    false,
                );
            }
        }
        self.request_drain();
        let (submitted, in_flight) = {
            let rt = lock(&self.rt);
            (rt.submitted(), rt.in_flight())
        };
        let mut r = Response::json(
            200,
            Json::obj(vec![
                ("draining", Json::Bool(true)),
                ("submitted", Json::Num(submitted as f64)),
                ("in_flight", Json::Num(in_flight as f64)),
            ]),
        );
        // The drain closes this listener; be honest with the client.
        r.close = true;
        (r, true)
    }

    /// The `/metrics` text exposition (Prometheus-style lines; stable
    /// `fsoc_` prefix, deterministic ordering via BTreeMaps).
    pub fn metrics_text(&self) -> String {
        let (queue_depth, submitted, in_flight, workers, health) = {
            let rt = lock(&self.rt);
            (
                rt.queue_depth(),
                rt.submitted(),
                rt.in_flight(),
                rt.workers(),
                rt.health_report(),
            )
        };
        let mut out = String::new();
        out.push_str(&format!("fsoc_queue_depth {queue_depth}\n"));
        out.push_str(&format!("fsoc_workers {workers}\n"));
        out.push_str(&format!("fsoc_sessions_submitted {submitted}\n"));
        out.push_str(&format!("fsoc_sessions_in_flight {in_flight}\n"));
        out.push_str(&format!(
            "fsoc_draining {}\n",
            if self.draining() { 1 } else { 0 }
        ));
        for (label, n) in [
            ("completed", health.completed),
            ("deadline-exceeded", health.deadline_exceeded),
            ("fabric-degraded", health.fabric_degraded),
            ("failed", health.failed),
        ] {
            out.push_str(&format!(
                "fsoc_sessions_verdict{{verdict=\"{label}\"}} {n}\n"
            ));
        }
        for (name, n) in [
            ("retries", health.retries),
            ("retry_cycles_burned", health.retry_cycles_burned),
            ("quarantines", health.quarantines),
            ("rebuilds", health.rebuilds),
            ("replans", health.replans),
        ] {
            out.push_str(&format!("fsoc_health_{name} {n}\n"));
        }
        {
            let c = lock(&self.counters);
            out.push_str(&format!("fsoc_http_requests_total {}\n", c.requests));
            for (code, n) in &c.responses_by_code {
                out.push_str(&format!(
                    "fsoc_http_responses_total{{code=\"{code}\"}} {n}\n"
                ));
            }
        }
        let (opened, closed) = self.connection_counts();
        out.push_str(&format!("fsoc_http_connections_opened {opened}\n"));
        out.push_str(&format!("fsoc_http_connections_closed {closed}\n"));
        // Per-class energy totals folded over every resolved outcome —
        // the serving fleet's energy ledger through the paper's lens.
        let mut energy: BTreeMap<String, f64> = BTreeMap::new();
        let mut samples = 0u64;
        {
            let tickets = lock(&self.tickets);
            for t in tickets.values() {
                if let Some(Ok(o)) = t.try_result() {
                    samples += o.stats.samples;
                    for (class, pj) in &o.report.breakdown.by_class {
                        *energy.entry(class.clone()).or_insert(0.0) += pj;
                    }
                }
            }
        }
        out.push_str(&format!("fsoc_samples_served {samples}\n"));
        for (class, pj) in &energy {
            out.push_str(&format!("fsoc_energy_pj{{class=\"{class}\"}} {pj:.3}\n"));
        }
        out
    }
}

/// Lock a gateway mutex, shrugging off poison exactly like the serving
/// runtime does (`serve::runtime::lock_q` rationale: the data stays
/// internally consistent between guard acquisitions, and one panicking
/// connection must not take the whole front end down).
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Render one session outcome for the polling endpoint. Alongside the
/// human-readable floats, the energy totals are pinned as `f64::to_bits`
/// hex strings — the wire form of the repo's bit-determinism contract
/// (HTTP-fetched outcomes must equal in-process serving exactly, and a
/// decimal rendering would hide one-ulp drift).
pub fn outcome_json(o: &SessionOutcome) -> Json {
    let bits = |f: f64| Json::Str(format!("{:016x}", f.to_bits()));
    Json::obj(vec![
        ("name", Json::Str(o.name.clone())),
        ("verdict", Json::Str(o.verdict.as_str().to_string())),
        ("attempts", Json::Num(o.attempts as f64)),
        ("replans", Json::Num(o.replans as f64)),
        ("retry_cycles_burned", Json::Num(o.retry_cycles_burned as f64)),
        ("samples", Json::Num(o.stats.samples as f64)),
        ("cycles", Json::Num(o.stats.cycles as f64)),
        ("sops", Json::Num(o.stats.sops as f64)),
        ("p50_latency_ms", Json::Num(o.stats.p50_latency_ms)),
        ("p99_latency_ms", Json::Num(o.stats.p99_latency_ms)),
        ("queue_wait_s", Json::Num(o.queue_wait_s)),
        ("mismatches", Json::Num(o.mismatches as f64)),
        ("checked", Json::Num(o.checked as f64)),
        (
            "degradation",
            Json::obj(vec![
                ("armed", Json::Bool(o.degradation.armed)),
                ("delivered", Json::Num(o.degradation.delivered as f64)),
                ("dropped", Json::Num(o.degradation.dropped as f64)),
                (
                    "rerouted_hops",
                    Json::Num(o.degradation.rerouted_hops as f64),
                ),
                ("dead_routers", Json::Num(o.degradation.dead_routers as f64)),
                ("dead_links", Json::Num(o.degradation.dead_links as f64)),
            ]),
        ),
        (
            "report",
            Json::obj(vec![
                ("pj_per_sop", Json::Num(o.report.pj_per_sop)),
                ("power_mw", Json::Num(o.report.power_mw)),
                ("dynamic_pj", Json::Num(o.report.breakdown.dynamic_pj)),
                ("static_pj", Json::Num(o.report.breakdown.static_pj)),
            ]),
        ),
        ("pj_per_sop_bits", bits(o.report.pj_per_sop)),
        ("dynamic_pj_bits", bits(o.report.breakdown.dynamic_pj)),
        ("static_pj_bits", bits(o.report.breakdown.static_pj)),
    ])
}
