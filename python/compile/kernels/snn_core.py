"""Layer-1 Pallas kernel: the chip datapath's compute hot-spot.

One fused kernel per layer-timestep implements exactly what the silicon's
ZSPE → SPE → neuron-updater pipeline computes (see ``ref.py`` for the
bit-exact specification): sparsity-gated codebook accumulation plus the
partial-update LIF step.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the ASIC's
event-driven zero-skip becomes a *masked accumulate* — on a TPU-shaped
target branching per spike would stall the VPU, so the zero-skip is
expressed as multiplication by the 0/1 spike vector and the synapse-valid
mask, letting the MXU/VPU stream. The non-uniform weight codebook (≤16
entries) is VMEM-resident — the analogue of the paper's shared per-core
weight SRAM — and the per-synapse 4-bit indexes are expanded by an
on-the-fly gather. BlockSpec tiles the neuron axis (the dual-SPE
parallelism analogue); the A (axon) axis stays resident per tile, matching
the chip's "all synapses of a core share one codebook" locality.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; numerics are identical (see tests/test_kernel.py), and
real-TPU performance is *estimated* from the BlockSpec VMEM footprint in
DESIGN.md §Perf rather than measured.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NO_SYNAPSE = ref.NO_SYNAPSE

# Neuron-axis tile (the dual-SPE lane analogue; multiple of the VPU's 128
# lanes on real hardware).
DEFAULT_BLOCK_N = 128


def _kernel(spikes_ref, widx_ref, codebook_ref, mp_ref, out_spikes_ref,
            new_mp_ref, *, threshold, leak_mode, leak_value, reset_mode,
            mp_lo, mp_hi):
    """Pallas kernel body for one neuron tile."""
    spikes = spikes_ref[...].astype(jnp.int32)  # [A]
    widx = widx_ref[...].astype(jnp.int32)      # [A, BN]
    codebook = codebook_ref[...]                # [C]
    mp = mp_ref[...]                            # [BN]

    has_syn = (widx != NO_SYNAPSE).astype(jnp.int32)
    w = codebook[jnp.where(widx == NO_SYNAPSE, 0, widx)] * has_syn
    # Masked accumulate = the ZSPE zero-skip + SPE codebook MAC.
    acc = spikes @ w
    touched = (spikes @ has_syn) > 0

    # int32 is exact here: |mp| < 2^15 and |acc| ≤ A·96 ≪ 2^31.
    m = jnp.clip(mp + acc, mp_lo, mp_hi).astype(jnp.int32)
    if leak_mode == ref.LEAK_LINEAR:
        m = jnp.sign(m) * jnp.maximum(jnp.abs(m) - jnp.int32(leak_value), 0)
    elif leak_mode == ref.LEAK_SHIFT:
        m = m - (m >> leak_value)

    fire = touched & (m >= threshold)
    if reset_mode == ref.RESET_ZERO:
        m_after = jnp.where(fire, 0, m)
    else:
        m_after = jnp.where(fire, m - threshold, m)

    out_spikes_ref[...] = fire.astype(jnp.int32)
    new_mp_ref[...] = jnp.where(touched, m_after, mp)


def layer_step(spikes, widx, codebook, mp, p: ref.LayerParams,
               block_n: int = DEFAULT_BLOCK_N):
    """One timestep of one layer through the Pallas kernel.

    Same contract as :func:`ref.layer_step_ref`.
    """
    a, n = widx.shape
    bn = min(block_n, n)
    # Pad the neuron axis to a whole number of tiles.
    n_pad = (-n) % bn
    if n_pad:
        widx = jnp.pad(widx, ((0, 0), (0, n_pad)), constant_values=NO_SYNAPSE)
        mp = jnp.pad(mp, (0, n_pad))
    n_tot = n + n_pad
    grid = (n_tot // bn,)

    kernel = functools.partial(
        _kernel,
        threshold=int(p.threshold),
        leak_mode=int(p.leak_mode),
        leak_value=int(p.leak_value),
        reset_mode=int(p.reset_mode),
        mp_lo=int(p.mp_lo),
        mp_hi=int(p.mp_hi),
    )
    out_spikes, new_mp = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((a,), lambda i: (0,)),          # spikes: resident
            pl.BlockSpec((a, bn), lambda i: (0, i)),     # widx tile
            pl.BlockSpec((codebook.shape[0],), lambda i: (0,)),  # codebook
            pl.BlockSpec((bn,), lambda i: (i,)),         # mp tile
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tot,), jnp.int32),
            jax.ShapeDtypeStruct((n_tot,), jnp.int32),
        ],
        interpret=True,
    )(spikes.astype(jnp.int32), widx.astype(jnp.int32),
      codebook.astype(jnp.int32), mp.astype(jnp.int32))
    return out_spikes[:n], new_mp[:n]


def vmem_footprint_bytes(a: int, n: int, c: int,
                         block_n: int = DEFAULT_BLOCK_N) -> dict:
    """Estimated per-tile VMEM residency of the kernel (DESIGN.md §Perf).

    int32 working set per grid step: spikes[A] + widx[A, BN] + codebook[C]
    + mp/out/new_mp[BN] each.
    """
    bn = min(block_n, n)
    return {
        "spikes": 4 * a,
        "widx_tile": 4 * a * bn,
        "codebook": 4 * c,
        "mp_tiles": 3 * 4 * bn,
        "total": 4 * (a + a * bn + c + 3 * bn),
    }
