//! Streaming session/serving API — the crate's top-level surface.
//!
//! The paper's chip is an always-on edge device consuming event streams
//! continuously; this layer makes the simulator serve the same way
//! instead of only running pre-materialized batches:
//!
//! - [`SocBuilder`] — fluent construction + **the** single validation
//!   choke point for chip/run/serving configuration (JSON, CLI flags
//!   and fluent calls all funnel through it);
//! - [`Workload`] — pluggable sample sources ([`SyntheticStream`],
//!   [`EventReplay`], [`TrafficWorkload`], or anything downstream
//!   implements), parsed from spec strings by [`workload_from_spec`];
//! - [`Session`] — a streaming inference session with per-push results,
//!   incremental [`Session::snapshot`] reports, per-session
//!   energy/latency ledgers and a consuming [`Session::close`] (the
//!   typestate makes "forgot `finish_report`" unrepresentable);
//! - [`ServeRuntime`] — the serving engine: persistent worker threads
//!   pulling from a bounded submission queue ([`ServeRuntime::submit`]
//!   blocks on backpressure, [`ServeRuntime::try_submit`] surfaces
//!   [`crate::Error::QueueFull`]), **warm chip reuse** via
//!   [`crate::soc::Soc::reset_for_session`] (bit-identical to fresh
//!   chips), per-[`SessionTicket`] waits, an [`ServeRuntime::outcomes`]
//!   iterator yielding results as sessions finish, and per-session
//!   failure isolation;
//! - [`SocPool`] — the batch-compatibility wrapper over the runtime
//!   (`serve` submits everything and waits; `serve_sequential` is the
//!   fresh-chip sequential reference path the runtime's bit-identity
//!   guarantee is stated against).
//!
//! The batch layer ([`crate::coordinator::ExperimentRunner`]) is rebuilt
//! on top of these primitives.

pub mod builder;
pub mod pool;
pub mod runtime;
pub mod session;
pub mod workload;

pub use builder::SocBuilder;
pub use pool::{ServeOutcome, SessionFailure, SessionOutcome, SessionSpec, SocPool};
pub use runtime::{Outcomes, ServeRuntime, SessionResult, SessionTicket};
pub use session::{DegradationStats, Session, SessionReport, SessionStats};
pub use workload::{
    workload_from_spec, EventReplay, SyntheticStream, TrafficWorkload, Workload,
};
