//! Cluster scale-out smoke: for each swept chip count (1/2/4), find the
//! largest network the ring can serve (probed through the real
//! `ClusterMapper::plan` feasibility rule), build the cluster, and time
//! warm-reused sessions over it — sessions/s, inter-chip L3 flits/s,
//! cluster-wide flit conservation, and the headline
//! largest-servable-network scaling factor vs one chip (the measured
//! form of the paper's "extended off-chip high-level router nodes"
//! claim at serving granularity).
//!
//! Emits `BENCH_cluster.json` (schema `bench-cluster-v1`) in the
//! working directory and gates against a checked-in
//! `BENCH_cluster.baseline.json` (working directory, then the
//! repository root), failing the process on a >30 % regression or a
//! structural-floor violation (scaling < 4×, a multi-chip point with no
//! ring traffic, broken conservation). Controls:
//!
//! - `FSOC_BENCH_FAST=1` — CI smoke budget;
//! - `FSOC_CLUSTER_BASELINE=<path>` — explicit baseline location;
//! - `FSOC_CLUSTER_SKIP_CHECK=1` — emit JSON only, no gate.

use fullerene_soc::benches_support::{cluster_perf, cluster_perf_check, cluster_perf_json};
use fullerene_soc::metrics::Table;
use fullerene_soc::util::json::Json;
use std::path::{Path, PathBuf};

fn baseline_path() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("FSOC_CLUSTER_BASELINE") {
        return Some(PathBuf::from(p));
    }
    for p in [
        "BENCH_cluster.baseline.json",
        "../BENCH_cluster.baseline.json",
    ] {
        let p = Path::new(p);
        if p.exists() {
            return Some(p.to_path_buf());
        }
    }
    None
}

fn main() {
    let fast = std::env::var("FSOC_BENCH_FAST").is_ok_and(|v| v == "1");
    let p = cluster_perf(42, fast).expect("cluster sweep must build and drain");

    let mut t = Table::new(&[
        "chips",
        "hidden",
        "neurons",
        "shards",
        "cut",
        "sessions/s",
        "L3 flits",
        "L3 flits/s",
        "conserved",
    ]);
    for c in &p.cases {
        t.push_row(vec![
            c.chips.to_string(),
            c.hidden_layers.to_string(),
            c.neurons.to_string(),
            c.shards.to_string(),
            c.cut_neurons.to_string(),
            format!("{:.1}", c.sessions_per_s),
            c.interchip_flits.to_string(),
            format!("{:.0}", c.interchip_flits_per_s),
            c.conservation_holds.to_string(),
        ]);
    }
    println!("## bench: cluster\n{}", t.render());
    println!(
        "largest-servable-network scaling: {:.2}x at {} chips",
        p.scaling_factor,
        p.cases.last().map_or(0, |c| c.chips)
    );

    let out = Path::new("BENCH_cluster.json");
    cluster_perf_json(&p, "measured")
        .write_file(out)
        .expect("write BENCH_cluster.json");
    println!("wrote {}", out.display());

    if std::env::var("FSOC_CLUSTER_SKIP_CHECK").is_ok_and(|v| v == "1") {
        println!("baseline check skipped (FSOC_CLUSTER_SKIP_CHECK=1)");
        return;
    }
    match baseline_path() {
        None => {
            // The structural floors hold without any baseline — enforce
            // them with an empty one rather than skipping outright.
            let fails = cluster_perf_check(&p, &Json::obj(vec![]), 0.30);
            if fails.is_empty() {
                println!("no BENCH_cluster.baseline.json found; structural floors passed");
            } else {
                eprintln!("CLUSTER FLOOR VIOLATION:");
                for f in &fails {
                    eprintln!("  - {f}");
                }
                std::process::exit(1);
            }
        }
        Some(path) => {
            let baseline = Json::read_file(&path).expect("parse baseline");
            let fails = cluster_perf_check(&p, &baseline, 0.30);
            if fails.is_empty() {
                println!("baseline check vs {} passed", path.display());
            } else {
                eprintln!("CLUSTER REGRESSION vs {}:", path.display());
                for f in &fails {
                    eprintln!("  - {f}");
                }
                std::process::exit(1);
            }
        }
    }
}
