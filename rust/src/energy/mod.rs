//! Calibrated 55 nm event-energy / area / power model.
//!
//! The paper reports silicon measurements; we substitute an **event-driven
//! energy model**: every architectural event the cycle simulator produces
//! (synapse op, zero-skip, membrane-potential update, router hop, cache
//! access, CPU instruction, …) is charged a per-event energy constant, and
//! static/clock power is charged per active (non-gated) cycle. The
//! constants in [`constants`] are calibrated so the model reproduces the
//! paper's reported anchor points (0.627 pJ/SOP best core energy,
//! 0.026 pJ/hop P2P, 0.434 mW CPU average, 2.8 mW chip floor); all
//! *derived* quantities — curve shapes, crossovers, ratios against the
//! baselines — come out of simulated event counts, not hard-coding.
//!
//! Supply-voltage scaling: dynamic event energies scale with `(V/V_NOM)²`,
//! static power with `V/V_NOM` (a standard first-order CMOS model); the
//! paper operates the chip at 1.08–1.32 V.

pub mod area;
pub mod constants;
pub mod model;
pub mod report;

pub use area::AreaModel;
pub use constants::EnergyParams;
pub use model::{EnergyBreakdown, EnergyLedger, EventClass};
pub use report::ChipReport;
