//! **End-to-end driver** (the repo's headline validation): load the
//! *trained, quantized* networks produced by `make artifacts`, run the
//! exported held-out test sets through the full SoC simulator (cores +
//! fullerene NoC + RISC-V firmware), cross-check every sample against the
//! AOT-compiled XLA golden model, and print the Table-I row per dataset:
//! accuracy, pJ/SOP, power, power density, latency.
//!
//! ```bash
//! make artifacts            # trains + exports (once)
//! cargo run --release --example edge_inference
//! cargo run --release --example edge_inference -- --samples 20 --no-xla
//! ```
//!
//! The measured numbers land in EXPERIMENTS.md §Table-I.

use fullerene_soc::coordinator::GoldenCheck;
use fullerene_soc::datasets::Dataset;
use fullerene_soc::energy::ChipReport;
use fullerene_soc::nn::load_weights_json;
use fullerene_soc::serve::SocBuilder;
use fullerene_soc::util::cli::Args;
use fullerene_soc::{Error, Result};
use std::path::PathBuf;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let limit: usize = args.get_parse_or("samples", 50);
    let use_xla = !args.flag("no-xla");

    let mut reports = Vec::new();
    for name in ["nmnist", "dvsgesture", "cifar10"] {
        let weights = artifacts.join(format!("{name}.weights.json"));
        let dataset = artifacts.join(format!("dataset_{name}.json"));
        if !weights.exists() || !dataset.exists() {
            eprintln!("[{name}] artifacts missing — run `make artifacts` first; skipping");
            continue;
        }
        let net = load_weights_json(&weights)?;
        let ds = Dataset::load_json(&dataset)?;
        println!(
            "[{name}] {} synapses, T={}, {} test samples (running {})",
            net.total_synapses(),
            net.timesteps,
            ds.samples.len(),
            ds.samples.len().min(limit)
        );
        let check = if use_xla { GoldenCheck::Both } else { GoldenCheck::Reference };
        let runner = SocBuilder::new()
            .check(check)
            .artifacts(artifacts.clone())
            .limit(limit)
            .build_runner(net)?;
        let out = runner.run(&ds)?;
        println!(
            "[{name}] golden check: {} checks, {} mismatches {}",
            out.checked,
            out.mismatches,
            if out.mismatches == 0 { "✓" } else { "✗ DIVERGENCE" }
        );
        if out.mismatches > 0 {
            return Err(Error::Runtime(format!(
                "{name}: cycle simulator diverged from the golden model"
            )));
        }
        reports.push(out.report);
    }
    if reports.is_empty() {
        return Err(Error::Artifact(
            "no artifacts found — run `make artifacts`".into(),
        ));
    }
    println!("\n=== Table I (reproduced) ===\n{}", ChipReport::table(&reports).render());
    Ok(())
}
