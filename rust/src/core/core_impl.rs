//! [`NeuroCore`]: the assembled neuromorphic core — register table,
//! ping-pong spike cache, ZSPE→SPE pipeline, neuron updater, clock gating
//! and energy accounting.

use super::cache::PingPong;
use super::codebook::Codebook;
use super::neuron::{NeuronArray, NeuronParams};
use super::pipeline::{self, PipelineStats};
use super::regtable::RegTable;
use super::spe::{AccumCtx, Spe};
use super::synapses::Synapses;
use crate::energy::{EnergyLedger, EnergyParams, EventClass};
use crate::Result;


/// Depth of the ZSPE→SPE job queue (hardware buffer slots).
pub const SPE_QUEUE_DEPTH: usize = 8;

/// Statistics for one core timestep.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CoreStats {
    /// Accumulation-phase pipeline stats.
    pub pipeline: PipelineStats,
    /// Neurons read-modified-written by the updater (partial update:
    /// touched only).
    pub neurons_updated: u64,
    /// Output spikes fired.
    pub spikes_fired: u64,
    /// Total cycles for the timestep (accumulation + updater drain).
    pub cycles: u64,
}

/// Output of one core timestep.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TimestepOutput {
    /// Neuron ids that fired this timestep (ascending).
    pub spikes: Vec<u32>,
    /// Timestep statistics.
    pub stats: CoreStats,
}

/// A neuromorphic core instance.
#[derive(Debug, Clone)]
pub struct NeuroCore {
    regs: RegTable,
    codebook: Codebook,
    synapses: Synapses,
    neurons: NeuronArray,
    spike_cache: PingPong<u16>,
    spe: Spe,
    // Scratch (reused across timesteps; cleared via the touched list).
    acc: Vec<i32>,
    touched: Vec<bool>,
    touched_list: Vec<u32>,
    /// Reusable staging scratch (one spike-word bank), so per-timestep
    /// staging allocates nothing on the hot path.
    stage_scratch: Vec<u16>,
    /// Spike words have been staged since the last consumed timestep —
    /// the activity signal the SoC worklist schedules ticks from.
    pending_input: bool,
    ledger: EnergyLedger,
    energy: EnergyParams,
    total_cycles: u64,
    gated_cycles: u64,
    /// Static-ledger key, precomputed once (the hot `finish_window` path
    /// must not rebuild it per window).
    static_label: String,
}

impl NeuroCore {
    /// Assemble a core. `synapses.axons()` must match `axons`.
    pub fn new(
        core_id: u8,
        axons: usize,
        neurons: usize,
        neuron_params: NeuronParams,
        codebook: Codebook,
        synapses: Synapses,
        energy: EnergyParams,
    ) -> Result<Self> {
        let regs = RegTable::new(core_id, axons, neurons, neuron_params.clone(), &codebook)?;
        if synapses.axons() != axons {
            return Err(crate::Error::Core(format!(
                "synapse table covers {} axons, core has {}",
                synapses.axons(),
                axons
            )));
        }
        let words = regs.spike_words();
        Ok(NeuroCore {
            regs,
            codebook,
            synapses,
            neurons: NeuronArray::new(neurons, neuron_params),
            spike_cache: PingPong::new(words),
            spe: Spe::new(SPE_QUEUE_DEPTH),
            acc: vec![0; neurons],
            touched: vec![false; neurons],
            touched_list: Vec::with_capacity(neurons),
            stage_scratch: vec![0; words],
            pending_input: false,
            ledger: EnergyLedger::new(),
            energy,
            total_cycles: 0,
            gated_cycles: 0,
            static_label: format!("core{core_id}"),
        })
    }

    /// Register table (read/write: enable bit etc.).
    pub fn regs(&self) -> &RegTable {
        &self.regs
    }

    /// Set the clock-gate enable bit.
    pub fn set_enabled(&mut self, on: bool) {
        self.regs.enabled = on;
    }

    /// The core's neuron array (golden-model comparison, MPDMA).
    pub fn neurons(&self) -> &NeuronArray {
        &self.neurons
    }

    /// Mutable neuron array (MPDMA restore).
    pub fn neurons_mut(&mut self) -> &mut NeuronArray {
        &mut self.neurons
    }

    /// The core's synapse table.
    pub fn synapses(&self) -> &Synapses {
        &self.synapses
    }

    /// The core's codebook.
    pub fn codebook(&self) -> &Codebook {
        &self.codebook
    }

    /// Stage input spikes (axon ids) for the *next* timestep into the
    /// shadow bank of the ping-pong spike cache. Out-of-range axons are an
    /// error at debug level and ignored in release (hardware would drop).
    ///
    /// Staging **OR-merges**: a core that receives spikes from several
    /// sources within one timestep (IDMA input plus routed spikes, or
    /// several upstream layers) accumulates the union — a second staging
    /// no longer silently drops the first. The merged bank is consumed
    /// (and cleared) by the next non-gated [`Self::tick_timestep`].
    pub fn stage_input_spikes(&mut self, axons: &[u32]) {
        // Packs into the reusable scratch sized to the highest staged
        // word, so sparse staging costs O(activity), not O(core width);
        // the merge leaves words beyond the scratch untouched (zero).
        super::pack_spikes_into(axons, self.regs.axons, &mut self.stage_scratch);
        self.spike_cache.merge_shadow(&self.stage_scratch);
        self.pending_input = true;
    }

    /// Stage a full boolean spike vector for the next timestep
    /// (OR-merging, like [`Self::stage_input_spikes`]).
    pub fn stage_input_vector(&mut self, spikes: &[bool]) {
        debug_assert!(spikes.len() <= self.regs.axons);
        let n = spikes.len().min(self.regs.axons);
        super::pack_spike_vector_into(&spikes[..n], &mut self.stage_scratch);
        self.spike_cache.merge_shadow(&self.stage_scratch);
        self.pending_input = true;
    }

    /// True when spike words have been staged since the last consumed
    /// timestep. The SoC scheduler ticks only cores with pending input —
    /// an idle core costs zero active cycles.
    pub fn pending_input(&self) -> bool {
        self.pending_input
    }

    /// Execute one timestep: swap the ping-pong cache, run the pipeline
    /// over the (now active) spike bank, drain the updater, fire spikes.
    ///
    /// When the core is clock-gated (enable bit off) the timestep costs
    /// zero cycles of active power and produces no spikes.
    pub fn tick_timestep(&mut self) -> TimestepOutput {
        if !self.regs.enabled {
            // Clock-gated: account a nominal gated cycle so leakage is
            // integrated by the caller via `finish_window`.
            return TimestepOutput::default();
        }
        self.pending_input = false;
        self.spike_cache.swap();

        // ---- stages 1–3: accumulate -------------------------------------
        // The pipeline reads the active bank by borrow (no per-timestep
        // copy); disjoint-field borrows keep the SPE/scratch mutable.
        let mut ctx = AccumCtx {
            acc: &mut self.acc,
            touched: &mut self.touched,
            touched_list: &mut self.touched_list,
        };
        let pstats = pipeline::run_accumulation(
            self.spike_cache.active_bank(),
            self.regs.axons,
            &self.synapses,
            &self.codebook,
            &mut self.spe,
            &mut ctx,
        );
        // Consume-on-read: a timestep without fresh staging must see an
        // empty cache, not a replay of two timesteps ago.
        self.spike_cache.clear_active();

        // ---- stage 4: partial neuron update (touched only) ---------------
        self.touched_list.sort_unstable();
        let mut spikes = Vec::new();
        for &t in self.touched_list.iter() {
            if self.neurons.update_one(t as usize, self.acc[t as usize]) {
                spikes.push(t);
            }
        }
        let neurons_updated = self.touched_list.len() as u64;
        let update_cycles = neurons_updated; // 1 neuron / cycle drain
        // clear scratch via the touched list (O(touched), not O(neurons))
        for &t in self.touched_list.iter() {
            self.acc[t as usize] = 0;
            self.touched[t as usize] = false;
        }
        self.touched_list.clear();

        // ---- energy -------------------------------------------------------
        let cycles = pstats.cycles + update_cycles;
        self.ledger.add(EventClass::CacheRead, pstats.words_read);
        self.ledger.add(EventClass::ZspeWord, pstats.words_scanned);
        self.ledger
            .add(EventClass::ZspeForward, pstats.spikes_forwarded);
        self.ledger.add(EventClass::ZeroSkip, pstats.zeros_skipped);
        self.ledger.add(EventClass::Sop, pstats.sops);
        self.ledger.add(EventClass::MpUpdate, neurons_updated);
        self.ledger
            .add(EventClass::SpikeFire, spikes.len() as u64);
        self.total_cycles += cycles;

        TimestepOutput {
            stats: CoreStats {
                pipeline: pstats,
                neurons_updated,
                spikes_fired: spikes.len() as u64,
                cycles,
            },
            spikes,
        }
    }

    /// Charge spike-cache write energy for `words` staged words (the DMA /
    /// NoC receiver calls this when it fills the shadow bank).
    pub fn charge_cache_writes(&mut self, words: u64) {
        self.ledger.add(EventClass::CacheWrite, words);
    }

    /// Charge spike-cache write energy for staging `spikes` spike events,
    /// packed at [`super::SPIKE_WORD_BITS`] spikes per cache word. The one
    /// place the pack width enters staging energy accounting — callers
    /// must not hand-roll the word math (a word-width change would desync
    /// the ledger).
    pub fn charge_spike_writes(&mut self, spikes: usize) {
        self.charge_cache_writes(spikes.div_ceil(super::SPIKE_WORD_BITS) as u64);
    }

    /// Account a window of `window_cycles` wall cycles: the core was
    /// active for its recorded busy cycles and gated for the rest.
    ///
    /// Busy cycles beyond the window are **carried into the next window**
    /// rather than silently truncated, so a busy core's total active
    /// cycles are conserved across windows however the caller slices
    /// them.
    pub fn finish_window(&mut self, window_cycles: u64) {
        let active = self.total_cycles.min(window_cycles);
        let gated = window_cycles - active;
        self.gated_cycles += gated;
        self.ledger.add_static(
            &self.static_label,
            active,
            gated,
            self.energy.p_core_active,
            self.energy.p_core_gated,
        );
        self.total_cycles -= active;
    }

    /// Busy cycles since the last `finish_window`.
    pub fn busy_cycles(&self) -> u64 {
        self.total_cycles
    }

    /// The core's precomputed static-ledger key (`core<id>`). Callers
    /// charging this core's static power into their own ledger (the SoC's
    /// per-snapshot report) must use this instead of rebuilding the
    /// string per call.
    pub fn static_label(&self) -> &str {
        &self.static_label
    }

    /// Drop accumulated energy/cycle accounting (ledger, busy/gated
    /// counters), keeping configuration and dynamic neuron state. Used
    /// when a chip is reused for a fresh accounting window (a new
    /// serving session).
    pub fn reset_accounting(&mut self) {
        self.ledger = EnergyLedger::new();
        self.total_cycles = 0;
        self.gated_cycles = 0;
    }

    /// Read (and keep) the core's energy ledger.
    pub fn ledger(&self) -> &EnergyLedger {
        &self.ledger
    }

    /// Drain the ledger (merge into a chip-level ledger).
    pub fn take_ledger(&mut self) -> EnergyLedger {
        std::mem::take(&mut self.ledger)
    }

    /// Reset dynamic state (MPs, caches) keeping configuration.
    pub fn reset_state(&mut self) {
        self.neurons.reset_all();
        let words = self.regs.spike_words();
        self.spike_cache = PingPong::new(words);
        self.spe = Spe::new(SPE_QUEUE_DEPTH);
        self.acc.iter_mut().for_each(|a| *a = 0);
        self.touched.iter_mut().for_each(|t| *t = false);
        self.touched_list.clear();
        self.pending_input = false;
    }
}

impl super::CoreEngine for NeuroCore {
    fn stage_input_spikes(&mut self, axons: &[u32]) {
        NeuroCore::stage_input_spikes(self, axons);
    }
    fn stage_input_vector(&mut self, spikes: &[bool]) {
        NeuroCore::stage_input_vector(self, spikes);
    }
    fn tick_timestep(&mut self) -> TimestepOutput {
        NeuroCore::tick_timestep(self)
    }
    fn finish_window(&mut self, window_cycles: u64) {
        NeuroCore::finish_window(self, window_cycles);
    }
    fn busy_cycles(&self) -> u64 {
        NeuroCore::busy_cycles(self)
    }
    fn ledger(&self) -> &EnergyLedger {
        NeuroCore::ledger(self)
    }
    fn mps(&self) -> &[i32] {
        self.neurons.mps()
    }
    fn set_enabled(&mut self, on: bool) {
        NeuroCore::set_enabled(self, on);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::neuron::{LeakMode, ResetMode};
    use crate::core::synapses::SynapsesBuilder;

    fn small_core() -> NeuroCore {
        let cb = Codebook::default_log16();
        let mut b = SynapsesBuilder::new(32, 8, cb.n());
        // every axon connects to every neuron with weight index 12 (=14)
        b.connect_dense(|_, _| 12).unwrap();
        NeuroCore::new(
            3,
            32,
            8,
            NeuronParams {
                threshold: 50,
                leak: LeakMode::None,
                reset: ResetMode::Subtract,
                mp_bits: 16,
            },
            cb,
            b.build(),
            EnergyParams::nominal(),
        )
        .unwrap()
    }

    #[test]
    fn spikes_accumulate_and_fire() {
        let mut c = small_core();
        // 4 spikes × weight 14 = 56 ≥ 50 → every neuron fires, residue 6.
        c.stage_input_spikes(&[0, 5, 16, 31]);
        let out = c.tick_timestep();
        assert_eq!(out.spikes, (0..8).collect::<Vec<u32>>());
        assert_eq!(out.stats.pipeline.sops, 4 * 8);
        assert_eq!(out.stats.neurons_updated, 8);
        assert!(c.neurons().mps().iter().all(|&m| m == 6));
    }

    #[test]
    fn no_input_means_no_update_partial_semantics() {
        let mut c = small_core();
        c.stage_input_spikes(&[0]); // 1 spike → acc 14 < 50
        let o1 = c.tick_timestep();
        assert!(o1.spikes.is_empty());
        assert!(c.neurons().mps().iter().all(|&m| m == 14));
        // Empty timestep: partial update leaves MP untouched (no leak).
        c.stage_input_spikes(&[]);
        let o2 = c.tick_timestep();
        assert_eq!(o2.stats.neurons_updated, 0);
        assert!(c.neurons().mps().iter().all(|&m| m == 14));
    }

    #[test]
    fn gated_core_does_nothing() {
        let mut c = small_core();
        c.stage_input_spikes(&[0, 1, 2, 3]);
        c.set_enabled(false);
        let out = c.tick_timestep();
        assert!(out.spikes.is_empty());
        assert_eq!(out.stats.cycles, 0);
    }

    #[test]
    fn ledger_counts_match_stats() {
        let mut c = small_core();
        c.stage_input_spikes(&[1, 2]);
        let out = c.tick_timestep();
        assert_eq!(c.ledger().count(EventClass::Sop), out.stats.pipeline.sops);
        assert_eq!(
            c.ledger().count(EventClass::ZeroSkip),
            out.stats.pipeline.zeros_skipped
        );
        assert_eq!(c.ledger().count(EventClass::MpUpdate), 8);
    }

    #[test]
    fn ping_pong_staging_applies_next_timestep_only() {
        let mut c = small_core();
        c.stage_input_spikes(&[0, 1, 2, 3]); // for t=0
        let o0 = c.tick_timestep();
        assert_eq!(o0.spikes.len(), 8);
        // nothing staged for t=1 → no work
        let o1 = c.tick_timestep();
        assert_eq!(o1.stats.pipeline.spikes_forwarded, 0);
    }

    #[test]
    fn reset_state_clears_mps() {
        let mut c = small_core();
        c.stage_input_spikes(&[0]);
        c.tick_timestep();
        assert!(c.neurons().mps().iter().any(|&m| m != 0));
        c.reset_state();
        assert!(c.neurons().mps().iter().all(|&m| m == 0));
    }

    #[test]
    fn finish_window_accounts_static_split() {
        let mut c = small_core();
        c.stage_input_spikes(&[0, 1]);
        c.tick_timestep();
        let busy = c.busy_cycles();
        assert!(busy > 0);
        c.finish_window(1000);
        assert_eq!(c.busy_cycles(), 0);
        let pj = c.ledger().static_pj(200.0e6);
        assert!(pj > 0.0);
    }

    #[test]
    fn finish_window_carries_overflow_and_conserves_active_cycles() {
        let mut c = small_core();
        c.stage_input_spikes(&[0, 1, 2, 3]);
        c.tick_timestep();
        let busy = c.busy_cycles();
        assert!(busy > 1, "need a multi-cycle timestep for the split");
        let mut split = c.clone();
        // One window covering everything: active = busy, gated = 0.
        c.finish_window(busy);
        assert_eq!(c.busy_cycles(), 0);
        // Two windows whose first is too short: the overflow must carry
        // (the old code dropped it), and the summed static energy must
        // equal the single-window accounting bit for bit.
        let w1 = busy / 2;
        split.finish_window(w1);
        assert_eq!(split.busy_cycles(), busy - w1, "overflow must carry");
        split.finish_window(busy - w1);
        assert_eq!(split.busy_cycles(), 0);
        let f = 200.0e6;
        assert_eq!(
            c.ledger().static_pj(f).to_bits(),
            split.ledger().static_pj(f).to_bits(),
            "active cycles not conserved across windows"
        );
    }

    #[test]
    fn multi_source_staging_or_merges() {
        // Two sources in one timestep (IDMA input + routed spikes): the
        // union must be consumed. weight(12) = 14; 8 spikes × 14 = 112.
        let mut c = small_core(); // threshold 50
        c.stage_input_spikes(&[0, 5, 16, 31]);
        c.stage_input_spikes(&[1, 6, 17, 30]);
        assert!(c.pending_input());
        let out = c.tick_timestep();
        assert!(!c.pending_input(), "tick consumes the staged words");
        assert_eq!(out.stats.pipeline.spikes_forwarded, 8);
        assert_eq!(out.stats.pipeline.sops, 8 * 8);
        // 112 ≥ 50 → fire, residue 62 ≥ 50 would need a second threshold:
        // subtract-reset leaves 112 - 50 = 62.
        assert_eq!(out.spikes, (0..8).collect::<Vec<u32>>());
        assert!(c.neurons().mps().iter().all(|&m| m == 62));
    }

    #[test]
    fn overlapping_stagings_or_not_add() {
        // The same axon staged twice is ONE spike (bit OR), not two.
        let mut c = small_core();
        c.stage_input_spikes(&[0, 1]);
        c.stage_input_spikes(&[1, 2]);
        let out = c.tick_timestep();
        assert_eq!(out.stats.pipeline.spikes_forwarded, 3);
        // 3 spikes × weight 14 = 42 < 50: no fire.
        assert!(out.spikes.is_empty());
        assert!(c.neurons().mps().iter().all(|&m| m == 42));
    }

    #[test]
    fn pending_input_tracks_staging_and_gating() {
        let mut c = small_core();
        assert!(!c.pending_input());
        c.stage_input_spikes(&[0]);
        assert!(c.pending_input());
        // A gated tick must not consume (nor clear) the staged words.
        c.set_enabled(false);
        c.tick_timestep();
        assert!(c.pending_input(), "gated tick must keep input pending");
        c.set_enabled(true);
        let out = c.tick_timestep();
        assert_eq!(out.stats.pipeline.spikes_forwarded, 1);
        assert!(!c.pending_input());
        // reset_state clears pending staging.
        c.stage_input_spikes(&[2]);
        c.reset_state();
        assert!(!c.pending_input());
        let out = c.tick_timestep();
        assert_eq!(out.stats.pipeline.spikes_forwarded, 0);
    }
}
