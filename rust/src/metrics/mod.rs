//! Counters and plain-text table rendering used by benches and the CLI.

pub mod counters;
pub mod table;

pub use counters::Counters;
pub use table::Table;
