//! Minimal fixed-width table renderer for bench/CLI output.
//!
//! Keeps bench output diff-able: every figure/table reproduction prints
//! through this, so `bench_output.txt` is stable and greppable.

/// A simple column-oriented text table.
#[derive(Debug, Clone)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with initial headers.
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a column header (cells are filled by subsequent `row` calls).
    pub fn add_column(&mut self, name: &str) {
        self.headers.push(name.to_string());
    }

    /// Append a row: label + one cell per non-label column.
    pub fn row<I, S>(&mut self, label: &str, cells: I)
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut r = vec![label.to_string()];
        r.extend(cells.into_iter().map(Into::into));
        self.rows.push(r);
    }

    /// Append a row from pre-built cells (must match header count).
    pub fn push_row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when the table has no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to an aligned plain-text string.
    pub fn render(&self) -> String {
        let ncols = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.headers.len()))
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncols];
        let measure = |widths: &mut Vec<usize>, row: &[String]| {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        };
        measure(&mut widths, &self.headers);
        for r in &self.rows {
            measure(&mut widths, r);
        }
        let fmt_row = |row: &[String]| {
            let mut s = String::new();
            for (i, w) in widths.iter().enumerate() {
                let cell = row.get(i).map(String::as_str).unwrap_or("");
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{cell:<w$}"));
            }
            s.trim_end().to_string()
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols.saturating_sub(1))));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["metric", "a", "b"]);
        t.row("x", ["1", "22"]);
        t.row("longer", ["333", "4"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("metric"));
        assert!(lines[2].starts_with("x"));
    }

    #[test]
    fn row_len_tracking() {
        let mut t = Table::new(&["m"]);
        assert!(t.is_empty());
        t.row("r", Vec::<String>::new());
        assert_eq!(t.len(), 1);
    }
}
