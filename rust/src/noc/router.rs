//! The multi-mode connection-matrix router (CMRouter, paper §II.B).
//!
//! Structure (paper): "independent input and output buffers, a register
//! table, a link controller, a channel arbiter, a reconfigurable
//! connection matrix, and a clock gating unit. […] The connection matrix
//! records all routing links among neighbor cores utilizing only
//! `Nc × Nc × Wcid` bits (Nc = 5 neighbor cores, Wcid = 5-bit core id)."
//!
//! Model: per-port input/output FIFOs; each cycle the **channel arbiter**
//! matches input heads to output ports (round-robin priority, one flit per
//! output per cycle) subject to the **connection matrix** (a reconfigurable
//! `in × out` permission table — the bit-exact hardware budget is
//! `Nc·Nc·Wcid = 125` bits, checked in tests); the **link controller**
//! hangs an input up when the flit's timestep tag is out of sync with the
//! router's current timestep or when the chosen output is full
//! (backpressure). A clock-gated router does nothing and burns only
//! leakage.
//!
//! The same switch structure is instantiated at core nodes (their NoC
//! interface); only router nodes count as "hops" in latency/energy
//! accounting, matching the paper's hop definition.

use super::packet::Flit;
use super::topology::NodeId;
use std::collections::VecDeque;

/// Default per-port FIFO depth (flits).
pub const DEFAULT_BUF_DEPTH: usize = 4;

/// Why an input port made no progress this cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stall {
    /// No flit waiting.
    Empty,
    /// Output buffer full (backpressure hang-up).
    Backpressure,
    /// Timestep tag mismatch (link controller hang-up).
    TimestepSync,
    /// Connection matrix forbids the in→out link.
    MatrixBlocked,
    /// Lost round-robin arbitration this cycle.
    Arbitration,
}

/// One CMRouter / node switch.
#[derive(Debug, Clone)]
pub struct CmRouter {
    /// The node this switch lives at.
    pub node: NodeId,
    /// Neighbor node per port (port i ↔ `ports[i]`).
    ports: Vec<NodeId>,
    in_buf: Vec<VecDeque<Flit>>,
    out_buf: Vec<VecDeque<Flit>>,
    depth: usize,
    /// Total flits across input FIFOs (kept incrementally so the
    /// simulator's active-switch scheduling reads occupancy in O(1)).
    in_occ: usize,
    /// Total flits across output FIFOs.
    out_occ: usize,
    /// Reconfigurable connection matrix: `allow[in][out]`.
    allow: Vec<Vec<bool>>,
    /// Round-robin arbiter cursor (per output port).
    rr: Vec<usize>,
    /// Current timestep (link controller sync reference).
    pub timestep: u32,
    /// Clock-gate enable.
    pub enabled: bool,
    // --- statistics -----------------------------------------------------
    /// Flits switched in→out.
    pub switched: u64,
    /// Stall events by cause (empty excluded).
    pub stalls_backpressure: u64,
    /// Timestep-sync hang-ups.
    pub stalls_timestep: u64,
    /// Matrix-blocked events.
    pub stalls_matrix: u64,
    /// Cycles with any activity (for clock gating accounting).
    pub active_cycles: u64,
}

impl CmRouter {
    /// New switch with one port per neighbor.
    pub fn new(node: NodeId, neighbors: &[NodeId], depth: usize) -> Self {
        let p = neighbors.len();
        CmRouter {
            node,
            ports: neighbors.to_vec(),
            in_buf: (0..p).map(|_| VecDeque::with_capacity(depth)).collect(),
            out_buf: (0..p).map(|_| VecDeque::with_capacity(depth)).collect(),
            depth,
            in_occ: 0,
            out_occ: 0,
            allow: vec![vec![true; p]; p],
            rr: vec![0; p],
            timestep: 0,
            enabled: true,
            switched: 0,
            stalls_backpressure: 0,
            stalls_timestep: 0,
            stalls_matrix: 0,
            active_cycles: 0,
        }
    }

    /// Number of ports.
    pub fn port_count(&self) -> usize {
        self.ports.len()
    }

    /// Port index toward neighbor `n`.
    pub fn port_to(&self, n: NodeId) -> Option<usize> {
        self.ports.iter().position(|&p| p == n)
    }

    /// Neighbor on a port.
    pub fn neighbor(&self, port: usize) -> NodeId {
        self.ports[port]
    }

    /// Reconfigure the connection matrix (register-table write).
    pub fn set_allow(&mut self, in_port: usize, out_port: usize, on: bool) {
        self.allow[in_port][out_port] = on;
    }

    /// Hardware storage of the connection matrix in bits:
    /// `Nc × Nc × Wcid` (the paper's budget; Wcid = 5).
    pub fn matrix_storage_bits(&self) -> usize {
        self.ports.len() * self.ports.len() * 5
    }

    /// True if input FIFO `port` has room.
    pub fn can_accept(&self, port: usize) -> bool {
        self.in_buf[port].len() < self.depth
    }

    /// Push an arriving flit into input FIFO `port` (link stage).
    /// Returns false (and drops nothing — caller retries) when full.
    pub fn accept(&mut self, port: usize, flit: Flit) -> bool {
        if self.in_buf[port].len() >= self.depth {
            return false;
        }
        self.in_buf[port].push_back(flit);
        self.in_occ += 1;
        true
    }

    /// Peek the head of an input FIFO.
    pub fn in_head(&self, port: usize) -> Option<&Flit> {
        self.in_buf[port].front()
    }

    /// Peek the head of an output FIFO.
    pub fn out_head(&self, port: usize) -> Option<&Flit> {
        self.out_buf[port].front()
    }

    /// Pop the head of an output FIFO (link stage moved it).
    pub fn out_pop(&mut self, port: usize) -> Option<Flit> {
        let f = self.out_buf[port].pop_front();
        if f.is_some() {
            self.out_occ -= 1;
        }
        f
    }

    /// Pop the head of an input FIFO. Only the fault-injection path uses
    /// this (draining a killed router, discarding unroutable heads) —
    /// normal forwarding always goes through [`CmRouter::arbitrate`].
    pub fn in_pop(&mut self, port: usize) -> Option<Flit> {
        let f = self.in_buf[port].pop_front();
        if f.is_some() {
            self.in_occ -= 1;
        }
        f
    }

    /// Occupancy across all input FIFOs (O(1): kept incrementally).
    pub fn in_occupancy(&self) -> usize {
        self.in_occ
    }

    /// Occupancy across all output FIFOs (O(1): kept incrementally).
    pub fn out_occupancy(&self) -> usize {
        self.out_occ
    }

    /// One arbitration cycle: for each output port pick (round-robin over
    /// input ports) one eligible head flit and switch it. `route` maps a
    /// flit to its desired output port. Returns flits switched this cycle.
    pub fn arbitrate(&mut self, route: impl Fn(&Flit) -> Option<usize>) -> u32 {
        if !self.enabled {
            return 0;
        }
        // Hot-path early-out: an idle switch does no work (and allocates
        // nothing) this cycle.
        if self.in_occ == 0 {
            return 0;
        }
        let p = self.ports.len();
        let mut moved = 0;
        // Pre-compute desired output of each input head.
        let mut want: Vec<Option<usize>> = Vec::with_capacity(p);
        for i in 0..p {
            want.push(self.in_buf[i].front().and_then(|f| {
                if f.timestep != self.timestep {
                    None // link-controller hang-up; counted below
                } else {
                    route(f)
                }
            }));
            if let Some(f) = self.in_buf[i].front() {
                if f.timestep != self.timestep {
                    self.stalls_timestep += 1;
                }
            }
        }
        for out in 0..p {
            if self.out_buf[out].len() >= self.depth {
                // Output full: anyone wanting it is back-pressured.
                for w in want.iter().flatten() {
                    if *w == out {
                        self.stalls_backpressure += 1;
                    }
                }
                continue;
            }
            // Round-robin from rr[out].
            let start = self.rr[out];
            let mut granted = None;
            for k in 0..p {
                let i = (start + k) % p;
                if want[i] == Some(out) {
                    if !self.allow[i][out] {
                        self.stalls_matrix += 1;
                        continue;
                    }
                    granted = Some(i);
                    break;
                }
            }
            if let Some(i) = granted {
                let flit = self.in_buf[i].pop_front().expect("head exists");
                self.in_occ -= 1;
                self.out_buf[out].push_back(flit);
                self.out_occ += 1;
                want[i] = None;
                self.rr[out] = (i + 1) % p;
                self.switched += 1;
                moved += 1;
            }
        }
        if moved > 0 {
            self.active_cycles += 1;
        }
        moved
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::packet::TxMode;

    fn flit(id: u64, dst: usize, ts: u32) -> Flit {
        Flit {
            id,
            src_core: 0,
            dst_core: dst,
            mode: TxMode::P2p,
            axon: 0,
            timestep: ts,
            injected_at: 0,
            hops: 0,
            at: 0,
        }
    }

    #[test]
    fn matrix_budget_matches_paper() {
        let r = CmRouter::new(0, &[1, 2, 3, 4, 5], 4);
        assert_eq!(r.matrix_storage_bits(), 125); // 5×5×5 bits
    }

    #[test]
    fn switches_one_flit_per_output_per_cycle() {
        let mut r = CmRouter::new(0, &[10, 11], 4);
        r.accept(0, flit(1, 7, 0));
        r.accept(0, flit(2, 7, 0));
        // Both want output port 1.
        let moved = r.arbitrate(|_| Some(1));
        assert_eq!(moved, 1);
        assert_eq!(r.out_head(1).unwrap().id, 1);
        let moved = r.arbitrate(|_| Some(1));
        assert_eq!(moved, 1);
    }

    #[test]
    fn round_robin_alternates_inputs() {
        let mut r = CmRouter::new(0, &[10, 11, 12], 8);
        for i in 0..3 {
            r.accept(0, flit(i, 0, 0));
            r.accept(1, flit(100 + i, 0, 0));
        }
        let mut order = Vec::new();
        for _ in 0..6 {
            r.arbitrate(|_| Some(2));
            order.push(r.out_pop(2).unwrap().id);
        }
        // Inputs 0 and 1 must interleave, not starve.
        assert!(order.windows(2).any(|w| w[0] < 100 && w[1] >= 100));
        assert!(order.iter().filter(|&&i| i < 100).count() == 3);
    }

    #[test]
    fn backpressure_hangs_up_input() {
        let mut r = CmRouter::new(0, &[10, 11], 1);
        r.accept(0, flit(1, 0, 0));
        r.arbitrate(|_| Some(1)); // fills out_buf[1] (depth 1)
        r.accept(0, flit(2, 0, 0));
        let moved = r.arbitrate(|_| Some(1));
        assert_eq!(moved, 0);
        assert!(r.stalls_backpressure > 0);
        // Drain and retry.
        r.out_pop(1);
        assert_eq!(r.arbitrate(|_| Some(1)), 1);
    }

    #[test]
    fn timestep_mismatch_hangs_up() {
        let mut r = CmRouter::new(0, &[10, 11], 4);
        r.accept(0, flit(1, 0, 5)); // future timestep
        assert_eq!(r.arbitrate(|_| Some(1)), 0);
        assert!(r.stalls_timestep > 0);
        r.timestep = 5;
        assert_eq!(r.arbitrate(|_| Some(1)), 1);
    }

    #[test]
    fn connection_matrix_blocks_disallowed_turns() {
        let mut r = CmRouter::new(0, &[10, 11], 4);
        r.set_allow(0, 1, false);
        r.accept(0, flit(1, 0, 0));
        assert_eq!(r.arbitrate(|_| Some(1)), 0);
        assert!(r.stalls_matrix > 0);
        r.set_allow(0, 1, true);
        assert_eq!(r.arbitrate(|_| Some(1)), 1);
    }

    #[test]
    fn gated_router_is_inert() {
        let mut r = CmRouter::new(0, &[10], 4);
        r.enabled = false;
        r.accept(0, flit(1, 0, 0));
        assert_eq!(r.arbitrate(|_| Some(0)), 0);
        assert_eq!(r.switched, 0);
    }

    #[test]
    fn occupancy_counters_track_fifo_contents() {
        let mut r = CmRouter::new(0, &[10, 11], 4);
        assert_eq!((r.in_occupancy(), r.out_occupancy()), (0, 0));
        r.accept(0, flit(1, 0, 0));
        r.accept(1, flit(2, 0, 0));
        assert_eq!((r.in_occupancy(), r.out_occupancy()), (2, 0));
        r.arbitrate(|_| Some(0));
        assert_eq!(r.in_occupancy() + r.out_occupancy(), 2);
        while r.out_pop(0).is_some() {}
        r.arbitrate(|_| Some(0));
        r.out_pop(0);
        assert_eq!((r.in_occupancy(), r.out_occupancy()), (0, 0));
    }

    #[test]
    fn in_pop_drains_and_tracks_occupancy() {
        let mut r = CmRouter::new(0, &[10, 11], 4);
        r.accept(0, flit(1, 0, 0));
        r.accept(0, flit(2, 0, 0));
        r.accept(1, flit(3, 0, 0));
        assert_eq!(r.in_occupancy(), 3);
        assert_eq!(r.in_pop(0).unwrap().id, 1);
        assert_eq!(r.in_pop(0).unwrap().id, 2);
        assert!(r.in_pop(0).is_none());
        assert_eq!(r.in_occupancy(), 1);
        assert_eq!(r.in_pop(1).unwrap().id, 3);
        assert_eq!(r.in_occupancy(), 0);
    }

    #[test]
    fn accept_respects_depth() {
        let mut r = CmRouter::new(0, &[10], 2);
        assert!(r.accept(0, flit(1, 0, 0)));
        assert!(r.accept(0, flit(2, 0, 0)));
        assert!(!r.accept(0, flit(3, 0, 0)));
        assert!(!r.can_accept(0));
    }
}
