//! The fullerene-like network-on-chip (paper §II.B).
//!
//! Twenty neuromorphic cores and twelve level-1 CMRouters form one
//! fullerene-like routing domain: the routers sit at the 12 vertices of an
//! icosahedron, the cores at its 20 (triangular) faces; each router links
//! to the 5 cores on its incident faces (`Nc = 5`, matching the paper's
//! 5×5×5-bit connection-matrix budget) and each core links to the 3
//! routers at its face's corners. The resulting 32-node graph has average
//! degree 3.75 and degree variance 0.94 — the numbers the paper reports —
//! which pins this construction (see `DESIGN.md` §Fullerene-topology).
//!
//! Modules:
//! - [`topology`] — graph builders: fullerene + baseline 2D-mesh, torus,
//!   ring, tree; [`metrics`] computes degree/latency statistics (Fig. 5a/5b).
//! - [`router`] — the multi-mode connection-matrix router (CMRouter):
//!   input/output buffers, register table, link controller (hang-up),
//!   channel arbiter, reconfigurable connection matrix, clock gating.
//! - [`packet`] — spike flits and the hybrid transmission modes
//!   (P2P / broadcast / merge).
//! - [`sim`] — the event-driven cycle-level NoC simulator (Fig. 5c:
//!   throughput, pJ/hop): active-switch worklist, precomputed port
//!   routing, streaming delivery accounting.
//! - [`fault`] — deterministic fault injection: seeded [`FaultPlan`]
//!   schedules (router/link kills, throttles, transient congestion)
//!   consumed by [`NocSim`] to model degraded fabrics.
//! - [`reference`] — the pre-optimization full-scan simulator, retained
//!   verbatim as the bit-exactness oracle and perf baseline.
//! - [`traffic`] — synthetic traffic generators for the router benches.
//! - [`multilevel`] — level-2 scale-up: multiple domains joined through
//!   central level-2 routers into one cycle-simulatable fabric, with the
//!   closed-form hop model retained as a cross-check oracle.

pub mod fault;
pub mod metrics;
pub mod multilevel;
pub mod packet;
pub mod reference;
pub mod router;
pub mod sim;
pub mod topology;
pub mod traffic;

pub use fault::{FabricHealth, FaultEvent, FaultKind, FaultPlan, LinkLevel, When, FAULT_SPEC_USAGE};
pub use metrics::TopoStats;
pub use multilevel::{AnalyticModel, MultiDomain, MultiDomainMeasurement};
pub use packet::{Dest, Flit, TxMode};
pub use reference::ReferenceNocSim;
pub use router::CmRouter;
pub use sim::{NocSim, SimStats, TraceMode};
pub use topology::{NodeId, NodeKind, Topology};

/// The driving surface shared by the event-driven [`NocSim`] and the
/// full-scan [`ReferenceNocSim`] oracle, so traffic generators, the
/// equivalence suite and the perf benches can drive either simulator
/// through one code path.
pub trait Fabric {
    /// Inject spikes from `src_core` toward `dest`; returns the
    /// consecutive flit-id range created.
    fn inject(&mut self, src_core: usize, dest: &Dest, axon: u32) -> std::ops::Range<u64>;
    /// Advance one cycle.
    fn step(&mut self);
    /// Drain all in-flight flits or error.
    fn run_until_drained(&mut self, max_cycles: u64) -> crate::Result<()>;
    /// Aggregate statistics so far.
    fn stats(&self) -> SimStats;
    /// Current cycle.
    fn cycle(&self) -> u64;
    /// Flits injected but not yet delivered.
    fn in_flight(&self) -> u64;
    /// Advance the global timestep.
    fn set_timestep(&mut self, ts: u32);
}

impl Fabric for NocSim {
    fn inject(&mut self, src_core: usize, dest: &Dest, axon: u32) -> std::ops::Range<u64> {
        NocSim::inject(self, src_core, dest, axon)
    }
    fn step(&mut self) {
        NocSim::step(self)
    }
    fn run_until_drained(&mut self, max_cycles: u64) -> crate::Result<()> {
        NocSim::run_until_drained(self, max_cycles)
    }
    fn stats(&self) -> SimStats {
        NocSim::stats(self)
    }
    fn cycle(&self) -> u64 {
        NocSim::cycle(self)
    }
    fn in_flight(&self) -> u64 {
        NocSim::in_flight(self)
    }
    fn set_timestep(&mut self, ts: u32) {
        NocSim::set_timestep(self, ts)
    }
}

impl Fabric for ReferenceNocSim {
    fn inject(&mut self, src_core: usize, dest: &Dest, axon: u32) -> std::ops::Range<u64> {
        ReferenceNocSim::inject(self, src_core, dest, axon)
    }
    fn step(&mut self) {
        ReferenceNocSim::step(self)
    }
    fn run_until_drained(&mut self, max_cycles: u64) -> crate::Result<()> {
        ReferenceNocSim::run_until_drained(self, max_cycles)
    }
    fn stats(&self) -> SimStats {
        ReferenceNocSim::stats(self)
    }
    fn cycle(&self) -> u64 {
        ReferenceNocSim::cycle(self)
    }
    fn in_flight(&self) -> u64 {
        ReferenceNocSim::in_flight(self)
    }
    fn set_timestep(&mut self, ts: u32) {
        ReferenceNocSim::set_timestep(self, ts)
    }
}
