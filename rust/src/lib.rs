//! # fullerene-soc
//!
//! Software reproduction of *"A 0.96pJ/SOP, 30.23K-neuron/mm² Heterogeneous
//! Neuromorphic Chip With Fullerene-like Interconnection Topology for
//! Edge-AI Computing"* (CS.AR 2024).
//!
//! The crate is a **cycle-level, energy-annotated simulator** of the paper's
//! heterogeneous SoC plus the coordination runtime around it:
//!
//! - [`core`] — the neuromorphic core: zero-skip sparse process engine
//!   (ZSPE), dual synapse process engines (SPE) with non-uniform quantized
//!   weight codebooks, LIF neuron updater with partial membrane-potential
//!   updates, a four-stage pipeline, and clock gating. A dense baseline
//!   core ([`core::dense`]) implements the paper's "traditional scheme"
//!   for the 2.69× ablation.
//! - [`noc`] — the fullerene-like network-on-chip: 12 connection-matrix
//!   routers (CMRouter) at icosahedron vertices + 20 cores at its faces,
//!   hybrid P2P/broadcast/merge transmission, a cycle-driven simulator,
//!   baseline topologies (2D-mesh, torus, tree, ring), and level-2
//!   multi-domain scale-up.
//! - [`riscv`] — an RV32IM instruction-set simulator with three clock
//!   domains, sleep/wake clock gating, and the Extended Neuromorphic Unit
//!   (ENU) custom-instruction coupling to the neuromorphic processor.
//! - [`soc`] — SoC plumbing: neuromorphic bus, IDMA/MPDMA, clock manager,
//!   output buffers, external-memory interface.
//! - [`nn`] — network descriptions, non-uniform weight quantization
//!   (k-means codebooks, N, W ∈ {4, 8, 16}), and the neuron→core mapper.
//! - [`datasets`] — synthetic event-stream workloads with NMNIST-like,
//!   DVS-Gesture-like, and rate-coded CIFAR-like geometry/statistics.
//! - [`energy`] — the calibrated 55 nm event-energy/area model that turns
//!   simulation event counts into pJ/SOP, mW and mm² figures.
//! - [`cluster`] — multi-chip scale-out: min-cut layer partitioning
//!   ([`cluster::ClusterMapper`]), the off-chip L3 router ring with its
//!   own energy/latency/fault model ([`cluster::L3Fabric`]), and the
//!   lockstep multi-chip driver ([`cluster::Cluster`]) behind the
//!   [`cluster::Engine`] serving dispatch.
//! - [`serve`] — the streaming session/serving API: [`serve::SocBuilder`]
//!   (fluent, validated configuration), the pluggable [`serve::Workload`]
//!   sample sources, streaming [`serve::Session`]s with incremental
//!   reports, and the multi-session [`serve::SocPool`] with deterministic
//!   merged reporting. Sessions run on an [`cluster::Engine`], so one
//!   session can span a whole cluster (`--chips N`).
//! - [`http`] — the network-facing serving front end: a dependency-free
//!   HTTP/1.1 server (`serve-http` subcommand) bridging JSON workload
//!   submissions into the [`serve`] runtime with 429 backpressure,
//!   `/metrics` exposition and a clean-drain shutdown.
//! - [`coordinator`] — the batch experiment layer (dataset runs +
//!   reference/XLA cross-checking), rebuilt on top of [`serve`].
//! - [`runtime`] — PJRT/XLA execution of the AOT-compiled JAX golden model
//!   (`artifacts/*.hlo.txt`) used to validate the hardware simulation.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

// Backed by the linter's `no-unsafe` rule (which also covers benches,
// examples and integration tests outside this crate root).
#![forbid(unsafe_code)]

pub mod config;
pub mod util;
pub mod benches_support;
pub mod cluster;
pub mod coordinator;
pub mod core;
pub mod datasets;
pub mod energy;
pub mod error;
pub mod http;
pub mod lint;
pub mod metrics;
pub mod nn;
pub mod noc;
pub mod riscv;
pub mod runtime;
pub mod serve;
pub mod soc;

pub use error::{Error, Result};
