//! The pre-optimization NoC simulator, retained **verbatim** as the
//! bit-exactness oracle for the event-driven [`super::sim::NocSim`].
//!
//! Every cycle this implementation scans the *whole* fabric — injection,
//! arbitration and link movement iterate all switches whether or not they
//! hold flits; routing re-derives the output port with a
//! `neighbors().position()` scan per flit; `stats` re-walks the full
//! delivery log and every switch; `snapshot_ledger` formats its static
//! keys per call. That O(fabric)-per-cycle behavior is exactly what the
//! optimized simulator exists to avoid — and exactly what makes this copy
//! valuable:
//!
//! - `tests/equivalence_noc.rs` drives both simulators with identical
//!   traffic and asserts stats, ledgers and traces are bit-identical
//!   (`f64::to_bits`) across topologies and load regimes;
//! - `benches/noc_throughput.rs` measures both on the same scenarios so
//!   `BENCH_noc.json` carries a machine-independent speedup ratio.
//!
//! Do not "fix" or speed this file up: its value is being the frozen
//! semantics the fast path must reproduce.

use super::packet::{Dest, Flit, TxMode};
use super::router::CmRouter;
use super::sim::{Delivered, SimStats};
use super::topology::{NodeId, NodeKind, Topology};
use crate::energy::{EnergyLedger, EnergyParams, EventClass};
use crate::{Error, Result};
use std::collections::VecDeque;
use std::ops::Range;

/// The full-scan reference NoC simulator (see module docs).
pub struct ReferenceNocSim {
    topo: Topology,
    next_hop: Vec<Vec<NodeId>>,
    switches: Vec<CmRouter>,
    /// Per-node local-port index (== neighbor count).
    local_port: Vec<usize>,
    /// Injection staging: flits that did not fit the local FIFO yet.
    pending: Vec<VecDeque<Flit>>,
    delivered: Vec<Delivered>,
    cycle: u64,
    next_id: u64,
    timestep: u32,
    ledger: EnergyLedger,
    energy: EnergyParams,
    in_flight: u64,
}

impl ReferenceNocSim {
    /// Build a simulator over `topo` with per-port FIFO depth `depth`.
    pub fn new(topo: Topology, depth: usize, energy: EnergyParams) -> Self {
        let next_hop = topo.next_hop_table();
        let mut switches = Vec::with_capacity(topo.len());
        let mut local_port = Vec::with_capacity(topo.len());
        for n in 0..topo.len() {
            let mut ports = topo.neighbors(n).to_vec();
            local_port.push(ports.len());
            ports.push(n); // local port loops to self
            switches.push(CmRouter::new(n, &ports, depth));
        }
        let n = topo.len();
        ReferenceNocSim {
            topo,
            next_hop,
            switches,
            local_port,
            pending: (0..n).map(|_| VecDeque::new()).collect(),
            delivered: Vec::new(),
            cycle: 0,
            next_id: 0,
            timestep: 0,
            ledger: EnergyLedger::new(),
            energy,
            in_flight: 0,
        }
    }

    /// The topology being simulated.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Current cycle.
    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    /// Flits injected but not yet delivered.
    pub fn in_flight(&self) -> u64 {
        self.in_flight
    }

    /// Advance the global timestep (propagates to every switch's link
    /// controller).
    pub fn set_timestep(&mut self, ts: u32) {
        self.timestep = ts;
        for s in &mut self.switches {
            s.timestep = ts;
        }
    }

    /// Clock-gate a specific router node (failure/power experiments).
    pub fn set_node_enabled(&mut self, node: NodeId, on: bool) {
        self.switches[node].enabled = on;
    }

    /// Inject spikes from `src_core` (domain-local core id) to `dest`.
    /// Returns the injected flit-id range (same contract as the
    /// optimized simulator, so both can be driven interchangeably).
    pub fn inject(&mut self, src_core: usize, dest: &Dest, axon: u32) -> Range<u64> {
        let src_node = self.topo.core_node(src_core);
        let (mode, dsts): (TxMode, Vec<usize>) = match dest {
            Dest::Core(c) => (TxMode::P2p, vec![*c]),
            Dest::Cores(cs) => (TxMode::Broadcast, cs.clone()),
            Dest::Merge(c) => (TxMode::Merge, vec![*c]),
        };
        let first = self.next_id;
        for dst in dsts {
            let id = self.next_id;
            self.next_id += 1;
            self.pending[src_node].push_back(Flit {
                id,
                src_core,
                dst_core: dst,
                mode,
                axon,
                timestep: self.timestep,
                injected_at: self.cycle,
                hops: 0,
                at: src_node,
            });
            self.in_flight += 1;
        }
        first..self.next_id
    }

    /// One simulation cycle: injection → arbitration → link movement →
    /// ejection, scanning every switch.
    pub fn step(&mut self) {
        self.cycle += 1;

        // 1. Injection: move pending flits into local input FIFOs.
        for n in 0..self.switches.len() {
            let lp = self.local_port[n];
            while self.pending[n].front().is_some() {
                if self.switches[n].can_accept(lp) {
                    let f = self.pending[n].pop_front().unwrap();
                    self.switches[n].accept(lp, f);
                } else {
                    break;
                }
            }
        }

        // 2. Arbitration at every switch.
        for n in 0..self.switches.len() {
            let nh = &self.next_hop;
            let topo = &self.topo;
            let lp = self.local_port[n];
            let route = |f: &Flit| -> Option<usize> {
                let dst_node = topo.core_node(f.dst_core);
                if dst_node == n {
                    return Some(lp);
                }
                let next = nh[n][f.dst_core];
                if next == usize::MAX {
                    return None;
                }
                topo.neighbors(n).iter().position(|&x| x == next)
            };
            self.switches[n].arbitrate(route);
        }

        // 3. Link stage: move output heads to neighbor inputs (1 per link
        //    direction per cycle); eject local-port heads.
        for n in 0..self.switches.len() {
            let lp = self.local_port[n];
            if self.switches[n].out_occupancy() == 0 {
                continue;
            }
            // Ejection.
            if let Some(f) = self.switches[n].out_pop(lp) {
                self.in_flight -= 1;
                self.delivered.push(Delivered {
                    latency: self.cycle - f.injected_at,
                    flit: f,
                });
            }
            let n_ports = self.topo.neighbors(n).len();
            for p in 0..n_ports {
                if self.switches[n].out_head(p).is_none() {
                    continue;
                }
                let nb = self.topo.neighbors(n)[p];
                let back_port = self.switches[nb]
                    .port_to(n)
                    .expect("links are symmetric");
                if self.switches[nb].can_accept(back_port) {
                    let mut f = self.switches[n].out_pop(p).unwrap();
                    f.at = nb;
                    let nb_is_l2 = matches!(self.topo.kind(nb), NodeKind::RouterL2(_));
                    let n_is_l2 = matches!(self.topo.kind(n), NodeKind::RouterL2(_));
                    self.ledger.add1(if nb_is_l2 || n_is_l2 {
                        EventClass::LinkL2
                    } else {
                        EventClass::LinkTraversal
                    });
                    if self.topo.kind(nb).is_router() {
                        f.hops += 1;
                        self.ledger.add1(if nb_is_l2 {
                            EventClass::HopL2
                        } else {
                            match f.mode {
                                TxMode::P2p => EventClass::HopP2p,
                                TxMode::Broadcast => EventClass::HopBroadcast,
                                TxMode::Merge => EventClass::HopMerge,
                            }
                        });
                    }
                    self.switches[nb].accept(back_port, f);
                }
            }
        }
    }

    /// Run until all injected flits are delivered, or error after
    /// `max_cycles` without full drain (no fixed-point fast path — the
    /// reference spins the whole budget).
    pub fn run_until_drained(&mut self, max_cycles: u64) -> Result<()> {
        let start = self.cycle;
        while self.in_flight > 0 {
            if self.cycle - start >= max_cycles {
                return Err(Error::Noc(format!(
                    "NoC not drained after {max_cycles} cycles ({} in flight)",
                    self.in_flight
                )));
            }
            self.step();
        }
        Ok(())
    }

    /// Delivered flits so far (always the full trace).
    pub fn delivered(&self) -> &[Delivered] {
        &self.delivered
    }

    /// Aggregate statistics — O(delivered + switches) per call: re-walks
    /// the delivery log and every switch (the cost the optimized
    /// simulator folds away).
    pub fn stats(&self) -> SimStats {
        let n = self.delivered.len() as f64;
        let (mut lat, mut hops, mut maxl) = (0.0, 0.0, 0u64);
        for d in &self.delivered {
            lat += d.latency as f64;
            hops += d.flit.hops as f64;
            maxl = maxl.max(d.latency);
        }
        let (mut bp, mut ts) = (0u64, 0u64);
        for s in &self.switches {
            bp += s.stalls_backpressure;
            ts += s.stalls_timestep;
        }
        SimStats {
            cycles: self.cycle,
            delivered: self.delivered.len() as u64,
            avg_latency: if n > 0.0 { lat / n } else { 0.0 },
            avg_hops: if n > 0.0 { hops / n } else { 0.0 },
            max_latency: maxl,
            throughput: if self.cycle > 0 {
                n / self.cycle as f64
            } else {
                0.0
            },
            stalls_backpressure: bp,
            stalls_timestep: ts,
        }
    }

    /// Non-destructive ledger assembly (formats static keys per call —
    /// the allocation churn the optimized path precomputes away).
    pub fn snapshot_ledger(&self) -> EnergyLedger {
        let mut ledger = self.ledger.clone();
        for s in &self.switches {
            match self.topo.kind(s.node) {
                NodeKind::Core(_) => {}
                NodeKind::RouterL1(_) => {
                    let active = s.active_cycles.min(self.cycle);
                    ledger.add_static(
                        &format!("router{}", s.node),
                        active,
                        self.cycle - active,
                        self.energy.p_router_active,
                        self.energy.p_router_gated,
                    );
                }
                NodeKind::RouterL2(_) => {
                    let active = s.active_cycles.min(self.cycle);
                    ledger.add_static(
                        &format!("router-l2-{}", s.node),
                        active,
                        self.cycle - active,
                        self.energy.p_router_l2_active,
                        self.energy.p_router_l2_gated,
                    );
                }
            }
        }
        ledger
    }

    /// Dynamic-only energy (pJ) of NoC activity so far.
    pub fn dynamic_pj(&self) -> f64 {
        self.ledger.dynamic_pj(&self.energy)
    }

    /// Dynamic energy per delivered flit-hop (pJ/hop).
    pub fn pj_per_hop(&self) -> Option<f64> {
        let hops: u64 = self.delivered.iter().map(|d| d.flit.hops as u64).sum();
        (hops > 0).then(|| {
            let hop_pj = self.ledger.count(EventClass::HopP2p) as f64 * self.energy.e_hop_p2p
                + self.ledger.count(EventClass::HopBroadcast) as f64 * self.energy.e_hop_bcast
                + self.ledger.count(EventClass::HopMerge) as f64 * self.energy.e_hop_merge
                + self.ledger.count(EventClass::HopL2) as f64 * self.energy.e_hop_l2;
            hop_pj / hops as f64
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_delivers_p2p_on_fullerene() {
        let mut s = ReferenceNocSim::new(Topology::fullerene(), 4, EnergyParams::nominal());
        let ids = s.inject(0, &Dest::Core(13), 7);
        assert_eq!((ids.start, ids.end), (0, 1));
        s.run_until_drained(1000).unwrap();
        let d = &s.delivered()[0];
        assert_eq!(d.flit.dst_core, 13);
        assert!(d.flit.hops >= 1);
        assert_eq!(s.stats().delivered, 1);
    }
}
