"""Layer-2 JAX model: the SNN forward pass.

Two versions of the same network:

- :func:`int_forward` — the **deployed integer network**: quantized
  codebook weights, the chip's exact integer LIF semantics, computed by
  the Layer-1 Pallas kernel (``kernels/snn_core.py``) and scanned over
  timesteps. This is what gets AOT-lowered to HLO for the Rust runtime
  and what defines Table-I accuracy.
- :func:`float_forward` — the **training surrogate**: float weights,
  differentiable spike via a fast-sigmoid surrogate gradient, same
  topology and dynamics shape. Training happens here; the weights are
  then quantized (``quantize.py``) into the integer network.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import ref, snn_core


@dataclasses.dataclass(frozen=True)
class NetSpec:
    """Network topology + float dynamics used for training."""

    name: str
    inputs: int
    hidden: tuple
    classes: int
    timesteps: int
    threshold: float = 1.0
    leak: float = 0.02
    # integer codebook geometry for deployment
    n_levels: int = 16
    w_bits: int = 8

    @property
    def layer_sizes(self):
        dims = (self.inputs,) + tuple(self.hidden) + (self.classes,)
        return list(zip(dims[:-1], dims[1:]))


# ------------------------- float training model ---------------------------

@jax.custom_jvp
def spike_fn(v):
    """Heaviside spike with a fast-sigmoid surrogate gradient."""
    return jnp.where(jnp.asarray(v) >= 0.0, 1.0, 0.0).astype(jnp.float32)


@spike_fn.defjvp
def _spike_jvp(primals, tangents):
    (v,), (dv,) = primals, tangents
    out = spike_fn(v)
    # fast sigmoid surrogate: 1 / (1 + 10|v|)^2
    grad = 1.0 / (1.0 + 10.0 * jnp.abs(v)) ** 2
    return out, grad * dv


def init_params(spec: NetSpec, key):
    """He-scaled float weights per layer."""
    params = []
    for (a, n) in spec.layer_sizes:
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (a, n), jnp.float32) * (2.0 / a) ** 0.5
        params.append(w)
    return params


def float_forward(params, raster, spec: NetSpec):
    """Training forward: returns per-class output spike counts (float).

    raster: float32[T, inputs] of 0/1.
    """
    def step(mps, spikes_t):
        spikes = spikes_t
        new_mps = []
        for li, w in enumerate(params):
            drive = spikes @ w
            m = mps[li] + drive
            # linear leak toward zero
            m = jnp.sign(m) * jnp.maximum(jnp.abs(m) - spec.leak, 0.0)
            out = spike_fn(m - spec.threshold)
            m = m - out * spec.threshold  # subtract reset
            new_mps.append(m)
            spikes = out
        return new_mps, spikes

    mps = [jnp.zeros(n, jnp.float32) for (_, n) in spec.layer_sizes]
    _, outs = jax.lax.scan(step, mps, raster)
    return outs.sum(axis=0)  # [classes]


def batched_float_forward(params, rasters, spec: NetSpec):
    """vmapped float forward over a batch: [B, T, I] → [B, classes]."""
    return jax.vmap(lambda r: float_forward(params, r, spec))(rasters)


# ------------------------- integer deployed model -------------------------

@dataclasses.dataclass(frozen=True)
class IntLayer:
    """One deployed layer: codebook indexes + integer dynamics."""

    widx: jnp.ndarray      # int32 [A, N] (255 = pruned)
    codebook: jnp.ndarray  # int32 [C]
    params: ref.LayerParams


def int_forward(layers, raster, use_pallas: bool = True):
    """Deployed integer forward: per-class output spike counts (int32).

    raster: int32[T, inputs] of 0/1. Scanned over T; each layer-timestep
    runs the Pallas kernel (or the jnp oracle when ``use_pallas=False``).
    """
    step_fn = snn_core.layer_step if use_pallas else ref.layer_step_ref

    def step(mps, spikes_t):
        spikes = spikes_t
        new_mps = []
        for li, layer in enumerate(layers):
            out, m = step_fn(spikes, layer.widx, layer.codebook, mps[li],
                             layer.params)
            new_mps.append(m)
            spikes = out
        return tuple(new_mps), spikes

    mps = tuple(jnp.zeros(l.widx.shape[1], jnp.int32) for l in layers)
    _, outs = jax.lax.scan(step, mps, raster.astype(jnp.int32))
    return outs.sum(axis=0).astype(jnp.int32)


def int_accuracy(layers, rasters, labels, use_pallas: bool = False) -> float:
    """Integer-model accuracy over a batch (oracle path by default — it is
    numerically identical to the kernel and much faster to trace)."""
    fn = jax.jit(functools.partial(int_forward, layers,
                                   use_pallas=use_pallas))
    correct = 0
    for r, y in zip(rasters, labels):
        counts = fn(jnp.asarray(r, jnp.int32))
        correct += int(counts.argmax()) == int(y)
    return correct / len(labels)
