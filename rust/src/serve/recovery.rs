//! Policy-driven session recovery: deadlines, deterministic retry with
//! simulated-cycle backoff, and engine quarantine thresholds.
//!
//! The fault-injection subsystem (PR 6/7) stops at *detection* — a
//! degraded session is faithfully reported and thrown away. This module
//! closes the detect→react loop for the serving layer:
//!
//! - [`RecoveryPolicy`] — the knob bundle carried from
//!   [`crate::serve::SocBuilder`] into [`crate::serve::SocPool`] and
//!   [`crate::serve::ServeRuntime`]. All-zero (the default) disables
//!   every mechanism, and the disabled path is **bit-identical** to the
//!   pre-recovery serving code: the determinism oracles (warm≡fresh,
//!   runtime≡sequential, N=1 cluster≡chip) are untouched unless a user
//!   opts in.
//! - [`SessionVerdict`] — the terminal classification of a session
//!   attempt. `DeadlineExceeded` is distinct from `FabricDegraded`: the
//!   former means the fabric made progress but not fast enough, the
//!   latter that it reached a zero-progress fixed point.
//! - [`HealthReport`] — runtime-level recovery counters (retries,
//!   deadline kills, quarantines, rebuilds) aggregated across workers.
//!
//! Determinism contract: every recovery decision is a pure function of
//! (policy, session cycle counts, fault plan). Backoff is charged in
//! **simulated cycles** with a seeded jitter term — no wall-clock
//! randomness — so a retried session replays `f64::to_bits`-identically
//! run to run. The only wall-clock mechanism is the optional
//! `deadline_wall_ms` host watchdog, which by construction only fires on
//! a hung host and never participates in the simulated ledger.

use crate::util::prng::Rng;
use crate::{Error, Result};

/// Recovery knobs for the serving layer. Zero means "off" for every
/// field; [`RecoveryPolicy::default`] is fully disabled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecoveryPolicy {
    /// Kill a session once its accumulated simulated core-clock cycles
    /// exceed this budget (checked at sample granularity). 0 = no
    /// simulated deadline.
    pub deadline_cycles: u64,
    /// Kill a session once its host wall-clock run time exceeds this
    /// many milliseconds — a watchdog for hung hosts, deliberately
    /// outside the simulated ledger. 0 = no wall deadline.
    pub deadline_wall_ms: u64,
    /// Re-run a failed/degraded/deadline-killed session up to this many
    /// times on a power-cycled engine. 0 = never retry (today's
    /// behavior, bit for bit).
    pub retries: u32,
    /// Base simulated-cycle backoff charged before the first retry;
    /// doubles per attempt (capped). 0 = retry immediately (the failed
    /// attempt's own cycles are still charged).
    pub backoff_cycles: u64,
    /// Seed of the deterministic backoff jitter. 0 = no jitter.
    pub retry_seed: u64,
    /// Quarantine a warm engine after a session whose degradation
    /// counters (dead routers + dead links + dropped flits) reach this
    /// threshold: the engine is discarded and the next session builds a
    /// fresh one. 0 = never quarantine.
    pub quarantine_after: u64,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy {
            deadline_cycles: 0,
            deadline_wall_ms: 0,
            retries: 0,
            backoff_cycles: 0,
            retry_seed: 0,
            quarantine_after: 0,
        }
    }
}

impl RecoveryPolicy {
    /// The fully-disabled policy (same as `default`, named for clarity
    /// at call sites that must pin pre-recovery behavior).
    pub fn disabled() -> Self {
        RecoveryPolicy::default()
    }

    /// True when any recovery mechanism is armed.
    pub fn enabled(&self) -> bool {
        self.deadline_cycles > 0
            || self.deadline_wall_ms > 0
            || self.retries > 0
            || self.quarantine_after > 0
    }

    /// Range-check the policy (called from the `SocBuilder` choke
    /// point, so no construction route skips it).
    pub fn validate(&self) -> Result<()> {
        if self.retries > 32 {
            return Err(Error::config(format!(
                "retries is {} (max 32 — a session that fails 33 times is not \
                 transiently unlucky)",
                self.retries
            )));
        }
        if self.backoff_cycles > 0 && self.retries == 0 {
            return Err(Error::config(
                "backoff_cycles is set but retries is 0 — backoff only applies \
                 between retry attempts",
            ));
        }
        Ok(())
    }

    /// Simulated-cycle backoff charged before retry attempt `attempt`
    /// (1-based: the first retry is attempt 1). Exponential with a
    /// seeded deterministic jitter — a pure function of (policy,
    /// attempt), never of wall time.
    pub fn backoff_for(&self, attempt: u32) -> u64 {
        if self.backoff_cycles == 0 {
            return 0;
        }
        let shift = attempt.saturating_sub(1).min(20);
        let base = self.backoff_cycles.saturating_mul(1u64 << shift);
        let jitter = if self.retry_seed == 0 {
            0
        } else {
            let mut rng = Rng::new(self.retry_seed ^ (0x9E3779B9_u64.wrapping_mul(attempt as u64 + 1)));
            rng.below_usize(16) as u64
        };
        base.saturating_add(jitter)
    }
}

/// Terminal classification of one session attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionVerdict {
    /// The session served every sample and closed its report.
    Completed,
    /// The fabric reached a zero-progress fixed point (stranded flits)
    /// and the attempt fast-failed with the `FabricDegraded` stall
    /// classification.
    FabricDegraded,
    /// The attempt overran its simulated-cycle or host-wall deadline.
    DeadlineExceeded,
    /// Any other failure (workload panic, geometry mismatch, engine
    /// error).
    Failed,
}

impl SessionVerdict {
    /// Classify a session error. The `FabricDegraded` marker string is
    /// the stall classification minted by the NoC drain loop.
    pub fn from_error(e: &Error) -> SessionVerdict {
        match e {
            Error::Deadline(_) => SessionVerdict::DeadlineExceeded,
            Error::Noc(m) if m.contains("FabricDegraded") => SessionVerdict::FabricDegraded,
            _ => SessionVerdict::Failed,
        }
    }

    /// Stable lowercase label (bench JSON / CLI output).
    pub fn as_str(&self) -> &'static str {
        match self {
            SessionVerdict::Completed => "completed",
            SessionVerdict::FabricDegraded => "fabric-degraded",
            SessionVerdict::DeadlineExceeded => "deadline-exceeded",
            SessionVerdict::Failed => "failed",
        }
    }
}

/// Runtime-level recovery counters, aggregated across every worker of a
/// [`crate::serve::ServeRuntime`] (and, for the sequential reference
/// path, across a [`crate::serve::SocPool`] serve). Monotonic for the
/// runtime's lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HealthReport {
    /// Sessions whose terminal outcome was recorded (completed + failed).
    pub sessions: u64,
    /// Sessions that completed (possibly after retries).
    pub completed: u64,
    /// Retry attempts performed (a session completed on its 3rd attempt
    /// contributes 2).
    pub retries: u64,
    /// Simulated cycles burned by failed attempts + backoff (the
    /// recovery overhead ledger).
    pub retry_cycles_burned: u64,
    /// Sessions whose terminal verdict was `DeadlineExceeded`.
    pub deadline_exceeded: u64,
    /// Sessions whose terminal verdict was `FabricDegraded`.
    pub fabric_degraded: u64,
    /// Sessions whose terminal verdict was `Failed` (other errors).
    pub failed: u64,
    /// Warm engines discarded by the quarantine threshold.
    pub quarantines: u64,
    /// Fresh engine builds in keep-warm mode (first session per worker
    /// plus every post-quarantine / post-failure rebuild).
    pub rebuilds: u64,
    /// Cluster shard replans performed by failover (folded from session
    /// outcomes).
    pub replans: u64,
}

impl HealthReport {
    /// Record a terminal session result: `Ok` outcomes carry their
    /// attempt ledger; `Err` outcomes are classified by verdict.
    pub(crate) fn record_outcome(
        &mut self,
        result: &Result<crate::serve::pool::SessionOutcome>,
    ) {
        self.sessions += 1;
        match result {
            Ok(o) => {
                self.completed += 1;
                self.retries += o.attempts.saturating_sub(1) as u64;
                self.retry_cycles_burned += o.retry_cycles_burned;
                self.replans += o.replans;
            }
            Err(e) => match SessionVerdict::from_error(e) {
                SessionVerdict::DeadlineExceeded => self.deadline_exceeded += 1,
                SessionVerdict::FabricDegraded => self.fabric_degraded += 1,
                _ => self.failed += 1,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_policy_is_fully_disabled() {
        let p = RecoveryPolicy::default();
        assert!(!p.enabled());
        assert_eq!(p, RecoveryPolicy::disabled());
        assert_eq!(p.backoff_for(1), 0);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn backoff_is_deterministic_exponential_and_wall_clock_free() {
        let p = RecoveryPolicy {
            retries: 3,
            backoff_cycles: 100,
            retry_seed: 7,
            ..RecoveryPolicy::default()
        };
        // Pure function of (policy, attempt): identical across calls.
        assert_eq!(p.backoff_for(1), p.backoff_for(1));
        assert_eq!(p.backoff_for(2), p.backoff_for(2));
        // Exponential base: attempt 2 at least doubles attempt 1's base.
        assert!(p.backoff_for(1) >= 100 && p.backoff_for(1) < 100 + 16);
        assert!(p.backoff_for(2) >= 200 && p.backoff_for(2) < 200 + 16);
        // Jitter off when unseeded.
        let q = RecoveryPolicy { retry_seed: 0, ..p };
        assert_eq!(q.backoff_for(1), 100);
        assert_eq!(q.backoff_for(3), 400);
        // Saturates instead of overflowing at absurd attempt counts.
        let r = RecoveryPolicy {
            backoff_cycles: u64::MAX / 2,
            ..q
        };
        assert_eq!(r.backoff_for(33), u64::MAX);
    }

    #[test]
    fn verdicts_classify_the_error_taxonomy() {
        assert_eq!(
            SessionVerdict::from_error(&Error::Deadline("x".into())),
            SessionVerdict::DeadlineExceeded
        );
        assert_eq!(
            SessionVerdict::from_error(&Error::Noc(
                "FabricDegraded: NoC not drained: fixed point".into()
            )),
            SessionVerdict::FabricDegraded
        );
        assert_eq!(
            SessionVerdict::from_error(&Error::Noc("unroutable".into())),
            SessionVerdict::Failed
        );
        assert_eq!(
            SessionVerdict::from_error(&Error::Runtime("panic".into())),
            SessionVerdict::Failed
        );
        assert_eq!(SessionVerdict::DeadlineExceeded.as_str(), "deadline-exceeded");
    }

    #[test]
    fn policy_validation_rejects_nonsense() {
        let p = RecoveryPolicy {
            retries: 33,
            ..RecoveryPolicy::default()
        };
        assert!(p.validate().is_err());
        let p = RecoveryPolicy {
            backoff_cycles: 10,
            retries: 0,
            ..RecoveryPolicy::default()
        };
        assert!(p.validate().is_err());
        let p = RecoveryPolicy {
            deadline_cycles: 1_000_000,
            retries: 2,
            backoff_cycles: 64,
            ..RecoveryPolicy::default()
        };
        assert!(p.validate().is_ok());
        assert!(p.enabled());
    }
}
