//! Level-2 scale-up (paper: "the NoC can be scaled up through extended
//! off-chip high-level router nodes") — as a **cycle-level simulation**.
//!
//! A [`MultiDomain`] stitches `D` fullerene domains together as one real
//! [`Topology`]: each domain keeps its 20 cores + 12 level-1 routers and
//! gains the central level-2 router; level-2 routers interconnect in a
//! ring (the off-chip topology the paper sketches). Global core ids are
//! `domain * 20 + local`. Inter-domain flits actually climb
//! `core → L1 → L2`, ride the L2 ring, and descend — every hop switched by
//! a [`super::router::CmRouter`] and priced by the energy ledger
//! ([`crate::energy::EventClass::HopL2`] / `LinkL2`).
//!
//! The closed-form hop model that used to *be* this module survives as
//! [`AnalyticModel`], kept as a cross-check oracle: integration tests
//! assert the simulated hop counts agree with it (exactly for
//! inter-domain pairs, within a stated tolerance for mixed traffic).

use super::metrics::TopoStats;
use super::packet::Dest;
use super::sim::{NocSim, TraceMode};
use super::topology::Topology;
use crate::energy::{EnergyParams, EventClass};
use crate::util::prng::Rng;
use crate::Result;

/// Closed-form router-hop model of the hierarchical fabric (the retained
/// analytic oracle).
///
/// Hop accounting matches the simulator's definition (a hop = an arrival
/// at a router node): intra-domain pairs average `intra_hops`; an
/// inter-domain flit pays 2 hops on the climb (its L1, its domain's L2),
/// one hop per L2-ring link, and 1 hop on the descend (the destination's
/// L1 — arrival at the destination *core* is not a hop).
#[derive(Debug, Clone)]
pub struct AnalyticModel {
    /// Number of fullerene domains.
    pub domains: usize,
    /// Average intra-domain core-to-core router hops (fullerene level-1
    /// fabric; hierarchical routing never shortcuts through L2, so this
    /// is exactly the plain-fullerene figure, 60/19/2 ≈ 1.58).
    pub intra_hops: f64,
    /// Router hops on the climb `core → L1 → L2` (always 2).
    pub climb_hops: f64,
    /// Router hops on the descend `L2 → L1 → core` (always 1 — the final
    /// core arrival is not a router hop).
    pub descend_hops: f64,
}

impl AnalyticModel {
    /// Build the model for `domains` domains.
    pub fn new(domains: usize) -> Self {
        assert!(domains >= 1);
        let stats = TopoStats::compute(&Topology::fullerene());
        AnalyticModel {
            domains,
            // Link distance between cores is even (core/router layers
            // alternate), and every second link lands on a router.
            intra_hops: stats.avg_core_hops / 2.0,
            climb_hops: 2.0,
            descend_hops: 1.0,
        }
    }

    /// Ring distance between two domains.
    pub fn l2_ring_hops(&self, a: usize, b: usize) -> usize {
        let d = a.abs_diff(b);
        d.min(self.domains - d)
    }

    /// Expected router hops between two cores (global ids).
    pub fn hops_between(&self, src: usize, dst: usize) -> f64 {
        let (sd, dd) = (src / 20, dst / 20);
        if sd == dd {
            self.intra_hops
        } else {
            self.climb_hops + self.l2_ring_hops(sd, dd) as f64 + self.descend_hops
        }
    }

    /// Average hops over uniform random distinct core pairs.
    pub fn avg_hops_uniform(&self) -> f64 {
        let n = (self.domains * 20) as f64;
        if self.domains == 1 {
            return self.intra_hops;
        }
        // P(same domain) over ordered distinct pairs.
        let same = (20.0 - 1.0) / (n - 1.0);
        // Expected ring distance between two distinct uniform domains.
        let d = self.domains;
        let mut ring = 0.0;
        for k in 1..d {
            ring += self.l2_ring_hops(0, k) as f64;
        }
        ring /= (d - 1) as f64;
        let inter = self.climb_hops + ring + self.descend_hops;
        same * self.intra_hops + (1.0 - same) * inter
    }
}

/// Measured-vs-analytic summary of one multi-domain traffic run.
#[derive(Debug, Clone)]
pub struct MultiDomainMeasurement {
    /// Flits delivered.
    pub delivered: u64,
    /// Mean injection→ejection latency (cycles).
    pub avg_latency: f64,
    /// Mean simulated router hops per flit.
    pub measured_hops: f64,
    /// Analytic expectation over the *same* (src, dst) pair multiset.
    pub analytic_hops: f64,
    /// L2-router hop events charged to the ledger.
    pub l2_hop_events: u64,
    /// Dynamic NoC energy of the run (pJ).
    pub dynamic_pj: f64,
}

impl MultiDomainMeasurement {
    /// Relative deviation of the simulation from the analytic oracle.
    pub fn relative_error(&self) -> f64 {
        (self.measured_hops - self.analytic_hops).abs() / self.analytic_hops
    }
}

/// A multi-domain (scaled-up) system: the simulatable graph plus the
/// analytic oracle.
#[derive(Debug, Clone)]
pub struct MultiDomain {
    /// Number of fullerene domains.
    pub domains: usize,
    /// The full `D`-domain graph (cores, L1 routers, L2 ring).
    pub topo: Topology,
    /// The retained closed-form hop model.
    pub analytic: AnalyticModel,
}

impl MultiDomain {
    /// Build a system of `domains` fullerene domains.
    pub fn new(domains: usize) -> Self {
        assert!(domains >= 1);
        MultiDomain {
            domains,
            topo: Topology::multi_domain(domains),
            analytic: AnalyticModel::new(domains),
        }
    }

    /// Total cores in the system.
    pub fn total_cores(&self) -> usize {
        self.domains * 20
    }

    /// Total neurons at the paper's 8 K/core.
    pub fn total_neurons(&self) -> usize {
        self.total_cores() * 8192
    }

    /// A fresh cycle-level simulator over the multi-domain fabric.
    pub fn sim(&self, depth: usize, energy: EnergyParams) -> NocSim {
        NocSim::new(self.topo.clone(), depth, energy)
    }

    /// Inject `flits` random P2P flits (a `locality` fraction stays
    /// intra-domain), drain, and report measured hop/latency/energy
    /// figures next to the analytic expectation for the same pair set.
    ///
    /// Hop counts are congestion-independent (routing is deterministic),
    /// so `measured_hops` vs `analytic_hops` is a sharp oracle even at
    /// heavy load; latency is where congestion shows up.
    pub fn measure(
        &self,
        flits: usize,
        locality: f64,
        seed: u64,
        energy: EnergyParams,
    ) -> Result<MultiDomainMeasurement> {
        let mut sim = self.sim(4, energy);
        // Aggregates only: the measurement never reads per-flit records,
        // so skip trace retention (stats are exact in every mode).
        sim.set_trace_mode(TraceMode::Off);
        let mut rng = Rng::new(seed);
        let n = self.total_cores();
        let mut analytic_sum = 0.0;
        let mut injected = 0u64;
        for _ in 0..flits {
            let src = rng.below_usize(n);
            let dst = if self.domains == 1 || rng.bool(locality) {
                (src / 20) * 20 + rng.below_usize(20)
            } else {
                rng.below_usize(n)
            };
            if dst == src {
                continue;
            }
            sim.inject(src, &Dest::Core(dst), 0);
            analytic_sum += self.analytic.hops_between(src, dst);
            injected += 1;
        }
        sim.run_until_drained(1_000_000)?;
        let st = sim.stats();
        let dynamic_pj = sim.dynamic_pj();
        let ledger = sim.finish_ledger();
        Ok(MultiDomainMeasurement {
            delivered: st.delivered,
            avg_latency: st.avg_latency,
            measured_hops: st.avg_hops,
            analytic_hops: if injected > 0 {
                analytic_sum / injected as f64
            } else {
                0.0
            },
            l2_hop_events: ledger.count(EventClass::HopL2),
            dynamic_pj,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_domain_degenerates_to_intra() {
        let m = MultiDomain::new(1);
        assert_eq!(m.total_cores(), 20);
        assert!((m.analytic.avg_hops_uniform() - m.analytic.intra_hops).abs() < 1e-12);
    }

    #[test]
    fn scaling_grows_neurons_linearly() {
        let m = MultiDomain::new(8);
        assert_eq!(m.total_cores(), 160);
        assert_eq!(m.total_neurons(), 8 * 20 * 8192);
    }

    #[test]
    fn ring_distance_wraps() {
        let a = AnalyticModel::new(6);
        assert_eq!(a.l2_ring_hops(0, 5), 1);
        assert_eq!(a.l2_ring_hops(1, 4), 3);
    }

    #[test]
    fn inter_domain_costlier_than_intra() {
        let a = AnalyticModel::new(4);
        assert!(a.hops_between(0, 25) > a.hops_between(0, 5));
    }

    #[test]
    fn avg_hops_grows_sublinearly_with_domains() {
        let h2 = AnalyticModel::new(2).avg_hops_uniform();
        let h8 = AnalyticModel::new(8).avg_hops_uniform();
        let h32 = AnalyticModel::new(32).avg_hops_uniform();
        assert!(h2 < h8 && h8 < h32);
        // Ring diameter grows linearly in domains, so the ratio of
        // avg-hops growth to core growth must stay well below linear.
        let growth = h32 / h2;
        assert!(growth < 16.0, "growth {growth}");
    }

    #[test]
    fn intra_hops_is_the_fullerene_figure() {
        // 9 core pairs at 1 hop, 9 at 2, 1 at 3 → 60/19 links / 2.
        let a = AnalyticModel::new(2);
        assert!((a.intra_hops - 60.0 / 19.0 / 2.0).abs() < 1e-9);
    }

    #[test]
    fn simulated_inter_domain_pair_matches_oracle_exactly() {
        for d in [2usize, 4] {
            let m = MultiDomain::new(d);
            let mut sim = m.sim(4, EnergyParams::nominal());
            let dst = 20 + 7; // domain 1
            sim.inject(3, &Dest::Core(dst), 0);
            sim.run_until_drained(10_000).unwrap();
            let hops = sim.delivered()[0].flit.hops as f64;
            assert!(
                (hops - m.analytic.hops_between(3, dst)).abs() < 1e-12,
                "D={d}: simulated {hops} vs analytic {}",
                m.analytic.hops_between(3, dst)
            );
        }
    }

    #[test]
    fn measure_agrees_with_oracle_under_mixed_traffic() {
        let m = MultiDomain::new(4);
        let r = m.measure(400, 0.8, 11, EnergyParams::nominal()).unwrap();
        assert!(r.delivered > 300);
        assert!(r.l2_hop_events > 0, "no flit ever climbed to L2");
        // Inter-domain pairs match exactly; intra pairs deviate from the
        // domain-average by at most ±(diameter−avg), so the mixture stays
        // well inside 20 %.
        assert!(
            r.relative_error() < 0.20,
            "measured {} vs analytic {}",
            r.measured_hops,
            r.analytic_hops
        );
    }
}
