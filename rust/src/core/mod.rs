//! The neuromorphic core (paper §II.A).
//!
//! A core integrates:
//!
//! - a **register table** ([`regtable::RegTable`]) holding the core ID,
//!   clock-gating enable, neuron configuration and weight configuration;
//! - **double ping-pong caches** ([`cache::PingPong`]) for spike data and
//!   weight indexes;
//! - a **zero-skip sparse process engine** ([`zspe::Zspe`]) that scans
//!   16-bit spike words and forwards weight-index requests only for valid
//!   (non-zero) spikes;
//! - **dual synapse process engines** ([`spe::Spe`]) that fetch 4 synapse
//!   weights per cycle from the shared non-uniform quantized codebook
//!   ([`codebook::Codebook`], `N × W` bits, `N, W ∈ {4, 8, 16}`) and
//!   accumulate partial membrane potentials;
//! - a **neuron updater** ([`neuron::NeuronArray`]) controlling LIF
//!   integration, leak, reset and spike firing, with *partial MP updates*
//!   (only neurons touched by input spikes are read-modified-written);
//! - a **four-stage pipeline** ([`pipeline`]) over cache → ZSPE → SPE →
//!   updater with inter-stage buffers, which produces the cycle counts;
//! - **clock gating** driven by the register-table enable bit.
//!
//! [`dense::DenseCore`] is the paper's "traditional scheme" baseline: no
//! zero-skip (every axon, spiking or not, walks the full synapse list) and
//! full MP updates (every neuron read-modified-written every timestep).
//! Fig. 3's 2.69× energy-efficiency claim is the ratio between the two.

pub mod cache;
pub mod codebook;
pub mod core_impl;
pub mod dense;
pub mod neuron;
pub mod pipeline;
pub mod regtable;
pub mod spe;
pub mod synapses;
pub mod zspe;

pub use cache::PingPong;
pub use codebook::Codebook;
pub use core_impl::{CoreStats, NeuroCore, TimestepOutput};
pub use dense::DenseCore;
pub use neuron::{LeakMode, NeuronArray, NeuronParams, ResetMode};
pub use regtable::{RegTable, WeightConfig};
pub use synapses::{Synapses, SynapsesBuilder};

/// Width of one spike word processed by the ZSPE per cycle (paper: 16).
pub const SPIKE_WORD_BITS: usize = 16;

/// Synapse operations the dual SPEs complete per cycle (paper: 4).
pub const SPE_LANES: usize = 4;

/// Maximum neurons per core (paper: 160 K neurons / 20 cores).
pub const MAX_NEURONS_PER_CORE: usize = 8192;

/// Pack a boolean spike vector into 16-bit words, LSB = lowest axon id.
pub fn pack_spikes(spikes: &[bool]) -> Vec<u16> {
    let mut words = vec![0u16; spikes.len().div_ceil(SPIKE_WORD_BITS)];
    for (i, &s) in spikes.iter().enumerate() {
        if s {
            words[i / SPIKE_WORD_BITS] |= 1 << (i % SPIKE_WORD_BITS);
        }
    }
    words
}

/// Unpack 16-bit spike words into a boolean vector of length `n`.
pub fn unpack_spikes(words: &[u16], n: usize) -> Vec<bool> {
    (0..n)
        .map(|i| words[i / SPIKE_WORD_BITS] >> (i % SPIKE_WORD_BITS) & 1 == 1)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let spikes: Vec<bool> = (0..37).map(|i| i % 3 == 0).collect();
        let words = pack_spikes(&spikes);
        assert_eq!(words.len(), 3);
        assert_eq!(unpack_spikes(&words, 37), spikes);
    }

    #[test]
    fn pack_sets_expected_bits() {
        let mut spikes = vec![false; 16];
        spikes[0] = true;
        spikes[15] = true;
        assert_eq!(pack_spikes(&spikes), vec![0x8001]);
    }
}
