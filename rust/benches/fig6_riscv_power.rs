//! Fig. 6 reproduction: RISC-V CPU power with the sleep/clock-gating
//! design vs the busy-wait baseline, on the MNIST control firmware.
//!
//! Paper anchors: 0.434 mW average, 43 % below the baseline.

use fullerene_soc::benches_support;
use fullerene_soc::riscv::cpu::Cpu;
use fullerene_soc::riscv::firmware;
use fullerene_soc::util::bench::Bench;

fn main() {
    println!("## Fig. 6: RISC-V power (MNIST control firmware, 16 MHz)");
    let t = benches_support::fig6_table().expect("fig6 model runs");
    println!("{}", t.render());
    println!("paper anchors: 0.434 mW with gating, −43% vs baseline\n");

    // ISS wall-clock throughput (perf tracking): instructions/second of
    // the simulator itself.
    let mut b = Bench::new("fig6_riscv_power");
    let prog = firmware::compute_kernel(2000).unwrap();
    b.bench("iss-compute-kernel-2k-iters", || {
        let mut cpu = Cpu::new(4096, true);
        cpu.load_program(&prog).unwrap();
        cpu.run(100_000).unwrap();
        cpu.instret
    });
    let r = &b.results()[0];
    // ~5 instructions per loop iteration × 2000 iterations.
    let mips = 10_000.0 / (r.median_ns / 1e3);
    println!("ISS speed ≈ {mips:.0} M instr/s");
    b.finish();
}
