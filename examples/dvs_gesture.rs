//! Temporal-workload study on the DVS-Gesture-like stream: how spike
//! sparsity, NoC traffic and energy evolve over a gesture's timesteps,
//! and how the chip behaves at different operating points (frequency /
//! voltage — the paper's 1.08–1.32 V, 50–200 MHz envelope).
//!
//! ```bash
//! cargo run --release --example dvs_gesture            # fallback net
//! make artifacts && cargo run --release --example dvs_gesture
//! ```

use fullerene_soc::datasets::{Dataset, Workload};
use fullerene_soc::energy::ChipReport;
use fullerene_soc::metrics::Table;
use fullerene_soc::nn::load_weights_json;
use fullerene_soc::serve::SocBuilder;
use std::path::Path;

fn load_net() -> fullerene_soc::Result<fullerene_soc::nn::NetworkDesc> {
    let trained = Path::new("artifacts/dvsgesture.weights.json");
    if trained.exists() {
        println!("using trained weights: {}", trained.display());
        return Ok(load_weights_json(trained)?);
    }
    println!("(untrained fallback network — run `make artifacts` for the real one)");
    let w = Workload::DvsGesture;
    Ok(fullerene_soc::benches_support::structural_net(
        "dvs-fallback",
        w.inputs(),
        96,
        w.classes(),
        w.timesteps(),
    ))
}

fn main() -> fullerene_soc::Result<()> {
    let net = load_net()?;
    let w = Workload::DvsGesture;
    let ds_path = Path::new("artifacts/dataset_dvsgesture.json");
    let ds = if ds_path.exists() {
        Dataset::load_json(ds_path)?
    } else {
        w.generate(11, 5)
    };

    // --- per-timestep activity profile of one gesture ---------------------
    let sample = &ds.samples[0];
    println!("## per-timestep activity (sample 0, class {})", sample.label);
    let mut t = Table::new(&["t", "input spikes", "sparsity"]);
    for ts in 0..ds.timesteps {
        let n = sample.spikes_at(ts as u16).len();
        t.push_row(vec![
            ts.to_string(),
            n.to_string(),
            format!("{:.3}", 1.0 - n as f64 / ds.inputs as f64),
        ]);
    }
    println!("{}", t.render());

    // --- operating-point sweep (Table I envelope) --------------------------
    // One streaming session per operating point: the builder validates
    // each point and the session close delivers the report (accuracy
    // included — the session counts labelled pushes itself).
    println!("## operating-point sweep (8 samples each)");
    let mut reports = Vec::new();
    for (f_mhz, v) in [(50.0, 1.08), (100.0, 1.08), (200.0, 1.08), (100.0, 1.32)] {
        let mut session = SocBuilder::new()
            .f_core_mhz(f_mhz)
            .supply_v(v)
            .open_session(&net, &format!("{f_mhz:.0}MHz/{v}V"))?;
        for s in ds.samples.iter().take(8) {
            session.push(s)?;
        }
        reports.push(session.close().report);
    }
    println!("{}", ChipReport::table(&reports).render());
    println!(
        "note: pJ/SOP is voltage-dependent (dynamic ∝ V²) and power scales \
         with frequency — the envelope matches Table I's 2.8–113 mW span \
         directionally."
    );
    Ok(())
}
