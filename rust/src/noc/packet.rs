//! Spike flits and the hybrid transmission modes.
//!
//! The CMRouter's connection matrix lets one physical flit format serve
//! three modes (paper: "compatible with multiple transmission modes,
//! including P2P, broadcast, and merge, while avoiding complex packet
//! encoding and decoding"):
//!
//! - **P2P**: one source core → one destination core;
//! - **broadcast**: one source → a set of destination cores (the flit is
//!   replicated at tree-branch routers, paying the cheap per-destination
//!   energy);
//! - **merge**: spikes from several source cores converge onto one
//!   destination axon range (the router merges streams; the destination
//!   sees a single logical stream).

use super::topology::NodeId;

/// Transmission mode of a flit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TxMode {
    /// Point-to-point.
    P2p,
    /// One-to-many broadcast.
    Broadcast,
    /// Many-to-one merge.
    Merge,
}

/// Destination specification at injection time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Dest {
    /// Single destination core (domain-local core id).
    Core(usize),
    /// Broadcast to several cores.
    Cores(Vec<usize>),
    /// Merge-mode delivery to one core (distinguished from [`Dest::Core`]
    /// only by energy/arbitration accounting).
    Merge(usize),
}

impl Dest {
    /// The transmission mode this destination implies.
    pub fn mode(&self) -> TxMode {
        match self {
            Dest::Core(_) => TxMode::P2p,
            Dest::Cores(_) => TxMode::Broadcast,
            Dest::Merge(_) => TxMode::Merge,
        }
    }
}

/// A spike flit in flight.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Flit {
    /// Unique id (for latency bookkeeping).
    pub id: u64,
    /// Source core (domain-local id).
    pub src_core: usize,
    /// Destination core (domain-local id) — broadcast flits are split into
    /// per-destination copies at injection/branch points, each carrying
    /// its own `dst_core`.
    pub dst_core: usize,
    /// Transmission mode (for energy accounting).
    pub mode: TxMode,
    /// Spike payload: the axon id at the destination core.
    pub axon: u32,
    /// Timestep tag (cores must stay timestep-synchronized; the link
    /// controller hangs up on mismatch).
    pub timestep: u32,
    /// Injection cycle (latency bookkeeping).
    pub injected_at: u64,
    /// Hops (router traversals) so far.
    pub hops: u32,
    /// Current node (maintained by the simulator).
    pub at: NodeId,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dest_modes() {
        assert_eq!(Dest::Core(1).mode(), TxMode::P2p);
        assert_eq!(Dest::Cores(vec![1, 2]).mode(), TxMode::Broadcast);
        assert_eq!(Dest::Merge(3).mode(), TxMode::Merge);
    }
}
