//! Regression pins for the paper's published topology metrics (Fig. 5a/5b),
//! computed through `noc::metrics::TopoStats` over the same node convention
//! the paper uses (cores + routers both count as communication nodes).
//!
//! Paper anchors: fullerene average node degree 3.75, exceeding the
//! mesh/torus/tree baselines by ~32 %; degree variance ≈ 0.93 (exact
//! construction value 0.9375); average core-to-core distance 3.16 links.

use fullerene_soc::noc::{TopoStats, Topology};

fn baselines() -> Vec<TopoStats> {
    vec![
        TopoStats::compute(&Topology::mesh2d(4, 5)),
        TopoStats::compute(&Topology::torus(4, 5)),
        TopoStats::compute(&Topology::tree(4, 20)),
        TopoStats::compute(&Topology::ring(20)),
    ]
}

#[test]
fn fullerene_degree_is_exactly_the_paper_value() {
    let f = TopoStats::compute(&Topology::fullerene());
    assert!((f.avg_degree - 3.75).abs() < 1e-12, "avg degree {}", f.avg_degree);
}

#[test]
fn fullerene_degree_variance_matches_paper_093() {
    let f = TopoStats::compute(&Topology::fullerene());
    // Exact construction value: 12 routers at degree 5, 20 cores at 3
    // around the 3.75 mean → variance 0.9375; the paper rounds to 0.93.
    assert!(
        (f.degree_variance - 0.9375).abs() < 1e-12,
        "variance {}",
        f.degree_variance
    );
    assert!((f.degree_variance - 0.93).abs() < 0.01);
}

#[test]
fn fullerene_degree_exceeds_every_baseline_and_by_about_a_third_on_average() {
    let f = TopoStats::compute(&Topology::fullerene());
    let base = baselines();
    for b in &base {
        let gain = f.avg_degree / b.avg_degree;
        assert!(gain > 1.2, "{}: degree gain only {gain:.3}", b.name);
    }
    // The paper headlines "+32 %"; averaged across our four baselines the
    // margin is comfortably above that (regression floor, not a tight pin).
    let mean_base = base.iter().map(|b| b.avg_degree).sum::<f64>() / base.len() as f64;
    let mean_gain = f.avg_degree / mean_base;
    assert!(mean_gain > 1.32, "mean degree gain {mean_gain:.3}");
}

#[test]
fn fullerene_average_core_distance_is_316_links() {
    let f = TopoStats::compute(&Topology::fullerene());
    // Exactly 60/19 ≈ 3.158: per core, 9 neighbors at 2 links, 9 at 4,
    // and the antipodal face at 6.
    assert!(
        (f.avg_core_hops - 60.0 / 19.0).abs() < 1e-12,
        "avg distance {}",
        f.avg_core_hops
    );
    assert!((f.avg_core_hops - 3.16).abs() < 0.01);
    assert_eq!(f.diameter_core_hops, 6);
}

#[test]
fn fullerene_variance_is_the_smallest_of_all_topologies() {
    let f = TopoStats::compute(&Topology::fullerene());
    for b in baselines() {
        assert!(
            b.degree_variance > f.degree_variance,
            "{}: variance {} not above fullerene's {}",
            b.name,
            b.degree_variance,
            f.degree_variance
        );
    }
}

#[test]
fn multi_domain_keeps_per_domain_degree_statistics_stable() {
    // Adding domains must not distort the level-1 fabric: in a 4-domain
    // system, L1 routers gain exactly one L2 uplink (degree 6) and cores
    // stay at degree 3.
    let t = Topology::multi_domain(4);
    let mut l1 = 0usize;
    for n in 0..t.len() {
        match t.kind(n) {
            fullerene_soc::noc::NodeKind::Core(_) => {
                assert_eq!(t.neighbors(n).len(), 3)
            }
            fullerene_soc::noc::NodeKind::RouterL1(_) => {
                assert_eq!(t.neighbors(n).len(), 6);
                l1 += 1;
            }
            fullerene_soc::noc::NodeKind::RouterL2(_) => {
                assert_eq!(t.neighbors(n).len(), 14)
            }
        }
    }
    assert_eq!(l1, 48);
}
