//! External asynchronous SRAM interface (Fig. 7: "an external memory
//! interface [is] connected to the bus for […] off-chip asynchronous SRAM
//! data access"). Off-chip accesses are slow and expensive — the model
//! charges a fixed latency and the calibrated per-word energy, which is
//! what makes "keep weights in on-core codebooks" the winning design
//! point in the ablation bench.

use super::bus::{BusOp, NeuroBus};
use crate::energy::{EnergyLedger, EventClass};

/// External SRAM model.
#[derive(Debug, Clone)]
pub struct ExtMem {
    /// Access latency in core cycles per 16-bit word.
    pub latency: u64,
    /// Words transferred.
    pub words: u64,
}

impl Default for ExtMem {
    fn default() -> Self {
        // Async SRAM at ~10 ns per access ≈ 2 cycles at 200 MHz.
        ExtMem { latency: 2, words: 0 }
    }
}

impl ExtMem {
    /// Transfer `words` 16-bit words; returns cycles consumed.
    pub fn transfer(&mut self, words: u64, bus: &mut NeuroBus, ledger: &mut EnergyLedger) -> u64 {
        self.words += words;
        ledger.add(EventClass::ExtMemWord, words);
        let bus_cycles = bus.transfer(BusOp::ExtMem, words.div_ceil(2), ledger);
        bus_cycles + self.latency * words
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::EnergyParams;

    #[test]
    fn slow_and_expensive() {
        let p = EnergyParams::nominal();
        let mut m = ExtMem::default();
        let mut bus = NeuroBus::new();
        let mut l = EnergyLedger::new();
        let cycles = m.transfer(10, &mut bus, &mut l);
        assert_eq!(cycles, 5 + 20);
        // Off-chip word ≫ on-core cache access energy.
        assert!(p.e_extmem_word > 10.0 * p.e_cache_rd);
    }
}
