//! Min-cut-flavored partitioning of one logical network across the
//! chips of a cluster.
//!
//! The partition unit is the **layer**: every shard runs a contiguous
//! block of layers on its own chip, and the only inter-chip traffic is
//! the spike stream crossing each block boundary over the off-chip L3
//! ring. That makes the cut size of a boundary exactly the width (in
//! neurons) of the layer feeding it — so the planner is a small dynamic
//! program over contiguous layer splits that minimizes the summed
//! boundary width, the min-cut objective Moradi & Manohar's off-chip
//! cost gap (arxiv 1809.06016) says to minimize: every cut neuron is a
//! potential flit on a link an order of magnitude costlier than any
//! on-chip wire.
//!
//! Per-shard feasibility reuses the exact capacity rule of
//! [`crate::nn::Mapping::plan`] (greedy packing of `ceil(neurons /
//! max_neurons_per_core)` cores per layer), so a plan accepted here can
//! always be built by the per-chip mapper.

use crate::nn::NetworkDesc;
use crate::{Error, Result};

/// A contiguous-layer partition of one network across cluster shards.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    /// Per-shard half-open layer ranges `[start, end)`, in shard order,
    /// covering every layer exactly once.
    pub ranges: Vec<(usize, usize)>,
    /// Neurons sitting on shard boundaries — the summed width of every
    /// cut layer interface, i.e. the min-cut objective value. Each one
    /// can fire at most once per timestep, so this also bounds the
    /// per-timestep inter-chip flit count.
    pub cut_neurons: usize,
}

impl Partition {
    /// Number of shards (chips actually used; at most the ring size).
    pub fn shards(&self) -> usize {
        self.ranges.len()
    }

    /// Cores the per-chip mapper will pack for shard `s` of `net`.
    pub fn cores_of(&self, net: &NetworkDesc, s: usize, max_neurons_per_core: usize) -> usize {
        let (a, b) = self.ranges[s];
        net.layers[a..b]
            .iter()
            .map(|l| l.neurons.div_ceil(max_neurons_per_core))
            .sum()
    }

    /// The sub-network shard `s` runs: the range's layers verbatim, with
    /// the shard's last layer acting as its "classes" (its spikes leave
    /// the chip over the ring — or the readout path, on the terminal
    /// shard). Axon ids crossing a boundary are layer-local neuron
    /// indices, which is exactly the next shard's input axon space, so
    /// no id translation happens at the cut.
    pub fn sub_net(&self, net: &NetworkDesc, s: usize) -> NetworkDesc {
        let (a, b) = self.ranges[s];
        NetworkDesc {
            name: format!("{}#shard{}", net.name, s),
            layers: net.layers[a..b].to_vec(),
            timesteps: net.timesteps,
            classes: net.layers[b - 1].neurons,
        }
    }
}

/// Plans [`Partition`]s. Stateless; the cluster calls
/// [`ClusterMapper::plan`] once at build time.
pub struct ClusterMapper;

impl ClusterMapper {
    /// Partition `net` across at most `chips` shards, each with
    /// `n_cores` cores of `max_neurons_per_core` neurons.
    ///
    /// Objective (lexicographic): minimize cut neurons, then use fewer
    /// shards, then minimize the largest shard's core count (balance).
    /// The optimum is exact for the first objective and for shard count;
    /// balance is resolved by the same DP and is exact among min-cut,
    /// min-shard solutions reachable through its optimal substructure —
    /// the tie-break regression tests pin the behavior.
    pub fn plan(
        net: &NetworkDesc,
        chips: usize,
        n_cores: usize,
        max_neurons_per_core: usize,
    ) -> Result<Partition> {
        if chips == 0 {
            return Err(Error::Config("cluster needs at least one chip".into()));
        }
        net.validate()?;
        let nl = net.layers.len();
        let cores: Vec<usize> = net
            .layers
            .iter()
            .map(|l| l.neurons.div_ceil(max_neurons_per_core))
            .collect();
        if let Some((li, &c)) = cores.iter().enumerate().find(|&(_, &c)| c > n_cores) {
            return Err(Error::Config(format!(
                "layer {li} ('{}') alone needs {c} cores but one chip has {n_cores} — \
                 layer-contiguous partitioning cannot split it across chips",
                net.layers[li].name
            )));
        }
        // prefix[i] = cores of layers[0..i]; a segment [a, b) is feasible
        // iff prefix[b] - prefix[a] <= n_cores.
        let mut prefix = vec![0usize; nl + 1];
        for (i, &c) in cores.iter().enumerate() {
            prefix[i + 1] = prefix[i] + c;
        }
        // best[i][k] = minimal (cut, max_shard_cores) covering layers
        // [0, i) with exactly k shards; from[i][k] reconstructs the split.
        let inf = (usize::MAX, usize::MAX);
        let kmax = chips.min(nl);
        let mut best = vec![vec![inf; kmax + 1]; nl + 1];
        let mut from = vec![vec![usize::MAX; kmax + 1]; nl + 1];
        best[0][0] = (0, 0);
        for i in 1..=nl {
            for k in 1..=kmax.min(i) {
                for j in (k - 1)..i {
                    if best[j][k - 1] == inf || prefix[i] - prefix[j] > n_cores {
                        continue;
                    }
                    let (pc, pm) = best[j][k - 1];
                    // Boundary before layer j exists only when shard
                    // k isn't the first; its width is layer j's input
                    // interface = layer j-1's neurons.
                    let cut = pc + if j > 0 { net.layers[j - 1].neurons } else { 0 };
                    let cand = (cut, pm.max(prefix[i] - prefix[j]));
                    if cand < best[i][k] {
                        best[i][k] = cand;
                        from[i][k] = j;
                    }
                }
            }
        }
        // Pick (cut, shard count, balance) lexicographically over k.
        let mut pick: Option<(usize, usize, usize)> = None; // (cut, k, maxc)
        for (k, &(cut, maxc)) in best[nl].iter().enumerate().skip(1) {
            if (cut, maxc) == inf {
                continue;
            }
            let better = match pick {
                None => true,
                Some(p) => (cut, k, maxc) < p,
            };
            if better {
                pick = Some((cut, k, maxc));
            }
        }
        let Some((cut, k, _)) = pick else {
            return Err(Error::Config(format!(
                "network '{}' needs more than {chips} chips ({} cores total, \
                 {n_cores} per chip)",
                net.name, prefix[nl]
            )));
        };
        let mut ranges = Vec::with_capacity(k);
        let (mut i, mut kk) = (nl, k);
        while kk > 0 {
            let j = from[i][kk];
            ranges.push((j, i));
            i = j;
            kk -= 1;
        }
        ranges.reverse();
        Ok(Partition {
            ranges,
            cut_neurons: cut,
        })
    }

    /// Failover replanning: re-partition `net` over the **surviving**
    /// chips of a `chips`-node ring whose `dead` mask marks unreachable
    /// L3 nodes. Same DP, same objective — the exclusion mask only
    /// shrinks the chip budget — plus the assignment of each new shard
    /// to a concrete surviving ring node (ascending node order, so the
    /// shard chain still travels the ring in one direction and the
    /// replan is a pure function of `(net, dead mask, geometry)`).
    ///
    /// Errors when every chip is dead or the survivors cannot host the
    /// network (the cluster then stays in its degraded configuration).
    pub fn replan(
        net: &NetworkDesc,
        chips: usize,
        dead: &[bool],
        n_cores: usize,
        max_neurons_per_core: usize,
    ) -> Result<(Partition, Vec<usize>)> {
        debug_assert_eq!(dead.len(), chips);
        let alive: Vec<usize> = (0..chips).filter(|&c| !dead.get(c).copied().unwrap_or(false)).collect();
        if alive.is_empty() {
            return Err(Error::Config(format!(
                "cluster failover: all {chips} chips are dead — nothing to replan onto"
            )));
        }
        let partition = Self::plan(net, alive.len(), n_cores, max_neurons_per_core)?;
        let nodes = alive[..partition.shards()].to_vec();
        Ok((partition, nodes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::neuron::{LeakMode, NeuronParams, ResetMode};
    use crate::core::Codebook;
    use crate::nn::network::LayerDesc;

    /// A chain of fully-connected layers with the given widths.
    fn chain(widths: &[(usize, usize)]) -> NetworkDesc {
        let cb = Codebook::default_log16();
        let params = NeuronParams {
            threshold: 40,
            leak: LeakMode::Linear(1),
            reset: ResetMode::Subtract,
            mp_bits: 16,
        };
        let layers: Vec<LayerDesc> = widths
            .iter()
            .enumerate()
            .map(|(i, &(inputs, neurons))| LayerDesc {
                name: format!("l{i}"),
                inputs,
                neurons,
                codebook: cb.clone(),
                widx: (0..inputs * neurons).map(|j| ((j * 7) % 16) as u8).collect(),
                neuron_params: params.clone(),
            })
            .collect();
        let classes = widths.last().unwrap().1;
        NetworkDesc {
            name: "chain".into(),
            layers,
            timesteps: 4,
            classes,
        }
    }

    #[test]
    fn single_chip_preferred_when_everything_fits() {
        let net = chain(&[(8, 16), (16, 16), (16, 4)]);
        // 16-neuron layers at 16/core: 1+1+1 = 3 cores, one chip of 20.
        let p = ClusterMapper::plan(&net, 4, 20, 16).unwrap();
        assert_eq!(p.ranges, vec![(0, 3)]);
        assert_eq!(p.cut_neurons, 0, "no boundary, no cut");
        assert_eq!(p.sub_net(&net, 0).layers.len(), 3);
    }

    #[test]
    fn cut_lands_on_the_narrowest_interface() {
        // 3 layers, 2 cores each at capacity 3 per chip: must split 2|1
        // or 1|2. The interface after l0 is 32 neurons, after l1 only 4 —
        // min-cut must choose the narrow waist.
        let net = chain(&[(8, 32), (32, 4), (4, 32)]);
        let p = ClusterMapper::plan(&net, 2, 3, 16).unwrap();
        assert_eq!(p.ranges, vec![(0, 2), (2, 3)]);
        assert_eq!(p.cut_neurons, 4);
        // Shard sub-networks chain correctly and validate.
        let s0 = p.sub_net(&net, 0);
        let s1 = p.sub_net(&net, 1);
        s0.validate().unwrap();
        s1.validate().unwrap();
        assert_eq!(s0.classes, 4, "shard output = boundary width");
        assert_eq!(s1.input_size(), 4, "next shard consumes the boundary");
    }

    #[test]
    fn balance_breaks_ties_between_equal_cuts() {
        // Four 16-neuron layers: every interface is 16 wide, so any
        // single cut costs 16. With 2 chips of 3 cores, a 2|2 split
        // (max 2 cores/shard) must win over 3|1 (max 3).
        let net = chain(&[(8, 16), (16, 16), (16, 16), (16, 16)]);
        let p = ClusterMapper::plan(&net, 2, 3, 16).unwrap();
        assert_eq!(p.cut_neurons, 16);
        assert_eq!(p.ranges, vec![(0, 2), (2, 4)]);
        assert_eq!(p.cores_of(&net, 0, 16), 2);
        assert_eq!(p.cores_of(&net, 1, 16), 2);
    }

    #[test]
    fn infeasible_plans_are_rejected_with_cause() {
        let net = chain(&[(8, 64), (64, 4)]);
        // One 64-neuron layer needs 4 cores; a 3-core chip can never
        // host it, no matter how many chips the ring has.
        let err = ClusterMapper::plan(&net, 8, 3, 16).unwrap_err().to_string();
        assert!(err.contains("alone needs"), "{err}");
        // Feasible per layer but not within the chip budget.
        let net = chain(&[(8, 32), (32, 32), (32, 32), (32, 4)]);
        let err = ClusterMapper::plan(&net, 1, 3, 16).unwrap_err().to_string();
        assert!(err.contains("more than 1 chips"), "{err}");
        assert!(ClusterMapper::plan(&net, 0, 3, 16).is_err(), "chips = 0");
    }

    #[test]
    fn replan_excludes_dead_chips_deterministically() {
        // Depth-4 chain, 2 cores/layer at 3 cores per chip → needs ≥ 3
        // shards on a healthy 4-ring; killing one chip still fits.
        let net = chain(&[(8, 32), (32, 32), (32, 32), (32, 4)]);
        let healthy = ClusterMapper::plan(&net, 4, 3, 16).unwrap();
        assert!(healthy.shards() >= 3);
        let (p, nodes) = ClusterMapper::replan(&net, 4, &[false, true, false, false], 3, 16).unwrap();
        assert_eq!(p.shards(), nodes.len());
        assert!(nodes.iter().all(|&n| n != 1), "dead chip must not host a shard");
        let mut sorted = nodes.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, nodes, "shard chain travels the ring in node order");
        // Deterministic: same mask, same outcome.
        assert_eq!(
            ClusterMapper::replan(&net, 4, &[false, true, false, false], 3, 16).unwrap(),
            (p, nodes)
        );
        // Too few survivors → error, not a bogus plan.
        assert!(ClusterMapper::replan(&net, 4, &[true, true, true, false], 3, 16).is_err());
        assert!(ClusterMapper::replan(&net, 4, &[true; 4], 3, 16).is_err(), "all dead");
        // No dead chips reduces to the base plan on the full ring.
        let (p0, nodes0) = ClusterMapper::replan(&net, 4, &[false; 4], 3, 16).unwrap();
        assert_eq!(p0, ClusterMapper::plan(&net, 4, 3, 16).unwrap());
        assert_eq!(nodes0, (0..p0.shards()).collect::<Vec<_>>());
    }

    #[test]
    fn ranges_always_cover_all_layers_contiguously() {
        let net = chain(&[(8, 32), (32, 16), (16, 32), (32, 8), (8, 4)]);
        for chips in 1..=4 {
            let Ok(p) = ClusterMapper::plan(&net, chips, 4, 16) else {
                continue;
            };
            assert!(p.shards() <= chips);
            assert_eq!(p.ranges[0].0, 0);
            assert_eq!(p.ranges.last().unwrap().1, net.layers.len());
            for w in p.ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous, gap-free cover");
            }
            let cut: usize = p
                .ranges
                .iter()
                .skip(1)
                .map(|&(a, _)| net.layers[a - 1].neurons)
                .sum();
            assert_eq!(cut, p.cut_neurons, "reported cut matches the ranges");
        }
    }
}
