//! RV32IM decoder (+ the custom-0 ENU opcode and `wfi` sleep), with the
//! encoders the in-tree assembler uses. Decode/encode round-trip is
//! property-tested.

use crate::{Error, Result};

/// ALU operation (register-register and register-immediate forms).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AluOp {
    Add,
    Sub,
    Sll,
    Slt,
    Sltu,
    Xor,
    Srl,
    Sra,
    Or,
    And,
}

/// Branch condition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BrOp {
    Beq,
    Bne,
    Blt,
    Bge,
    Bltu,
    Bgeu,
}

/// Load width/sign.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LdOp {
    Lb,
    Lh,
    Lw,
    Lbu,
    Lhu,
}

/// Store width.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StOp {
    Sb,
    Sh,
    Sw,
}

/// M-extension operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MulOp {
    Mul,
    Mulh,
    Mulhsu,
    Mulhu,
    Div,
    Divu,
    Rem,
    Remu,
}

/// A decoded instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Instr {
    Lui { rd: u8, imm: i32 },
    Auipc { rd: u8, imm: i32 },
    Jal { rd: u8, imm: i32 },
    Jalr { rd: u8, rs1: u8, imm: i32 },
    Branch { op: BrOp, rs1: u8, rs2: u8, imm: i32 },
    Load { op: LdOp, rd: u8, rs1: u8, imm: i32 },
    Store { op: StOp, rs1: u8, rs2: u8, imm: i32 },
    OpImm { op: AluOp, rd: u8, rs1: u8, imm: i32 },
    Op { op: AluOp, rd: u8, rs1: u8, rs2: u8 },
    MulDiv { op: MulOp, rd: u8, rs1: u8, rs2: u8 },
    Fence,
    Ecall,
    Ebreak,
    /// `wfi` — the paper's sleep instruction (gates HFCLK).
    Wfi,
    /// Custom-0 ENU instruction: `funct7` selects the neuromorphic
    /// operation, rs1/rs2 carry operands, rd receives status.
    Enu { funct: u8, rd: u8, rs1: u8, rs2: u8 },
}

#[inline]
fn bits(x: u32, hi: u32, lo: u32) -> u32 {
    (x >> lo) & ((1 << (hi - lo + 1)) - 1)
}

#[inline]
fn sext(x: u32, bits_: u32) -> i32 {
    let shift = 32 - bits_;
    ((x << shift) as i32) >> shift
}

/// Decode one 32-bit instruction word.
pub fn decode(w: u32) -> Result<Instr> {
    let opcode = bits(w, 6, 0);
    let rd = bits(w, 11, 7) as u8;
    let funct3 = bits(w, 14, 12);
    let rs1 = bits(w, 19, 15) as u8;
    let rs2 = bits(w, 24, 20) as u8;
    let funct7 = bits(w, 31, 25);
    let i_imm = sext(bits(w, 31, 20), 12);
    let s_imm = sext((bits(w, 31, 25) << 5) | bits(w, 11, 7), 12);
    let b_imm = sext(
        (bits(w, 31, 31) << 12) | (bits(w, 7, 7) << 11) | (bits(w, 30, 25) << 5)
            | (bits(w, 11, 8) << 1),
        13,
    );
    let u_imm = (w & 0xFFFF_F000) as i32;
    let j_imm = sext(
        (bits(w, 31, 31) << 20) | (bits(w, 19, 12) << 12) | (bits(w, 20, 20) << 11)
            | (bits(w, 30, 21) << 1),
        21,
    );

    let bad = || Error::Riscv(format!("illegal instruction {w:#010x}"));

    Ok(match opcode {
        0x37 => Instr::Lui { rd, imm: u_imm },
        0x17 => Instr::Auipc { rd, imm: u_imm },
        0x6F => Instr::Jal { rd, imm: j_imm },
        0x67 => Instr::Jalr { rd, rs1, imm: i_imm },
        0x63 => {
            let op = match funct3 {
                0 => BrOp::Beq,
                1 => BrOp::Bne,
                4 => BrOp::Blt,
                5 => BrOp::Bge,
                6 => BrOp::Bltu,
                7 => BrOp::Bgeu,
                _ => return Err(bad()),
            };
            Instr::Branch { op, rs1, rs2, imm: b_imm }
        }
        0x03 => {
            let op = match funct3 {
                0 => LdOp::Lb,
                1 => LdOp::Lh,
                2 => LdOp::Lw,
                4 => LdOp::Lbu,
                5 => LdOp::Lhu,
                _ => return Err(bad()),
            };
            Instr::Load { op, rd, rs1, imm: i_imm }
        }
        0x23 => {
            let op = match funct3 {
                0 => StOp::Sb,
                1 => StOp::Sh,
                2 => StOp::Sw,
                _ => return Err(bad()),
            };
            Instr::Store { op, rs1, rs2, imm: s_imm }
        }
        0x13 => {
            let op = match funct3 {
                0 => AluOp::Add,
                1 => {
                    if funct7 != 0 {
                        return Err(bad());
                    }
                    AluOp::Sll
                }
                2 => AluOp::Slt,
                3 => AluOp::Sltu,
                4 => AluOp::Xor,
                5 => {
                    if funct7 == 0x20 {
                        AluOp::Sra
                    } else if funct7 == 0 {
                        AluOp::Srl
                    } else {
                        return Err(bad());
                    }
                }
                6 => AluOp::Or,
                7 => AluOp::And,
                _ => return Err(bad()),
            };
            // Shift immediates use only the low 5 bits.
            let imm = if matches!(op, AluOp::Sll | AluOp::Srl | AluOp::Sra) {
                (i_imm & 0x1F) as i32
            } else {
                i_imm
            };
            Instr::OpImm { op, rd, rs1, imm }
        }
        0x33 => {
            if funct7 == 1 {
                let op = match funct3 {
                    0 => MulOp::Mul,
                    1 => MulOp::Mulh,
                    2 => MulOp::Mulhsu,
                    3 => MulOp::Mulhu,
                    4 => MulOp::Div,
                    5 => MulOp::Divu,
                    6 => MulOp::Rem,
                    7 => MulOp::Remu,
                    _ => return Err(bad()),
                };
                Instr::MulDiv { op, rd, rs1, rs2 }
            } else {
                let op = match (funct3, funct7) {
                    (0, 0x00) => AluOp::Add,
                    (0, 0x20) => AluOp::Sub,
                    (1, 0x00) => AluOp::Sll,
                    (2, 0x00) => AluOp::Slt,
                    (3, 0x00) => AluOp::Sltu,
                    (4, 0x00) => AluOp::Xor,
                    (5, 0x00) => AluOp::Srl,
                    (5, 0x20) => AluOp::Sra,
                    (6, 0x00) => AluOp::Or,
                    (7, 0x00) => AluOp::And,
                    _ => return Err(bad()),
                };
                Instr::Op { op, rd, rs1, rs2 }
            }
        }
        0x0F => Instr::Fence,
        0x73 => match w {
            0x0000_0073 => Instr::Ecall,
            0x0010_0073 => Instr::Ebreak,
            0x1050_0073 => Instr::Wfi,
            _ => return Err(bad()),
        },
        // custom-0 (0x0B): the ENU opcode space.
        0x0B => Instr::Enu { funct: funct7 as u8, rd, rs1, rs2 },
        _ => return Err(bad()),
    })
}

// ======================= encoders (assembler backend) =====================

fn r_type(funct7: u32, rs2: u8, rs1: u8, funct3: u32, rd: u8, opcode: u32) -> u32 {
    (funct7 << 25) | ((rs2 as u32) << 20) | ((rs1 as u32) << 15) | (funct3 << 12)
        | ((rd as u32) << 7)
        | opcode
}

fn i_type(imm: i32, rs1: u8, funct3: u32, rd: u8, opcode: u32) -> u32 {
    (((imm as u32) & 0xFFF) << 20) | ((rs1 as u32) << 15) | (funct3 << 12)
        | ((rd as u32) << 7)
        | opcode
}

fn s_type(imm: i32, rs2: u8, rs1: u8, funct3: u32, opcode: u32) -> u32 {
    let imm = imm as u32;
    (bits(imm, 11, 5) << 25) | ((rs2 as u32) << 20) | ((rs1 as u32) << 15) | (funct3 << 12)
        | (bits(imm, 4, 0) << 7)
        | opcode
}

fn b_type(imm: i32, rs2: u8, rs1: u8, funct3: u32) -> u32 {
    let imm = imm as u32;
    (bits(imm, 12, 12) << 31) | (bits(imm, 10, 5) << 25) | ((rs2 as u32) << 20)
        | ((rs1 as u32) << 15)
        | (funct3 << 12)
        | (bits(imm, 4, 1) << 8)
        | (bits(imm, 11, 11) << 7)
        | 0x63
}

fn j_type(imm: i32, rd: u8) -> u32 {
    let imm = imm as u32;
    (bits(imm, 20, 20) << 31) | (bits(imm, 10, 1) << 21) | (bits(imm, 11, 11) << 20)
        | (bits(imm, 19, 12) << 12)
        | ((rd as u32) << 7)
        | 0x6F
}

/// Encode an instruction back to its 32-bit word.
pub fn encode(i: &Instr) -> u32 {
    use Instr::*;
    match *i {
        Lui { rd, imm } => ((imm as u32) & 0xFFFF_F000) | ((rd as u32) << 7) | 0x37,
        Auipc { rd, imm } => ((imm as u32) & 0xFFFF_F000) | ((rd as u32) << 7) | 0x17,
        Jal { rd, imm } => j_type(imm, rd),
        Jalr { rd, rs1, imm } => i_type(imm, rs1, 0, rd, 0x67),
        Branch { op, rs1, rs2, imm } => {
            let f3 = match op {
                BrOp::Beq => 0,
                BrOp::Bne => 1,
                BrOp::Blt => 4,
                BrOp::Bge => 5,
                BrOp::Bltu => 6,
                BrOp::Bgeu => 7,
            };
            b_type(imm, rs2, rs1, f3)
        }
        Load { op, rd, rs1, imm } => {
            let f3 = match op {
                LdOp::Lb => 0,
                LdOp::Lh => 1,
                LdOp::Lw => 2,
                LdOp::Lbu => 4,
                LdOp::Lhu => 5,
            };
            i_type(imm, rs1, f3, rd, 0x03)
        }
        Store { op, rs1, rs2, imm } => {
            let f3 = match op {
                StOp::Sb => 0,
                StOp::Sh => 1,
                StOp::Sw => 2,
            };
            s_type(imm, rs2, rs1, f3, 0x23)
        }
        OpImm { op, rd, rs1, imm } => {
            let (f3, high) = match op {
                AluOp::Add => (0, 0),
                AluOp::Sll => (1, 0),
                AluOp::Slt => (2, 0),
                AluOp::Sltu => (3, 0),
                AluOp::Xor => (4, 0),
                AluOp::Srl => (5, 0),
                AluOp::Sra => (5, 0x20 << 5),
                AluOp::Or => (6, 0),
                AluOp::And => (7, 0),
                AluOp::Sub => unreachable!("no subi"),
            };
            i_type(imm, rs1, f3, rd, 0x13) | (high << 20)
        }
        Op { op, rd, rs1, rs2 } => {
            let (f3, f7) = match op {
                AluOp::Add => (0, 0x00),
                AluOp::Sub => (0, 0x20),
                AluOp::Sll => (1, 0x00),
                AluOp::Slt => (2, 0x00),
                AluOp::Sltu => (3, 0x00),
                AluOp::Xor => (4, 0x00),
                AluOp::Srl => (5, 0x00),
                AluOp::Sra => (5, 0x20),
                AluOp::Or => (6, 0x00),
                AluOp::And => (7, 0x00),
            };
            r_type(f7, rs2, rs1, f3, rd, 0x33)
        }
        MulDiv { op, rd, rs1, rs2 } => {
            let f3 = match op {
                MulOp::Mul => 0,
                MulOp::Mulh => 1,
                MulOp::Mulhsu => 2,
                MulOp::Mulhu => 3,
                MulOp::Div => 4,
                MulOp::Divu => 5,
                MulOp::Rem => 6,
                MulOp::Remu => 7,
            };
            r_type(1, rs2, rs1, f3, rd, 0x33)
        }
        Fence => 0x0000_000F,
        Ecall => 0x0000_0073,
        Ebreak => 0x0010_0073,
        Wfi => 0x1050_0073,
        Enu { funct, rd, rs1, rs2 } => r_type(funct as u32, rs2, rs1, 0, rd, 0x0B),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;

    #[test]
    fn known_encodings() {
        // addi x1, x0, 5
        assert_eq!(
            decode(0x0050_0093).unwrap(),
            Instr::OpImm { op: AluOp::Add, rd: 1, rs1: 0, imm: 5 }
        );
        // add x3, x1, x2
        assert_eq!(
            decode(0x0020_81B3).unwrap(),
            Instr::Op { op: AluOp::Add, rd: 3, rs1: 1, rs2: 2 }
        );
        // wfi
        assert_eq!(decode(0x1050_0073).unwrap(), Instr::Wfi);
        // mul x5, x6, x7
        assert_eq!(
            decode(0x0273_02B3).unwrap(),
            Instr::MulDiv { op: MulOp::Mul, rd: 5, rs1: 6, rs2: 7 }
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode(0xFFFF_FFFF).is_err());
        assert!(decode(0x0000_0000).is_err());
    }

    #[test]
    fn encode_decode_roundtrip_property() {
        check("rv32im-roundtrip", 500, 0xC0FFEE, |r| {
            let rd = r.below(32) as u8;
            let rs1 = r.below(32) as u8;
            let rs2 = r.below(32) as u8;
            let instr = match r.below(12) {
                0 => Instr::Lui { rd, imm: ((r.next_u32() as i32) & !0xFFF) },
                1 => Instr::Jal { rd, imm: (r.range_i64(-(1 << 19), (1 << 19) - 1) as i32) * 2 },
                2 => Instr::Jalr { rd, rs1, imm: r.range_i64(-2048, 2047) as i32 },
                3 => Instr::Branch {
                    op: BrOp::Bne,
                    rs1,
                    rs2,
                    imm: (r.range_i64(-2048, 2047) as i32) * 2,
                },
                4 => Instr::Load { op: LdOp::Lw, rd, rs1, imm: r.range_i64(-2048, 2047) as i32 },
                5 => Instr::Store { op: StOp::Sw, rs1, rs2, imm: r.range_i64(-2048, 2047) as i32 },
                6 => Instr::OpImm { op: AluOp::Xor, rd, rs1, imm: r.range_i64(-2048, 2047) as i32 },
                7 => Instr::Op { op: AluOp::Sub, rd, rs1, rs2 },
                8 => Instr::MulDiv { op: MulOp::Divu, rd, rs1, rs2 },
                9 => Instr::OpImm { op: AluOp::Sra, rd, rs1, imm: r.below(32) as i32 },
                10 => Instr::Enu { funct: r.below(128) as u8, rd, rs1, rs2 },
                _ => Instr::Wfi,
            };
            let w = encode(&instr);
            let back = decode(w).unwrap_or_else(|e| panic!("{e} for {instr:?} ({w:#x})"));
            assert_eq!(back, instr, "word {w:#010x}");
        });
    }

    #[test]
    fn branch_immediate_reconstruction() {
        let i = Instr::Branch { op: BrOp::Beq, rs1: 1, rs2: 2, imm: -8 };
        assert_eq!(decode(encode(&i)).unwrap(), i);
        let i = Instr::Branch { op: BrOp::Bgeu, rs1: 31, rs2: 30, imm: 4094 };
        assert_eq!(decode(encode(&i)).unwrap(), i);
    }
}
