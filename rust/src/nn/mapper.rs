//! Neuron→core mapper: splits each layer of a [`NetworkDesc`] across the
//! chip's 20 neuromorphic cores and derives the NoC multicast plan.
//!
//! Placement rules (matching the hardware constraints):
//! - a core hosts neurons of exactly **one** layer (a core has a single
//!   shared codebook and a single neuron-parameter set);
//! - at most `max_neurons_per_core` neurons per core (chip: 8192);
//! - every core of a layer receives the layer's **full input axon space**
//!   (fan-in is resolved inside the core through its synapse table), so a
//!   presynaptic spike is **broadcast** to all cores of the next layer —
//!   this is exactly the broadcast transmission mode the CMRouter
//!   provides.

use super::network::{NetworkDesc, NO_SYNAPSE};
use crate::core::{NeuroCore, Synapses, SynapsesBuilder};
use crate::energy::EnergyParams;
use crate::{Error, Result};

/// One physical core's assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CorePlacement {
    /// Physical core id (0..n_cores).
    pub core_id: usize,
    /// Layer index this core serves.
    pub layer: usize,
    /// First layer-local neuron hosted here.
    pub neuron_offset: usize,
    /// Number of neurons hosted here.
    pub neurons: usize,
    /// Axons (= the layer's input width).
    pub axons: usize,
}

/// A complete mapping of a network onto the chip.
#[derive(Debug, Clone)]
pub struct Mapping {
    /// Per-core assignments (dense, one entry per used core).
    pub placements: Vec<CorePlacement>,
    /// Physical cores used by each layer.
    pub layer_cores: Vec<Vec<usize>>,
}

impl Mapping {
    /// Map `net` onto `n_cores` cores with at most `max_neurons_per_core`
    /// neurons each.
    pub fn plan(net: &NetworkDesc, n_cores: usize, max_neurons_per_core: usize) -> Result<Mapping> {
        net.validate()?;
        let mut placements = Vec::new();
        let mut layer_cores = Vec::new();
        let mut next_core = 0usize;
        for (li, layer) in net.layers.iter().enumerate() {
            let mut cores_of_layer = Vec::new();
            let mut off = 0usize;
            while off < layer.neurons {
                if next_core >= n_cores {
                    return Err(Error::Mapping(format!(
                        "network needs more than {n_cores} cores \
                         (stuck at layer {li} neuron {off})"
                    )));
                }
                let take = (layer.neurons - off).min(max_neurons_per_core);
                placements.push(CorePlacement {
                    core_id: next_core,
                    layer: li,
                    neuron_offset: off,
                    neurons: take,
                    axons: layer.inputs,
                });
                cores_of_layer.push(next_core);
                next_core += 1;
                off += take;
            }
            layer_cores.push(cores_of_layer);
        }
        Ok(Mapping {
            placements,
            layer_cores,
        })
    }

    /// Cores used in total.
    pub fn cores_used(&self) -> usize {
        self.placements.len()
    }

    /// The placement hosted on physical core `core_id` (if any).
    pub fn placement_of(&self, core_id: usize) -> Option<&CorePlacement> {
        self.placements.iter().find(|p| p.core_id == core_id)
    }

    /// Broadcast destination set for spikes leaving layer `li`
    /// (`None` for the last layer — its spikes go to the output buffer).
    pub fn dest_cores_after(&self, li: usize) -> Option<&[usize]> {
        self.layer_cores.get(li + 1).map(Vec::as_slice)
    }

    /// Build the synapse table for one placement from the network
    /// description (pruned synapses skipped).
    pub fn synapses_for(&self, net: &NetworkDesc, p: &CorePlacement) -> Result<Synapses> {
        let layer = &net.layers[p.layer];
        let mut b = SynapsesBuilder::new(p.axons, p.neurons, layer.codebook.n());
        for a in 0..p.axons {
            for n in 0..p.neurons {
                let w = layer.index_of(a, p.neuron_offset + n);
                if w != NO_SYNAPSE {
                    b.connect(a, n, w)?;
                }
            }
        }
        Ok(b.build())
    }

    /// Instantiate all [`NeuroCore`]s for this mapping.
    pub fn build_cores(&self, net: &NetworkDesc, energy: &EnergyParams) -> Result<Vec<NeuroCore>> {
        self.placements
            .iter()
            .map(|p| {
                let layer = &net.layers[p.layer];
                NeuroCore::new(
                    p.core_id as u8,
                    p.axons,
                    p.neurons,
                    layer.neuron_params.clone(),
                    layer.codebook.clone(),
                    self.synapses_for(net, p)?,
                    energy.clone(),
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::neuron::{LeakMode, NeuronParams, ResetMode};
    use crate::core::Codebook;
    use crate::nn::network::LayerDesc;

    fn net(inputs: usize, hidden: usize, out: usize) -> NetworkDesc {
        let cb = Codebook::default_log16();
        let params = NeuronParams {
            threshold: 30,
            leak: LeakMode::None,
            reset: ResetMode::Subtract,
            mp_bits: 16,
        };
        NetworkDesc {
            name: "t".into(),
            layers: vec![
                LayerDesc {
                    name: "h".into(),
                    inputs,
                    neurons: hidden,
                    codebook: cb.clone(),
                    widx: (0..inputs * hidden).map(|i| (i % 16) as u8).collect(),
                    neuron_params: params.clone(),
                },
                LayerDesc {
                    name: "o".into(),
                    inputs: hidden,
                    neurons: out,
                    codebook: cb,
                    widx: (0..hidden * out).map(|i| (i % 16) as u8).collect(),
                    neuron_params: params,
                },
            ],
            timesteps: 4,
            classes: out,
        }
    }

    #[test]
    fn splits_layers_across_cores() {
        let n = net(64, 100, 10);
        let m = Mapping::plan(&n, 20, 40).unwrap();
        // hidden needs ceil(100/40)=3 cores, out needs 1.
        assert_eq!(m.cores_used(), 4);
        assert_eq!(m.layer_cores[0], vec![0, 1, 2]);
        assert_eq!(m.layer_cores[1], vec![3]);
        // Every neuron placed exactly once.
        let covered: usize = m
            .placements
            .iter()
            .filter(|p| p.layer == 0)
            .map(|p| p.neurons)
            .sum();
        assert_eq!(covered, 100);
        // Offsets are contiguous.
        let mut offs: Vec<(usize, usize)> = m
            .placements
            .iter()
            .filter(|p| p.layer == 0)
            .map(|p| (p.neuron_offset, p.neurons))
            .collect();
        offs.sort_unstable();
        assert_eq!(offs, vec![(0, 40), (40, 40), (80, 20)]);
    }

    #[test]
    fn too_large_network_rejected() {
        let n = net(64, 10_000, 10);
        assert!(Mapping::plan(&n, 20, 400).is_err());
    }

    #[test]
    fn dest_cores_point_to_next_layer() {
        let n = net(64, 100, 10);
        let m = Mapping::plan(&n, 20, 40).unwrap();
        assert_eq!(m.dest_cores_after(0), Some(&[3usize][..]));
        assert_eq!(m.dest_cores_after(1), None);
    }

    #[test]
    fn built_cores_match_placements() {
        let n = net(32, 50, 10);
        let m = Mapping::plan(&n, 20, 30).unwrap();
        let cores = m.build_cores(&n, &EnergyParams::nominal()).unwrap();
        assert_eq!(cores.len(), m.cores_used());
        for (core, p) in cores.iter().zip(&m.placements) {
            assert_eq!(core.regs().neurons, p.neurons);
            assert_eq!(core.regs().axons, p.axons);
            assert_eq!(core.regs().core_id() as usize, p.core_id);
        }
    }

    #[test]
    fn synapse_tables_respect_offsets() {
        let n = net(8, 6, 2);
        let m = Mapping::plan(&n, 20, 4).unwrap();
        // Layer 0 split into cores of 4 + 2 neurons.
        let p1 = &m.placements[1];
        assert_eq!(p1.neuron_offset, 4);
        let syn = m.synapses_for(&n, p1).unwrap();
        // Core-local neuron 0 = layer neuron 4: index (a*6 + 4) % 16.
        let (targets, widx) = syn.slices_of(0);
        assert_eq!(targets[0], 0);
        assert_eq!(widx[0], n.layers[0].index_of(0, 4));
    }
}
