//! The "traditional scheme" baseline core used for the paper's 2.69×
//! energy-efficiency comparison (Fig. 3).
//!
//! Differences from [`super::NeuroCore`]:
//!
//! - **no zero-skip**: every axon's synapse list is walked every timestep;
//!   a zero spike contributes `w × 0` but still costs a full synapse
//!   operation (weight-index fetch + codebook read + add);
//! - **full membrane-potential update**: every neuron is
//!   read-modified-written every timestep (leak applies to all neurons),
//!   instead of the partial touched-only update.
//!
//! Useful-SOP accounting: only synapse ops triggered by *valid* spikes
//! count as useful SOPs (that is what Fig. 3's pJ/SOP denominators use on
//! both designs), while the baseline's energy also pays for the wasted
//! zero-spike walks — that asymmetry is precisely the 2.69× story.

use super::codebook::Codebook;
use super::neuron::{NeuronArray, NeuronParams};
use super::synapses::Synapses;
use crate::energy::{EnergyLedger, EnergyParams, EventClass};
use crate::Result;


/// Statistics for one baseline-core timestep.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DenseStats {
    /// Synapse walks performed (all axons × fanout).
    pub synapse_walks: u64,
    /// Of which triggered by valid spikes (useful SOPs).
    pub useful_sops: u64,
    /// Neurons updated (always all neurons).
    pub neurons_updated: u64,
    /// Spikes fired.
    pub spikes_fired: u64,
    /// Total cycles.
    pub cycles: u64,
}

/// The dense baseline core.
#[derive(Debug, Clone)]
pub struct DenseCore {
    axons: usize,
    codebook: Codebook,
    synapses: Synapses,
    neurons: NeuronArray,
    staged: Vec<bool>,
    current: Vec<bool>,
    acc: Vec<i32>,
    /// Neurons that received at least one accumulation this timestep —
    /// they pay the full MP update; the rest pay the leak-only pass.
    touched: Vec<bool>,
    ledger: EnergyLedger,
    energy: EnergyParams,
    total_cycles: u64,
}

impl DenseCore {
    /// Assemble a baseline core with the same network contents as a
    /// [`super::NeuroCore`].
    pub fn new(
        axons: usize,
        neurons: usize,
        neuron_params: NeuronParams,
        codebook: Codebook,
        synapses: Synapses,
        energy: EnergyParams,
    ) -> Result<Self> {
        if synapses.axons() != axons {
            return Err(crate::Error::Core(format!(
                "synapse table covers {} axons, core has {}",
                synapses.axons(),
                axons
            )));
        }
        Ok(DenseCore {
            axons,
            codebook,
            synapses,
            neurons: NeuronArray::new(neurons, neuron_params),
            staged: vec![false; axons],
            current: vec![false; axons],
            acc: vec![0; neurons],
            touched: vec![false; neurons],
            ledger: EnergyLedger::new(),
            energy,
            total_cycles: 0,
        })
    }

    /// Stage input spikes (axon ids) for the next timestep.
    pub fn stage_input_spikes(&mut self, axons_in: &[u32]) {
        self.staged.iter_mut().for_each(|s| *s = false);
        for &a in axons_in {
            if (a as usize) < self.axons {
                self.staged[a as usize] = true;
            }
        }
    }

    /// Execute one timestep the traditional way.
    pub fn tick_timestep(&mut self) -> (Vec<u32>, DenseStats) {
        std::mem::swap(&mut self.staged, &mut self.current);
        // Consume-on-read (see NeuroCore): don't replay stale spikes.
        self.staged.iter_mut().for_each(|s| *s = false);
        let mut st = DenseStats::default();

        // Walk EVERY synapse of EVERY axon (no zero-skip).
        for a in 0..self.axons {
            let spiking = self.current[a];
            let (targets, widx) = self.synapses.slices_of(a);
            for (&t, &w) in targets.iter().zip(widx) {
                if spiking {
                    let ti = t as usize;
                    self.acc[ti] = self.acc[ti].saturating_add(self.codebook.weight(w));
                    self.touched[ti] = true;
                    st.useful_sops += 1;
                }
                st.synapse_walks += 1;
            }
        }

        // Update EVERY neuron (leak everywhere — the baseline cannot
        // skip). Neurons that accumulated input pay the full MP
        // read-modify-write; untouched neurons pay the cheaper leak-only
        // pass (`e_mp_leak_only` — the cost the sparse design's partial
        // update eliminates entirely).
        let mut spikes = Vec::new();
        let mut touched_n = 0u64;
        for n in 0..self.neurons.len() {
            if self.touched[n] {
                touched_n += 1;
                self.touched[n] = false;
            }
            if self.neurons.update_one(n, self.acc[n]) {
                spikes.push(n as u32);
            }
            self.acc[n] = 0;
        }
        st.neurons_updated = self.neurons.len() as u64;
        st.spikes_fired = spikes.len() as u64;

        // Cycles: synapse walks at the same 4-lane rate, plus the full
        // neuron drain, plus the spike-word cache reads.
        let words = self.axons.div_ceil(super::SPIKE_WORD_BITS) as u64;
        st.cycles = words + st.synapse_walks.div_ceil(4) + st.neurons_updated;
        self.total_cycles += st.cycles;

        // Energy: every walk is priced as a full SOP; touched neurons
        // pay the full MP update, the rest the leak-only pass.
        self.ledger.add(EventClass::CacheRead, words);
        self.ledger.add(EventClass::Sop, st.synapse_walks);
        self.ledger.add(EventClass::MpUpdate, touched_n);
        self.ledger
            .add(EventClass::MpLeakOnly, st.neurons_updated - touched_n);
        self.ledger.add(EventClass::SpikeFire, st.spikes_fired);

        (spikes, st)
    }

    /// Account static power over a window (the baseline cannot gate).
    pub fn finish_window(&mut self, window_cycles: u64) {
        self.ledger.add_static(
            "dense-core",
            window_cycles,
            0,
            self.energy.p_core_active,
            self.energy.p_core_gated,
        );
        self.total_cycles = 0;
    }

    /// Busy cycles since last window.
    pub fn busy_cycles(&self) -> u64 {
        self.total_cycles
    }

    /// The energy ledger.
    pub fn ledger(&self) -> &EnergyLedger {
        &self.ledger
    }

    /// Neuron array (for functional comparisons).
    pub fn neurons(&self) -> &NeuronArray {
        &self.neurons
    }

    /// Energy per *useful* SOP over everything recorded so far.
    pub fn pj_per_useful_sop(&self, f_hz: f64, useful_sops: u64) -> Option<f64> {
        (useful_sops > 0).then(|| self.ledger.total_pj(&self.energy, f_hz) / useful_sops as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::neuron::{LeakMode, ResetMode};
    use crate::core::synapses::SynapsesBuilder;

    fn baseline() -> DenseCore {
        let cb = Codebook::default_log16();
        let mut b = SynapsesBuilder::new(32, 8, cb.n());
        b.connect_dense(|_, _| 12).unwrap(); // weight 14
        DenseCore::new(
            32,
            8,
            NeuronParams {
                threshold: 50,
                leak: LeakMode::None,
                reset: ResetMode::Subtract,
                mp_bits: 16,
            },
            cb,
            b.build(),
            EnergyParams::nominal(),
        )
        .unwrap()
    }

    #[test]
    fn walks_all_synapses_regardless_of_sparsity() {
        let mut c = baseline();
        c.stage_input_spikes(&[0]);
        let (_, st) = c.tick_timestep();
        assert_eq!(st.synapse_walks, 32 * 8);
        assert_eq!(st.useful_sops, 8);
        assert_eq!(st.neurons_updated, 8);
    }

    #[test]
    fn functional_output_matches_sparse_core_without_leak() {
        // With LeakMode::None, dense and sparse semantics coincide.
        let mut d = baseline();
        d.stage_input_spikes(&[0, 5, 16, 31]);
        let (spikes, _) = d.tick_timestep();
        assert_eq!(spikes, (0..8).collect::<Vec<u32>>());
        assert!(d.neurons().mps().iter().all(|&m| m == 6));
    }

    #[test]
    fn energy_pays_for_wasted_walks() {
        let mut c = baseline();
        c.stage_input_spikes(&[0]); // 1 of 32 axons spiking
        let (_, st) = c.tick_timestep();
        let pj = c.pj_per_useful_sop(200.0e6, st.useful_sops).unwrap();
        // 256 walks priced for 8 useful sops → ≥ 32× the raw SOP energy.
        assert!(pj > EnergyParams::nominal().e_sop * 30.0);
    }
}
