//! The assembled SoC: RISC-V CPU (+ENU) ⇄ neuromorphic processor
//! (20 cores + fullerene NoC) ⇄ DMA/output-buffer plumbing, executing
//! event-stream workloads end-to-end under the calibrated energy model.
//!
//! Execution model of one sample (one inference):
//!
//! 1. **Boot** (once per [`Soc`]): the MNIST control firmware runs on the
//!    CPU; its ENU commands are consumed — `NetParamInit` streams the
//!    weight-index tables through IDMA, `CoreEnable` ungates the mapped
//!    cores, `NetworkStart` marks the network busy.
//! 2. **Per timestep** `t`: input events are DMA'd into the layer-0
//!    cores' ping-pong caches (staging **OR-merges**, so multiple sources
//!    within a timestep compose); each layer's **staged** cores are
//!    ticked in order — the scheduler's worklist skips cores with no
//!    pending spike words, so an idle core costs zero active cycles
//!    (pinned by the `cores_ticked` counter) — and output spikes are
//!    **broadcast** through the fullerene NoC to the cores of the
//!    next layer (the CMRouter broadcast mode — one flit copy per
//!    destination core, cheap per-hop energy); final-layer spikes land in
//!    output buffer 0. The CPU is woken by the timestep-switch signal,
//!    acknowledges via `enu.tsack`, and goes back to sleep.
//! 3. **Finish**: the network-finish wake lets the firmware read the
//!    result word (`winner << 16 | spike_count`) through `enu.result`.
//!
//! Timestep wall-cycle model (documented, deliberately serial): layers
//! execute back-to-back within a timestep (the chip pipelines them across
//! timesteps; serialization is the conservative bound), so
//! `ts_cycles = Σ_layers max(core cycles) + NoC drain + DMA cycles`.

use super::bus::NeuroBus;
use super::clockmgr::ClockManager;
use super::dma::{Dma, DmaKind};
use super::outbuf::OutputBuffers;
use crate::core::NeuroCore;
use crate::datasets::{Dataset, Sample};
use crate::energy::{AreaModel, ChipReport, EnergyLedger, EnergyParams};
use crate::nn::{Mapping, NetworkDesc};
use crate::noc::{Dest, FabricHealth, FaultPlan, NocSim, NodeKind, Topology};
use crate::riscv::cpu::{Cpu, CpuState, WakeEvent};
use crate::riscv::enu::EnuCommand;
use crate::riscv::firmware;
use crate::{Error, Result};

/// SoC configuration.
#[derive(Debug, Clone)]
pub struct SocConfig {
    /// Fullerene routing domains. 1 = the paper's single chip; >1 builds
    /// the scale-up system ([`Topology::multi_domain`]): each domain adds
    /// 20 cores, 12 L1 routers and a level-2 centre router, with the L2
    /// routers joined in a ring — all cycle-simulated.
    pub domains: usize,
    /// Physical neuromorphic cores (paper: 20 per domain).
    pub n_cores: usize,
    /// Max neurons per core (paper: 8192).
    pub max_neurons_per_core: usize,
    /// NoC FIFO depth per port.
    pub fifo_depth: usize,
    /// Neuromorphic-processor clock (Hz).
    pub f_core_hz: f64,
    /// RISC-V clock (Hz).
    pub f_cpu_hz: f64,
    /// Supply voltage (V).
    pub supply_v: f64,
    /// Route inter-layer spikes through the cycle-accurate NoC simulator
    /// (true) or an ideal zero-latency fabric (false — for fast sweeps;
    /// energy is still charged per hop from the topology distances).
    pub use_noc: bool,
    /// Run the RISC-V firmware protocol (false = drive the neuromorphic
    /// processor directly, for benches isolating the cores).
    pub drive_cpu: bool,
    /// Deterministic fabric fault schedule, armed on the NoC at build
    /// time (resilience experiments; see [`crate::noc::fault`]). The
    /// default empty plan is provably free: the chip is bit-identical to
    /// one built before fault injection existed.
    pub fault_plan: FaultPlan,
    /// Chips in the simulated cluster (1 = the paper's single device).
    /// A multi-chip config cannot assemble a bare [`Soc`] — it builds a
    /// [`crate::cluster::Cluster`] (one `Soc` per network shard plus the
    /// off-chip L3 router ring joining them) through
    /// [`crate::serve::SocBuilder`] or the serving runtime.
    pub chips: usize,
    /// Cluster shard failover: when an off-chip L3 ring node dies
    /// mid-session, re-partition the network over the surviving chips
    /// ([`crate::cluster::ClusterMapper::replan`]) at the next sample
    /// boundary instead of serving degraded forever. Off by default —
    /// the disabled path is bit-identical to a cluster built before
    /// failover existed. Meaningless (and ignored) at `chips == 1`.
    pub failover: bool,
}

impl Default for SocConfig {
    fn default() -> Self {
        SocConfig {
            domains: 1,
            n_cores: 20,
            max_neurons_per_core: 8192,
            fifo_depth: 4,
            f_core_hz: 100.0e6,
            f_cpu_hz: 50.0e6,
            supply_v: crate::energy::constants::V_NOM,
            use_noc: true,
            drive_cpu: true,
            fault_plan: FaultPlan::none(),
            chips: 1,
            failover: false,
        }
    }
}

/// Ideal-fabric per-pair routing cost, derived by walking the *real*
/// next-hop table (so the no-NoC energy path follows the same
/// hierarchical policy as the cycle simulator, including L2 classes).
#[derive(Debug, Clone, Copy, Default)]
struct HopCost {
    /// Arrivals at level-1 routers.
    l1_hops: u32,
    /// Arrivals at level-2 routers.
    l2_hops: u32,
    /// Link traversals within the level-1 fabric.
    links: u32,
    /// Link traversals with a level-2 endpoint.
    l2_links: u32,
}

/// Outcome of a batch [`Soc::run_dataset`] call: accuracy plus the work
/// counters of exactly that batch (not the chip's lifetime totals).
#[derive(Debug, Clone)]
pub struct DatasetOutcome {
    /// Fraction of samples classified correctly.
    pub accuracy: f64,
    /// Samples actually run (dataset size clipped by the limit).
    pub samples: u64,
    /// Correctly classified samples.
    pub correct: u64,
    /// Synapse operations performed by this batch.
    pub sops: u64,
    /// Core-clock cycles consumed by this batch.
    pub cycles: u64,
    /// Spike flits routed through the NoC by this batch.
    pub spikes_routed: u64,
}

/// Result of one inference.
#[derive(Debug, Clone)]
pub struct SampleResult {
    /// Predicted class.
    pub predicted: usize,
    /// Per-class output spike counts.
    pub counts: Vec<u32>,
    /// Whether the prediction matched the label.
    pub correct: bool,
    /// Core-clock cycles consumed by this sample.
    pub cycles: u64,
    /// Synapse operations performed.
    pub sops: u64,
    /// Spike flits routed through the NoC **by this sample** (like
    /// `cycles`/`sops`, a per-sample figure — the accounting-window total
    /// lives in [`crate::energy::ChipReport::spikes_routed`]).
    pub spikes_routed: u64,
    /// Core ticks executed for this sample. The scheduler ticks only
    /// cores with pending spike words, so this is an activity measure:
    /// an idle layer-timestep contributes zero (the pre-worklist engine
    /// would have contributed every placed core every timestep).
    pub cores_ticked: u64,
}

/// The one power-on control-CPU recipe, shared by [`Soc::new`] and
/// [`Soc::reset_for_session`] so the warm-equals-fresh bit-identity
/// contract cannot be broken by editing one construction site without
/// the other.
fn power_on_cpu() -> Cpu {
    Cpu::new(64 * 1024, true)
}

/// The assembled chip.
pub struct Soc {
    /// Configuration.
    pub config: SocConfig,
    net: NetworkDesc,
    mapping: Mapping,
    cores: Vec<NeuroCore>,
    /// physical core id → index into `cores` (usize::MAX = unused).
    core_index: Vec<usize>,
    noc: NocSim,
    /// The control CPU.
    pub cpu: Cpu,
    bus: NeuroBus,
    idma: Dma,
    mpdma: Dma,
    outbufs: OutputBuffers,
    clocks: ClockManager,
    energy: EnergyParams,
    area: AreaModel,
    ledger: EnergyLedger,
    booted: bool,
    params_loaded: bool,
    // --- run accounting ---------------------------------------------------
    total_cycles: u64,
    total_sops: u64,
    spikes_routed: u64,
    samples_run: u64,
    /// Samples run with a known label (the accuracy denominator —
    /// unlabelled serving pushes must not dilute accuracy).
    labelled: u64,
    correct: u64,
    /// Core ticks executed this accounting window (the worklist
    /// regression counter: idle layer-timesteps must not grow it).
    cores_ticked: u64,
    /// Cached core→core routing costs for the ideal-fabric energy charge.
    hop_table: Vec<Vec<HopCost>>,
    /// Per-layer broadcast destination sets, precomputed so the routing
    /// hot path builds no `Dest` per layer per timestep (`None` for the
    /// last layer — its spikes go to the output buffer).
    layer_dests: Vec<Option<Dest>>,
    // --- hot-path scratch (reused across layers/timesteps) ----------------
    /// Per-destination-core staging lists for spike delivery.
    route_scratch: Vec<Vec<u32>>,
    /// (source core, axon) pairs firing out of the current layer.
    firing_scratch: Vec<(usize, u32)>,
    // --- in-progress sample accounting -------------------------------------
    // Valid between `sample_begin` and `sample_end`; written by the
    // decomposed sample path so `run_sample` and the cluster's
    // timestep-interleaved driver share one accounting implementation.
    /// Cycles consumed by the in-progress sample so far.
    cur_cycles: u64,
    /// Synapse operations performed by the in-progress sample so far.
    cur_sops: u64,
    /// `cores_ticked` at `sample_begin` (per-sample delta baseline).
    cur_ticked_before: u64,
    /// `spikes_routed` at `sample_begin` (per-sample delta baseline).
    cur_routed_before: u64,
}

impl Soc {
    /// Assemble a chip running `net` under `config`.
    pub fn new(net: NetworkDesc, config: SocConfig) -> Result<Soc> {
        net.validate()?;
        let energy = EnergyParams::nominal().at_voltage(config.supply_v);
        let mapping = Mapping::plan(&net, config.n_cores, config.max_neurons_per_core)?;
        let cores = mapping.build_cores(&net, &energy)?;
        let mut core_index = vec![usize::MAX; config.n_cores];
        for (i, p) in mapping.placements.iter().enumerate() {
            core_index[p.core_id] = i;
        }
        if config.domains == 0 {
            return Err(Error::Soc("domains must be >= 1".into()));
        }
        if config.chips == 0 {
            return Err(Error::Soc("chips must be >= 1".into()));
        }
        if config.chips > 1 {
            return Err(Error::Soc(format!(
                "config asks for {} chips: a bare Soc is a single chip — build a \
                 cluster instead (serve::SocBuilder / --chips)",
                config.chips
            )));
        }
        // One plain fullerene domain for the paper's chip; the simulated
        // hierarchical fabric (L1 + L2 ring) for scale-up systems.
        let topo = if config.domains == 1 {
            Topology::fullerene()
        } else {
            Topology::multi_domain(config.domains)
        };
        if config.n_cores > topo.cores().len() {
            return Err(Error::Soc(format!(
                "{} cores requested but {} fullerene domain(s) have {}",
                config.n_cores,
                config.domains,
                topo.cores().len()
            )));
        }
        // Core→core routing costs for the ideal fabric, by walking the
        // same hierarchical next-hop table the cycle simulator routes
        // with — BFS link counts would shortcut intra-domain traffic
        // through L2 and miss the L2 energy classes.
        let table = topo.next_hop_table();
        let n_c = topo.cores().len();
        let mut hop_table = vec![vec![HopCost::default(); n_c]; n_c];
        for (i, &ci) in topo.cores().iter().enumerate() {
            for (j, hop) in hop_table[i].iter_mut().enumerate() {
                if i == j {
                    continue;
                }
                let dst = topo.core_node(j);
                let mut cost = HopCost::default();
                let mut cur = ci;
                let mut steps = 0usize;
                while cur != dst {
                    let next = table[cur][j];
                    debug_assert_ne!(next, usize::MAX, "unroutable core pair");
                    let cur_l2 = matches!(topo.kind(cur), NodeKind::RouterL2(_));
                    match topo.kind(next) {
                        NodeKind::RouterL1(_) => {
                            cost.l1_hops += 1;
                            if cur_l2 {
                                cost.l2_links += 1;
                            } else {
                                cost.links += 1;
                            }
                        }
                        NodeKind::RouterL2(_) => {
                            cost.l2_hops += 1;
                            cost.l2_links += 1;
                        }
                        NodeKind::Core(_) => {
                            cost.links += 1;
                        }
                    }
                    cur = next;
                    steps += 1;
                    debug_assert!(steps <= topo.len(), "routing loop in hop table");
                }
                *hop = cost;
            }
        }
        // Serving chips keep only the NoC ledger + streaming accumulators
        // (no per-flit trace): long-lived sessions no longer grow without
        // bound. Functional delivery flows through the ejection staging
        // buffer, drained after every routed layer.
        let mut noc = NocSim::new(topo, config.fifo_depth, energy.clone());
        noc.set_trace_mode(crate::noc::TraceMode::Off);
        noc.set_collect_ejected(true);
        // Arm the (possibly empty) fault schedule; invalid plans — kills
        // naming cores or absent links — are rejected at build time.
        noc.set_fault_plan(config.fault_plan.clone())?;
        let clocks = ClockManager::new(config.f_core_hz, config.f_cpu_hz, energy.p_clock_tree)?;
        let layer_dests = (0..net.layers.len())
            .map(|li| mapping.dest_cores_after(li).map(|d| Dest::Cores(d.to_vec())))
            .collect();
        Ok(Soc {
            cpu: power_on_cpu(),
            bus: NeuroBus::new(),
            idma: Dma::new(DmaKind::Idma),
            mpdma: Dma::new(DmaKind::Mpdma),
            outbufs: OutputBuffers::new(),
            ledger: EnergyLedger::new(),
            area: AreaModel::multi_chip(config.domains),
            booted: false,
            params_loaded: false,
            total_cycles: 0,
            total_sops: 0,
            spikes_routed: 0,
            samples_run: 0,
            labelled: 0,
            correct: 0,
            cores_ticked: 0,
            hop_table,
            layer_dests,
            route_scratch: vec![Vec::new(); config.n_cores],
            firing_scratch: Vec::new(),
            cur_cycles: 0,
            cur_sops: 0,
            cur_ticked_before: 0,
            cur_routed_before: 0,
            net,
            mapping,
            cores,
            core_index,
            noc,
            clocks,
            energy,
            config,
        })
    }

    /// The mapped network.
    pub fn network(&self) -> &NetworkDesc {
        &self.net
    }

    /// The core mapping.
    pub fn mapping(&self) -> &Mapping {
        &self.mapping
    }

    /// Total core-clock cycles so far.
    pub fn total_cycles(&self) -> u64 {
        self.total_cycles
    }

    /// Core ticks executed in the current accounting window. The
    /// activity-proportional scheduler ticks only cores with pending
    /// spike words, so an idle layer-timestep adds zero here — the
    /// regression counter pinning the worklist semantics.
    pub fn cores_ticked(&self) -> u64 {
        self.cores_ticked
    }

    /// NoC fabric statistics for the current accounting window — O(1):
    /// the simulator folds them incrementally, so serving snapshots can
    /// poll this per sample without rescanning the fabric.
    pub fn noc_stats(&self) -> crate::noc::SimStats {
        self.noc.stats()
    }

    /// Fabric degradation counters for the current accounting window
    /// (all zero with `armed == false` when no fault plan is configured).
    pub fn fabric_health(&self) -> FabricHealth {
        self.noc.fabric_health()
    }

    /// Spike flits injected into the on-chip fabric in the current
    /// accounting window (one per destination core, matching the NoC's
    /// per-copy broadcast semantics). Cluster-side conservation input.
    pub(crate) fn spikes_routed_window(&self) -> u64 {
        self.spikes_routed
    }

    /// Flits currently in flight inside the on-chip fabric — zero at
    /// every timestep boundary on a healthy chip. Cluster-side
    /// conservation input.
    pub(crate) fn noc_in_flight(&self) -> u64 {
        self.noc.in_flight()
    }

    /// Boot the control CPU: run the firmware protocol and consume the
    /// resulting ENU commands. No-op when `drive_cpu` is false.
    fn boot(&mut self) -> Result<()> {
        self.booted = true;
        if !self.config.drive_cpu {
            // Directly enable mapped cores.
            for c in &mut self.cores {
                c.set_enabled(true);
            }
            return Ok(());
        }
        let param_words = (self.net.total_synapses() as u64
            * self.net.layers[0].codebook.index_bits() as u64)
            .div_ceil(16) as u32;
        let prog = firmware::mnist_control(self.net.timesteps as u32, param_words.max(1))?;
        self.cpu.load_program(&prog)?;
        self.cpu.run(1_000_000)?;
        if self.cpu.state != CpuState::Sleeping {
            return Err(Error::Soc("firmware did not reach the sleep loop".into()));
        }
        self.drain_enu_commands()?;
        Ok(())
    }

    /// Apply pending ENU commands to the neuromorphic processor.
    fn drain_enu_commands(&mut self) -> Result<()> {
        while let Some(cmd) = self.cpu.enu.pop_command() {
            match cmd {
                EnuCommand::NetParamInit { words, .. } => {
                    if !self.params_loaded {
                        self.params_loaded = true;
                        let cycles =
                            self.idma
                                .burst(words as u64, &mut self.bus, &mut self.ledger);
                        self.total_cycles += cycles;
                        // The staged words land in the cores' caches.
                        let per_core = words as u64 / self.cores.len().max(1) as u64;
                        for c in &mut self.cores {
                            c.charge_cache_writes(per_core);
                        }
                    }
                }
                EnuCommand::CoreEnable { mask } => {
                    // The firmware's 20-bit enable mask is per-domain: in a
                    // multi-domain system every domain applies the same
                    // local mask (core_id mod 20), matching a broadcast
                    // register write to all domain controllers.
                    for (i, p) in self.mapping.placements.iter().enumerate() {
                        self.cores[i].set_enabled(mask >> (p.core_id % 20) & 1 == 1);
                    }
                }
                EnuCommand::NetworkStart { .. } => {
                    self.cpu.lsu.mmio.npu_status |= 1;
                }
                EnuCommand::TimestepAck | EnuCommand::NetworkStop => {}
            }
        }
        Ok(())
    }

    /// Let the CPU run for a window of `core_cycles` (converted to its own
    /// clock), optionally delivering a wake event first.
    fn run_cpu_window(&mut self, core_cycles: u64, wake: Option<WakeEvent>) -> Result<()> {
        if !self.config.drive_cpu {
            return Ok(());
        }
        if let Some(ev) = wake {
            self.cpu.wake(ev);
        }
        let budget = self.clocks.cpu_cycles_for(core_cycles).max(1);
        let mut spent = 0u64;
        // Run until the firmware sleeps again (overrunning the budget is
        // fine — the CPU clock is slower than the window in practice).
        while self.cpu.state == CpuState::Running {
            spent += self.cpu.step()?;
            if spent > 1_000_000 {
                return Err(Error::Soc("firmware runaway in timestep window".into()));
            }
        }
        // Remaining window cycles are slept through (gated).
        while spent < budget && self.cpu.state == CpuState::Sleeping {
            spent += self.cpu.step()?;
        }
        self.drain_enu_commands()?;
        Ok(())
    }

    /// Deliver spikes from layer `li` cores to layer `li+1` cores through
    /// the NoC (or the ideal fabric). `firing` holds (physical core id,
    /// axon id in the next layer's input space). Returns NoC cycles.
    ///
    /// Allocation-free on the hot path: the broadcast [`Dest`] is
    /// precomputed per layer at construction and the per-destination
    /// staging lists are reused scratch. Staging OR-merges in the cores,
    /// so deliveries compose with any earlier staging this timestep.
    fn route_spikes(&mut self, li: usize, firing: &[(usize, u32)]) -> Result<u64> {
        let Some(dst_cores) = self.mapping.dest_cores_after(li) else {
            return Ok(0);
        };
        self.spikes_routed += firing.len() as u64 * dst_cores.len() as u64;
        // Group deliveries per destination core into the reusable
        // scratch lists (taken out of `self` for the fill so the NoC and
        // ledger stay freely borrowable; restored before returning).
        let mut per_core = std::mem::take(&mut self.route_scratch);
        let cycles = if self.config.use_noc {
            let start = self.noc.cycle();
            // One precomputed Dest for the whole layer: inject borrows
            // the destination list, so the broadcast fan-out allocates
            // nothing per flit.
            let dest = self.layer_dests[li].as_ref().expect("non-last layer has dests");
            for &(src, axon) in firing {
                self.noc.inject(src, dest, axon);
            }
            if let Err(e) = self.noc.run_until_drained(1_000_000) {
                self.route_scratch = per_core;
                return Err(e);
            }
            // The ejection staging buffer is drained here every layer, so
            // it never accumulates across the run.
            for (dst_core, axon) in self.noc.drain_ejected() {
                per_core[dst_core].push(axon);
            }
            self.noc.cycle() - start
        } else {
            // Ideal fabric: zero latency, but charge hop/link energy along
            // the real hierarchical routes (L1 hops at the broadcast rate,
            // L2 hops/links at the scale-up rates).
            use crate::energy::EventClass;
            let (mut l1_hops, mut l2_hops, mut links, mut l2_links) = (0u64, 0u64, 0u64, 0u64);
            for &(src, axon) in firing {
                for &dst in dst_cores {
                    per_core[dst].push(axon);
                    let c = &self.hop_table[src][dst];
                    l1_hops += c.l1_hops as u64;
                    l2_hops += c.l2_hops as u64;
                    links += c.links as u64;
                    l2_links += c.l2_links as u64;
                }
            }
            self.ledger.add(EventClass::HopBroadcast, l1_hops);
            self.ledger.add(EventClass::HopL2, l2_hops);
            self.ledger.add(EventClass::LinkTraversal, links);
            self.ledger.add(EventClass::LinkL2, l2_links);
            0
        };
        for (dst, axons) in per_core.iter_mut().enumerate() {
            if axons.is_empty() {
                continue;
            }
            let idx = self.core_index[dst];
            if idx != usize::MAX {
                self.cores[idx].stage_input_spikes(axons);
                self.cores[idx].charge_spike_writes(axons.len());
            }
            axons.clear();
        }
        self.route_scratch = per_core;
        Ok(cycles)
    }

    /// Begin one inference: boot if needed, clear the dynamic neuron
    /// state through the MPDMA path and zero the per-sample accounting
    /// scratch. First third of [`Soc::run_sample`], split out so the
    /// cluster layer can interleave timesteps across shard chips.
    pub(crate) fn sample_begin(&mut self) -> Result<()> {
        if !self.booted {
            self.boot()?;
        }
        // Fresh dynamic state per inference: membrane potentials are
        // cleared through the MPDMA path (16-bit word per neuron).
        let mut mp_words = 0u64;
        for c in &mut self.cores {
            c.reset_state();
            mp_words += c.regs().neurons as u64;
        }
        let mpdma_cycles = self.mpdma.burst(mp_words, &mut self.bus, &mut self.ledger);
        self.outbufs.clear(0);
        self.cur_cycles = mpdma_cycles;
        self.cur_sops = 0;
        self.cur_ticked_before = self.cores_ticked;
        self.cur_routed_before = self.spikes_routed;
        Ok(())
    }

    /// Execute timestep `t` of the in-progress sample: inject `spikes_in`
    /// into the layer-0 cores (IDMA path), tick every staged layer, route
    /// inter-layer spikes and service the CPU timestep window. Middle
    /// third of [`Soc::run_sample`]; returns the timestep's wall cycles.
    ///
    /// `egress` is the cluster hook: when `Some`, final-layer spikes are
    /// pushed there (as layer-local neuron ids — exactly the next
    /// shard's input axon space) instead of landing in output buffer 0,
    /// because a non-terminal shard's output leaves the chip over the
    /// off-chip L3 fabric rather than through the readout path. `None`
    /// reproduces the single-chip semantics bit for bit.
    pub(crate) fn sample_timestep(
        &mut self,
        t: usize,
        spikes_in: &[u32],
        mut egress: Option<&mut Vec<u32>>,
    ) -> Result<u64> {
        self.noc.set_timestep(t as u32);
        // --- input injection (IDMA path) ------------------------------
        let mut dma_cycles = 0;
        if !spikes_in.is_empty() {
            let words = spikes_in.len().div_ceil(2) as u64;
            dma_cycles = self.idma.burst(words, &mut self.bus, &mut self.ledger);
            for &c in &self.mapping.layer_cores[0] {
                let idx = self.core_index[c];
                self.cores[idx].stage_input_spikes(spikes_in);
                self.cores[idx].charge_spike_writes(spikes_in.len());
            }
        }
        // --- layer-by-layer execution ----------------------------------
        // Activity-proportional scheduling: only cores with pending
        // spike words are ticked. An un-staged (or gated) core is
        // skipped outright — identical function (partial MP updates
        // mean untouched neurons never change or fire) at zero active
        // cycles, instead of paying a full zero-word cache scan per
        // idle core per timestep.
        let mut ts_cycles = dma_cycles;
        for li in 0..self.net.layers.len() {
            let mut layer_max_cycles = 0u64;
            let mut firing = std::mem::take(&mut self.firing_scratch);
            firing.clear();
            let last = li == self.net.layers.len() - 1;
            for &pc in &self.mapping.layer_cores[li] {
                let idx = self.core_index[pc];
                if !self.cores[idx].pending_input() || !self.cores[idx].regs().enabled {
                    continue;
                }
                let placement_off = self
                    .mapping
                    .placement_of(pc)
                    .expect("placed core")
                    .neuron_offset;
                let out = self.cores[idx].tick_timestep();
                self.cores_ticked += 1;
                layer_max_cycles = layer_max_cycles.max(out.stats.cycles);
                self.cur_sops += out.stats.pipeline.sops;
                for &n in &out.spikes {
                    let global = placement_off as u32 + n;
                    if !last {
                        firing.push((pc, global));
                    } else if let Some(out_of_chip) = egress.as_deref_mut() {
                        out_of_chip.push(global);
                    } else {
                        self.outbufs
                            .record_spike(0, global as usize, &mut self.ledger)?;
                    }
                }
            }
            ts_cycles += layer_max_cycles;
            let routed = if !last && !firing.is_empty() {
                self.route_spikes(li, &firing)
            } else {
                Ok(0)
            };
            self.firing_scratch = firing;
            ts_cycles += routed?;
        }
        // --- CPU timestep service --------------------------------------
        self.cpu.lsu.mmio.npu_status =
            (self.cpu.lsu.mmio.npu_status & 0xFFFF) | ((t as u32) << 16) | 1;
        self.run_cpu_window(ts_cycles.max(1), Some(WakeEvent::TimestepSwitch))?;
        self.cur_cycles += ts_cycles;
        Ok(ts_cycles)
    }

    /// Finish the in-progress sample: result readout, the firmware
    /// finish protocol and run-counter accumulation. Final third of
    /// [`Soc::run_sample`].
    ///
    /// `readout == false` is the non-terminal-shard variant: this chip
    /// ran its layers, but the logical sample's prediction lives on the
    /// cluster's terminal shard, so the output-buffer readout and the
    /// samples/accuracy counters are skipped here — the terminal shard
    /// alone accounts the logical sample, keeping cluster reports from
    /// multiplying sample counts by the shard count.
    pub(crate) fn sample_end(
        &mut self,
        label: usize,
        label_known: bool,
        readout: bool,
    ) -> Result<SampleResult> {
        // --- finish: result readout ---------------------------------------
        let counts = if readout {
            let counts = self.outbufs.counts(0, self.net.classes);
            self.cpu.lsu.mmio.result[0] = self.outbufs.mmio_word(0, self.net.classes);
            counts
        } else {
            Vec::new()
        };
        self.cpu.lsu.mmio.npu_status &= !1;
        if self.config.drive_cpu {
            // The firmware exits its loop on network finish; re-arm it for
            // the next sample by reloading (host MCU restarting inference).
            self.run_cpu_window(64, Some(WakeEvent::NetworkFinish))?;
            if self.cpu.state == CpuState::Halted {
                let prog = firmware::mnist_control(self.net.timesteps as u32, 1)?;
                let saved = self.cpu.lsu.mmio.clone();
                self.cpu.load_program(&prog)?;
                self.cpu.lsu.mmio = saved;
                self.cpu.run(1_000_000)?;
                self.drain_enu_commands()?;
                self.cpu.lsu.mmio.npu_status |= 1;
            }
        }

        let predicted = if readout {
            self.outbufs.winner(0, self.net.classes)
        } else {
            0
        };
        let correct = readout && label_known && predicted == label;
        self.total_cycles += self.cur_cycles;
        self.total_sops += self.cur_sops;
        if readout {
            self.samples_run += 1;
            if label_known {
                self.labelled += 1;
            }
            if correct {
                self.correct += 1;
            }
        }
        Ok(SampleResult {
            predicted,
            counts,
            correct,
            cycles: self.cur_cycles,
            sops: self.cur_sops,
            spikes_routed: self.spikes_routed - self.cur_routed_before,
            cores_ticked: self.cores_ticked - self.cur_ticked_before,
        })
    }

    /// Run one sample through the chip. Exactly
    /// [`Soc::sample_begin`] + one [`Soc::sample_timestep`] per network
    /// timestep + [`Soc::sample_end`] — the decomposition the cluster
    /// layer drives piecewise, recomposed here so the single-chip path
    /// is the same code (and stays bit-identical to its pre-cluster
    /// behaviour).
    pub fn run_sample(&mut self, sample: &Sample, label_known: bool) -> Result<SampleResult> {
        self.sample_begin()?;
        for t in 0..self.net.timesteps {
            let spikes_in = sample.spikes_at(t as u16);
            self.sample_timestep(t, &spikes_in, None)?;
        }
        self.sample_end(sample.label, label_known, true)
    }

    /// Run (up to `limit`) samples of a dataset through the chip.
    pub fn run_dataset(&mut self, ds: &Dataset, limit: usize) -> Result<DatasetOutcome> {
        if ds.inputs != self.net.input_size() {
            return Err(Error::Soc(format!(
                "dataset has {} inputs, network expects {}",
                ds.inputs,
                self.net.input_size()
            )));
        }
        let n = ds.samples.len().min(limit);
        let spikes_before = self.spikes_routed;
        let mut correct = 0u64;
        let mut sops = 0u64;
        let mut cycles = 0u64;
        for s in &ds.samples[..n] {
            let r = self.run_sample(s, true)?;
            if r.correct {
                correct += 1;
            }
            sops += r.sops;
            cycles += r.cycles;
        }
        Ok(DatasetOutcome {
            accuracy: correct as f64 / n.max(1) as f64,
            samples: n as u64,
            correct,
            sops,
            cycles,
            spikes_routed: self.spikes_routed - spikes_before,
        })
    }

    /// Assemble the chip-level report **without draining accounting**:
    /// merges a copy of every subsystem ledger and charges static power
    /// over the wall window so far. This is the incremental path behind
    /// [`crate::serve::Session::snapshot`] — calling it twice with no
    /// work in between yields bit-identical reports, and a subsequent
    /// [`Soc::finish_report`] over the same window returns the same
    /// numbers.
    pub fn snapshot_report(&self, workload: &str) -> ChipReport {
        let mut ledger = self.ledger.clone();
        let wall = self.total_cycles.max(1);
        for c in &self.cores {
            ledger.merge(c.ledger());
            let active = c.busy_cycles().min(wall);
            ledger.add_static(
                c.static_label(),
                active,
                wall - active,
                self.energy.p_core_active,
                self.energy.p_core_gated,
            );
        }
        ledger.merge(&self.noc.snapshot_ledger());
        // CPU: dynamic ledger + domain statics (converted to core cycles).
        ledger.merge(&self.cpu.ledger);
        let scale = self.clocks.f_core_hz / self.clocks.f_cpu_hz;
        ledger.add_static(
            "cpu-hf",
            (self.cpu.clocks.hf_active as f64 * scale) as u64,
            (self.cpu.clocks.hf_gated as f64 * scale) as u64,
            self.energy.p_cpu_active,
            self.energy.p_cpu_sleep,
        );
        ledger.add_static("cpu-lf", wall, 0, self.energy.p_cpu_lf, 0.0);
        self.clocks.charge_window(&mut ledger, wall);
        ledger.add_static("soc-misc", wall, 0, self.energy.p_soc_misc, 0.0);

        // Accuracy over *labelled* samples only: unlabelled serving
        // pushes never dilute it, and an all-unlabelled run reports N.A.
        let accuracy = (self.labelled > 0)
            .then(|| self.correct as f64 / self.labelled as f64);
        ChipReport::from_ledger(
            workload,
            &ledger,
            &self.energy,
            &self.area,
            self.clocks.f_core_hz,
            wall,
            self.samples_run,
            self.labelled,
            accuracy,
            self.spikes_routed,
        )
    }

    /// Assemble the chip-level report and **reset run accounting**, so a
    /// reused chip starts its next accounting window (the next serving
    /// session) from zero. Equivalent to [`Soc::snapshot_report`]
    /// followed by [`Soc::reset_accounting`].
    pub fn finish_report(&mut self, workload: &str) -> ChipReport {
        let report = self.snapshot_report(workload);
        self.reset_accounting();
        report
    }

    /// Re-arm a served chip for a fresh session so that the next session
    /// is **bit-identical** to one run on a brand-new [`Soc::new`] chip,
    /// while skipping the expensive host-side construction (mapping
    /// planning, synapse-table builds, topology + hop-table precompute —
    /// all of which depend only on `(net, config)` and are kept).
    ///
    /// Built on [`Soc::reset_accounting`] plus a return of every piece of
    /// *dynamic* chip state to its power-on value: core membrane
    /// potentials / spike caches / enables, the control CPU (fresh ISS,
    /// zeroed clock domains), DMA/bus beat counters, output buffers, and
    /// the boot latches — so the next sample re-runs the firmware boot
    /// protocol and re-charges the parameter-load DMA exactly like a
    /// fresh chip does. Warm reuse is therefore a pure host-side
    /// optimization: simulated physics, reports and ledgers cannot tell
    /// the difference (pinned bit-for-bit in `tests/serving_api.rs`).
    pub fn reset_for_session(&mut self) {
        self.reset_accounting();
        for c in &mut self.cores {
            c.reset_state();
            // Fresh cores come up enabled (RegTable default); boot
            // re-applies the firmware's enable mask.
            c.set_enabled(true);
        }
        self.cpu = power_on_cpu();
        self.bus = NeuroBus::new();
        self.idma = Dma::new(DmaKind::Idma);
        self.mpdma = Dma::new(DmaKind::Mpdma);
        self.outbufs = OutputBuffers::new();
        self.booted = false;
        self.params_loaded = false;
    }

    /// Replace the chip's armed fault schedule (the NoC must be drained
    /// — between samples / sessions). Validation is the same as at build
    /// time; the new plan also becomes the one
    /// [`Soc::reset_accounting`] re-arms. The serving retry loop uses
    /// this to install a plan's unfired tail
    /// ([`crate::noc::FaultPlan::shifted`]) on a power-cycled chip.
    pub fn rearm_fault_plan(&mut self, plan: FaultPlan) -> Result<()> {
        self.noc.set_fault_plan(plan.clone())?;
        self.config.fault_plan = plan;
        Ok(())
    }

    /// Clear every energy ledger and run counter (cycles, SOPs, samples,
    /// routed spikes) while keeping the booted chip state, weights and
    /// mapping. The NoC must be drained (it always is between samples).
    pub fn reset_accounting(&mut self) {
        self.ledger = EnergyLedger::new();
        for c in &mut self.cores {
            c.reset_accounting();
        }
        self.noc.reset_accounting();
        self.cpu.ledger = EnergyLedger::new();
        self.cpu.clocks.hf_active = 0;
        self.cpu.clocks.hf_gated = 0;
        self.cpu.clocks.lf_cycles = 0;
        self.cpu.clocks.bus_active = 0;
        self.total_cycles = 0;
        self.total_sops = 0;
        self.spikes_routed = 0;
        self.samples_run = 0;
        self.labelled = 0;
        self.correct = 0;
        self.cores_ticked = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::neuron::{LeakMode, NeuronParams, ResetMode};
    use crate::core::Codebook;
    use crate::nn::network::LayerDesc;

    /// A small 2-layer network whose weights make spikes propagate.
    fn small_net(inputs: usize, hidden: usize, classes: usize) -> NetworkDesc {
        let cb = Codebook::default_log16();
        let params = NeuronParams {
            threshold: 40,
            leak: LeakMode::Linear(1),
            reset: ResetMode::Subtract,
            mp_bits: 16,
        };
        NetworkDesc {
            name: "soc-test".into(),
            layers: vec![
                LayerDesc {
                    name: "h".into(),
                    inputs,
                    neurons: hidden,
                    codebook: cb.clone(),
                    widx: (0..inputs * hidden)
                        .map(|i| if i % 3 == 0 { 13 } else { 8 } as u8)
                        .collect(),
                    neuron_params: params.clone(),
                },
                LayerDesc {
                    name: "o".into(),
                    inputs: hidden,
                    neurons: classes,
                    codebook: cb,
                    widx: (0..hidden * classes)
                        .map(|i| if i % 2 == 0 { 14 } else { 8 } as u8)
                        .collect(),
                    neuron_params: params,
                },
            ],
            timesteps: 5,
            classes,
        }
    }

    fn busy_sample(inputs: usize, timesteps: usize) -> Sample {
        let mut events = Vec::new();
        for t in 0..timesteps {
            for a in (0..inputs).step_by(2) {
                events.push((t as u16, a as u32));
            }
        }
        Sample { label: 0, events }
    }

    #[test]
    fn sample_runs_end_to_end_with_cpu_and_noc() {
        let net = small_net(32, 24, 4);
        let mut soc = Soc::new(net, SocConfig {
            max_neurons_per_core: 16,
            ..SocConfig::default()
        })
        .unwrap();
        let s = busy_sample(32, 5);
        let r = soc.run_sample(&s, true).unwrap();
        assert!(r.sops > 0, "no synapse work happened");
        assert!(r.cycles > 0);
        assert!(r.counts.iter().sum::<u32>() > 0, "no output spikes");
        assert!(r.spikes_routed > 0, "NoC was never used");
    }

    #[test]
    fn cpu_slept_most_of_the_time() {
        let net = small_net(32, 24, 4);
        let mut soc = Soc::new(net, SocConfig {
            max_neurons_per_core: 16,
            ..SocConfig::default()
        })
        .unwrap();
        let s = busy_sample(32, 5);
        soc.run_sample(&s, true).unwrap();
        let c = &soc.cpu.clocks;
        assert!(
            c.hf_gated > c.hf_active,
            "CPU should sleep between timesteps (active {}, gated {})",
            c.hf_active,
            c.hf_gated
        );
    }

    #[test]
    fn ideal_fabric_matches_noc_functionally() {
        let net = small_net(32, 24, 4);
        let s = busy_sample(32, 5);
        let mut with_noc = Soc::new(net.clone(), SocConfig {
            max_neurons_per_core: 16,
            ..SocConfig::default()
        })
        .unwrap();
        let mut ideal = Soc::new(net, SocConfig {
            max_neurons_per_core: 16,
            use_noc: false,
            ..SocConfig::default()
        })
        .unwrap();
        let r1 = with_noc.run_sample(&s, true).unwrap();
        let r2 = ideal.run_sample(&s, true).unwrap();
        assert_eq!(r1.counts, r2.counts, "fabric choice must not change function");
        assert_eq!(r1.sops, r2.sops);
    }

    #[test]
    fn soc_matches_reference_network_semantics() {
        let net = small_net(16, 12, 4);
        let s = busy_sample(16, 5);
        let raster = s.to_raster(5, 16);
        let expect = net.reference_run(&raster);
        let mut soc = Soc::new(net, SocConfig {
            max_neurons_per_core: 5, // force multi-core split
            ..SocConfig::default()
        })
        .unwrap();
        let r = soc.run_sample(&s, true).unwrap();
        assert_eq!(r.counts, expect, "chip must compute the reference function");
    }

    #[test]
    fn report_aggregates_everything() {
        let net = small_net(32, 24, 4);
        let mut soc = Soc::new(net, SocConfig {
            max_neurons_per_core: 16,
            ..SocConfig::default()
        })
        .unwrap();
        let s = busy_sample(32, 5);
        soc.run_sample(&s, true).unwrap();
        let rep = soc.finish_report("test");
        assert!(rep.sops > 0);
        assert!(rep.pj_per_sop.is_finite() && rep.pj_per_sop > 0.0);
        assert!(rep.power_mw > 0.0);
        assert_eq!(rep.samples, 1);
    }

    #[test]
    fn snapshot_is_nondestructive_and_matches_finish() {
        let net = small_net(32, 24, 4);
        let mut soc = Soc::new(net, SocConfig {
            max_neurons_per_core: 16,
            ..SocConfig::default()
        })
        .unwrap();
        let s = busy_sample(32, 5);
        soc.run_sample(&s, true).unwrap();
        let snap1 = soc.snapshot_report("t");
        let snap2 = soc.snapshot_report("t");
        // Snapshots are idempotent (no double-charged statics) …
        assert_eq!(snap1.pj_per_sop.to_bits(), snap2.pj_per_sop.to_bits());
        assert_eq!(snap1.power_mw.to_bits(), snap2.power_mw.to_bits());
        assert_eq!(snap1.breakdown.by_static, snap2.breakdown.by_static);
        // … and the final report over the same window is bit-identical.
        let fin = soc.finish_report("t");
        assert_eq!(snap1.pj_per_sop.to_bits(), fin.pj_per_sop.to_bits());
        assert_eq!(snap1.power_mw.to_bits(), fin.power_mw.to_bits());
        assert_eq!(snap1.cycles, fin.cycles);
        assert_eq!(snap1.breakdown.by_class, fin.breakdown.by_class);
    }

    #[test]
    fn finish_report_resets_accounting_for_reuse() {
        let net = small_net(32, 24, 4);
        let mut soc = Soc::new(net, SocConfig {
            max_neurons_per_core: 16,
            ..SocConfig::default()
        })
        .unwrap();
        let s = busy_sample(32, 5);
        soc.run_sample(&s, true).unwrap();
        let first = soc.finish_report("w1");
        assert_eq!(first.samples, 1);
        // Second accounting window on the same (already booted) chip.
        soc.run_sample(&s, true).unwrap();
        let second = soc.finish_report("w2");
        assert_eq!(second.samples, 1, "counters must restart per window");
        assert!(second.sops > 0 && second.power_mw > 0.0);
        // No boot-time IDMA parameter load in the second window, so its
        // energy must not exceed the first window's.
        assert!(second.total_pj() <= first.total_pj());
    }

    #[test]
    fn reset_for_session_reproduces_a_fresh_chip_bit_for_bit() {
        let net = small_net(32, 24, 4);
        let cfg = SocConfig {
            max_neurons_per_core: 16,
            ..SocConfig::default()
        };
        let s = busy_sample(32, 5);
        // Warm path: serve one session, re-arm, serve another.
        let mut warm = Soc::new(net.clone(), cfg.clone()).unwrap();
        warm.run_sample(&s, true).unwrap();
        warm.finish_report("first");
        warm.reset_for_session();
        let wr = warm.run_sample(&s, true).unwrap();
        let wrep = warm.finish_report("w");
        // Cold oracle: a brand-new chip serving the same session.
        let mut cold = Soc::new(net, cfg).unwrap();
        let cr = cold.run_sample(&s, true).unwrap();
        let crep = cold.finish_report("w");
        assert_eq!(wr.counts, cr.counts, "warm chip diverged functionally");
        assert_eq!(wr.cycles, cr.cycles);
        assert_eq!(wr.sops, cr.sops);
        assert_eq!(wr.spikes_routed, cr.spikes_routed);
        assert_eq!(wr.cores_ticked, cr.cores_ticked);
        assert_eq!(wrep.cycles, crep.cycles);
        assert_eq!(wrep.pj_per_sop.to_bits(), crep.pj_per_sop.to_bits());
        assert_eq!(wrep.power_mw.to_bits(), crep.power_mw.to_bits());
        assert_eq!(wrep.breakdown.by_class, crep.breakdown.by_class);
        assert_eq!(wrep.breakdown.by_static, crep.breakdown.by_static);
    }

    #[test]
    fn invalid_fault_plan_rejected_at_build() {
        use crate::noc::When;
        let net = small_net(32, 24, 4);
        let cfg = SocConfig {
            max_neurons_per_core: 16,
            // Node 15 is a core of the fullerene domain, not a router.
            fault_plan: FaultPlan::none().kill_router(15, When::Cycle(1)),
            ..SocConfig::default()
        };
        assert!(Soc::new(net, cfg).is_err());
    }

    #[test]
    fn faulted_session_heals_and_replays_identically_after_reset() {
        use crate::noc::When;
        let net = small_net(32, 24, 4);
        let cfg = SocConfig {
            max_neurons_per_core: 16,
            fault_plan: FaultPlan::none().kill_router(0, When::Timestep(1)),
            ..SocConfig::default()
        };
        let s = busy_sample(32, 5);
        let mut warm = Soc::new(net.clone(), cfg.clone()).unwrap();
        let first = warm.run_sample(&s, true).unwrap();
        assert!(warm.fabric_health().armed);
        assert_eq!(
            warm.fabric_health().dead_routers,
            1,
            "timestep-keyed kill must fire mid-sample"
        );
        warm.finish_report("first");
        warm.reset_for_session();
        assert_eq!(
            warm.fabric_health().dead_routers,
            0,
            "session reset must heal the fabric"
        );
        let wr = warm.run_sample(&s, true).unwrap();
        let wrep = warm.finish_report("w");
        // Cold oracle: a brand-new chip with the same fault plan.
        let mut cold = Soc::new(net, cfg).unwrap();
        let cr = cold.run_sample(&s, true).unwrap();
        let crep = cold.finish_report("w");
        assert_eq!(wr.counts, cr.counts, "healed chip diverged functionally");
        assert_eq!(wr.cycles, cr.cycles);
        assert_eq!(first.counts, cr.counts, "same plan + session → same outcome");
        assert_eq!(wrep.pj_per_sop.to_bits(), crep.pj_per_sop.to_bits());
        assert_eq!(wrep.breakdown.by_class, crep.breakdown.by_class);
        assert_eq!(warm.fabric_health(), cold.fabric_health());
    }

    #[test]
    fn unlabelled_samples_never_dilute_accuracy() {
        let net = small_net(32, 24, 4);
        let mut soc = Soc::new(net, SocConfig {
            max_neurons_per_core: 16,
            ..SocConfig::default()
        })
        .unwrap();
        let s = busy_sample(32, 5);
        // Pure serving: no labels → accuracy must be N.A., not 0 %.
        soc.run_sample(&s, false).unwrap();
        soc.run_sample(&s, false).unwrap();
        let rep = soc.finish_report("unlabelled");
        assert_eq!(rep.samples, 2);
        assert_eq!(rep.accuracy, None, "unlabelled run must not report accuracy");
        // Mixed: accuracy is over the labelled pushes only.
        let labelled = soc.run_sample(&s, true).unwrap();
        soc.run_sample(&s, false).unwrap();
        let rep = soc.finish_report("mixed");
        assert_eq!(rep.samples, 2);
        let expect = if labelled.correct { 1.0 } else { 0.0 };
        assert_eq!(rep.accuracy, Some(expect));
    }

    #[test]
    fn run_dataset_reports_batch_counters() {
        let net = small_net(32, 24, 4);
        let mut soc = Soc::new(net, SocConfig {
            max_neurons_per_core: 16,
            ..SocConfig::default()
        })
        .unwrap();
        let ds = Dataset {
            name: "t".into(),
            inputs: 32,
            timesteps: 5,
            classes: 4,
            samples: vec![busy_sample(32, 5), busy_sample(32, 5), busy_sample(32, 5)],
        };
        let out = soc.run_dataset(&ds, 2).unwrap();
        assert_eq!(out.samples, 2, "limit must clip the batch");
        assert!(out.sops > 0 && out.cycles > 0 && out.spikes_routed > 0);
        assert!((0.0..=1.0).contains(&out.accuracy));
        assert_eq!(out.correct as f64 / out.samples as f64, out.accuracy);
    }

    #[test]
    fn noc_stats_stream_during_serving() {
        let net = small_net(32, 24, 4);
        let mut soc = Soc::new(net, SocConfig {
            max_neurons_per_core: 16,
            ..SocConfig::default()
        })
        .unwrap();
        let s = busy_sample(32, 5);
        soc.run_sample(&s, true).unwrap();
        let st = soc.noc_stats();
        assert!(st.delivered > 0, "no flits accounted");
        assert!(st.avg_latency > 0.0 && st.avg_hops >= 1.0);
        // The serving chip keeps no per-flit trace, yet the streaming
        // aggregates above stay exact — and reset with the window.
        soc.finish_report("w");
        assert_eq!(soc.noc_stats().delivered, 0);
    }

    #[test]
    fn idle_layer_timesteps_tick_zero_cores() {
        let net = small_net(32, 24, 4);
        let mut soc = Soc::new(net, SocConfig {
            max_neurons_per_core: 16,
            ..SocConfig::default()
        })
        .unwrap();
        let placed = soc.mapping().cores_used() as u64;
        // A sample with no input events: every layer-timestep is idle, so
        // the worklist must tick zero cores end to end.
        let empty = Sample { label: 0, events: vec![] };
        let r = soc.run_sample(&empty, true).unwrap();
        assert_eq!(r.cores_ticked, 0, "idle timesteps must tick zero cores");
        assert_eq!(soc.cores_ticked(), 0);
        // Input only at t=0 of 5 timesteps: cores tick in the first
        // timestep only (layer 1 consumes its routed spikes within t=0),
        // so the total is bounded by one tick per placed core.
        let burst = Sample {
            label: 0,
            events: (0..32).step_by(2).map(|a| (0u16, a as u32)).collect(),
        };
        let r = soc.run_sample(&burst, true).unwrap();
        assert!(r.cores_ticked > 0, "staged cores must tick");
        assert!(
            r.cores_ticked <= placed,
            "idle-layer timesteps ticked cores: {} ticks for {} placed cores",
            r.cores_ticked,
            placed
        );
        // A busy sample ticks more, but never more than every placed core
        // every timestep (the old always-tick bound).
        let busy = busy_sample(32, 5);
        let r = soc.run_sample(&busy, true).unwrap();
        assert!(r.cores_ticked > placed);
        assert!(r.cores_ticked <= placed * 5);
        // The window counter resets with the accounting window.
        assert!(soc.cores_ticked() > 0);
        soc.finish_report("w");
        assert_eq!(soc.cores_ticked(), 0);
    }

    #[test]
    fn network_too_big_for_chip_rejected() {
        let net = small_net(16, 8192 * 21, 4);
        assert!(Soc::new(net, SocConfig::default()).is_err());
    }

    #[test]
    fn multi_domain_chip_spans_domains_and_matches_reference() {
        // 24 hidden neurons at 1 neuron/core force 28 placements: the
        // network cannot fit one 20-core domain, so layer traffic crosses
        // the simulated L2 ring — and must still compute the reference
        // function bit-for-bit.
        let net = small_net(16, 24, 4);
        let s = busy_sample(16, 5);
        let raster = s.to_raster(5, 16);
        let expect = net.reference_run(&raster);
        let cfg = SocConfig {
            domains: 2,
            n_cores: 40,
            max_neurons_per_core: 1,
            ..SocConfig::default()
        };
        let mut soc = Soc::new(net.clone(), cfg.clone()).unwrap();
        let r = soc.run_sample(&s, true).unwrap();
        assert_eq!(r.counts, expect, "multi-domain chip diverged from reference");
        let rep = soc.finish_report("multidomain");
        // Cross-domain spikes must have been priced on the L2 fabric, and
        // the area model must scale with the domain count (density stays
        // at the paper's figure).
        assert!(
            rep.breakdown.by_class.contains_key("HopL2"),
            "no L2 hop energy recorded: {:?}",
            rep.breakdown.by_class.keys().collect::<Vec<_>>()
        );
        assert!((rep.neuron_density_k_mm2 - 30.23).abs() < 1.0);

        // The ideal (no-NoC) fabric follows the same hierarchical routes:
        // identical function, and L2 energy classes still charged.
        let mut ideal = Soc::new(net, SocConfig { use_noc: false, ..cfg }).unwrap();
        let ri = ideal.run_sample(&s, true).unwrap();
        assert_eq!(ri.counts, expect);
        let repi = ideal.finish_report("multidomain-ideal");
        assert!(
            repi.breakdown.by_class.contains_key("HopL2")
                && repi.breakdown.by_class.contains_key("LinkL2"),
            "ideal fabric missed L2 classes: {:?}",
            repi.breakdown.by_class.keys().collect::<Vec<_>>()
        );
    }

    #[test]
    fn more_cores_than_domains_provide_rejected() {
        let net = small_net(16, 8, 4);
        assert!(Soc::new(net, SocConfig {
            domains: 1,
            n_cores: 40,
            ..SocConfig::default()
        })
        .is_err());
    }
}
