//! Extended Neuromorphic Unit (ENU, paper §II.C): decodes the custom-0
//! neuromorphic instructions and drives the neuromorphic bus.
//!
//! "A set of dedicated neuromorphic instructions (including network
//! parameter initialization, core enable, network startup, etc.) has been
//! extended for efficient control of the neuromorphic processor. […] The
//! ENU generates dedicated control signals by decoding neuromorphic
//! instructions and sends them to the neuromorphic processor through a
//! neuromorphic bus."
//!
//! The ENU shares the LSU with the core: `NetParamInit` reads its
//! parameter-table header through the LSU (arbitrated), then the command
//! is queued on the neuromorphic bus for the SoC/coordinator to consume.

use super::lsu::{Lsu, LsuClient};
use crate::Result;
use std::collections::VecDeque;

/// funct7 encodings of the ENU instructions.
pub mod funct {
    /// Initialize network parameters: rs1 = table address, rs2 = words.
    pub const NET_INIT: u8 = 0x00;
    /// Enable/disable cores: rs1 = 20-bit core enable mask.
    pub const CORE_EN: u8 = 0x01;
    /// Start network computation: rs1 = number of timesteps.
    pub const NET_START: u8 = 0x02;
    /// Read network status into rd.
    pub const NET_STATUS: u8 = 0x03;
    /// Read result word: rs1 = output buffer index (0–3); into rd.
    pub const RESULT_RD: u8 = 0x04;
    /// Acknowledge a timestep-switch wake.
    pub const TS_ACK: u8 = 0x05;
    /// Stop/abort network computation.
    pub const NET_STOP: u8 = 0x06;
}

/// A decoded neuromorphic command on the neuromorphic bus.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EnuCommand {
    /// Stream `words` 32-bit words of parameters from RAM `addr` to the
    /// neuromorphic processor (the coordinator runs IDMA for this).
    NetParamInit { addr: u32, words: u32 },
    /// Core clock-gate enables, bit per core.
    CoreEnable { mask: u32 },
    /// Run the network for `timesteps`.
    NetworkStart { timesteps: u32 },
    /// Acknowledge timestep switch.
    TimestepAck,
    /// Abort.
    NetworkStop,
}

/// The ENU: command queue + status plumbing.
#[derive(Debug, Clone, Default)]
pub struct EnuUnit {
    queue: VecDeque<EnuCommand>,
    /// Instructions decoded (energy accounting).
    pub issued: u64,
}

impl EnuUnit {
    /// Empty unit.
    pub fn new() -> Self {
        Self::default()
    }

    /// Execute one custom-0 instruction. Returns the value for `rd`
    /// (0 when the instruction produces none).
    pub fn execute(
        &mut self,
        f: u8,
        rs1_val: u32,
        rs2_val: u32,
        lsu: &mut Lsu,
    ) -> Result<u32> {
        self.issued += 1;
        match f {
            funct::NET_INIT => {
                // Validate the table header through the shared LSU (this
                // is the arbitrated access path the paper describes).
                let _probe = lsu.read(LsuClient::Enu, rs1_val, 4)?;
                self.queue.push_back(EnuCommand::NetParamInit {
                    addr: rs1_val,
                    words: rs2_val,
                });
                Ok(0)
            }
            funct::CORE_EN => {
                self.queue.push_back(EnuCommand::CoreEnable { mask: rs1_val });
                Ok(0)
            }
            funct::NET_START => {
                lsu.mmio.npu_status |= 1; // busy
                self.queue
                    .push_back(EnuCommand::NetworkStart { timesteps: rs1_val });
                Ok(0)
            }
            funct::NET_STATUS => Ok(lsu.mmio.npu_status),
            funct::RESULT_RD => {
                let idx = (rs1_val & 3) as usize;
                Ok(lsu.mmio.result[idx])
            }
            funct::TS_ACK => {
                self.queue.push_back(EnuCommand::TimestepAck);
                Ok(0)
            }
            funct::NET_STOP => {
                lsu.mmio.npu_status &= !1;
                self.queue.push_back(EnuCommand::NetworkStop);
                Ok(0)
            }
            other => Err(crate::Error::Riscv(format!(
                "unknown ENU funct7 {other:#x}"
            ))),
        }
    }

    /// Pop the next command off the neuromorphic bus.
    pub fn pop_command(&mut self) -> Option<EnuCommand> {
        self.queue.pop_front()
    }

    /// Commands waiting on the bus.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commands_queue_in_order() {
        let mut lsu = Lsu::new(1024);
        let mut enu = EnuUnit::new();
        enu.execute(funct::NET_INIT, 0x100, 16, &mut lsu).unwrap();
        enu.execute(funct::CORE_EN, 0xFFFFF, 0, &mut lsu).unwrap();
        enu.execute(funct::NET_START, 20, 0, &mut lsu).unwrap();
        assert_eq!(
            enu.pop_command(),
            Some(EnuCommand::NetParamInit { addr: 0x100, words: 16 })
        );
        assert_eq!(enu.pop_command(), Some(EnuCommand::CoreEnable { mask: 0xFFFFF }));
        assert_eq!(enu.pop_command(), Some(EnuCommand::NetworkStart { timesteps: 20 }));
        assert_eq!(enu.pop_command(), None);
        assert_eq!(enu.issued, 3);
    }

    #[test]
    fn net_start_sets_busy_and_status_reads_it() {
        let mut lsu = Lsu::new(64);
        let mut enu = EnuUnit::new();
        enu.execute(funct::NET_START, 5, 0, &mut lsu).unwrap();
        assert_eq!(enu.execute(funct::NET_STATUS, 0, 0, &mut lsu).unwrap() & 1, 1);
        enu.execute(funct::NET_STOP, 0, 0, &mut lsu).unwrap();
        assert_eq!(enu.execute(funct::NET_STATUS, 0, 0, &mut lsu).unwrap() & 1, 0);
    }

    #[test]
    fn result_read_returns_buffer_word() {
        let mut lsu = Lsu::new(64);
        lsu.mmio.result[1] = 0xDEAD;
        let mut enu = EnuUnit::new();
        assert_eq!(enu.execute(funct::RESULT_RD, 1, 0, &mut lsu).unwrap(), 0xDEAD);
    }

    #[test]
    fn net_init_uses_shared_lsu() {
        let mut lsu = Lsu::new(1024);
        let mut enu = EnuUnit::new();
        enu.execute(funct::NET_INIT, 0x40, 4, &mut lsu).unwrap();
        assert_eq!(lsu.served_enu, 1, "header probe must go through the LSU");
        assert!(lsu.conflicts >= 1);
    }

    #[test]
    fn bad_funct_rejected() {
        let mut lsu = Lsu::new(64);
        let mut enu = EnuUnit::new();
        assert!(enu.execute(0x7F, 0, 0, &mut lsu).is_err());
    }
}
