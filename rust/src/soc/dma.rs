//! DMA engines (Fig. 7): **IDMA** streams weight-index/parameter data into
//! the cores, **MPDMA** saves/restores membrane potentials. Both move
//! 16-bit words, charge per-word energy and consume bus beats.

use super::bus::{BusOp, NeuroBus};
use crate::energy::{EnergyLedger, EventClass};

/// Which DMA engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DmaKind {
    /// Index/parameter DMA.
    Idma,
    /// Membrane-potential DMA.
    Mpdma,
}

/// A DMA engine.
#[derive(Debug, Clone)]
pub struct Dma {
    kind: DmaKind,
    /// Total 16-bit words moved.
    pub words: u64,
    /// Total transfers (bursts).
    pub bursts: u64,
}

impl Dma {
    /// New engine.
    pub fn new(kind: DmaKind) -> Self {
        Dma {
            kind,
            words: 0,
            bursts: 0,
        }
    }

    /// Engine kind.
    pub fn kind(&self) -> DmaKind {
        self.kind
    }

    /// Move `words` 16-bit words; returns cycles consumed (2 words per
    /// 32-bit bus beat, one beat per cycle).
    pub fn burst(&mut self, words: u64, bus: &mut NeuroBus, ledger: &mut EnergyLedger) -> u64 {
        self.words += words;
        self.bursts += 1;
        ledger.add(EventClass::DmaWord, words);
        bus.transfer(BusOp::Dma, words.div_ceil(2), ledger)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_counts_words_and_beats() {
        let mut dma = Dma::new(DmaKind::Idma);
        let mut bus = NeuroBus::new();
        let mut l = EnergyLedger::new();
        let cycles = dma.burst(17, &mut bus, &mut l);
        assert_eq!(cycles, 9); // ceil(17/2)
        assert_eq!(dma.words, 17);
        assert_eq!(l.count(EventClass::DmaWord), 17);
        assert_eq!(bus.dma_beats, 9);
    }
}
