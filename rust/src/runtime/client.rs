//! Thin wrapper over the `xla` crate's PJRT CPU client: load HLO text,
//! compile once, execute many times.

use crate::{Error, Result};
use std::path::Path;

/// A compiled XLA executable + its client.
pub struct XlaExec {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
}

impl XlaExec {
    /// Load an HLO text file and compile it on the PJRT CPU client.
    pub fn load_hlo_text(path: &Path) -> Result<XlaExec> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
        )
        .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {}: {e}", path.display())))?;
        Ok(XlaExec { client, exe })
    }

    /// Platform name ("cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute with i32 tensor inputs `(data, shape)`; the computation is
    /// lowered with `return_tuple=True`, so the single output is unwrapped
    /// from a 1-tuple and returned as a flat i32 vector.
    pub fn run_i32(&self, inputs: &[(&[i32], &[usize])]) -> Result<Vec<i32>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| -> Result<xla::Literal> {
                let lit = xla::Literal::vec1(data);
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims)
                    .map_err(|e| Error::Runtime(format!("reshape: {e}")))
            })
            .collect::<Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("execute: {e}")))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("to_literal: {e}")))?;
        let tuple = out
            .to_tuple1()
            .map_err(|e| Error::Runtime(format!("to_tuple1: {e}")))?;
        tuple
            .to_vec::<i32>()
            .map_err(|e| Error::Runtime(format!("to_vec<i32>: {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end smoke against the reference example's generator output
    /// is covered by integration tests once `make artifacts` has run; here
    /// we only check the error path (missing file) stays an Err, not a
    /// panic.
    #[test]
    fn missing_artifact_is_an_error() {
        let r = XlaExec::load_hlo_text(Path::new("/nonexistent/model.hlo.txt"));
        assert!(r.is_err());
    }
}
