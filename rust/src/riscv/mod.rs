//! The RISC-V CPU and its heterogeneous coupling (paper §II.C).
//!
//! An RV32IM instruction-set simulator with:
//!
//! - **three clock domains** ([`clock`]): the high-frequency main domain
//!   (HFCLK, gatable through a sleep instruction), the always-on
//!   low-frequency domain (wake controller, timers), and the bus domain;
//! - **sleep/wake** power management: software executes `wfi` (the
//!   paper's sleep instruction); the HFCLK halts until a
//!   *timestep-switch* or *network-computing-finish* wake event arrives
//!   from the neuromorphic processor;
//! - the **Extended Neuromorphic Unit** ([`enu`]): custom-0 opcode
//!   instructions (network parameter initialization, core enable, network
//!   startup, status reads, …) decoded by the ENU, which shares the
//!   [`lsu`] load-and-store unit with the core and drives the
//!   neuromorphic bus;
//! - an **energy/power model** ([`power`]) calibrated to the paper's
//!   0.434 mW average (43 % below the ungated baseline) on the MNIST
//!   control firmware.
//!
//! [`asm`] provides a small assembler so firmware ([`firmware`]) stays
//! readable in the repo; [`decode`]/[`exec`] implement the ISA.

pub mod asm;
pub mod clock;
pub mod cpu;
pub mod decode;
pub mod enu;
pub mod firmware;
pub mod lsu;
pub mod power;

pub use cpu::{Cpu, CpuState, WakeEvent};
pub use decode::{decode, Instr};
pub use enu::{EnuCommand, EnuUnit};
pub use lsu::{Lsu, MMIO_BASE};
