//! Cluster-layer integration tests — the load-bearing guarantees of the
//! multi-chip scale-out:
//!
//! - the **N = 1 oracle**: a single-chip cluster is bit-identical to a
//!   plain [`Soc`] (per-sample results, reports, energy ledgers, down to
//!   `f64::to_bits`), anchoring the cluster to every existing
//!   equivalence chain;
//! - **cluster-wide flit conservation**: delivered + dropped + in-flight
//!   equals injected, summed over every shard NoC and the L3 ring, under
//!   randomized fault plans mixing on-chip and L3 events (in-tree
//!   `propcheck` loop, seeds reported on failure);
//! - the **partition-balance regression** at Fig. 3 geometry: equal-cut
//!   splits must break ties toward balanced shards;
//! - **shard failover**: a ring-node kill landing mid-session moves the
//!   dead node's shard onto a spare chip at the next sample boundary,
//!   the session completes against the functional reference, the books
//!   span the replan, and the degraded run replays bit for bit.

use fullerene_soc::benches_support::{FIG3_AXONS, FIG3_NEURONS};
use fullerene_soc::cluster::{Cluster, ClusterMapper, Engine};
use fullerene_soc::core::neuron::{LeakMode, NeuronParams, ResetMode};
use fullerene_soc::core::Codebook;
use fullerene_soc::datasets::Sample;
use fullerene_soc::nn::network::{LayerDesc, NetworkDesc};
use fullerene_soc::noc::{FaultPlan, LinkLevel, When};
use fullerene_soc::serve::{SessionSpec, SessionVerdict, SocBuilder, TrafficWorkload};
use fullerene_soc::soc::{Soc, SocConfig};
use fullerene_soc::util::propcheck::check;

/// A chain of fully-connected layers that actually propagates spikes
/// (the same recipe the cluster unit tests pin against the functional
/// reference).
fn chain_net(inputs: usize, widths: &[usize], classes: usize, timesteps: usize) -> NetworkDesc {
    let cb = Codebook::default_log16();
    let params = NeuronParams {
        threshold: 40,
        leak: LeakMode::Linear(1),
        reset: ResetMode::Subtract,
        mp_bits: 16,
    };
    let mut layers = Vec::new();
    let mut prev = inputs;
    for (i, &w) in widths.iter().chain(std::iter::once(&classes)).enumerate() {
        layers.push(LayerDesc {
            name: format!("l{i}"),
            inputs: prev,
            neurons: w,
            codebook: cb.clone(),
            widx: (0..prev * w).map(|j| ((j * 7) % 16) as u8).collect(),
            neuron_params: params.clone(),
        });
        prev = w;
    }
    NetworkDesc {
        name: "cluster-it".into(),
        layers,
        timesteps,
        classes,
    }
}

/// Deterministic synthetic spike streams dense enough to cross every
/// shard boundary.
fn samples(n: usize, inputs: usize, timesteps: usize, seed: u64) -> Vec<Sample> {
    (0..n)
        .map(|i| {
            let mut events = Vec::new();
            for t in 0..timesteps {
                for a in 0..inputs {
                    if (a as u64 * 7 + t as u64 * 13 + i as u64 * 31 + seed) % 4 == 0 {
                        events.push((t as u16, a as u32));
                    }
                }
            }
            Sample {
                label: i % 10,
                events,
            }
        })
        .collect()
}

/// The N = 1 oracle: every observable of a single-chip cluster — sample
/// results, report counters, and both energy ledgers — is bit-identical
/// to the plain chip's, so the cluster layer costs nothing at one chip
/// and inherits the whole single-chip equivalence chain.
#[test]
fn single_chip_cluster_is_bit_identical_to_the_plain_soc() {
    let net = chain_net(16, &[32], 10, 6);
    let data = samples(6, 16, 6, 99);
    let config = SocConfig::default();
    let mut soc = Soc::new(net.clone(), config.clone()).unwrap();
    let mut cluster = Cluster::new(net.clone(), config.clone()).unwrap();
    assert_eq!(cluster.chips(), 1);
    assert_eq!(cluster.shards(), 1);
    assert!(cluster.l3_stats().is_none(), "one chip has no ring");

    for s in &data {
        let a = soc.run_sample(s, true).unwrap();
        let b = cluster.run_sample(s, true).unwrap();
        // Spike order/content: per-class counts are the readout's spike
        // stream in arrival order.
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.predicted, b.predicted);
        assert_eq!(a.correct, b.correct);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.sops, b.sops);
        assert_eq!(a.spikes_routed, b.spikes_routed);
        assert_eq!(a.cores_ticked, b.cores_ticked);
    }

    let ra = soc.snapshot_report("oracle");
    let rb = cluster.snapshot_report("oracle");
    assert_eq!(ra.cycles, rb.cycles);
    assert_eq!(ra.sops, rb.sops);
    assert_eq!(ra.spikes_routed, rb.spikes_routed);
    assert_eq!(ra.samples, rb.samples);
    assert_eq!(
        ra.accuracy.map(f64::to_bits),
        rb.accuracy.map(f64::to_bits)
    );
    assert_eq!(ra.pj_per_sop.to_bits(), rb.pj_per_sop.to_bits());
    assert_eq!(ra.power_mw.to_bits(), rb.power_mw.to_bits());
    assert_eq!(
        ra.breakdown.dynamic_pj.to_bits(),
        rb.breakdown.dynamic_pj.to_bits()
    );
    assert_eq!(
        ra.breakdown.static_pj.to_bits(),
        rb.breakdown.static_pj.to_bits()
    );
    // Every ledger line, dynamic and static, bit for bit.
    assert_eq!(ra.breakdown.by_class.len(), rb.breakdown.by_class.len());
    for (k, v) in &ra.breakdown.by_class {
        assert_eq!(
            Some(v.to_bits()),
            rb.breakdown.by_class.get(k).map(|x| x.to_bits()),
            "dynamic ledger diverged at {k}"
        );
    }
    assert_eq!(ra.breakdown.by_static.len(), rb.breakdown.by_static.len());
    for (k, v) in &ra.breakdown.by_static {
        assert_eq!(
            Some(v.to_bits()),
            rb.breakdown.by_static.get(k).map(|x| x.to_bits()),
            "static ledger diverged at {k}"
        );
    }

    // The serving dispatch agrees: at chips == 1 the engine is a plain
    // chip, not a degenerate cluster.
    let engine = Engine::new(net.clone(), config).unwrap();
    assert!(engine.as_soc().is_some());
    assert!(engine.as_cluster().is_none());
    // And the builder choke point hands out the same single-shard shape.
    let built = SocBuilder::new().build_cluster(&net).unwrap();
    assert_eq!(built.shards(), 1);
}

/// Cluster-wide flit conservation under randomized fault plans: however
/// the fabrics are killed or throttled — on-chip routers, ring nodes,
/// ring links, at cycle or timestep granularity — every flit handed to
/// any fabric is delivered, dropped, or in flight, and nothing is in
/// flight at sample boundaries.
#[test]
fn prop_cluster_conservation_under_random_fault_plans() {
    check("cluster-conservation", 12, 0xC1057E8, |r| {
        let chips = 2 + r.below_usize(3); // 2..=4 chips
        // Chip capacity is 3 cores; a 32-wide layer packs 2 cores, so a
        // chip holds exactly one hidden layer (the terminal chip adds
        // the 1-core classifier): `depth ≤ chips` is the exact
        // layer-contiguous feasibility rule, and `depth ≥ 2` forces a
        // real multi-shard split.
        let depth = 2 + r.below_usize(chips - 1); // 2..=chips
        let widths: Vec<usize> = (0..depth).map(|_| 32).collect();
        let net = chain_net(16, &widths, 10, 5);
        let mut plan = FaultPlan::none();
        // Up to three random events, mixing the on-chip and L3 halves.
        for _ in 0..(1 + r.below_usize(3)) {
            let when = if r.below_usize(2) == 0 {
                When::Timestep(r.below_usize(5) as u32)
            } else {
                When::Cycle(1 + r.below_usize(200) as u64)
            };
            match r.below_usize(4) {
                0 => plan = plan.kill_l3(r.below_usize(chips), when),
                1 => plan = plan.throttle_l3(2 + r.below_usize(3) as u64, when),
                2 => plan = plan.kill_router(r.below_usize(12), when),
                _ => {
                    plan = plan.throttle(
                        LinkLevel::L1,
                        2 + r.below_usize(3) as u64,
                        when,
                    )
                }
            }
        }
        let config = SocConfig {
            chips,
            n_cores: 3,
            max_neurons_per_core: 16,
            fault_plan: plan,
            ..SocConfig::default()
        };
        let mut cluster = Cluster::new(net, config).unwrap();
        assert!(cluster.shards() > 1, "geometry must force a real split");
        for s in &samples(4, 16, 5, r.next_u32() as u64) {
            cluster.run_sample(s, true).unwrap();
            let c = cluster.conservation();
            assert!(
                c.holds(),
                "injected {} != delivered {} + dropped {} + in_flight {}",
                c.injected,
                c.delivered,
                c.dropped,
                c.in_flight
            );
            assert_eq!(c.in_flight, 0, "fabrics drain at sample boundaries");
        }
        // The books stay balanced across a warm session boundary too.
        cluster.reset_for_session();
        let c = cluster.conservation();
        assert_eq!(c, Default::default(), "reset zeroes every counter");
    });
}

/// Partition-balance regression at Fig. 3 geometry: a chain of
/// [`FIG3_NEURONS`]-wide layers has equal-width interfaces everywhere,
/// so the min-cut DP must break the tie toward balanced shards — the
/// 2|2 split, never 3|1 — and report the cut as exactly one interface.
#[test]
fn fig3_geometry_partitions_balance() {
    let widths = [FIG3_NEURONS; 3];
    let net = chain_net(FIG3_AXONS, &widths, FIG3_NEURONS, 4);
    // One Fig. 3 core holds a full 256-neuron layer: 4 one-core layers
    // over two 3-core chips.
    let p = ClusterMapper::plan(&net, 2, 3, FIG3_NEURONS).unwrap();
    assert_eq!(p.shards(), 2);
    assert_eq!(p.ranges, vec![(0, 2), (2, 4)]);
    assert_eq!(p.cut_neurons, FIG3_NEURONS, "exactly one cut interface");
    assert_eq!(p.cores_of(&net, 0, FIG3_NEURONS), 2);
    assert_eq!(p.cores_of(&net, 1, FIG3_NEURONS), 2);

    // Same geometry, four chips: the balanced 1|1|1|1 cover wins and the
    // cut is every interface — capacity scaling never trades balance
    // away when the cuts are equal.
    let p4 = ClusterMapper::plan(&net, 4, 1, FIG3_NEURONS).unwrap();
    assert_eq!(p4.shards(), 4);
    assert_eq!(p4.cut_neurons, 3 * FIG3_NEURONS);
    for s in 0..4 {
        assert_eq!(p4.cores_of(&net, s, FIG3_NEURONS), 1);
    }
}

/// The failover acceptance path, end to end: a ring-node kill lands
/// mid-sample on a three-chip cluster, the next sample boundary moves
/// the dead node's shard onto the spare chip, and the session finishes
/// every remaining sample against the unpartitioned functional
/// reference with the cluster-wide flit books balanced across the
/// replan. A warm reset then replays the whole degraded session bit
/// for bit, and the serving stack surfaces the replan count in its
/// per-session ledger.
#[test]
fn mid_session_chip_kill_fails_over_and_completes_the_session() {
    // 3-core chips at 16 neurons/core: l0 packs 2 cores and l1 + the
    // classifier pack 3, so `{l0} | {l1,l2}` is the only feasible
    // two-shard split — ring node 2 starts as the spare.
    let net = chain_net(16, &[32, 32], 10, 5);
    let data = samples(5, 16, 5, 0xFA11);
    let plan = FaultPlan::none().kill_l3(1, When::Timestep(2));
    let config = SocConfig {
        chips: 3,
        n_cores: 3,
        max_neurons_per_core: 16,
        failover: true,
        fault_plan: plan.clone(),
        ..SocConfig::default()
    };
    let mut cluster = Cluster::new(net.clone(), config).unwrap();
    assert_eq!(cluster.shards(), 2, "min-cut picks the two-shard split");
    assert_eq!(cluster.shard_nodes(), &[0, 1]);

    // Sample 0 catches the kill mid-flight: boundary flits drop, but
    // replans wait for a boundary where every fabric is drained.
    let mut results = vec![cluster.run_sample(&data[0], true).unwrap()];
    let storm_drops = cluster.l3_stats().unwrap().dropped;
    assert!(storm_drops > 0, "the kill must land mid-sample");
    assert_eq!(cluster.replans(), 0, "replans happen at boundaries");

    // The next boundary fails over onto the spare; every remaining
    // sample completes and matches the unpartitioned reference.
    for s in &data[1..] {
        results.push(cluster.run_sample(s, true).unwrap());
    }
    assert_eq!(cluster.replans(), 1);
    assert_eq!(cluster.shard_nodes(), &[0, 2], "shard 1 took the spare");
    for (i, (r, s)) in results.iter().zip(&data).enumerate().skip(1) {
        let raster = s.to_raster(net.timesteps, net.input_size());
        assert_eq!(
            r.counts,
            net.reference_run(&raster),
            "sample {i} diverged post-replan"
        );
    }
    // The bidirectional ring reaches the spare without touching the
    // dead node, so the drop counter freezes at its storm value.
    assert_eq!(cluster.l3_stats().unwrap().dropped, storm_drops);
    let books = cluster.conservation();
    assert!(books.holds(), "books must span the replan: {books:?}");
    assert_eq!(books.in_flight, 0);
    assert!(books.dropped > 0, "pre-replan drops stay on the books");

    // Warm reset restores the base partition, then the whole degraded
    // session — storm, boundary drops, failover — replays bit for bit.
    cluster.reset_for_session();
    assert_eq!(cluster.replans(), 0);
    assert_eq!(cluster.shard_nodes(), &[0, 1], "reset restores the base");
    for (i, (first, s)) in results.iter().zip(&data).enumerate() {
        let again = cluster.run_sample(s, true).unwrap();
        assert_eq!(first.counts, again.counts, "replay diverged at {i}");
        assert_eq!(first.cycles, again.cycles, "replay diverged at {i}");
        assert_eq!(first.sops, again.sops, "replay diverged at {i}");
        assert_eq!(first.spikes_routed, again.spikes_routed);
    }
    assert_eq!(cluster.replans(), 1, "the replay fails over too");
    assert_eq!(cluster.conservation(), books, "bit-identical books");

    // The serving stack carries the event end to end: the builder choke
    // point wires `--failover` into the pool, and the session ledger
    // reports the replan on a completed verdict.
    let report = SocBuilder::new()
        .chips(3)
        .n_cores(3)
        .max_neurons_per_core(16)
        .failover(true)
        .fault_plan(plan)
        .build_pool(&net)
        .unwrap()
        .serve_sequential(vec![SessionSpec::new(
            "failover",
            Box::new(TrafficWorkload::new(16, 10, 5, 0.25, 4, 7)),
        )])
        .unwrap();
    assert!(report.failures.is_empty());
    assert_eq!(report.sessions.len(), 1);
    assert_eq!(report.sessions[0].verdict, SessionVerdict::Completed);
    assert_eq!(report.sessions[0].replans, 1);
}
