//! Integration tests for the streaming serving API: `SocBuilder` as the
//! single validation choke point, `Session` snapshot/close semantics and
//! the `SocPool` concurrency-determinism guarantee (≥2 concurrent
//! sessions bit-identical to the same sessions run sequentially).

use fullerene_soc::config::RunConfig;
use fullerene_soc::coordinator::GoldenCheck;
use fullerene_soc::core::neuron::{LeakMode, NeuronParams, ResetMode};
use fullerene_soc::core::Codebook;
use fullerene_soc::nn::network::{LayerDesc, NetworkDesc};
use fullerene_soc::serve::{
    SessionSpec, SocBuilder, SocPool, TrafficWorkload, Workload,
};

fn small_net(inputs: usize, hidden: usize, classes: usize, timesteps: usize) -> NetworkDesc {
    let cb = Codebook::default_log16();
    let params = NeuronParams {
        threshold: 50,
        leak: LeakMode::Linear(1),
        reset: ResetMode::Subtract,
        mp_bits: 16,
    };
    NetworkDesc {
        name: "serve-test".into(),
        layers: vec![
            LayerDesc {
                name: "h".into(),
                inputs,
                neurons: hidden,
                codebook: cb.clone(),
                widx: (0..inputs * hidden).map(|i| ((i * 11) % 16) as u8).collect(),
                neuron_params: params.clone(),
            },
            LayerDesc {
                name: "o".into(),
                inputs: hidden,
                neurons: classes,
                codebook: cb,
                widx: (0..hidden * classes).map(|i| ((i * 5) % 16) as u8).collect(),
                neuron_params: params,
            },
        ],
        timesteps,
        classes,
    }
}

fn traffic_specs(n: usize, samples: usize) -> Vec<SessionSpec> {
    (0..n)
        .map(|i| {
            SessionSpec::new(
                &format!("sess{i}"),
                Box::new(TrafficWorkload::new(40, 4, 5, 0.15, samples, 100 + i as u64)),
            )
        })
        .collect()
}

/// Acceptance criterion: ≥2 concurrent sessions produce reports
/// bit-identical (`f64::to_bits`) to the same sessions run sequentially.
#[test]
fn concurrent_sessions_bit_identical_to_sequential() {
    let net = small_net(40, 24, 4, 5);
    let pool = SocPool::new(
        net,
        fullerene_soc::soc::SocConfig::default(),
        3,
        GoldenCheck::Reference,
    )
    .unwrap();
    let par = pool.serve(traffic_specs(4, 5)).unwrap();
    let seq = pool.serve_sequential(traffic_specs(4, 5)).unwrap();

    assert_eq!(par.sessions.len(), 4);
    assert_eq!(par.checked, 20);
    assert_eq!(par.mismatches, 0, "chip diverged from reference");
    assert_eq!(par.mismatches, seq.mismatches);

    // Per-session reports are bit-identical in submission order …
    for (a, b) in par.sessions.iter().zip(&seq.sessions) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.report.cycles, b.report.cycles);
        assert_eq!(a.report.sops, b.report.sops);
        assert_eq!(a.report.pj_per_sop.to_bits(), b.report.pj_per_sop.to_bits());
        assert_eq!(a.report.power_mw.to_bits(), b.report.power_mw.to_bits());
        assert_eq!(a.stats.samples, b.stats.samples);
        assert_eq!(a.stats.cycles, b.stats.cycles);
    }
    // … and so is the deterministic merge.
    let (m, s) = (&par.merged, &seq.merged);
    assert_eq!(m.cycles, s.cycles);
    assert_eq!(m.sops, s.sops);
    assert_eq!(m.samples, s.samples);
    assert_eq!(m.pj_per_sop.to_bits(), s.pj_per_sop.to_bits());
    assert_eq!(m.core_pj_per_sop.to_bits(), s.core_pj_per_sop.to_bits());
    assert_eq!(m.power_mw.to_bits(), s.power_mw.to_bits());
    assert_eq!(
        m.breakdown.dynamic_pj.to_bits(),
        s.breakdown.dynamic_pj.to_bits()
    );
    assert_eq!(
        m.breakdown.static_pj.to_bits(),
        s.breakdown.static_pj.to_bits()
    );
    assert_eq!(m.breakdown.by_class, s.breakdown.by_class);
    assert_eq!(m.breakdown.by_static, s.breakdown.by_static);
}

/// Sessions are isolated: each runs on its own chip, so a session's
/// report covers exactly its own samples.
#[test]
fn sessions_have_independent_ledgers() {
    let net = small_net(40, 24, 4, 5);
    let pool = SocPool::new(
        net,
        fullerene_soc::soc::SocConfig::default(),
        2,
        GoldenCheck::None,
    )
    .unwrap();
    let out = pool.serve(traffic_specs(3, 4)).unwrap();
    for s in &out.sessions {
        assert_eq!(s.report.samples, 4);
        assert_eq!(s.stats.samples, 4);
        assert!(s.stats.p99_latency_ms >= s.stats.p50_latency_ms);
        assert!(s.report.pj_per_sop.is_finite());
    }
    assert_eq!(out.merged.samples, 12);
}

/// Pool guard rails: XLA checks, zero workers, zero sessions and
/// geometry mismatches are all hard errors.
#[test]
fn pool_rejects_invalid_setups() {
    let net = small_net(40, 24, 4, 5);
    let cfg = fullerene_soc::soc::SocConfig::default();
    assert!(SocPool::new(net.clone(), cfg.clone(), 2, GoldenCheck::Xla).is_err());
    assert!(SocPool::new(net.clone(), cfg.clone(), 0, GoldenCheck::None).is_err());
    let pool = SocPool::new(net, cfg, 2, GoldenCheck::None).unwrap();
    assert!(pool.serve(Vec::new()).is_err(), "zero sessions must error");
    // 64-input traffic against a 40-input network.
    let bad = vec![SessionSpec::new(
        "bad",
        Box::new(TrafficWorkload::new(64, 4, 5, 0.1, 2, 1)),
    )];
    assert!(pool.serve(bad).is_err());
}

/// Session streaming semantics: snapshots are incremental and the close
/// report is bit-identical to a snapshot taken at the same point.
#[test]
fn session_snapshot_is_incremental_and_matches_close() {
    let net = small_net(40, 24, 4, 5);
    let mut wl = TrafficWorkload::new(40, 4, 5, 0.2, 3, 9);
    let mut session = SocBuilder::new().open_session(&net, "snap").unwrap();
    session.push(&wl.next_sample().unwrap()).unwrap();
    let s1 = session.snapshot();
    assert_eq!(s1.samples, 1);
    session.push(&wl.next_sample().unwrap()).unwrap();
    session.push(&wl.next_sample().unwrap()).unwrap();
    let s3 = session.snapshot();
    assert_eq!(s3.samples, 3);
    assert!(s3.cycles > s1.cycles, "snapshot must extend the window");
    let closed = session.close();
    assert_eq!(closed.report.samples, 3);
    assert_eq!(closed.report.pj_per_sop.to_bits(), s3.pj_per_sop.to_bits());
    assert_eq!(closed.report.power_mw.to_bits(), s3.power_mw.to_bits());
    assert_eq!(closed.stats.samples, 3);
    assert!(closed.stats.p50_latency_ms > 0.0);
}

/// Regression for the validation choke point: configs assembled the way
/// the CLI assembles them (mutating a default `RunConfig` from flags,
/// never touching the JSON loader) must still be range-checked, because
/// the builder validates on every build path.
#[test]
fn cli_style_configs_cannot_skip_validation() {
    let net = small_net(40, 24, 4, 5);

    // Flag-style mutation: --domains 0 used to reach Soc::new unchecked
    // unless the caller remembered RunConfig::validate.
    let mut cfg = RunConfig::default();
    cfg.soc.domains = 0;
    assert!(cfg.validate().is_err());
    assert!(SocBuilder::from_run_config(&cfg).build_runner(net.clone()).is_err());
    assert!(SocBuilder::from_run_config(&cfg).build_soc(&net).is_err());
    assert!(SocBuilder::from_run_config(&cfg).build_pool(&net).is_err());
    assert!(SocBuilder::from_run_config(&cfg)
        .open_session(&net, "x")
        .is_err());

    let mut cfg = RunConfig::default();
    cfg.soc.supply_v = 2.0; // --supply 2.0
    assert!(SocBuilder::from_run_config(&cfg).build_soc(&net).is_err());

    let mut cfg = RunConfig::default();
    cfg.soc.n_cores = 21; // --domains 1 with 21 cores
    assert!(SocBuilder::from_run_config(&cfg).build_soc(&net).is_err());

    // The happy path still builds.
    let cfg = RunConfig::default();
    assert!(SocBuilder::from_run_config(&cfg).build_soc(&net).is_ok());
}

/// The fluent path hits the same choke point as the RunConfig path.
#[test]
fn builder_is_the_single_choke_point() {
    let net = small_net(40, 24, 4, 5);
    assert!(SocBuilder::new()
        .fifo_depth(0)
        .open_session(&net, "x")
        .is_err());
    assert!(SocBuilder::new()
        .f_core_mhz(500.0)
        .build_soc(&net)
        .is_err());
    assert!(SocBuilder::new().workers(0).build_pool(&net).is_err());
}
