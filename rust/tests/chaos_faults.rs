//! Chaos regression suite for the fault-injection subsystem: the
//! degraded-fabric **failure modes** users actually hit, pinned at the
//! integration boundary (public `fullerene_soc::noc` API only).
//!
//! The headline regression: a severed link must strand committed flits
//! at a **fast-failing fixed point** classified `FabricDegraded` — not
//! spin the drain loop until its cycle budget dies. The rest pins the
//! fullerene-vs-mesh single-kill asymmetry (the paper's degree-3 core
//! attach buys reroutes where a mesh strands cores outright), kill-storm
//! determinism through the string spec grammar, and the parse surface.

use fullerene_soc::energy::{EnergyParams, EventClass};
use fullerene_soc::noc::topology::NO_PORT;
use fullerene_soc::noc::{Dest, FaultPlan, NocSim, NodeId, Topology, When, FAULT_SPEC_USAGE};

fn sim(t: Topology) -> NocSim {
    NocSim::new(t, 4, EnergyParams::nominal())
}

/// A `(src core, dst core)` pair whose pristine route leaves the source
/// over the link to `router` — traffic guaranteed to feel a fault there.
fn pair_via_router(t: &Topology, router: NodeId) -> (usize, usize) {
    let out = t.out_port_table();
    for c in 0..t.cores().len() {
        let n = t.core_node(c);
        for dst in 0..t.cores().len() {
            if dst == c {
                continue;
            }
            let p = out[n][dst];
            if p != NO_PORT && t.neighbors(n)[p as usize] == router {
                return (c, dst);
            }
        }
    }
    panic!("no pristine route uses router {router}");
}

/// The killed-link fixed point fails **fast** with a `FabricDegraded`
/// stall classification and a stranded-flit count — it must never spin
/// until the caller's cycle budget is exhausted.
///
/// Stranding a flit on a link cut takes backpressure: at a cycle
/// boundary an idle fabric holds nothing in output FIFOs, and routing
/// recomputes around the dead link before the next arbitration. So the
/// recipe congests the first-hop router until flits back up into the
/// source's output FIFO, then cuts the source→router link underneath
/// them. Flits already committed to that FIFO have nowhere to go.
#[test]
fn killed_link_reports_fabric_degraded_instead_of_spinning() {
    let t = Topology::fullerene();
    let (c, dst) = pair_via_router(&t, 0);
    let src_node = t.core_node(c);
    let run = || {
        let mut s = sim(t.clone());
        s.set_fault_plan(
            FaultPlan::none()
                .congest(0, 300, When::Cycle(1))
                .kill_link(src_node, 0, When::Cycle(20)),
        )
        .unwrap();
        // Enough traffic through the congested router to fill its input
        // FIFO (depth 4) and back the overflow up into the source core's
        // output FIFO before the cycle-20 cut.
        let injected = 12u64;
        for _ in 0..injected {
            s.inject(c, &Dest::Core(dst), 0);
        }
        let budget = 1_000_000;
        let err = s.run_until_drained(budget).unwrap_err().to_string();
        assert!(
            err.contains("FabricDegraded"),
            "stall misclassified: {err}"
        );
        assert!(err.contains("flits stranded"), "no stranded count: {err}");
        // Fast fail: the congestion window self-expires around cycle 300
        // and the fixed point is classified within the plan's
        // zero-progress tolerance — nowhere near the million-cycle
        // budget a spinning drain would burn.
        assert!(
            s.cycle() < 5_000,
            "drain spun to cycle {} against a {budget} budget",
            s.cycle()
        );
        let h = s.fabric_health();
        let st = s.stats();
        assert_eq!(h.dead_links, 1);
        assert!(s.in_flight() > 0, "nothing stranded — the cut missed");
        assert_eq!(
            st.delivered + h.dropped + s.in_flight(),
            injected,
            "conservation must hold at the degraded fixed point"
        );
        assert_eq!(s.snapshot_ledger().count(EventClass::FlitDropped), h.dropped);
        (st, h, s.in_flight(), s.cycle())
    };
    let (sa, ha, ia, ca) = run();
    let (sb, hb, ib, cb) = run();
    // The degraded fixed point itself is deterministic.
    assert_eq!(ha, hb);
    assert_eq!(ia, ib);
    assert_eq!(ca, cb);
    assert_eq!(sa.delivered, sb.delivered);
    assert_eq!(sa.avg_latency.to_bits(), sb.avg_latency.to_bits());
}

/// The resilience asymmetry the paper's topology buys, at flit level:
/// a mesh core hangs off exactly one router, so killing it strands every
/// flit addressed to (or sourced at) that core — while the fullerene's
/// 3-router core attach reroutes around any single kill and delivers
/// everything. Either way the fabric **drains**: undeliverable flits go
/// to the dropped ledger, never into a busy-loop.
#[test]
fn single_kill_strands_a_mesh_core_but_not_a_fullerene_core() {
    // Mesh: kill core 0's only router, aim every core at core 0.
    let t = Topology::mesh2d(4, 5);
    let victim_router = t.neighbors(t.core_node(0))[0];
    let n_cores = t.cores().len();
    let mut m = sim(t);
    m.set_fault_plan(FaultPlan::none().kill_router(victim_router, When::Cycle(1)))
        .unwrap();
    for c in 1..n_cores {
        m.inject(c, &Dest::Core(0), 0);
    }
    m.inject(0, &Dest::Core(7), 0);
    m.run_until_drained(100_000)
        .expect("a kill-only plan must always drain (dropped, not stuck)");
    let h = m.fabric_health();
    assert_eq!(m.in_flight(), 0);
    assert_eq!(h.dead_routers, 1);
    assert_eq!(
        h.dropped,
        n_cores as u64,
        "every flit to/from the orphaned core must drop"
    );
    assert_eq!(m.stats().delivered, 0);

    // Fullerene: same shape of attack, zero loss.
    let t = Topology::fullerene();
    let (c, dst) = pair_via_router(&t, 0);
    let mut f = sim(t);
    f.set_fault_plan(FaultPlan::none().kill_router(0, When::Cycle(1)))
        .unwrap();
    for src in 0..20 {
        f.inject(src, &Dest::Core((src + 7) % 20), 0);
    }
    f.inject(c, &Dest::Core(dst), 1);
    f.run_until_drained(100_000).unwrap();
    let h = f.fabric_health();
    assert_eq!(h.dead_routers, 1);
    assert_eq!(h.dropped, 0, "fullerene must reroute a single kill");
    assert_eq!(f.stats().delivered, 21);
    assert!(h.rerouted_hops >= 1, "the kill must force a detour");
}

/// A kill storm armed through the **string grammar** (the CLI/config
/// path) is bit-identically deterministic run to run, including the
/// seeded `kill-frac` expansion, and conserves every flit.
#[test]
fn parsed_kill_storm_is_deterministic_and_conserves_flits() {
    let spec = "throttle-l1:2@1;congest:7+25@3;kill-router:3@5;kill-frac:0.2#77@9";
    let run = || {
        let mut s = sim(Topology::fullerene());
        s.set_fault_plan(FaultPlan::parse(spec).unwrap()).unwrap();
        let mut injected = 0u64;
        for round in 0..10u32 {
            for c in 0..20 {
                s.inject(c, &Dest::Core((c + 9) % 20), round);
                injected += 1;
            }
        }
        s.run_until_drained(1_000_000).unwrap();
        (s.stats(), s.fabric_health(), s.switch_visits(), injected)
    };
    let (sa, ha, va, injected) = run();
    // fullerene: 12 routers, kill-frac 0.2 → round(2.4) = 2 seeded kills,
    // plus the explicit kill of router 3 (the seeded picks may overlap it).
    assert!(ha.armed);
    assert!((2..=3).contains(&ha.dead_routers), "dead {}", ha.dead_routers);
    assert_eq!(sa.delivered + ha.dropped, injected, "flit conservation");
    let (sb, hb, vb, _) = run();
    assert_eq!(ha, hb, "fabric health must replay bit-identically");
    assert_eq!(va, vb, "worklist activity must replay bit-identically");
    assert_eq!(sa.delivered, sb.delivered);
    assert_eq!(sa.avg_latency.to_bits(), sb.avg_latency.to_bits());
    assert_eq!(sa.avg_hops.to_bits(), sb.avg_hops.to_bits());
    assert_eq!(sa.max_latency, sb.max_latency);
}

/// Multi-domain (D=4) chaos: an L2 scale-up throttle layered under two
/// staggered router kills on the 4-domain hierarchical fabric. The
/// compound plan must drain (kills drop eagerly, a throttle only slows
/// arbitration), conserve every flit, and replay bit-identically —
/// faults on the L2 ring are as deterministic as single-domain ones.
#[test]
fn multi_domain_l2_throttle_under_router_kills_conserves_and_replays() {
    use fullerene_soc::noc::LinkLevel;

    let t = Topology::multi_domain(4);
    let n_cores = t.cores().len();
    assert_eq!(n_cores, 80, "4 domains × 20 cores");
    let routers = t.routers();
    // One kill early in domain 0's L1 fabric, one later and further
    // into the router list (a different domain), with every scale-up
    // link running at a third of its arbitration rate in between.
    let (ra, rb) = (routers[0], routers[routers.len() / 2]);
    let run = || {
        let mut s = sim(t.clone());
        s.set_fault_plan(
            FaultPlan::none()
                .throttle(LinkLevel::L2, 3, When::Cycle(5))
                .kill_router(ra, When::Cycle(9))
                .kill_router(rb, When::Cycle(40)),
        )
        .unwrap();
        let mut injected = 0u64;
        for round in 0..10u32 {
            for c in 0..n_cores {
                // (c + 27) % 80 crosses domain boundaries for most
                // sources, so the throttled L2 ring carries real load.
                s.inject(c, &Dest::Core((c + 27) % n_cores), round);
                injected += 1;
            }
        }
        s.run_until_drained(2_000_000)
            .expect("kill+throttle plans must drain, never wedge");
        assert_eq!(s.in_flight(), 0);
        let h = s.fabric_health();
        let st = s.stats();
        assert_eq!(h.dead_routers, 2, "both staggered kills must fire");
        assert_eq!(
            st.delivered + h.dropped,
            injected,
            "conservation across 4 domains + L2 ring"
        );
        assert!(st.delivered > 0, "the degraded fabric went dark");
        (st, h, s.switch_visits(), s.cycle())
    };
    let (sa, ha, va, ca) = run();
    let (sb, hb, vb, cb) = run();
    assert_eq!(ha, hb, "fabric health must replay bit-identically");
    assert_eq!(va, vb, "worklist activity must replay bit-identically");
    assert_eq!(ca, cb);
    assert_eq!(sa.delivered, sb.delivered);
    assert_eq!(sa.avg_latency.to_bits(), sb.avg_latency.to_bits());
    assert_eq!(sa.avg_hops.to_bits(), sb.avg_hops.to_bits());
}

/// The spec grammar's public contract: usage text exists, round-trip
/// parses hold, and malformed specs are rejected with the usage hint —
/// the same strings `--fault-plan` and the JSON `fault_plan` key accept.
#[test]
fn fault_spec_grammar_round_trips_and_rejects_garbage() {
    assert!(FAULT_SPEC_USAGE.contains("kill-router"));
    assert!(FAULT_SPEC_USAGE.contains("kill-frac"));

    let plan =
        FaultPlan::parse("kill-router:0@t2; kill-link:1-2@30; throttle-l2:3@7; congest:4+50@9")
            .unwrap();
    assert!(!plan.is_empty());
    assert_eq!(plan.events.len(), 4);

    // Whitespace/empty specs mean "no faults".
    assert!(FaultPlan::parse("").unwrap().is_empty());
    assert!(FaultPlan::parse("  ;  ; ").unwrap().is_empty());

    for bad in [
        "bogus",
        "kill-router:zzz@1",
        "kill-router:1",          // missing @when
        "kill-link:5@1",          // missing -b endpoint
        "throttle-l1:0@1",        // factor < 1
        "congest:1+0@1",          // zero-length window
        "kill-frac:1.5#9@1",      // frac out of [0,1]
        "kill-router:1@t",        // empty timestep
    ] {
        assert!(FaultPlan::parse(bad).is_err(), "accepted: {bad:?}");
    }

    // Structurally valid specs can still name a non-router: that is the
    // arming-time validation's job (node 15 is a fullerene core).
    let plan = FaultPlan::parse("kill-router:15@1").unwrap();
    assert!(sim(Topology::fullerene()).set_fault_plan(plan).is_err());
}
