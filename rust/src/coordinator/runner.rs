//! [`ExperimentRunner`]: the batch experiment layer, rebuilt on the
//! streaming serving primitives — run a dataset on the simulated chip,
//! optionally cross-checking every sample against the functional
//! references (the in-process integer reference and/or the AOT-compiled
//! XLA golden model).
//!
//! Internally a batch run is one [`crate::serve::Session`] over the
//! serving [`Engine`] the config asks for (one chip, or a whole cluster
//! when `soc.chips > 1`); a sharded run
//! ([`ExperimentRunner::run_parallel`]) submits one
//! [`crate::serve::EventReplay`] session per contiguous shard — a pure
//! function of `(n, workers)` — to a [`crate::serve::ServeRuntime`],
//! with the per-shard [`ChipReport`]s merged in submission order through
//! [`ChipReport::merged`]. Because the simulator is deterministic and
//! the merge order is fixed, the aggregate is **bit-identical** to
//! executing the same shards sequentially
//! ([`ExperimentRunner::run_sharded`] with `parallel = false`, the
//! [`crate::serve::SocPool`] reference path), regardless of thread
//! scheduling.

use crate::cluster::Engine;
use crate::datasets::Dataset;
use crate::energy::ChipReport;
use crate::nn::NetworkDesc;
use crate::runtime::GoldenModel;
use crate::serve::{EventReplay, ServeRuntime, Session, SessionSpec, SocPool};
use crate::soc::SocConfig;
use crate::{Error, Result};
use std::path::PathBuf;

/// What to validate against while running.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GoldenCheck {
    /// No cross-checking (fastest).
    None,
    /// Check against [`NetworkDesc::reference_run`] (pure Rust).
    Reference,
    /// Check against the XLA-executed AOT artifact.
    Xla,
    /// Check against both.
    Both,
}

/// Experiment configuration. Prefer assembling it through
/// [`crate::serve::SocBuilder::build_runner`], which validates every
/// field on the way in.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Chip configuration.
    pub soc: SocConfig,
    /// Max samples to run.
    pub limit: usize,
    /// Cross-check mode.
    pub check: GoldenCheck,
    /// Artifacts directory (for the XLA golden model).
    pub artifacts: PathBuf,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            soc: SocConfig::default(),
            limit: usize::MAX,
            check: GoldenCheck::Reference,
            artifacts: GoldenModel::artifacts_dir(),
        }
    }
}

/// Outcome of an experiment run.
#[derive(Debug)]
pub struct ExperimentOutcome {
    /// Chip-level report (Table-I row; a deterministic merge of shard
    /// reports for sharded runs).
    pub report: ChipReport,
    /// Samples where the chip disagreed with a reference (should be 0).
    pub mismatches: u64,
    /// Samples checked against a golden model.
    pub checked: u64,
}

/// Shard `w` of `workers` over `n` items: the contiguous range
/// `[w·n/workers, (w+1)·n/workers)`. Pure in its inputs, so sequential
/// and parallel execution see identical work splits.
fn shard_range(n: usize, workers: usize, w: usize) -> (usize, usize) {
    (w * n / workers, (w + 1) * n / workers)
}

/// The runner.
pub struct ExperimentRunner {
    net: NetworkDesc,
    config: ExperimentConfig,
    golden: Option<GoldenModel>,
}

impl ExperimentRunner {
    /// Build a runner; loads the XLA golden model when requested.
    pub fn new(net: NetworkDesc, config: ExperimentConfig) -> Result<ExperimentRunner> {
        let golden = match config.check {
            GoldenCheck::Xla | GoldenCheck::Both => {
                Some(GoldenModel::load(&config.artifacts, &net.name)?)
            }
            _ => None,
        };
        Ok(ExperimentRunner { net, config, golden })
    }

    /// Run the dataset through the configured engine (one chip, or a
    /// `soc.chips`-shard cluster) as one streaming session; returns the
    /// report and the mismatch count against the requested references.
    pub fn run(&self, ds: &Dataset) -> Result<ExperimentOutcome> {
        if ds.inputs != self.net.input_size() {
            return Err(Error::Config(format!(
                "dataset inputs {} != network inputs {}",
                ds.inputs,
                self.net.input_size()
            )));
        }
        let engine = Engine::new(self.net.clone(), self.config.soc.clone())?;
        let mut session = Session::open_engine(engine, &ds.name);
        let mut mismatches = 0u64;
        let mut checked = 0u64;
        let use_ref = matches!(
            self.config.check,
            GoldenCheck::Reference | GoldenCheck::Both
        );
        let n = ds.samples.len().min(self.config.limit);
        for sample in &ds.samples[..n] {
            let r = session.push(sample)?;
            if use_ref {
                let raster = sample.to_raster(self.net.timesteps, self.net.input_size());
                let expect = self.net.reference_run(&raster);
                checked += 1;
                if expect != r.counts {
                    mismatches += 1;
                }
            }
            if let Some(g) = &self.golden {
                let expect = g.run_sample(sample)?;
                checked += 1;
                if expect != r.counts {
                    mismatches += 1;
                }
            }
        }
        Ok(ExperimentOutcome {
            report: session.close().report,
            mismatches,
            checked,
        })
    }

    /// Sharded batch run across all host cores: one session per
    /// contiguous sample shard, served by a [`SocPool`], merged
    /// deterministically. Bit-identical to
    /// [`ExperimentRunner::run_sharded`] with `parallel = false` for the
    /// same `(dataset, workers)` input.
    ///
    /// The XLA golden model holds per-process runtime state, so only
    /// [`GoldenCheck::None`] and [`GoldenCheck::Reference`] are supported
    /// here; use [`ExperimentRunner::run`] for XLA-checked runs.
    pub fn run_parallel(&self, ds: &Dataset, workers: usize) -> Result<ExperimentOutcome> {
        self.run_sharded(ds, workers, true)
    }

    /// Sharded run with explicit execution mode (`parallel = false`
    /// serves the exact same shard sessions one after another on the
    /// calling thread — the reference path for the bit-identity
    /// guarantee).
    pub fn run_sharded(
        &self,
        ds: &Dataset,
        workers: usize,
        parallel: bool,
    ) -> Result<ExperimentOutcome> {
        if matches!(self.config.check, GoldenCheck::Xla | GoldenCheck::Both) {
            return Err(Error::Config(
                "sharded runner supports check none|reference (XLA golden state \
                 is per-process); use ExperimentRunner::run"
                    .into(),
            ));
        }
        if ds.inputs != self.net.input_size() {
            return Err(Error::Config(format!(
                "dataset inputs {} != network inputs {}",
                ds.inputs,
                self.net.input_size()
            )));
        }
        let n = ds.samples.len().min(self.config.limit);
        let workers = workers.clamp(1, n.max(1));
        // One shared copy of the clipped sample list; every shard is an
        // `[a, b)` window over the same Arc, not a per-shard clone.
        let shared = std::sync::Arc::new(ds.samples[..n].to_vec());
        let specs: Vec<SessionSpec> = (0..workers)
            .map(|w| {
                let (a, b) = shard_range(n, workers, w);
                SessionSpec::new(
                    &ds.name,
                    Box::new(EventReplay::shard(
                        &ds.name,
                        ds.inputs,
                        ds.timesteps,
                        ds.classes,
                        shared.clone(),
                        a,
                        b,
                    )),
                )
            })
            .collect();
        let out = if parallel {
            // A batch run knows every spec up front, so the runtime is
            // sized to the spec list (queue never blocks) and the first
            // per-session failure is converted back into a whole-call
            // `Err` — the batch all-or-nothing contract.
            let mut rt = ServeRuntime::new(
                self.net.clone(),
                self.config.soc.clone(),
                workers,
                self.config.check,
                specs.len(),
                true,
            )?;
            for spec in specs {
                rt.submit(spec)?;
            }
            let out = rt.finish()?;
            if let Some(f) = out.failures.first() {
                return Err(f.error.clone());
            }
            out
        } else {
            let pool = SocPool::new(
                self.net.clone(),
                self.config.soc.clone(),
                workers,
                self.config.check,
            )?;
            pool.serve_sequential(specs)?
        };
        Ok(ExperimentOutcome {
            report: out.merged,
            mismatches: out.mismatches,
            checked: out.checked,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::neuron::{LeakMode, NeuronParams, ResetMode};
    use crate::core::Codebook;
    use crate::datasets::Workload;
    use crate::nn::network::LayerDesc;

    fn small_net_for(w: Workload, hidden: usize) -> NetworkDesc {
        let cb = Codebook::default_log16();
        let params = NeuronParams {
            threshold: 60,
            leak: LeakMode::Linear(1),
            reset: ResetMode::Subtract,
            mp_bits: 16,
        };
        let inputs = w.inputs();
        let classes = w.classes();
        NetworkDesc {
            name: format!("{}-test", w.name()),
            layers: vec![
                LayerDesc {
                    name: "h".into(),
                    inputs,
                    neurons: hidden,
                    codebook: cb.clone(),
                    widx: (0..inputs * hidden).map(|i| ((i * 7) % 16) as u8).collect(),
                    neuron_params: params.clone(),
                },
                LayerDesc {
                    name: "o".into(),
                    inputs: hidden,
                    neurons: classes,
                    codebook: cb,
                    widx: (0..hidden * classes).map(|i| ((i * 5) % 16) as u8).collect(),
                    neuron_params: params,
                },
            ],
            timesteps: w.timesteps(),
            classes,
        }
    }

    #[test]
    fn chip_never_disagrees_with_reference() {
        let net = small_net_for(Workload::Nmnist, 40);
        let ds = Workload::Nmnist.generate(4, 11);
        let runner = ExperimentRunner::new(
            net,
            ExperimentConfig {
                limit: 4,
                check: GoldenCheck::Reference,
                ..ExperimentConfig::default()
            },
        )
        .unwrap();
        let out = runner.run(&ds).unwrap();
        assert_eq!(out.checked, 4);
        assert_eq!(out.mismatches, 0, "cycle sim diverged from reference");
        assert!(out.report.sops > 0);
    }

    #[test]
    fn parallel_runner_is_bit_identical_to_sequential_sharding() {
        let net = small_net_for(Workload::Nmnist, 30);
        let ds = Workload::Nmnist.generate(9, 23);
        let runner = ExperimentRunner::new(
            net,
            ExperimentConfig {
                check: GoldenCheck::Reference,
                ..ExperimentConfig::default()
            },
        )
        .unwrap();
        let par = runner.run_parallel(&ds, 4).unwrap();
        let seq = runner.run_sharded(&ds, 4, false).unwrap();
        assert_eq!(par.mismatches, seq.mismatches);
        assert_eq!(par.checked, seq.checked);
        let (a, b) = (&par.report, &seq.report);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.sops, b.sops);
        assert_eq!(a.spikes_routed, b.spikes_routed);
        assert_eq!(a.samples, b.samples);
        // Floating aggregates must be bit-identical, not merely close.
        assert_eq!(a.pj_per_sop.to_bits(), b.pj_per_sop.to_bits());
        assert_eq!(a.core_pj_per_sop.to_bits(), b.core_pj_per_sop.to_bits());
        assert_eq!(a.power_mw.to_bits(), b.power_mw.to_bits());
        assert_eq!(
            a.breakdown.dynamic_pj.to_bits(),
            b.breakdown.dynamic_pj.to_bits()
        );
        assert_eq!(
            a.breakdown.static_pj.to_bits(),
            b.breakdown.static_pj.to_bits()
        );
        assert_eq!(a.breakdown.by_class, b.breakdown.by_class);
        assert_eq!(par.mismatches, 0, "cycle sim diverged from reference");
    }

    #[test]
    fn single_worker_shard_matches_the_plain_sequential_run() {
        let net = small_net_for(Workload::Nmnist, 24);
        let ds = Workload::Nmnist.generate(4, 5);
        let runner = ExperimentRunner::new(
            net,
            ExperimentConfig {
                check: GoldenCheck::Reference,
                ..ExperimentConfig::default()
            },
        )
        .unwrap();
        let plain = runner.run(&ds).unwrap();
        let shard = runner.run_parallel(&ds, 1).unwrap();
        // One shard = the whole dataset through one Soc: identical counters.
        assert_eq!(plain.report.cycles, shard.report.cycles);
        assert_eq!(plain.report.sops, shard.report.sops);
        assert_eq!(plain.report.samples, shard.report.samples);
        assert_eq!(plain.checked, shard.checked);
        assert_eq!(plain.mismatches, shard.mismatches);
        // Derived metrics are recomputed by the merge, so compare loosely.
        assert!((plain.report.pj_per_sop - shard.report.pj_per_sop).abs() < 1e-9);
    }

    #[test]
    fn sharded_runner_rejects_xla_checks() {
        let net = small_net_for(Workload::Nmnist, 10);
        let ds = Workload::Nmnist.generate(2, 1);
        let runner = ExperimentRunner::new(
            net,
            ExperimentConfig {
                check: GoldenCheck::None,
                ..ExperimentConfig::default()
            },
        )
        .unwrap();
        // GoldenCheck is copied into config before construction; emulate a
        // caller flipping it afterwards via a fresh runner with Xla —
        // construction itself would try to load artifacts, so instead
        // check the public contract through run_sharded's error path by
        // mutating a clone of the config.
        let mut cfg = runner.config.clone();
        cfg.check = GoldenCheck::Xla;
        let bad = ExperimentRunner {
            net: runner.net.clone(),
            config: cfg,
            golden: None,
        };
        assert!(bad.run_sharded(&ds, 2, false).is_err());
    }

    #[test]
    fn dataset_network_mismatch_rejected() {
        let net = small_net_for(Workload::Nmnist, 10);
        let ds = Workload::Cifar10.generate(2, 1);
        let runner =
            ExperimentRunner::new(net, ExperimentConfig::default()).unwrap();
        assert!(runner.run(&ds).is_err());
    }
}
