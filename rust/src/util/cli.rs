//! Tiny CLI flag parser (replaces `clap`, unavailable offline).
//!
//! Grammar: `prog <subcommand> [--key value | --key=value | --flag] ...`.
//! Unknown flags are an error, so typos fail loudly.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand plus `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token (the subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    /// Flags seen (for unknown-flag detection).
    seen: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse(args: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => {
                        // Peek: value unless next is another flag.
                        let next_is_val =
                            it.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                        if next_is_val {
                            (stripped.to_string(), Some(it.next().unwrap()))
                        } else {
                            (stripped.to_string(), None)
                        }
                    }
                };
                out.seen.push(key.clone());
                out.opts.insert(key, val.unwrap_or_else(|| "true".into()));
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the real process arguments.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option with default; exits with a message on parse failure.
    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            None => default,
            Some(s) => s.parse().unwrap_or_else(|_| {
                eprintln!("error: --{key} expects a {}", std::any::type_name::<T>());
                std::process::exit(2);
            }),
        }
    }

    /// Boolean flag (present without value, or `--k=true`).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Error on flags not in `allowed` (call after reading all options).
    pub fn reject_unknown(&self, allowed: &[&str]) -> Result<(), String> {
        for k in &self.seen {
            if !allowed.contains(&k.as_str()) {
                return Err(format!("unknown flag --{k} (allowed: {allowed:?})"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("bench --sparsity 0.5 --out=/tmp/x --fast");
        assert_eq!(a.command.as_deref(), Some("bench"));
        assert_eq!(a.get("sparsity"), Some("0.5"));
        assert_eq!(a.get("out"), Some("/tmp/x"));
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse("run --steps 100");
        assert_eq!(a.get_parse_or("steps", 5u32), 100);
        assert_eq!(a.get_parse_or("other", 7u32), 7);
    }

    #[test]
    fn positional_args() {
        let a = parse("run file1 file2");
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("run --good 1 --typo 2");
        assert!(a.reject_unknown(&["good"]).is_err());
        assert!(a.reject_unknown(&["good", "typo"]).is_ok());
    }
}
