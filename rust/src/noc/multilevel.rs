//! Level-2 scale-up (paper: "the NoC can be scaled up through extended
//! off-chip high-level router nodes").
//!
//! A [`MultiDomain`] stitches `D` fullerene domains together: each domain
//! keeps its 20 cores + 12 level-1 routers and gains the central level-2
//! router; level-2 routers interconnect in a ring (the off-chip topology
//! the paper sketches). Global core ids are `domain * 20 + local`.
//!
//! Analytic latency model for the scaling bench: intra-domain traffic uses
//! the level-1 fabric; inter-domain traffic climbs `core → L1 → L2`, rides
//! the L2 ring, and descends `L2 → L1 → core`.

use super::metrics::TopoStats;
use super::topology::{NodeKind, Topology};

/// A multi-domain (scaled-up) system description.
#[derive(Debug, Clone)]
pub struct MultiDomain {
    /// Number of fullerene domains.
    pub domains: usize,
    /// The single-domain graph (with L2 centre).
    pub domain_topo: Topology,
    /// Average intra-domain core-to-core router hops.
    pub intra_hops: f64,
    /// Average core→L2 router hops within a domain.
    pub to_l2_hops: f64,
}

impl MultiDomain {
    /// Build a system of `domains` fullerene domains.
    pub fn new(domains: usize) -> Self {
        assert!(domains >= 1);
        let t = Topology::fullerene_with_l2();
        let stats = TopoStats::compute(&t);
        // Average router hops from a core up to the L2 centre:
        // core → any of its 3 L1 routers → L2 = 2 router hops.
        let l2 = (0..t.len())
            .find(|&n| matches!(t.kind(n), NodeKind::RouterL2(_)))
            .unwrap();
        let mut total = 0usize;
        for &c in t.cores() {
            // BFS gives node distance; router hops = node distance / 2
            // rounded (core→L1 link, L1→L2 link = 2 links = 2 router
            // arrivals: L1 and L2).
            total += t.bfs(c)[l2];
        }
        let to_l2_links = total as f64 / t.cores().len() as f64;
        MultiDomain {
            domains,
            intra_hops: stats.avg_core_hops / 2.0, // router hops ≈ links/2
            to_l2_hops: to_l2_links,               // links on the climb
            domain_topo: t,
        }
    }

    /// Total cores in the system.
    pub fn total_cores(&self) -> usize {
        self.domains * 20
    }

    /// Total neurons at the paper's 8 K/core.
    pub fn total_neurons(&self) -> usize {
        self.total_cores() * 8192
    }

    /// Ring distance between two domains.
    pub fn l2_ring_hops(&self, a: usize, b: usize) -> usize {
        let d = a.abs_diff(b);
        d.min(self.domains - d)
    }

    /// Average router hops between two cores (global ids).
    pub fn hops_between(&self, src: usize, dst: usize) -> f64 {
        let (sd, dd) = (src / 20, dst / 20);
        if sd == dd {
            self.intra_hops
        } else {
            // climb + ring + descend (router-hop units).
            self.to_l2_hops + self.l2_ring_hops(sd, dd) as f64 + self.to_l2_hops
        }
    }

    /// Average hops over uniform random core pairs (analytic expectation).
    pub fn avg_hops_uniform(&self) -> f64 {
        let n = self.total_cores() as f64;
        if self.domains == 1 {
            return self.intra_hops;
        }
        // P(same domain) over ordered distinct pairs.
        let same = (20.0 - 1.0) / (n - 1.0);
        // Expected ring distance between two distinct uniform domains.
        let d = self.domains;
        let mut ring = 0.0;
        for k in 1..d {
            ring += self.l2_ring_hops(0, k) as f64;
        }
        ring /= (d - 1) as f64;
        same * self.intra_hops + (1.0 - same) * (2.0 * self.to_l2_hops + ring)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_domain_degenerates_to_intra() {
        let m = MultiDomain::new(1);
        assert_eq!(m.total_cores(), 20);
        assert!((m.avg_hops_uniform() - m.intra_hops).abs() < 1e-12);
    }

    #[test]
    fn scaling_grows_neurons_linearly() {
        let m = MultiDomain::new(8);
        assert_eq!(m.total_cores(), 160);
        assert_eq!(m.total_neurons(), 8 * 20 * 8192);
    }

    #[test]
    fn ring_distance_wraps() {
        let m = MultiDomain::new(6);
        assert_eq!(m.l2_ring_hops(0, 5), 1);
        assert_eq!(m.l2_ring_hops(1, 4), 3);
    }

    #[test]
    fn inter_domain_costlier_than_intra() {
        let m = MultiDomain::new(4);
        assert!(m.hops_between(0, 25) > m.hops_between(0, 5));
    }

    #[test]
    fn avg_hops_grows_sublinearly_with_domains() {
        let h2 = MultiDomain::new(2).avg_hops_uniform();
        let h8 = MultiDomain::new(8).avg_hops_uniform();
        let h32 = MultiDomain::new(32).avg_hops_uniform();
        assert!(h2 < h8 && h8 < h32);
        // Ring diameter grows linearly in domains, so the ratio of
        // avg-hops growth to core growth must stay well below linear.
        let growth = h32 / h2;
        assert!(growth < 16.0, "growth {growth}");
    }
}
