//! [`ExperimentRunner`]: run a workload on the simulated chip, optionally
//! cross-checking every sample against the functional references (the
//! in-process integer reference and/or the AOT-compiled XLA golden model).

use crate::datasets::Dataset;
use crate::energy::ChipReport;
use crate::nn::NetworkDesc;
use crate::runtime::GoldenModel;
use crate::soc::{Soc, SocConfig};
use crate::{Error, Result};
use std::path::PathBuf;

/// What to validate against while running.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GoldenCheck {
    /// No cross-checking (fastest).
    None,
    /// Check against [`NetworkDesc::reference_run`] (pure Rust).
    Reference,
    /// Check against the XLA-executed AOT artifact.
    Xla,
    /// Check against both.
    Both,
}

/// Experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Chip configuration.
    pub soc: SocConfig,
    /// Max samples to run.
    pub limit: usize,
    /// Cross-check mode.
    pub check: GoldenCheck,
    /// Artifacts directory (for the XLA golden model).
    pub artifacts: PathBuf,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            soc: SocConfig::default(),
            limit: usize::MAX,
            check: GoldenCheck::Reference,
            artifacts: GoldenModel::artifacts_dir(),
        }
    }
}

/// Outcome of an experiment run.
#[derive(Debug)]
pub struct ExperimentOutcome {
    /// Chip-level report (Table-I row).
    pub report: ChipReport,
    /// Samples where the chip disagreed with a reference (should be 0).
    pub mismatches: u64,
    /// Samples checked against a golden model.
    pub checked: u64,
}

/// The runner.
pub struct ExperimentRunner {
    net: NetworkDesc,
    config: ExperimentConfig,
    golden: Option<GoldenModel>,
}

impl ExperimentRunner {
    /// Build a runner; loads the XLA golden model when requested.
    pub fn new(net: NetworkDesc, config: ExperimentConfig) -> Result<ExperimentRunner> {
        let golden = match config.check {
            GoldenCheck::Xla | GoldenCheck::Both => {
                Some(GoldenModel::load(&config.artifacts, &net.name)?)
            }
            _ => None,
        };
        Ok(ExperimentRunner { net, config, golden })
    }

    /// Run the dataset through the chip; returns the report and the
    /// mismatch count against the requested references.
    pub fn run(&self, ds: &Dataset) -> Result<ExperimentOutcome> {
        if ds.inputs != self.net.input_size() {
            return Err(Error::Config(format!(
                "dataset inputs {} != network inputs {}",
                ds.inputs,
                self.net.input_size()
            )));
        }
        let mut soc = Soc::new(self.net.clone(), self.config.soc.clone())?;
        let mut mismatches = 0u64;
        let mut checked = 0u64;
        let n = ds.samples.len().min(self.config.limit);
        for sample in &ds.samples[..n] {
            let r = soc.run_sample(sample, true)?;
            let use_ref = matches!(
                self.config.check,
                GoldenCheck::Reference | GoldenCheck::Both
            );
            if use_ref {
                let raster = sample.to_raster(self.net.timesteps, self.net.input_size());
                let expect = self.net.reference_run(&raster);
                checked += 1;
                if expect != r.counts {
                    mismatches += 1;
                }
            }
            if let Some(g) = &self.golden {
                let expect = g.run_sample(sample)?;
                checked += 1;
                if expect != r.counts {
                    mismatches += 1;
                }
            }
        }
        Ok(ExperimentOutcome {
            report: soc.finish_report(&ds.name),
            mismatches,
            checked,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::neuron::{LeakMode, NeuronParams, ResetMode};
    use crate::core::Codebook;
    use crate::datasets::Workload;
    use crate::nn::network::LayerDesc;

    fn small_net_for(w: Workload, hidden: usize) -> NetworkDesc {
        let cb = Codebook::default_log16();
        let params = NeuronParams {
            threshold: 60,
            leak: LeakMode::Linear(1),
            reset: ResetMode::Subtract,
            mp_bits: 16,
        };
        let inputs = w.inputs();
        let classes = w.classes();
        NetworkDesc {
            name: format!("{}-test", w.name()),
            layers: vec![
                LayerDesc {
                    name: "h".into(),
                    inputs,
                    neurons: hidden,
                    codebook: cb.clone(),
                    widx: (0..inputs * hidden).map(|i| ((i * 7) % 16) as u8).collect(),
                    neuron_params: params.clone(),
                },
                LayerDesc {
                    name: "o".into(),
                    inputs: hidden,
                    neurons: classes,
                    codebook: cb,
                    widx: (0..hidden * classes).map(|i| ((i * 5) % 16) as u8).collect(),
                    neuron_params: params,
                },
            ],
            timesteps: w.timesteps(),
            classes,
        }
    }

    #[test]
    fn chip_never_disagrees_with_reference() {
        let net = small_net_for(Workload::Nmnist, 40);
        let ds = Workload::Nmnist.generate(4, 11);
        let runner = ExperimentRunner::new(
            net,
            ExperimentConfig {
                limit: 4,
                check: GoldenCheck::Reference,
                ..ExperimentConfig::default()
            },
        )
        .unwrap();
        let out = runner.run(&ds).unwrap();
        assert_eq!(out.checked, 4);
        assert_eq!(out.mismatches, 0, "cycle sim diverged from reference");
        assert!(out.report.sops > 0);
    }

    #[test]
    fn dataset_network_mismatch_rejected() {
        let net = small_net_for(Workload::Nmnist, 10);
        let ds = Workload::Cifar10.generate(2, 1);
        let runner =
            ExperimentRunner::new(net, ExperimentConfig::default()).unwrap();
        assert!(runner.run(&ds).is_err());
    }
}
