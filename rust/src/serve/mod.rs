//! Streaming session/serving API — the crate's top-level surface.
//!
//! The paper's chip is an always-on edge device consuming event streams
//! continuously; this layer makes the simulator serve the same way
//! instead of only running pre-materialized batches:
//!
//! - [`SocBuilder`] — fluent construction + **the** single validation
//!   choke point for chip/run configuration (JSON, CLI flags and fluent
//!   calls all funnel through it);
//! - [`Workload`] — pluggable sample sources ([`SyntheticStream`],
//!   [`EventReplay`], [`TrafficWorkload`], or anything downstream
//!   implements), parsed from spec strings by [`workload_from_spec`];
//! - [`Session`] — a streaming inference session with per-push results,
//!   incremental [`Session::snapshot`] reports, per-session
//!   energy/latency ledgers and a consuming [`Session::close`] (the
//!   typestate makes "forgot `finish_report`" unrepresentable);
//! - [`SocPool`] — N worker threads serving many independent sessions
//!   concurrently, one fresh chip per session, with deterministic
//!   merged reporting (bit-identical to sequential execution).
//!
//! The batch layer ([`crate::coordinator::ExperimentRunner`]) is rebuilt
//! on top of these primitives.

pub mod builder;
pub mod pool;
pub mod session;
pub mod workload;

pub use builder::SocBuilder;
pub use pool::{ServeOutcome, SessionOutcome, SessionSpec, SocPool};
pub use session::{Session, SessionReport, SessionStats};
pub use workload::{
    workload_from_spec, EventReplay, SyntheticStream, TrafficWorkload, Workload,
};
