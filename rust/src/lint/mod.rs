//! `soclint` — the in-tree determinism & invariant linter.
//!
//! The repo's core verification asset is **bit-identity**: every
//! optimization since PR 3 is proven `f64::to_bits`-equal against frozen
//! oracles, and the recovery layer only works because replays are
//! deterministic. This subsystem enforces the *preconditions* of that
//! determinism statically, the way neuromorphic toolchains encode
//! hardware constraints at compile time instead of discovering them at
//! runtime:
//!
//! - [`rules`] — layer-1 **source lints** over a hand-rolled tokenizer
//!   ([`tokens`]): hash-collection bans, host-clock quarantine, unscoped
//!   threads, float equality, silent panics on the serving surface,
//!   `unsafe` anywhere.
//! - [`model`] — layer-2 **model lints**: ledger completeness (every
//!   `EventClass` priced + charged + reported), every `Error` variant
//!   constructed, every CLI flag wired and documented.
//! - [`baseline`] — the checked-in **ratchet** (`LINT_BASELINE.json`)
//!   that CI compares against; new violations fail, fixed ones demand a
//!   baseline refresh.
//!
//! Suppression is only possible inline, at the finding site:
//! `// lint:allow(<rule>) <justification>` — the justification text is
//! mandatory; an allow without one suppresses nothing.
//!
//! Exposed as the `lint` subcommand on the `fullerene-soc` binary and run
//! as a CI job (see `.github/workflows/ci.yml`).

pub mod baseline;
pub mod model;
pub mod rules;
pub mod tokens;

use crate::error::{Error, Result};
use crate::util::cli::Args;
use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

/// One source file under lint, with its repo-relative path (forward
/// slashes, e.g. `rust/src/serve/pool.rs`).
#[derive(Debug, Clone)]
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

/// One lint finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: String,
    pub path: String,
    pub line: usize,
    pub msg: String,
}

impl Finding {
    pub fn new(rule: &str, path: &str, line: usize, msg: String) -> Self {
        Finding { rule: rule.into(), path: path.into(), line, msg }
    }

    /// `path:line: [rule] message` — the grep-able report form.
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.path, self.line, self.rule, self.msg)
    }
}

/// Per-file tokenization products, computed once at load.
struct Scanned {
    toks: Vec<tokens::Tok>,
    test_lines: BTreeSet<usize>,
    allows: Vec<tokens::Allow>,
}

/// The set of files a lint run sees, with cached token scans.
pub struct FileSet {
    pub files: Vec<SourceFile>,
    /// README.md text, for the `cli-flag-coverage` documentation half.
    pub readme: Option<String>,
    scans: BTreeMap<String, Scanned>,
    empty_lines: BTreeSet<usize>,
}

impl FileSet {
    /// Build from in-memory files (fixture tests use this).
    pub fn from_memory(files: Vec<SourceFile>, readme: Option<String>) -> Self {
        let mut scans = BTreeMap::new();
        for f in &files {
            let scan = tokens::scan(&f.text);
            let test_lines = tokens::cfg_test_lines(&scan.toks);
            scans.insert(
                f.path.clone(),
                Scanned { toks: scan.toks, test_lines, allows: scan.allows },
            );
        }
        FileSet { files, readme, scans, empty_lines: BTreeSet::new() }
    }

    /// Load the real tree under `root` (the repo root): `rust/src`,
    /// `rust/benches`, `rust/tests`, `rust/examples`, `examples`, plus
    /// `README.md`. Files are sorted by path — the lint walk order is
    /// deterministic like everything else here.
    pub fn load(root: &Path) -> Result<Self> {
        let mut files = Vec::new();
        for dir in ["rust/src", "rust/benches", "rust/tests", "rust/examples", "examples"] {
            let abs = root.join(dir);
            if abs.is_dir() {
                collect_rs(&abs, root, &mut files)?;
            }
        }
        files.sort_by(|a, b| a.path.cmp(&b.path));
        let readme = std::fs::read_to_string(root.join("README.md")).ok();
        Ok(Self::from_memory(files, readme))
    }

    /// Tokens of a file, if it is in the set.
    pub fn tokens(&self, path: &str) -> Option<&[tokens::Tok]> {
        self.scans.get(path).map(|s| s.toks.as_slice())
    }

    /// `#[cfg(test)]` lines of a file (empty set if absent).
    pub fn test_lines(&self, path: &str) -> &BTreeSet<usize> {
        self.scans.get(path).map(|s| &s.test_lines).unwrap_or(&self.empty_lines)
    }
}

/// Recursively collect `.rs` files under `dir` into repo-relative paths.
fn collect_rs(dir: &Path, root: &Path, out: &mut Vec<SourceFile>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, root, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            let text = std::fs::read_to_string(&path)?;
            out.push(SourceFile { path: rel, text });
        }
    }
    Ok(())
}

/// Every rule the linter knows, in report order (drives the explicit
/// zeros in the baseline file).
pub fn all_rules() -> Vec<&'static str> {
    let mut v: Vec<&'static str> = rules::SOURCE_RULES.to_vec();
    v.extend_from_slice(model::MODEL_RULES);
    v
}

/// Run both lint layers over a file set and apply `lint:allow`
/// suppression. Returns the surviving findings, sorted.
pub fn run(fs: &FileSet) -> Vec<Finding> {
    let mut findings = Vec::new();
    for f in &fs.files {
        if let Some(toks) = fs.tokens(&f.path) {
            findings.extend(rules::run_source_rules(f, toks, fs.test_lines(&f.path)));
        }
    }
    findings.extend(model::run_model_lints(fs));

    // A justified allow on the finding line (or the line above, for
    // comment-above style) suppresses exactly its named rule.
    findings.retain(|f| {
        let allowed = fs.scans.get(&f.path).is_some_and(|s| {
            s.allows.iter().any(|a| {
                a.justified
                    && a.rule == f.rule
                    && (a.line == f.line || a.line + 1 == f.line)
            })
        });
        !allowed
    });
    findings.sort_by(|a, b| {
        (&a.path, a.line, &a.rule, &a.msg).cmp(&(&b.path, b.line, &b.rule, &b.msg))
    });
    findings
}

/// Per-rule counts over a finding list, with explicit zeros for every
/// known rule.
pub fn counts(findings: &[Finding]) -> BTreeMap<String, u64> {
    let mut counts: BTreeMap<String, u64> =
        all_rules().iter().map(|r| (r.to_string(), 0)).collect();
    for f in findings {
        *counts.entry(f.rule.clone()).or_insert(0) += 1;
    }
    counts
}

/// Locate the repo root: `--root` wins; otherwise probe `.` then `..`
/// for `rust/src/lib.rs` (covers running from the repo root and from
/// `rust/`, which is how CI invokes cargo).
fn find_root(args: &Args) -> Result<PathBuf> {
    if let Some(r) = args.get("root") {
        return Ok(PathBuf::from(r));
    }
    for cand in [".", ".."] {
        let p = PathBuf::from(cand);
        if p.join("rust/src/lib.rs").is_file() {
            return Ok(p);
        }
    }
    Err(Error::config(
        "cannot find the repo root (no rust/src/lib.rs in . or ..); pass --root <path>",
    ))
}

/// The `lint` subcommand. Modes:
///
/// - (default) print findings and per-rule counts; informational.
/// - `--check` compare against the ratchet baseline; any drift fails.
/// - `--write-baseline` refresh `LINT_BASELINE.json` from the current
///   counts.
pub fn lint_main(args: &Args) -> Result<()> {
    args.reject_unknown(&["check", "write-baseline", "root", "baseline"])
        .map_err(Error::Config)?;
    let root = find_root(args)?;
    let baseline_path = match args.get("baseline") {
        Some(p) => PathBuf::from(p),
        None => root.join("LINT_BASELINE.json"),
    };
    let fs = FileSet::load(&root)?;
    let findings = run(&fs);
    let counts = counts(&findings);

    for f in &findings {
        println!("{}", f.render());
    }
    println!("soclint: {} file(s), {} finding(s)", fs.files.len(), findings.len());
    for (rule, n) in &counts {
        println!("  {rule:<28} {n}");
    }

    if args.flag("write-baseline") {
        baseline::Baseline::from_counts(counts).write(&baseline_path)?;
        println!("wrote {}", baseline_path.display());
        return Ok(());
    }
    if args.flag("check") {
        let base = baseline::Baseline::read(&baseline_path)?;
        let fails = base.check(&counts);
        if !fails.is_empty() {
            return Err(Error::Config(format!(
                "lint ratchet failed:\n  {}",
                fails.join("\n  ")
            )));
        }
        println!("lint ratchet OK against {}", baseline_path.display());
    }
    Ok(())
}
