//! The TCP front end: listener, per-connection threads, keep-alive
//! request loop, and the clean-drain shutdown path.
//!
//! Shutdown sequencing (admin endpoint or [`HttpServer::request_shutdown`]):
//! the drain flag flips, a wake connection unblocks `accept`, the
//! accept loop stops admitting, every connection thread is joined
//! (bounded by the socket read timeout — a silent keep-alive peer
//! cannot hold the drain hostage), and finally the serving runtime
//! itself drains via [`ServeRuntime::shutdown`](crate::serve::ServeRuntime::shutdown)
//! so every admitted session still resolves. [`HttpStats`] reports the
//! witness: connections opened == closed and `drained == true` is the
//! "zero hung connections, clean drain" floor the HTTP bench enforces.

use super::framing::{read_request, HttpError};
use super::gateway::Gateway;
use crate::serve::HealthReport;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Server knobs (validated by the CLI layer; the library applies them
/// as-is).
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Bind address, e.g. `127.0.0.1:0` (port 0 = OS-assigned).
    pub addr: String,
    /// Socket read/write timeout per operation (ms). Bounds how long a
    /// slow or silent client can pin a connection thread, and therefore
    /// the drain latency.
    pub io_timeout_ms: u64,
    /// `Content-Length` cap for request bodies.
    pub max_body_bytes: usize,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            addr: "127.0.0.1:0".into(),
            io_timeout_ms: 5_000,
            max_body_bytes: super::framing::DEFAULT_MAX_BODY_BYTES,
        }
    }
}

/// Final accounting of one server lifetime, returned by
/// [`HttpServer::join`].
#[derive(Debug, Clone)]
pub struct HttpStats {
    /// TCP connections accepted.
    pub connections_opened: u64,
    /// Connection threads that ran to completion. Equal to
    /// `connections_opened` on a clean drain — the no-hung-connections
    /// witness.
    pub connections_closed: u64,
    /// Requests answered (all status codes).
    pub requests: u64,
    /// Responses by status code.
    pub responses_by_code: BTreeMap<u16, u64>,
    /// Final serving-runtime health ledger after the drain.
    pub health: HealthReport,
    /// The runtime drain completed without error.
    pub drained: bool,
}

/// A running HTTP front end. Construct with [`HttpServer::start`]; the
/// accept loop runs on its own thread until a shutdown is requested,
/// then [`HttpServer::join`] returns the final [`HttpStats`].
pub struct HttpServer {
    addr: SocketAddr,
    gateway: Arc<Gateway>,
    shutdown: Arc<AtomicBool>,
    accept: JoinHandle<Result<HttpStats>>,
}

impl HttpServer {
    /// Bind `cfg.addr` and start serving `gateway` on a background
    /// accept loop.
    pub fn start(cfg: HttpConfig, gateway: Gateway) -> Result<HttpServer> {
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| Error::Config(format!("cannot bind '{}': {e}", cfg.addr)))?;
        let addr = listener.local_addr()?;
        let gateway = Arc::new(gateway);
        let shutdown = Arc::new(AtomicBool::new(false));
        let (gw, flag, cfg2) = (gateway.clone(), shutdown.clone(), cfg.clone());
        // lint:allow(no-unscoped-threads) accept loop joined by HttpServer::join; it joins every connection thread before returning
        let accept = std::thread::spawn(move || accept_loop(listener, addr, cfg2, gw, flag));
        Ok(HttpServer {
            addr,
            gateway,
            shutdown,
            accept,
        })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shared gateway (metrics snapshots, counters).
    pub fn gateway(&self) -> &Arc<Gateway> {
        &self.gateway
    }

    /// Programmatic shutdown: what `POST /admin/shutdown` does, without
    /// the HTTP round trip.
    pub fn request_shutdown(&self) {
        self.gateway.request_drain();
        self.shutdown.store(true, Ordering::SeqCst);
        wake_accept(self.addr);
    }

    /// Block until the accept loop drains and return the final stats.
    pub fn join(self) -> Result<HttpStats> {
        match self.accept.join() {
            Ok(r) => r,
            Err(_) => Err(Error::Runtime("http accept loop panicked".into())),
        }
    }
}

/// Unblock a blocking `accept` by dialing the listener once. Best
/// effort: if the dial fails the listener is already gone.
fn wake_accept(addr: SocketAddr) {
    let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(500));
}

fn accept_loop(
    listener: TcpListener,
    addr: SocketAddr,
    cfg: HttpConfig,
    gateway: Arc<Gateway>,
    shutdown: Arc<AtomicBool>,
) -> Result<HttpStats> {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::SeqCst) {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            // Transient accept errors (EMFILE, aborted handshake) must
            // not kill the front end; a post-shutdown error is the wake.
            Err(_) => continue,
        };
        if shutdown.load(Ordering::SeqCst) {
            break; // the wake connection itself, or a late arrival
        }
        gateway.connection_opened();
        let (gw, flag, cfg2) = (gateway.clone(), shutdown.clone(), cfg.clone());
        // lint:allow(no-unscoped-threads) connection threads collected in `conns` and joined below before the drain completes
        conns.push(std::thread::spawn(move || {
            handle_connection(stream, addr, &cfg2, &gw, &flag);
            gw.connection_closed();
        }));
        // Reap finished threads opportunistically so a long-lived server
        // does not accumulate one JoinHandle per historical connection.
        conns.retain(|h| !h.is_finished());
    }
    for h in conns {
        let _ = h.join();
    }
    let (drained, health) = match gateway.shutdown_runtime() {
        Ok(h) => (true, h),
        Err(_) => (false, HealthReport::default()),
    };
    let (connections_opened, connections_closed) = gateway.connection_counts();
    let responses_by_code = gateway.responses_by_code();
    let requests = responses_by_code.values().sum();
    Ok(HttpStats {
        connections_opened,
        connections_closed,
        requests,
        responses_by_code,
        health,
        drained,
    })
}

/// One connection's keep-alive loop: parse → route → respond, until the
/// peer closes, errors, asks for `Connection: close`, times out, or the
/// server drains.
fn handle_connection(
    stream: TcpStream,
    addr: SocketAddr,
    cfg: &HttpConfig,
    gateway: &Arc<Gateway>,
    shutdown: &Arc<AtomicBool>,
) {
    let timeout = Some(Duration::from_millis(cfg.io_timeout_ms.max(1)));
    if stream.set_read_timeout(timeout).is_err() || stream.set_write_timeout(timeout).is_err()
    {
        return;
    }
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match read_request(&mut reader, cfg.max_body_bytes) {
            Ok(None) => return, // peer closed cleanly between requests
            Ok(Some(req)) => {
                let (resp, want_shutdown) = gateway.handle(&req);
                let keep = req.keep_alive && !resp.close;
                let wrote = resp.write_to(&mut writer, keep).is_ok();
                gateway.record_response(resp.status);
                if want_shutdown {
                    gateway.request_drain();
                    shutdown.store(true, Ordering::SeqCst);
                    wake_accept(addr);
                    return;
                }
                if !wrote || !keep {
                    return;
                }
            }
            Err(HttpError::Closed) => return,
            // Slow/silent client (read timeout) or socket failure: no
            // peer worth answering — drop the connection. The timeout is
            // what bounds drain latency against half-open peers.
            Err(HttpError::Io(_)) => return,
            Err(e) => {
                // Framing violation: answer with its 4xx, then close —
                // the byte stream is no longer trustworthy for framing.
                let resp = e.to_response();
                let _ = resp.write_to(&mut writer, false);
                gateway.record_response(resp.status);
                return;
            }
        }
    }
}
