//! Synthetic event-stream datasets (the paper evaluates on NMNIST, DVS
//! Gesture and Cifar-10; those files are not available offline, so we
//! substitute generators that reproduce their **tensor geometry and spike
//! statistics** — see DESIGN.md §Substitutions):
//!
//! - [`nmnist`] — 34×34×2 saccade-style digit events, 10 classes;
//! - [`dvsgesture`] — 32×32×2 motion events (rotating/translating
//!   clusters), 11 classes;
//! - [`cifar`] — rate-coded 32×32×3 static images, 10 classes.
//!
//! The *same generator definitions* exist in `python/compile/data.py`
//! (seeded numpy) where training happens; the Python side also exports a
//! held-out test split into `artifacts/dataset_<name>.json` which
//! [`events::Dataset::load_json`] reads so that Rust evaluates the exact
//! samples the trained network was validated on.

pub mod cifar;
pub mod dvsgesture;
pub mod encode;
pub mod events;
pub mod nmnist;

pub use events::{Dataset, Sample};

/// Workload descriptor used across benches/examples.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    /// NMNIST-like saccade events.
    Nmnist,
    /// DVS-Gesture-like motion events.
    DvsGesture,
    /// Rate-coded CIFAR-like frames.
    Cifar10,
}

impl Workload {
    /// Canonical dataset name (artifact file stem).
    pub fn name(&self) -> &'static str {
        match self {
            Workload::Nmnist => "nmnist",
            Workload::DvsGesture => "dvsgesture",
            Workload::Cifar10 => "cifar10",
        }
    }

    /// Input width of the encoded stream.
    pub fn inputs(&self) -> usize {
        match self {
            Workload::Nmnist => 34 * 34 * 2,
            Workload::DvsGesture => 32 * 32 * 2,
            Workload::Cifar10 => 32 * 32 * 3,
        }
    }

    /// Class count.
    pub fn classes(&self) -> usize {
        match self {
            Workload::Nmnist => 10,
            Workload::DvsGesture => 11,
            Workload::Cifar10 => 10,
        }
    }

    /// Default simulation timesteps per sample.
    pub fn timesteps(&self) -> usize {
        match self {
            Workload::Nmnist => 20,
            Workload::DvsGesture => 25,
            Workload::Cifar10 => 16,
        }
    }

    /// Generate `n` synthetic samples with the Rust generator.
    pub fn generate(&self, n: usize, seed: u64) -> Dataset {
        match self {
            Workload::Nmnist => nmnist::generate(n, seed),
            Workload::DvsGesture => dvsgesture::generate(n, seed),
            Workload::Cifar10 => cifar::generate(n, seed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_matches_paper_datasets() {
        assert_eq!(Workload::Nmnist.inputs(), 2312);
        assert_eq!(Workload::DvsGesture.inputs(), 2048);
        assert_eq!(Workload::Cifar10.inputs(), 3072);
        assert_eq!(Workload::DvsGesture.classes(), 11);
    }
}
