//! `fullerene-soc` CLI launcher.
//!
//! Subcommands:
//!
//! - `run`       — run a workload on the simulated chip and print the
//!                 Table-I-style report (`--workload`, `--samples`,
//!                 `--config <json>`, `--check none|reference|xla|both`).
//! - `serve`     — stream N concurrent sessions through the persistent
//!                 `ServeRuntime` (`--sessions`, `--workload <spec>`,
//!                 `--workers`, `--queue-depth`, `--no-warm`), printing
//!                 outcomes as sessions finish plus per-session latency
//!                 stats and the merged report.
//! - `serve-http` — the same runtime behind a dependency-free HTTP/1.1
//!                 front end (`--port`, `--host`, `--admin-token`):
//!                 `POST /v1/sessions` submits JSON workload specs (429 +
//!                 `Retry-After` on a full queue), `GET /v1/sessions/<id>`
//!                 polls outcomes, `GET /metrics` exposes the serving
//!                 ledger, `POST /admin/shutdown` drains cleanly.
//! - `topo`      — print the Fig. 5a/5b topology comparison table.
//! - `bench`     — quick in-CLI reproductions: `core-sparsity` (Fig. 3),
//!                 `router` (Fig. 5c), `riscv-power` (Fig. 6).
//! - `inspect`   — show how a weights artifact maps onto the chip.
//! - `gen-data`  — emit a synthetic dataset JSON (debugging aid).
//! - `lint`      — `soclint`, the in-tree determinism & invariant linter
//!                 (`--check` ratchets against `LINT_BASELINE.json`;
//!                 `--write-baseline` refreshes it).
//!
//! All chip configuration funnels through `serve::SocBuilder`, so CLI
//! flags, JSON configs and fluent construction share one validator.

use fullerene_soc::config::{parse_check, parse_workload, RunConfig};
use fullerene_soc::datasets::Workload;
use fullerene_soc::energy::ChipReport;
use fullerene_soc::metrics::Table;
use fullerene_soc::nn::load_weights_json;
use fullerene_soc::noc::{TopoStats, Topology};
use fullerene_soc::serve::{workload_from_spec, SessionSpec, SocBuilder, Workload as _};
use fullerene_soc::util::cli::Args;
use fullerene_soc::{Error, Result};
use std::path::{Path, PathBuf};

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("run") => cmd_run(args),
        Some("serve") => cmd_serve(args),
        Some("serve-http") => cmd_serve_http(args),
        Some("topo") => cmd_topo(),
        Some("bench") => cmd_bench(args),
        Some("inspect") => cmd_inspect(args),
        Some("gen-data") => cmd_gen_data(args),
        Some("lint") => fullerene_soc::lint::lint_main(args),
        Some(other) => Err(Error::Config(format!(
            "unknown subcommand '{other}'; run without args for help"
        ))),
        None => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "fullerene-soc — neuromorphic SoC simulator (CS.AR 2024 reproduction)\n\
         \n\
         USAGE: fullerene-soc <run|serve|serve-http|topo|bench|inspect|gen-data|lint> [flags]\n\
         \n\
         run       --workload nmnist|dvsgesture|cifar10  --samples N  --seed S\n\
                   --weights artifacts/<net>.weights.json  --check none|reference|xla|both\n\
                   --config cfg.json  --no-noc  --no-cpu  --f-core-mhz F  --supply V\n\
                   --domains D (multi-domain chip: D fullerene domains + L2 ring)\n\
                   --chips C (C > 1: partition the network across a C-chip cluster\n\
                   joined by the off-chip L3 router ring)\n\
                   --fault-plan <spec>  (';'-separated degradation events:\n\
                   kill-router:<node>@<when> | kill-link:<a>-<b>@<when> |\n\
                   throttle-l1:<factor>@<when> | throttle-l2:<factor>@<when> |\n\
                   congest:<node>+<cycles>@<when> | kill-frac:<frac>#<seed>@<when> |\n\
                   kill-l3:<chip>@<when> | throttle-l3:<factor>@<when> (need --chips > 1),\n\
                   <when> = cycle number or t<timestep>, e.g.\n\
                   \"kill-router:3@200;kill-frac:0.2#7@t4\"; also accepted by serve)\n\
         serve     --sessions N  --workers K  --samples S  --seed S  --check none|reference\n\
                   --queue-depth Q (bounded submission queue; default = N)\n\
                   --chips C (each worker serves a whole C-chip cluster)\n\
                   --no-warm (fresh engine per session instead of warm reuse)\n\
                   --deadline-cycles N (kill a session past N simulated cycles)\n\
                   --deadline-wall-ms M (host wall-clock watchdog per session)\n\
                   --retries R --backoff-cycles B --retry-seed S (deterministic\n\
                   retry of failed/degraded/deadline-killed sessions with\n\
                   exponential simulated-cycle backoff; all default 0 = off)\n\
                   --quarantine-after T (discard a warm engine once dead routers\n\
                   + dead links + dropped flits reach T)\n\
                   --failover (with --chips > 1: re-partition onto surviving\n\
                   chips when a fault makes a shard unreachable)\n\
                   --workload <spec>  (spec: nmnist | dvsgesture | cifar10 |\n\
                   replay:<dataset.json> | traffic:<inputs>x<classes>x<timesteps>@<rate> |\n\
                   synthetic:<inputs>x<classes>x<timesteps>@<rate>;\n\
                   replay shares one parsed file across sessions, --samples caps its\n\
                   length and --seed is ignored for recorded streams)\n\
         serve-http --port P (default 7171; 0 = OS-assigned, printed at startup)\n\
                   --host H (default 127.0.0.1)  --workers K  --queue-depth Q\n\
                   --workload <spec> (default geometry for submissions; same\n\
                   grammar as serve)  --hidden N  --max-samples M (per-session\n\
                   cap on untrusted submissions)  --admin-token T (require\n\
                   'Authorization: Bearer T' on POST /admin/shutdown)\n\
                   --io-timeout-ms MS (socket read/write timeout; bounds how\n\
                   long a slow client pins a connection)  --max-body-bytes B\n\
                   --check none|reference; plus the shared chip flags and the\n\
                   serve recovery knobs. Endpoints: POST /v1/sessions,\n\
                   GET /v1/sessions/<id>, GET /metrics, GET /healthz,\n\
                   POST /admin/shutdown (drains, then the process exits 0)\n\
         topo      (prints the Fig. 5 topology comparison)\n\
         bench     core-sparsity | router | riscv-power  (quick figure repros)\n\
         inspect   --weights <file>   (mapping summary)\n\
         gen-data  --workload W --samples N --seed S --out file.json\n\
         lint      (soclint: determinism & invariant linter over the tree)\n\
                   --check (ratchet against LINT_BASELINE.json; CI gate)\n\
                   --write-baseline (refresh the ratchet after paying down debt)\n\
                   --root <repo-root>  --baseline <file>"
    );
}

/// Fallback network at explicit geometry (the shared structural recipe:
/// fixed pseudo-random codebook indexes — structure exercises every code
/// path; accuracy is chance, trained artifacts are what Table I uses).
fn fallback_net_dims(
    name: &str,
    inputs: usize,
    hidden: usize,
    classes: usize,
    timesteps: usize,
) -> fullerene_soc::nn::NetworkDesc {
    fullerene_soc::benches_support::structural_net(
        &format!("{name}-fallback"),
        inputs,
        hidden,
        classes,
        timesteps,
    )
}

/// Fallback network for a synthetic-dataset workload descriptor.
fn fallback_net(w: Workload, hidden: usize) -> fullerene_soc::nn::NetworkDesc {
    fallback_net_dims(w.name(), w.inputs(), hidden, w.classes(), w.timesteps())
}

/// Apply `run`/`serve`-shared chip flags onto a [`RunConfig`].
fn apply_chip_flags(cfg: &mut RunConfig, args: &Args) -> Result<()> {
    if args.flag("no-noc") {
        cfg.soc.use_noc = false;
    }
    if args.flag("no-cpu") {
        cfg.soc.drive_cpu = false;
    }
    if let Some(f) = args.get("f-core-mhz") {
        cfg.soc.f_core_hz = f
            .parse::<f64>()
            .map_err(|_| Error::config("bad --f-core-mhz"))?
            * 1e6;
    }
    if let Some(v) = args.get("supply") {
        cfg.soc.supply_v = v.parse().map_err(|_| Error::config("bad --supply"))?;
    }
    if let Some(m) = args.get("max-neurons-per-core") {
        cfg.soc.max_neurons_per_core =
            m.parse().map_err(|_| Error::config("bad flag"))?;
    }
    if let Some(d) = args.get("domains") {
        cfg.soc.domains = d.parse().map_err(|_| Error::config("bad --domains"))?;
    }
    if let Some(c) = args.get("chips") {
        cfg.soc.chips = c.parse().map_err(|_| Error::config("bad --chips"))?;
    }
    if let Some(spec) = args.get("fault-plan") {
        cfg.soc.fault_plan = fullerene_soc::noc::FaultPlan::parse(spec)?;
    }
    if args.flag("failover") {
        cfg.soc.failover = true;
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    args.reject_unknown(&[
        "workload",
        "samples",
        "seed",
        "weights",
        "check",
        "config",
        "no-noc",
        "no-cpu",
        "f-core-mhz",
        "supply",
        "hidden",
        "max-neurons-per-core",
        "domains",
        "chips",
        "fault-plan",
        "failover",
    ])
    .map_err(Error::Config)?;
    let mut cfg = match args.get("config") {
        Some(p) => RunConfig::load(Path::new(p))?,
        None => RunConfig::default(),
    };
    if let Some(w) = args.get("workload") {
        cfg.workload.workload = parse_workload(w)?;
    }
    cfg.workload.samples = args.get_parse_or("samples", cfg.workload.samples);
    cfg.workload.seed = args.get_parse_or("seed", cfg.workload.seed);
    if let Some(c) = args.get("check") {
        cfg.check = parse_check(c)?;
    }
    apply_chip_flags(&mut cfg, args)?;
    // Full-config validation (chip ranges via the builder choke point +
    // workload sanity) before any artifact loading.
    cfg.validate()?;

    let w = cfg.workload.workload;
    // Prefer the trained artifact; fall back to the structural network.
    let net = match args.get("weights") {
        Some(p) => load_weights_json(Path::new(p))?,
        None => {
            let auto = cfg.artifacts.join(format!("{}.weights.json", w.name()));
            if auto.exists() {
                println!("using trained weights: {}", auto.display());
                load_weights_json(&auto)?
            } else {
                eprintln!(
                    "note: no trained artifact at {}; using untrained fallback network \
                     (run `make artifacts` for trained weights)",
                    auto.display()
                );
                fallback_net(w, args.get_parse_or("hidden", 128))
            }
        }
    };

    // Prefer the exported test set (exact training distribution); fall
    // back to the Rust generator.
    let ds_path = cfg.artifacts.join(format!("dataset_{}.json", w.name()));
    let ds = if ds_path.exists() {
        println!("using exported dataset: {}", ds_path.display());
        fullerene_soc::datasets::Dataset::load_json(&ds_path)?
    } else {
        w.generate(cfg.workload.samples, cfg.workload.seed)
    };

    // The builder is the validation choke point: CLI-flag-assembled
    // configs get the same range checks as JSON-loaded ones.
    let runner = SocBuilder::from_run_config(&cfg).build_runner(net)?;
    let out = runner.run(&ds)?;
    if out.checked > 0 {
        println!(
            "golden check: {} samples checked, {} mismatches",
            out.checked, out.mismatches
        );
    }
    println!(
        "{}",
        ChipReport::table(std::slice::from_ref(&out.report)).render()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.reject_unknown(&[
        "sessions",
        "workers",
        "workload",
        "samples",
        "seed",
        "check",
        "hidden",
        "queue-depth",
        "no-warm",
        "no-noc",
        "no-cpu",
        "f-core-mhz",
        "supply",
        "max-neurons-per-core",
        "domains",
        "chips",
        "fault-plan",
        "failover",
        "deadline-cycles",
        "deadline-wall-ms",
        "retries",
        "backoff-cycles",
        "retry-seed",
        "quarantine-after",
    ])
    .map_err(Error::Config)?;
    let sessions: usize = args.get_parse_or("sessions", 4);
    let workers: usize = args.get_parse_or("workers", 4);
    let samples: usize = args.get_parse_or("samples", 8);
    let seed: u64 = args.get_parse_or("seed", 7);
    let spec = args.get_or("workload", "nmnist");
    // Default queue depth: the whole mix fits (clamped to the builder's
    // ceiling so a huge --sessions never fails validation on a flag the
    // user didn't pass), so the CLI submit loop never blocks; an
    // explicit smaller --queue-depth exercises backpressure. Explicit
    // values are range-checked by SocBuilder::validate, like every
    // other chip/serving knob.
    let queue_depth: usize = args.get_parse_or(
        "queue-depth",
        sessions.clamp(1, fullerene_soc::serve::builder::MAX_QUEUE_DEPTH),
    );
    let keep_warm = !args.flag("no-warm");
    let check = match args.get("check") {
        Some(c) => parse_check(c)?,
        None => fullerene_soc::coordinator::GoldenCheck::None,
    };
    // Self-healing knobs (all default to 0 = off; range-checked by the
    // builder choke point like every other serving knob).
    let recovery = fullerene_soc::serve::RecoveryPolicy {
        deadline_cycles: args.get_parse_or("deadline-cycles", 0),
        deadline_wall_ms: args.get_parse_or("deadline-wall-ms", 0),
        retries: args.get_parse_or("retries", 0),
        backoff_cycles: args.get_parse_or("backoff-cycles", 0),
        retry_seed: args.get_parse_or("retry-seed", 0),
        quarantine_after: args.get_parse_or("quarantine-after", 0),
    };
    if sessions == 0 {
        return Err(Error::config("--sessions must be >= 1"));
    }
    if samples == 0 {
        // Mirror the batch path's "samples must be > 0": zero-sample
        // sessions would merge into an all-NaN report.
        return Err(Error::config("--samples must be >= 1"));
    }

    let mut cfg = RunConfig::default();
    apply_chip_flags(&mut cfg, args)?;
    let hidden: usize = args.get_parse_or("hidden", 64);

    // Build the structural network and the session specs. Replay specs
    // are special-cased: the dataset file is parsed ONCE and shared
    // across sessions via Arc shards (`--samples` caps each session's
    // replay length; `--seed` has no effect on a recorded stream).
    let (net, specs) = if let Some(path) = spec.strip_prefix("replay:") {
        let ds = fullerene_soc::datasets::Dataset::load_json(Path::new(path))?;
        let (name, inputs, timesteps, classes) =
            (ds.name.clone(), ds.inputs, ds.timesteps, ds.classes);
        let take = ds.samples.len().min(samples);
        let shared = std::sync::Arc::new(ds.samples);
        let net = fallback_net_dims(&name, inputs, hidden, classes, timesteps);
        let specs: Vec<SessionSpec> = (0..sessions)
            .map(|i| {
                SessionSpec::new(
                    &format!("sess{i}"),
                    Box::new(fullerene_soc::serve::EventReplay::shard(
                        &name,
                        inputs,
                        timesteps,
                        classes,
                        shared.clone(),
                        0,
                        take,
                    )),
                )
            })
            .collect();
        (net, specs)
    } else {
        // Probe the spec for its geometry only (0 samples: the
        // synthetic/traffic generators produce nothing for the probe).
        let probe = workload_from_spec(&spec, 0, seed)?;
        let net = fallback_net_dims(
            probe.name(),
            probe.inputs(),
            hidden,
            probe.classes(),
            probe.timesteps(),
        );
        let specs = (0..sessions)
            .map(|i| -> Result<SessionSpec> {
                Ok(SessionSpec::new(
                    &format!("sess{i}"),
                    workload_from_spec(&spec, samples, seed + i as u64)?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        (net, specs)
    };

    // The streaming runtime: persistent workers, bounded submission
    // queue, warm chip reuse. All serving knobs (including --queue-depth
    // and --no-warm) funnel through SocBuilder::validate.
    let mut rt = SocBuilder::from_soc_config(cfg.soc.clone())
        .check(check)
        .workers(workers)
        .queue_depth(queue_depth)
        .keep_warm(keep_warm)
        .recovery(recovery)
        .build_serve_runtime(&net)?;
    for spec in specs {
        rt.submit(spec)?;
    }
    // Stream results as sessions finish (completion order) …
    for r in rt.outcomes() {
        match &r.outcome {
            Ok(o) => println!(
                "done {:12} #{:<3} {} samples, queue wait {:.3} ms",
                r.name,
                r.index,
                o.stats.samples,
                o.queue_wait_s * 1e3
            ),
            Err(e) => println!("FAILED {:10} #{:<3} {e}", r.name, r.index),
        }
    }
    // Every submitted session has resolved once the outcome stream ends,
    // so the health counters are final here (and printed before finish,
    // which errors when no session succeeded — the recovery tallies are
    // most interesting exactly then).
    if recovery.enabled() {
        let h = rt.health_report();
        println!(
            "recovery: {}/{} sessions completed, {} retries ({} cycles burned), \
             {} deadline-exceeded, {} fabric-degraded, {} failed, \
             {} quarantines, {} rebuilds, {} replans",
            h.completed,
            h.sessions,
            h.retries,
            h.retry_cycles_burned,
            h.deadline_exceeded,
            h.fabric_degraded,
            h.failed,
            h.quarantines,
            h.rebuilds,
            h.replans
        );
    }
    // … then fold the submission-order aggregate. Failed sessions are
    // isolated: listed below, excluded from the merge.
    let out = rt.finish()?;

    let mut t = Table::new(&["session", "samples", "cycles", "p50 ms", "p99 ms", "SOPs"]);
    for s in &out.sessions {
        t.push_row(vec![
            s.name.clone(),
            s.stats.samples.to_string(),
            s.stats.cycles.to_string(),
            format!("{:.3}", s.stats.p50_latency_ms),
            format!("{:.3}", s.stats.p99_latency_ms),
            s.stats.sops.to_string(),
        ]);
    }
    println!("{}", t.render());
    for s in out.sessions.iter().filter(|s| s.degradation.armed) {
        let d = &s.degradation;
        println!(
            "degraded {}: {:.1}% delivered ({} dropped, {} rerouted hops, \
             {} dead routers, {} dead links)",
            s.name,
            d.delivered_frac() * 100.0,
            d.dropped,
            d.rerouted_hops,
            d.dead_routers,
            d.dead_links
        );
    }
    for f in &out.failures {
        eprintln!("session '{}' (#{}) failed: {}", f.name, f.index, f.error);
    }
    if out.checked > 0 {
        println!(
            "golden check: {} samples checked, {} mismatches",
            out.checked, out.mismatches
        );
    }
    println!(
        "merged report ({} sessions, {} workers, {}):\n{}",
        out.sessions.len(),
        workers,
        if keep_warm { "warm chips" } else { "cold chips" },
        ChipReport::table(std::slice::from_ref(&out.merged)).render()
    );
    Ok(())
}

/// The network-facing serving front end: the same `ServeRuntime` as
/// `serve`, behind the dependency-free HTTP/1.1 layer (`http` module).
/// Runs until an authorized `POST /admin/shutdown` drains it, then
/// prints the final accounting and exits. Every construction knob still
/// funnels through `SocBuilder::validate`.
fn cmd_serve_http(args: &Args) -> Result<()> {
    args.reject_unknown(&[
        "port",
        "host",
        "workers",
        "queue-depth",
        "workload",
        "hidden",
        "max-samples",
        "admin-token",
        "io-timeout-ms",
        "max-body-bytes",
        "check",
        "no-warm",
        "no-noc",
        "no-cpu",
        "f-core-mhz",
        "supply",
        "max-neurons-per-core",
        "domains",
        "chips",
        "fault-plan",
        "failover",
        "deadline-cycles",
        "deadline-wall-ms",
        "retries",
        "backoff-cycles",
        "retry-seed",
        "quarantine-after",
    ])
    .map_err(Error::Config)?;
    let host = args.get_or("host", "127.0.0.1");
    let port: u16 = args.get_parse_or("port", 7171);
    let workers: usize = args.get_parse_or("workers", 2);
    let queue_depth: usize = args.get_parse_or("queue-depth", 64);
    let hidden: usize = args.get_parse_or("hidden", 64);
    let spec = args.get_or("workload", "traffic:64x4x4@0.1");
    let max_samples: usize = args.get_parse_or("max-samples", 512);
    let admin_token = args.get("admin-token").map(str::to_string);
    let io_timeout_ms: u64 = args.get_parse_or("io-timeout-ms", 5_000);
    let max_body_bytes: usize = args.get_parse_or(
        "max-body-bytes",
        fullerene_soc::http::framing::DEFAULT_MAX_BODY_BYTES,
    );
    let keep_warm = !args.flag("no-warm");
    let check = match args.get("check") {
        Some(c) => parse_check(c)?,
        None => fullerene_soc::coordinator::GoldenCheck::None,
    };
    if max_samples == 0 {
        return Err(Error::config("--max-samples must be >= 1"));
    }
    let recovery = fullerene_soc::serve::RecoveryPolicy {
        deadline_cycles: args.get_parse_or("deadline-cycles", 0),
        deadline_wall_ms: args.get_parse_or("deadline-wall-ms", 0),
        retries: args.get_parse_or("retries", 0),
        backoff_cycles: args.get_parse_or("backoff-cycles", 0),
        retry_seed: args.get_parse_or("retry-seed", 0),
        quarantine_after: args.get_parse_or("quarantine-after", 0),
    };
    let mut cfg = RunConfig::default();
    apply_chip_flags(&mut cfg, args)?;

    // The runtime serves ONE network geometry; submissions whose spec
    // disagrees fail their own session at the geometry precheck. Probe
    // the default spec for that geometry (0 samples: generators produce
    // nothing for the probe).
    let probe = workload_from_spec(&spec, 0, 0)?;
    let net = fallback_net_dims(
        probe.name(),
        probe.inputs(),
        hidden,
        probe.classes(),
        probe.timesteps(),
    );
    let rt = SocBuilder::from_soc_config(cfg.soc.clone())
        .check(check)
        .workers(workers)
        .queue_depth(queue_depth)
        .keep_warm(keep_warm)
        .recovery(recovery)
        .build_serve_runtime(&net)?;
    let gateway = fullerene_soc::http::Gateway::new(
        rt,
        fullerene_soc::http::GatewayConfig {
            admin_token,
            default_workload: spec.clone(),
            max_samples,
        },
    );
    let server = fullerene_soc::http::HttpServer::start(
        fullerene_soc::http::HttpConfig {
            addr: format!("{host}:{port}"),
            io_timeout_ms,
            max_body_bytes,
        },
        gateway,
    )?;
    println!("serve-http listening on http://{}", server.addr());
    println!(
        "endpoints: POST /v1/sessions  GET /v1/sessions/<id>  GET /metrics  \
         GET /healthz  POST /admin/shutdown"
    );
    let stats = server.join()?;

    let mut t = Table::new(&["code", "responses"]);
    for (code, n) in &stats.responses_by_code {
        t.push_row(vec![code.to_string(), n.to_string()]);
    }
    println!("{}", t.render());
    let h = stats.health;
    println!(
        "drained: {} sessions ({} completed, {} deadline-exceeded, {} fabric-degraded, \
         {} failed), {} retries, {} quarantines, {} rebuilds, {} replans",
        h.sessions,
        h.completed,
        h.deadline_exceeded,
        h.fabric_degraded,
        h.failed,
        h.retries,
        h.quarantines,
        h.rebuilds,
        h.replans
    );
    println!(
        "connections: {} opened, {} closed; {} requests",
        stats.connections_opened, stats.connections_closed, stats.requests
    );
    if !stats.drained || stats.connections_opened != stats.connections_closed {
        return Err(Error::Runtime(format!(
            "unclean shutdown: drained={}, {} of {} connections closed",
            stats.drained, stats.connections_closed, stats.connections_opened
        )));
    }
    Ok(())
}

fn cmd_topo() -> Result<()> {
    let stats = vec![
        TopoStats::compute(&Topology::fullerene()),
        TopoStats::compute(&Topology::fullerene_with_l2()),
        TopoStats::compute(&Topology::mesh2d(4, 5)),
        TopoStats::compute(&Topology::torus(4, 5)),
        TopoStats::compute(&Topology::ring(20)),
        TopoStats::compute(&Topology::tree(4, 20)),
    ];
    println!("{}", TopoStats::table(&stats).render());
    let f = &stats[0];
    let best_other = stats[2..]
        .iter()
        .map(|s| s.avg_core_hops)
        .fold(f64::INFINITY, f64::min);
    println!(
        "fullerene avg hops {:.2} vs best baseline {:.2} ({:.1}% lower)",
        f.avg_core_hops,
        best_other,
        (1.0 - f.avg_core_hops / best_other) * 100.0
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("core-sparsity") => {
            let t = fullerene_soc::benches_support::fig3_table(9, 42);
            println!("{}", t.render());
        }
        Some("router") => {
            let t = fullerene_soc::benches_support::fig5c_table(42);
            println!("{}", t.render());
        }
        Some("riscv-power") => {
            let t = fullerene_soc::benches_support::fig6_table()?;
            println!("{}", t.render());
        }
        other => {
            return Err(Error::Config(format!(
                "bench expects core-sparsity | router | riscv-power, got {other:?}"
            )))
        }
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let path = args
        .get("weights")
        .ok_or_else(|| Error::config("--weights <file> required"))?;
    let net = load_weights_json(Path::new(path))?;
    let mapping = fullerene_soc::nn::Mapping::plan(&net, 20, 8192)?;
    println!(
        "network '{}': {} layers, {} neurons, {} synapses, T={}",
        net.name,
        net.layers.len(),
        net.total_neurons(),
        net.total_synapses(),
        net.timesteps
    );
    let mut t = Table::new(&["core", "layer", "neurons", "axons", "offset"]);
    for p in &mapping.placements {
        t.push_row(vec![
            p.core_id.to_string(),
            net.layers[p.layer].name.clone(),
            p.neurons.to_string(),
            p.axons.to_string(),
            p.neuron_offset.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let w = parse_workload(&args.get_or("workload", "nmnist"))?;
    let n: usize = args.get_parse_or("samples", 10);
    let seed: u64 = args.get_parse_or("seed", 7);
    let out = PathBuf::from(args.get_or("out", "dataset.json"));
    let ds = w.generate(n, seed);
    ds.to_json().write_file(&out)?;
    println!(
        "wrote {} samples ({} inputs, T={}, sparsity {:.3}) to {}",
        n,
        ds.inputs,
        ds.timesteps,
        ds.sparsity(),
        out.display()
    );
    Ok(())
}
