"""Non-uniform weight quantization (k-means codebooks), the Python twin of
``rust/src/nn/quant.rs`` — same quantile initialization, same Lloyd
update, same integerization rule, so both sides satisfy the same
invariants (tested in tests/test_quantize.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np

NO_SYNAPSE = 255


@dataclasses.dataclass
class QuantizedLayer:
    codebook: np.ndarray  # int32 [n]
    widx: np.ndarray      # uint8, same shape as the input weights
    scale: float          # float_weight ≈ level × scale


def kmeans_quantize(weights: np.ndarray, n: int, w_bits: int,
                    iters: int = 15) -> QuantizedLayer:
    """Quantize float weights to ``n`` integer levels of ``w_bits``."""
    assert n in (4, 8, 16) and w_bits in (4, 8, 16)
    flat = np.asarray(weights, dtype=np.float64).ravel()
    assert flat.size > 0
    srt = np.sort(flat)
    qs = (np.arange(n) + 0.5) / n
    centroids = srt[((srt.size - 1) * qs).astype(int)].astype(np.float64)
    for i in range(1, n):
        if centroids[i] <= centroids[i - 1]:
            centroids[i] = centroids[i - 1] + 1e-9

    for _ in range(iters):
        d = np.abs(flat[:, None] - centroids[None, :])
        assign = d.argmin(axis=1)
        for c in range(n):
            sel = flat[assign == c]
            if sel.size:
                centroids[c] = sel.mean()
        centroids.sort()

    hi = (1 << (w_bits - 1)) - 1
    lo = -(1 << (w_bits - 1))
    maxabs = np.abs(centroids).max()
    scale = maxabs / hi if maxabs > 1e-6 else 1.0
    levels = np.clip(np.round(centroids / scale), lo, hi).astype(np.int32)
    # Final assignment against the integerized levels (deployed domain).
    d = np.abs(flat[:, None] - (levels[None, :] * scale))
    assign = d.argmin(axis=1).astype(np.uint8)
    return QuantizedLayer(codebook=levels,
                          widx=assign.reshape(np.shape(weights)),
                          scale=float(scale))


def quant_mse(weights: np.ndarray, q: QuantizedLayer) -> float:
    approx = q.codebook[q.widx.ravel().astype(int)] * q.scale
    return float(np.mean((np.ravel(weights) - approx) ** 2))
