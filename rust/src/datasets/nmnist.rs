//! NMNIST-like synthetic event streams: 34×34×2 (ON/OFF polarity),
//! 10 classes, saccade-style micro-motion.
//!
//! Each class has a deterministic prototype (a small constellation of
//! gaussian blobs — digit-ish shapes differ per class); a sample jitters
//! the prototype's position over three saccade phases and Bernoulli-codes
//! ON events from the intensity and OFF events from its temporal
//! difference, which is how a real DVS camera sees a moving static digit.

use super::encode::{rate_encode, Intensity};
use super::events::{Dataset, Sample};
use crate::util::prng::Rng;

/// Image side.
pub const SIDE: usize = 34;
/// Polarity channels.
pub const CHANNELS: usize = 2;
/// Timesteps per sample.
pub const TIMESTEPS: usize = 20;
/// Classes.
pub const CLASSES: usize = 10;

/// Deterministic class prototype (blob constellation).
fn prototype(class: usize) -> Intensity {
    let mut rng = Rng::new(0x5EED_0000 + class as u64);
    let mut m = Intensity::zeros(SIDE, SIDE, 1);
    // 3–5 blobs arranged on a class-specific ring + jittered offsets.
    let blobs = 3 + class % 3;
    for b in 0..blobs {
        let ang = std::f64::consts::TAU * (b as f64 / blobs as f64 + class as f64 * 0.13);
        let r = 6.0 + (class as f64 * 0.7) % 5.0;
        let cx = SIDE as f64 / 2.0 + r * ang.cos() + rng.normal();
        let cy = SIDE as f64 / 2.0 + r * ang.sin() + rng.normal();
        m.add_blob(0, cx, cy, 2.2 + 0.2 * (class % 4) as f64, 0.75);
    }
    m
}

/// Generate one sample of class `class`.
fn sample(class: usize, rng: &mut Rng) -> Sample {
    let proto = prototype(class);
    // Three saccade phases (the NMNIST acquisition protocol's triangle).
    let saccade = [(1i64, 0i64), (0, 1), (-1, -1)];
    let mut frames: Vec<Intensity> = Vec::with_capacity(TIMESTEPS);
    let mut prev = proto.shifted(0, 0);
    for t in 0..TIMESTEPS {
        let phase = t * saccade.len() / TIMESTEPS;
        let (dx, dy) = saccade[phase];
        let jx = rng.range_i64(-1, 1);
        let jy = rng.range_i64(-1, 1);
        let cur = proto.shifted(dx * (t as i64 % 4) + jx, dy * (t as i64 % 4) + jy);
        // ON channel = current intensity; OFF channel = where intensity
        // dropped vs the previous frame.
        let mut f = Intensity::zeros(SIDE, SIDE, CHANNELS);
        for y in 0..SIDE {
            for x in 0..SIDE {
                let c = cur.data[cur.idx(0, y, x)];
                let p = prev.data[prev.idx(0, y, x)];
                let on = f.idx(0, y, x);
                f.data[on] = c;
                let off = f.idx(1, y, x);
                f.data[off] = (p - c).max(0.0);
            }
        }
        prev = cur;
        frames.push(f);
    }
    rate_encode(&frames, 0.18, class, rng)
}

/// Generate `n` samples (labels round-robin over the classes).
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let samples: Vec<Sample> = (0..n).map(|i| sample(i % CLASSES, &mut rng)).collect();
    Dataset {
        name: "nmnist-syn".into(),
        inputs: SIDE * SIDE * CHANNELS,
        timesteps: TIMESTEPS,
        classes: CLASSES,
        samples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_valid_dataset() {
        let d = generate(20, 1);
        d.validate().unwrap();
        assert_eq!(d.inputs, 2312);
        assert_eq!(d.samples.len(), 20);
        // Every class appears twice.
        for c in 0..CLASSES {
            assert_eq!(d.samples.iter().filter(|s| s.label == c).count(), 2);
        }
    }

    #[test]
    fn sparsity_in_snn_regime() {
        let d = generate(10, 2);
        let s = d.sparsity();
        // Event streams are sparse: the paper's efficiency story needs
        // >40 % sparsity; DVS-style data is typically > 80 %.
        assert!(s > 0.8 && s < 0.999, "sparsity {s}");
    }

    #[test]
    fn classes_are_statistically_distinct() {
        let d = generate(40, 3);
        // Mean spatial activation per class must differ between classes:
        // compare per-class spike histograms' overlap.
        let hist = |class: usize| -> Vec<f64> {
            let mut h = vec![0.0; d.inputs];
            let mut cnt = 0.0f64;
            for s in d.samples.iter().filter(|s| s.label == class) {
                cnt += 1.0;
                for &(_, a) in &s.events {
                    h[a as usize] += 1.0;
                }
            }
            h.iter_mut().for_each(|v| *v /= cnt.max(1.0));
            h
        };
        let h0 = hist(0);
        let h1 = hist(1);
        let dot = |a: &[f64], b: &[f64]| a.iter().zip(b).map(|(x, y)| x * y).sum::<f64>();
        let cos = dot(&h0, &h1) / (dot(&h0, &h0).sqrt() * dot(&h1, &h1).sqrt());
        assert!(cos < 0.9, "class prototypes overlap too much (cos {cos})");
    }

    #[test]
    fn determinism_by_seed() {
        let a = generate(5, 9);
        let b = generate(5, 9);
        assert_eq!(a.samples, b.samples);
        let c = generate(5, 10);
        assert_ne!(a.samples, c.samples);
    }
}
