//! In-tree replacements for crates unavailable in the offline build
//! environment (see DESIGN.md §Offline-substitutions):
//!
//! - [`json`] — minimal JSON parser/writer (replaces `serde_json`) used for
//!   the Python↔Rust artifact interchange (trained weights, codebooks,
//!   network descriptions) and config files.
//! - [`prng`] — seeded SplitMix64/xoshiro256** PRNG (replaces `rand`) used
//!   by workload generators and property tests. Deterministic by seed.
//! - [`bench`] — micro-benchmark harness (replaces `criterion`): warmup +
//!   timed iterations, median/p10/p90, throughput, table rendering.
//! - [`cli`] — flag parser (replaces `clap`): subcommands plus
//!   `--key value` / `--key=value` options.
//! - [`propcheck`] — property-testing loop (replaces `proptest`): runs a
//!   property over N seeded random cases and reports the failing seed.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prng;
pub mod propcheck;

pub use json::Json;
pub use prng::Rng;
