//! LIF neuron state + updater (paper §II.A: "A neuron updater controls
//! neuron MP integration, leaking and resetting, and spike firing
//! procedures."), with **partial membrane-potential updates**: only
//! neurons touched by at least one valid input spike in the current
//! timestep are read-modified-written; untouched neurons keep their MP
//! unchanged and cannot fire. The dense baseline instead walks every
//! neuron every timestep.
//!
//! The integer semantics here are the **authoritative definition** of the
//! chip's arithmetic and are mirrored bit-exactly by the JAX golden model
//! (`python/compile/kernels/ref.py` / the Pallas kernel). Order per
//! touched neuron:
//!
//! 1. integrate: `mp ← sat_w(mp + acc)` (saturating to the MP register
//!    width),
//! 2. leak: linear decay toward zero by `leak` (or arithmetic-shift decay),
//! 3. fire: `spike ← mp ≥ threshold`,
//! 4. reset: to zero, or by threshold subtraction.



/// Leak applied after integration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeakMode {
    /// No leak.
    None,
    /// Subtract `λ` moving the MP toward zero, never crossing it.
    Linear(i32),
    /// Exponential-style decay: `mp ← mp - (mp >> k)` (arithmetic shift).
    Shift(u8),
}

/// Reset applied on firing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResetMode {
    /// Reset MP to zero.
    Zero,
    /// Subtract the threshold (residue-preserving).
    Subtract,
}

/// Neuron dynamics configuration (stored in the core register table).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NeuronParams {
    /// Firing threshold (> 0).
    pub threshold: i32,
    /// Leak mode.
    pub leak: LeakMode,
    /// Reset mode.
    pub reset: ResetMode,
    /// MP register width in bits (signed saturating arithmetic).
    pub mp_bits: u32,
}

impl Default for NeuronParams {
    fn default() -> Self {
        NeuronParams {
            threshold: 64,
            leak: LeakMode::Linear(1),
            reset: ResetMode::Subtract,
            mp_bits: 16,
        }
    }
}

impl NeuronParams {
    /// Saturation bounds of the MP register.
    #[inline]
    pub fn mp_range(&self) -> (i32, i32) {
        let half = 1i64 << (self.mp_bits - 1);
        ((-half) as i32, (half - 1) as i32)
    }
}

/// The membrane-potential array of one core plus its update logic.
#[derive(Debug, Clone)]
pub struct NeuronArray {
    params: NeuronParams,
    mp: Vec<i32>,
}

impl NeuronArray {
    /// All-zero MPs for `n` neurons.
    pub fn new(n: usize, params: NeuronParams) -> Self {
        NeuronArray {
            params,
            mp: vec![0; n],
        }
    }

    /// Number of neurons.
    pub fn len(&self) -> usize {
        self.mp.len()
    }

    /// True when the array has no neurons.
    pub fn is_empty(&self) -> bool {
        self.mp.is_empty()
    }

    /// Dynamics parameters.
    pub fn params(&self) -> &NeuronParams {
        &self.params
    }

    /// Current MP of neuron `i`.
    pub fn mp(&self, i: usize) -> i32 {
        self.mp[i]
    }

    /// Raw MP slice (for DMA/golden-model comparison).
    pub fn mps(&self) -> &[i32] {
        &self.mp
    }

    /// Overwrite all MPs (MPDMA restore path).
    pub fn load_mps(&mut self, mps: &[i32]) {
        self.mp.copy_from_slice(mps);
    }

    /// Reset all MPs to zero (network startup).
    pub fn reset_all(&mut self) {
        self.mp.iter_mut().for_each(|m| *m = 0);
    }

    /// Update one neuron with accumulated input `acc`; returns `true` when
    /// it fires. This is the single authoritative LIF step.
    #[inline]
    pub fn update_one(&mut self, i: usize, acc: i32) -> bool {
        let (lo, hi) = self.params.mp_range();
        // 1. integrate, saturating.
        let mut m = (self.mp[i] as i64 + acc as i64).clamp(lo as i64, hi as i64) as i32;
        // 2. leak toward zero.
        m = match self.params.leak {
            LeakMode::None => m,
            LeakMode::Linear(l) => {
                if m > 0 {
                    (m - l).max(0)
                } else if m < 0 {
                    (m + l).min(0)
                } else {
                    0
                }
            }
            LeakMode::Shift(k) => m - (m >> k),
        };
        // 3. fire.
        let spike = m >= self.params.threshold;
        // 4. reset.
        if spike {
            m = match self.params.reset {
                ResetMode::Zero => 0,
                ResetMode::Subtract => m - self.params.threshold,
            };
        }
        self.mp[i] = m;
        spike
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(th: i32, leak: LeakMode, reset: ResetMode) -> NeuronParams {
        NeuronParams {
            threshold: th,
            leak,
            reset,
            mp_bits: 16,
        }
    }

    #[test]
    fn integrate_and_fire_subtract_reset() {
        let mut n = NeuronArray::new(1, params(10, LeakMode::None, ResetMode::Subtract));
        assert!(!n.update_one(0, 6)); // mp = 6
        assert!(n.update_one(0, 6)); // mp = 12 >= 10 → fire, residue 2
        assert_eq!(n.mp(0), 2);
    }

    #[test]
    fn zero_reset_discards_residue() {
        let mut n = NeuronArray::new(1, params(10, LeakMode::None, ResetMode::Zero));
        assert!(n.update_one(0, 15));
        assert_eq!(n.mp(0), 0);
    }

    #[test]
    fn linear_leak_moves_toward_zero_without_crossing() {
        let mut n = NeuronArray::new(2, params(100, LeakMode::Linear(3), ResetMode::Zero));
        n.update_one(0, 5); // 5 - 3 = 2
        assert_eq!(n.mp(0), 2);
        n.update_one(0, 0); // 2 - 3 clamps at 0
        assert_eq!(n.mp(0), 0);
        n.update_one(1, -5); // -5 + 3 = -2
        assert_eq!(n.mp(1), -2);
        n.update_one(1, 0); // -2 + 3 clamps at 0
        assert_eq!(n.mp(1), 0);
    }

    #[test]
    fn shift_leak_matches_arithmetic_shift() {
        let mut n = NeuronArray::new(1, params(1000, LeakMode::Shift(2), ResetMode::Zero));
        n.update_one(0, 100); // 100 - 25 = 75
        assert_eq!(n.mp(0), 75);
        let mut n2 = NeuronArray::new(1, params(1000, LeakMode::Shift(2), ResetMode::Zero));
        n2.update_one(0, -100); // -100 - (-100 >> 2 = -25) = -75
        assert_eq!(n2.mp(0), -75);
    }

    #[test]
    fn saturation_at_register_width() {
        let p = params(30000, LeakMode::None, ResetMode::Zero);
        let (lo, hi) = p.mp_range();
        assert_eq!((lo, hi), (-32768, 32767));
        let mut n = NeuronArray::new(1, p);
        n.update_one(0, 30000);
        n.update_one(0, 30000); // would be 60000 → saturates, fires
        assert_eq!(n.mp(0), 0); // fired at hi (32767 ≥ 30000) and reset
        let mut n = NeuronArray::new(1, params(40000, LeakMode::None, ResetMode::Zero));
        // threshold above saturation: can never fire, clamps at hi
        assert!(!n.update_one(0, 32000));
        assert!(!n.update_one(0, 32000));
        assert_eq!(n.mp(0), 32767);
    }

    #[test]
    fn load_and_reset() {
        let mut n = NeuronArray::new(3, NeuronParams::default());
        n.load_mps(&[1, 2, 3]);
        assert_eq!(n.mps(), &[1, 2, 3]);
        n.reset_all();
        assert_eq!(n.mps(), &[0, 0, 0]);
    }
}
