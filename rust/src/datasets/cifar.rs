//! CIFAR-10-like rate-coded synthetic frames: 32×32×3, 10 classes.
//!
//! Static per-class color/texture prototypes (seeded blob constellations
//! per RGB channel) with per-sample noise, rate-coded over the timestep
//! window — the standard way static-image benchmarks are fed to SNN
//! chips.

use super::encode::{rate_encode, Intensity};
use super::events::{Dataset, Sample};
use crate::util::prng::Rng;

/// Image side.
pub const SIDE: usize = 32;
/// RGB channels.
pub const CHANNELS: usize = 3;
/// Timesteps per sample.
pub const TIMESTEPS: usize = 16;
/// Classes.
pub const CLASSES: usize = 10;

fn prototype(class: usize) -> Intensity {
    let mut rng = Rng::new(0xC1FA_0000 + class as u64);
    let mut m = Intensity::zeros(SIDE, SIDE, CHANNELS);
    // Class-specific channel emphasis + blob layout.
    for ch in 0..CHANNELS {
        let blobs = 2 + (class + ch) % 3;
        let amp = 0.35 + 0.4 * (((class + ch * 3) % 5) as f64 / 4.0);
        for b in 0..blobs {
            let ang = std::f64::consts::TAU * (b as f64 / blobs as f64) + class as f64 * 0.37;
            let r = 4.0 + ((class * 7 + ch * 3 + b) % 9) as f64;
            let cx = SIDE as f64 / 2.0 + r * ang.cos() + rng.normal() * 0.5;
            let cy = SIDE as f64 / 2.0 + r * ang.sin() + rng.normal() * 0.5;
            m.add_blob(ch, cx, cy, 3.0 + (b % 2) as f64, amp);
        }
    }
    m
}

fn sample(class: usize, rng: &mut Rng) -> Sample {
    let proto = prototype(class);
    // Natural-image stand-in is deliberately the *hardest* task (the
    // paper's accuracy ordering is NMNIST > DVS Gesture > Cifar-10):
    // large shifts, heavy distractor clutter and background noise.
    let mut img = proto.shifted(rng.range_i64(-2, 2), rng.range_i64(-2, 2));
    for _ in 0..3 {
        let ch = rng.below_usize(CHANNELS);
        img.add_blob(
            ch,
            rng.f64() * SIDE as f64,
            rng.f64() * SIDE as f64,
            3.0,
            0.30,
        );
    }
    // Static frame repeated — rate coding does the temporal lifting;
    // ~1 % background spike noise on every pixel.
    let frames = vec![img; TIMESTEPS];
    let mut s = rate_encode(&frames, 0.22, class, rng);
    for t in 0..TIMESTEPS as u16 {
        for a in 0..(SIDE * SIDE * CHANNELS) as u32 {
            if rng.bool(0.008) {
                s.events.push((t, a));
            }
        }
    }
    s.events.sort_unstable();
    s.events.dedup();
    s
}

/// Generate `n` samples (labels round-robin).
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xC1FA_F00D);
    let samples: Vec<Sample> = (0..n).map(|i| sample(i % CLASSES, &mut rng)).collect();
    Dataset {
        name: "cifar10-syn".into(),
        inputs: SIDE * SIDE * CHANNELS,
        timesteps: TIMESTEPS,
        classes: CLASSES,
        samples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_geometry_and_sparsity() {
        let d = generate(20, 6);
        d.validate().unwrap();
        assert_eq!(d.inputs, 3072);
        let s = d.sparsity();
        // Rate-coded frames are denser than DVS events but still sparse.
        assert!(s > 0.6 && s < 0.99, "sparsity {s}");
    }

    #[test]
    fn per_class_rates_stable() {
        let d = generate(40, 7);
        for c in 0..CLASSES {
            let rates: Vec<f64> = d
                .samples
                .iter()
                .filter(|s| s.label == c)
                .map(|s| s.rate(TIMESTEPS))
                .collect();
            let mean = rates.iter().sum::<f64>() / rates.len() as f64;
            for r in &rates {
                assert!((r - mean).abs() < mean * 0.5, "class {c} unstable");
            }
        }
    }

    #[test]
    fn deterministic() {
        assert_eq!(generate(4, 2).samples, generate(4, 2).samples);
    }
}
