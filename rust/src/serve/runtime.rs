//! Persistent-worker, warm-chip, streaming-submission serving runtime —
//! the crate's top-level serving surface.
//!
//! The paper's chip is an always-on edge device: sessions arrive
//! continuously, lengths are skewed, and the processor is never torn
//! down between users. [`ServeRuntime`] serves the simulator the same
//! way, replacing the removed batch `SocPool::serve` dispatch (all
//! specs up front, static `i % workers` buckets, a fresh chip per
//! session, one aggregate at the end):
//!
//! - **Persistent workers, pull-based dispatch.** N worker threads live
//!   for the runtime's lifetime and pull from one shared bounded queue,
//!   so a long session occupies exactly one worker while its siblings
//!   drain every short session behind it — no head-of-line blocking
//!   from static buckets (pinned in `tests/serving_api.rs`).
//! - **Warm engine reuse.** Each worker keeps its serving
//!   [`Engine`] — one chip, or a whole cluster when the runtime was
//!   built with `chips > 1` — between sessions and re-arms it via
//!   [`Engine::reset_for_session`] instead of paying a fresh build
//!   (mapping planning, synapse tables, hop-table precompute, cluster
//!   partitioning) per session. Warm reuse is proven **bit-identical**
//!   to fresh engines — simulated physics cannot tell the difference.
//! - **Streaming submission.** [`ServeRuntime::submit`] blocks while
//!   the bounded queue is full; [`ServeRuntime::try_submit`] returns
//!   [`Error::QueueFull`] instead (backpressure the caller can act on).
//!   Both hand back a [`SessionTicket`] whose
//!   [`wait`](SessionTicket::wait) blocks for that session's outcome;
//!   [`ServeRuntime::outcomes`] yields results **as sessions finish**.
//! - **Per-session failure isolation.** A bad workload (error or panic)
//!   fails its own ticket — attributed to the session name and
//!   submission index — and its siblings keep serving; the worker's
//!   chip is discarded so no failed-session state leaks forward.
//! - **Determinism.** Sessions are independent and merged reports fold
//!   in **submission order**, so [`ServeRuntime::finish`] is
//!   bit-identical (`f64::to_bits`) to `SocPool::serve_sequential` over
//!   the same specs, for every worker count and queue depth.

use super::builder::MAX_QUEUE_DEPTH;
use super::pool::{
    check_geometry, merge_outcomes, run_session_on, ServeOutcome, SessionFailure,
    SessionOutcome, SessionSpec,
};
use super::recovery::{HealthReport, RecoveryPolicy};
use crate::cluster::Engine;
use crate::coordinator::GoldenCheck;
use crate::nn::NetworkDesc;
use crate::soc::SocConfig;
use crate::{Error, Result};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::Instant;

/// Lock a runtime mutex, shrugging off poisoning. A session that panics
/// resolves its own ticket through the catch in [`serve_one`]; should a
/// thread ever die while *holding* one of the runtime's locks, the data
/// behind it (queue counters, ticket slots, health tallies) is plain
/// state that stays internally consistent between guard acquisitions —
/// so abandoning every sibling session over a lost guard would turn one
/// isolated failure into a runtime-wide outage. The runtime therefore
/// treats poison as noise: take the guard and keep serving (pinned by
/// the poison regression test below).
fn lock_q<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Wait on `cv` until `pred` holds, re-checking after every wake and
/// recovering from poisoning exactly like [`lock_q`]. `Condvar::wait`
/// surfaces poison *before* the predicate re-check, so a plain
/// `wait_while(..).unwrap_or_else(..)` could return with the predicate
/// still false — this helper never does.
fn wait_until<'a, T>(
    cv: &Condvar,
    mut guard: MutexGuard<'a, T>,
    mut pred: impl FnMut(&T) -> bool,
) -> MutexGuard<'a, T> {
    while !pred(&guard) {
        guard = cv.wait(guard).unwrap_or_else(PoisonError::into_inner);
    }
    guard
}

/// One submitted-but-not-yet-served session.
struct Pending {
    index: u64,
    spec: SessionSpec,
    ticket: Arc<TicketInner>,
    submitted_at: Instant,
}

/// Mutable queue state behind [`Shared::q`].
struct QueueState {
    /// Bounded submission queue (capacity = `Shared::queue_depth`).
    pending: VecDeque<Pending>,
    /// No further submissions; workers drain `pending` and exit.
    closed: bool,
    /// Sessions submitted so far (also the next submission index).
    submitted: u64,
    /// Sessions fully served (ticket resolved).
    finished: u64,
    /// Finished tickets not yet yielded by [`ServeRuntime::outcomes`].
    completions: VecDeque<Arc<TicketInner>>,
    /// Per-worker "session currently being served" labels — the panic
    /// attribution of last resort should a worker die outside the
    /// per-session catch (the session-level catch normally resolves the
    /// ticket itself).
    running: Vec<Option<String>>,
}

/// State shared between the runtime handle and its workers.
struct Shared {
    net: NetworkDesc,
    config: SocConfig,
    check: GoldenCheck,
    keep_warm: bool,
    queue_depth: usize,
    recovery: RecoveryPolicy,
    /// Runtime-wide recovery counters; see [`ServeRuntime::health_report`].
    health: Mutex<HealthReport>,
    q: Mutex<QueueState>,
    /// Workers wait here for work (or close).
    work: Condvar,
    /// Submitters wait here for queue space.
    space: Condvar,
    /// Outcome consumers wait here for completions.
    done: Condvar,
}

/// Resolution slot of one submitted session.
struct TicketInner {
    index: u64,
    name: String,
    slot: Mutex<Option<Result<SessionOutcome>>>,
    ready: Condvar,
}

/// Handle to one submitted session: identifies it (submission index +
/// name) and blocks for its outcome independently of every sibling.
pub struct SessionTicket {
    inner: Arc<TicketInner>,
}

impl SessionTicket {
    /// Submission index (0-based, global over the runtime's lifetime).
    pub fn index(&self) -> u64 {
        self.inner.index
    }

    /// Session name.
    pub fn name(&self) -> &str {
        &self.inner.name
    }

    /// Block until this session finishes and return its outcome. Failure
    /// isolation: an `Err` here is *this* session's failure — siblings
    /// are unaffected. May be called more than once (the result is
    /// cloned out, never drained).
    pub fn wait(&self) -> Result<SessionOutcome> {
        let mut slot = lock_q(&self.inner.slot);
        loop {
            // Re-take the predicate's witness by hand instead of
            // expect()ing on it: a spurious None after wait_until would
            // otherwise panic the caller's thread.
            if let Some(r) = slot.as_ref() {
                return r.clone();
            }
            slot = wait_until(&self.inner.ready, slot, |s| s.is_some());
        }
    }

    /// Non-blocking probe: the outcome if the session already finished.
    pub fn try_result(&self) -> Option<Result<SessionOutcome>> {
        lock_q(&self.inner.slot).clone()
    }
}

/// One entry of the streaming outcome feed: which session (submission
/// index + name) and how it ended.
#[derive(Debug, Clone)]
pub struct SessionResult {
    /// Submission index.
    pub index: u64,
    /// Session name.
    pub name: String,
    /// The session's outcome (failures are isolated to this entry).
    pub outcome: Result<SessionOutcome>,
}

/// The long-lived serving runtime. See the module docs for the model;
/// construct via [`crate::serve::SocBuilder::build_serve_runtime`] (the
/// validation choke point) or [`ServeRuntime::new`].
///
/// **Retention contract:** every submitted session's resolved outcome
/// (one [`SessionOutcome`] — a chip report plus stats, a few KB) is
/// retained for the runtime's lifetime so [`ServeRuntime::finish`] can
/// fold the aggregate in submission order and late
/// [`SessionTicket::wait`]s always resolve. Memory therefore grows with
/// *sessions submitted*, not with samples served (per-sample state
/// stays on the chips, which are bounded by the worker count). An
/// unbounded 24/7 deployment should `finish()` a runtime at window
/// boundaries (e.g. per million sessions) and spawn a fresh one — the
/// warm chips cost one `Soc::new` per worker to rebuild.
pub struct ServeRuntime {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    /// Every ticket ever issued, in submission order — the submission-
    /// order fold behind [`ServeRuntime::finish`].
    tickets: Vec<Arc<TicketInner>>,
}

impl ServeRuntime {
    /// Spawn a runtime: `workers` persistent threads over a bounded
    /// submission queue of `queue_depth` entries, serving sessions on
    /// `net` at `config` (`config.chips > 1` gives every worker a whole
    /// cluster). `keep_warm` re-arms each worker's engine via
    /// [`Engine::reset_for_session`] between sessions instead of building
    /// a new one. `check` may be [`GoldenCheck::None`] or
    /// [`GoldenCheck::Reference`] (the XLA golden model holds
    /// per-process state and cannot back concurrent sessions).
    /// `recovery` arms the self-healing layer — deadlines, deterministic
    /// retry, quarantine ([`RecoveryPolicy::disabled`] keeps today's
    /// behavior bit for bit).
    pub fn new(
        net: NetworkDesc,
        config: SocConfig,
        workers: usize,
        check: GoldenCheck,
        queue_depth: usize,
        keep_warm: bool,
        recovery: RecoveryPolicy,
    ) -> Result<ServeRuntime> {
        if matches!(check, GoldenCheck::Xla | GoldenCheck::Both) {
            return Err(Error::Config(
                "ServeRuntime supports check none|reference (XLA golden state \
                 is per-process); use ExperimentRunner::run for XLA checks"
                    .into(),
            ));
        }
        if workers == 0 {
            return Err(Error::Config(
                "ServeRuntime needs at least one worker".into(),
            ));
        }
        if !(1..=MAX_QUEUE_DEPTH).contains(&queue_depth) {
            // Same ceiling as SocBuilder::validate — the direct
            // constructor must not be a hole in the choke point.
            return Err(Error::Config(format!(
                "queue_depth {queue_depth} outside 1..={MAX_QUEUE_DEPTH}"
            )));
        }
        net.validate()?;
        recovery.validate()?;
        let shared = Arc::new(Shared {
            net,
            config,
            check,
            keep_warm,
            queue_depth,
            recovery,
            health: Mutex::new(HealthReport::default()),
            q: Mutex::new(QueueState {
                // Grows to actual occupancy (bounded by queue_depth);
                // pre-allocating the full depth would waste memory at
                // large depths for nothing.
                pending: VecDeque::new(),
                closed: false,
                submitted: 0,
                finished: 0,
                completions: VecDeque::new(),
                running: vec![None; workers],
            }),
            work: Condvar::new(),
            space: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|wid| {
                let shared = shared.clone();
                // lint:allow(no-unscoped-threads) workers joined in close_and_join(); merges stay in submission order
                std::thread::spawn(move || worker_loop(&shared, wid))
            })
            .collect();
        Ok(ServeRuntime {
            shared,
            workers: handles,
            tickets: Vec::new(),
        })
    }

    /// Worker-thread count.
    pub fn workers(&self) -> usize {
        lock_q(&self.shared.q).running.len()
    }

    /// The recovery policy this runtime was built with.
    pub fn recovery(&self) -> RecoveryPolicy {
        self.shared.recovery
    }

    /// Snapshot of the runtime-wide recovery counters: sessions served,
    /// retries and their simulated-cycle overhead, verdict tallies,
    /// quarantines and engine rebuilds. Monotonic for the runtime's
    /// lifetime; all-zero activity fields when the policy is disabled.
    pub fn health_report(&self) -> HealthReport {
        *lock_q(&self.shared.health)
    }

    /// Bounded submission-queue depth.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue_depth
    }

    /// Whether workers re-arm their engine between sessions.
    pub fn keep_warm(&self) -> bool {
        self.shared.keep_warm
    }

    /// Sessions submitted over the runtime's lifetime.
    pub fn submitted(&self) -> u64 {
        lock_q(&self.shared.q).submitted
    }

    /// Sessions submitted but not yet finished.
    pub fn in_flight(&self) -> u64 {
        let q = lock_q(&self.shared.q);
        q.submitted - q.finished
    }

    /// Submit a session, **blocking while the queue is full** until a
    /// worker frees a slot. Returns the session's [`SessionTicket`].
    pub fn submit(&mut self, spec: SessionSpec) -> Result<SessionTicket> {
        self.enqueue(spec, true)
    }

    /// Submit without blocking: [`Error::QueueFull`] when the bounded
    /// queue has no free slot — the backpressure signal an admission
    /// layer shapes traffic on. The spec is dropped on refusal; clone
    /// upstream if retry is intended.
    pub fn try_submit(&mut self, spec: SessionSpec) -> Result<SessionTicket> {
        self.enqueue(spec, false)
    }

    fn enqueue(&mut self, spec: SessionSpec, block: bool) -> Result<SessionTicket> {
        let mut q = lock_q(&self.shared.q);
        loop {
            // Closed beats full: a post-shutdown submission must error
            // out, not park forever on a queue no worker will drain
            // (shutdown wakes `space` exactly so this check re-runs).
            if q.closed {
                return Err(Error::Runtime(format!(
                    "serve runtime is shut down; session '{}' refused",
                    spec.name
                )));
            }
            if q.pending.len() < self.shared.queue_depth {
                break;
            }
            if !block {
                return Err(Error::QueueFull(self.shared.queue_depth));
            }
            q = self
                .shared
                .space
                .wait(q)
                .unwrap_or_else(PoisonError::into_inner);
        }
        let index = q.submitted;
        q.submitted += 1;
        let ticket = Arc::new(TicketInner {
            index,
            name: spec.name.clone(),
            slot: Mutex::new(None),
            ready: Condvar::new(),
        });
        q.pending.push_back(Pending {
            index,
            spec,
            ticket: ticket.clone(),
            // lint:allow(host-clock-quarantine) queue-wait is host latency telemetry, not sim state
            submitted_at: Instant::now(),
        });
        drop(q);
        self.shared.work.notify_one();
        self.tickets.push(ticket.clone());
        Ok(SessionTicket { inner: ticket })
    }

    /// Iterator over session results **in completion order**, blocking
    /// until the next session finishes and ending once every session
    /// submitted so far has been yielded. Short sessions surface here
    /// while a long sibling is still running — the streaming view the
    /// batch API could not express. Calling it again later resumes with
    /// newly finished sessions.
    pub fn outcomes(&mut self) -> Outcomes<'_> {
        Outcomes { rt: self }
    }

    /// Close the queue (no further submissions), let the workers drain
    /// every pending session, join them, and fold the per-session
    /// reports **in submission order** into a [`ServeOutcome`]. Failed
    /// sessions are excluded from the merge and listed in
    /// [`ServeOutcome::failures`]; the call errors only when *no*
    /// session succeeded (or none was submitted).
    pub fn finish(mut self) -> Result<ServeOutcome> {
        self.close_and_join()?;
        let tickets = std::mem::take(&mut self.tickets);
        let mut sessions = Vec::with_capacity(tickets.len());
        let mut failures = Vec::new();
        for t in &tickets {
            let slot = lock_q(&t.slot);
            match slot.as_ref() {
                Some(Ok(o)) => sessions.push(o.clone()),
                Some(Err(e)) => failures.push(SessionFailure {
                    index: t.index,
                    name: t.name.clone(),
                    error: e.clone(),
                }),
                // Workers resolve every ticket on drain; if one somehow
                // didn't, that is this session's failure, not a panic.
                None => failures.push(SessionFailure {
                    index: t.index,
                    name: t.name.clone(),
                    error: Error::Runtime(format!(
                        "session '{}' (#{}) was never resolved by a worker",
                        t.name, t.index
                    )),
                }),
            }
        }
        merge_outcomes(sessions, failures, self.shared.config.domains)
    }

    /// Clean drain, in place: stop accepting submissions, let the
    /// workers serve every already-admitted session, and join them.
    /// After `shutdown` returns, every issued ticket has resolved,
    /// [`ServeRuntime::outcomes`] yields only already-finished sessions,
    /// and further `submit`/`try_submit` calls error out instead of
    /// parking. Idempotent (a second call joins nothing) and
    /// poison-tolerant like every other runtime path; [`ServeRuntime::finish`]
    /// remains the consuming variant that also folds the aggregate.
    pub fn shutdown(&mut self) -> Result<()> {
        self.close_and_join()
    }

    /// Close the queue and join every worker, attributing a worker death
    /// to the session it was serving (the per-session catch normally
    /// resolves the ticket first, so this path is the backstop).
    fn close_and_join(&mut self) -> Result<()> {
        {
            let mut q = lock_q(&self.shared.q);
            q.closed = true;
        }
        self.shared.work.notify_all();
        // Wake submitters blocked on a full queue so they observe
        // `closed` and error out — otherwise a drain with a full queue
        // would leave them waiting on a condvar nobody signals again.
        self.shared.space.notify_all();
        let mut first_err = None;
        for (wid, h) in std::mem::take(&mut self.workers).into_iter().enumerate() {
            if h.join().is_err() && first_err.is_none() {
                // lint:allow(no-silent-panic-in-serving) wid enumerates self.workers, running has that length
                let running = lock_q(&self.shared.q).running[wid].take();
                first_err = Some(Error::Soc(match running {
                    Some(s) => {
                        format!("serving worker {wid} died while serving session {s}")
                    }
                    None => format!("serving worker {wid} died between sessions"),
                }));
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for ServeRuntime {
    /// Dropping the runtime closes the queue, drains every already
    /// submitted session (tickets always resolve) and joins the workers.
    fn drop(&mut self) {
        let _ = self.close_and_join();
    }
}

/// Streaming completion-order iterator over a runtime's session results;
/// see [`ServeRuntime::outcomes`].
pub struct Outcomes<'a> {
    rt: &'a mut ServeRuntime,
}

impl Iterator for Outcomes<'_> {
    type Item = SessionResult;

    fn next(&mut self) -> Option<SessionResult> {
        let shared = &self.rt.shared;
        let mut q = lock_q(&shared.q);
        loop {
            if let Some(t) = q.completions.pop_front() {
                let slot = lock_q(&t.slot);
                let outcome = match slot.as_ref() {
                    Some(r) => r.clone(),
                    // A completed ticket always carries a result; if not,
                    // surface it as this session's failure, not a panic.
                    None => Err(Error::Runtime(format!(
                        "session '{}' (#{}) completed without a result",
                        t.name, t.index
                    ))),
                };
                return Some(SessionResult {
                    index: t.index,
                    name: t.name.clone(),
                    outcome,
                });
            }
            if q.finished == q.submitted {
                return None; // nothing in flight and nothing queued
            }
            q = shared.done.wait(q).unwrap_or_else(PoisonError::into_inner);
        }
    }
}

/// Best-effort panic-payload rendering for failure attribution.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// The persistent worker: pull a session, arm an engine (warm when
/// possible), serve it, resolve its ticket, repeat until the queue is
/// closed **and** drained.
fn worker_loop(shared: &Arc<Shared>, wid: usize) {
    let mut warm: Option<Engine> = None;
    loop {
        let pending = {
            let mut q = wait_until(&shared.work, lock_q(&shared.q), |q| {
                !q.pending.is_empty() || q.closed
            });
            match q.pending.pop_front() {
                Some(p) => {
                    // lint:allow(no-silent-panic-in-serving) wid < workers by construction of the pool
                    q.running[wid] =
                        Some(format!("'{}' (#{})", p.spec.name, p.index));
                    p
                }
                None => return, // closed and drained
            }
        };
        shared.space.notify_one();
        let mut p = pending;
        let queue_wait_s = p.submitted_at.elapsed().as_secs_f64();
        let result = serve_one(shared, &mut warm, &mut p, queue_wait_s);
        lock_q(&shared.health).record_outcome(&result);
        *lock_q(&p.ticket.slot) = Some(result);
        p.ticket.ready.notify_all();
        {
            let mut q = lock_q(&shared.q);
            // lint:allow(no-silent-panic-in-serving) wid < workers by construction of the pool
            q.running[wid] = None;
            q.finished += 1;
            q.completions.push_back(p.ticket.clone());
        }
        shared.done.notify_all();
    }
}

/// Serve one pulled session with failure isolation: workload errors and
/// panics resolve *this* session's outcome (panics attributed to the
/// session name/index — never a bare "worker thread panicked") and
/// discard the worker's engine so no partial state survives into the
/// next session.
fn serve_one(
    shared: &Arc<Shared>,
    warm: &mut Option<Engine>,
    p: &mut Pending,
    queue_wait_s: f64,
) -> Result<SessionOutcome> {
    let name = p.spec.name.clone();
    let index = p.index;
    // Geometry precheck BEFORE arming an engine: a misconfigured
    // submission must not cost the worker its pristine warm engine (the
    // discard rule below is for sessions that actually ran on it).
    check_geometry(&shared.net, &name, &*p.spec.workload)?;
    let caught = catch_unwind(AssertUnwindSafe(|| -> Result<SessionOutcome> {
        let engine = match warm.take() {
            Some(mut e) => {
                e.reset_for_session();
                e
            }
            None => {
                lock_q(&shared.health).rebuilds += 1;
                Engine::new(shared.net.clone(), shared.config.clone())?
            }
        };
        let (outcome, engine) = run_session_on(
            engine,
            &shared.net,
            shared.check,
            &name,
            &mut *p.spec.workload,
            queue_wait_s,
            &shared.recovery,
        )?;
        let wear = outcome.degradation.dead_routers
            + outcome.degradation.dead_links
            + outcome.degradation.dropped;
        if shared.recovery.quarantine_after > 0 && wear >= shared.recovery.quarantine_after {
            // Quarantine: this engine's fabric crossed the dead-fabric /
            // dropped-flit threshold. Drop it even in keep-warm mode so
            // the next session on this worker builds fresh silicon.
            lock_q(&shared.health).quarantines += 1;
        } else if shared.keep_warm {
            *warm = Some(engine);
        }
        Ok(outcome)
    }));
    match caught {
        Ok(r) => r,
        Err(payload) => {
            *warm = None; // a panicking session must not leave an engine behind
            Err(Error::Soc(format!(
                "session '{name}' (#{index}) panicked: {}",
                panic_message(&*payload)
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::neuron::{LeakMode, NeuronParams, ResetMode};
    use crate::core::Codebook;
    use crate::nn::network::{LayerDesc, NetworkDesc};
    use crate::serve::TrafficWorkload;

    fn tiny_net() -> NetworkDesc {
        let cb = Codebook::default_log16();
        let params = NeuronParams {
            threshold: 50,
            leak: LeakMode::Linear(1),
            reset: ResetMode::Subtract,
            mp_bits: 16,
        };
        NetworkDesc {
            name: "runtime-test".into(),
            layers: vec![
                LayerDesc {
                    name: "h".into(),
                    inputs: 16,
                    neurons: 8,
                    codebook: cb.clone(),
                    widx: (0..16 * 8).map(|i| ((i * 11) % 16) as u8).collect(),
                    neuron_params: params.clone(),
                },
                LayerDesc {
                    name: "o".into(),
                    inputs: 8,
                    neurons: 4,
                    codebook: cb,
                    widx: (0..8 * 4).map(|i| ((i * 5) % 16) as u8).collect(),
                    neuron_params: params,
                },
            ],
            timesteps: 3,
            classes: 4,
        }
    }

    fn spec(i: u64, samples: usize) -> SessionSpec {
        SessionSpec::new(
            &format!("s{i}"),
            Box::new(TrafficWorkload::new(16, 4, 3, 0.2, samples, 100 + i)),
        )
    }

    /// Regression: a thread dying while holding the runtime's locks
    /// (queue, health, ticket slot) poisons them, and every runtime path
    /// — submit, ticket wait, counters, health, finish — must recover
    /// and keep serving instead of propagating the sibling's panic.
    #[test]
    fn poisoned_runtime_locks_recover_and_keep_serving() {
        let mut rt = ServeRuntime::new(
            tiny_net(),
            SocConfig::default(),
            1,
            GoldenCheck::None,
            8,
            true,
            RecoveryPolicy::disabled(),
        )
        .unwrap();
        let t0 = rt.submit(spec(0, 2)).unwrap();
        assert!(t0.wait().is_ok());
        // Poison the shared mutexes the way a dying thread would:
        // panic while holding the guards.
        let shared = rt.shared.clone();
        let ticket_inner = t0.inner.clone();
        let _ = std::thread::spawn(move || {
            let _q = shared.q.lock().unwrap();
            let _h = shared.health.lock().unwrap();
            let _s = ticket_inner.slot.lock().unwrap();
            panic!("poison the runtime locks");
        })
        .join();
        assert!(rt.shared.q.is_poisoned(), "queue mutex must be poisoned");
        assert!(rt.shared.health.is_poisoned(), "health mutex must be poisoned");
        // A resolved ticket still reads back through its poisoned slot.
        assert!(t0.try_result().expect("t0 already resolved").is_ok());
        // And the runtime keeps serving new sessions end to end.
        let t1 = rt.submit(spec(1, 2)).unwrap();
        let o = t1.wait().expect("session served across poisoned locks");
        assert_eq!(o.stats.samples, 2);
        assert_eq!(rt.submitted(), 2);
        assert_eq!(rt.in_flight(), 0);
        let health = rt.health_report();
        assert_eq!(health.sessions, 2);
        assert_eq!(health.completed, 2);
        let out = rt.finish().expect("aggregate folds across poisoned locks");
        assert_eq!(out.sessions.len(), 2);
        assert!(out.failures.is_empty());
    }

    /// Clean drain: `shutdown()` resolves every admitted session, joins
    /// the workers, rejects post-shutdown submissions with an error
    /// (instead of parking them on a queue nobody drains), stays
    /// idempotent, and still lets `finish()` fold the aggregate.
    #[test]
    fn shutdown_drains_resolves_and_rejects_new_submissions() {
        let mut rt = ServeRuntime::new(
            tiny_net(),
            SocConfig::default(),
            2,
            GoldenCheck::None,
            8,
            true,
            RecoveryPolicy::disabled(),
        )
        .unwrap();
        let t0 = rt.submit(spec(0, 2)).unwrap();
        let t1 = rt.submit(spec(1, 1)).unwrap();
        rt.shutdown().expect("clean drain");
        // Both tickets resolved without any explicit wait.
        assert!(t0.try_result().expect("t0 drained").is_ok());
        assert!(t1.try_result().expect("t1 drained").is_ok());
        assert_eq!(rt.in_flight(), 0);
        // Post-shutdown submissions error out — both entry points.
        let e = rt.submit(spec(2, 1)).unwrap_err();
        assert!(
            e.to_string().contains("shut down"),
            "submit after shutdown must name the drain, got: {e}"
        );
        assert!(rt.try_submit(spec(3, 1)).is_err());
        // Idempotent: a second drain joins nothing and succeeds.
        rt.shutdown().expect("shutdown is idempotent");
        // The consuming aggregate still folds the drained sessions.
        let out = rt.finish().expect("finish after shutdown");
        assert_eq!(out.sessions.len(), 2);
        assert!(out.failures.is_empty());
    }

    /// Regression for the drain's poisoned-lock path: a thread dying
    /// while holding the queue/health mutexes must not leak into
    /// `shutdown()` — the drain recovers the guards, resolves every
    /// ticket and keeps the post-shutdown submission contract.
    #[test]
    fn shutdown_survives_poisoned_locks() {
        let mut rt = ServeRuntime::new(
            tiny_net(),
            SocConfig::default(),
            1,
            GoldenCheck::None,
            4,
            true,
            RecoveryPolicy::disabled(),
        )
        .unwrap();
        let t0 = rt.submit(spec(0, 1)).unwrap();
        assert!(t0.wait().is_ok());
        let shared = rt.shared.clone();
        let _ = std::thread::spawn(move || {
            let _q = shared.q.lock().unwrap();
            let _h = shared.health.lock().unwrap();
            panic!("poison the runtime locks");
        })
        .join();
        assert!(rt.shared.q.is_poisoned());
        rt.shutdown().expect("drain across poisoned locks");
        assert!(rt.submit(spec(1, 1)).is_err());
        let h = rt.health_report();
        assert_eq!(h.sessions, 1);
        assert_eq!(h.completed, 1);
    }

    /// The health report tallies sessions/completions and, in keep-warm
    /// single-worker serving, exactly one engine build.
    #[test]
    fn health_report_counts_sessions_and_rebuilds() {
        let mut rt = ServeRuntime::new(
            tiny_net(),
            SocConfig::default(),
            1,
            GoldenCheck::None,
            8,
            true,
            RecoveryPolicy::disabled(),
        )
        .unwrap();
        for i in 0..3 {
            let t = rt.submit(spec(i, 1)).unwrap();
            t.wait().unwrap();
        }
        let h = rt.health_report();
        assert_eq!(h.sessions, 3);
        assert_eq!(h.completed, 3);
        assert_eq!(h.retries, 0);
        assert_eq!(h.retry_cycles_burned, 0);
        assert_eq!(h.quarantines, 0);
        assert_eq!(h.rebuilds, 1, "warm worker builds exactly one engine");
        rt.finish().unwrap();
    }
}
