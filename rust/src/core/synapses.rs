//! Per-core synapse connectivity: CSR by axon, with per-synapse weight
//! *indexes* into the shared codebook (the chip stores only `log2 N` bits
//! per synapse — that is how 64 M synapses/core fit).

use crate::{Error, Result};


/// Compressed synapse table: for each axon, a slice of (target neuron,
/// weight index) pairs.
#[derive(Debug, Clone, Default)]
pub struct Synapses {
    /// CSR offsets, length `axons + 1`.
    offsets: Vec<u32>,
    /// Target neuron ids, length = total synapses.
    targets: Vec<u32>,
    /// Codebook indexes, parallel to `targets`.
    widx: Vec<u8>,
}

impl Synapses {
    /// Number of axons.
    pub fn axons(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// Total synapse count.
    pub fn len(&self) -> usize {
        self.targets.len()
    }

    /// True when there are no synapses.
    pub fn is_empty(&self) -> bool {
        self.targets.is_empty()
    }

    /// Fan-out of one axon.
    #[inline]
    pub fn fanout(&self, axon: usize) -> usize {
        (self.offsets[axon + 1] - self.offsets[axon]) as usize
    }

    /// Iterate the (target, weight index) pairs of one axon.
    #[inline]
    pub fn synapses_of(&self, axon: usize) -> impl Iterator<Item = (u32, u8)> + '_ {
        let a = self.offsets[axon] as usize;
        let b = self.offsets[axon + 1] as usize;
        self.targets[a..b].iter().copied().zip(self.widx[a..b].iter().copied())
    }

    /// Raw slices of one axon's synapses (hot-path accessor).
    #[inline]
    pub fn slices_of(&self, axon: usize) -> (&[u32], &[u8]) {
        let a = self.offsets[axon] as usize;
        let b = self.offsets[axon + 1] as usize;
        (&self.targets[a..b], &self.widx[a..b])
    }

    /// Storage the chip would need for this table: `synapses × log2 N` bits.
    pub fn storage_bits(&self, index_bits: usize) -> u64 {
        self.len() as u64 * index_bits as u64
    }
}

/// Builder that accepts synapses in any order and freezes them into CSR.
#[derive(Debug, Clone)]
pub struct SynapsesBuilder {
    axons: usize,
    neurons: usize,
    n_codebook: usize,
    /// (axon, target, widx) triples.
    entries: Vec<(u32, u32, u8)>,
}

impl SynapsesBuilder {
    /// New builder for a core with `axons` inputs, `neurons` targets and a
    /// codebook of `n_codebook` entries.
    pub fn new(axons: usize, neurons: usize, n_codebook: usize) -> Self {
        SynapsesBuilder {
            axons,
            neurons,
            n_codebook,
            entries: Vec::new(),
        }
    }

    /// Add one synapse `axon → neuron` with codebook index `widx`.
    pub fn connect(&mut self, axon: usize, neuron: usize, widx: u8) -> Result<&mut Self> {
        if axon >= self.axons {
            return Err(Error::Core(format!(
                "axon {axon} out of range 0..{}",
                self.axons
            )));
        }
        if neuron >= self.neurons {
            return Err(Error::Core(format!(
                "neuron {neuron} out of range 0..{}",
                self.neurons
            )));
        }
        if widx as usize >= self.n_codebook {
            return Err(Error::Core(format!(
                "weight index {widx} out of codebook range 0..{}",
                self.n_codebook
            )));
        }
        self.entries.push((axon as u32, neuron as u32, widx));
        Ok(self)
    }

    /// Dense all-to-all connection where `widx_of(axon, neuron)` supplies
    /// the codebook index.
    pub fn connect_dense(
        &mut self,
        widx_of: impl Fn(usize, usize) -> u8,
    ) -> Result<&mut Self> {
        self.entries.reserve(self.axons * self.neurons);
        for a in 0..self.axons {
            for n in 0..self.neurons {
                let w = widx_of(a, n);
                if w as usize >= self.n_codebook {
                    return Err(Error::Core(format!(
                        "weight index {w} out of codebook range"
                    )));
                }
                self.entries.push((a as u32, n as u32, w));
            }
        }
        Ok(self)
    }

    /// Freeze into CSR form (counting sort by axon; stable in target order
    /// of insertion).
    pub fn build(&self) -> Synapses {
        let mut counts = vec![0u32; self.axons + 1];
        for &(a, _, _) in &self.entries {
            counts[a as usize + 1] += 1;
        }
        for i in 1..counts.len() {
            counts[i] += counts[i - 1];
        }
        let offsets = counts.clone();
        let mut cursor = counts;
        let mut targets = vec![0u32; self.entries.len()];
        let mut widx = vec![0u8; self.entries.len()];
        for &(a, t, w) in &self.entries {
            let pos = cursor[a as usize] as usize;
            targets[pos] = t;
            widx[pos] = w;
            cursor[a as usize] += 1;
        }
        Synapses {
            offsets,
            targets,
            widx,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_iterate() {
        let mut b = SynapsesBuilder::new(3, 4, 16);
        b.connect(2, 0, 5).unwrap();
        b.connect(0, 1, 1).unwrap();
        b.connect(0, 3, 2).unwrap();
        let s = b.build();
        assert_eq!(s.axons(), 3);
        assert_eq!(s.len(), 3);
        assert_eq!(s.fanout(0), 2);
        assert_eq!(s.fanout(1), 0);
        assert_eq!(s.fanout(2), 1);
        let v: Vec<_> = s.synapses_of(0).collect();
        assert_eq!(v, vec![(1, 1), (3, 2)]);
    }

    #[test]
    fn bounds_checked() {
        let mut b = SynapsesBuilder::new(2, 2, 4);
        assert!(b.connect(2, 0, 0).is_err());
        assert!(b.connect(0, 2, 0).is_err());
        assert!(b.connect(0, 0, 4).is_err());
    }

    #[test]
    fn dense_builder_counts() {
        let mut b = SynapsesBuilder::new(4, 3, 16);
        b.connect_dense(|a, n| ((a + n) % 16) as u8).unwrap();
        let s = b.build();
        assert_eq!(s.len(), 12);
        assert_eq!(s.storage_bits(4), 48);
    }
}
