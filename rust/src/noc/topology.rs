//! NoC topology graphs: the fullerene-like domain and the baseline
//! topologies it is compared against in Fig. 5 (2D-mesh, torus, ring,
//! tree).
//!
//! Convention: *communication nodes* are cores **and** routers, matching
//! the paper's degree accounting (the fullerene's published average degree
//! 3.75 and variance 0.93 only come out if both node types count — see
//! `DESIGN.md`). In the baseline topologies every router carries one
//! attached core (the classic NoC arrangement); in the fullerene domain
//! cores attach to three routers each.

use crate::{Error, Result};

/// Index of a node within a [`Topology`].
pub type NodeId = usize;

/// Sentinel entry in [`Topology::out_port_table`]: no output port routes
/// the flit (unreachable destination).
pub const NO_PORT: u16 = u16::MAX;

/// What a communication node is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    /// A neuromorphic core (holds the domain-local core id).
    Core(u8),
    /// A level-1 router.
    RouterL1(u8),
    /// A level-2 router (domain centre, scale-up port).
    RouterL2(u8),
}

impl NodeKind {
    /// True for cores.
    pub fn is_core(&self) -> bool {
        matches!(self, NodeKind::Core(_))
    }

    /// True for any router.
    pub fn is_router(&self) -> bool {
        !self.is_core()
    }
}

/// An undirected multigraph-free topology.
#[derive(Debug, Clone)]
pub struct Topology {
    /// Human-readable name ("fullerene", "mesh-4x5", …).
    pub name: String,
    nodes: Vec<NodeKind>,
    adj: Vec<Vec<NodeId>>,
    cores: Vec<NodeId>,
    /// Routing domain of each node (all 0 for single-domain topologies).
    domain: Vec<u32>,
    /// Number of routing domains (1 unless built by [`Topology::multi_domain`]).
    domains: usize,
}

impl Topology {
    fn new(name: &str) -> Self {
        Topology {
            name: name.to_string(),
            nodes: Vec::new(),
            adj: Vec::new(),
            cores: Vec::new(),
            domain: Vec::new(),
            domains: 1,
        }
    }

    fn add_node(&mut self, kind: NodeKind) -> NodeId {
        self.add_node_dom(kind, 0)
    }

    fn add_node_dom(&mut self, kind: NodeKind, dom: u32) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(kind);
        self.adj.push(Vec::new());
        self.domain.push(dom);
        if kind.is_core() {
            self.cores.push(id);
        }
        id
    }

    fn add_edge(&mut self, a: NodeId, b: NodeId) {
        debug_assert!(a != b);
        debug_assert!(!self.adj[a].contains(&b), "duplicate edge {a}-{b}");
        self.adj[a].push(b);
        self.adj[b].push(a);
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when the topology has no nodes.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Node kind.
    pub fn kind(&self, n: NodeId) -> NodeKind {
        self.nodes[n]
    }

    /// Neighbors of a node.
    pub fn neighbors(&self, n: NodeId) -> &[NodeId] {
        &self.adj[n]
    }

    /// All core node ids (in core-id order).
    pub fn cores(&self) -> &[NodeId] {
        &self.cores
    }

    /// All router node ids.
    pub fn routers(&self) -> Vec<NodeId> {
        (0..self.len()).filter(|&n| self.nodes[n].is_router()).collect()
    }

    /// Node id of core with (global) core id `c`. In a multi-domain
    /// topology global core ids are `domain * 20 + local`, matching the
    /// order the builder inserts cores.
    pub fn core_node(&self, c: usize) -> NodeId {
        self.cores[c]
    }

    /// Routing domain of a node (always 0 in single-domain topologies).
    pub fn domain_of(&self, n: NodeId) -> u32 {
        self.domain[n]
    }

    /// Number of routing domains in this topology.
    pub fn n_domains(&self) -> usize {
        self.domains
    }

    /// Total undirected edge count.
    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(Vec::len).sum::<usize>() / 2
    }

    /// BFS distances from `src` to every node (`usize::MAX` if unreachable).
    pub fn bfs(&self, src: NodeId) -> Vec<usize> {
        let mut dist = vec![usize::MAX; self.len()];
        let mut q = std::collections::VecDeque::new();
        dist[src] = 0;
        q.push_back(src);
        while let Some(u) = q.pop_front() {
            for &v in &self.adj[u] {
                if dist[v] == usize::MAX {
                    dist[v] = dist[u] + 1;
                    q.push_back(v);
                }
            }
        }
        dist
    }

    /// BFS distances from `src` over the alive subgraph: dead nodes and
    /// dead links are excluded, and `skip_l2` additionally excludes every
    /// level-2 router (the intra-domain metric). `usize::MAX` marks
    /// unreachable nodes. With empty masks this is exactly
    /// [`Topology::bfs`] (same queue order, hence the same distances and
    /// the same deterministic tie-breaks downstream).
    fn bfs_masked(
        &self,
        src: NodeId,
        skip_l2: bool,
        node_dead: &[bool],
        dead_links: &[(NodeId, NodeId)],
    ) -> Vec<usize> {
        let dead = |n: NodeId| node_dead.get(n).copied().unwrap_or(false);
        let mut dist = vec![usize::MAX; self.len()];
        if dead(src) || (skip_l2 && matches!(self.nodes[src], NodeKind::RouterL2(_))) {
            return dist;
        }
        let mut q = std::collections::VecDeque::new();
        dist[src] = 0;
        q.push_back(src);
        while let Some(u) = q.pop_front() {
            for &v in &self.adj[u] {
                if dist[v] == usize::MAX
                    && !dead(v)
                    && !(skip_l2 && matches!(self.nodes[v], NodeKind::RouterL2(_)))
                    && !link_is_dead(dead_links, u, v)
                {
                    dist[v] = dist[u] + 1;
                    q.push_back(v);
                }
            }
        }
        dist
    }

    /// BFS distances from `src` over the subgraph that excludes every
    /// level-2 router (`usize::MAX` if unreachable without L2 nodes).
    fn bfs_no_l2(&self, src: NodeId) -> Vec<usize> {
        self.bfs_masked(src, true, &[], &[])
    }

    /// Next-hop routing table: `table[node][core]` = neighbor of `node` on
    /// a path toward core `core` (deterministic: lowest-id neighbor that
    /// decreases the distance metric). `table[n][c] == n` when `n` *is*
    /// that core.
    ///
    /// Routing is **hierarchical** when the topology contains level-2
    /// routers: traffic whose current node sits in the destination's
    /// domain stays on the level-1 fabric (L2 nodes are never used as an
    /// intra-domain shortcut — they are scale-up ports, matching the
    /// paper), while traffic in any other domain follows full-graph
    /// shortest paths, which necessarily climb `core → L1 → L2`, ride the
    /// L2 ring, and descend. The mixed policy is loop-free: an intra-mode
    /// step strictly decreases the L2-free distance and stays intra-mode;
    /// a full-mode step strictly decreases the full distance or enters
    /// intra-mode, which it never leaves.
    pub fn next_hop_table(&self) -> Vec<Vec<NodeId>> {
        self.next_hop_table_masked(&[], &[])
    }

    /// [`Topology::next_hop_table`] over the **alive subgraph**: routes
    /// avoid `node_dead` nodes and `dead_links` (normalized `(min, max)`
    /// pairs, sorted ascending). Same hierarchical policy and the same
    /// lowest-id tie-break, so with empty masks the result is identical
    /// to the pristine table — the fault-injection subsystem's
    /// "no-fault is bit-identical" contract rests on that. Entries from
    /// dead nodes, and toward cores severed from the alive component,
    /// stay `usize::MAX` (the simulator drops such flits).
    pub fn next_hop_table_masked(
        &self,
        node_dead: &[bool],
        dead_links: &[(NodeId, NodeId)],
    ) -> Vec<Vec<NodeId>> {
        debug_assert!(
            dead_links.windows(2).all(|w| w[0] < w[1]),
            "dead links must be sorted"
        );
        let dead = |n: NodeId| node_dead.get(n).copied().unwrap_or(false);
        let has_l2 = self
            .nodes
            .iter()
            .any(|k| matches!(k, NodeKind::RouterL2(_)));
        let mut table = vec![vec![usize::MAX; self.cores.len()]; self.len()];
        for (ci, &cnode) in self.cores.iter().enumerate() {
            let d_full = self.bfs_masked(cnode, false, node_dead, dead_links);
            let d_intra = if has_l2 {
                Some(self.bfs_masked(cnode, true, node_dead, dead_links))
            } else {
                None
            };
            let dst_dom = self.domain[cnode];
            for n in 0..self.len() {
                if n == cnode {
                    table[n][ci] = n;
                    continue;
                }
                if dead(n) {
                    continue;
                }
                let dist: &[usize] = match &d_intra {
                    Some(di)
                        if self.domain[n] == dst_dom
                            && !matches!(self.nodes[n], NodeKind::RouterL2(_))
                            && di[n] != usize::MAX =>
                    {
                        di
                    }
                    _ => &d_full,
                };
                if dist[n] == usize::MAX {
                    continue;
                }
                // lowest-id neighbor strictly closer to the destination
                // (a masked-BFS distance is finite only for alive nodes,
                // but the direct link must also be alive).
                let mut best = usize::MAX;
                for &v in &self.adj[n] {
                    if dist[v] != usize::MAX
                        && dist[v] + 1 == dist[n]
                        && !link_is_dead(dead_links, n, v)
                        && v < best
                    {
                        best = v;
                    }
                }
                table[n][ci] = best;
            }
        }
        table
    }

    /// Precomputed output-port routing table: `table[node][core]` is the
    /// index of `node`'s output port toward core `core` under the
    /// [`Topology::next_hop_table`] policy — the simulator's per-flit
    /// routing becomes a single indexed load instead of a linear
    /// `neighbors().position()` scan. When `node` *is* that core the entry
    /// is the **local port** (`neighbors(node).len()`); unreachable pairs
    /// hold [`NO_PORT`].
    pub fn out_port_table(&self) -> Vec<Vec<u16>> {
        self.out_port_table_masked(&[], &[])
    }

    /// [`Topology::out_port_table`] over the alive subgraph (see
    /// [`Topology::next_hop_table_masked`]). Unroutable entries — dead
    /// source node, destination core cut off — hold [`NO_PORT`].
    pub fn out_port_table_masked(
        &self,
        node_dead: &[bool],
        dead_links: &[(NodeId, NodeId)],
    ) -> Vec<Vec<u16>> {
        let next_hop = self.next_hop_table_masked(node_dead, dead_links);
        let mut table = vec![vec![NO_PORT; self.cores.len()]; self.len()];
        for n in 0..self.len() {
            for (ci, &cnode) in self.cores.iter().enumerate() {
                if n == cnode {
                    table[n][ci] = self.adj[n].len() as u16;
                    continue;
                }
                let nh = next_hop[n][ci];
                if nh == usize::MAX {
                    continue;
                }
                let p = self
                    .adj[n]
                    .iter()
                    .position(|&x| x == nh)
                    .expect("next hop must be a neighbor");
                table[n][ci] = p as u16;
            }
        }
        table
    }

    /// Reverse port map: `table[node][port]` is the port index *at the
    /// neighbor on that port* that points back to `node` — the link stage
    /// delivers a flit into the right input FIFO without searching the
    /// neighbor's port list.
    pub fn back_port_table(&self) -> Vec<Vec<u16>> {
        (0..self.len())
            .map(|n| {
                self.adj[n]
                    .iter()
                    .map(|&nb| {
                        self.adj[nb]
                            .iter()
                            .position(|&x| x == n)
                            .expect("links are symmetric") as u16
                    })
                    .collect()
            })
            .collect()
    }

    /// Validate basic invariants (connected, no isolated cores).
    pub fn validate(&self) -> Result<()> {
        if self.cores.is_empty() {
            return Err(Error::Noc(format!("{}: no cores", self.name)));
        }
        let dist = self.bfs(0);
        if dist.iter().any(|&d| d == usize::MAX) {
            return Err(Error::Noc(format!("{}: not connected", self.name)));
        }
        Ok(())
    }

    // ======================= builders =====================================

    /// The fullerene-like level-1 domain: 12 level-1 routers at
    /// icosahedron vertices, 20 cores at its faces; router↔core links on
    /// face incidence (each router serves 5 cores, each core reaches 3
    /// routers). 32 nodes, 60 edges, average degree 3.75, variance 0.9375.
    pub fn fullerene() -> Topology {
        let (faces, _) = icosahedron();
        let mut t = Topology::new("fullerene");
        let routers: Vec<NodeId> = (0..12)
            .map(|i| t.add_node(NodeKind::RouterL1(i as u8)))
            .collect();
        for (ci, face) in faces.iter().enumerate() {
            let core = t.add_node(NodeKind::Core(ci as u8));
            for &v in face {
                t.add_edge(core, routers[v]);
            }
        }
        t
    }

    /// Fullerene domain plus the central level-2 router linked to all 12
    /// level-1 routers (the paper's scale-up point).
    pub fn fullerene_with_l2() -> Topology {
        let mut t = Self::fullerene();
        t.name = "fullerene+l2".into();
        let l2 = t.add_node(NodeKind::RouterL2(0));
        let routers: Vec<NodeId> = (0..t.len() - 1)
            .filter(|&n| matches!(t.nodes[n], NodeKind::RouterL1(_)))
            .collect();
        for r in routers {
            t.add_edge(l2, r);
        }
        t
    }

    /// A multi-domain system as a *real* graph (cycle-simulatable, not
    /// just the analytic [`crate::noc::multilevel`] model): `domains`
    /// fullerene domains, each with its level-2 centre router, the L2
    /// routers joined in a ring (the paper's off-chip extension). Global
    /// core ids are `domain * 20 + local`.
    pub fn multi_domain(domains: usize) -> Topology {
        assert!((1..=256).contains(&domains));
        let (faces, _) = icosahedron();
        let mut t = Topology::new(&format!("fullerene-x{domains}"));
        t.domains = domains;
        let mut l2s = Vec::with_capacity(domains);
        for d in 0..domains {
            let dom = d as u32;
            let routers: Vec<NodeId> = (0..12)
                .map(|i| t.add_node_dom(NodeKind::RouterL1(i as u8), dom))
                .collect();
            for (ci, face) in faces.iter().enumerate() {
                let core = t.add_node_dom(NodeKind::Core(ci as u8), dom);
                for &v in face {
                    t.add_edge(core, routers[v]);
                }
            }
            let l2 = t.add_node_dom(NodeKind::RouterL2(d as u8), dom);
            for &r in &routers {
                t.add_edge(l2, r);
            }
            l2s.push(l2);
        }
        // L2 ring (only when more than one domain; 2 domains = one link).
        for d in 0..domains {
            let a = l2s[d];
            let b = l2s[(d + 1) % domains];
            if a != b && !t.adj[a].contains(&b) {
                t.add_edge(a, b);
            }
        }
        t
    }

    /// 2D mesh of `rows × cols` routers, one core attached to each router.
    pub fn mesh2d(rows: usize, cols: usize) -> Topology {
        let mut t = Topology::new(&format!("mesh-{rows}x{cols}"));
        let mut r = vec![vec![0usize; cols]; rows];
        for (i, row) in r.iter_mut().enumerate() {
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = t.add_node(NodeKind::RouterL1((i * cols + j) as u8));
            }
        }
        for i in 0..rows {
            for j in 0..cols {
                if j + 1 < cols {
                    t.add_edge(r[i][j], r[i][j + 1]);
                }
                if i + 1 < rows {
                    t.add_edge(r[i][j], r[i + 1][j]);
                }
            }
        }
        for (ci, &router) in r.iter().flatten().enumerate() {
            let core = t.add_node(NodeKind::Core(ci as u8));
            t.add_edge(core, router);
        }
        t
    }

    /// 2D torus (mesh with wraparound links), one core per router.
    pub fn torus(rows: usize, cols: usize) -> Topology {
        let mut t = Topology::new(&format!("torus-{rows}x{cols}"));
        let mut r = vec![vec![0usize; cols]; rows];
        for (i, row) in r.iter_mut().enumerate() {
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = t.add_node(NodeKind::RouterL1((i * cols + j) as u8));
            }
        }
        for i in 0..rows {
            for j in 0..cols {
                let right = r[i][(j + 1) % cols];
                let down = r[(i + 1) % rows][j];
                if cols > 1 && !t.adj[r[i][j]].contains(&right) {
                    t.add_edge(r[i][j], right);
                }
                if rows > 1 && !t.adj[r[i][j]].contains(&down) {
                    t.add_edge(r[i][j], down);
                }
            }
        }
        for (ci, &router) in r.iter().flatten().enumerate() {
            let core = t.add_node(NodeKind::Core(ci as u8));
            t.add_edge(core, router);
        }
        t
    }

    /// Ring of `n` routers, one core per router.
    pub fn ring(n: usize) -> Topology {
        let mut t = Topology::new(&format!("ring-{n}"));
        let routers: Vec<NodeId> = (0..n)
            .map(|i| t.add_node(NodeKind::RouterL1(i as u8)))
            .collect();
        for i in 0..n {
            if n > 2 || i + 1 < n {
                let a = routers[i];
                let b = routers[(i + 1) % n];
                if !t.adj[a].contains(&b) {
                    t.add_edge(a, b);
                }
            }
        }
        for (ci, &router) in routers.iter().enumerate() {
            let core = t.add_node(NodeKind::Core(ci as u8));
            t.add_edge(core, router);
        }
        t
    }

    /// `arity`-ary tree with `n_cores` leaf routers (core attached to each
    /// leaf), internal routers above them up to a single root — the
    /// tree-NoC baseline of the comparison table.
    pub fn tree(arity: usize, n_cores: usize) -> Topology {
        assert!(arity >= 2);
        let mut t = Topology::new(&format!("tree-a{arity}-{n_cores}"));
        // Build level by level, bottom-up.
        let mut level: Vec<NodeId> = (0..n_cores)
            .map(|i| t.add_node(NodeKind::RouterL1(i as u8)))
            .collect();
        for (ci, &leaf) in level.clone().iter().enumerate() {
            let core = t.add_node(NodeKind::Core(ci as u8));
            t.add_edge(core, leaf);
        }
        let mut rid = n_cores;
        while level.len() > 1 {
            let mut next = Vec::new();
            for chunk in level.chunks(arity) {
                let parent = t.add_node(NodeKind::RouterL1((rid % 256) as u8));
                rid += 1;
                for &c in chunk {
                    t.add_edge(parent, c);
                }
                next.push(parent);
            }
            level = next;
        }
        t
    }
}

/// Membership test over a sorted, normalized (`a < b`) dead-link list.
fn link_is_dead(dead_links: &[(NodeId, NodeId)], a: NodeId, b: NodeId) -> bool {
    if dead_links.is_empty() {
        return false;
    }
    let key = if a < b { (a, b) } else { (b, a) };
    dead_links.binary_search(&key).is_ok()
}

/// Icosahedron combinatorics: returns (20 faces as vertex triples, 30
/// edges as vertex pairs) over vertices 0..12.
///
/// Built from the golden-ratio coordinates (0, ±1, ±φ) cyclic; edges are
/// the 30 closest pairs (length 2), faces the 20 mutually-adjacent
/// triangles. Pure integer output, checked by construction.
pub fn icosahedron() -> (Vec<[usize; 3]>, Vec<(usize, usize)>) {
    let phi = (1.0 + 5.0_f64.sqrt()) / 2.0;
    let mut v: Vec<[f64; 3]> = Vec::with_capacity(12);
    for &s1 in &[1.0, -1.0] {
        for &s2 in &[1.0, -1.0] {
            v.push([0.0, s1, s2 * phi]);
            v.push([s1, s2 * phi, 0.0]);
            v.push([s1 * phi, 0.0, s2]);
        }
    }
    debug_assert_eq!(v.len(), 12);
    let d2 = |a: &[f64; 3], b: &[f64; 3]| -> f64 {
        (a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2) + (a[2] - b[2]).powi(2)
    };
    // Edge length² = 4 (pairs at distance 2); everything else is farther.
    let mut edges = Vec::new();
    let mut adj = vec![[false; 12]; 12];
    for i in 0..12 {
        for j in i + 1..12 {
            if d2(&v[i], &v[j]) < 4.5 {
                edges.push((i, j));
                adj[i][j] = true;
                adj[j][i] = true;
            }
        }
    }
    assert_eq!(edges.len(), 30, "icosahedron must have 30 edges");
    let mut faces = Vec::new();
    for i in 0..12 {
        for j in i + 1..12 {
            if !adj[i][j] {
                continue;
            }
            for k in j + 1..12 {
                if adj[i][k] && adj[j][k] {
                    faces.push([i, j, k]);
                }
            }
        }
    }
    assert_eq!(faces.len(), 20, "icosahedron must have 20 faces");
    (faces, edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn icosahedron_combinatorics() {
        let (faces, edges) = icosahedron();
        assert_eq!(faces.len(), 20);
        assert_eq!(edges.len(), 30);
        // Every vertex belongs to exactly 5 faces and 5 edges.
        for v in 0..12 {
            let f = faces.iter().filter(|f| f.contains(&v)).count();
            let e = edges.iter().filter(|(a, b)| *a == v || *b == v).count();
            assert_eq!((f, e), (5, 5), "vertex {v}");
        }
    }

    #[test]
    fn fullerene_has_paper_published_shape() {
        let t = Topology::fullerene();
        t.validate().unwrap();
        assert_eq!(t.len(), 32);
        assert_eq!(t.cores().len(), 20);
        assert_eq!(t.edge_count(), 60);
        // Cores have degree 3, routers degree 5.
        for n in 0..t.len() {
            let deg = t.neighbors(n).len();
            match t.kind(n) {
                NodeKind::Core(_) => assert_eq!(deg, 3),
                NodeKind::RouterL1(_) => assert_eq!(deg, 5),
                NodeKind::RouterL2(_) => unreachable!(),
            }
        }
    }

    #[test]
    fn fullerene_l2_center_connects_all_routers() {
        let t = Topology::fullerene_with_l2();
        t.validate().unwrap();
        assert_eq!(t.len(), 33);
        let l2 = (0..t.len())
            .find(|&n| matches!(t.kind(n), NodeKind::RouterL2(_)))
            .unwrap();
        assert_eq!(t.neighbors(l2).len(), 12);
    }

    #[test]
    fn mesh_torus_ring_tree_validate() {
        for t in [
            Topology::mesh2d(4, 5),
            Topology::torus(4, 5),
            Topology::ring(20),
            Topology::tree(4, 20),
        ] {
            t.validate().unwrap();
            assert_eq!(t.cores().len(), 20, "{}", t.name);
        }
    }

    #[test]
    fn torus_wrap_links_increase_degree() {
        let m = Topology::mesh2d(4, 5);
        let t = Topology::torus(4, 5);
        assert!(t.edge_count() > m.edge_count());
    }

    #[test]
    fn next_hop_routes_toward_destination() {
        let t = Topology::fullerene();
        let table = t.next_hop_table();
        // From any node, following next hops reaches the core.
        for (ci, &cnode) in t.cores().iter().enumerate() {
            for start in 0..t.len() {
                let mut cur = start;
                let mut hops = 0;
                while cur != cnode {
                    cur = table[cur][ci];
                    hops += 1;
                    assert!(hops <= t.len(), "routing loop from {start} to core {ci}");
                }
            }
        }
    }

    #[test]
    fn out_port_table_agrees_with_next_hop_table() {
        for t in [
            Topology::fullerene(),
            Topology::mesh2d(4, 5),
            Topology::ring(20),
            Topology::multi_domain(2),
        ] {
            let nh = t.next_hop_table();
            let ports = t.out_port_table();
            for n in 0..t.len() {
                for (ci, &cnode) in t.cores().iter().enumerate() {
                    let p = ports[n][ci];
                    if n == cnode {
                        assert_eq!(p as usize, t.neighbors(n).len(), "{}: local", t.name);
                    } else if nh[n][ci] == usize::MAX {
                        assert_eq!(p, NO_PORT, "{}", t.name);
                    } else {
                        assert_eq!(t.neighbors(n)[p as usize], nh[n][ci], "{}", t.name);
                    }
                }
            }
        }
    }

    #[test]
    fn back_port_table_inverts_every_link() {
        for t in [Topology::fullerene(), Topology::multi_domain(3)] {
            let back = t.back_port_table();
            for n in 0..t.len() {
                for (p, &nb) in t.neighbors(n).iter().enumerate() {
                    let q = back[n][p] as usize;
                    assert_eq!(t.neighbors(nb)[q], n, "{}: {n} port {p}", t.name);
                }
            }
        }
    }

    #[test]
    fn multi_domain_graph_shape() {
        let t = Topology::multi_domain(3);
        t.validate().unwrap();
        assert_eq!(t.cores().len(), 60);
        // 3 × (32 + 1 L2) nodes.
        assert_eq!(t.len(), 99);
        // Edges: 3 × (60 core links + 12 L2 links) + 3 ring links.
        assert_eq!(t.edge_count(), 3 * 72 + 3);
        // Every L2 router: 12 domain links + 2 ring links.
        for n in 0..t.len() {
            if matches!(t.kind(n), NodeKind::RouterL2(_)) {
                assert_eq!(t.neighbors(n).len(), 14);
            }
        }
    }

    #[test]
    fn multi_domain_routes_across_domains() {
        let t = Topology::multi_domain(2);
        let table = t.next_hop_table();
        // From core 0 (domain 0) to core 25 (domain 1): follow hops.
        let src = t.core_node(0);
        let dst = t.core_node(25);
        let mut cur = src;
        let mut hops = 0;
        while cur != dst {
            cur = table[cur][25];
            hops += 1;
            assert!(hops < 50, "routing loop");
        }
        // Path must pass through at least one L2 router.
        assert!(t.bfs(src)[dst] >= 5, "cross-domain path too short");
    }

    /// Follow `table` from `src` node to core id `dst_core`; returns the
    /// node path (panics on a routing loop).
    fn walk(t: &Topology, table: &[Vec<NodeId>], src: NodeId, dst_core: usize) -> Vec<NodeId> {
        let dst = t.core_node(dst_core);
        let mut path = vec![src];
        let mut cur = src;
        while cur != dst {
            cur = table[cur][dst_core];
            assert_ne!(cur, usize::MAX, "unroutable");
            path.push(cur);
            assert!(path.len() <= t.len() + 2, "routing loop");
        }
        path
    }

    #[test]
    fn intra_domain_routing_never_uses_l2() {
        let t = Topology::multi_domain(3);
        let table = t.next_hop_table();
        for d in 0..3 {
            for dst in 1..20 {
                let path = walk(&t, &table, t.core_node(d * 20), d * 20 + dst);
                for &n in &path {
                    assert!(
                        !matches!(t.kind(n), NodeKind::RouterL2(_)),
                        "intra-domain path used an L2 router"
                    );
                    assert_eq!(t.domain_of(n), d as u32, "intra path left its domain");
                }
            }
        }
    }

    #[test]
    fn cross_domain_routing_climbs_rides_ring_descends() {
        let t = Topology::multi_domain(4);
        let table = t.next_hop_table();
        for (src_d, dst_d) in [(0usize, 1usize), (0, 2), (3, 1)] {
            let ring = {
                let d = src_d.abs_diff(dst_d);
                d.min(4 - d)
            };
            let path = walk(&t, &table, t.core_node(src_d * 20 + 3), dst_d * 20 + 7);
            let l2s_on_path = path
                .iter()
                .filter(|&&n| matches!(t.kind(n), NodeKind::RouterL2(_)))
                .count();
            // Climb visits the source L2, the ring visits ring-1
            // intermediates, the descend enters through the destination L2.
            assert_eq!(l2s_on_path, ring + 1, "{src_d}->{dst_d}");
            let router_hops = path.iter().filter(|&&n| t.kind(n).is_router()).count();
            assert_eq!(router_hops, ring + 3, "{src_d}->{dst_d}");
        }
    }

    #[test]
    fn domain_tags_cover_all_nodes() {
        let t = Topology::multi_domain(3);
        assert_eq!(t.n_domains(), 3);
        for d in 0..3u32 {
            let n = (0..t.len()).filter(|&n| t.domain_of(n) == d).count();
            assert_eq!(n, 33, "domain {d}");
        }
        assert_eq!(Topology::fullerene().n_domains(), 1);
    }

    #[test]
    fn single_domain_multi_equals_fullerene_with_l2() {
        let m = Topology::multi_domain(1);
        let f = Topology::fullerene_with_l2();
        assert_eq!(m.len(), f.len());
        assert_eq!(m.edge_count(), f.edge_count());
    }

    #[test]
    fn masked_tables_with_empty_masks_equal_pristine() {
        for t in [
            Topology::fullerene(),
            Topology::mesh2d(4, 5),
            Topology::ring(20),
            Topology::multi_domain(2),
        ] {
            assert_eq!(t.next_hop_table(), t.next_hop_table_masked(&[], &[]), "{}", t.name);
            assert_eq!(t.out_port_table(), t.out_port_table_masked(&[], &[]), "{}", t.name);
        }
    }

    #[test]
    fn fullerene_reroutes_around_any_single_dead_router() {
        // Every core attaches to 3 routers, so killing any one router
        // leaves every core pair routable — the decentralization claim.
        let t = Topology::fullerene();
        for r in t.routers() {
            let mut dead = vec![false; t.len()];
            dead[r] = true;
            let table = t.next_hop_table_masked(&dead, &[]);
            for (ci, &cnode) in t.cores().iter().enumerate() {
                for n in 0..t.len() {
                    if n == r {
                        continue;
                    }
                    let mut cur = n;
                    let mut hops = 0;
                    while cur != cnode {
                        cur = table[cur][ci];
                        assert_ne!(cur, usize::MAX, "router {r} cut core {ci} off");
                        assert_ne!(cur, r, "route used the dead router {r}");
                        hops += 1;
                        assert!(hops <= t.len(), "routing loop around dead router {r}");
                    }
                }
            }
        }
    }

    #[test]
    fn mesh_single_dead_router_strands_its_core() {
        // Mesh cores hang off exactly one router (degree 1): killing that
        // router makes the core unreachable — the structural contrast the
        // resilience sweep measures.
        let t = Topology::mesh2d(4, 5);
        let core0 = t.core_node(0);
        let router = t.neighbors(core0)[0];
        let mut dead = vec![false; t.len()];
        dead[router] = true;
        let table = t.out_port_table_masked(&dead, &[]);
        let far = t.core_node(19);
        assert_eq!(table[far][0], NO_PORT, "stranded core still routable");
    }

    #[test]
    fn dead_link_is_avoided_by_masked_routes() {
        let t = Topology::fullerene();
        let c0 = t.core_node(0);
        let r = t.neighbors(c0)[0];
        let cut = if c0 < r { (c0, r) } else { (r, c0) };
        let nh = t.next_hop_table_masked(&[], &[cut]);
        // Core 0 still reaches every core, never over the cut link.
        for ci in 1..20 {
            let mut cur = c0;
            let mut hops = 0;
            loop {
                let next = nh[cur][ci];
                assert_ne!(next, usize::MAX, "link cut severed core {ci}");
                assert!(
                    !(cur == c0 && next == r),
                    "route used the dead link {c0}-{r}"
                );
                cur = next;
                if cur == t.core_node(ci) {
                    break;
                }
                hops += 1;
                assert!(hops <= t.len(), "routing loop");
            }
        }
    }

    #[test]
    fn bfs_distances_sane() {
        let t = Topology::ring(6);
        let c0 = t.core_node(0);
        let c3 = t.core_node(3);
        // core0 → router0 → r1 → r2 → r3 → core3 = 5 hops.
        assert_eq!(t.bfs(c0)[c3], 5);
    }
}
