//! Cross-module integration: the full SoC (cores + NoC + CPU + DMA) must
//! compute exactly the network function defined by `NetworkDesc::reference_run`
//! across mapping splits, fabric choices and CPU involvement.

use fullerene_soc::core::neuron::{LeakMode, NeuronParams, ResetMode};
use fullerene_soc::core::Codebook;
use fullerene_soc::datasets::{Sample, Workload};
use fullerene_soc::nn::network::{LayerDesc, NetworkDesc};
use fullerene_soc::soc::{Soc, SocConfig};
use fullerene_soc::util::prng::Rng;

fn random_net(seed: u64, inputs: usize, hidden: usize, classes: usize, t: usize) -> NetworkDesc {
    let mut rng = Rng::new(seed);
    let cb = Codebook::default_log16();
    let params = NeuronParams {
        threshold: 50,
        leak: LeakMode::Linear(1),
        reset: ResetMode::Subtract,
        mp_bits: 16,
    };
    let mut widx = |n: usize| -> Vec<u8> {
        (0..n)
            .map(|_| {
                if rng.bool(0.15) {
                    255 // pruned
                } else {
                    rng.below(16) as u8
                }
            })
            .collect()
    };
    NetworkDesc {
        name: format!("itest-{seed}"),
        layers: vec![
            LayerDesc {
                name: "h".into(),
                inputs,
                neurons: hidden,
                codebook: cb.clone(),
                widx: widx(inputs * hidden),
                neuron_params: params.clone(),
            },
            LayerDesc {
                name: "o".into(),
                inputs: hidden,
                neurons: classes,
                codebook: cb,
                widx: widx(hidden * classes),
                neuron_params: params,
            },
        ],
        timesteps: t,
        classes,
    }
}

fn random_sample(seed: u64, inputs: usize, t: usize, density: f64) -> Sample {
    let mut rng = Rng::new(seed);
    let mut events = Vec::new();
    for ts in 0..t {
        for a in 0..inputs {
            if rng.bool(density) {
                events.push((ts as u16, a as u32));
            }
        }
    }
    Sample { label: 0, events }
}

#[test]
fn soc_equals_reference_across_configs() {
    for (seed, max_npc, use_noc, drive_cpu) in [
        (1u64, 64usize, true, true),
        (2, 7, true, false), // awkward split, no CPU
        (3, 64, false, true),
        (4, 13, false, false),
    ] {
        let net = random_net(seed, 40, 28, 5, 6);
        let sample = random_sample(seed * 100, 40, 6, 0.25);
        let raster = sample.to_raster(6, 40);
        let expect = net.reference_run(&raster);
        let mut soc = Soc::new(
            net,
            SocConfig {
                max_neurons_per_core: max_npc,
                use_noc,
                drive_cpu,
                ..SocConfig::default()
            },
        )
        .unwrap();
        let got = soc.run_sample(&sample, true).unwrap();
        assert_eq!(
            got.counts, expect,
            "divergence at seed={seed} split={max_npc} noc={use_noc} cpu={drive_cpu}"
        );
    }
}

#[test]
fn multi_sample_runs_are_independent() {
    // Running A then B must give B the same result as running B alone
    // (state fully reset between inferences).
    let net = random_net(9, 32, 20, 4, 5);
    let a = random_sample(900, 32, 5, 0.3);
    let b = random_sample(901, 32, 5, 0.3);
    let cfg = SocConfig {
        max_neurons_per_core: 16,
        ..SocConfig::default()
    };
    let mut soc = Soc::new(net.clone(), cfg.clone()).unwrap();
    soc.run_sample(&a, true).unwrap();
    let b_after_a = soc.run_sample(&b, true).unwrap();
    let mut fresh = Soc::new(net, cfg).unwrap();
    let b_alone = fresh.run_sample(&b, true).unwrap();
    assert_eq!(b_after_a.counts, b_alone.counts);
}

#[test]
fn three_layer_network_works() {
    let cb = Codebook::default_log16();
    let params = NeuronParams {
        threshold: 30,
        leak: LeakMode::None,
        reset: ResetMode::Subtract,
        mp_bits: 16,
    };
    let mk = |inputs: usize, n: usize, salt: usize| LayerDesc {
        name: format!("l{salt}"),
        inputs,
        neurons: n,
        codebook: cb.clone(),
        widx: (0..inputs * n).map(|i| ((i * 11 + salt) % 16) as u8).collect(),
        neuron_params: params.clone(),
    };
    let net = NetworkDesc {
        name: "deep".into(),
        layers: vec![mk(24, 18, 1), mk(18, 12, 2), mk(12, 4, 3)],
        timesteps: 5,
        classes: 4,
    };
    let sample = random_sample(77, 24, 5, 0.4);
    let expect = net.reference_run(&sample.to_raster(5, 24));
    let mut soc = Soc::new(
        net,
        SocConfig {
            max_neurons_per_core: 7,
            ..SocConfig::default()
        },
    )
    .unwrap();
    let got = soc.run_sample(&sample, true).unwrap();
    assert_eq!(got.counts, expect);
}

#[test]
fn full_workload_dataset_end_to_end() {
    // NMNIST-geometry dataset through a thin network on the full chip.
    let net = random_net(5, Workload::Nmnist.inputs(), 48, 10, 20);
    let ds = Workload::Nmnist.generate(3, 42);
    let mut soc = Soc::new(net.clone(), SocConfig::default()).unwrap();
    let out = soc.run_dataset(&ds, 3).unwrap();
    assert!((0.0..=1.0).contains(&out.accuracy));
    assert_eq!(out.samples, 3);
    assert!(out.sops > 0 && out.cycles > 0);
    let rep = soc.finish_report("nmnist-itest");
    assert!(rep.sops > 0);
    assert!(rep.power_mw > 0.0 && rep.power_mw < 200.0, "power {}", rep.power_mw);
    assert!(rep.pj_per_sop > 0.1 && rep.pj_per_sop < 100.0, "pJ/SOP {}", rep.pj_per_sop);
}

#[test]
fn energy_scales_with_voltage() {
    let net = random_net(6, 32, 20, 4, 5);
    let s = random_sample(600, 32, 5, 0.3);
    let run_at = |v: f64| {
        let mut soc = Soc::new(
            net.clone(),
            SocConfig {
                supply_v: v,
                max_neurons_per_core: 16,
                ..SocConfig::default()
            },
        )
        .unwrap();
        soc.run_sample(&s, true).unwrap();
        soc.finish_report("v-sweep").pj_per_sop
    };
    let lo = run_at(1.08);
    let hi = run_at(1.32);
    assert!(hi > lo * 1.2, "voltage scaling missing: {lo} vs {hi}");
}
