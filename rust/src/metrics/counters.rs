//! Generic named counters used by subsystems for non-energy telemetry
//! (stalls, buffer occupancy peaks, retries, …).


use std::collections::BTreeMap;

/// A set of named monotonically increasing counters plus gauges.
#[derive(Debug, Clone, Default)]
pub struct Counters {
    counts: BTreeMap<String, u64>,
    maxima: BTreeMap<String, u64>,
}

impl Counters {
    /// Empty counter set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment counter `name` by `n`.
    #[inline]
    pub fn inc(&mut self, name: &str, n: u64) {
        *self.counts.entry(name.to_string()).or_insert(0) += n;
    }

    /// Record a high-watermark gauge value (keeps the max seen).
    #[inline]
    pub fn high_water(&mut self, name: &str, v: u64) {
        let e = self.maxima.entry(name.to_string()).or_insert(0);
        *e = (*e).max(v);
    }

    /// Read a counter (0 when never incremented).
    pub fn get(&self, name: &str) -> u64 {
        self.counts.get(name).copied().unwrap_or(0)
    }

    /// Read a high-watermark gauge.
    pub fn max_of(&self, name: &str) -> u64 {
        self.maxima.get(name).copied().unwrap_or(0)
    }

    /// Merge another counter set into this one.
    pub fn merge(&mut self, other: &Counters) {
        for (k, v) in &other.counts {
            *self.counts.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.maxima {
            let e = self.maxima.entry(k.clone()).or_insert(0);
            *e = (*e).max(*v);
        }
    }

    /// Iterate counters (sorted by name).
    pub fn iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counts.iter().map(|(k, v)| (k.as_str(), *v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inc_get_merge() {
        let mut a = Counters::new();
        a.inc("stalls", 3);
        a.high_water("occ", 5);
        let mut b = Counters::new();
        b.inc("stalls", 2);
        b.high_water("occ", 4);
        a.merge(&b);
        assert_eq!(a.get("stalls"), 5);
        assert_eq!(a.max_of("occ"), 5);
        assert_eq!(a.get("missing"), 0);
    }
}
