//! Self-healing serving smoke: a calibrated congestion storm catches
//! the long sessions of a mixed workload mid-run, and the same mix is
//! served twice — recovery on (simulated-cycle deadline + deterministic
//! seeded retry) vs recovery off (deadline alone). The axis guards the
//! recovery layer's reason to exist: the recovery arm must complete a
//! strictly higher session fraction than the no-recovery arm, at a
//! bounded simulated-cycle overhead.
//!
//! Emits `BENCH_recovery.json` (schema `bench-recovery-v1`) in the
//! working directory and gates against a checked-in
//! `BENCH_recovery.baseline.json` (working directory, then the
//! repository root), failing the process on a >30 % regression or a
//! structural-floor violation. Controls:
//!
//! - `FSOC_BENCH_FAST=1` — CI smoke budget;
//! - `FSOC_RECOVERY_BASELINE=<path>` — explicit baseline location;
//! - `FSOC_RECOVERY_SKIP_CHECK=1` — emit JSON only, no gate.

use fullerene_soc::benches_support::{recovery_check, recovery_json, recovery_perf, recovery_table};
use fullerene_soc::util::json::Json;
use std::path::{Path, PathBuf};

fn baseline_path() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("FSOC_RECOVERY_BASELINE") {
        return Some(PathBuf::from(p));
    }
    for p in [
        "BENCH_recovery.baseline.json",
        "../BENCH_recovery.baseline.json",
    ] {
        let p = Path::new(p);
        if p.exists() {
            return Some(p.to_path_buf());
        }
    }
    None
}

fn main() {
    let fast = std::env::var("FSOC_BENCH_FAST").is_ok_and(|v| v == "1");
    let r = recovery_perf(42, fast).expect("recovery bench must serve");

    println!("## bench: recovery\n{}", recovery_table(&r).render());
    println!(
        "storm: every router congested for {} cycles at cycle {}; deadline {} cycles; \
         recovery overhead {:.4} of the clean-run cycles",
        r.storm_window, r.storm_at_cycle, r.deadline_cycles, r.recovery_overhead_frac
    );

    let out = Path::new("BENCH_recovery.json");
    recovery_json(&r, "measured")
        .write_file(out)
        .expect("write BENCH_recovery.json");
    println!("wrote {}", out.display());

    if std::env::var("FSOC_RECOVERY_SKIP_CHECK").is_ok_and(|v| v == "1") {
        println!("baseline check skipped (FSOC_RECOVERY_SKIP_CHECK=1)");
        return;
    }
    match baseline_path() {
        None => {
            // The structural floors hold without any baseline — enforce
            // them with an empty one rather than skipping outright.
            let fails = recovery_check(&r, &Json::obj(vec![]), 0.30);
            if fails.is_empty() {
                println!("no BENCH_recovery.baseline.json found; structural floors passed");
            } else {
                eprintln!("RECOVERY FLOOR VIOLATION:");
                for f in &fails {
                    eprintln!("  - {f}");
                }
                std::process::exit(1);
            }
        }
        Some(p) => {
            let baseline = Json::read_file(&p).expect("parse baseline");
            let fails = recovery_check(&r, &baseline, 0.30);
            if fails.is_empty() {
                println!("baseline check vs {} passed", p.display());
            } else {
                eprintln!("RECOVERY REGRESSION vs {}:", p.display());
                for f in &fails {
                    eprintln!("  - {f}");
                }
                std::process::exit(1);
            }
        }
    }
}
