//! Cycle-driven NoC simulator: one [`CmRouter`] switch per topology node
//! (routers *and* core NoC interfaces), shortest-path routing from the
//! precomputed next-hop table, bounded FIFOs with backpressure, and
//! energy/latency accounting (Fig. 5c).
//!
//! Each node's switch gets one port per neighbor plus a **local port**:
//! injection enqueues into the local input FIFO (arbitrating with relay
//! traffic for the node's links), ejection drains from the local output
//! FIFO. A flit's **hop count** increments on arrival at a *router* node,
//! matching the paper's hop definition; link traversals are charged
//! separately.

use super::packet::{Dest, Flit, TxMode};
use super::router::CmRouter;
use super::topology::{NodeId, NodeKind, Topology};
use crate::energy::{EnergyLedger, EnergyParams, EventClass};
use crate::{Error, Result};
use std::collections::VecDeque;

/// A delivered flit with measured latency.
#[derive(Debug, Clone)]
pub struct Delivered {
    /// The flit.
    pub flit: Flit,
    /// Cycles from injection to ejection.
    pub latency: u64,
}

/// Aggregate simulation statistics.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    /// Cycles simulated.
    pub cycles: u64,
    /// Flits delivered.
    pub delivered: u64,
    /// Mean latency (cycles).
    pub avg_latency: f64,
    /// Mean router hops per flit.
    pub avg_hops: f64,
    /// Max latency (cycles).
    pub max_latency: u64,
    /// Delivered flits per cycle (throughput).
    pub throughput: f64,
    /// Total backpressure stalls across switches.
    pub stalls_backpressure: u64,
    /// Total timestep-sync hang-ups.
    pub stalls_timestep: u64,
}

/// The NoC simulator.
pub struct NocSim {
    topo: Topology,
    next_hop: Vec<Vec<NodeId>>,
    switches: Vec<CmRouter>,
    /// Per-node local-port index (== neighbor count).
    local_port: Vec<usize>,
    /// Injection staging: flits that did not fit the local FIFO yet.
    pending: Vec<VecDeque<Flit>>,
    delivered: Vec<Delivered>,
    cycle: u64,
    next_id: u64,
    timestep: u32,
    ledger: EnergyLedger,
    energy: EnergyParams,
    in_flight: u64,
}

impl NocSim {
    /// Build a simulator over `topo` with per-port FIFO depth `depth`.
    pub fn new(topo: Topology, depth: usize, energy: EnergyParams) -> Self {
        let next_hop = topo.next_hop_table();
        let mut switches = Vec::with_capacity(topo.len());
        let mut local_port = Vec::with_capacity(topo.len());
        for n in 0..topo.len() {
            let mut ports = topo.neighbors(n).to_vec();
            local_port.push(ports.len());
            ports.push(n); // local port loops to self
            switches.push(CmRouter::new(n, &ports, depth));
        }
        let n = topo.len();
        NocSim {
            topo,
            next_hop,
            switches,
            local_port,
            pending: (0..n).map(|_| VecDeque::new()).collect(),
            delivered: Vec::new(),
            cycle: 0,
            next_id: 0,
            timestep: 0,
            ledger: EnergyLedger::new(),
            energy,
            in_flight: 0,
        }
    }

    /// The topology being simulated.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Current cycle.
    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    /// Flits injected but not yet delivered.
    pub fn in_flight(&self) -> u64 {
        self.in_flight
    }

    /// Advance the global timestep (propagates to every switch's link
    /// controller).
    pub fn set_timestep(&mut self, ts: u32) {
        self.timestep = ts;
        for s in &mut self.switches {
            s.timestep = ts;
        }
    }

    /// Clock-gate a specific router node (failure/power experiments).
    pub fn set_node_enabled(&mut self, node: NodeId, on: bool) {
        self.switches[node].enabled = on;
    }

    /// Inject spikes from `src_core` (domain-local core id) to `dest`.
    /// Broadcast destinations are split into per-destination copies
    /// carrying the cheap broadcast energy class. Returns flit ids.
    pub fn inject(&mut self, src_core: usize, dest: &Dest, axon: u32) -> Vec<u64> {
        let src_node = self.topo.core_node(src_core);
        let (mode, dsts): (TxMode, Vec<usize>) = match dest {
            Dest::Core(c) => (TxMode::P2p, vec![*c]),
            Dest::Cores(cs) => (TxMode::Broadcast, cs.clone()),
            Dest::Merge(c) => (TxMode::Merge, vec![*c]),
        };
        let mut ids = Vec::with_capacity(dsts.len());
        for dst in dsts {
            let id = self.next_id;
            self.next_id += 1;
            self.pending[src_node].push_back(Flit {
                id,
                src_core,
                dst_core: dst,
                mode,
                axon,
                timestep: self.timestep,
                injected_at: self.cycle,
                hops: 0,
                at: src_node,
            });
            self.in_flight += 1;
            ids.push(id);
        }
        ids
    }

    /// One simulation cycle: injection → arbitration → link movement →
    /// ejection.
    pub fn step(&mut self) {
        self.cycle += 1;

        // 1. Injection: move pending flits into local input FIFOs.
        for n in 0..self.switches.len() {
            let lp = self.local_port[n];
            while self.pending[n].front().is_some() {
                if self.switches[n].can_accept(lp) {
                    let f = self.pending[n].pop_front().unwrap();
                    self.switches[n].accept(lp, f);
                } else {
                    break;
                }
            }
        }

        // 2. Arbitration at every switch.
        for n in 0..self.switches.len() {
            let nh = &self.next_hop;
            let topo = &self.topo;
            let lp = self.local_port[n];
            // Copy ports mapping out of the borrow.
            let route = |f: &Flit| -> Option<usize> {
                let dst_node = topo.core_node(f.dst_core);
                if dst_node == n {
                    return Some(lp);
                }
                let next = nh[n][f.dst_core];
                if next == usize::MAX {
                    return None;
                }
                topo.neighbors(n).iter().position(|&x| x == next)
            };
            self.switches[n].arbitrate(route);
        }

        // 3. Link stage: move output heads to neighbor inputs (1 per link
        //    direction per cycle); eject local-port heads.
        for n in 0..self.switches.len() {
            let lp = self.local_port[n];
            // Hot-path early-out: nothing queued on any output.
            if self.switches[n].out_occupancy() == 0 {
                continue;
            }
            // Ejection.
            if let Some(f) = self.switches[n].out_pop(lp) {
                self.in_flight -= 1;
                self.delivered.push(Delivered {
                    latency: self.cycle - f.injected_at,
                    flit: f,
                });
            }
            // Physical links (allocation-free: borrow the adjacency slice
            // through the topology field, disjoint from `switches`).
            let n_ports = self.topo.neighbors(n).len();
            for p in 0..n_ports {
                if self.switches[n].out_head(p).is_none() {
                    continue;
                }
                let nb = self.topo.neighbors(n)[p];
                let back_port = self.switches[nb]
                    .port_to(n)
                    .expect("links are symmetric");
                if self.switches[nb].can_accept(back_port) {
                    let mut f = self.switches[n].out_pop(p).unwrap();
                    f.at = nb;
                    // Links with an L2 endpoint are the long scale-up
                    // wires; arrival at an L2 router charges the wider
                    // crossbar's hop energy instead of the mode class.
                    let nb_is_l2 = matches!(self.topo.kind(nb), NodeKind::RouterL2(_));
                    let n_is_l2 = matches!(self.topo.kind(n), NodeKind::RouterL2(_));
                    self.ledger.add1(if nb_is_l2 || n_is_l2 {
                        EventClass::LinkL2
                    } else {
                        EventClass::LinkTraversal
                    });
                    if self.topo.kind(nb).is_router() {
                        f.hops += 1;
                        self.ledger.add1(if nb_is_l2 {
                            EventClass::HopL2
                        } else {
                            match f.mode {
                                TxMode::P2p => EventClass::HopP2p,
                                TxMode::Broadcast => EventClass::HopBroadcast,
                                TxMode::Merge => EventClass::HopMerge,
                            }
                        });
                    }
                    self.switches[nb].accept(back_port, f);
                }
            }
        }
    }

    /// Run until all injected flits are delivered, or error after
    /// `max_cycles` without full drain (deadlock/livelock detection).
    pub fn run_until_drained(&mut self, max_cycles: u64) -> Result<()> {
        let start = self.cycle;
        while self.in_flight > 0 {
            if self.cycle - start >= max_cycles {
                return Err(Error::Noc(format!(
                    "NoC not drained after {max_cycles} cycles ({} in flight)",
                    self.in_flight
                )));
            }
            self.step();
        }
        Ok(())
    }

    /// Delivered flits so far.
    pub fn delivered(&self) -> &[Delivered] {
        &self.delivered
    }

    /// Aggregate statistics.
    pub fn stats(&self) -> SimStats {
        let n = self.delivered.len() as f64;
        let (mut lat, mut hops, mut maxl) = (0.0, 0.0, 0u64);
        for d in &self.delivered {
            lat += d.latency as f64;
            hops += d.flit.hops as f64;
            maxl = maxl.max(d.latency);
        }
        let (mut bp, mut ts) = (0u64, 0u64);
        for s in &self.switches {
            bp += s.stalls_backpressure;
            ts += s.stalls_timestep;
        }
        SimStats {
            cycles: self.cycle,
            delivered: self.delivered.len() as u64,
            avg_latency: if n > 0.0 { lat / n } else { 0.0 },
            avg_hops: if n > 0.0 { hops / n } else { 0.0 },
            max_latency: maxl,
            throughput: if self.cycle > 0 {
                n / self.cycle as f64
            } else {
                0.0
            },
            stalls_backpressure: bp,
            stalls_timestep: ts,
        }
    }

    /// Non-destructive ledger assembly: a copy of the accumulated dynamic
    /// ledger plus router static power over the simulated window so far.
    /// Level-2 routers carry their own (larger) static power class. The
    /// simulator state is untouched, so this can back an incremental
    /// report snapshot mid-run.
    pub fn snapshot_ledger(&self) -> EnergyLedger {
        let mut ledger = self.ledger.clone();
        for s in &self.switches {
            match self.topo.kind(s.node) {
                NodeKind::Core(_) => {}
                NodeKind::RouterL1(_) => {
                    let active = s.active_cycles.min(self.cycle);
                    ledger.add_static(
                        &format!("router{}", s.node),
                        active,
                        self.cycle - active,
                        self.energy.p_router_active,
                        self.energy.p_router_gated,
                    );
                }
                NodeKind::RouterL2(_) => {
                    let active = s.active_cycles.min(self.cycle);
                    ledger.add_static(
                        &format!("router-l2-{}", s.node),
                        active,
                        self.cycle - active,
                        self.energy.p_router_l2_active,
                        self.energy.p_router_l2_gated,
                    );
                }
            }
        }
        ledger
    }

    /// Account router static power over the simulated window and return
    /// the accumulated ledger (dynamic events + static), draining the
    /// internal dynamic ledger.
    pub fn finish_ledger(&mut self) -> EnergyLedger {
        let ledger = self.snapshot_ledger();
        self.ledger = EnergyLedger::new();
        ledger
    }

    /// Reset energy/latency accounting (dynamic ledger, per-switch
    /// activity counters, delivery log and the cycle counter) so a new
    /// measurement window starts from zero. Only valid while the fabric
    /// is drained (no flits in flight).
    pub fn reset_accounting(&mut self) {
        debug_assert_eq!(self.in_flight, 0, "reset_accounting on a busy fabric");
        self.ledger = EnergyLedger::new();
        self.delivered.clear();
        self.cycle = 0;
        for s in &mut self.switches {
            s.active_cycles = 0;
        }
    }

    /// Dynamic-only energy (pJ) of NoC activity so far.
    pub fn dynamic_pj(&self) -> f64 {
        self.ledger.dynamic_pj(&self.energy)
    }

    /// Dynamic energy per delivered flit-hop (pJ/hop) — Fig. 5c metric.
    /// Includes level-2 hops when the fabric has them.
    pub fn pj_per_hop(&self) -> Option<f64> {
        let hops: u64 = self.delivered.iter().map(|d| d.flit.hops as u64).sum();
        (hops > 0).then(|| {
            let hop_pj = self.ledger.count(EventClass::HopP2p) as f64 * self.energy.e_hop_p2p
                + self.ledger.count(EventClass::HopBroadcast) as f64 * self.energy.e_hop_bcast
                + self.ledger.count(EventClass::HopMerge) as f64 * self.energy.e_hop_merge
                + self.ledger.count(EventClass::HopL2) as f64 * self.energy.e_hop_l2;
            hop_pj / hops as f64
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(topo: Topology) -> NocSim {
        NocSim::new(topo, 4, EnergyParams::nominal())
    }

    #[test]
    fn p2p_delivery_on_fullerene() {
        let mut s = sim(Topology::fullerene());
        s.inject(0, &Dest::Core(13), 7);
        s.run_until_drained(1000).unwrap();
        let d = &s.delivered()[0];
        assert_eq!(d.flit.dst_core, 13);
        assert_eq!(d.flit.axon, 7);
        assert!(d.flit.hops >= 1);
        assert!(d.latency >= d.flit.hops as u64);
    }

    #[test]
    fn broadcast_reaches_every_destination() {
        let mut s = sim(Topology::fullerene());
        let dsts = vec![1, 5, 9, 13, 17];
        s.inject(0, &Dest::Cores(dsts.clone()), 3);
        s.run_until_drained(2000).unwrap();
        let mut got: Vec<usize> = s.delivered().iter().map(|d| d.flit.dst_core).collect();
        got.sort_unstable();
        assert_eq!(got, dsts);
        // Broadcast copies charge the cheap hop class.
        assert!(s.ledger.count(EventClass::HopBroadcast) > 0);
        assert_eq!(s.ledger.count(EventClass::HopP2p), 0);
    }

    #[test]
    fn hop_counts_match_bfs_distance_under_light_load() {
        let t = Topology::fullerene();
        let table_free = t.clone();
        let mut s = sim(t);
        for dst in 1..20 {
            s.inject(0, &Dest::Core(dst), 0);
            s.run_until_drained(1000).unwrap();
        }
        // With one flit at a time, hops = router nodes on the shortest
        // path = BFS distance / 2 (alternating core/router layers).
        let d0 = table_free.bfs(table_free.core_node(0));
        for d in s.delivered() {
            let bfs = d0[table_free.core_node(d.flit.dst_core)];
            assert_eq!(
                d.flit.hops as usize,
                bfs / 2,
                "dst {} bfs {bfs}",
                d.flit.dst_core
            );
        }
    }

    #[test]
    fn merge_mode_uses_merge_energy() {
        let mut s = sim(Topology::fullerene());
        s.inject(2, &Dest::Merge(7), 0);
        s.inject(3, &Dest::Merge(7), 1);
        s.run_until_drained(1000).unwrap();
        assert_eq!(s.delivered().len(), 2);
        assert!(s.ledger.count(EventClass::HopMerge) > 0);
    }

    #[test]
    fn timestep_desync_blocks_until_advanced() {
        let mut s = sim(Topology::fullerene());
        s.inject(0, &Dest::Core(10), 0);
        s.set_timestep(1); // switches ahead of the flit's tag
        for _ in 0..50 {
            s.step();
        }
        assert_eq!(s.delivered().len(), 0, "desynced flit must not move");
        assert!(s.stats().stalls_timestep > 0);
        s.set_timestep(0);
        s.run_until_drained(1000).unwrap();
        assert_eq!(s.delivered().len(), 1);
    }

    #[test]
    fn gated_router_detected_as_undrained() {
        let mut s = sim(Topology::ring(6));
        // Gate every router: flits can never move.
        let routers = s.topology().routers();
        for r in routers {
            s.set_node_enabled(r, false);
        }
        s.inject(0, &Dest::Core(3), 0);
        assert!(s.run_until_drained(200).is_err());
    }

    #[test]
    fn saturation_throughput_bounded_by_link_capacity() {
        let mut s = sim(Topology::fullerene());
        // Saturate: every core sends to a far core repeatedly.
        for round in 0..20 {
            for c in 0..20 {
                s.inject(c, &Dest::Core((c + 10) % 20), round);
            }
        }
        s.run_until_drained(100_000).unwrap();
        let st = s.stats();
        assert_eq!(st.delivered, 400);
        assert!(st.throughput > 0.0);
        assert!(st.avg_latency >= st.avg_hops);
    }

    #[test]
    fn pj_per_hop_matches_p2p_constant_under_pure_p2p() {
        let mut s = sim(Topology::fullerene());
        for dst in 1..20 {
            s.inject(0, &Dest::Core(dst), 0);
        }
        s.run_until_drained(10_000).unwrap();
        let pj = s.pj_per_hop().unwrap();
        assert!((pj - EnergyParams::nominal().e_hop_p2p).abs() < 1e-9);
    }

    #[test]
    fn cross_domain_flit_traverses_l2_and_charges_l2_energy() {
        let mut s = sim(Topology::multi_domain(2));
        s.inject(0, &Dest::Core(25), 4);
        s.run_until_drained(10_000).unwrap();
        assert_eq!(s.delivered().len(), 1);
        let d = &s.delivered()[0];
        // climb (L1, L2) + one ring link (L2) + descend (L1): 4 router
        // arrivals, two of them at L2 routers.
        assert_eq!(d.flit.hops, 4);
        assert_eq!(s.ledger.count(EventClass::HopL2), 2);
        // L1→L2, L2→L2 and L2→L1 wires all charge the L2 link class.
        assert_eq!(s.ledger.count(EventClass::LinkL2), 3);
        assert_eq!(s.ledger.count(EventClass::HopP2p), 2);
    }

    #[test]
    fn intra_domain_traffic_on_multidomain_charges_no_l2() {
        let mut s = sim(Topology::multi_domain(2));
        for dst in 1..20 {
            s.inject(0, &Dest::Core(dst), 0);
            s.inject(20, &Dest::Core(20 + dst), 0);
        }
        s.run_until_drained(100_000).unwrap();
        assert_eq!(s.delivered().len(), 38);
        assert_eq!(s.ledger.count(EventClass::HopL2), 0);
        assert_eq!(s.ledger.count(EventClass::LinkL2), 0);
    }

    #[test]
    fn l2_static_power_lands_in_its_own_ledger_entries() {
        let mut s = sim(Topology::multi_domain(2));
        s.inject(0, &Dest::Core(25), 0);
        s.run_until_drained(10_000).unwrap();
        let ledger = s.finish_ledger();
        let b = ledger.breakdown(&EnergyParams::nominal(), 100.0e6);
        assert!(
            b.by_static.keys().any(|k| k.starts_with("router-l2-")),
            "missing L2 static entries: {:?}",
            b.by_static.keys().collect::<Vec<_>>()
        );
    }

    #[test]
    fn mesh_delivery_works_too() {
        let mut s = sim(Topology::mesh2d(4, 5));
        s.inject(0, &Dest::Core(19), 0);
        s.run_until_drained(1000).unwrap();
        assert_eq!(s.delivered().len(), 1);
    }
}
