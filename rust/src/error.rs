//! Crate-wide error type.

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors produced by the simulator, configuration, and runtime layers.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Configuration failed validation (bad field, inconsistent sizes, …).
    #[error("config error: {0}")]
    Config(String),

    /// A network description is malformed or cannot be mapped to the chip.
    #[error("network error: {0}")]
    Network(String),

    /// The neuron→core mapper could not place the network.
    #[error("mapping error: {0}")]
    Mapping(String),

    /// NoC simulation error (unroutable packet, buffer misuse, …).
    #[error("noc error: {0}")]
    Noc(String),

    /// Neuromorphic-core simulation error.
    #[error("core error: {0}")]
    Core(String),

    /// RISC-V ISS error (illegal instruction, bus fault, …).
    #[error("riscv error: {0}")]
    Riscv(String),

    /// SoC-level error (bus, DMA, clock manager).
    #[error("soc error: {0}")]
    Soc(String),

    /// PJRT/XLA runtime error.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Artifact (HLO text / weights JSON) missing or malformed.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// JSON parse/serialize error (in-tree parser, `util::json`).
    #[error("json error: {0}")]
    Json(String),

    /// I/O error.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Shorthand constructor for configuration errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
}
