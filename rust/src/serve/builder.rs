//! Fluent chip/serving configuration with one validation choke point.
//!
//! [`SocBuilder`] unifies what used to be scattered across `SocConfig`
//! (chip geometry), `ExperimentConfig` (golden checks, limits) and
//! `RunConfig` (CLI/JSON configs): every field is set fluently and
//! **every build path validates** — JSON-loaded, CLI-flag-built and
//! hand-assembled configs all funnel through [`SocBuilder::validate`],
//! so no construction route can skip range checking anymore.

use super::pool::SocPool;
use super::recovery::RecoveryPolicy;
use super::runtime::ServeRuntime;
use super::session::Session;
use crate::cluster::{Cluster, Engine};
use crate::config::RunConfig;
use crate::coordinator::{ExperimentConfig, ExperimentRunner, GoldenCheck};
use crate::nn::NetworkDesc;
use crate::noc::{FaultPlan, Topology};
use crate::runtime::GoldenModel;
use crate::soc::{Soc, SocConfig};
use crate::{Error, Result};
use std::path::PathBuf;

/// Fluent builder for chips, sessions, pools and experiment runners.
#[derive(Debug, Clone)]
pub struct SocBuilder {
    soc: SocConfig,
    check: GoldenCheck,
    artifacts: PathBuf,
    limit: usize,
    workers: usize,
    queue_depth: usize,
    keep_warm: bool,
    recovery: RecoveryPolicy,
}

/// Default bounded submission-queue depth for serve runtimes built
/// without an explicit [`SocBuilder::queue_depth`].
pub const DEFAULT_QUEUE_DEPTH: usize = 64;
/// Upper bound on the submission-queue depth (each pending entry holds a
/// boxed workload; an unbounded queue would defeat backpressure).
pub const MAX_QUEUE_DEPTH: usize = 65_536;

impl Default for SocBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl SocBuilder {
    /// Builder at the paper's nominal operating point (20 cores, one
    /// fullerene domain, 100 MHz / 1.08 V, cycle-accurate NoC, firmware
    /// CPU), reference checking, host-parallel workers.
    pub fn new() -> Self {
        SocBuilder {
            soc: SocConfig::default(),
            check: GoldenCheck::Reference,
            artifacts: GoldenModel::artifacts_dir(),
            limit: usize::MAX,
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            queue_depth: DEFAULT_QUEUE_DEPTH,
            keep_warm: true,
            recovery: RecoveryPolicy::disabled(),
        }
    }

    /// Start from an existing chip config (e.g. CLI-flag assembled).
    pub fn from_soc_config(soc: SocConfig) -> Self {
        SocBuilder {
            soc,
            ..Self::new()
        }
    }

    /// Adopt a full [`RunConfig`] (JSON/CLI layer): chip, check mode,
    /// artifacts directory, sample limit and recovery policy.
    pub fn from_run_config(cfg: &RunConfig) -> Self {
        Self::from_soc_config(cfg.soc.clone())
            .check(cfg.check)
            .artifacts(cfg.artifacts.clone())
            .limit(cfg.workload.samples)
            .recovery(cfg.recovery)
    }

    /// The chip config assembled so far (unvalidated).
    pub fn soc_config(&self) -> &SocConfig {
        &self.soc
    }

    /// Fullerene routing domains (1 = the paper's chip).
    pub fn domains(mut self, domains: usize) -> Self {
        self.soc.domains = domains;
        self
    }

    /// Chips in the serving engine (1 = a single chip; > 1 builds a
    /// [`Cluster`] joined by the off-chip L3 router ring, and sessions
    /// opened from this builder span all of them).
    pub fn chips(mut self, chips: usize) -> Self {
        self.soc.chips = chips;
        self
    }

    /// Physical neuromorphic cores.
    pub fn n_cores(mut self, n: usize) -> Self {
        self.soc.n_cores = n;
        self
    }

    /// Max neurons per core.
    pub fn max_neurons_per_core(mut self, n: usize) -> Self {
        self.soc.max_neurons_per_core = n;
        self
    }

    /// NoC FIFO depth per port.
    pub fn fifo_depth(mut self, depth: usize) -> Self {
        self.soc.fifo_depth = depth;
        self
    }

    /// Neuromorphic-processor clock (Hz).
    pub fn f_core_hz(mut self, hz: f64) -> Self {
        self.soc.f_core_hz = hz;
        self
    }

    /// Neuromorphic-processor clock (MHz convenience).
    pub fn f_core_mhz(self, mhz: f64) -> Self {
        self.f_core_hz(mhz * 1.0e6)
    }

    /// RISC-V clock (Hz).
    pub fn f_cpu_hz(mut self, hz: f64) -> Self {
        self.soc.f_cpu_hz = hz;
        self
    }

    /// Supply voltage (V).
    pub fn supply_v(mut self, v: f64) -> Self {
        self.soc.supply_v = v;
        self
    }

    /// Cycle-accurate NoC (true) vs ideal fabric (false).
    pub fn use_noc(mut self, on: bool) -> Self {
        self.soc.use_noc = on;
        self
    }

    /// Run the RISC-V firmware protocol (false = drive cores directly).
    pub fn drive_cpu(mut self, on: bool) -> Self {
        self.soc.drive_cpu = on;
        self
    }

    /// Deterministic fabric fault schedule, armed on every chip built
    /// from this builder (resilience experiments; see
    /// [`crate::noc::fault`]). [`SocBuilder::validate`] checks the
    /// on-chip half of the plan against the configured topology and the
    /// `kill-l3`/`throttle-l3` half against the configured cluster ring,
    /// so a kill naming a core, an absent link or an out-of-range ring
    /// node fails at build time, not mid-session.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.soc.fault_plan = plan;
        self
    }

    /// Golden-check mode for runners/pools built from this builder.
    pub fn check(mut self, check: GoldenCheck) -> Self {
        self.check = check;
        self
    }

    /// Artifacts directory (XLA golden model, trained weights).
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = dir.into();
        self
    }

    /// Max samples per batch run.
    pub fn limit(mut self, limit: usize) -> Self {
        self.limit = limit;
        self
    }

    /// Worker threads for pools/runtimes built from this builder.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Bounded submission-queue depth for serve runtimes built from this
    /// builder: [`ServeRuntime::submit`] blocks (and
    /// [`ServeRuntime::try_submit`] returns [`Error::QueueFull`]) once
    /// this many sessions are queued ahead of the workers.
    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth;
        self
    }

    /// Warm engine reuse for serve runtimes built from this builder:
    /// `true` (default) re-arms each worker's engine via
    /// [`Engine::reset_for_session`] between sessions; `false` builds a
    /// fresh engine per session (the cold baseline the serve bench
    /// measures against).
    pub fn keep_warm(mut self, on: bool) -> Self {
        self.keep_warm = on;
        self
    }

    /// Install a whole [`RecoveryPolicy`] for pools/runtimes built from
    /// this builder (deadlines, deterministic retry, quarantine).
    /// Disabled by default; validated by [`SocBuilder::validate`] like
    /// every other knob.
    pub fn recovery(mut self, policy: RecoveryPolicy) -> Self {
        self.recovery = policy;
        self
    }

    /// Kill a session once its simulated core-clock cycles exceed this
    /// budget (0 = no deadline; see [`RecoveryPolicy::deadline_cycles`]).
    pub fn deadline_cycles(mut self, cycles: u64) -> Self {
        self.recovery.deadline_cycles = cycles;
        self
    }

    /// Host wall-clock watchdog per session, in milliseconds (0 = off;
    /// see [`RecoveryPolicy::deadline_wall_ms`]).
    pub fn deadline_wall_ms(mut self, ms: u64) -> Self {
        self.recovery.deadline_wall_ms = ms;
        self
    }

    /// Retry budget for failed/degraded/deadline-killed sessions (0 =
    /// never retry; see [`RecoveryPolicy::retries`]).
    pub fn retries(mut self, retries: u32) -> Self {
        self.recovery.retries = retries;
        self
    }

    /// Base simulated-cycle backoff before the first retry, doubling per
    /// attempt (see [`RecoveryPolicy::backoff_cycles`]).
    pub fn backoff_cycles(mut self, cycles: u64) -> Self {
        self.recovery.backoff_cycles = cycles;
        self
    }

    /// Seed of the deterministic retry-backoff jitter (0 = no jitter;
    /// see [`RecoveryPolicy::retry_seed`]).
    pub fn retry_seed(mut self, seed: u64) -> Self {
        self.recovery.retry_seed = seed;
        self
    }

    /// Quarantine a warm engine after a session whose degradation
    /// counters reach this threshold (0 = never; see
    /// [`RecoveryPolicy::quarantine_after`]).
    pub fn quarantine_after(mut self, threshold: u64) -> Self {
        self.recovery.quarantine_after = threshold;
        self
    }

    /// Cluster shard failover: on a mid-session chip/ring fault that
    /// makes a shard unreachable, re-partition the network over the
    /// surviving chips at the next sample boundary
    /// ([`crate::cluster::Cluster`]). Off by default; meaningless (and
    /// ignored) at `chips == 1`.
    pub fn failover(mut self, on: bool) -> Self {
        self.soc.failover = on;
        self
    }

    /// **The** validation choke point: every range check the chip model
    /// imposes, applied no matter how the config was assembled (JSON
    /// file, CLI flags, fluent calls).
    pub fn validate(&self) -> Result<()> {
        let s = &self.soc;
        if !(1..=64).contains(&s.domains) {
            return Err(Error::Config(format!(
                "domains {} outside 1..=64",
                s.domains
            )));
        }
        let max_cores = 20 * s.domains;
        if s.n_cores == 0 || s.n_cores > max_cores {
            return Err(Error::Config(format!(
                "n_cores {} outside 1..={max_cores} ({} fullerene domain(s))",
                s.n_cores, s.domains
            )));
        }
        if s.max_neurons_per_core == 0
            || s.max_neurons_per_core > crate::core::MAX_NEURONS_PER_CORE
        {
            return Err(Error::Config(format!(
                "max_neurons_per_core {} outside 1..={}",
                s.max_neurons_per_core,
                crate::core::MAX_NEURONS_PER_CORE
            )));
        }
        if s.fifo_depth == 0 || s.fifo_depth > 64 {
            return Err(Error::Config("fifo_depth outside 1..=64".into()));
        }
        if !(50.0e6..=200.0e6).contains(&s.f_core_hz) {
            return Err(Error::Config(format!(
                "core clock {} Hz outside the 50–200 MHz envelope",
                s.f_core_hz
            )));
        }
        if !(16.0e6..=100.0e6).contains(&s.f_cpu_hz) {
            return Err(Error::Config(format!(
                "cpu clock {} Hz outside the 16–100 MHz envelope",
                s.f_cpu_hz
            )));
        }
        if !(0.9..=1.4).contains(&s.supply_v) {
            return Err(Error::Config(format!(
                "supply {} V outside the 0.9–1.4 V model range",
                s.supply_v
            )));
        }
        if !(1..=16).contains(&s.chips) {
            return Err(Error::Config(format!(
                "chips {} outside 1..=16 (the extended L3 ring tops out at \
                 16 scale-out nodes)",
                s.chips
            )));
        }
        if self.workers == 0 {
            return Err(Error::Config("workers must be >= 1".into()));
        }
        if !(1..=MAX_QUEUE_DEPTH).contains(&self.queue_depth) {
            return Err(Error::Config(format!(
                "queue_depth {} outside 1..={MAX_QUEUE_DEPTH}",
                self.queue_depth
            )));
        }
        self.recovery.validate()?;
        if !s.fault_plan.is_empty() {
            // Split the plan: the on-chip half is checked against the
            // configured topology (a kill naming a core, a cut naming an
            // absent link fails here instead of mid-session); the
            // kill-l3/throttle-l3 half against the actual cluster ring
            // (out-of-range node, or any L3 event at chips == 1).
            let (chip_plan, l3_plan) = s.fault_plan.split_l3();
            if !chip_plan.is_empty() {
                let topo = if s.domains == 1 {
                    Topology::fullerene()
                } else {
                    Topology::multi_domain(s.domains)
                };
                chip_plan.validate(&topo)?;
            }
            l3_plan.validate_l3(s.chips)?;
        }
        Ok(())
    }

    /// Validate and return the chip config.
    pub fn build_config(&self) -> Result<SocConfig> {
        self.validate()?;
        Ok(self.soc.clone())
    }

    /// Validate and assemble a chip running `net`. Refused when the
    /// builder is configured for more than one chip — use
    /// [`SocBuilder::build_cluster`] or [`SocBuilder::build_engine`].
    pub fn build_soc(&self, net: &NetworkDesc) -> Result<Soc> {
        self.validate()?;
        Soc::new(net.clone(), self.soc.clone())
    }

    /// Validate and assemble a multi-chip [`Cluster`] running `net`
    /// across `chips` shards over the off-chip L3 ring. Works at
    /// `chips == 1` too (a degenerate cluster with no ring, bit-identical
    /// to the plain chip).
    pub fn build_cluster(&self, net: &NetworkDesc) -> Result<Cluster> {
        self.validate()?;
        Cluster::new(net.clone(), self.soc.clone())
    }

    /// Validate and assemble the serving [`Engine`] this builder's
    /// `chips` setting asks for: the plain chip at 1, a cluster above.
    pub fn build_engine(&self, net: &NetworkDesc) -> Result<Engine> {
        self.validate()?;
        Engine::new(net.clone(), self.soc.clone())
    }

    /// Validate, assemble the configured engine (chip or cluster) and
    /// open a streaming [`Session`] on it.
    pub fn open_session(&self, net: &NetworkDesc, name: &str) -> Result<Session> {
        Ok(Session::open_engine(self.build_engine(net)?, name))
    }

    /// Validate and build a serving pool over `net` with this builder's
    /// worker count, check mode and recovery policy.
    pub fn build_pool(&self, net: &NetworkDesc) -> Result<SocPool> {
        self.validate()?;
        Ok(
            SocPool::new(net.clone(), self.soc.clone(), self.workers, self.check)?
                .with_recovery(self.recovery),
        )
    }

    /// Validate and spawn a persistent [`ServeRuntime`] over `net` with
    /// this builder's worker count, check mode, queue depth and
    /// warm-reuse policy — the validation choke point in front of the
    /// serving engine (CLI `serve --queue-depth/--no-warm` funnels
    /// through here too).
    pub fn build_serve_runtime(&self, net: &NetworkDesc) -> Result<ServeRuntime> {
        self.validate()?;
        ServeRuntime::new(
            net.clone(),
            self.soc.clone(),
            self.workers,
            self.check,
            self.queue_depth,
            self.keep_warm,
            self.recovery,
        )
    }

    /// Validate and build a batch [`ExperimentRunner`] over `net`.
    pub fn build_runner(&self, net: NetworkDesc) -> Result<ExperimentRunner> {
        self.validate()?;
        ExperimentRunner::new(
            net,
            ExperimentConfig {
                soc: self.soc.clone(),
                limit: self.limit,
                check: self.check,
                artifacts: self.artifacts.clone(),
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fluent_setters_reach_the_config() {
        let b = SocBuilder::new()
            .domains(2)
            .n_cores(40)
            .f_core_mhz(200.0)
            .supply_v(1.32)
            .use_noc(false)
            .drive_cpu(false)
            .workers(3);
        let cfg = b.build_config().unwrap();
        assert_eq!(cfg.domains, 2);
        assert_eq!(cfg.n_cores, 40);
        assert!((cfg.f_core_hz - 200.0e6).abs() < 1.0);
        assert!(!cfg.use_noc && !cfg.drive_cpu);
    }

    #[test]
    fn every_range_check_fires() {
        assert!(SocBuilder::new().domains(0).validate().is_err());
        assert!(SocBuilder::new().domains(65).validate().is_err());
        assert!(SocBuilder::new().n_cores(21).validate().is_err());
        assert!(SocBuilder::new().domains(4).n_cores(80).validate().is_ok());
        assert!(SocBuilder::new().max_neurons_per_core(0).validate().is_err());
        assert!(SocBuilder::new().fifo_depth(0).validate().is_err());
        assert!(SocBuilder::new().f_core_mhz(300.0).validate().is_err());
        assert!(SocBuilder::new().f_cpu_hz(5.0e6).validate().is_err());
        assert!(SocBuilder::new().supply_v(2.0).validate().is_err());
        assert!(SocBuilder::new().workers(0).validate().is_err());
        assert!(SocBuilder::new().queue_depth(0).validate().is_err());
        assert!(SocBuilder::new()
            .queue_depth(MAX_QUEUE_DEPTH + 1)
            .validate()
            .is_err());
        assert!(SocBuilder::new().queue_depth(1).validate().is_ok());
        assert!(SocBuilder::new().keep_warm(false).validate().is_ok());
        assert!(SocBuilder::new().chips(0).validate().is_err());
        assert!(SocBuilder::new().chips(17).validate().is_err());
        assert!(SocBuilder::new().chips(16).validate().is_ok());
        assert!(SocBuilder::new().validate().is_ok());
        // Recovery knobs validate through the same choke point.
        assert!(SocBuilder::new().retries(33).validate().is_err());
        assert!(SocBuilder::new().backoff_cycles(10).validate().is_err());
        assert!(SocBuilder::new()
            .retries(2)
            .backoff_cycles(64)
            .deadline_cycles(1_000_000)
            .validate()
            .is_ok());
    }

    #[test]
    fn recovery_and_failover_knobs_reach_their_configs() {
        let b = SocBuilder::new()
            .deadline_cycles(500_000)
            .deadline_wall_ms(2_000)
            .retries(3)
            .backoff_cycles(128)
            .retry_seed(42)
            .quarantine_after(5)
            .chips(2)
            .failover(true);
        let cfg = b.build_config().unwrap();
        assert!(cfg.failover);
        let expected = RecoveryPolicy {
            deadline_cycles: 500_000,
            deadline_wall_ms: 2_000,
            retries: 3,
            backoff_cycles: 128,
            retry_seed: 42,
            quarantine_after: 5,
        };
        assert_eq!(b.recovery, expected);
        assert!(expected.enabled());
        // The whole-policy setter overrides the per-knob ones; failover
        // lives on the chip config and is untouched by it.
        let b = b.recovery(RecoveryPolicy::disabled());
        assert!(!b.recovery.enabled());
        assert!(b.build_config().unwrap().failover);
    }

    #[test]
    fn fault_plan_reaches_the_config_and_is_validated() {
        use crate::noc::When;
        let plan = FaultPlan::none().kill_router(3, When::Cycle(100));
        let cfg = SocBuilder::new()
            .fault_plan(plan.clone())
            .build_config()
            .unwrap();
        assert_eq!(cfg.fault_plan, plan);
        // Node 15 is a core of the single fullerene domain — rejected.
        let bad = FaultPlan::none().kill_router(15, When::Cycle(1));
        assert!(SocBuilder::new().fault_plan(bad).validate().is_err());
        // Router ids shift across topologies: validate against the real one.
        let t = Topology::multi_domain(2);
        let r = t.routers()[0];
        assert!(SocBuilder::new()
            .domains(2)
            .n_cores(40)
            .fault_plan(FaultPlan::none().kill_router(r, When::Cycle(1)))
            .validate()
            .is_ok());
    }

    #[test]
    fn l3_fault_events_validate_against_the_configured_ring() {
        use crate::noc::When;
        // L3 events need a cluster: rejected at chips == 1 (the default)…
        let plan = FaultPlan::none().kill_l3(1, When::Timestep(2));
        let err = SocBuilder::new()
            .fault_plan(plan.clone())
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("multi-chip"), "{err}");
        // …accepted on a ring that has the named node…
        assert!(SocBuilder::new().chips(4).fault_plan(plan).validate().is_ok());
        // …and range-checked against the actual ring size.
        let oob = FaultPlan::none().kill_l3(4, When::Cycle(10));
        let err = SocBuilder::new()
            .chips(4)
            .fault_plan(oob)
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("out of range"), "{err}");
        // Mixed plans validate each half against its own fabric: the
        // on-chip kill against the topology, the throttle against the ring.
        let mixed = FaultPlan::none()
            .kill_router(3, When::Cycle(5))
            .throttle_l3(4, When::Cycle(100));
        assert!(SocBuilder::new().chips(2).fault_plan(mixed.clone()).validate().is_ok());
        assert!(SocBuilder::new().fault_plan(mixed).validate().is_err());
    }
}
