"""AOT compile path: data → train → quantize → export artifacts.

Per dataset this emits, under ``artifacts/``:

- ``<name>.weights.json``  — the quantized network (rust ``nn::loader``);
- ``dataset_<name>.json``  — the held-out test split (rust ``datasets``);
- ``<name>.hlo.txt``       — the integer network (Pallas kernel inside)
  lowered to HLO **text** for the Rust PJRT runtime;
- ``<name>.meta.json``     — shape sidecar for the runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Python runs ONCE at build time (``make artifacts``); it is never on the
Rust request path.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import model, train
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants: the baked codebook-index matrices must
    # round-trip through the text format (default printing elides them as
    # `constant({...})`, which the Rust-side parser would reject).
    return comp.as_hlo_text(print_large_constants=True)


SPECS = {
    "nmnist": model.NetSpec(
        name="nmnist", inputs=34 * 34 * 2, hidden=(1024,), classes=10,
        timesteps=20),
    "dvsgesture": model.NetSpec(
        name="dvsgesture", inputs=32 * 32 * 2, hidden=(1024,), classes=11,
        timesteps=25),
    "cifar10": model.NetSpec(
        name="cifar10", inputs=32 * 32 * 3, hidden=(512,), classes=10,
        timesteps=16),
}


def export_weights_json(result: train.TrainResult, path: str) -> None:
    spec = result.spec
    layers = []
    sizes = spec.layer_sizes
    for li, (layer, scale) in enumerate(zip(result.int_layers,
                                            result.scales)):
        a, n = sizes[li]
        widx = np.asarray(layer.widx, dtype=np.uint8)
        p = layer.params
        leak = ({"mode": "none"} if p.leak_mode == ref.LEAK_NONE else
                {"mode": "linear", "value": int(p.leak_value)}
                if p.leak_mode == ref.LEAK_LINEAR else
                {"mode": "shift", "value": int(p.leak_value)})
        layers.append({
            "name": f"fc{li}",
            "inputs": a,
            "neurons": n,
            "codebook": [int(v) for v in np.asarray(layer.codebook)],
            "w_bits": spec.w_bits,
            "scale": scale,
            "widx_hex": widx.tobytes().hex(),
            "threshold": int(p.threshold),
            "leak": leak,
            "reset": "subtract" if p.reset_mode == ref.RESET_SUBTRACT
                     else "zero",
            "mp_bits": int(p.mp_bits),
        })
    doc = {
        "name": spec.name,
        "timesteps": spec.timesteps,
        "classes": spec.classes,
        "layers": layers,
    }
    with open(path, "w") as f:
        json.dump(doc, f, separators=(",", ":"))


def export_hlo(result: train.TrainResult, out_dir: str, name: str,
               log=print) -> None:
    spec = result.spec
    layers = result.int_layers

    def run_fn(raster):
        return (model.int_forward(layers, raster, use_pallas=True),)

    example = jax.ShapeDtypeStruct((spec.timesteps, spec.inputs), jnp.int32)
    t0 = time.time()
    lowered = jax.jit(run_fn).lower(example)
    text = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)
    meta = {"inputs": spec.inputs, "timesteps": spec.timesteps,
            "classes": spec.classes}
    with open(os.path.join(out_dir, f"{name}.meta.json"), "w") as f:
        json.dump(meta, f)
    log(f"  lowered {name}.hlo.txt ({len(text) / 1e6:.1f} MB, "
        f"{time.time() - t0:.1f}s)")


def build_dataset(name: str, fast: bool):
    gen = data_mod.GENERATORS[name]
    n_train, n_test = (120, 40) if fast else (480, 120)
    ds_train = gen(n_train, seed=1000)
    ds_test = gen(n_test, seed=2000)  # disjoint seed → held-out split
    return ds_train, ds_test


def run_one(name: str, out_dir: str, fast: bool, log=print):
    os.makedirs(out_dir, exist_ok=True)
    spec = SPECS[name]
    ds_train, ds_test = build_dataset(name, fast)
    assert ds_train.inputs == spec.inputs
    assert ds_train.timesteps == spec.timesteps
    epochs = 6 if fast else 20
    result = train.train_and_quantize(
        spec, ds_train.rasters, ds_train.labels, ds_test.rasters,
        ds_test.labels, epochs=epochs, seed=42, log=log)
    export_weights_json(result,
                        os.path.join(out_dir, f"{name}.weights.json"))
    # Export the test split the chip will be evaluated on (capped for
    # simulation time).
    ds_test.name = name
    ds_test.export_json(os.path.join(out_dir, f"dataset_{name}.json"),
                        limit=40 if fast else 100)
    export_hlo(result, out_dir, name, log=log)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--datasets", default="nmnist,dvsgesture,cifar10")
    ap.add_argument("--fast", action="store_true",
                    default=os.environ.get("FSOC_FAST") == "1")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    summary = {}
    for name in args.datasets.split(","):
        name = name.strip()
        print(f"=== {name} ({'fast' if args.fast else 'full'}) ===")
        r = run_one(name, args.out, args.fast)
        summary[name] = {"float_acc": r.float_acc, "int_acc": r.int_acc}
    with open(os.path.join(args.out, "training_summary.json"), "w") as f:
        json.dump(summary, f, indent=2)
    print("summary:", json.dumps(summary))
    # Marker for the Makefile.
    with open(os.path.join(args.out, ".stamp"), "w") as f:
        f.write(str(time.time()))


if __name__ == "__main__":
    main()
