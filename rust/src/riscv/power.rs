//! RISC-V power model (Fig. 6): per-instruction-class dynamic energy +
//! per-domain static power, evaluated over the clock-domain accounting.
//!
//! Calibration targets: ≈0.434 mW average on the MNIST control firmware
//! with gating (the firmware sleeps between timesteps), ≈43 % below the
//! ungated baseline.

use super::clock::ClockDomains;
use crate::energy::{EnergyLedger, EnergyParams};

/// Power summary of a CPU run.
#[derive(Debug, Clone)]
pub struct CpuPowerReport {
    /// Wall cycles (HF-domain units).
    pub wall_cycles: u64,
    /// Instructions retired.
    pub instret: u64,
    /// Fraction of time the HF domain was gated.
    pub gated_fraction: f64,
    /// Dynamic energy (pJ).
    pub dynamic_pj: f64,
    /// Static energy (pJ).
    pub static_pj: f64,
    /// Average power (mW) at `f_hz`.
    pub avg_power_mw: f64,
}

/// Build the power report for a finished run.
///
/// Static model: HF active cycles at `p_cpu_active`, HF gated cycles at
/// `p_cpu_sleep`, plus the always-on LF domain at `p_cpu_lf`.
pub fn report(
    ledger: &EnergyLedger,
    clocks: &ClockDomains,
    instret: u64,
    params: &EnergyParams,
    f_hz: f64,
) -> CpuPowerReport {
    let mut l = ledger.clone();
    l.add_static(
        "cpu-hf",
        clocks.hf_active,
        clocks.hf_gated,
        params.p_cpu_active,
        params.p_cpu_sleep,
    );
    l.add_static("cpu-lf", clocks.lf_cycles, 0, params.p_cpu_lf, 0.0);
    let wall = clocks.wall().max(1);
    CpuPowerReport {
        wall_cycles: clocks.wall(),
        instret,
        gated_fraction: clocks.gated_fraction(),
        dynamic_pj: l.dynamic_pj(params),
        static_pj: l.static_pj(f_hz),
        avg_power_mw: l.avg_power_mw(params, wall, f_hz),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::EventClass;

    #[test]
    fn sleeping_cpu_draws_much_less() {
        let p = EnergyParams::nominal();
        let mut gated = ClockDomains::new(true);
        let mut ungated = ClockDomains::new(false);
        for i in 0..10_000 {
            gated.tick(i % 100 < 5); // 5 % duty cycle
            ungated.tick(i % 100 < 5);
        }
        let mut ledger = EnergyLedger::new();
        ledger.add(EventClass::CpuAlu, 500);
        let rg = report(&ledger, &gated, 500, &p, 16.0e6);
        let ru = report(&ledger, &ungated, 500, &p, 16.0e6);
        assert!(rg.avg_power_mw < ru.avg_power_mw * 0.6);
        assert!(rg.gated_fraction > 0.9);
    }
}
