//! Serving plumbing shared by the sequential reference path and the
//! persistent [`ServeRuntime`](super::runtime::ServeRuntime), plus
//! [`SocPool`] itself — the one-thread **reference pool**.
//!
//! Historically `SocPool::serve` was the crate's concurrent serving
//! entry point: all [`SessionSpec`]s up front, static `i % workers`
//! round-robin buckets, threads spawned per call and nothing returned
//! until the last session drained. That dispatch lived on as a
//! deprecated runtime-backed wrapper for one release and is now
//! **removed** — concurrent serving goes through the runtime
//! (streaming submission, warm engine reuse, per-session failure
//! isolation). What stays here is everything the runtime and the tests
//! still share: the spec/outcome types, [`run_session_on`] (the single
//! session-execution code path — what makes runtime and sequential
//! serving bit-identical), and [`SocPool::serve_sequential`], the
//! fresh-engine-per-session **reference path** the runtime's
//! determinism guarantee is stated against (merged reports fold in
//! submission order, so the two match down to `f64::to_bits`).

use super::recovery::{RecoveryPolicy, SessionVerdict};
use super::session::{DegradationStats, Session, SessionStats};
use super::workload::Workload;
use crate::cluster::Engine;
use crate::coordinator::GoldenCheck;
use crate::datasets::Sample;
use crate::energy::{AreaModel, ChipReport};
use crate::nn::NetworkDesc;
use crate::soc::SocConfig;
use crate::{Error, Result};

/// One queued session: a label plus the sample stream to serve.
pub struct SessionSpec {
    /// Session name (becomes the report's workload label).
    pub name: String,
    /// The sample source; drained to exhaustion by the pool.
    pub workload: Box<dyn Workload>,
}

impl SessionSpec {
    /// A named session over a boxed workload.
    pub fn new(name: &str, workload: Box<dyn Workload>) -> Self {
        SessionSpec {
            name: name.to_string(),
            workload,
        }
    }
}

/// Per-session serving result.
#[derive(Debug, Clone)]
pub struct SessionOutcome {
    /// Session name.
    pub name: String,
    /// Chip report for exactly this session's window.
    pub report: ChipReport,
    /// Latency/throughput statistics.
    pub stats: SessionStats,
    /// NoC fabric statistics for exactly this session's window (delivered
    /// flits, latency/hop aggregates, stall totals).
    pub noc: crate::noc::SimStats,
    /// Fabric-degradation statistics for the window: dropped/rerouted
    /// flits and dead fabric under the chip's fault plan (all zero with
    /// `armed == false` on a healthy chip).
    pub degradation: DegradationStats,
    /// Samples that disagreed with the integer reference (0 unless
    /// reference checking is enabled).
    pub mismatches: u64,
    /// Samples checked against the reference.
    pub checked: u64,
    /// Host-side seconds the session spent queued between submission and
    /// a worker picking it up (0 on the sequential path). A load signal,
    /// not simulated physics — deliberately absent from every
    /// determinism comparison.
    pub queue_wait_s: f64,
    /// Attempts it took to complete the session (1 = first try; > 1 only
    /// with a [`RecoveryPolicy`] retry budget).
    pub attempts: u32,
    /// Simulated cycles burned by failed attempts plus deterministic
    /// retry backoff — the recovery overhead ledger. 0 without retries.
    pub retry_cycles_burned: u64,
    /// Terminal verdict. A returned outcome is always
    /// [`SessionVerdict::Completed`]; failed sessions surface their
    /// verdict through [`crate::serve::HealthReport`] classification of
    /// the error instead.
    pub verdict: SessionVerdict,
    /// Cluster failover replans performed during the session (0 on
    /// single-chip engines or with `failover` disabled).
    pub replans: u64,
}

/// A session that failed in isolation: its siblings kept serving and the
/// aggregate report simply excludes it.
#[derive(Debug, Clone)]
pub struct SessionFailure {
    /// Submission index of the failed session.
    pub index: u64,
    /// Session name.
    pub name: String,
    /// What went wrong (workload error, geometry mismatch, worker panic —
    /// panics are attributed to the session name/index).
    pub error: Error,
}

/// Aggregate of one serve call ([`SocPool::serve_sequential`] or
/// [`ServeRuntime::finish`](super::runtime::ServeRuntime::finish)).
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    /// Per-session outcomes of the **successful** sessions, in
    /// submission order.
    pub sessions: Vec<SessionOutcome>,
    /// Deterministic merge of every successful session report
    /// (submission order).
    pub merged: ChipReport,
    /// Total reference mismatches across sessions.
    pub mismatches: u64,
    /// Total reference checks across sessions.
    pub checked: u64,
    /// Sessions that failed, in submission order (empty on the strict
    /// wrapper paths, which convert the first failure into an `Err`).
    pub failures: Vec<SessionFailure>,
}

/// Reject a workload whose geometry cannot drive `net`. Runs both as
/// the runtime worker's pre-chip-arming check (a misconfigured
/// submission must not cost a pristine warm chip) and at the top of
/// [`run_session_on`].
pub(crate) fn check_geometry(
    net: &NetworkDesc,
    name: &str,
    workload: &dyn Workload,
) -> Result<()> {
    if workload.inputs() != net.input_size() {
        return Err(Error::Config(format!(
            "session '{name}': workload has {} inputs, network expects {}",
            workload.inputs(),
            net.input_size()
        )));
    }
    Ok(())
}

/// Buffers samples pulled from a workload so retry attempts replay the
/// **exact** stream the failed attempt saw — a retried session is a pure
/// function of (net, config, plan, samples), never of how far the
/// upstream workload happened to advance.
struct ReplayBuffer<'a> {
    inner: &'a mut dyn Workload,
    seen: Vec<Sample>,
    cursor: usize,
}

impl<'a> ReplayBuffer<'a> {
    fn new(inner: &'a mut dyn Workload) -> Self {
        ReplayBuffer {
            inner,
            seen: Vec::new(),
            cursor: 0,
        }
    }

    fn next(&mut self) -> Option<Sample> {
        if let Some(s) = self.seen.get(self.cursor) {
            let s = s.clone();
            self.cursor += 1;
            return Some(s);
        }
        let s = self.inner.next_sample()?;
        self.seen.push(s.clone());
        self.cursor += 1;
        Some(s)
    }

    fn rewind(&mut self) {
        self.cursor = 0;
    }
}

/// The sample stream one attempt drains: the raw workload (retry-off
/// fast path — zero buffering, today's behavior bit for bit) or a
/// rewindable [`ReplayBuffer`].
enum SampleSource<'a, 'b> {
    Stream(&'a mut dyn Workload),
    Replay(&'a mut ReplayBuffer<'b>),
}

impl SampleSource<'_, '_> {
    fn next(&mut self) -> Option<Sample> {
        match self {
            SampleSource::Stream(w) => w.next_sample(),
            SampleSource::Replay(r) => r.next(),
        }
    }
}

/// One session attempt on one engine. Returns `(result, engine,
/// simulated cycles consumed)`; unlike the pre-recovery path, an erroring
/// attempt hands its engine back so the retry loop can power-cycle it
/// instead of paying a fresh build. Deadlines are checked **after** each
/// push — a session whose final sample completes inside the budget never
/// sees a kill, regardless of pull order.
#[allow(clippy::too_many_arguments)]
fn run_attempt(
    engine: Engine,
    net: &NetworkDesc,
    check: GoldenCheck,
    name: &str,
    source: &mut SampleSource<'_, '_>,
    deadline_cycles: u64,
    wall_deadline: Option<std::time::Instant>,
    queue_wait_s: f64,
) -> (Result<SessionOutcome>, Engine, u64) {
    let mut session = Session::open_engine(engine, name);
    let use_ref = matches!(check, GoldenCheck::Reference);
    let mut mismatches = 0u64;
    let mut checked = 0u64;
    while let Some(sample) = source.next() {
        let r = match session.push(&sample) {
            Ok(r) => r,
            Err(e) => {
                let cycles = session.cycles();
                return (Err(e), session.into_engine(), cycles);
            }
        };
        if use_ref {
            let raster = sample.to_raster(net.timesteps, net.input_size());
            let expect = net.reference_run(&raster);
            checked += 1;
            if expect != r.counts {
                mismatches += 1;
            }
        }
        if deadline_cycles > 0 && session.cycles() > deadline_cycles {
            let cycles = session.cycles();
            let e = Error::Deadline(format!(
                "session '{name}' burned {cycles} simulated cycles against a \
                 {deadline_cycles}-cycle budget"
            ));
            return (Err(e), session.into_engine(), cycles);
        }
        if let Some(dl) = wall_deadline {
            // lint:allow(host-clock-quarantine) the wall-deadline watchdog is host timing by design
            if std::time::Instant::now() >= dl {
                let cycles = session.cycles();
                let e = Error::Deadline(format!(
                    "session '{name}' overran its host wall-clock deadline"
                ));
                return (Err(e), session.into_engine(), cycles);
            }
        }
    }
    let noc = session.noc_stats();
    let degradation = session.degradation();
    // Read before close: finish_report resets the window counters.
    let replans = session
        .engine()
        .as_cluster()
        .map(|c| c.replans())
        .unwrap_or(0);
    let (closed, engine) = session.close_reuse();
    let cycles = closed.stats.cycles;
    (
        Ok(SessionOutcome {
            name: name.to_string(),
            report: closed.report,
            stats: closed.stats,
            noc,
            degradation,
            mismatches,
            checked,
            queue_wait_s,
            attempts: 1,
            retry_cycles_burned: 0,
            verdict: SessionVerdict::Completed,
            replans,
        }),
        engine,
        cycles,
    )
}

/// Serve one session to exhaustion on the given engine (one chip or a
/// cluster). This is the single session-execution code path shared by
/// [`SocPool::serve_sequential`] and the
/// [`ServeRuntime`](super::runtime::ServeRuntime) workers, which is what
/// makes the two bit-identical — including recovery: deadline kills and
/// seeded retry run the same code on either path. With the default
/// disabled [`RecoveryPolicy`] this streams samples exactly like the
/// pre-recovery code (no buffering, no extra checks firing), and error
/// paths drop the engine (a failed session must never leak state into a
/// later one). With a retry budget, failed attempts power-cycle the
/// engine via [`Engine::reset_for_session`], re-arm the fault plan's
/// unfired tail ([`crate::noc::FaultPlan::shifted`] — transients that
/// already fired do not replay), replay the same samples, and ledger the
/// burned cycles into the outcome.
pub(crate) fn run_session_on(
    engine: Engine,
    net: &NetworkDesc,
    check: GoldenCheck,
    name: &str,
    workload: &mut dyn Workload,
    queue_wait_s: f64,
    policy: &RecoveryPolicy,
) -> Result<(SessionOutcome, Engine)> {
    check_geometry(net, name, workload)?;
    let wall_deadline = if policy.deadline_wall_ms > 0 {
        // lint:allow(host-clock-quarantine) the wall-deadline watchdog is host timing by design
        Some(std::time::Instant::now() + std::time::Duration::from_millis(policy.deadline_wall_ms))
    } else {
        None
    };
    if policy.retries == 0 {
        let (r, engine, _) = run_attempt(
            engine,
            net,
            check,
            name,
            &mut SampleSource::Stream(workload),
            policy.deadline_cycles,
            wall_deadline,
            queue_wait_s,
        );
        let outcome = r?;
        return Ok((outcome, engine));
    }
    // Retry path: capture the base fault plan up front (retries re-arm a
    // shifted tail), buffer the stream for bit-exact replay.
    let base_plan = engine.config().fault_plan.clone();
    let mut replay = ReplayBuffer::new(workload);
    let mut engine = engine;
    let mut burned = 0u64;
    let mut attempts = 0u32;
    loop {
        attempts += 1;
        let (r, engine_back, cycles) = run_attempt(
            engine,
            net,
            check,
            name,
            &mut SampleSource::Replay(&mut replay),
            policy.deadline_cycles,
            wall_deadline,
            queue_wait_s,
        );
        match r {
            Ok(mut outcome) => {
                outcome.attempts = attempts;
                outcome.retry_cycles_burned = burned;
                let mut engine = engine_back;
                if attempts > 1 {
                    // The winning attempt ran the plan's shifted tail;
                    // hand the engine back with the *original* plan so
                    // warm reuse stays bit-identical to a fresh chip.
                    engine.rearm_fault_plan(base_plan.clone())?;
                }
                return Ok((outcome, engine));
            }
            Err(e) => {
                if attempts > policy.retries {
                    return Err(e);
                }
                burned = burned
                    .saturating_add(cycles)
                    .saturating_add(policy.backoff_for(attempts));
                let mut eng = engine_back;
                eng.reset_for_session();
                eng.rearm_fault_plan(base_plan.shifted(burned))?;
                replay.rewind();
                engine = eng;
            }
        }
    }
}

/// Merge successful session outcomes (already in submission order) into
/// a [`ServeOutcome`]. Errors when no session succeeded — there is
/// nothing to report over.
pub(crate) fn merge_outcomes(
    sessions: Vec<SessionOutcome>,
    failures: Vec<SessionFailure>,
    domains: usize,
) -> Result<ServeOutcome> {
    if sessions.is_empty() {
        return Err(match failures.into_iter().next() {
            Some(f) => f.error,
            None => Error::Config("no sessions to serve".into()),
        });
    }
    let reports: Vec<ChipReport> = sessions.iter().map(|s| s.report.clone()).collect();
    let merged = ChipReport::merged(&reports, &AreaModel::multi_chip(domains))?;
    let mismatches = sessions.iter().map(|s| s.mismatches).sum();
    let checked = sessions.iter().map(|s| s.checked).sum();
    Ok(ServeOutcome {
        sessions,
        merged,
        mismatches,
        checked,
        failures,
    })
}

/// A pool of serving engines: the sequential reference path
/// ([`SocPool::serve_sequential`]) that the concurrent
/// [`ServeRuntime`](super::runtime::ServeRuntime) is proven
/// bit-identical against.
pub struct SocPool {
    net: NetworkDesc,
    config: SocConfig,
    workers: usize,
    check: GoldenCheck,
    recovery: RecoveryPolicy,
}

impl SocPool {
    /// A pool over `net` at `config`. `workers` is retained as the
    /// concurrency hint callers pass on when they build a runtime from
    /// this pool's parameters. `check` may be [`GoldenCheck::None`] or
    /// [`GoldenCheck::Reference`]; the XLA golden model holds per-process
    /// runtime state and cannot back concurrent sessions.
    pub fn new(
        net: NetworkDesc,
        config: SocConfig,
        workers: usize,
        check: GoldenCheck,
    ) -> Result<SocPool> {
        if matches!(check, GoldenCheck::Xla | GoldenCheck::Both) {
            return Err(Error::Config(
                "SocPool supports check none|reference (XLA golden state is \
                 per-process); use ExperimentRunner::run for XLA checks"
                    .into(),
            ));
        }
        if workers == 0 {
            return Err(Error::Config("SocPool needs at least one worker".into()));
        }
        net.validate()?;
        Ok(SocPool {
            net,
            config,
            workers,
            check,
            recovery: RecoveryPolicy::default(),
        })
    }

    /// Arm a recovery policy on the sequential path (deadlines + retry;
    /// the pool has no warm engines, so quarantine never applies here).
    /// The default disabled policy leaves serving bit-identical to a
    /// pool built before recovery existed — which keeps the
    /// runtime ≡ sequential oracle meaningful under recovery too.
    pub fn with_recovery(mut self, policy: RecoveryPolicy) -> Self {
        self.recovery = policy;
        self
    }

    /// Worker-thread count the pool dispatches across.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The network every session is served with.
    pub fn network(&self) -> &NetworkDesc {
        &self.net
    }

    /// Serve every spec one after another on the calling thread, a fresh
    /// engine per session — the reference path for the bit-identity
    /// guarantee (the runtime's merged report must match this one down
    /// to `f64::to_bits`). For concurrent dispatch, build a
    /// [`ServeRuntime`](super::runtime::ServeRuntime) (the removed
    /// `SocPool::serve` wrapper used to do exactly that).
    pub fn serve_sequential(&self, specs: Vec<SessionSpec>) -> Result<ServeOutcome> {
        if specs.is_empty() {
            return Err(Error::Config("no sessions to serve".into()));
        }
        let mut sessions = Vec::with_capacity(specs.len());
        for mut spec in specs {
            let engine = Engine::new(self.net.clone(), self.config.clone())?;
            let (outcome, _engine) = run_session_on(
                engine,
                &self.net,
                self.check,
                &spec.name,
                &mut *spec.workload,
                0.0,
                &self.recovery,
            )?;
            sessions.push(outcome);
        }
        merge_outcomes(sessions, Vec::new(), self.config.domains)
    }
}
