//! Scale-up study (paper: "the NoC can be scaled up through extended
//! off-chip high-level router nodes"): multi-domain systems built from
//! fullerene level-1 domains joined by level-2 routers, from 1 domain
//! (20 cores / 160 K neurons) to 64 domains (10 M neurons).
//!
//! ```bash
//! cargo run --release --example scaling
//! ```

use fullerene_soc::energy::EnergyParams;
use fullerene_soc::metrics::Table;
use fullerene_soc::noc::multilevel::MultiDomain;
use fullerene_soc::noc::{Dest, NocSim, TopoStats, Topology};
use fullerene_soc::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    // --- the single-domain baseline ---------------------------------------
    let base = TopoStats::compute(&Topology::fullerene());
    let with_l2 = TopoStats::compute(&Topology::fullerene_with_l2());
    println!(
        "single domain: avg core-to-core distance {:.2} links ({:.2} router hops); \
         adding the L2 centre: {:.2} links",
        base.avg_core_hops,
        base.avg_core_hops / 2.0,
        with_l2.avg_core_hops
    );

    // --- multi-domain scaling ----------------------------------------------
    let mut t = Table::new(&[
        "domains",
        "cores",
        "neurons",
        "avg router hops (uniform)",
        "intra-domain hops",
        "worst inter-domain hops",
    ]);
    for d in [1usize, 2, 4, 8, 16, 32, 64] {
        let m = MultiDomain::new(d);
        let worst = if d > 1 {
            m.hops_between(0, (d / 2) * 20) // diametrically opposite domain
        } else {
            m.intra_hops
        };
        t.push_row(vec![
            d.to_string(),
            m.total_cores().to_string(),
            format!("{:.2}M", m.total_neurons() as f64 / 1e6),
            format!("{:.2}", m.avg_hops_uniform()),
            format!("{:.2}", m.intra_hops),
            format!("{:.2}", worst),
        ]);
    }
    println!("{}", t.render());

    // Locality analysis: what fraction of traffic must stay intra-domain
    // for the average to stay under 2× the single-domain latency?
    println!("## locality requirement");
    let mut t = Table::new(&["domains", "max remote fraction for <=2x latency"]);
    for d in [4usize, 16, 64] {
        let m = MultiDomain::new(d);
        let intra = m.intra_hops;
        let remote = 2.0 * m.to_l2_hops
            + (1..d).map(|k| m.l2_ring_hops(0, k) as f64).sum::<f64>() / (d - 1) as f64;
        // solve intra*(1-x) + remote*x = 2*intra
        let x = ((2.0 * intra - intra) / (remote - intra)).clamp(0.0, 1.0);
        t.push_row(vec![d.to_string(), format!("{:.1}%", x * 100.0)]);
    }
    println!("{}", t.render());
    println!(
        "interpretation: mapping layers within domains (what nn::Mapping \
         does) keeps nearly all spike traffic on the cheap intra-domain \
         fabric; the L2 ring only carries layer-boundary crossings."
    );

    // --- cycle-level validation of the analytic model ----------------------
    // Simulate a real 4-domain graph and compare measured hop counts with
    // the analytic expectation (10 % locality mix).
    println!("## cycle-level multi-domain simulation (4 domains, 80 cores)");
    let topo = Topology::multi_domain(4);
    let mut sim = NocSim::new(topo, 4, EnergyParams::nominal());
    let mut rng = Rng::new(17);
    for _ in 0..400 {
        let src = rng.below_usize(80);
        // 90 % intra-domain, 10 % cross-domain traffic.
        let dst = if rng.bool(0.9) {
            (src / 20) * 20 + rng.below_usize(20)
        } else {
            rng.below_usize(80)
        };
        if dst != src {
            sim.inject(src, &Dest::Core(dst), 0);
        }
    }
    sim.run_until_drained(1_000_000)?;
    let st = sim.stats();
    println!(
        "delivered {} flits | avg latency {:.1} cycles | avg {:.2} router \
         hops | max latency {}",
        st.delivered, st.avg_latency, st.avg_hops, st.max_latency
    );
    Ok(())
}
