//! End-to-end load generator for the `serve-http` front end: hundreds
//! of concurrent keep-alive connections submitting JSON workload
//! sessions, honoring 429 backpressure (back off + resubmit), polling
//! every accepted session to a terminal state, and reporting
//! client-side request-latency percentiles plus the 429 tally.
//!
//! ```bash
//! # terminal 1 — the server
//! cargo run --release -- serve-http --port 7171 --workers 2
//! # terminal 2 — the load
//! cargo run --release --example http_load -- \
//!     --addr 127.0.0.1:7171 --connections 32 --sessions 4 --shutdown
//! ```
//!
//! Flags: `--addr HOST:PORT` (default `127.0.0.1:7171`),
//! `--connections N` (default 8), `--sessions N` per connection
//! (default 4), `--samples N` per session (default 2), `--seed N`,
//! `--workload SPEC` (server default when omitted), `--admin-token T`,
//! `--shutdown` (drain the server via `POST /admin/shutdown` at the
//! end — the CI http-smoke job uses this to prove a clean drain).
//!
//! Exits non-zero on any protocol error, hung session, or failed
//! shutdown, so a harness can gate on it directly.

use fullerene_soc::http::Client;
use fullerene_soc::util::cli::Args;
use fullerene_soc::util::json::Json;
use fullerene_soc::{Error, Result};
use std::time::Duration;

/// Nearest-rank percentile over a sorted slice (local copy: the
/// crate-internal helper is not public, and the example should lean on
/// the public API only).
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// What one connection did: request latencies (seconds), 429s absorbed,
/// sessions driven to a terminal state.
struct ConnOutcome {
    latencies_s: Vec<f64>,
    refused_429: u64,
    terminal: u64,
}

/// One keep-alive connection: submit `sessions` specs (retrying through
/// 429s), then poll each accepted id until it leaves `pending`.
fn drive_connection(
    addr: &str,
    conn: usize,
    sessions: usize,
    samples: usize,
    seed: u64,
    workload: Option<&str>,
) -> Result<ConnOutcome> {
    let mut client = Client::connect_timeout_ms(addr, 10_000)?;
    let mut out = ConnOutcome {
        latencies_s: Vec::new(),
        refused_429: 0,
        terminal: 0,
    };
    let mut ids = Vec::new();
    for s in 0..sessions {
        let mut fields = vec![
            ("name", Json::Str(format!("load-c{conn}s{s}"))),
            ("samples", Json::Num(samples as f64)),
            ("seed", Json::Num((seed + 1000 * conn as u64 + s as u64) as f64)),
        ];
        if let Some(w) = workload {
            fields.push(("workload", Json::Str(w.to_string())));
        }
        let body = Json::obj(fields);
        loop {
            // lint:allow(host-clock-quarantine) client-side request latency is the example's measurement
            let t0 = std::time::Instant::now();
            let resp = client.post_json("/v1/sessions", &body)?;
            out.latencies_s.push(t0.elapsed().as_secs_f64());
            match resp.status {
                202 => {
                    ids.push(resp.json()?.get("id")?.as_i64()? as u64);
                    break;
                }
                429 => {
                    // The backpressure contract: back off for the
                    // server's hint, then resubmit the same spec.
                    out.refused_429 += 1;
                    let hint_s = resp
                        .json()
                        .ok()
                        .and_then(|j| j.get_opt("retry_after_s").and_then(|v| v.as_f64().ok()))
                        .unwrap_or(0.0);
                    std::thread::sleep(Duration::from_millis(
                        ((hint_s * 1e3) as u64).clamp(1, 50),
                    ));
                }
                other => {
                    return Err(Error::Runtime(format!(
                        "submit got {other}: {}",
                        resp.body
                    )))
                }
            }
        }
    }
    let mut polls = 0u64;
    let mut pending: std::collections::VecDeque<u64> = ids.into();
    while let Some(id) = pending.pop_front() {
        polls += 1;
        if polls > 500_000 {
            return Err(Error::Runtime(format!(
                "session {id} never reached a terminal state (hung?)"
            )));
        }
        // lint:allow(host-clock-quarantine) client-side request latency is the example's measurement
        let t0 = std::time::Instant::now();
        let resp = client.get(&format!("/v1/sessions/{id}"))?;
        out.latencies_s.push(t0.elapsed().as_secs_f64());
        if resp.status != 200 {
            return Err(Error::Runtime(format!(
                "poll of {id} got {}: {}",
                resp.status, resp.body
            )));
        }
        if resp.json()?.get("state")?.as_str()? == "pending" {
            pending.push_back(id);
            std::thread::sleep(Duration::from_millis(1));
        } else {
            out.terminal += 1;
        }
    }
    Ok(out)
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    if let Err(e) = args.reject_unknown(&[
        "addr",
        "connections",
        "sessions",
        "samples",
        "seed",
        "workload",
        "admin-token",
        "shutdown",
    ]) {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
    let addr = args.get_or("addr", "127.0.0.1:7171");
    let connections: usize = args.get_parse_or("connections", 8);
    let sessions: usize = args.get_parse_or("sessions", 4);
    let samples: usize = args.get_parse_or("samples", 2);
    let seed: u64 = args.get_parse_or("seed", 42);
    let workload = args.get("workload").map(str::to_string);
    let admin_token = args.get("admin-token").map(str::to_string);
    let do_shutdown = args.flag("shutdown");

    // Fail fast if nothing is listening.
    let mut probe = Client::connect_timeout_ms(&addr, 5_000)
        .map_err(|e| Error::Runtime(format!("no server at {addr}: {e}")))?;
    let hz = probe.get("/healthz")?;
    if hz.status != 200 {
        return Err(Error::Runtime(format!("/healthz returned {}", hz.status)));
    }
    drop(probe);

    println!(
        "http_load: {connections} connections x {sessions} sessions x {samples} samples -> {addr}"
    );
    // lint:allow(host-clock-quarantine) end-to-end wall time is the example's measurement
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..connections)
        .map(|c| {
            let addr = addr.clone();
            let workload = workload.clone();
            // lint:allow(no-unscoped-threads) load connections; every handle is joined right below
            std::thread::spawn(move || {
                drive_connection(&addr, c, sessions, samples, seed, workload.as_deref())
            })
        })
        .collect();
    let mut lats = Vec::new();
    let mut refused = 0u64;
    let mut terminal = 0u64;
    let mut failures = Vec::new();
    for (c, h) in handles.into_iter().enumerate() {
        match h.join() {
            Ok(Ok(o)) => {
                lats.extend(o.latencies_s);
                refused += o.refused_429;
                terminal += o.terminal;
            }
            Ok(Err(e)) => failures.push(format!("connection {c}: {e}")),
            Err(_) => failures.push(format!("connection {c}: panicked")),
        }
    }
    let host_s = t0.elapsed().as_secs_f64().max(1e-9);
    lats.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));

    let expected = (connections * sessions) as u64;
    println!(
        "done in {host_s:.3} s: {terminal}/{expected} sessions terminal, \
         {refused} refused (429, retried), {} requests",
        lats.len()
    );
    println!(
        "request latency: p50 {:.3} ms, p99 {:.3} ms; throughput {:.1} sessions/s",
        percentile(&lats, 0.50) * 1e3,
        percentile(&lats, 0.99) * 1e3,
        terminal as f64 / host_s
    );

    if do_shutdown {
        let mut admin = Client::connect_timeout_ms(&addr, 5_000)?;
        let headers: Vec<(String, String)> = admin_token
            .iter()
            .map(|t| ("Authorization".to_string(), format!("Bearer {t}")))
            .collect();
        let hdr: Vec<(&str, &str)> = headers
            .iter()
            .map(|(k, v)| (k.as_str(), v.as_str()))
            .collect();
        let resp = admin.request("POST", "/admin/shutdown", Some("{}"), &hdr)?;
        if resp.status != 200 {
            return Err(Error::Runtime(format!(
                "admin shutdown got {}: {}",
                resp.status, resp.body
            )));
        }
        println!("server draining: {}", resp.body);
    }

    if !failures.is_empty() || terminal != expected {
        for f in &failures {
            eprintln!("FAIL: {f}");
        }
        return Err(Error::Runtime(format!(
            "{}/{expected} sessions terminal, {} connection failures",
            terminal,
            failures.len()
        )));
    }
    Ok(())
}
