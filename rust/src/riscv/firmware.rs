//! Control firmware for the RISC-V CPU, written in the in-tree assembler.
//!
//! [`mnist_control`] is the paper's Fig. 6 workload: initialize network
//! parameters, enable the cores, start the network, then sleep between
//! timesteps (waking on timestep-switch) and finally read the result —
//! the CPU spends most wall time gated, which is where the 0.434 mW /
//! −43 % claim comes from.

use super::asm::assemble;
use crate::Result;

/// The MNIST-style control loop (Fig. 6 workload).
///
/// Protocol:
/// 1. `enu.init` streams the parameter table (addr `0x400`, `words`).
/// 2. `enu.coreen` enables all 20 cores.
/// 3. `enu.start` launches `timesteps` timesteps.
/// 4. Loop: `wfi` until woken; on wake check status — if the network is
///    still busy, `enu.tsack` and sleep again; else read result word 0.
/// 5. `ebreak`.
pub fn mnist_control(timesteps: u32, param_words: u32) -> Result<Vec<u32>> {
    let src = format!(
        "
        # -- initialization ------------------------------------
        li   x10, 0x400          # parameter table address
        li   x11, {param_words}  # parameter words
        enu.init x10, x11
        li   x12, 0xFFFFF        # 20-core enable mask
        enu.coreen x12
        li   x13, {timesteps}
        enu.start x0, x13
        # -- per-timestep sleep loop ----------------------------
    tsloop:
        wfi
        enu.status x14           # bit0 = busy
        andi x15, x14, 1
        beqz x15, done           # network finished
        enu.tsack
        j    tsloop
        # -- read back result -----------------------------------
    done:
        li   x16, 0
        enu.result x17, x16      # winning class word
        ebreak
        "
    );
    assemble(&src)
}

/// Busy-poll variant used as the *no-sleep* ablation: identical protocol
/// but spins on `enu.status` instead of `wfi` (the CPU never gates).
pub fn mnist_control_busywait(timesteps: u32, param_words: u32) -> Result<Vec<u32>> {
    let src = format!(
        "
        li   x10, 0x400
        li   x11, {param_words}
        enu.init x10, x11
        li   x12, 0xFFFFF
        enu.coreen x12
        li   x13, {timesteps}
        enu.start x0, x13
    poll:
        enu.status x14
        andi x15, x14, 1
        bnez x15, poll
        li   x16, 0
        enu.result x17, x16
        ebreak
        "
    );
    assemble(&src)
}

/// A pure-compute benchmark kernel (no ENU): sums and multiplies over a
/// small array — used to measure active-mode CPU power in isolation.
pub fn compute_kernel(iterations: u32) -> Result<Vec<u32>> {
    let src = format!(
        "
        li   x1, 0          # acc
        li   x2, 0          # i
        li   x3, {iterations}
    loop:
        mul  x4, x2, x2
        add  x1, x1, x4
        addi x2, x2, 1
        blt  x2, x3, loop
        ebreak
        "
    );
    assemble(&src)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::riscv::cpu::{Cpu, CpuState, WakeEvent};
    use crate::riscv::enu::EnuCommand;

    #[test]
    fn mnist_firmware_issues_protocol_then_sleeps() {
        let mut cpu = Cpu::new(64 * 1024, true);
        cpu.load_program(&mnist_control(10, 64).unwrap()).unwrap();
        cpu.run(10_000).unwrap();
        assert_eq!(cpu.state, CpuState::Sleeping);
        assert_eq!(
            cpu.enu.pop_command(),
            Some(EnuCommand::NetParamInit { addr: 0x400, words: 64 })
        );
        assert_eq!(cpu.enu.pop_command(), Some(EnuCommand::CoreEnable { mask: 0xFFFFF }));
        assert_eq!(cpu.enu.pop_command(), Some(EnuCommand::NetworkStart { timesteps: 10 }));
    }

    #[test]
    fn wake_cycle_acks_timesteps_until_done() {
        let mut cpu = Cpu::new(64 * 1024, true);
        cpu.load_program(&mnist_control(3, 8).unwrap()).unwrap();
        cpu.run(10_000).unwrap(); // runs to first wfi
        while cpu.enu.pop_command().is_some() {}
        // Simulate 3 timestep wakes with busy status, then finish.
        for _ in 0..3 {
            cpu.lsu.mmio.npu_status |= 1;
            assert!(cpu.wake(WakeEvent::TimestepSwitch));
            cpu.run(10_000).unwrap();
            assert_eq!(cpu.state, CpuState::Sleeping);
            assert_eq!(cpu.enu.pop_command(), Some(EnuCommand::TimestepAck));
        }
        cpu.lsu.mmio.npu_status &= !1;
        cpu.lsu.mmio.result[0] = 7;
        assert!(cpu.wake(WakeEvent::NetworkFinish));
        cpu.run(10_000).unwrap();
        assert_eq!(cpu.state, CpuState::Halted);
        assert_eq!(cpu.regs[17], 7, "read the result word");
    }

    #[test]
    fn busywait_variant_never_sleeps() {
        let mut cpu = Cpu::new(64 * 1024, true);
        cpu.load_program(&mnist_control_busywait(3, 8).unwrap())
            .unwrap();
        // Finish immediately so the poll loop exits.
        for _ in 0..2000 {
            if cpu.state != CpuState::Running {
                break;
            }
            cpu.step().unwrap();
            // Clear busy after a while.
            if cpu.instret == 500 {
                cpu.lsu.mmio.npu_status &= !1;
            }
        }
        assert_eq!(cpu.state, CpuState::Halted);
        assert_eq!(cpu.clocks.hf_gated, 0);
    }

    #[test]
    fn compute_kernel_sums_squares() {
        let mut cpu = Cpu::new(4096, true);
        cpu.load_program(&compute_kernel(10).unwrap()).unwrap();
        cpu.run(1000).unwrap();
        assert_eq!(cpu.regs[1], (0..10).map(|i| i * i).sum::<u32>());
    }
}
