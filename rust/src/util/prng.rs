//! Deterministic, seedable PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Replaces the `rand` crate (unavailable offline). Quality is more than
//! adequate for workload generation and property tests; determinism by
//! seed is what the reproduction actually needs.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded generator (any seed works, including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` (Lemire's method; `n > 0`).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Poisson sample (Knuth for small λ, normal approximation above 30).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from `0..n` (partial shuffle).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below_usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn below_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let mut buckets = [0u32; 10];
        for _ in 0..10_000 {
            let v = r.below(10);
            assert!(v < 10);
            buckets[v as usize] += 1;
        }
        for &b in &buckets {
            assert!((700..=1300).contains(&b), "bucket {b}");
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn poisson_mean_tracks_lambda() {
        let mut r = Rng::new(5);
        for &lambda in &[0.5, 4.0, 50.0] {
            let n = 5000;
            let m: f64 = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (m - lambda).abs() < lambda.max(1.0) * 0.15,
                "λ={lambda} mean={m}"
            );
        }
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(11);
        let picks = r.choose_k(100, 20);
        assert_eq!(picks.len(), 20);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}
