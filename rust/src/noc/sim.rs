//! Event-driven cycle-level NoC simulator: one [`CmRouter`] switch per
//! topology node (routers *and* core NoC interfaces), shortest-path
//! routing from a precomputed per-port table, bounded FIFOs with
//! backpressure, and energy/latency accounting (Fig. 5c).
//!
//! **Scheduling is activity-proportional**: the simulator keeps a sorted
//! worklist of *active* switches (those holding flits or pending
//! injections), maintained incrementally as flits enqueue/dequeue, and
//! [`NocSim::step`] visits only that list — an idle fabric costs ~zero
//! per cycle, so simulated time tracks traffic, not fabric size. The
//! per-flit route decision is a single indexed load
//! ([`Topology::out_port_table`]) and the link stage delivers through the
//! precomputed [`Topology::back_port_table`] instead of searching for the
//! neighbor's back-port. The pre-optimization full-scan simulator is
//! retained verbatim as [`super::reference::ReferenceNocSim`]; the
//! equivalence suite (`tests/equivalence_noc.rs`) asserts this simulator
//! is bit-identical to it (stats, ledgers, traces) across topologies and
//! load regimes.
//!
//! **Accounting is streaming**: latency/hop/stall aggregates fold at
//! delivery time (so [`NocSim::stats`] is O(1)) and the per-flit trace is
//! a [`TraceMode`] the caller picks — `Full` for tests/oracles, a
//! fixed-size `Ring` for debugging, `Off` for long-lived serving
//! sessions, which keep only the ledger and no longer grow without
//! bound.
//!
//! Each node's switch gets one port per neighbor plus a **local port**:
//! injection enqueues into the local input FIFO (arbitrating with relay
//! traffic for the node's links), ejection drains from the local output
//! FIFO. A flit's **hop count** increments on arrival at a *router* node,
//! matching the paper's hop definition; link traversals are charged
//! separately.

use super::fault::{Action, FabricHealth, FaultPlan, FaultState, LinkLevel};
use super::packet::{Dest, Flit, TxMode};
use super::router::CmRouter;
use super::topology::{NodeId, NodeKind, Topology, NO_PORT};
use crate::energy::{EnergyLedger, EnergyParams, EventClass};
use crate::{Error, Result};
use std::collections::VecDeque;
use std::ops::Range;

/// A delivered flit with measured latency.
#[derive(Debug, Clone)]
pub struct Delivered {
    /// The flit.
    pub flit: Flit,
    /// Cycles from injection to ejection.
    pub latency: u64,
}

/// What per-flit delivery record the simulator keeps. Aggregate
/// statistics ([`NocSim::stats`], [`NocSim::pj_per_hop`]) are exact in
/// every mode — the trace only affects [`NocSim::delivered`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceMode {
    /// Keep every delivery (unbounded — tests and oracles).
    Full,
    /// Keep only the most recent `n` deliveries in a fixed-size ring
    /// (bounded memory; entries are in ring order, not delivery order).
    Ring(usize),
    /// Keep no per-flit records (long-lived serving sessions: the ledger
    /// and streaming accumulators are the only state, fixing unbounded
    /// memory growth).
    Off,
}

/// Aggregate simulation statistics.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    /// Cycles simulated.
    pub cycles: u64,
    /// Flits delivered.
    pub delivered: u64,
    /// Mean latency (cycles).
    pub avg_latency: f64,
    /// Mean router hops per flit.
    pub avg_hops: f64,
    /// Max latency (cycles).
    pub max_latency: u64,
    /// Delivered flits per cycle (throughput).
    pub throughput: f64,
    /// Total backpressure stalls across switches.
    pub stalls_backpressure: u64,
    /// Total timestep-sync hang-ups.
    pub stalls_timestep: u64,
}

/// The event-driven NoC simulator.
pub struct NocSim {
    topo: Topology,
    /// `(node, dst core) → output port` (local port = neighbor count,
    /// [`NO_PORT`] = unreachable), replacing the per-flit
    /// `neighbors().position()` scan.
    out_port: Vec<Vec<u16>>,
    /// `(node, port) → receiving port at the neighbor` (link stage).
    back_port: Vec<Vec<u16>>,
    switches: Vec<CmRouter>,
    /// Per-node local-port index (== neighbor count).
    local_port: Vec<usize>,
    /// Injection staging: flits that did not fit the local FIFO yet.
    pending: Vec<VecDeque<Flit>>,
    // --- active-switch worklist ----------------------------------------
    /// Sorted ids of switches with any work (pending, input or output
    /// flits). `step` visits exactly this list.
    active: Vec<NodeId>,
    /// Nodes activated since the last `step` merge (kept separate so
    /// activation during a step never perturbs the in-flight iteration).
    incoming: Vec<NodeId>,
    is_active: Vec<bool>,
    /// Cumulative switch visits across all cycles (for the idle-fabric
    /// zero-work regression test).
    visits: u64,
    /// Whether the last `step` moved any flit (fixed-point detection).
    progress: bool,
    // --- streaming delivery accounting ---------------------------------
    delivered_n: u64,
    lat_sum: f64,
    /// Total router hops over delivered flits. `avg_hops` derives from
    /// this exactly: integer hop sums stay far below 2^53, so
    /// `hop_total as f64` is bit-identical to the reference's
    /// sequential f64 accumulation.
    hop_total: u64,
    max_latency: u64,
    stalls_bp: u64,
    stalls_ts: u64,
    trace_mode: TraceMode,
    trace: Vec<Delivered>,
    /// Ring-mode write cursor.
    trace_next: usize,
    /// When set, ejections also stage `(dst_core, axon)` pairs for the
    /// SoC to drain ([`NocSim::drain_ejected`]) — functional delivery
    /// decoupled from the trace.
    collect_ejected: bool,
    ejected: Vec<(usize, u32)>,
    // --- precomputed per-node lookups -----------------------------------
    is_l2: Vec<bool>,
    is_router: Vec<bool>,
    /// Static-power ledger keys ("router{n}" / "router-l2-{n}"; empty for
    /// cores), built once so snapshots stop `format!`-ing per switch.
    static_keys: Vec<String>,
    cycle: u64,
    next_id: u64,
    timestep: u32,
    ledger: EnergyLedger,
    energy: EnergyParams,
    in_flight: u64,
    /// Armed fault-injection state. `None` for the empty plan, so the
    /// unfaulted hot path pays exactly one predictable branch and stays
    /// bit-identical to a simulator that never saw a plan (pinned by the
    /// equivalence suite, `switch_visits` included).
    faults: Option<Box<FaultState>>,
}

impl NocSim {
    /// Build a simulator over `topo` with per-port FIFO depth `depth`.
    pub fn new(topo: Topology, depth: usize, energy: EnergyParams) -> Self {
        let out_port = topo.out_port_table();
        let back_port = topo.back_port_table();
        let mut switches = Vec::with_capacity(topo.len());
        let mut local_port = Vec::with_capacity(topo.len());
        let mut is_l2 = Vec::with_capacity(topo.len());
        let mut is_router = Vec::with_capacity(topo.len());
        let mut static_keys = Vec::with_capacity(topo.len());
        for n in 0..topo.len() {
            let mut ports = topo.neighbors(n).to_vec();
            local_port.push(ports.len());
            ports.push(n); // local port loops to self
            switches.push(CmRouter::new(n, &ports, depth));
            is_l2.push(matches!(topo.kind(n), NodeKind::RouterL2(_)));
            is_router.push(topo.kind(n).is_router());
            static_keys.push(match topo.kind(n) {
                NodeKind::Core(_) => String::new(),
                NodeKind::RouterL1(_) => format!("router{n}"),
                NodeKind::RouterL2(_) => format!("router-l2-{n}"),
            });
        }
        let n = topo.len();
        NocSim {
            topo,
            out_port,
            back_port,
            switches,
            local_port,
            pending: (0..n).map(|_| VecDeque::new()).collect(),
            active: Vec::with_capacity(n),
            incoming: Vec::with_capacity(n),
            is_active: vec![false; n],
            visits: 0,
            progress: false,
            delivered_n: 0,
            lat_sum: 0.0,
            hop_total: 0,
            max_latency: 0,
            stalls_bp: 0,
            stalls_ts: 0,
            trace_mode: TraceMode::Full,
            trace: Vec::new(),
            trace_next: 0,
            collect_ejected: false,
            ejected: Vec::new(),
            is_l2,
            is_router,
            static_keys,
            cycle: 0,
            next_id: 0,
            timestep: 0,
            ledger: EnergyLedger::new(),
            energy,
            in_flight: 0,
            faults: None,
        }
    }

    /// Arm `plan` (replacing any previous one), resolving it against the
    /// topology — seeded `kill-frac` events expand to concrete routers
    /// here. Only valid on a drained fabric. An empty plan disarms
    /// entirely: the simulator stores `None` and behaves bit-identically
    /// to one that never saw a plan.
    pub fn set_fault_plan(&mut self, plan: FaultPlan) -> Result<()> {
        debug_assert_eq!(self.in_flight, 0, "fault plan change on a busy fabric");
        if plan.is_empty() {
            self.faults = None;
            return Ok(());
        }
        self.faults = Some(FaultState::arm(&plan, &self.topo, self.out_port.clone())?);
        Ok(())
    }

    /// Degradation counters for the current accounting window (all zero,
    /// `armed == false`, when no fault plan is armed).
    pub fn fabric_health(&self) -> FabricHealth {
        self.faults.as_deref().map_or_else(FabricHealth::default, FaultState::health)
    }

    /// The topology being simulated.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Current cycle.
    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    /// Flits injected but not yet delivered.
    pub fn in_flight(&self) -> u64 {
        self.in_flight
    }

    /// Select what per-flit trace the simulator keeps (only valid on a
    /// drained fabric; the default is [`TraceMode::Full`]).
    pub fn set_trace_mode(&mut self, mode: TraceMode) {
        debug_assert_eq!(self.in_flight, 0, "trace mode change on a busy fabric");
        self.trace_mode = mode;
        self.trace.clear();
        self.trace_next = 0;
    }

    /// Enable/disable ejection staging: every delivery also pushes its
    /// `(dst_core, axon)` payload into a buffer the caller drains with
    /// [`NocSim::drain_ejected`]. This is how the SoC consumes deliveries
    /// without keeping (or rescanning) a full trace.
    pub fn set_collect_ejected(&mut self, on: bool) {
        self.collect_ejected = on;
    }

    /// Drain the staged `(dst_core, axon)` ejections in delivery order
    /// (the staging buffer is retained, so steady-state serving allocates
    /// nothing here).
    pub fn drain_ejected(&mut self) -> std::vec::Drain<'_, (usize, u32)> {
        self.ejected.drain(..)
    }

    /// Cumulative active-switch visits across all `step` calls: a drained
    /// idle fabric does no per-switch work, so this counter freezes.
    pub fn switch_visits(&self) -> u64 {
        self.visits
    }

    /// Advance the global timestep (propagates to every switch's link
    /// controller; timestep-keyed fault events whose activation this
    /// reaches fire now).
    pub fn set_timestep(&mut self, ts: u32) {
        self.timestep = ts;
        for s in &mut self.switches {
            s.timestep = ts;
        }
        if self.faults.is_some() {
            let due = self.faults.as_mut().unwrap().take_due_timestep(ts);
            for action in due {
                self.apply_fault_action(action);
            }
        }
    }

    /// Clock-gate a specific router node (failure/power experiments).
    pub fn set_node_enabled(&mut self, node: NodeId, on: bool) {
        self.switches[node].enabled = on;
    }

    // ------------------- fault injection (cold paths) --------------------

    /// Activate cycle-keyed fault events due this cycle and expire
    /// congestion windows. Called from `step` only while a plan is armed.
    fn apply_due_faults(&mut self) {
        let cycle = self.cycle;
        let expired = self.faults.as_mut().unwrap().take_expired_congestion(cycle);
        for node in expired {
            if !self.faults.as_deref().unwrap().node_dead[node] {
                self.switches[node].enabled = true;
            }
        }
        let due = self.faults.as_mut().unwrap().take_due_cycle(cycle);
        for action in due {
            self.apply_fault_action(action);
        }
    }

    fn apply_fault_action(&mut self, action: Action) {
        match action {
            Action::Kill(node) => self.kill_router(node),
            Action::CutLink(a, b) => self.cut_link(a, b),
            Action::Throttle(level, factor) => {
                let fs = self.faults.as_mut().unwrap();
                match level {
                    LinkLevel::L1 => fs.throttle_l1 = factor.max(1),
                    LinkLevel::L2 => fs.throttle_l2 = factor.max(1),
                }
            }
            Action::Congest(node, duration) => {
                let until = self.cycle + duration;
                let fs = self.faults.as_mut().unwrap();
                if fs.node_dead[node] {
                    return;
                }
                fs.congested.push((node, until));
                self.switches[node].enabled = false;
            }
        }
    }

    /// Kill `node`: permanently disable its switch, eagerly drop every
    /// flit it holds plus flits neighbors already committed onto its
    /// links, and recompute routing around it. Dropped flits leave
    /// `in_flight` (drains terminate, conservation holds as
    /// `injected == delivered + dropped + in-flight`) and each charges
    /// the `FlitDropped` ledger class.
    fn kill_router(&mut self, node: NodeId) {
        {
            let fs = self.faults.as_mut().unwrap();
            if fs.node_dead[node] {
                return;
            }
            fs.node_dead[node] = true;
            fs.degraded = true;
            fs.congested.retain(|&(n, _)| n != node);
        }
        self.switches[node].enabled = false;
        for p in 0..self.switches[node].port_count() {
            while self.switches[node].in_pop(p).is_some() {
                self.drop_flit();
            }
            while self.switches[node].out_pop(p).is_some() {
                self.drop_flit();
            }
        }
        // Routers stage no injections today, but drain defensively.
        for _ in 0..self.pending[node].len() {
            self.drop_flit();
        }
        self.pending[node].clear();
        // Flits neighbors already committed onto the now-dead links.
        for p in 0..self.local_port[node] {
            let nb = self.topo.neighbors(node)[p];
            let back = self.back_port[node][p] as usize;
            while self.switches[nb].out_pop(back).is_some() {
                self.drop_flit();
            }
        }
        self.recompute_degraded_routes();
    }

    /// Sever the link `a`–`b`: routing recomputes around it, but flits
    /// already committed to either side's output FIFO strand — the drain
    /// loop classifies that fixed point as `FabricDegraded`.
    fn cut_link(&mut self, a: NodeId, b: NodeId) {
        let (a, b) = (a.min(b), a.max(b));
        {
            let fs = self.faults.as_mut().unwrap();
            match fs.dead_links.binary_search(&(a, b)) {
                Ok(_) => return,
                Err(i) => fs.dead_links.insert(i, (a, b)),
            }
            fs.degraded = true;
        }
        self.recompute_degraded_routes();
    }

    fn recompute_degraded_routes(&mut self) {
        let fs = self.faults.as_mut().unwrap();
        fs.out_port = self.topo.out_port_table_masked(&fs.node_dead, &fs.dead_links);
    }

    /// Account one discarded flit (dead-router drain or severed route).
    fn drop_flit(&mut self) {
        self.in_flight -= 1;
        self.ledger.add1(EventClass::FlitDropped);
        self.faults.as_mut().unwrap().dropped += 1;
        self.progress = true;
    }

    /// Put `n` on the worklist for the next step (no-op when already
    /// listed).
    #[inline]
    fn activate(&mut self, n: NodeId) {
        if !self.is_active[n] {
            self.is_active[n] = true;
            self.incoming.push(n);
        }
    }

    /// Inject spikes from `src_core` (domain-local core id) to `dest`.
    /// Broadcast destinations are split into per-destination copies
    /// carrying the cheap broadcast energy class. Allocation-free: the
    /// destination list is borrowed and the returned flit ids are the
    /// consecutive range `first..last+1`.
    pub fn inject(&mut self, src_core: usize, dest: &Dest, axon: u32) -> Range<u64> {
        let src_node = self.topo.core_node(src_core);
        let (mode, dsts): (TxMode, &[usize]) = match dest {
            Dest::Core(c) => (TxMode::P2p, std::slice::from_ref(c)),
            Dest::Cores(cs) => (TxMode::Broadcast, cs),
            Dest::Merge(c) => (TxMode::Merge, std::slice::from_ref(c)),
        };
        let first = self.next_id;
        for &dst in dsts {
            let id = self.next_id;
            self.next_id += 1;
            self.pending[src_node].push_back(Flit {
                id,
                src_core,
                dst_core: dst,
                mode,
                axon,
                timestep: self.timestep,
                injected_at: self.cycle,
                hops: 0,
                at: src_node,
            });
            self.in_flight += 1;
        }
        if !dsts.is_empty() {
            self.activate(src_node);
        }
        first..self.next_id
    }

    /// Fold one delivery into the streaming accumulators (+ trace/staging
    /// per the configured modes). Order matches the ejection order, so
    /// the f64 sums are bit-identical to the reference's stats walk.
    fn record_delivery(&mut self, f: Flit) {
        let latency = self.cycle - f.injected_at;
        self.delivered_n += 1;
        self.lat_sum += latency as f64;
        self.hop_total += f.hops as u64;
        self.max_latency = self.max_latency.max(latency);
        if self.collect_ejected {
            self.ejected.push((f.dst_core, f.axon));
        }
        match self.trace_mode {
            TraceMode::Full => self.trace.push(Delivered { latency, flit: f }),
            TraceMode::Ring(cap) => {
                if cap > 0 {
                    if self.trace.len() < cap {
                        self.trace.push(Delivered { latency, flit: f });
                    } else {
                        self.trace[self.trace_next] = Delivered { latency, flit: f };
                    }
                    self.trace_next = (self.trace_next + 1) % cap;
                }
            }
            TraceMode::Off => {}
        }
    }

    /// One simulation cycle: injection → arbitration → link movement →
    /// ejection, visiting only the active switches (in ascending node
    /// order, matching the reference's full scan).
    pub fn step(&mut self) {
        self.cycle += 1;
        self.progress = false;
        if self.faults.is_some() {
            self.apply_due_faults();
        }
        if !self.incoming.is_empty() {
            self.active.append(&mut self.incoming);
            self.active.sort_unstable();
        }
        self.visits += self.active.len() as u64;
        // Detach the worklist for the duration of the step: stages borrow
        // `self` freely while iterating, and it is never modified mid-step
        // (new activations land in `incoming`, merged next cycle — a
        // switch receiving its first flit this cycle has nothing else to
        // do this cycle anyway).
        let active = std::mem::take(&mut self.active);

        // 1. Injection: move pending flits into local input FIFOs.
        for &n in &active {
            if self.pending[n].is_empty() {
                continue;
            }
            let lp = self.local_port[n];
            while self.pending[n].front().is_some() {
                if self.switches[n].can_accept(lp) {
                    let f = self.pending[n].pop_front().unwrap();
                    self.switches[n].accept(lp, f);
                    self.progress = true;
                } else {
                    break;
                }
            }
        }

        // 2. Arbitration at every active switch; stall totals fold into
        //    the simulator-level accumulators so `stats` stays O(1).
        for &n in &active {
            if self.switches[n].in_occupancy() == 0 {
                continue;
            }
            // Degraded fabric only: discard input heads whose destination
            // lost its last route (dead-router fallout) so they never
            // wedge a FIFO, then arbitrate over the degraded table.
            if matches!(self.faults.as_deref(), Some(fs) if fs.degraded) {
                for p in 0..self.switches[n].port_count() {
                    loop {
                        let unroutable = {
                            let fs = self.faults.as_deref().unwrap();
                            match self.switches[n].in_head(p) {
                                Some(f) => fs.out_port[n][f.dst_core] == NO_PORT,
                                None => false,
                            }
                        };
                        if !unroutable {
                            break;
                        }
                        self.switches[n].in_pop(p);
                        self.drop_flit();
                    }
                }
                if self.switches[n].in_occupancy() == 0 {
                    continue;
                }
            }
            let (bp0, ts0) = {
                let s = &self.switches[n];
                (s.stalls_backpressure, s.stalls_timestep)
            };
            let row: &[u16] = match self.faults.as_deref() {
                Some(fs) if fs.degraded => &fs.out_port[n],
                _ => &self.out_port[n],
            };
            let moved = self.switches[n].arbitrate(|f| {
                let p = row[f.dst_core];
                if p == NO_PORT {
                    None
                } else {
                    Some(p as usize)
                }
            });
            if moved > 0 {
                self.progress = true;
            }
            let s = &self.switches[n];
            self.stalls_bp += s.stalls_backpressure - bp0;
            self.stalls_ts += s.stalls_timestep - ts0;
        }

        // 3. Link stage: move output heads to neighbor inputs (1 per link
        //    direction per cycle); eject local-port heads.
        for &n in &active {
            if self.switches[n].out_occupancy() == 0 {
                continue;
            }
            let lp = self.local_port[n];
            // Ejection.
            if let Some(f) = self.switches[n].out_pop(lp) {
                self.in_flight -= 1;
                self.progress = true;
                self.record_delivery(f);
            }
            // Physical links: the receiving port is precomputed, so no
            // neighbor-list search per flit.
            for p in 0..lp {
                if self.switches[n].out_head(p).is_none() {
                    continue;
                }
                let nb = self.topo.neighbors(n)[p];
                let nb_is_l2 = self.is_l2[nb];
                // Fault gates (armed plans only): severed links and dead
                // endpoints strand committed flits; throttled links move
                // only on period-aligned cycles.
                if let Some(fs) = self.faults.as_deref() {
                    if fs.link_blocked(n, nb)
                        || fs.throttled(nb_is_l2 || self.is_l2[n], self.cycle)
                    {
                        continue;
                    }
                }
                let back = self.back_port[n][p] as usize;
                if self.switches[nb].can_accept(back) {
                    let mut f = self.switches[n].out_pop(p).unwrap();
                    f.at = nb;
                    // A hop over a port the pristine table would not have
                    // chosen is redundancy in action — count it.
                    if let Some(fs) = self.faults.as_deref_mut() {
                        if fs.degraded && self.out_port[n][f.dst_core] != p as u16 {
                            fs.rerouted_hops += 1;
                        }
                    }
                    // Links with an L2 endpoint are the long scale-up
                    // wires; arrival at an L2 router charges the wider
                    // crossbar's hop energy instead of the mode class.
                    self.ledger.add1(if nb_is_l2 || self.is_l2[n] {
                        EventClass::LinkL2
                    } else {
                        EventClass::LinkTraversal
                    });
                    if self.is_router[nb] {
                        f.hops += 1;
                        self.ledger.add1(if nb_is_l2 {
                            EventClass::HopL2
                        } else {
                            match f.mode {
                                TxMode::P2p => EventClass::HopP2p,
                                TxMode::Broadcast => EventClass::HopBroadcast,
                                TxMode::Merge => EventClass::HopMerge,
                            }
                        });
                    }
                    self.switches[nb].accept(back, f);
                    self.progress = true;
                    self.activate(nb);
                }
            }
        }

        // 4. Re-attach the worklist, retiring switches with no remaining
        //    work: the idle fabric does no per-switch work next cycle.
        self.active = active;
        let pending = &self.pending;
        let switches = &self.switches;
        let is_active = &mut self.is_active;
        self.active.retain(|&n| {
            let busy = !pending[n].is_empty()
                || switches[n].in_occupancy() > 0
                || switches[n].out_occupancy() > 0;
            if !busy {
                is_active[n] = false;
            }
            busy
        });
    }

    /// Run until all injected flits are delivered (or dropped by an
    /// armed fault plan). Errors after `max_cycles` without full drain —
    /// or **immediately** when a cycle makes no progress at all: the
    /// simulator is deterministic and nothing changes between `step`s
    /// here, so a zero-progress cycle is a fixed point (timestep desync,
    /// a degraded fabric stranding flits, gated routers or a
    /// backpressure deadlock) and spinning to `max_cycles` would only
    /// burn host time. The one exception: an armed fault plan can
    /// unblock the fabric by itself (pending activations, congestion
    /// expiry, throttle periods), so stagnation is tolerated exactly as
    /// long as the plan can still change state.
    pub fn run_until_drained(&mut self, max_cycles: u64) -> Result<()> {
        let start = self.cycle;
        let mut stagnant = 0u64;
        while self.in_flight > 0 {
            if self.cycle - start >= max_cycles {
                return Err(Error::Noc(format!(
                    "NoC not drained after {max_cycles} cycles ({} in flight)",
                    self.in_flight
                )));
            }
            self.step();
            if self.progress {
                stagnant = 0;
                continue;
            }
            if self.in_flight == 0 {
                break;
            }
            stagnant += 1;
            let tolerance = self
                .faults
                .as_deref()
                .map_or(0, |fs| fs.zero_progress_tolerance(self.cycle));
            if stagnant <= tolerance {
                continue;
            }
            return Err(Error::Noc(format!(
                "NoC not drained: fixed point after {} cycles with {} in \
                 flight ({})",
                self.cycle - start,
                self.in_flight,
                self.stall_reason()
            )));
        }
        Ok(())
    }

    /// Classify why the active set cannot make progress (error reporting
    /// only — runs on the cold path).
    fn stall_reason(&self) -> String {
        for &n in &self.active {
            let s = &self.switches[n];
            for p in 0..s.port_count() {
                if let Some(f) = s.in_head(p) {
                    if f.timestep != self.timestep {
                        return "stalled on timestep sync — advance with set_timestep".into();
                    }
                }
            }
        }
        if let Some(fs) = self.faults.as_deref() {
            if fs.degraded {
                return format!(
                    "FabricDegraded: {} flits stranded by killed routers/links",
                    self.in_flight
                );
            }
        }
        "gated routers or a backpressure deadlock".into()
    }

    /// Per-flit delivery trace under the configured [`TraceMode`]: every
    /// delivery (`Full`), the most recent ones in ring order (`Ring`), or
    /// empty (`Off`). Aggregate stats never depend on this.
    pub fn delivered(&self) -> &[Delivered] {
        &self.trace
    }

    /// Aggregate statistics — O(1): folded incrementally at delivery and
    /// arbitration time, never re-walking switches or the trace.
    pub fn stats(&self) -> SimStats {
        let n = self.delivered_n as f64;
        SimStats {
            cycles: self.cycle,
            delivered: self.delivered_n,
            avg_latency: if n > 0.0 { self.lat_sum / n } else { 0.0 },
            avg_hops: if n > 0.0 { self.hop_total as f64 / n } else { 0.0 },
            max_latency: self.max_latency,
            throughput: if self.cycle > 0 {
                n / self.cycle as f64
            } else {
                0.0
            },
            stalls_backpressure: self.stalls_bp,
            stalls_timestep: self.stalls_ts,
        }
    }

    /// Non-destructive ledger assembly: a copy of the accumulated dynamic
    /// ledger plus router static power over the simulated window so far.
    /// Level-2 routers carry their own (larger) static power class. The
    /// simulator state is untouched, so this can back an incremental
    /// report snapshot mid-run. Ledger keys are precomputed at
    /// construction — no per-snapshot string formatting.
    pub fn snapshot_ledger(&self) -> EnergyLedger {
        let mut ledger = self.ledger.clone();
        for s in &self.switches {
            let key = &self.static_keys[s.node];
            if key.is_empty() {
                continue; // core NoC interfaces carry no router static power
            }
            let active = s.active_cycles.min(self.cycle);
            let (p_active, p_gated) = if self.is_l2[s.node] {
                (self.energy.p_router_l2_active, self.energy.p_router_l2_gated)
            } else {
                (self.energy.p_router_active, self.energy.p_router_gated)
            };
            ledger.add_static(key, active, self.cycle - active, p_active, p_gated);
        }
        ledger
    }

    /// Account router static power over the simulated window and return
    /// the accumulated ledger (dynamic events + static), draining the
    /// internal dynamic ledger.
    pub fn finish_ledger(&mut self) -> EnergyLedger {
        let ledger = self.snapshot_ledger();
        self.ledger = EnergyLedger::new();
        ledger
    }

    /// Reset energy/latency accounting (dynamic ledger, per-switch
    /// activity/stall counters, delivery accumulators/trace and the
    /// cycle counter) so a new measurement window starts from zero —
    /// on a reused chip, [`NocSim::stats`] then reports exactly the new
    /// window (sessions must never see a predecessor's stalls). An armed
    /// fault plan is healed and **re-armed from scratch** (switches
    /// re-enabled, routes restored, counters zeroed, schedule rewound):
    /// a warm chip after a faulted session must be bit-identical to a
    /// fresh one. Only valid while the fabric is drained (no flits in
    /// flight). The [`NocSim::switch_visits`] diagnostic stays
    /// lifetime-cumulative.
    pub fn reset_accounting(&mut self) {
        debug_assert_eq!(self.in_flight, 0, "reset_accounting on a busy fabric");
        self.ledger = EnergyLedger::new();
        self.trace.clear();
        self.trace_next = 0;
        self.ejected.clear();
        self.delivered_n = 0;
        self.lat_sum = 0.0;
        self.hop_total = 0;
        self.max_latency = 0;
        self.stalls_bp = 0;
        self.stalls_ts = 0;
        self.cycle = 0;
        for s in &mut self.switches {
            s.active_cycles = 0;
            s.switched = 0;
            s.stalls_backpressure = 0;
            s.stalls_timestep = 0;
            s.stalls_matrix = 0;
        }
        if let Some(fs) = self.faults.as_deref() {
            for n in 0..self.switches.len() {
                if fs.node_dead[n] {
                    self.switches[n].enabled = true;
                }
            }
            for &(n, _) in &fs.congested {
                self.switches[n].enabled = true;
            }
            let plan = fs.plan.clone();
            self.faults = Some(
                FaultState::arm(&plan, &self.topo, self.out_port.clone())
                    .expect("a previously armed plan re-validates"),
            );
        }
    }

    /// Dynamic-only energy (pJ) of NoC activity so far.
    pub fn dynamic_pj(&self) -> f64 {
        self.ledger.dynamic_pj(&self.energy)
    }

    /// Dynamic energy per delivered flit-hop (pJ/hop) — Fig. 5c metric.
    /// Includes level-2 hops when the fabric has them.
    pub fn pj_per_hop(&self) -> Option<f64> {
        let hops = self.hop_total;
        (hops > 0).then(|| {
            let hop_pj = self.ledger.count(EventClass::HopP2p) as f64 * self.energy.e_hop_p2p
                + self.ledger.count(EventClass::HopBroadcast) as f64 * self.energy.e_hop_bcast
                + self.ledger.count(EventClass::HopMerge) as f64 * self.energy.e_hop_merge
                + self.ledger.count(EventClass::HopL2) as f64 * self.energy.e_hop_l2;
            hop_pj / hops as f64
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(topo: Topology) -> NocSim {
        NocSim::new(topo, 4, EnergyParams::nominal())
    }

    #[test]
    fn p2p_delivery_on_fullerene() {
        let mut s = sim(Topology::fullerene());
        s.inject(0, &Dest::Core(13), 7);
        s.run_until_drained(1000).unwrap();
        let d = &s.delivered()[0];
        assert_eq!(d.flit.dst_core, 13);
        assert_eq!(d.flit.axon, 7);
        assert!(d.flit.hops >= 1);
        assert!(d.latency >= d.flit.hops as u64);
    }

    #[test]
    fn broadcast_reaches_every_destination() {
        let mut s = sim(Topology::fullerene());
        let dsts = vec![1, 5, 9, 13, 17];
        s.inject(0, &Dest::Cores(dsts.clone()), 3);
        s.run_until_drained(2000).unwrap();
        let mut got: Vec<usize> = s.delivered().iter().map(|d| d.flit.dst_core).collect();
        got.sort_unstable();
        assert_eq!(got, dsts);
        // Broadcast copies charge the cheap hop class.
        assert!(s.ledger.count(EventClass::HopBroadcast) > 0);
        assert_eq!(s.ledger.count(EventClass::HopP2p), 0);
    }

    #[test]
    fn hop_counts_match_bfs_distance_under_light_load() {
        let t = Topology::fullerene();
        let table_free = t.clone();
        let mut s = sim(t);
        for dst in 1..20 {
            s.inject(0, &Dest::Core(dst), 0);
            s.run_until_drained(1000).unwrap();
        }
        // With one flit at a time, hops = router nodes on the shortest
        // path = BFS distance / 2 (alternating core/router layers).
        let d0 = table_free.bfs(table_free.core_node(0));
        for d in s.delivered() {
            let bfs = d0[table_free.core_node(d.flit.dst_core)];
            assert_eq!(
                d.flit.hops as usize,
                bfs / 2,
                "dst {} bfs {bfs}",
                d.flit.dst_core
            );
        }
    }

    #[test]
    fn merge_mode_uses_merge_energy() {
        let mut s = sim(Topology::fullerene());
        s.inject(2, &Dest::Merge(7), 0);
        s.inject(3, &Dest::Merge(7), 1);
        s.run_until_drained(1000).unwrap();
        assert_eq!(s.delivered().len(), 2);
        assert!(s.ledger.count(EventClass::HopMerge) > 0);
    }

    #[test]
    fn timestep_desync_blocks_until_advanced() {
        let mut s = sim(Topology::fullerene());
        s.inject(0, &Dest::Core(10), 0);
        s.set_timestep(1); // switches ahead of the flit's tag
        for _ in 0..50 {
            s.step();
        }
        assert_eq!(s.delivered().len(), 0, "desynced flit must not move");
        assert!(s.stats().stalls_timestep > 0);
        s.set_timestep(0);
        s.run_until_drained(1000).unwrap();
        assert_eq!(s.delivered().len(), 1);
    }

    #[test]
    fn timestep_desync_fails_a_drain_fast_with_cause() {
        let mut s = sim(Topology::fullerene());
        s.inject(0, &Dest::Core(10), 0);
        s.set_timestep(5);
        let err = s.run_until_drained(1_000_000).unwrap_err();
        assert!(err.to_string().contains("timestep"), "{err}");
        // Fast-forwarded: nowhere near the cycle budget was burned.
        assert!(s.cycle() < 100, "spun {} cycles", s.cycle());
    }

    #[test]
    fn gated_router_detected_as_undrained() {
        let mut s = sim(Topology::ring(6));
        // Gate every router: flits can never move.
        let routers = s.topology().routers();
        for r in routers {
            s.set_node_enabled(r, false);
        }
        s.inject(0, &Dest::Core(3), 0);
        assert!(s.run_until_drained(200).is_err());
    }

    #[test]
    fn saturation_throughput_bounded_by_link_capacity() {
        let mut s = sim(Topology::fullerene());
        // Saturate: every core sends to a far core repeatedly.
        for round in 0..20 {
            for c in 0..20 {
                s.inject(c, &Dest::Core((c + 10) % 20), round);
            }
        }
        s.run_until_drained(100_000).unwrap();
        let st = s.stats();
        assert_eq!(st.delivered, 400);
        assert!(st.throughput > 0.0);
        assert!(st.avg_latency >= st.avg_hops);
    }

    #[test]
    fn pj_per_hop_matches_p2p_constant_under_pure_p2p() {
        let mut s = sim(Topology::fullerene());
        for dst in 1..20 {
            s.inject(0, &Dest::Core(dst), 0);
        }
        s.run_until_drained(10_000).unwrap();
        let pj = s.pj_per_hop().unwrap();
        assert!((pj - EnergyParams::nominal().e_hop_p2p).abs() < 1e-9);
    }

    #[test]
    fn cross_domain_flit_traverses_l2_and_charges_l2_energy() {
        let mut s = sim(Topology::multi_domain(2));
        s.inject(0, &Dest::Core(25), 4);
        s.run_until_drained(10_000).unwrap();
        assert_eq!(s.delivered().len(), 1);
        let d = &s.delivered()[0];
        // climb (L1, L2) + one ring link (L2) + descend (L1): 4 router
        // arrivals, two of them at L2 routers.
        assert_eq!(d.flit.hops, 4);
        assert_eq!(s.ledger.count(EventClass::HopL2), 2);
        // L1→L2, L2→L2 and L2→L1 wires all charge the L2 link class.
        assert_eq!(s.ledger.count(EventClass::LinkL2), 3);
        assert_eq!(s.ledger.count(EventClass::HopP2p), 2);
    }

    #[test]
    fn intra_domain_traffic_on_multidomain_charges_no_l2() {
        let mut s = sim(Topology::multi_domain(2));
        for dst in 1..20 {
            s.inject(0, &Dest::Core(dst), 0);
            s.inject(20, &Dest::Core(20 + dst), 0);
        }
        s.run_until_drained(100_000).unwrap();
        assert_eq!(s.delivered().len(), 38);
        assert_eq!(s.ledger.count(EventClass::HopL2), 0);
        assert_eq!(s.ledger.count(EventClass::LinkL2), 0);
    }

    #[test]
    fn l2_static_power_lands_in_its_own_ledger_entries() {
        let mut s = sim(Topology::multi_domain(2));
        s.inject(0, &Dest::Core(25), 0);
        s.run_until_drained(10_000).unwrap();
        let ledger = s.finish_ledger();
        let b = ledger.breakdown(&EnergyParams::nominal(), 100.0e6);
        assert!(
            b.by_static.keys().any(|k| k.starts_with("router-l2-")),
            "missing L2 static entries: {:?}",
            b.by_static.keys().collect::<Vec<_>>()
        );
    }

    #[test]
    fn mesh_delivery_works_too() {
        let mut s = sim(Topology::mesh2d(4, 5));
        s.inject(0, &Dest::Core(19), 0);
        s.run_until_drained(1000).unwrap();
        assert_eq!(s.delivered().len(), 1);
    }

    #[test]
    fn reset_accounting_starts_a_fresh_stall_window() {
        let mut s = sim(Topology::fullerene());
        s.inject(0, &Dest::Core(10), 0);
        s.set_timestep(1); // desync → stalls accumulate
        for _ in 0..10 {
            s.step();
        }
        s.set_timestep(0);
        s.run_until_drained(1000).unwrap();
        assert!(s.stats().stalls_timestep > 0);
        s.reset_accounting();
        let st = s.stats();
        assert_eq!(st.delivered, 0);
        assert_eq!(st.cycles, 0);
        assert_eq!(st.stalls_timestep, 0, "stalls must be per-window");
        assert_eq!(st.stalls_backpressure, 0);
    }

    #[test]
    fn idle_fabric_does_no_per_switch_work() {
        let mut s = sim(Topology::multi_domain(4));
        s.inject(0, &Dest::Core(70), 0);
        s.run_until_drained(10_000).unwrap();
        let v = s.switch_visits();
        assert!(v > 0);
        for _ in 0..1000 {
            s.step();
        }
        assert_eq!(s.switch_visits(), v, "drained fabric still visited switches");
    }

    #[test]
    fn trace_ring_bounds_memory_and_keeps_stats_exact() {
        let run = |mode: TraceMode| {
            let mut s = sim(Topology::fullerene());
            s.set_trace_mode(mode);
            for round in 0..5u32 {
                for c in 0..20 {
                    s.inject(c, &Dest::Core((c + 9) % 20), round);
                }
            }
            s.run_until_drained(100_000).unwrap();
            s
        };
        let full = run(TraceMode::Full);
        let ring = run(TraceMode::Ring(8));
        let off = run(TraceMode::Off);
        assert_eq!(full.delivered().len(), 100);
        assert_eq!(ring.delivered().len(), 8, "ring must stay fixed-size");
        assert!(off.delivered().is_empty());
        // Streaming aggregates are exact regardless of trace mode.
        for other in [&ring, &off] {
            let (a, b) = (full.stats(), other.stats());
            assert_eq!(a.delivered, b.delivered);
            assert_eq!(a.avg_latency.to_bits(), b.avg_latency.to_bits());
            assert_eq!(a.avg_hops.to_bits(), b.avg_hops.to_bits());
            assert_eq!(a.max_latency, b.max_latency);
            assert_eq!(
                full.pj_per_hop().unwrap().to_bits(),
                other.pj_per_hop().unwrap().to_bits()
            );
        }
    }

    #[test]
    fn ejection_staging_carries_payloads_in_delivery_order() {
        let mut s = sim(Topology::fullerene());
        s.set_trace_mode(TraceMode::Off);
        s.set_collect_ejected(true);
        s.inject(0, &Dest::Cores(vec![3, 7, 11]), 42);
        s.run_until_drained(10_000).unwrap();
        let got: Vec<(usize, u32)> = s.drain_ejected().collect();
        let mut dsts: Vec<usize> = got.iter().map(|&(d, _)| d).collect();
        dsts.sort_unstable();
        assert_eq!(dsts, vec![3, 7, 11]);
        assert!(got.iter().all(|&(_, a)| a == 42));
        // Drained: second drain yields nothing.
        assert_eq!(s.drain_ejected().count(), 0);
    }

    #[test]
    fn inject_returns_consecutive_id_range() {
        let mut s = sim(Topology::fullerene());
        let a = s.inject(0, &Dest::Core(5), 0);
        assert_eq!((a.start, a.end), (0, 1));
        let b = s.inject(1, &Dest::Cores(vec![2, 3, 4]), 0);
        assert_eq!((b.start, b.end), (1, 4));
        assert_eq!(s.in_flight(), 4);
    }

    // ---------------------- fault injection ----------------------------

    use super::super::fault::When;

    /// A `(src core, dst core)` pair whose pristine route leaves the
    /// source over the link to `router` — traffic guaranteed to feel a
    /// fault at that router.
    fn pair_via_router(t: &Topology, router: NodeId) -> (usize, usize) {
        let out = t.out_port_table();
        for c in 0..t.cores().len() {
            let n = t.core_node(c);
            for dst in 0..t.cores().len() {
                if dst == c {
                    continue;
                }
                let p = out[n][dst];
                if p != NO_PORT && t.neighbors(n)[p as usize] == router {
                    return (c, dst);
                }
            }
        }
        panic!("no pristine route uses router {router}");
    }

    #[test]
    fn empty_fault_plan_is_disarmed_and_free() {
        let drive = |s: &mut NocSim| {
            for c in 0..20 {
                s.inject(c, &Dest::Core((c + 7) % 20), 0);
            }
            s.run_until_drained(10_000).unwrap();
        };
        let mut plain = sim(Topology::fullerene());
        drive(&mut plain);
        let mut armed = sim(Topology::fullerene());
        armed.set_fault_plan(FaultPlan::none()).unwrap();
        assert_eq!(armed.fabric_health(), FabricHealth::default());
        assert!(!armed.fabric_health().armed);
        drive(&mut armed);
        let (a, b) = (plain.stats(), armed.stats());
        assert_eq!(a.avg_latency.to_bits(), b.avg_latency.to_bits());
        assert_eq!(a.delivered, b.delivered);
        assert_eq!(plain.switch_visits(), armed.switch_visits());
    }

    #[test]
    fn invalid_fault_plan_is_rejected_at_arm() {
        let mut s = sim(Topology::fullerene());
        // Node 15 is a core, not a router.
        let plan = FaultPlan::none().kill_router(15, When::Cycle(1));
        assert!(s.set_fault_plan(plan).is_err());
        // The rejected plan leaves the simulator disarmed.
        assert!(!s.fabric_health().armed);
    }

    #[test]
    fn single_router_kill_on_fullerene_reroutes_and_delivers_everything() {
        let t = Topology::fullerene();
        let (c, dst) = pair_via_router(&t, 0);
        let mut s = sim(t);
        s.set_fault_plan(FaultPlan::none().kill_router(0, When::Cycle(0)))
            .unwrap();
        for src in 0..20 {
            s.inject(src, &Dest::Core((src + 7) % 20), 0);
        }
        s.inject(c, &Dest::Core(dst), 1);
        s.run_until_drained(10_000).unwrap();
        let h = s.fabric_health();
        // Every core keeps 2 live routers: nothing drops, detours absorb
        // the kill — the degree-redundancy the paper's topology buys.
        assert_eq!(s.stats().delivered, 21);
        assert_eq!(h.dropped, 0);
        assert_eq!(h.dead_routers, 1);
        assert_eq!(h.dead_links, 0);
        assert!(h.rerouted_hops >= 1, "kill must force a detour");
        assert_eq!(s.ledger.count(EventClass::FlitDropped), 0);
    }

    #[test]
    fn kill_drops_flits_inside_the_dead_router() {
        let t = Topology::fullerene();
        let (c, dst) = pair_via_router(&t, 0);
        let mut s = sim(t);
        s.set_fault_plan(FaultPlan::none().kill_router(0, When::Cycle(2)))
            .unwrap();
        s.inject(c, &Dest::Core(dst), 0);
        s.step(); // flit now sits in router 0's input FIFO
        assert_eq!(s.in_flight(), 1);
        s.step(); // cycle 2: the kill fires and drains it
        assert_eq!(s.in_flight(), 0);
        let h = s.fabric_health();
        assert_eq!(h.dropped, 1);
        assert_eq!(s.ledger.count(EventClass::FlitDropped), 1);
        assert_eq!(s.stats().delivered, 0);
        // Nothing in flight: the drain returns immediately.
        s.run_until_drained(10).unwrap();
    }

    #[test]
    fn kill_mid_burst_conserves_flits_and_is_deterministic() {
        let run = || {
            let mut s = sim(Topology::fullerene());
            s.set_fault_plan(
                FaultPlan::none()
                    .kill_router(3, When::Cycle(5))
                    .kill_router(7, When::Cycle(9)),
            )
            .unwrap();
            for round in 0..10 {
                for c in 0..20 {
                    s.inject(c, &Dest::Core((c + 9) % 20), round);
                }
            }
            s.run_until_drained(100_000).unwrap();
            s
        };
        let a = run();
        let h = a.fabric_health();
        assert_eq!(a.stats().delivered + h.dropped, 200, "flit conservation");
        assert_eq!(a.in_flight(), 0);
        assert_eq!(a.ledger.count(EventClass::FlitDropped), h.dropped);
        assert_eq!(h.dead_routers, 2);
        let b = run();
        assert_eq!(a.fabric_health(), b.fabric_health());
        let (sa, sb) = (a.stats(), b.stats());
        assert_eq!(sa.delivered, sb.delivered);
        assert_eq!(sa.avg_latency.to_bits(), sb.avg_latency.to_bits());
        assert_eq!(sa.avg_hops.to_bits(), sb.avg_hops.to_bits());
        assert_eq!(a.switch_visits(), b.switch_visits());
    }

    #[test]
    fn congestion_stalls_then_recovers() {
        let t = Topology::fullerene();
        let out = t.out_port_table();
        let n0 = t.core_node(0);
        let r = t.neighbors(n0)[out[n0][10] as usize];
        let lat0 = {
            let mut s = sim(t.clone());
            s.inject(0, &Dest::Core(10), 0);
            s.run_until_drained(1000).unwrap();
            s.delivered()[0].latency
        };
        let mut s = sim(t);
        s.set_fault_plan(FaultPlan::none().congest(r, 40, When::Cycle(2)))
            .unwrap();
        s.inject(0, &Dest::Core(10), 0);
        // The drain survives the zero-progress window: the plan knows the
        // congestion self-expires.
        s.run_until_drained(10_000).unwrap();
        let lat = s.delivered()[0].latency;
        assert!(lat > lat0 + 30, "congested {lat} vs clean {lat0}");
        let h = s.fabric_health();
        assert_eq!(h.dropped, 0);
        assert_eq!(h.dead_routers, 0);
        assert!(h.armed);
    }

    #[test]
    fn throttled_links_slow_traffic_but_deliver() {
        let lat0 = {
            let mut s = sim(Topology::fullerene());
            s.inject(0, &Dest::Core(10), 0);
            s.run_until_drained(1000).unwrap();
            s.delivered()[0].latency
        };
        let mut s = sim(Topology::fullerene());
        s.set_fault_plan(FaultPlan::none().throttle(LinkLevel::L1, 4, When::Cycle(0)))
            .unwrap();
        s.inject(0, &Dest::Core(10), 0);
        s.run_until_drained(10_000).unwrap();
        let lat = s.delivered()[0].latency;
        assert!(lat > lat0, "throttled {lat} vs clean {lat0}");
        let h = s.fabric_health();
        assert_eq!(h.dropped, 0);
        assert_eq!(h.dead_routers, 0);
    }

    #[test]
    fn timestep_keyed_fault_fires_when_the_timestep_arrives() {
        let mut s = sim(Topology::fullerene());
        s.set_fault_plan(FaultPlan::none().kill_router(0, When::Timestep(2)))
            .unwrap();
        s.set_timestep(1);
        assert_eq!(s.fabric_health().dead_routers, 0);
        s.set_timestep(2);
        assert_eq!(s.fabric_health().dead_routers, 1);
        s.set_timestep(3); // fires once
        assert_eq!(s.fabric_health().dead_routers, 1);
    }

    #[test]
    fn reset_accounting_heals_and_re_arms_bit_identically() {
        let t = Topology::fullerene();
        let (c, dst) = pair_via_router(&t, 0);
        let mut s = sim(t);
        s.set_fault_plan(FaultPlan::none().kill_router(0, When::Cycle(2)))
            .unwrap();
        let window = |s: &mut NocSim| {
            s.inject(c, &Dest::Core(dst), 0);
            for src in 0..20 {
                s.inject(src, &Dest::Core((src + 7) % 20), 0);
            }
            s.run_until_drained(10_000).unwrap();
            (s.stats(), s.fabric_health())
        };
        let (st1, h1) = window(&mut s);
        assert_eq!(h1.dead_routers, 1);
        s.reset_accounting();
        // Healed + rewound: nothing dead, nothing counted, still armed.
        let h = s.fabric_health();
        assert!(h.armed);
        assert_eq!(h.dead_routers, 0);
        assert_eq!(h.dropped, 0);
        assert_eq!(h.rerouted_hops, 0);
        let (st2, h2) = window(&mut s);
        assert_eq!(h1, h2, "warm window must replay the fault identically");
        assert_eq!(st1.delivered, st2.delivered);
        assert_eq!(st1.avg_latency.to_bits(), st2.avg_latency.to_bits());
        assert_eq!(st1.avg_hops.to_bits(), st2.avg_hops.to_bits());
        assert_eq!(st1.max_latency, st2.max_latency);
    }
}
