"""L2 model tests: float/integer forwards, training convergence on a toy
task, and float→int conversion consistency."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model, train
from compile.kernels import ref


def toy_spec(inputs=24, hidden=16, classes=3, timesteps=6):
    return model.NetSpec(name="toy", inputs=inputs, hidden=(hidden,),
                         classes=classes, timesteps=timesteps)


def toy_data(spec, n, seed):
    """Linearly separable toy task: class c lights input block c."""
    rng = np.random.default_rng(seed)
    block = spec.inputs // spec.classes
    rasters = np.zeros((n, spec.timesteps, spec.inputs), dtype=bool)
    labels = rng.integers(0, spec.classes, n)
    for i, y in enumerate(labels):
        lo = int(y) * block
        p = np.full(spec.inputs, 0.02)
        p[lo:lo + block] = 0.5
        rasters[i] = rng.random((spec.timesteps, spec.inputs)) < p
    return rasters, labels


def test_float_forward_shapes():
    spec = toy_spec()
    params = model.init_params(spec, jax.random.PRNGKey(0))
    raster = jnp.zeros((spec.timesteps, spec.inputs), jnp.float32)
    counts = model.float_forward(params, raster, spec)
    assert counts.shape == (spec.classes,)
    batch = model.batched_float_forward(
        params, jnp.zeros((4, spec.timesteps, spec.inputs)), spec)
    assert batch.shape == (4, spec.classes)


def test_spike_fn_surrogate_gradient_nonzero():
    g = jax.grad(lambda v: model.spike_fn(v))(0.05)
    assert g > 0.0
    g_far = jax.grad(lambda v: model.spike_fn(v))(5.0)
    assert g_far < g  # surrogate decays away from the threshold


def test_training_learns_toy_task():
    spec = toy_spec()
    x, y = toy_data(spec, 120, seed=0)
    params, acc = train.train_float(spec, x, y, epochs=12, batch=32,
                                    lr=5e-3, seed=0, log=lambda *_: None)
    assert acc > 0.9, f"float train acc {acc}"


def test_int_conversion_preserves_function():
    spec = toy_spec()
    x, y = toy_data(spec, 120, seed=1)
    params, _ = train.train_float(spec, x, y, epochs=12, batch=32, lr=5e-3,
                                  seed=1, log=lambda *_: None)
    int_layers, scales = train.to_int_layers(spec, params)
    assert len(int_layers) == 2 and all(s > 0 for s in scales)
    xt, yt = toy_data(spec, 60, seed=2)
    acc = model.int_accuracy(int_layers, xt, yt)
    assert acc > 0.8, f"integer acc {acc} lost too much vs float"


def test_int_forward_pallas_equals_oracle_path():
    spec = toy_spec()
    x, y = toy_data(spec, 40, seed=3)
    params, _ = train.train_float(spec, x, y, epochs=6, batch=20, lr=5e-3,
                                  seed=3, log=lambda *_: None)
    int_layers, _ = train.to_int_layers(spec, params)
    r = jnp.asarray(x[0], jnp.int32)
    via_pallas = model.int_forward(int_layers, r, use_pallas=True)
    via_ref = model.int_forward(int_layers, r, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(via_pallas),
                                  np.asarray(via_ref))


def test_int_forward_deterministic():
    spec = toy_spec()
    x, _ = toy_data(spec, 10, seed=4)
    params = model.init_params(spec, jax.random.PRNGKey(4))
    int_layers, _ = train.to_int_layers(spec, params)
    r = jnp.asarray(x[0], jnp.int32)
    a = model.int_forward(int_layers, r, use_pallas=False)
    b = model.int_forward(int_layers, r, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_int_layer_params_within_mp_range():
    spec = toy_spec()
    params = model.init_params(spec, jax.random.PRNGKey(5))
    int_layers, _ = train.to_int_layers(spec, params)
    for l in int_layers:
        hi = (1 << (l.params.mp_bits - 1)) - 1
        assert 0 < l.params.threshold <= hi
        assert l.params.leak_mode in (ref.LEAK_NONE, ref.LEAK_LINEAR)
