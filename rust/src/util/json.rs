//! Minimal JSON parser + writer (replaces `serde_json`, unavailable in the
//! offline environment).
//!
//! Scope: full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null), good errors with byte offsets, and typed
//! accessors. This is the interchange format between the Python compile
//! path (`python/compile/aot.py` emits `artifacts/*.json`) and the Rust
//! runtime, so strictness matters more than speed.

use crate::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- typed accessors ---------------------------------------------------

    /// As f64, or error.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(Error::Json(format!("expected number, got {}", other.kind()))),
        }
    }

    /// As i64 (must be integral), or error.
    pub fn as_i64(&self) -> Result<i64> {
        let f = self.as_f64()?;
        // lint:allow(no-float-eq) fract()==0 is the exact IEEE integrality test, not a tolerance check
        if f.fract() != 0.0 || f.abs() > 2f64.powi(53) {
            return Err(Error::Json(format!("expected integer, got {f}")));
        }
        Ok(f as i64)
    }

    /// As usize, or error.
    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        usize::try_from(i).map_err(|_| Error::Json(format!("expected usize, got {i}")))
    }

    /// As bool, or error.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(Error::Json(format!("expected bool, got {}", other.kind()))),
        }
    }

    /// As string slice, or error.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::Json(format!("expected string, got {}", other.kind()))),
        }
    }

    /// As array slice, or error.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(Error::Json(format!("expected array, got {}", other.kind()))),
        }
    }

    /// As object map, or error.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(Error::Json(format!("expected object, got {}", other.kind()))),
        }
    }

    /// Object field access, or error naming the missing key.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Json(format!("missing key '{key}'")))
    }

    /// Optional object field.
    pub fn get_opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    /// Array of i64s.
    pub fn as_i64_vec(&self) -> Result<Vec<i64>> {
        self.as_arr()?.iter().map(|v| v.as_i64()).collect()
    }

    /// Array of f64s.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    // ---- construction helpers ----------------------------------------------

    /// Object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array from i64 iterator.
    pub fn from_i64s(it: impl IntoIterator<Item = i64>) -> Json {
        Json::Arr(it.into_iter().map(|v| Json::Num(v as f64)).collect())
    }

    /// Array from f64 iterator.
    pub fn from_f64s(it: impl IntoIterator<Item = f64>) -> Json {
        Json::Arr(it.into_iter().map(Json::Num).collect())
    }

    // ---- parse / write -------------------------------------------------------

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(input: &str) -> Result<Json> {
        let mut p = Parser {
            b: input.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::Json(format!("trailing data at byte {}", p.i)));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                // lint:allow(no-float-eq) fract()==0 is the exact IEEE integrality test, not a tolerance check
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Read and parse a JSON file.
    pub fn read_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Artifact(format!("cannot read {}: {e}", path.display()))
        })?;
        Json::parse(&text)
    }

    /// Write to a file.
    pub fn write_file(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_string())?;
        Ok(())
    }
}

/// Escape one string for emission. Every control character (C0 and
/// DEL) is escaped — short forms where JSON has them, `\uXXXX`
/// otherwise — and non-BMP codepoints are written as UTF-16 surrogate
/// pairs, so emitted strings survive any spec-conforming parser (the
/// HTTP front end serves these bytes to arbitrary clients; a raw
/// control byte would make /metrics and outcome payloads invalid JSON).
/// BMP characters above 0x7F stay raw UTF-8.
fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 || (c as u32) == 0x7f => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c if (c as u32) > 0xFFFF => {
                let v = (c as u32) - 0x10000;
                let hi = 0xD800 + (v >> 10);
                let lo = 0xDC00 + (v & 0x3FF);
                let _ = write!(out, "\\u{hi:04x}\\u{lo:04x}");
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.i)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Json(format!(
                "unexpected {:?} at byte {}",
                other.map(|b| b as char),
                self.i
            ))),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.ws();
            arr.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Json("unterminated string".into())),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self
                        .peek()
                        .ok_or_else(|| Error::Json("unterminated escape".into()))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(Error::Json("truncated \\u escape".into()));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            self.i += 4;
                            // Surrogate pairs: decode \uD800-\uDBFF + \uDC00-\uDFFF.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])
                                            .map_err(|_| Error::Json("bad surrogate".into()))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| Error::Json("bad surrogate".into()))?;
                                    self.i += 6;
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(Error::Json("lone surrogate".into()));
                                }
                            } else {
                                cp
                            };
                            s.push(
                                char::from_u32(ch)
                                    .ok_or_else(|| Error::Json("invalid codepoint".into()))?,
                            );
                        }
                        other => {
                            return Err(Error::Json(format!(
                                "bad escape '\\{}'",
                                other as char
                            )))
                        }
                    }
                }
                Some(_) => {
                    // Consume a maximal run of plain bytes in one step
                    // (O(n) overall — per-char validation of the rest of
                    // the input would be quadratic on multi-MB strings).
                    let start = self.i;
                    while let Some(&c) = self.b.get(self.i) {
                        if c == b'"' || c == b'\\' || c < 0x20 {
                            break;
                        }
                        self.i += 1;
                    }
                    if let Some(&c) = self.b.get(self.i) {
                        if c < 0x20 && c != b'"' && c != b'\\' {
                            return Err(Error::Json("raw control char in string".into()));
                        }
                    }
                    let run = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| Error::Json("invalid utf-8".into()))?;
                    s.push_str(run);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Json(format!("bad number '{text}' at byte {start}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "s": "x\ny"}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.get("a").unwrap().as_f64_vec().unwrap(), vec![1.0, 2.5, -300.0]);
        assert!(v.get("b").unwrap().get("c").unwrap().as_bool().unwrap());
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_trailing_garbage_and_bad_syntax() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("01a").is_err());
    }

    #[test]
    fn integer_accessors_enforce_integrality() {
        let v = Json::parse("[1, 1.5]").unwrap();
        assert_eq!(v.as_arr().unwrap()[0].as_i64().unwrap(), 1);
        assert!(v.as_arr().unwrap()[1].as_i64().is_err());
    }

    #[test]
    fn unicode_escapes_and_surrogates() {
        let v = Json::parse(r#""Aé😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé😀");
    }

    /// The serializer must emit strictly valid JSON for hostile string
    /// content: control characters (including \b, \f and DEL, which the
    /// old writer passed through raw) and non-BMP codepoints round-trip
    /// through our own parser, and the escaped forms are what a
    /// spec-conforming third-party parser expects.
    #[test]
    fn escapes_control_chars_and_non_bmp_round_trip() {
        let hostile = "a\u{0}b\u{1}c\u{8}d\u{c}e\u{1f}f\u{7f}g😀h𝕊i\né—ok";
        let v = Json::obj(vec![("s", Json::Str(hostile.into()))]);
        let wire = v.to_string();
        // No raw control byte may survive onto the wire.
        assert!(
            wire.bytes().all(|b| b >= 0x20),
            "raw control byte in emitted JSON: {wire:?}"
        );
        // Short escapes and surrogate pairs, not raw passthrough.
        assert!(wire.contains("\\u0000"));
        assert!(wire.contains("\\b"));
        assert!(wire.contains("\\f"));
        assert!(wire.contains("\\u007f"));
        assert!(wire.contains("\\ud83d\\ude00"), "😀 as a surrogate pair");
        assert!(wire.contains("\\ud835\\udd4a"), "𝕊 as a surrogate pair");
        // BMP non-ASCII stays raw UTF-8 (no escaping needed).
        assert!(wire.contains('é'));
        // Full round trip through our own parser is lossless.
        let re = Json::parse(&wire).unwrap();
        assert_eq!(re.get("s").unwrap().as_str().unwrap(), hostile);
        // Keys get the same treatment as values.
        let k = Json::obj(vec![("x\u{2}😀", Json::Num(1.0))]);
        let re = Json::parse(&k.to_string()).unwrap();
        assert!(re.get_opt("x\u{2}😀").is_some());
    }

    #[test]
    fn missing_key_error_names_key() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        let err = v.get("missing").unwrap_err();
        assert!(err.to_string().contains("missing"));
    }

    #[test]
    fn writes_compact_integers() {
        let v = Json::obj(vec![("n", Json::Num(42.0))]);
        assert_eq!(v.to_string(), r#"{"n":42}"#);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }
}
