//! HTTP front-end perf smoke: end-to-end serving throughput and
//! per-request latency through the `serve-http` stack — a loopback
//! `HttpServer` driven by keep-alive `http::Client` connections on a
//! uniform mix, a skewed mix (one long + shorts) and a deliberately
//! saturated mix (queue depth 1, one worker) whose floors are the
//! backpressure contract itself: at least one 429 on the wire, every
//! refused submission retried to admission, every connection closed and
//! a clean runtime drain (the seventh perf-trajectory axis).
//!
//! Emits `BENCH_http.json` (schema `bench-http-v1`) in the working
//! directory and gates against a checked-in `BENCH_http.baseline.json`
//! (working directory, then the repository root), failing the process
//! on a >30 % regression. The structural floors fire whatever the
//! baseline. Controls:
//!
//! - `FSOC_BENCH_FAST=1` — CI smoke budget;
//! - `FSOC_HTTP_BASELINE=<path>` — explicit baseline location;
//! - `FSOC_HTTP_SKIP_CHECK=1` — emit JSON only, no gate.

use fullerene_soc::benches_support::{http_perf, http_perf_check, http_perf_json, http_perf_table};
use fullerene_soc::util::json::Json;
use std::path::{Path, PathBuf};

fn baseline_path() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("FSOC_HTTP_BASELINE") {
        return Some(PathBuf::from(p));
    }
    for p in ["BENCH_http.baseline.json", "../BENCH_http.baseline.json"] {
        let p = Path::new(p);
        if p.exists() {
            return Some(p.to_path_buf());
        }
    }
    None
}

fn main() {
    let fast = std::env::var("FSOC_BENCH_FAST").is_ok_and(|v| v == "1");
    let perf = http_perf(42, fast).expect("http perf scenarios run");

    println!("## bench: http\n{}", http_perf_table(&perf).render());
    println!(
        "saturated 429s: {} (floor: >= 1); connections all closed: {}; clean drain: {}",
        perf.saturated_429s, perf.all_connections_closed, perf.clean_drain
    );

    let out = Path::new("BENCH_http.json");
    http_perf_json(&perf, "measured")
        .write_file(out)
        .expect("write BENCH_http.json");
    println!("wrote {}", out.display());

    if std::env::var("FSOC_HTTP_SKIP_CHECK").is_ok_and(|v| v == "1") {
        println!("baseline check skipped (FSOC_HTTP_SKIP_CHECK=1)");
        return;
    }
    match baseline_path() {
        None => println!("no BENCH_http.baseline.json found; baseline check skipped"),
        Some(p) => {
            let baseline = Json::read_file(&p).expect("parse baseline");
            let fails = http_perf_check(&perf, &baseline, 0.30);
            if fails.is_empty() {
                println!("baseline check vs {} passed", p.display());
            } else {
                eprintln!("PERF REGRESSION vs {}:", p.display());
                for f in &fails {
                    eprintln!("  - {f}");
                }
                std::process::exit(1);
            }
        }
    }
}
