//! The three-layer contract: the AOT-compiled JAX/Pallas golden model
//! (executed through PJRT from Rust), the pure-Rust integer reference and
//! the cycle-level SoC simulator must agree bit-for-bit on the exported
//! test samples.
//!
//! These tests need `make artifacts` to have run; they skip (pass
//! trivially with a note) when the artifacts are absent so `cargo test`
//! works on a fresh checkout.

use fullerene_soc::datasets::Dataset;
use fullerene_soc::nn::load_weights_json;
use fullerene_soc::runtime::GoldenModel;
use fullerene_soc::soc::{Soc, SocConfig};
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    std::env::var("FSOC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn have_artifacts(name: &str) -> bool {
    let d = artifacts_dir();
    d.join(format!("{name}.hlo.txt")).exists()
        && d.join(format!("{name}.weights.json")).exists()
        && d.join(format!("dataset_{name}.json")).exists()
}

fn check_dataset(name: &str, samples: usize) {
    if !have_artifacts(name) {
        eprintln!("skipping golden check for '{name}': run `make artifacts` first");
        return;
    }
    let dir = artifacts_dir();
    let net = load_weights_json(&dir.join(format!("{name}.weights.json"))).unwrap();
    let ds = Dataset::load_json(&dir.join(format!("dataset_{name}.json"))).unwrap();
    let golden = GoldenModel::load(&dir, name).unwrap();
    assert_eq!(golden.inputs, net.input_size());
    assert_eq!(golden.classes, net.classes);

    let mut soc = Soc::new(net.clone(), SocConfig::default()).unwrap();
    for (i, sample) in ds.samples.iter().take(samples).enumerate() {
        let raster = sample.to_raster(net.timesteps, net.input_size());
        let reference = net.reference_run(&raster);
        let xla = golden.run_sample(sample).unwrap();
        assert_eq!(
            xla, reference,
            "{name}[{i}]: XLA golden vs rust reference disagree"
        );
        let chip = soc.run_sample(sample, true).unwrap();
        assert_eq!(
            chip.counts, reference,
            "{name}[{i}]: cycle simulator vs reference disagree"
        );
    }
}

#[test]
fn nmnist_three_way_agreement() {
    check_dataset("nmnist", 5);
}

#[test]
fn dvsgesture_three_way_agreement() {
    check_dataset("dvsgesture", 3);
}

#[test]
fn cifar10_three_way_agreement() {
    check_dataset("cifar10", 3);
}

#[test]
fn trained_accuracy_is_far_above_chance() {
    // The headline Table-I accuracy path: trained weights on the chip.
    if !have_artifacts("nmnist") {
        eprintln!("skipping accuracy check: run `make artifacts` first");
        return;
    }
    let dir = artifacts_dir();
    let net = load_weights_json(&dir.join("nmnist.weights.json")).unwrap();
    let ds = Dataset::load_json(&dir.join("dataset_nmnist.json")).unwrap();
    let mut soc = Soc::new(net, SocConfig::default()).unwrap();
    let n = ds.samples.len().min(20);
    let acc = soc.run_dataset(&ds, n).unwrap().accuracy;
    assert!(
        acc > 0.5,
        "trained NMNIST accuracy {acc} is not above chance (0.1)"
    );
}
