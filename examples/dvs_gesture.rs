//! Temporal-workload study on the DVS-Gesture-like stream: how spike
//! sparsity, NoC traffic and energy evolve over a gesture's timesteps,
//! and how the chip behaves at different operating points (frequency /
//! voltage — the paper's 1.08–1.32 V, 50–200 MHz envelope).
//!
//! ```bash
//! cargo run --release --example dvs_gesture            # fallback net
//! make artifacts && cargo run --release --example dvs_gesture
//! ```

use fullerene_soc::datasets::{Dataset, Workload};
use fullerene_soc::energy::ChipReport;
use fullerene_soc::metrics::Table;
use fullerene_soc::nn::load_weights_json;
use fullerene_soc::soc::{Soc, SocConfig};
use std::path::Path;

fn load_net() -> fullerene_soc::Result<fullerene_soc::nn::NetworkDesc> {
    let trained = Path::new("artifacts/dvsgesture.weights.json");
    if trained.exists() {
        println!("using trained weights: {}", trained.display());
        return Ok(load_weights_json(trained)?);
    }
    println!("(untrained fallback network — run `make artifacts` for the real one)");
    use fullerene_soc::core::neuron::{LeakMode, NeuronParams, ResetMode};
    use fullerene_soc::core::Codebook;
    use fullerene_soc::nn::network::LayerDesc;
    let w = Workload::DvsGesture;
    let cb = Codebook::default_log16();
    let params = NeuronParams {
        threshold: 90,
        leak: LeakMode::Linear(1),
        reset: ResetMode::Subtract,
        mp_bits: 16,
    };
    Ok(fullerene_soc::nn::NetworkDesc {
        name: "dvs-fallback".into(),
        layers: vec![
            LayerDesc {
                name: "h".into(),
                inputs: w.inputs(),
                neurons: 96,
                codebook: cb.clone(),
                widx: (0..w.inputs() * 96).map(|i| ((i * 13) % 16) as u8).collect(),
                neuron_params: params.clone(),
            },
            LayerDesc {
                name: "o".into(),
                inputs: 96,
                neurons: w.classes(),
                codebook: cb,
                widx: (0..96 * w.classes()).map(|i| ((i * 11) % 16) as u8).collect(),
                neuron_params: params,
            },
        ],
        timesteps: w.timesteps(),
        classes: w.classes(),
    })
}

fn main() -> fullerene_soc::Result<()> {
    let net = load_net()?;
    let w = Workload::DvsGesture;
    let ds_path = Path::new("artifacts/dataset_dvsgesture.json");
    let ds = if ds_path.exists() {
        Dataset::load_json(ds_path)?
    } else {
        w.generate(11, 5)
    };

    // --- per-timestep activity profile of one gesture ---------------------
    let sample = &ds.samples[0];
    println!("## per-timestep activity (sample 0, class {})", sample.label);
    let mut t = Table::new(&["t", "input spikes", "sparsity"]);
    for ts in 0..ds.timesteps {
        let n = sample.spikes_at(ts as u16).len();
        t.push_row(vec![
            ts.to_string(),
            n.to_string(),
            format!("{:.3}", 1.0 - n as f64 / ds.inputs as f64),
        ]);
    }
    println!("{}", t.render());

    // --- operating-point sweep (Table I envelope) --------------------------
    println!("## operating-point sweep (8 samples each)");
    let mut reports = Vec::new();
    for (f_mhz, v) in [(50.0, 1.08), (100.0, 1.08), (200.0, 1.08), (100.0, 1.32)] {
        let mut soc = Soc::new(
            net.clone(),
            SocConfig {
                f_core_hz: f_mhz * 1e6,
                supply_v: v,
                ..SocConfig::default()
            },
        )?;
        let acc = soc.run_dataset(&ds, 8)?;
        let mut rep = soc.finish_report(&format!("{f_mhz:.0}MHz/{v}V"));
        rep.accuracy = Some(acc);
        reports.push(rep);
    }
    println!("{}", ChipReport::table(&reports).render());
    println!(
        "note: pJ/SOP is voltage-dependent (dynamic ∝ V²) and power scales \
         with frequency — the envelope matches Table I's 2.8–113 mW span \
         directionally."
    );
    Ok(())
}
