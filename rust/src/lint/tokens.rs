//! A lightweight Rust tokenizer for the in-tree linter.
//!
//! This is deliberately **not** a full parser: the lint rules only need
//! identifiers, operators, literals and comment text, so we tokenize with a
//! hand-rolled scanner instead of pulling in `syn` (the crate is
//! zero-dependency by contract). The scanner understands everything that
//! would otherwise produce false positives inside literals:
//!
//! - line comments and *nested* block comments (`/* /* */ */`),
//! - string / raw-string / byte-string literals (`"…"`, `r#"…"#`, `b"…"`),
//! - char literals vs lifetimes (`'a'` vs `'a`),
//! - numeric literals with a float/int distinction (for `no-float-eq`),
//! - multi-char operators (`::`, `==`, `!=`, `=>`, `..`, …).
//!
//! Alongside the token stream the scanner collects `// lint:allow(rule)
//! justification` annotations from comments — the only sanctioned way to
//! suppress a finding — and can compute `#[cfg(test)]` line regions so the
//! rules skip test-only code.

/// What a token is; `text` carries the exact source spelling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident,
    /// Operator / punctuation (possibly multi-char, e.g. `::`).
    Op,
    /// Numeric literal; `float` is true for `1.0`, `1e3`, `2f64`, …
    Num { float: bool },
    /// String, raw-string or byte-string literal (text excludes quotes).
    Str,
    /// Char literal.
    CharLit,
    /// Lifetime (`'a`).
    Lifetime,
}

/// One token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

impl Tok {
    /// True if this token is an identifier with exactly this text.
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    /// True if this token is an operator with exactly this text.
    pub fn is_op(&self, s: &str) -> bool {
        self.kind == TokKind::Op && self.text == s
    }
}

/// An inline suppression: `// lint:allow(<rule>) <justification>`.
///
/// The justification is mandatory — an allow without one is recorded with
/// `justified == false` and does **not** suppress anything (the linter
/// reports it as its own finding instead).
#[derive(Debug, Clone)]
pub struct Allow {
    pub rule: String,
    pub line: usize,
    pub justified: bool,
}

/// Tokenizer output: the token stream plus any `lint:allow` annotations.
#[derive(Debug, Default)]
pub struct Scan {
    pub toks: Vec<Tok>,
    pub allows: Vec<Allow>,
}

/// Operators we combine into multi-char tokens (longest match wins).
const MULTI_OPS: &[&str] = &[
    "..=", "...", "<<=", ">>=", "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "..", "+=",
    "-=", "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>",
];

/// Tokenize Rust source. Never fails: unterminated literals are taken to
/// the end of input (a linter must not die on the code it inspects).
pub fn scan(src: &str) -> Scan {
    let chars: Vec<char> = src.chars().collect();
    let mut out = Scan::default();
    let mut i = 0usize;
    let mut line = 1usize;
    let n = chars.len();

    while i < n {
        let c = chars[i];
        // Newlines / whitespace.
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment — harvest lint:allow annotations.
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            let body: String = chars[start..j].iter().collect();
            harvest_allows(&body, line, &mut out.allows);
            i = j;
            continue;
        }
        // Block comment, nested.
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            let start = j;
            while j < n && depth > 0 {
                if chars[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            let body: String = chars[start..j.min(n)].iter().collect();
            harvest_allows(&body, line, &mut out.allows);
            i = j;
            continue;
        }
        // Raw / byte string prefixes: r"…", r#"…"#, b"…", br#"…"#.
        if (c == 'r' || c == 'b') && is_raw_or_byte_string(&chars, i) {
            let (tok, ni, nl) = scan_prefixed_string(&chars, i, line);
            out.toks.push(tok);
            i = ni;
            line = nl;
            continue;
        }
        // Plain string.
        if c == '"' {
            let (text, ni, nl) = scan_quoted(&chars, i + 1, line);
            out.toks.push(Tok { kind: TokKind::Str, text, line });
            i = ni;
            line = nl;
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            let esc = i + 1 < n && chars[i + 1] == '\\';
            let closes = i + 2 < n && chars[i + 2] == '\'';
            if esc || closes {
                // '\n' or 'x' — a char literal. Scan to the closing quote.
                let mut j = i + 1;
                let start = j;
                while j < n && chars[j] != '\'' {
                    if chars[j] == '\\' {
                        j += 1;
                    }
                    j += 1;
                }
                let text: String = chars[start..j.min(n)].iter().collect();
                out.toks.push(Tok { kind: TokKind::CharLit, text, line });
                i = (j + 1).min(n);
            } else {
                // 'a — a lifetime.
                let mut j = i + 1;
                while j < n && (chars[j].is_alphanumeric() || chars[j] == '_') {
                    j += 1;
                }
                let text: String = chars[i..j].iter().collect();
                out.toks.push(Tok { kind: TokKind::Lifetime, text, line });
                i = j;
            }
            continue;
        }
        // Identifier / keyword.
        if c.is_alphabetic() || c == '_' {
            let mut j = i + 1;
            while j < n && (chars[j].is_alphanumeric() || chars[j] == '_') {
                j += 1;
            }
            let text: String = chars[i..j].iter().collect();
            out.toks.push(Tok { kind: TokKind::Ident, text, line });
            i = j;
            continue;
        }
        // Number.
        if c.is_ascii_digit() {
            let (tok, ni) = scan_number(&chars, i, line);
            out.toks.push(tok);
            i = ni;
            continue;
        }
        // Operator: try multi-char longest-match, else single char.
        let mut matched = false;
        for op in MULTI_OPS {
            let olen = op.len(); // all multi-ops are ASCII
            if i + olen <= n && chars[i..i + olen].iter().collect::<String>() == **op {
                out.toks.push(Tok { kind: TokKind::Op, text: (*op).into(), line });
                i += olen;
                matched = true;
                break;
            }
        }
        if !matched {
            out.toks.push(Tok { kind: TokKind::Op, text: c.to_string(), line });
            i += 1;
        }
    }
    out
}

/// True if position `i` starts a raw/byte string prefix rather than an
/// identifier (`r"`, `r#"`, `b"`, `br"`, `rb"`, `b'`-style byte chars are
/// treated as char literals by the main loop).
fn is_raw_or_byte_string(chars: &[char], i: usize) -> bool {
    let n = chars.len();
    let mut j = i;
    // Up to two prefix letters (r, b, br, rb).
    while j < n && (chars[j] == 'r' || chars[j] == 'b') && j - i < 2 {
        j += 1;
    }
    // Skip raw-string hashes.
    while j < n && chars[j] == '#' {
        j += 1;
    }
    j < n && chars[j] == '"' && j > i
}

/// Scan `r#"…"#` / `b"…"` starting at the prefix letter.
fn scan_prefixed_string(chars: &[char], i: usize, mut line: usize) -> (Tok, usize, usize) {
    let n = chars.len();
    let start_line = line;
    let mut j = i;
    while j < n && (chars[j] == 'r' || chars[j] == 'b') {
        j += 1;
    }
    let mut hashes = 0usize;
    while j < n && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    j += 1; // opening quote
    let body_start = j;
    let raw = hashes > 0 || chars[i] == 'r' || (chars[i] == 'b' && i + 1 < n && chars[i + 1] == 'r');
    loop {
        if j >= n {
            break;
        }
        if chars[j] == '\n' {
            line += 1;
            j += 1;
            continue;
        }
        if !raw && chars[j] == '\\' {
            j += 2;
            continue;
        }
        if chars[j] == '"' {
            // For raw strings the quote must be followed by the hashes.
            let mut k = j + 1;
            let mut seen = 0usize;
            while k < n && seen < hashes && chars[k] == '#' {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                let text: String = chars[body_start..j].iter().collect();
                return (Tok { kind: TokKind::Str, text, line: start_line }, k, line);
            }
        }
        j += 1;
    }
    let text: String = chars[body_start..n].iter().collect();
    (Tok { kind: TokKind::Str, text, line: start_line }, n, line)
}

/// Scan a plain `"…"` body starting just after the opening quote.
fn scan_quoted(chars: &[char], start: usize, mut line: usize) -> (String, usize, usize) {
    let n = chars.len();
    let mut j = start;
    while j < n {
        match chars[j] {
            '\\' => j += 2,
            '\n' => {
                line += 1;
                j += 1;
            }
            '"' => {
                let text: String = chars[start..j].iter().collect();
                return (text, j + 1, line);
            }
            _ => j += 1,
        }
    }
    (chars[start..n].iter().collect(), n, line)
}

/// Scan a numeric literal; decides int vs float.
fn scan_number(chars: &[char], i: usize, line: usize) -> (Tok, usize) {
    let n = chars.len();
    let mut j = i;
    let radix_prefixed = chars[i] == '0'
        && i + 1 < n
        && matches!(chars[i + 1], 'x' | 'X' | 'b' | 'B' | 'o' | 'O');
    if radix_prefixed {
        j = i + 2;
        while j < n && (chars[j].is_ascii_alphanumeric() || chars[j] == '_') {
            j += 1;
        }
        let text: String = chars[i..j].iter().collect();
        return (Tok { kind: TokKind::Num { float: false }, text, line }, j);
    }
    let mut float = false;
    while j < n && (chars[j].is_ascii_digit() || chars[j] == '_') {
        j += 1;
    }
    // Fractional part — but `0..n` is a range and `1.max(x)` a method call.
    if j < n && chars[j] == '.' {
        let after = chars.get(j + 1).copied();
        let is_range = after == Some('.');
        let is_method = after.map(|c| c.is_alphabetic() || c == '_').unwrap_or(false);
        if !is_range && !is_method {
            float = true;
            j += 1;
            while j < n && (chars[j].is_ascii_digit() || chars[j] == '_') {
                j += 1;
            }
        }
    }
    // Exponent.
    if j < n && (chars[j] == 'e' || chars[j] == 'E') {
        let after = chars.get(j + 1).copied();
        let exp = after.map(|c| c.is_ascii_digit() || c == '+' || c == '-').unwrap_or(false);
        if exp {
            float = true;
            j += 2;
            while j < n && (chars[j].is_ascii_digit() || chars[j] == '_') {
                j += 1;
            }
        }
    }
    // Suffix (u32, f64, usize, …).
    let suffix_start = j;
    while j < n && (chars[j].is_ascii_alphanumeric() || chars[j] == '_') {
        j += 1;
    }
    let suffix: String = chars[suffix_start..j].iter().collect();
    if suffix.starts_with("f32") || suffix.starts_with("f64") {
        float = true;
    }
    let text: String = chars[i..j].iter().collect();
    (Tok { kind: TokKind::Num { float }, text, line }, j)
}

/// Extract `lint:allow(<rule>) <justification>` annotations from a comment
/// body. Several annotations may share one comment.
fn harvest_allows(body: &str, mut line: usize, out: &mut Vec<Allow>) {
    for part in body.split('\n') {
        let mut rest = part;
        while let Some(pos) = rest.find("lint:allow(") {
            let after = &rest[pos + "lint:allow(".len()..];
            if let Some(close) = after.find(')') {
                let rule = after[..close].trim().to_string();
                let just = after[close + 1..].trim();
                // The justification ends at the next annotation, if any.
                let just = just.split("lint:allow(").next().unwrap_or("").trim();
                out.push(Allow { rule, line, justified: !just.is_empty() });
                rest = &after[close + 1..];
            } else {
                break;
            }
        }
        line += 1;
    }
}

/// Compute the set of 1-based lines covered by `#[cfg(test)]` items
/// (modules or functions) so rules can skip test-only code. The region is
/// found by matching the attribute token sequence and then brace-matching
/// the item body; attribute-on-statement (`#[cfg(test)] use …;`) regions
/// end at the terminating semicolon.
pub fn cfg_test_lines(toks: &[Tok]) -> std::collections::BTreeSet<usize> {
    let mut lines = std::collections::BTreeSet::new();
    let mut i = 0usize;
    while i + 6 < toks.len() {
        let hit = toks[i].is_op("#")
            && toks[i + 1].is_op("[")
            && toks[i + 2].is_ident("cfg")
            && toks[i + 3].is_op("(")
            && toks[i + 4].is_ident("test")
            && toks[i + 5].is_op(")")
            && toks[i + 6].is_op("]");
        if !hit {
            i += 1;
            continue;
        }
        let region_start_line = toks[i].line;
        // Find the item body: the first `{` at depth 0 before a `;`.
        let mut j = i + 7;
        let mut end_line = region_start_line;
        while j < toks.len() {
            if toks[j].is_op(";") {
                end_line = toks[j].line;
                break;
            }
            if toks[j].is_op("{") {
                let mut depth = 1usize;
                let mut k = j + 1;
                while k < toks.len() && depth > 0 {
                    if toks[k].is_op("{") {
                        depth += 1;
                    } else if toks[k].is_op("}") {
                        depth -= 1;
                    }
                    k += 1;
                }
                end_line = toks[k.min(toks.len()) - 1].line;
                break;
            }
            j += 1;
        }
        for l in region_start_line..=end_line {
            lines.insert(l);
        }
        i = j + 1;
    }
    lines
}
