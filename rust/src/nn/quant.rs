//! Non-uniform weight quantization: k-means (Lloyd) codebooks over a
//! layer's float weights, emitted as `N` integer levels of `W` bits plus
//! the per-synapse index matrix — the chip's shared-codebook scheme
//! (paper §II.A: "All synapses share N × W-bit quantized weights in a
//! core").
//!
//! The same algorithm (same initialization, same iteration count) is
//! implemented in `python/compile/quantize.py`; both sides are tested
//! against the invariants (codebook size, monotone levels, assignment
//! optimality) rather than against each other bit-for-bit, since training
//! happens only on the Python side.

use crate::core::Codebook;
use crate::{Error, Result};

/// A quantized layer: integer codebook + index matrix + the float scale
/// that maps levels back to the original weight domain.
#[derive(Debug, Clone)]
pub struct QuantizedLayer {
    /// Integer codebook (N × W bits).
    pub codebook: Codebook,
    /// Per-synapse codebook indexes (row-major `[input][neuron]`).
    pub widx: Vec<u8>,
    /// `float_weight ≈ level × scale`.
    pub scale: f64,
}

/// K-means quantization of `weights` (any shape, flattened row-major) to
/// `n` levels of `w_bits` each. `iters` Lloyd iterations (deterministic:
/// quantile initialization, no RNG).
pub fn kmeans_quantize(
    weights: &[f64],
    n: usize,
    w_bits: usize,
    iters: usize,
) -> Result<QuantizedLayer> {
    if weights.is_empty() {
        return Err(Error::Network("cannot quantize empty weights".into()));
    }
    // Quantile init: split the sorted weights into n equal-mass buckets.
    let mut sorted = weights.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut centroids: Vec<f64> = (0..n)
        .map(|i| {
            let q = (i as f64 + 0.5) / n as f64;
            sorted[((sorted.len() - 1) as f64 * q) as usize]
        })
        .collect();
    // Nudge duplicate centroids apart so every cluster can win points.
    for i in 1..n {
        if centroids[i] <= centroids[i - 1] {
            centroids[i] = centroids[i - 1] + 1e-9;
        }
    }

    let mut assign = vec![0u8; weights.len()];
    for _ in 0..iters {
        // Assignment step (centroids stay sorted → binary search works,
        // but n ≤ 16 so a linear scan is fastest).
        for (i, &w) in weights.iter().enumerate() {
            let mut best = 0usize;
            let mut bd = f64::INFINITY;
            for (c, &cent) in centroids.iter().enumerate() {
                let d = (w - cent).abs();
                if d < bd {
                    bd = d;
                    best = c;
                }
            }
            assign[i] = best as u8;
        }
        // Update step.
        let mut sums = vec![0.0f64; n];
        let mut counts = vec![0usize; n];
        for (i, &w) in weights.iter().enumerate() {
            sums[assign[i] as usize] += w;
            counts[assign[i] as usize] += 1;
        }
        for c in 0..n {
            if counts[c] > 0 {
                centroids[c] = sums[c] / counts[c] as f64;
            }
        }
        centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
    }
    // Integerize: scale so the largest |centroid| hits the W-bit range.
    let (lo, hi) = Codebook::range(w_bits);
    let maxabs = centroids.iter().fold(0.0f64, |m, &c| m.max(c.abs()));
    // Degenerate all-zero case (the 1e-9 tie-break nudges are noise, not
    // signal): keep scale 1 so every level rounds to 0.
    let scale = if maxabs > 1e-6 {
        maxabs / hi as f64
    } else {
        1.0
    };
    let levels: Vec<i32> = centroids
        .iter()
        .map(|&c| ((c / scale).round() as i64).clamp(lo as i64, hi as i64) as i32)
        .collect();
    // Final assignment against the *integerized* levels (what the chip
    // actually stores), so every index is nearest in the deployed domain.
    for (i, &w) in weights.iter().enumerate() {
        let mut best = 0usize;
        let mut bd = f64::INFINITY;
        for (c, &lvl) in levels.iter().enumerate() {
            let d = (w - lvl as f64 * scale).abs();
            if d < bd {
                bd = d;
                best = c;
            }
        }
        assign[i] = best as u8;
    }
    Ok(QuantizedLayer {
        codebook: Codebook::new(levels, w_bits)?,
        widx: assign,
        scale,
    })
}

/// Mean squared quantization error in the float domain.
pub fn quant_mse(weights: &[f64], q: &QuantizedLayer) -> f64 {
    weights
        .iter()
        .zip(&q.widx)
        .map(|(&w, &i)| {
            let approx = q.codebook.weight(i) as f64 * q.scale;
            (w - approx).powi(2)
        })
        .sum::<f64>()
        / weights.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::propcheck::check;

    #[test]
    fn recovers_discrete_levels_exactly() {
        // Weights drawn from 4 distinct values → 4-level codebook must
        // reach ~zero error.
        let vals = [-0.5, -0.1, 0.2, 0.7];
        let mut rng = Rng::new(3);
        let w: Vec<f64> = (0..400).map(|_| vals[rng.below_usize(4)]).collect();
        let q = kmeans_quantize(&w, 4, 8, 20).unwrap();
        assert!(quant_mse(&w, &q) < 1e-4, "mse {}", quant_mse(&w, &q));
    }

    #[test]
    fn more_levels_never_hurt() {
        let mut rng = Rng::new(9);
        let w: Vec<f64> = (0..1000).map(|_| rng.normal() * 0.3).collect();
        let e4 = quant_mse(&w, &kmeans_quantize(&w, 4, 8, 15).unwrap());
        let e16 = quant_mse(&w, &kmeans_quantize(&w, 16, 8, 15).unwrap());
        assert!(e16 < e4, "e16 {e16} vs e4 {e4}");
    }

    #[test]
    fn codebook_levels_sorted_and_in_range() {
        check("quant-invariants", 30, 77, |r| {
            let len = 50 + r.below_usize(200);
            let w: Vec<f64> = (0..len).map(|_| r.normal()).collect();
            let n = [4usize, 8, 16][r.below_usize(3)];
            let bits = [4usize, 8, 16][r.below_usize(3)];
            let q = kmeans_quantize(&w, n, bits, 10).unwrap();
            assert_eq!(q.codebook.n(), n);
            let vals = q.codebook.values();
            assert!(vals.windows(2).all(|p| p[0] <= p[1]), "unsorted {vals:?}");
            let (lo, hi) = Codebook::range(bits);
            assert!(vals.iter().all(|&v| v >= lo && v <= hi));
            assert!(q.widx.iter().all(|&i| (i as usize) < n));
        });
    }

    #[test]
    fn assignment_is_nearest_level() {
        let mut rng = Rng::new(5);
        let w: Vec<f64> = (0..300).map(|_| rng.normal()).collect();
        let q = kmeans_quantize(&w, 8, 8, 15).unwrap();
        for (i, &x) in w.iter().enumerate() {
            let chosen = q.codebook.weight(q.widx[i]) as f64 * q.scale;
            for &lvl in q.codebook.values() {
                let alt = lvl as f64 * q.scale;
                assert!(
                    (x - chosen).abs() <= (x - alt).abs() + 1e-6,
                    "w={x} chose {chosen}, but {alt} is closer"
                );
            }
        }
    }

    #[test]
    fn empty_weights_rejected() {
        assert!(kmeans_quantize(&[], 4, 8, 5).is_err());
    }

    #[test]
    fn all_zero_weights_ok() {
        let q = kmeans_quantize(&[0.0; 64], 4, 8, 5).unwrap();
        assert!(q.codebook.values().iter().all(|&v| v == 0));
    }
}
