//! Scale-up study (paper: "the NoC can be scaled up through extended
//! off-chip high-level router nodes"): multi-domain systems built from
//! fullerene level-1 domains joined by a ring of level-2 routers, from
//! 1 domain (20 cores / 160 K neurons) to 64 domains (10 M neurons).
//!
//! Every system up to 16 domains is **cycle-simulated** — inter-domain
//! flits really climb `core → L1 → L2`, ride the L2 ring and descend,
//! with L2 hop/link energy ledgered — and checked against the retained
//! analytic hop model. Beyond 16 domains the analytic model extrapolates.
//!
//! ```bash
//! cargo run --release --example scaling
//! ```

use fullerene_soc::benches_support;
use fullerene_soc::metrics::Table;
use fullerene_soc::noc::{AnalyticModel, TopoStats, Topology};

fn main() -> fullerene_soc::Result<()> {
    // --- the single-domain baseline ---------------------------------------
    let base = TopoStats::compute(&Topology::fullerene());
    println!(
        "single domain: avg core-to-core distance {:.2} links = {:.2} router \
         hops (paper Fig. 5a: 3.16)",
        base.avg_core_hops,
        base.avg_core_hops / 2.0
    );

    // --- cycle-simulated multi-domain scaling ------------------------------
    // (20 cores / 0.16 M neurons per domain; 80 % of traffic intra-domain)
    println!("\n## cycle-level scaling (simulated fabric vs analytic oracle)");
    println!(
        "{}",
        benches_support::multidomain_table(&[1, 2, 4, 8, 16], 600, 0.8, 17).render()
    );

    // --- analytic extrapolation to the 10M-neuron regime --------------------
    println!("## analytic extrapolation (uniform traffic)");
    let mut t = Table::new(&["domains", "cores", "neurons", "avg router hops"]);
    for d in [16usize, 32, 64] {
        let a = AnalyticModel::new(d);
        t.push_row(vec![
            d.to_string(),
            (d * 20).to_string(),
            format!("{:.2}M", (d * 20 * 8192) as f64 / 1e6),
            format!("{:.2}", a.avg_hops_uniform()),
        ]);
    }
    println!("{}", t.render());

    // --- locality requirement ----------------------------------------------
    // What fraction of traffic may cross domains before the average path
    // exceeds 2× the single-domain latency?
    println!("## locality requirement");
    let mut t = Table::new(&["domains", "max remote fraction for <=2x latency"]);
    for d in [4usize, 16, 64] {
        let a = AnalyticModel::new(d);
        let intra = a.intra_hops;
        let ring: f64 =
            (1..d).map(|k| a.l2_ring_hops(0, k) as f64).sum::<f64>() / (d - 1) as f64;
        let remote = a.climb_hops + ring + a.descend_hops;
        // solve intra*(1-x) + remote*x = 2*intra
        let x = (intra / (remote - intra)).clamp(0.0, 1.0);
        t.push_row(vec![d.to_string(), format!("{:.1}%", x * 100.0)]);
    }
    println!("{}", t.render());
    println!(
        "interpretation: mapping layers within domains (what nn::Mapping \
         does) keeps nearly all spike traffic on the cheap intra-domain \
         fabric; the L2 ring only carries layer-boundary crossings."
    );
    Ok(())
}
