//! Serving-layer perf smoke: host-side session throughput of the
//! persistent `ServeRuntime` — a uniform session mix and a skewed mix
//! (one long + N short sessions) across 2 pull-based workers, plus a
//! warm-vs-cold chip pair on 1 worker whose sessions-per-second ratio is
//! the machine-independent win of `Soc::reset_for_session` over paying
//! `Soc::new` per session (the third perf-trajectory axis next to
//! `BENCH_noc.json` and `BENCH_core.json`).
//!
//! Emits `BENCH_serve.json` (schema `bench-serve-v1`) in the working
//! directory and gates against a checked-in `BENCH_serve.baseline.json`
//! (working directory, then the repository root), failing the process on
//! a >30 % regression. The warm-vs-cold speedup must stay > 1.0 and the
//! skewed mix's short sessions must finish before the long one,
//! whatever the baseline. Controls:
//!
//! - `FSOC_BENCH_FAST=1` — CI smoke budget;
//! - `FSOC_SERVE_BASELINE=<path>` — explicit baseline location;
//! - `FSOC_SERVE_SKIP_CHECK=1` — emit JSON only, no gate.

use fullerene_soc::benches_support::{serve_perf, serve_perf_check, serve_perf_json};
use fullerene_soc::metrics::Table;
use fullerene_soc::util::json::Json;
use std::path::{Path, PathBuf};

fn baseline_path() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("FSOC_SERVE_BASELINE") {
        return Some(PathBuf::from(p));
    }
    for p in ["BENCH_serve.baseline.json", "../BENCH_serve.baseline.json"] {
        let p = Path::new(p);
        if p.exists() {
            return Some(p.to_path_buf());
        }
    }
    None
}

fn main() {
    let fast = std::env::var("FSOC_BENCH_FAST").is_ok_and(|v| v == "1");
    let perf = serve_perf(42, fast).expect("serve perf scenarios run");

    let mut t = Table::new(&[
        "scenario",
        "sessions",
        "samples",
        "workers",
        "host s",
        "sessions/s",
        "q-wait p50 ms",
        "q-wait p99 ms",
    ]);
    for c in &perf.cases {
        t.push_row(vec![
            c.name.clone(),
            c.sessions.to_string(),
            c.samples.to_string(),
            c.workers.to_string(),
            format!("{:.3}", c.host_s),
            format!("{:.1}", c.sessions_per_s),
            format!("{:.3}", c.queue_wait_p50_s * 1e3),
            format!("{:.3}", c.queue_wait_p99_s * 1e3),
        ]);
    }
    println!("## bench: serve_throughput\n{}", t.render());
    println!(
        "warm-vs-cold chip speedup (reset_for_session vs Soc::new per session): {:.2}x",
        perf.warm_vs_cold_speedup
    );
    println!(
        "skewed mix: short sessions finished before the long one: {}",
        perf.skewed_shorts_finished_first
    );

    let out = Path::new("BENCH_serve.json");
    serve_perf_json(&perf, "measured")
        .write_file(out)
        .expect("write BENCH_serve.json");
    println!("wrote {}", out.display());

    if std::env::var("FSOC_SERVE_SKIP_CHECK").is_ok_and(|v| v == "1") {
        println!("baseline check skipped (FSOC_SERVE_SKIP_CHECK=1)");
        return;
    }
    match baseline_path() {
        None => println!("no BENCH_serve.baseline.json found; baseline check skipped"),
        Some(p) => {
            let baseline = Json::read_file(&p).expect("parse baseline");
            let fails = serve_perf_check(&perf, &baseline, 0.30);
            if fails.is_empty() {
                println!("baseline check vs {} passed", p.display());
            } else {
                eprintln!("PERF REGRESSION vs {}:", p.display());
                for f in &fails {
                    eprintln!("  - {f}");
                }
                std::process::exit(1);
            }
        }
    }
}
