"""Synthetic dataset generators: geometry, sparsity, determinism, export."""

import json

import numpy as np

from compile import data


def test_geometry_matches_paper_datasets():
    d = data.make_nmnist(4, seed=0)
    assert d.inputs == 2312 and d.timesteps == 20 and d.classes == 10
    d = data.make_dvsgesture(4, seed=0)
    assert d.inputs == 2048 and d.timesteps == 25 and d.classes == 11
    d = data.make_cifar(4, seed=0)
    assert d.inputs == 3072 and d.timesteps == 16 and d.classes == 10


def test_sparsity_in_snn_regime():
    for make, lo, hi in [(data.make_nmnist, 0.8, 0.999),
                         (data.make_dvsgesture, 0.85, 0.999),
                         (data.make_cifar, 0.6, 0.99)]:
        d = make(6, seed=1)
        s = d.sparsity()
        assert lo < s < hi, f"{d.name} sparsity {s}"


def test_determinism():
    a = data.make_nmnist(5, seed=7)
    b = data.make_nmnist(5, seed=7)
    np.testing.assert_array_equal(a.rasters, b.rasters)
    c = data.make_nmnist(5, seed=8)
    assert (a.rasters != c.rasters).any()


def test_labels_round_robin():
    d = data.make_cifar(25, seed=2)
    assert (d.labels == np.arange(25) % 10).all()


def test_classes_distinct():
    d = data.make_nmnist(40, seed=3)
    hists = []
    for c in range(2):
        sel = d.rasters[d.labels == c]
        hists.append(sel.reshape(-1, d.inputs).mean(axis=0))
    h0, h1 = hists
    cos = (h0 @ h1) / (np.linalg.norm(h0) * np.linalg.norm(h1) + 1e-12)
    assert cos < 0.9, f"class prototypes overlap (cos {cos})"


def test_export_json_roundtrips(tmp_path):
    d = data.make_dvsgesture(3, seed=4)
    path = tmp_path / "ds.json"
    d.export_json(str(path), limit=2)
    doc = json.loads(path.read_text())
    assert doc["inputs"] == 2048
    assert len(doc["samples"]) == 2
    # events reconstruct the raster
    s0 = doc["samples"][0]
    got = np.zeros((d.timesteps, d.inputs), dtype=bool)
    for t, a in s0["events"]:
        got[t, a] = True
    np.testing.assert_array_equal(got, d.rasters[0])
    assert s0["label"] == int(d.labels[0])
