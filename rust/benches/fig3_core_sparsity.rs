//! Fig. 3 reproduction: neuromorphic-core computing efficiency (GSOP/s)
//! and synapse energy efficiency (pJ/SOP) over the 0–100 % spike-sparsity
//! sweep at 200 MHz, sparse core vs the traditional dense baseline.
//!
//! Paper anchors: best 0.627 GSOP/s and 0.627 pJ/SOP; ≥0.426 GSOP/s and
//! ≤1.196 pJ/SOP in the sparse regime; ×2.69 energy-efficiency gain over
//! the traditional scheme.

use fullerene_soc::benches_support::{self, spikes_at_sparsity};
use fullerene_soc::util::bench::Bench;
use fullerene_soc::util::prng::Rng;

fn main() {
    // --- the figure itself -------------------------------------------------
    println!("## Fig. 3: core efficiency vs spike sparsity (200 MHz)");
    println!("{}", benches_support::fig3_table(11, 42).render());
    let pts = benches_support::fig3_sweep(11, 42);
    let best = pts
        .iter()
        .filter(|p| p.gsops.is_finite() && p.pj_per_sop.is_finite())
        .fold((0.0f64, f64::INFINITY), |acc, p| {
            (acc.0.max(p.gsops), acc.1.min(p.pj_per_sop))
        });
    println!(
        "best computing efficiency {:.3} GSOP/s (paper 0.627), best energy \
         {:.3} pJ/SOP (paper 0.627)",
        best.0, best.1
    );
    let cross = pts.iter().find(|p| p.gain >= 2.69);
    match cross {
        Some(p) => println!(
            "2.69x energy-efficiency gain (paper's headline) reached at \
             sparsity {:.0}%",
            p.sparsity * 100.0
        ),
        None => println!("2.69x gain not reached in sweep"),
    }

    // --- wall-clock of the simulator itself (perf tracking) ----------------
    let mut b = Bench::new("fig3_core_sparsity");
    let energy = fullerene_soc::energy::EnergyParams::nominal();
    for sparsity in [0.0f64, 0.5, 0.9] {
        let mut rng = Rng::new(7);
        let spikes = spikes_at_sparsity(sparsity, &mut rng);
        let mut core = benches_support_core(&energy);
        b.bench(&format!("core-timestep/s={sparsity}"), || {
            core.stage_input_spikes(&spikes);
            core.tick_timestep().stats.cycles
        });
    }
    b.finish();
}

fn benches_support_core(
    energy: &fullerene_soc::energy::EnergyParams,
) -> fullerene_soc::core::NeuroCore {
    use fullerene_soc::core::neuron::{LeakMode, NeuronParams, ResetMode};
    use fullerene_soc::core::{Codebook, SynapsesBuilder};
    let cb = Codebook::default_log16();
    let mut bld = SynapsesBuilder::new(
        benches_support_axons(),
        benches_support_neurons(),
        cb.n(),
    );
    bld.connect_dense(|a, n| ((a * 31 + n * 7) % 16) as u8).unwrap();
    fullerene_soc::core::NeuroCore::new(
        0,
        benches_support_axons(),
        benches_support_neurons(),
        NeuronParams {
            threshold: 5000,
            leak: LeakMode::Linear(2),
            reset: ResetMode::Subtract,
            mp_bits: 16,
        },
        cb,
        bld.build(),
        energy.clone(),
    )
    .unwrap()
}

fn benches_support_axons() -> usize {
    fullerene_soc::benches_support::FIG3_AXONS
}

fn benches_support_neurons() -> usize {
    fullerene_soc::benches_support::FIG3_NEURONS
}
