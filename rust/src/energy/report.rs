//! Chip-level report assembly: turns ledgers + area model into the
//! Table-I-style row for a workload run.

use super::{AreaModel, EnergyBreakdown, EnergyLedger, EnergyParams};
use crate::metrics::table::Table;


/// End-to-end chip report for one workload (one Table I column).
#[derive(Debug, Clone)]
pub struct ChipReport {
    /// Workload name (e.g. "nmnist-syn").
    pub workload: String,
    /// Neuromorphic-processor frequency used (Hz).
    pub f_core_hz: f64,
    /// Supply voltage (V).
    pub supply_v: f64,
    /// Wall cycles simulated on the neuromorphic-processor clock.
    pub cycles: u64,
    /// Total synapse operations performed.
    pub sops: u64,
    /// Total spikes routed through the NoC.
    pub spikes_routed: u64,
    /// Classified samples (if the workload is a classification task).
    pub samples: u64,
    /// Classification accuracy in [0,1] (if applicable).
    pub accuracy: Option<f64>,
    /// Chip energy per synapse op (pJ/SOP) — whole-SoC accounting.
    pub pj_per_sop: f64,
    /// Core-complex energy per synapse op (pJ/SOP) — the paper's Table-I
    /// accounting (neuromorphic cores only).
    pub core_pj_per_sop: f64,
    /// Average chip power (mW).
    pub power_mw: f64,
    /// Power density (mW/mm²).
    pub power_density: f64,
    /// Neuron density (K/mm²) — static, from the area model.
    pub neuron_density_k_mm2: f64,
    /// Inference latency per sample (ms), if samples > 0.
    pub latency_ms_per_sample: Option<f64>,
    /// Itemized energy.
    pub breakdown: EnergyBreakdown,
}

impl ChipReport {
    /// Assemble a report from a merged ledger.
    #[allow(clippy::too_many_arguments)]
    pub fn from_ledger(
        workload: &str,
        ledger: &EnergyLedger,
        params: &EnergyParams,
        area: &AreaModel,
        f_core_hz: f64,
        cycles: u64,
        samples: u64,
        accuracy: Option<f64>,
        spikes_routed: u64,
    ) -> Self {
        use crate::energy::model::EventClass;
        let sops = ledger.count(EventClass::Sop);
        let power_mw = ledger.avg_power_mw(params, cycles, f_core_hz);
        let pj_per_sop = ledger.pj_per_sop(params, f_core_hz).unwrap_or(f64::NAN);
        let core_pj_per_sop = ledger
            .core_pj_per_sop(params, f_core_hz)
            .unwrap_or(f64::NAN);
        let latency = (samples > 0)
            .then(|| cycles as f64 / f_core_hz * 1000.0 / samples as f64);
        ChipReport {
            workload: workload.to_string(),
            f_core_hz,
            supply_v: params.supply_v,
            cycles,
            sops,
            spikes_routed,
            samples,
            accuracy,
            pj_per_sop,
            core_pj_per_sop,
            power_mw,
            power_density: area.power_density(power_mw),
            neuron_density_k_mm2: area.neuron_density_k_per_mm2(),
            latency_ms_per_sample: latency,
            breakdown: ledger.breakdown(params, f_core_hz),
        }
    }

    /// Render several reports as a Table-I-style comparison table.
    pub fn table(reports: &[ChipReport]) -> Table {
        let mut t = Table::new(&["metric"]);
        for r in reports {
            t.add_column(&r.workload);
        }
        let fmt_opt = |v: Option<f64>, scale: f64, digits: usize| {
            v.map(|x| format!("{:.*}", digits, x * scale))
                .unwrap_or_else(|| "N.A.".into())
        };
        t.row(
            "frequency (MHz)",
            reports.iter().map(|r| format!("{:.0}", r.f_core_hz / 1e6)),
        );
        t.row(
            "supply (V)",
            reports.iter().map(|r| format!("{:.2}", r.supply_v)),
        );
        t.row("SOPs", reports.iter().map(|r| r.sops.to_string()));
        t.row(
            "core energy eff. (pJ/SOP)",
            reports.iter().map(|r| format!("{:.3}", r.core_pj_per_sop)),
        );
        t.row(
            "chip energy eff. (pJ/SOP)",
            reports.iter().map(|r| format!("{:.3}", r.pj_per_sop)),
        );
        t.row(
            "power (mW)",
            reports.iter().map(|r| format!("{:.2}", r.power_mw)),
        );
        t.row(
            "power density (mW/mm^2)",
            reports.iter().map(|r| format!("{:.2}", r.power_density)),
        );
        t.row(
            "neuron density (K/mm^2)",
            reports
                .iter()
                .map(|r| format!("{:.2}", r.neuron_density_k_mm2)),
        );
        t.row(
            "accuracy (%)",
            reports.iter().map(|r| fmt_opt(r.accuracy, 100.0, 1)),
        );
        t.row(
            "latency (ms/sample)",
            reports
                .iter()
                .map(|r| fmt_opt(r.latency_ms_per_sample, 1.0, 3)),
        );
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::model::EventClass;

    #[test]
    fn report_from_ledger_computes_density_and_power() {
        let p = EnergyParams::nominal();
        let a = AreaModel::paper_chip();
        let mut l = EnergyLedger::new();
        l.add(EventClass::Sop, 1_000_000);
        let r = ChipReport::from_ledger("t", &l, &p, &a, 100e6, 1_000_000, 10, Some(0.9), 123);
        assert_eq!(r.sops, 1_000_000);
        assert!(r.pj_per_sop > 0.0);
        assert!(r.power_mw > 0.0);
        assert!((r.neuron_density_k_mm2 - 30.23).abs() < 1.0);
        assert!(r.latency_ms_per_sample.unwrap() > 0.0);
    }

    #[test]
    fn table_renders_all_rows() {
        let p = EnergyParams::nominal();
        let a = AreaModel::paper_chip();
        let mut l = EnergyLedger::new();
        l.add(EventClass::Sop, 100);
        let r = ChipReport::from_ledger("w", &l, &p, &a, 100e6, 100, 0, None, 0);
        let t = ChipReport::table(&[r]);
        let s = t.render();
        assert!(s.contains("pJ/SOP"));
        assert!(s.contains("N.A."));
    }
}
