//! Synthetic traffic generators for the NoC benches (Fig. 5c): uniform
//! random, hotspot, nearest-neighbor and broadcast-heavy patterns, plus a
//! Poisson injection process.

use super::packet::Dest;
use super::Fabric;
use crate::util::prng::Rng;

/// A traffic pattern: maps (source core, rng) to a destination.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pattern {
    /// Uniform random destination ≠ source.
    Uniform,
    /// All traffic converges on core 0 with probability ¾, else uniform.
    Hotspot,
    /// Destination = (src + 1) mod n (neighbor-ish).
    Neighbor,
    /// Broadcast to `fanout` random destinations.
    Broadcast(usize),
}

/// Poisson traffic driver over a [`NocSim`].
pub struct TrafficGen {
    pattern: Pattern,
    /// Offered load: expected injections per core per cycle.
    rate: f64,
    rng: Rng,
    n_cores: usize,
    injected: u64,
}

impl TrafficGen {
    /// New generator with injection `rate` (flits/core/cycle) and `seed`.
    pub fn new(pattern: Pattern, rate: f64, n_cores: usize, seed: u64) -> Self {
        TrafficGen {
            pattern,
            rate,
            rng: Rng::new(seed),
            n_cores,
            injected: 0,
        }
    }

    /// Total flit injections performed.
    pub fn injected(&self) -> u64 {
        self.injected
    }

    fn dest_for(&mut self, src: usize) -> Dest {
        match self.pattern {
            Pattern::Uniform => {
                let mut d = self.rng.below_usize(self.n_cores - 1);
                if d >= src {
                    d += 1;
                }
                Dest::Core(d)
            }
            Pattern::Hotspot => {
                if self.rng.bool(0.75) && src != 0 {
                    Dest::Core(0)
                } else {
                    let mut d = self.rng.below_usize(self.n_cores - 1);
                    if d >= src {
                        d += 1;
                    }
                    Dest::Core(d)
                }
            }
            Pattern::Neighbor => Dest::Core((src + 1) % self.n_cores),
            Pattern::Broadcast(k) => {
                let mut dsts: Vec<usize> = self
                    .rng
                    .choose_k(self.n_cores - 1, k)
                    .into_iter()
                    .map(|d| if d >= src { d + 1 } else { d })
                    .collect();
                dsts.sort_unstable();
                Dest::Cores(dsts)
            }
        }
    }

    /// Inject one cycle's worth of traffic into `sim` (any [`Fabric`]:
    /// the event-driven simulator or the reference oracle).
    pub fn tick(&mut self, sim: &mut impl Fabric) {
        for src in 0..self.n_cores {
            let k = self.rng.poisson(self.rate);
            for _ in 0..k {
                let dest = self.dest_for(src);
                let axon = self.rng.next_u32() % 1024;
                let ids = sim.inject(src, &dest, axon);
                self.injected += ids.end - ids.start;
            }
        }
    }

    /// Drive `sim` for `cycles` of offered load then drain.
    pub fn run(&mut self, sim: &mut impl Fabric, cycles: u64) -> crate::Result<()> {
        for _ in 0..cycles {
            self.tick(sim);
            sim.step();
        }
        sim.run_until_drained(1_000_000)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::EnergyParams;
    use crate::noc::topology::Topology;
    use crate::noc::NocSim;

    #[test]
    fn uniform_load_delivers_everything() {
        let mut sim = NocSim::new(Topology::fullerene(), 4, EnergyParams::nominal());
        let mut tg = TrafficGen::new(Pattern::Uniform, 0.05, 20, 42);
        tg.run(&mut sim, 200).unwrap();
        let st = sim.stats();
        assert_eq!(st.delivered, tg.injected());
        assert!(st.avg_hops >= 1.0);
    }

    #[test]
    fn hotspot_raises_latency_vs_uniform() {
        let run = |pattern| {
            let mut sim = NocSim::new(Topology::fullerene(), 4, EnergyParams::nominal());
            let mut tg = TrafficGen::new(pattern, 0.15, 20, 7);
            tg.run(&mut sim, 300).unwrap();
            sim.stats().avg_latency
        };
        let uni = run(Pattern::Uniform);
        let hot = run(Pattern::Hotspot);
        assert!(
            hot > uni,
            "hotspot latency {hot} should exceed uniform {uni}"
        );
    }

    #[test]
    fn generator_drives_optimized_and_reference_identically() {
        use crate::noc::ReferenceNocSim;
        let mut a = NocSim::new(Topology::fullerene(), 4, EnergyParams::nominal());
        let mut b = ReferenceNocSim::new(Topology::fullerene(), 4, EnergyParams::nominal());
        let mut ta = TrafficGen::new(Pattern::Uniform, 0.1, 20, 5);
        let mut tb = TrafficGen::new(Pattern::Uniform, 0.1, 20, 5);
        ta.run(&mut a, 100).unwrap();
        tb.run(&mut b, 100).unwrap();
        assert_eq!(ta.injected(), tb.injected());
        let (sa, sb) = (a.stats(), b.stats());
        assert_eq!(sa.delivered, sb.delivered);
        assert_eq!(sa.avg_latency.to_bits(), sb.avg_latency.to_bits());
    }

    #[test]
    fn broadcast_pattern_multiplies_deliveries() {
        let mut sim = NocSim::new(Topology::fullerene(), 4, EnergyParams::nominal());
        let mut tg = TrafficGen::new(Pattern::Broadcast(3), 0.02, 20, 9);
        tg.run(&mut sim, 100).unwrap();
        assert_eq!(sim.stats().delivered, tg.injected());
        assert!(tg.injected() % 3 == 0, "each injection makes 3 copies");
    }
}
