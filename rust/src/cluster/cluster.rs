//! [`Cluster`]: N simulated chips joined by the off-chip L3 ring,
//! serving one logical network partitioned across them.
//!
//! ## Lockstep semantics
//!
//! The chip propagates spikes through **all** layers within one
//! timestep (the pipelined-reference contract of
//! [`NetworkDesc::reference_run`]), so the cluster must do the same
//! across chips: within timestep `t`, shard 0 runs its layers, its
//! terminal spikes cross the ring, shard 1 runs its layers on them —
//! still at `t` — and so on down the chain. The cycle-interleaved
//! driver therefore serializes shards *within* a timestep (that is also
//! the latency truth: a sample's spikes physically traverse the chips
//! in sequence) while every chip keeps its own ledgers, clocks and
//! fault state.
//!
//! ## Shard contract
//!
//! Each shard is an unmodified [`Soc`] running a contiguous-layer
//! sub-network (see [`crate::cluster::ClusterMapper`]), driven through
//! the decomposed `sample_begin`/`sample_timestep`/`sample_end` path.
//! Non-terminal shards emit their last-layer spikes as **layer-local
//! neuron ids** — exactly the next shard's input axon space — and skip
//! the readout path entirely; only the terminal shard accounts the
//! logical sample (prediction, accuracy, sample counters). On-chip
//! fault plans arm identically on every shard fabric; L3 events arm on
//! the ring ([`crate::noc::FaultPlan::split_l3`]).
//!
//! ## The N = 1 oracle
//!
//! A single-chip cluster holds one shard over the whole network and no
//! ring, and every public method delegates straight to that [`Soc`] —
//! so an N = 1 cluster is **bit-identical** to a plain chip (reports,
//! ledgers, spike order, `f64::to_bits`), which anchors the cluster to
//! every existing equivalence chain. Pinned in `tests/cluster.rs`.

use super::l3::{L3Fabric, L3Stats};
use super::mapper::{ClusterMapper, Partition};
use crate::datasets::Sample;
use crate::energy::{AreaModel, ChipReport, EnergyParams};
use crate::nn::NetworkDesc;
use crate::noc::{FabricHealth, FaultPlan, SimStats};
use crate::soc::{SampleResult, Soc, SocConfig};
use crate::{Error, Result};

/// Cluster-wide flit accounting: every spike flit handed to any fabric
/// (the shard NoCs and the L3 ring) must be delivered, dropped, or in
/// flight — nothing may leak. [`Cluster::conservation`] sums the books;
/// `tests/cluster.rs` holds the equality under random fault plans.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClusterConservation {
    /// Flits injected: on-chip routed spikes (one per destination core)
    /// plus flits handed to the L3 ring.
    pub injected: u64,
    /// Flits that reached a destination core or crossed the ring.
    pub delivered: u64,
    /// Flits discarded on degraded fabric (on-chip or L3).
    pub dropped: u64,
    /// Flits still inside a shard NoC (always 0 at sample boundaries;
    /// the L3 ring never holds flits across a boundary).
    pub in_flight: u64,
}

impl ClusterConservation {
    /// `injected == delivered + dropped + in_flight` — the invariant.
    pub fn holds(&self) -> bool {
        self.injected == self.delivered + self.dropped + self.in_flight
    }
}

/// N simulated chips + the off-chip L3 ring, serving one logical
/// network. Mirrors the [`Soc`] serving surface (`run_sample`,
/// `snapshot_report`, `finish_report`, `reset_for_session`…) so
/// [`crate::cluster::Engine`] can dispatch sessions to either.
pub struct Cluster {
    config: SocConfig,
    net: NetworkDesc,
    partition: Partition,
    /// One Soc per partition shard, in layer order. Shard `i` maps to
    /// ring node `shard_nodes[i]` — the identity on the base partition;
    /// ring nodes not hosting a shard exist (physical chips, targetable
    /// by `kill-l3`) but carry no mapped layers.
    shards: Vec<Soc>,
    /// Ring node hosting each shard. Diverges from the identity only
    /// after a failover replan excludes dead nodes.
    shard_nodes: Vec<usize>,
    /// `None` on a single-chip cluster (no off-chip ring exists).
    l3: Option<L3Fabric>,
    /// Failover replans performed this accounting window.
    replans: u64,
    /// Flit books of shards retired by failover rebuilds, folded so
    /// [`Cluster::conservation`] spans the whole session including the
    /// pre-replan configuration (`in_flight` is always 0 — replans only
    /// happen at sample boundaries, where every shard NoC is drained).
    saved: ClusterConservation,
    energy: EnergyParams,
    area: AreaModel,
}

impl Cluster {
    /// Assemble a cluster of `config.chips` chips running `net`. With
    /// `chips == 1` this is a boxed plain chip (the oracle case); with
    /// more, the network is min-cut partitioned and the ring built. The
    /// config's fault plan splits at this choke point: on-chip events
    /// validate against every shard fabric, L3 events against the ring.
    pub fn new(net: NetworkDesc, config: SocConfig) -> Result<Cluster> {
        if config.chips == 0 {
            return Err(Error::Soc("chips must be >= 1".into()));
        }
        let energy = EnergyParams::nominal().at_voltage(config.supply_v);
        let area = AreaModel::multi_chip(config.domains);
        if config.chips == 1 {
            // Soc::new rejects L3 fault events via the fabric validator.
            let soc = Soc::new(net.clone(), config.clone())?;
            return Ok(Cluster {
                config,
                partition: Partition {
                    ranges: vec![(0, net.layers.len())],
                    cut_neurons: 0,
                },
                net,
                shards: vec![soc],
                shard_nodes: vec![0],
                l3: None,
                replans: 0,
                saved: ClusterConservation::default(),
                energy,
                area,
            });
        }
        let (chip_plan, l3_plan) = config.fault_plan.split_l3();
        let partition = ClusterMapper::plan(
            &net,
            config.chips,
            config.n_cores,
            config.max_neurons_per_core,
        )?;
        let mut shards = Vec::with_capacity(partition.shards());
        for s in 0..partition.shards() {
            let shard_config = SocConfig {
                chips: 1,
                fault_plan: chip_plan.clone(),
                ..config.clone()
            };
            shards.push(Soc::new(partition.sub_net(&net, s), shard_config)?);
        }
        let l3 = L3Fabric::new(config.chips, &l3_plan)?;
        let shard_nodes = (0..partition.shards()).collect();
        Ok(Cluster {
            config,
            net,
            partition,
            shards,
            shard_nodes,
            l3: Some(l3),
            replans: 0,
            saved: ClusterConservation::default(),
            energy,
            area,
        })
    }

    /// The cluster's configuration (`config.chips` is the ring size).
    pub fn config(&self) -> &SocConfig {
        &self.config
    }

    /// The logical network served (pre-partitioning).
    pub fn network(&self) -> &NetworkDesc {
        &self.net
    }

    /// How the network is split across chips.
    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// Physical chips in the cluster (the L3 ring size).
    pub fn chips(&self) -> usize {
        self.config.chips
    }

    /// Chips actually carrying mapped layers (≤ [`Cluster::chips`]).
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Ring counters, when a ring exists (`chips > 1`).
    pub fn l3_stats(&self) -> Option<L3Stats> {
        self.l3.as_ref().map(|l3| l3.stats())
    }

    /// Failover replans performed this accounting window (0 unless
    /// `config.failover` and a shard's ring node died).
    pub fn replans(&self) -> u64 {
        self.replans
    }

    /// Ring node hosting each shard (the identity until a failover
    /// replan moves shards off dead nodes).
    pub fn shard_nodes(&self) -> &[usize] {
        &self.shard_nodes
    }

    /// Failover at a sample boundary: when any shard's ring node has
    /// died, re-partition the network over the surviving nodes and
    /// rebuild the shard chips fresh ([`ClusterMapper::replan`]). The
    /// retired shards' flit books fold into `saved` so cluster-wide
    /// conservation spans the replan; the L3 ring is **not** rebuilt —
    /// its dead nodes, counters and pending schedule carry the session's
    /// degradation history forward. When the survivors cannot host the
    /// network the cluster simply stays in its degraded configuration
    /// (drops keep the books; the next boundary retries).
    fn maybe_replan(&mut self) -> Result<()> {
        let Some(l3) = &self.l3 else {
            return Ok(());
        };
        if !self.shard_nodes.iter().any(|&n| l3.node_dead(n)) {
            return Ok(());
        }
        let dead: Vec<bool> = (0..self.config.chips).map(|c| l3.node_dead(c)).collect();
        let Ok((partition, nodes)) = ClusterMapper::replan(
            &self.net,
            self.config.chips,
            &dead,
            self.config.n_cores,
            self.config.max_neurons_per_core,
        ) else {
            return Ok(());
        };
        for s in &self.shards {
            self.saved.injected += s.spikes_routed_window();
            self.saved.delivered += s.noc_stats().delivered;
            self.saved.dropped += s.fabric_health().dropped;
        }
        let (chip_plan, _) = self.config.fault_plan.split_l3();
        let mut shards = Vec::with_capacity(partition.shards());
        for s in 0..partition.shards() {
            let shard_config = SocConfig {
                chips: 1,
                fault_plan: chip_plan.clone(),
                ..self.config.clone()
            };
            shards.push(Soc::new(partition.sub_net(&self.net, s), shard_config)?);
        }
        self.shards = shards;
        self.partition = partition;
        self.shard_nodes = nodes;
        self.replans += 1;
        Ok(())
    }

    /// The L3 ring as a serving-grade error instead of a panic: a
    /// multi-chip cluster always has one, but this sits on the serving
    /// path and must degrade to a session failure, not a crash.
    fn ring(&self) -> Result<&L3Fabric> {
        self.l3
            .as_ref()
            .ok_or_else(|| Error::Soc("multi-chip cluster lost its L3 ring".into()))
    }

    /// Run one sample across the cluster. The aggregate
    /// [`SampleResult`] sums compute over shards (cycles additionally
    /// include the ring's transfer latency — within a timestep the
    /// shards are pipeline stages of one sample, so their cycles add);
    /// prediction/accuracy come from the terminal shard's readout.
    pub fn run_sample(&mut self, sample: &Sample, label_known: bool) -> Result<SampleResult> {
        if self.l3.is_none() {
            // Single chip: the exact Soc path, bit for bit.
            return self.shards[0].run_sample(sample, label_known);
        }
        if self.config.failover {
            self.maybe_replan()?;
        }
        let (l3_cycles0, l3_injected0) = {
            let s = self.ring()?.stats();
            (s.cycles, s.injected)
        };
        for s in &mut self.shards {
            s.sample_begin()?;
        }
        let n = self.shards.len();
        let mut egress: Vec<u32> = Vec::new();
        for t in 0..self.net.timesteps {
            if let Some(l3) = &mut self.l3 {
                l3.set_timestep(t as u32);
            }
            let mut ingress: Vec<u32> = sample.spikes_at(t as u16);
            for si in 0..n {
                if si + 1 == n {
                    self.shards[si].sample_timestep(t, &ingress, None)?;
                } else {
                    egress.clear();
                    self.shards[si].sample_timestep(t, &ingress, Some(&mut egress))?;
                    // Placement order already yields ascending ids, but
                    // the input contract (sorted axons) is the next
                    // chip's, so enforce it at the boundary.
                    egress.sort_unstable();
                    let l3 = self
                        .l3
                        .as_mut()
                        .ok_or_else(|| Error::Soc("multi-chip cluster lost its L3 ring".into()))?;
                    let delivered = l3.transfer(
                        self.shard_nodes[si],
                        self.shard_nodes[si + 1],
                        egress.len() as u64,
                    )?;
                    ingress.clear();
                    if delivered {
                        ingress.extend_from_slice(&egress);
                    }
                }
            }
        }
        let mut agg = SampleResult {
            predicted: 0,
            counts: Vec::new(),
            correct: false,
            cycles: 0,
            sops: 0,
            spikes_routed: 0,
            cores_ticked: 0,
        };
        for si in 0..n {
            let r = if si + 1 == n {
                self.shards[si].sample_end(sample.label, label_known, true)?
            } else {
                self.shards[si].sample_end(0, false, false)?
            };
            agg.cycles += r.cycles;
            agg.sops += r.sops;
            agg.spikes_routed += r.spikes_routed;
            agg.cores_ticked += r.cores_ticked;
            if si + 1 == n {
                agg.predicted = r.predicted;
                agg.counts = r.counts;
                agg.correct = r.correct;
            }
        }
        let l3s = self.ring()?.stats();
        agg.cycles += l3s.cycles - l3_cycles0;
        agg.spikes_routed += l3s.injected - l3_injected0;
        Ok(agg)
    }

    /// Cluster wall clock: the slowest shard's accounting window (ring
    /// statics are charged over this span).
    fn wall(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.total_cycles())
            .max()
            .unwrap_or(0)
            .max(1)
    }

    /// Incremental cluster report: shard chip reports merged with the
    /// ring's ledger (as a compute-free pseudo-report contributing the
    /// off-chip transport energy) through [`ChipReport::merged`] — the
    /// same deterministic fold the multi-session serving paths use, so
    /// downstream merges keep composing. Single-chip clusters return
    /// the shard's report verbatim (bit-identity).
    pub fn snapshot_report(&self, workload: &str) -> ChipReport {
        let Some(l3) = &self.l3 else {
            return self.shards[0].snapshot_report(workload);
        };
        let mut reports: Vec<ChipReport> = self
            .shards
            .iter()
            .map(|s| s.snapshot_report(workload))
            .collect();
        reports.push(ChipReport::from_ledger(
            workload,
            &l3.snapshot_ledger(self.wall(), &self.energy),
            &self.energy,
            &self.area,
            self.config.f_core_hz,
            0,
            0,
            0,
            None,
            0,
        ));
        ChipReport::merged(&reports, &self.area)
            // lint:allow(no-silent-panic-in-serving) shards clone one SocConfig, so operating points match
            .expect("shard reports share one operating point by construction")
    }

    /// Final report + accounting reset (shards and ring), mirroring
    /// [`Soc::finish_report`].
    pub fn finish_report(&mut self, workload: &str) -> ChipReport {
        let report = self.snapshot_report(workload);
        self.reset_accounting();
        report
    }

    /// Re-arm every shard for a fresh session and heal/re-arm the ring —
    /// the cluster half of the warm == fresh contract
    /// ([`Soc::reset_for_session`] per shard). A cluster that failed
    /// over mid-session first restores the **base** partition (the one a
    /// fresh build would plan), so warm == fresh survives failover.
    pub fn reset_for_session(&mut self) {
        if self.replans > 0 {
            let partition = ClusterMapper::plan(
                &self.net,
                self.config.chips,
                self.config.n_cores,
                self.config.max_neurons_per_core,
            )
            // lint:allow(no-silent-panic-in-serving) replayed construction-time plan cannot newly fail
            .expect("base partition planned successfully at construction");
            let (chip_plan, _) = self.config.fault_plan.split_l3();
            let mut shards = Vec::with_capacity(partition.shards());
            for s in 0..partition.shards() {
                let shard_config = SocConfig {
                    chips: 1,
                    fault_plan: chip_plan.clone(),
                    ..self.config.clone()
                };
                shards.push(
                    Soc::new(partition.sub_net(&self.net, s), shard_config)
                        // lint:allow(no-silent-panic-in-serving) replayed construction-time build cannot newly fail
                        .expect("base shards built successfully at construction"),
                );
            }
            self.shards = shards;
            self.shard_nodes = (0..partition.shards()).collect();
            self.partition = partition;
            self.replans = 0;
        }
        self.saved = ClusterConservation::default();
        for s in &mut self.shards {
            s.reset_for_session();
        }
        if let Some(l3) = &mut self.l3 {
            l3.reset_accounting();
        }
    }

    /// Zero every ledger and counter (shards + ring) while keeping the
    /// built cluster, mirroring [`Soc::reset_accounting`]. A replanned
    /// cluster keeps its degraded-capacity layout (the next window keeps
    /// serving on the survivors); only [`Cluster::reset_for_session`]
    /// restores the base partition.
    pub fn reset_accounting(&mut self) {
        self.saved = ClusterConservation::default();
        self.replans = 0;
        for s in &mut self.shards {
            s.reset_accounting();
        }
        if let Some(l3) = &mut self.l3 {
            l3.reset_accounting();
        }
    }

    /// Replace the armed fault plan cluster-wide: the on-chip half
    /// re-arms on every shard fabric, the L3 half on a rebuilt ring.
    /// Only valid between sessions (drained fabrics, zeroed windows) —
    /// the serving retry loop calls this right after
    /// [`Cluster::reset_for_session`] to install a plan's unfired tail.
    pub fn rearm_fault_plan(&mut self, plan: FaultPlan) -> Result<()> {
        let (chip_plan, l3_plan) = plan.split_l3();
        l3_plan.validate_l3(self.config.chips)?;
        for s in &mut self.shards {
            s.rearm_fault_plan(chip_plan.clone())?;
        }
        if self.l3.is_some() {
            self.l3 = Some(L3Fabric::new(self.config.chips, &l3_plan)?);
        }
        self.config.fault_plan = plan;
        Ok(())
    }

    /// Fabric statistics summed over shard NoCs (the serving surface's
    /// delivery/stall view). Averages are delivery-weighted; latency
    /// extrema take the cluster-wide max. The L3 ring is reported
    /// separately via [`Cluster::l3_stats`].
    pub fn noc_stats(&self) -> SimStats {
        let stats: Vec<SimStats> = self.shards.iter().map(|s| s.noc_stats()).collect();
        let delivered: u64 = stats.iter().map(|s| s.delivered).sum();
        let cycles: u64 = stats.iter().map(|s| s.cycles).sum();
        let wsum = |f: fn(&SimStats) -> f64| -> f64 {
            if delivered == 0 {
                return 0.0;
            }
            stats.iter().map(|s| f(s) * s.delivered as f64).sum::<f64>() / delivered as f64
        };
        SimStats {
            cycles,
            delivered,
            avg_latency: wsum(|s| s.avg_latency),
            avg_hops: wsum(|s| s.avg_hops),
            max_latency: stats.iter().map(|s| s.max_latency).max().unwrap_or(0),
            throughput: if cycles == 0 {
                0.0
            } else {
                delivered as f64 / cycles as f64
            },
            stalls_backpressure: stats.iter().map(|s| s.stalls_backpressure).sum(),
            stalls_timestep: stats.iter().map(|s| s.stalls_timestep).sum(),
        }
    }

    /// Degradation counters summed across every fabric — shard NoCs and
    /// the L3 ring (dead ring nodes count as dead routers).
    pub fn fabric_health(&self) -> FabricHealth {
        let mut h = FabricHealth::default();
        for s in &self.shards {
            let sh = s.fabric_health();
            h.armed |= sh.armed;
            h.dropped += sh.dropped;
            h.rerouted_hops += sh.rerouted_hops;
            h.dead_routers += sh.dead_routers;
            h.dead_links += sh.dead_links;
        }
        if let Some(l3) = &self.l3 {
            let lh = l3.fabric_health();
            h.armed |= lh.armed;
            h.dropped += lh.dropped;
            h.rerouted_hops += lh.rerouted_hops;
            h.dead_routers += lh.dead_routers;
            h.dead_links += lh.dead_links;
        }
        h
    }

    /// The cluster-wide flit books (see [`ClusterConservation`]),
    /// including any shards retired by failover replans this window.
    pub fn conservation(&self) -> ClusterConservation {
        let mut c = self.saved;
        for s in &self.shards {
            c.injected += s.spikes_routed_window();
            c.delivered += s.noc_stats().delivered;
            c.dropped += s.fabric_health().dropped;
            c.in_flight += s.noc_in_flight();
        }
        if let Some(l3) = &self.l3 {
            let ls = l3.stats();
            c.injected += ls.injected;
            c.delivered += ls.delivered;
            c.dropped += ls.dropped;
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::neuron::{LeakMode, NeuronParams, ResetMode};
    use crate::core::Codebook;
    use crate::nn::network::LayerDesc;

    /// Deterministic synthetic spike streams (dense enough to cross
    /// every shard boundary).
    fn samples(n: usize, inputs: usize, timesteps: usize, seed: u64) -> Vec<Sample> {
        (0..n)
            .map(|i| {
                let mut events = Vec::new();
                for t in 0..timesteps {
                    for a in 0..inputs {
                        if (a as u64 * 7 + t as u64 * 13 + i as u64 * 31 + seed) % 4 == 0 {
                            events.push((t as u16, a as u32));
                        }
                    }
                }
                Sample {
                    label: i % 10,
                    events,
                }
            })
            .collect()
    }

    /// A deep chain that propagates spikes, sized so `max_cores` per
    /// chip forces a multi-shard partition.
    fn deep_net(inputs: usize, widths: &[usize], classes: usize, timesteps: usize) -> NetworkDesc {
        let cb = Codebook::default_log16();
        let params = NeuronParams {
            threshold: 40,
            leak: LeakMode::Linear(1),
            reset: ResetMode::Subtract,
            mp_bits: 16,
        };
        let mut layers = Vec::new();
        let mut prev = inputs;
        for (i, &w) in widths.iter().chain(std::iter::once(&classes)).enumerate() {
            layers.push(LayerDesc {
                name: format!("l{i}"),
                inputs: prev,
                neurons: w,
                codebook: cb.clone(),
                widx: (0..prev * w).map(|j| ((j * 7) % 16) as u8).collect(),
                neuron_params: params.clone(),
            });
            prev = w;
        }
        NetworkDesc {
            name: "cluster-test".into(),
            layers,
            timesteps,
            classes,
        }
    }

    fn tight_config(chips: usize, n_cores: usize) -> SocConfig {
        SocConfig {
            chips,
            n_cores,
            max_neurons_per_core: 16,
            ..SocConfig::default()
        }
    }

    #[test]
    fn multi_shard_cluster_matches_the_functional_reference() {
        // 3 layers × 2 cores at 3 cores/chip → 2 shards minimum.
        let net = deep_net(16, &[32, 32], 10, 6);
        let data = samples(5, 16, 6, 77);
        let mut cluster = Cluster::new(net.clone(), tight_config(2, 3)).unwrap();
        assert_eq!(cluster.shards(), 2);
        assert!(cluster.partition().cut_neurons > 0);
        for s in &data {
            let r = cluster.run_sample(s, true).unwrap();
            let raster = s.to_raster(net.timesteps, net.input_size());
            assert_eq!(
                r.counts,
                net.reference_run(&raster),
                "partitioned execution must match the unpartitioned reference"
            );
        }
        let c = cluster.conservation();
        assert!(c.holds(), "{c:?}");
        assert_eq!(c.in_flight, 0, "drained at sample boundaries");
        let l3 = cluster.l3_stats().unwrap();
        assert_eq!(l3.injected, l3.delivered, "healthy ring drops nothing");
        // The report merges shard compute with ring transport energy.
        let report = cluster.snapshot_report("t");
        assert!(report.sops > 0);
        assert!(
            report.breakdown.by_class.get("HopL3").copied().unwrap_or(0.0) > 0.0
                || l3.injected == 0,
            "cross-chip traffic must charge HopL3"
        );
    }

    #[test]
    fn warm_cluster_is_bit_identical_to_fresh() {
        let net = deep_net(16, &[32, 32], 10, 5);
        let data = samples(3, 16, 5, 13);
        let cfg = tight_config(2, 3);
        let mut warm = Cluster::new(net.clone(), cfg.clone()).unwrap();
        for s in &data {
            warm.run_sample(s, true).unwrap();
        }
        warm.reset_for_session();
        let mut fresh = Cluster::new(net, cfg).unwrap();
        for s in &data {
            let a = warm.run_sample(s, true).unwrap();
            let b = fresh.run_sample(s, true).unwrap();
            assert_eq!(a.counts, b.counts);
            assert_eq!(a.cycles, b.cycles);
            assert_eq!(a.sops, b.sops);
        }
        let (ra, rb) = (warm.snapshot_report("w"), fresh.snapshot_report("w"));
        assert_eq!(ra.cycles, rb.cycles);
        assert_eq!(ra.sops, rb.sops);
        assert_eq!(
            ra.breakdown.dynamic_pj.to_bits(),
            rb.breakdown.dynamic_pj.to_bits()
        );
        assert_eq!(
            ra.breakdown.static_pj.to_bits(),
            rb.breakdown.static_pj.to_bits()
        );
    }

    #[test]
    fn dead_ring_degrades_gracefully_and_keeps_the_books() {
        let net = deep_net(16, &[32, 32], 10, 6);
        let mut cfg = tight_config(2, 3);
        // Kill the terminal shard's ring node mid-run: cross-chip spikes
        // must drop (conservation intact), not crash or wedge.
        cfg.fault_plan = crate::noc::FaultPlan::none()
            .kill_l3(1, crate::noc::When::Timestep(3));
        let data = samples(4, 16, 6, 5);
        let mut cluster = Cluster::new(net, cfg).unwrap();
        for s in &data {
            cluster.run_sample(s, true).unwrap();
        }
        let c = cluster.conservation();
        assert!(c.holds(), "{c:?}");
        let l3 = cluster.l3_stats().unwrap();
        assert!(l3.dropped > 0, "the dead ring node must drop traffic");
        let h = cluster.fabric_health();
        assert!(h.armed);
        assert_eq!(h.dead_routers, 1);
        // finish_report heals: the next window starts clean and armed.
        let _ = cluster.finish_report("k");
        assert_eq!(cluster.fabric_health().dead_routers, 0);
        assert_eq!(cluster.l3_stats().unwrap().injected, 0);
    }

    #[test]
    fn failover_replans_onto_surviving_chips_and_keeps_the_books() {
        // 3 layers × 2 cores at 4 cores/chip → 2 shards; a 3-ring leaves
        // one spare node for the terminal shard to fail over onto.
        let net = deep_net(16, &[32, 32], 10, 6);
        let mut cfg = tight_config(3, 4);
        cfg.failover = true;
        cfg.fault_plan = crate::noc::FaultPlan::none()
            .kill_l3(1, crate::noc::When::Timestep(2));
        let data = samples(4, 16, 6, 9);
        let mut cluster = Cluster::new(net.clone(), cfg.clone()).unwrap();
        assert_eq!(cluster.shards(), 2);
        assert_eq!(cluster.shard_nodes(), &[0, 1]);
        // Sample 0 hits the kill mid-flight: cross-chip flits drop.
        cluster.run_sample(&data[0], true).unwrap();
        assert!(cluster.l3_stats().unwrap().dropped > 0);
        assert_eq!(cluster.replans(), 0, "replans happen at boundaries");
        // The next boundary fails over: shard 1 moves to node 2, and the
        // remaining samples match the unpartitioned reference again.
        for s in &data[1..] {
            let r = cluster.run_sample(s, true).unwrap();
            let raster = s.to_raster(net.timesteps, net.input_size());
            assert_eq!(r.counts, net.reference_run(&raster), "post-replan divergence");
        }
        assert_eq!(cluster.replans(), 1);
        assert_eq!(cluster.shard_nodes(), &[0, 2]);
        let c = cluster.conservation();
        assert!(c.holds(), "conservation must span the replan: {c:?}");
        assert_eq!(c.in_flight, 0);
        assert!(c.dropped > 0, "pre-replan drops stay on the books");
        // Warm reset restores the base layout (warm == fresh survives).
        cluster.reset_for_session();
        assert_eq!(cluster.replans(), 0);
        assert_eq!(cluster.shard_nodes(), &[0, 1]);
        // Failover off (the default): same storm, no replan.
        let mut off = cfg;
        off.failover = false;
        let mut degraded = Cluster::new(net, off).unwrap();
        for s in &data {
            degraded.run_sample(s, true).unwrap();
        }
        assert_eq!(degraded.replans(), 0);
        assert!(degraded.conservation().holds());
        assert!(degraded.l3_stats().unwrap().dropped > 0, "stays degraded");
    }

    #[test]
    fn oversubscribed_ring_leaves_unmapped_chips_targetable() {
        // The network fits one chip, but the config buys a 4-ring: the
        // physical routers exist and kill-l3:3 must validate.
        let net = deep_net(16, &[16], 10, 4);
        let mut cfg = SocConfig {
            chips: 4,
            ..SocConfig::default()
        };
        cfg.fault_plan =
            crate::noc::FaultPlan::none().kill_l3(3, crate::noc::When::Cycle(1));
        let cluster = Cluster::new(net, cfg).unwrap();
        assert_eq!(cluster.chips(), 4);
        assert_eq!(cluster.shards(), 1, "everything fits on chip 0");
        // Ring exists → its statics appear in the merged report.
        let report = cluster.snapshot_report("idle");
        assert!(report.breakdown.static_pj > 0.0);
    }
}
