//! Double ping-pong caches (paper §II.A: "Double ping-pong caches
//! facilitate expedited access to spike data and weight index").
//!
//! A [`PingPong`] pairs two banks: the *active* bank is read by the
//! pipeline for the current timestep while the *shadow* bank is filled
//! (by DMA / the NoC receiver) for the next timestep; `swap()` flips the
//! roles at the timestep boundary. Energy is charged by the owner via the
//! ledger; this type tracks access counts for that purpose.

/// A two-bank ping-pong buffer of `T`.
#[derive(Debug, Clone)]
pub struct PingPong<T: Clone + Default> {
    banks: [Vec<T>; 2],
    active: usize,
    reads: u64,
    writes: u64,
}

impl<T: Clone + Default> PingPong<T> {
    /// Create with both banks sized to `capacity` default elements.
    pub fn new(capacity: usize) -> Self {
        PingPong {
            banks: [vec![T::default(); capacity], vec![T::default(); capacity]],
            active: 0,
            reads: 0,
            writes: 0,
        }
    }

    /// Capacity of each bank.
    pub fn capacity(&self) -> usize {
        self.banks[0].len()
    }

    /// Read element `i` of the active bank.
    #[inline]
    pub fn read(&mut self, i: usize) -> T {
        self.reads += 1;
        self.banks[self.active][i].clone()
    }

    /// Read the whole active bank without per-element accounting
    /// (burst read; caller charges `len()` reads itself if needed).
    pub fn active_bank(&self) -> &[T] {
        &self.banks[self.active]
    }

    /// Write element `i` of the shadow bank (the one being filled).
    #[inline]
    pub fn write_shadow(&mut self, i: usize, v: T) {
        self.writes += 1;
        self.banks[1 - self.active][i] = v;
    }

    /// Bulk-fill the shadow bank (counts one write per element).
    ///
    /// **Overwrite** semantics: a second fill within the same timestep
    /// replaces the first. Staging paths that can receive spikes from
    /// several sources per timestep must use [`Self::merge_shadow`]
    /// instead; this method is kept for single-writer fills (and for the
    /// frozen [`crate::core::ReferenceCore`], whose old overwrite bug it
    /// preserves verbatim).
    pub fn fill_shadow(&mut self, data: &[T]) {
        let shadow = &mut self.banks[1 - self.active];
        for (i, v) in data.iter().enumerate() {
            shadow[i] = v.clone();
        }
        // Clear any tail beyond the new data so stale spikes don't leak
        // into the next timestep.
        for slot in shadow.iter_mut().skip(data.len()) {
            *slot = T::default();
        }
        self.writes += data.len() as u64;
    }

    /// Flip active/shadow at the timestep boundary.
    pub fn swap(&mut self) {
        self.active = 1 - self.active;
    }

    /// Zero the active bank (consume-on-read: the pipeline clears spike
    /// words as it drains them, so a timestep with no new staging does not
    /// replay stale spikes).
    pub fn clear_active(&mut self) {
        self.banks[self.active].iter_mut().for_each(|v| *v = T::default());
    }

    /// (reads, writes) performed so far; reset with [`Self::take_counts`].
    pub fn counts(&self) -> (u64, u64) {
        (self.reads, self.writes)
    }

    /// Return and reset the access counters.
    pub fn take_counts(&mut self) -> (u64, u64) {
        let c = (self.reads, self.writes);
        self.reads = 0;
        self.writes = 0;
        c
    }
}

impl<T: Clone + Default + std::ops::BitOrAssign> PingPong<T> {
    /// OR-merge `data` into the shadow bank (counts one write per
    /// element). Unlike [`Self::fill_shadow`] this is **accumulative**
    /// within a timestep: a core staged by several sources (IDMA input,
    /// routed spikes, multiple upstream layers) keeps the union of all
    /// stagings until the bank is swapped in and consumed. The shadow
    /// bank is guaranteed zeroed at the start of each staging window
    /// (consume-on-read clears every bank as it drains), so the first
    /// merge behaves exactly like a fill.
    pub fn merge_shadow(&mut self, data: &[T]) {
        let shadow = &mut self.banks[1 - self.active];
        // Indexing panics on data beyond the bank capacity — the same
        // contract as `fill_shadow`, so misuse can't silently drop
        // spikes or skew the write counter.
        for (i, v) in data.iter().enumerate() {
            shadow[i] |= v.clone();
        }
        self.writes += data.len() as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shadow_write_then_swap_becomes_visible() {
        let mut pp = PingPong::<u16>::new(4);
        pp.write_shadow(0, 7);
        assert_eq!(pp.read(0), 0, "active bank unchanged before swap");
        pp.swap();
        assert_eq!(pp.read(0), 7);
    }

    #[test]
    fn fill_shadow_clears_tail() {
        let mut pp = PingPong::<u16>::new(4);
        pp.fill_shadow(&[1, 2, 3, 4]);
        pp.swap();
        pp.fill_shadow(&[9]);
        pp.swap();
        assert_eq!(pp.active_bank(), &[9, 0, 0, 0]);
    }

    #[test]
    fn merge_shadow_accumulates_within_a_timestep() {
        let mut pp = PingPong::<u16>::new(4);
        // Two sources staging into the same timestep: the union survives.
        pp.merge_shadow(&[0x000F, 0, 0, 0]);
        pp.merge_shadow(&[0x00F0, 0x0001, 0, 0]);
        pp.swap();
        assert_eq!(pp.active_bank(), &[0x00FF, 0x0001, 0, 0]);
        // fill_shadow (single-writer path) keeps overwrite semantics.
        pp.clear_active();
        pp.fill_shadow(&[1, 0, 0, 0]);
        pp.fill_shadow(&[2, 0, 0, 0]);
        pp.swap();
        assert_eq!(pp.active_bank(), &[2, 0, 0, 0]);
    }

    #[test]
    fn merge_shadow_short_data_leaves_tail_untouched() {
        let mut pp = PingPong::<u16>::new(4);
        pp.merge_shadow(&[0, 0, 0, 0x8000]);
        pp.merge_shadow(&[3]);
        pp.swap();
        assert_eq!(pp.active_bank(), &[3, 0, 0, 0x8000]);
    }

    #[test]
    fn counts_track_accesses() {
        let mut pp = PingPong::<u16>::new(2);
        pp.write_shadow(0, 1);
        pp.swap();
        let _ = pp.read(0);
        let _ = pp.read(1);
        assert_eq!(pp.counts(), (2, 1));
        assert_eq!(pp.take_counts(), (2, 1));
        assert_eq!(pp.counts(), (0, 0));
    }
}
