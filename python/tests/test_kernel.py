"""L1 correctness: Pallas kernel ≡ pure-jnp oracle, bit for bit.

Hypothesis sweeps shapes, codebook sizes, dynamics modes and spike
densities; dedicated tests pin the edge cases (saturation, pruned
synapses, partial-update semantics, padding tiles).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.snn_core import layer_step, vmem_footprint_bytes

NO_SYN = ref.NO_SYNAPSE


def make_case(rng, a, n, c, density, mp_scale, prune):
    spikes = (rng.random(a) < density).astype(np.int32)
    widx = rng.integers(0, c, size=(a, n)).astype(np.int32)
    if prune > 0:
        mask = rng.random((a, n)) < prune
        widx = np.where(mask, NO_SYN, widx)
    codebook = rng.integers(-96, 97, size=c).astype(np.int32)
    mp = rng.integers(-mp_scale, mp_scale + 1, size=n).astype(np.int32)
    return spikes, widx, codebook, mp


def run_both(spikes, widx, codebook, mp, p, block_n=128):
    got_s, got_m = layer_step(
        jnp.asarray(spikes), jnp.asarray(widx), jnp.asarray(codebook),
        jnp.asarray(mp), p, block_n=block_n)
    exp_s, exp_m = ref.layer_step_ref(
        jnp.asarray(spikes), jnp.asarray(widx), jnp.asarray(codebook),
        jnp.asarray(mp), p)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(exp_s))
    np.testing.assert_array_equal(np.asarray(got_m), np.asarray(exp_m))
    return np.asarray(got_s), np.asarray(got_m)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    a=st.integers(1, 96),
    n=st.integers(1, 200),
    c=st.sampled_from([4, 8, 16]),
    density=st.floats(0.0, 1.0),
    leak=st.sampled_from([
        (ref.LEAK_NONE, 0), (ref.LEAK_LINEAR, 3), (ref.LEAK_SHIFT, 2)]),
    reset=st.sampled_from([ref.RESET_ZERO, ref.RESET_SUBTRACT]),
    prune=st.floats(0.0, 0.9),
)
def test_kernel_matches_ref_property(seed, a, n, c, density, leak, reset,
                                     prune):
    rng = np.random.default_rng(seed)
    spikes, widx, codebook, mp = make_case(rng, a, n, c, density, 500, prune)
    p = ref.LayerParams(threshold=rng.integers(1, 400),
                        leak_mode=leak[0], leak_value=leak[1],
                        reset_mode=reset, mp_bits=16)
    run_both(spikes, widx, codebook, mp, p)


def test_no_spikes_means_no_update():
    rng = np.random.default_rng(0)
    _, widx, codebook, mp = make_case(rng, 16, 32, 16, 0.0, 300, 0.0)
    spikes = np.zeros(16, np.int32)
    p = ref.LayerParams(threshold=10, leak_mode=ref.LEAK_LINEAR,
                        leak_value=5, reset_mode=ref.RESET_SUBTRACT)
    out, new_mp = run_both(spikes, widx, codebook, mp, p)
    assert out.sum() == 0
    np.testing.assert_array_equal(new_mp, mp)  # partial update: untouched


def test_pruned_synapses_do_not_touch():
    # One axon spikes but ALL its synapses are pruned.
    spikes = np.array([1], np.int32)
    widx = np.full((1, 8), NO_SYN, np.int32)
    codebook = np.arange(-8, 8, dtype=np.int32)
    mp = np.arange(8, dtype=np.int32) * 10
    p = ref.LayerParams(threshold=5, leak_mode=ref.LEAK_LINEAR, leak_value=1,
                        reset_mode=ref.RESET_ZERO)
    out, new_mp = run_both(spikes, widx, codebook, mp, p)
    assert out.sum() == 0
    np.testing.assert_array_equal(new_mp, mp)


def test_saturation_at_mp_bits():
    spikes = np.ones(64, np.int32)
    widx = np.zeros((64, 4), np.int32)      # all point at codebook[0]
    codebook = np.array([96] + [0] * 15, np.int32)  # 64 × 96 = 6144/step
    mp = np.full(4, 30000, np.int32)
    p = ref.LayerParams(threshold=40000, leak_mode=ref.LEAK_NONE,
                        leak_value=0, reset_mode=ref.RESET_ZERO, mp_bits=16)
    out, new_mp = run_both(spikes, widx, codebook, mp, p)
    assert out.sum() == 0                    # threshold above saturation
    assert (new_mp == 32767).all()           # clamped at +2^15-1


def test_subtract_reset_keeps_residue():
    spikes = np.array([1], np.int32)
    widx = np.zeros((1, 1), np.int32)
    codebook = np.array([17] + [0] * 15, np.int32)
    mp = np.zeros(1, np.int32)
    p = ref.LayerParams(threshold=10, leak_mode=ref.LEAK_NONE, leak_value=0,
                        reset_mode=ref.RESET_SUBTRACT)
    out, new_mp = run_both(spikes, widx, codebook, mp, p)
    assert out[0] == 1 and new_mp[0] == 7


def test_linear_leak_never_crosses_zero():
    spikes = np.array([1, 1], np.int32)
    widx = np.array([[0], [0]], np.int32)
    codebook = np.array([1] + [0] * 15, np.int32)   # acc = +2
    mp = np.array([-1], np.int32)                   # m = 1, leak 5 → 0
    p = ref.LayerParams(threshold=100, leak_mode=ref.LEAK_LINEAR,
                        leak_value=5, reset_mode=ref.RESET_ZERO)
    _, new_mp = run_both(spikes, widx, codebook, mp, p)
    assert new_mp[0] == 0


def test_shift_leak_arithmetic_on_negatives():
    spikes = np.array([1], np.int32)
    widx = np.zeros((1, 1), np.int32)
    codebook = np.array([-100] + [0] * 15, np.int32)
    mp = np.zeros(1, np.int32)
    p = ref.LayerParams(threshold=1000, leak_mode=ref.LEAK_SHIFT,
                        leak_value=2, reset_mode=ref.RESET_ZERO)
    _, new_mp = run_both(spikes, widx, codebook, mp, p)
    # -100 - (-100 >> 2) = -100 - (-25) = -75
    assert new_mp[0] == -75


def test_neuron_padding_tiles_are_exact():
    # n deliberately NOT a multiple of the tile.
    rng = np.random.default_rng(7)
    spikes, widx, codebook, mp = make_case(rng, 24, 130, 16, 0.5, 200, 0.1)
    p = ref.LayerParams(threshold=50, leak_mode=ref.LEAK_LINEAR,
                        leak_value=2, reset_mode=ref.RESET_SUBTRACT)
    run_both(spikes, widx, codebook, mp, p, block_n=64)


@pytest.mark.parametrize("block_n", [16, 32, 128, 512])
def test_block_size_invariance(block_n):
    rng = np.random.default_rng(11)
    spikes, widx, codebook, mp = make_case(rng, 48, 96, 8, 0.3, 100, 0.2)
    p = ref.LayerParams(threshold=30, leak_mode=ref.LEAK_SHIFT, leak_value=3,
                        reset_mode=ref.RESET_ZERO)
    s1, m1 = run_both(spikes, widx, codebook, mp, p, block_n=block_n)
    s2, m2 = run_both(spikes, widx, codebook, mp, p, block_n=128)
    np.testing.assert_array_equal(s1, s2)
    np.testing.assert_array_equal(m1, m2)


def test_vmem_footprint_model():
    f = vmem_footprint_bytes(a=1024, n=8192, c=16, block_n=128)
    assert f["widx_tile"] == 4 * 1024 * 128
    # The per-tile working set must fit a 16 MiB TPU VMEM comfortably.
    assert f["total"] < 16 * 1024 * 1024
