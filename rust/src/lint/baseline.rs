//! The lint **ratchet**: a checked-in per-rule violation count
//! (`LINT_BASELINE.json`) that CI compares against. Same arming
//! philosophy as the bench gates:
//!
//! - count above baseline → **new violation**, fail;
//! - count below baseline → the debt was paid down, so the stale baseline
//!   must be refreshed (`lint --write-baseline`) in the same change —
//!   otherwise the headroom would let violations creep back in.
//!
//! The file is written through [`crate::util::json`], keys sorted, so
//! diffs are stable.

use crate::error::{Error, Result};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// Schema tag written into the baseline file.
pub const SCHEMA: &str = "lint-baseline-v1";

/// Per-rule pinned violation counts.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Baseline {
    pub counts: BTreeMap<String, u64>,
}

impl Baseline {
    /// Build from per-rule counts, including explicit zeros for every
    /// known rule so the file documents the full contract surface.
    pub fn from_counts(counts: BTreeMap<String, u64>) -> Self {
        Baseline { counts }
    }

    /// Read a baseline file.
    pub fn read(path: &Path) -> Result<Self> {
        let j = Json::read_file(path)?;
        let schema = j.get("schema")?.as_str()?;
        if schema != SCHEMA {
            return Err(Error::Config(format!(
                "lint baseline {}: schema {schema:?}, expected {SCHEMA:?}",
                path.display()
            )));
        }
        let mut counts = BTreeMap::new();
        for (rule, v) in j.get("rules")?.as_obj()? {
            counts.insert(rule.clone(), v.as_i64()? as u64);
        }
        Ok(Baseline { counts })
    }

    /// Serialize to the checked-in JSON form.
    pub fn to_json(&self) -> Json {
        let rules = self
            .counts
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
            .collect::<BTreeMap<String, Json>>();
        Json::obj(vec![
            ("schema", Json::Str(SCHEMA.into())),
            ("rules", Json::Obj(rules)),
        ])
    }

    /// Write the baseline file.
    pub fn write(&self, path: &Path) -> Result<()> {
        self.to_json().write_file(path)
    }

    /// Ratchet comparison: current per-rule counts vs this baseline.
    /// Returns human-readable failures; empty means the gate passes.
    pub fn check(&self, current: &BTreeMap<String, u64>) -> Vec<String> {
        let mut fails = Vec::new();
        for (rule, &cur) in current {
            let base = self.counts.get(rule).copied().unwrap_or(0);
            if cur > base {
                fails.push(format!(
                    "{rule}: {cur} violation(s), baseline pins {base} — new violations \
                     must be fixed or carry a justified lint:allow"
                ));
            } else if cur < base {
                fails.push(format!(
                    "{rule}: {cur} violation(s), baseline pins {base} — violations were \
                     fixed; refresh the ratchet with `lint --write-baseline`"
                ));
            }
        }
        // Rules in the baseline the linter no longer knows are stale too.
        for rule in self.counts.keys() {
            if !current.contains_key(rule) {
                fails.push(format!(
                    "{rule}: pinned in the baseline but unknown to the linter — \
                     refresh with `lint --write-baseline`"
                ));
            }
        }
        fails
    }
}
