//! Table I reproduction: end-to-end chip metrics per workload — accuracy,
//! pJ/SOP, power, power density, neuron density, latency — on the trained
//! artifacts at the paper's 100 MHz / 1.08 V application operating point.
//!
//! Paper anchors (this work's column): 0.96 pJ/SOP (NMNIST), 1.17 pJ/SOP
//! (DVS Gesture), 1.24 pJ/SOP (Cifar-10); accuracy 98.8 / 92.7 / 81.5 %;
//! 2.8–113 mW; 0.52 mW/mm² floor; 30.23 K neurons/mm²; 160 K neurons;
//! 1280 M synapses.

use fullerene_soc::datasets::Dataset;
use fullerene_soc::energy::{AreaModel, ChipReport};
use fullerene_soc::nn::load_weights_json;
use fullerene_soc::soc::{Soc, SocConfig};
use fullerene_soc::util::bench::Bench;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    std::env::var("FSOC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

fn main() {
    let dir = artifacts_dir();
    let samples: usize = std::env::var("FSOC_TABLE1_SAMPLES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);

    // --- static rows of Table I --------------------------------------------
    let area = AreaModel::paper_chip();
    println!("## Table I static rows");
    println!(
        "neurons {} (paper 160 K) | synapses {} M (paper 1280 M) | \
         neuron density {:.2} K/mm^2 (paper 30.23) | die {:.2} mm^2",
        area.total_neurons(),
        area.total_synapses() / (1024 * 1024),
        area.neuron_density_k_per_mm2(),
        area.die_mm2
    );

    // --- dynamic rows: run each trained workload ----------------------------
    let mut reports = Vec::new();
    let mut b = Bench::new("table1_chip");
    for name in ["nmnist", "dvsgesture", "cifar10"] {
        let wpath = dir.join(format!("{name}.weights.json"));
        let dpath = dir.join(format!("dataset_{name}.json"));
        if !wpath.exists() || !dpath.exists() {
            println!("[{name}] artifacts missing — run `make artifacts`; skipping");
            continue;
        }
        let net = load_weights_json(&wpath).expect("weights parse");
        let ds = Dataset::load_json(&dpath).expect("dataset parse");
        let mut soc = Soc::new(net.clone(), SocConfig::default()).expect("soc");
        let out = soc.run_dataset(&ds, samples).expect("run");
        let mut rep = soc.finish_report(name);
        rep.accuracy = Some(out.accuracy);
        reports.push(rep);

        // Per-sample wall-clock of the whole-chip simulator.
        let mut soc2 = Soc::new(net, SocConfig::default()).expect("soc");
        let sample = ds.samples[0].clone();
        b.bench(&format!("chip-sample/{name}"), || {
            soc2.run_sample(&sample, true).unwrap().sops
        });
    }
    if !reports.is_empty() {
        println!("\n## Table I dynamic rows (measured, {samples} samples each)");
        println!("{}", ChipReport::table(&reports).render());
        println!(
            "paper anchors: 0.96 / 1.17 / 1.24 pJ/SOP; accuracy 98.8 / 92.7 / \
             81.5 %; power floor 2.8 mW → 0.52 mW/mm^2"
        );
    }
    b.finish();
}
