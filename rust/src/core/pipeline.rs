//! The four-stage core pipeline (paper §II.A: "A four-level pipeline is
//! set up in the core, including core caches, ZSPE, SPE, and neuron
//! updater. Buffers are inserted into the pipeline to optimize data-access
//! efficiency.")
//!
//! Stage 1 (cache) reads one 16-bit spike word per cycle into the word
//! buffer; stage 2 (ZSPE) scans the buffered word, forwarding valid-spike
//! jobs into the SPE queue (stalling when the queue is full); stage 3
//! (SPE) retires up to 4 synapse ops per cycle; stage 4 (neuron updater)
//! runs as a drain phase over the touched-neuron list at one neuron per
//! cycle. The stepper advances all stages each simulated cycle, so fill,
//! drain and back-pressure stalls fall out naturally.

use super::codebook::Codebook;
use super::spe::{AccumCtx, Spe};
use super::synapses::Synapses;
use super::zspe;


/// Cycle/event statistics of one timestep's accumulation phase.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PipelineStats {
    /// Cycles spent in the accumulation phase (stages 1–3).
    pub cycles: u64,
    /// Spike words read from the cache.
    pub words_read: u64,
    /// Words scanned by the ZSPE.
    pub words_scanned: u64,
    /// Valid spikes forwarded ZSPE → SPE.
    pub spikes_forwarded: u64,
    /// Zero spikes skipped by the ZSPE.
    pub zeros_skipped: u64,
    /// Synapse operations retired by the SPE.
    pub sops: u64,
    /// Cycles the ZSPE stalled on a full SPE queue.
    pub stall_cycles: u64,
}

/// Run the accumulation phase (stages 1–3) of one timestep.
///
/// `spike_words` is the active ping-pong bank; results accumulate into
/// `ctx`. Returns per-stage statistics; the caller (the core) charges
/// energy from them and then runs the stage-4 updater drain.
pub fn run_accumulation(
    spike_words: &[u16],
    axons: usize,
    syn: &Synapses,
    cb: &Codebook,
    spe: &mut Spe,
    ctx: &mut AccumCtx,
) -> PipelineStats {
    let mut st = PipelineStats::default();
    let n_words = spike_words.len();
    let mut next_word = 0usize; // stage-1 cursor
    let mut word_buf: Option<(u16, usize)> = None; // stage-1→2 buffer
    // Pending forwards from a scanned word that didn't fit the SPE queue.
    let mut pending: Vec<u32> = Vec::new();
    let mut pending_pos = 0usize;

    loop {
        // Cycle-step only while the front stages (fetch/scan/forward) are
        // still producing work; once they are empty the remaining SPE
        // backlog is retired in one cycle-exact bulk pass below — the
        // dominant fast path at realistic fan-outs (see EXPERIMENTS §Perf).
        let front_busy =
            next_word < n_words || word_buf.is_some() || pending_pos < pending.len();
        if !front_busy {
            break;
        }
        // Fast-forward: when forwarding is blocked on a full SPE queue the
        // front stages cannot make progress until the in-flight job
        // retires — skip those cycles in one step (identical sop/cycle
        // accounting; ZSPE hang-up cycles are charged as stalls).
        if pending_pos < pending.len() && spe.free_slots() == 0 {
            let (sops, cycles) = spe.fast_forward_one_job(syn, cb, ctx);
            st.sops += sops;
            st.cycles += cycles;
            st.stall_cycles += cycles;
            continue;
        }
        st.cycles += 1;

        // ---- stage 3: SPE retires synapse ops -----------------------------
        st.sops += spe.step(syn, cb, ctx) as u64;

        // ---- stage 2: ZSPE scan / forward ---------------------------------
        if pending_pos < pending.len() {
            // Drain previously scanned spikes into freed queue slots.
            let free = spe.free_slots();
            if free == 0 {
                st.stall_cycles += 1;
            } else {
                let take = free.min(pending.len() - pending_pos);
                for &a in &pending[pending_pos..pending_pos + take] {
                    spe.push(a);
                }
                pending_pos += take;
                if pending_pos == pending.len() {
                    pending.clear();
                    pending_pos = 0;
                }
            }
        } else if let Some((word, idx)) = word_buf {
            // Scan the buffered word this cycle.
            let scan = zspe::scan_word(word, idx, axons);
            st.words_scanned += 1;
            st.zeros_skipped += scan.skipped as u64;
            st.spikes_forwarded += scan.valid_axons.len() as u64;
            let free = spe.free_slots();
            let take = free.min(scan.valid_axons.len());
            for &a in &scan.valid_axons[..take] {
                spe.push(a);
            }
            if take < scan.valid_axons.len() {
                pending = scan.valid_axons;
                pending_pos = take;
            }
            word_buf = None;
        }

        // ---- stage 1: cache word fetch ------------------------------------
        if word_buf.is_none() && pending_pos >= pending.len() && next_word < n_words {
            word_buf = Some((spike_words[next_word], next_word));
            next_word += 1;
            st.words_read += 1;
        }
    }

    // ---- drain: retire the remaining SPE backlog in bulk ------------------
    let (sops, cycles) = spe.drain_bulk(syn, cb, ctx);
    st.sops += sops;
    st.cycles += cycles;
    st
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::synapses::SynapsesBuilder;
    use crate::core::{pack_spikes, Codebook};

    fn dense_syn(axons: usize, neurons: usize, widx: u8) -> Synapses {
        let mut b = SynapsesBuilder::new(axons, neurons, 16);
        b.connect_dense(|_, _| widx).unwrap();
        b.build()
    }

    fn run(spikes: &[bool], syn: &Synapses, neurons: usize) -> (PipelineStats, Vec<i32>) {
        let cb = Codebook::default_log16();
        let words = pack_spikes(spikes);
        let mut spe = Spe::new(8);
        let mut acc = vec![0i32; neurons];
        let mut touched = vec![false; neurons];
        let mut list = Vec::new();
        let st = run_accumulation(
            &words,
            spikes.len(),
            syn,
            &cb,
            &mut spe,
            &mut AccumCtx {
                acc: &mut acc,
                touched: &mut touched,
                touched_list: &mut list,
            },
        );
        (st, acc)
    }

    #[test]
    fn all_zero_input_costs_only_scan_cycles() {
        let syn = dense_syn(32, 4, 9);
        let (st, acc) = run(&vec![false; 32], &syn, 4);
        assert_eq!(st.sops, 0);
        assert_eq!(st.words_scanned, 2);
        assert_eq!(st.zeros_skipped, 32);
        // 2 fetch + 2 scan cycles, pipelined: fetch0, (scan0|fetch1), scan1 → ≤4
        assert!(st.cycles <= 4, "cycles = {}", st.cycles);
        assert!(acc.iter().all(|&a| a == 0));
    }

    #[test]
    fn sop_count_equals_valid_spikes_times_fanout() {
        let syn = dense_syn(32, 4, 9);
        let mut spikes = vec![false; 32];
        spikes[3] = true;
        spikes[17] = true;
        spikes[31] = true;
        let (st, acc) = run(&spikes, &syn, 4);
        assert_eq!(st.sops, 3 * 4);
        assert_eq!(st.spikes_forwarded, 3);
        assert_eq!(st.zeros_skipped, 29);
        // weight(9) = 1: each neuron accumulates one per valid spike.
        assert_eq!(acc, vec![3, 3, 3, 3]);
    }

    #[test]
    fn dense_input_is_spe_bound() {
        let syn = dense_syn(64, 16, 9);
        let (st, _) = run(&vec![true; 64], &syn, 16);
        assert_eq!(st.sops, 64 * 16);
        // SPE-bound: 1024 sops / 4 lanes = 256 cycles + small fill.
        assert!(st.cycles >= 256);
        assert!(st.cycles < 256 + 16, "cycles = {}", st.cycles);
    }

    #[test]
    fn backpressure_stalls_counted_with_large_words() {
        // 16 valid spikes in one word with queue depth 8 → pending drain.
        let syn = dense_syn(16, 32, 9);
        let (st, acc) = run(&vec![true; 16], &syn, 32);
        assert_eq!(st.sops, 16 * 32);
        assert_eq!(acc, vec![16i32; 32]);
    }

    #[test]
    fn partial_word_padding_not_counted() {
        let syn = dense_syn(20, 2, 9);
        let (st, _) = run(&vec![true; 20], &syn, 2);
        assert_eq!(st.spikes_forwarded, 20);
        assert_eq!(st.zeros_skipped, 0);
        assert_eq!(st.words_scanned, 2);
    }
}
