//! Streaming session/serving API — the crate's top-level surface.
//!
//! The paper's chip is an always-on edge device consuming event streams
//! continuously; this layer makes the simulator serve the same way
//! instead of only running pre-materialized batches:
//!
//! - [`SocBuilder`] — fluent construction + **the** single validation
//!   choke point for chip/run/serving configuration (JSON, CLI flags
//!   and fluent calls all funnel through it), including the cluster
//!   surface: `chips > 1` makes every engine it builds a
//!   [`crate::cluster::Cluster`] spanning the off-chip L3 ring
//!   ([`SocBuilder::build_cluster`] / [`SocBuilder::build_engine`]);
//! - [`Workload`] — pluggable sample sources ([`SyntheticStream`],
//!   [`EventReplay`], [`TrafficWorkload`], or anything downstream
//!   implements), parsed from spec strings by [`workload_from_spec`];
//! - [`Session`] — a streaming inference session with per-push results,
//!   incremental [`Session::snapshot`] reports, per-session
//!   energy/latency ledgers and a consuming [`Session::close`] (the
//!   typestate makes "forgot `finish_report`" unrepresentable);
//! - [`ServeRuntime`] — the serving runtime: persistent worker threads
//!   pulling from a bounded submission queue ([`ServeRuntime::submit`]
//!   blocks on backpressure, [`ServeRuntime::try_submit`] surfaces
//!   [`crate::Error::QueueFull`]), **warm engine reuse** via
//!   [`crate::cluster::Engine::reset_for_session`] (bit-identical to
//!   fresh engines — one chip each, or whole clusters at `chips > 1`),
//!   per-[`SessionTicket`] waits, an [`ServeRuntime::outcomes`]
//!   iterator yielding results as sessions finish, and per-session
//!   failure isolation;
//! - [`SocPool`] — the sequential reference pool (`serve_sequential`
//!   runs a fresh engine per session on the calling thread; the
//!   runtime's bit-identity guarantee is stated against it);
//! - [`RecoveryPolicy`] — opt-in self-healing: per-session deadlines,
//!   deterministic seeded retry with simulated-cycle backoff, warm-engine
//!   quarantine thresholds, and runtime [`HealthReport`] counters
//!   ([`ServeRuntime::health_report`]); disabled by default and
//!   bit-identical to the pre-recovery behavior when off.
//!
//! The batch layer ([`crate::coordinator::ExperimentRunner`]) is rebuilt
//! on top of these primitives.

pub mod builder;
pub mod pool;
pub mod recovery;
pub mod runtime;
pub mod session;
pub mod workload;

pub use builder::SocBuilder;
pub use pool::{ServeOutcome, SessionFailure, SessionOutcome, SessionSpec, SocPool};
pub use recovery::{HealthReport, RecoveryPolicy, SessionVerdict};
pub use runtime::{Outcomes, ServeRuntime, SessionResult, SessionTicket};
pub use session::{DegradationStats, Session, SessionReport, SessionStats};
pub use workload::{
    workload_from_spec, EventReplay, SyntheticStream, TrafficWorkload, Workload,
};
